//! API-compatible stub of the `xla` PJRT crate.
//!
//! The build environment bakes in no XLA/PJRT shared library, so this
//! path crate provides the exact type/method surface
//! `anytime_mb::runtime` compiles against, with every client operation
//! returning a descriptive error at runtime (DESIGN.md §7).  The
//! artifact-gated tests and CLI paths already degrade gracefully when
//! `PjrtRuntime::load` fails, so the stub turns "missing native dep"
//! into the same skip path as "missing artifacts".
//!
//! Swapping in the real `xla` crate is a one-line Cargo.toml change; no
//! source edits are required.

use std::path::Path;

/// Stub error; formatted with `{:?}` by the runtime layer.
#[derive(Debug, Clone)]
pub struct Error(pub &'static str);

const UNAVAILABLE: Error =
    Error("xla stub: PJRT is unavailable in this build (vendored API stub; see DESIGN.md §7)");

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(UNAVAILABLE)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(UNAVAILABLE)
    }
}

/// Compiled executable handle (stub: never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(UNAVAILABLE)
    }
}

/// Device buffer handle (stub: never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(UNAVAILABLE)
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(UNAVAILABLE)
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Element dtypes the project marshals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host literal.  Construction succeeds (it is pure host data); any
/// device-touching accessor fails.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(UNAVAILABLE)
    }

    pub fn get_first_element<T>(&self) -> Result<T, Error> {
        Err(UNAVAILABLE)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e:?}").contains("stub"));
    }

    #[test]
    fn literals_construct_on_host() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8]).is_ok());
        let _ = Literal::scalar(1.0);
    }
}
