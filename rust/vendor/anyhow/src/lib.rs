//! In-tree shim of the `anyhow` error-handling API.
//!
//! The offline vendor set ships no crates.io registry (DESIGN.md §7), so
//! this path crate provides the subset of anyhow the project uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream anyhow where it matters here:
//! * `{e}` displays the outermost message, `{e:#}` the full
//!   colon-separated context chain;
//! * any `std::error::Error` converts via `?` (its `source()` chain is
//!   captured);
//! * `Error` itself does NOT implement `std::error::Error`, which is
//!   what makes the blanket `From` impl coherent — same trick as
//!   upstream.

use std::fmt;

/// Error with a context chain; `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

/// Any std error converts, capturing its `source()` chain.  Coherent
/// because [`Error`] itself does not implement `std::error::Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("reading x").unwrap_err();
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
    }

    #[test]
    fn option_context() {
        let v: Result<i32> = None.context("missing 'k'");
        assert_eq!(format!("{}", v.unwrap_err()), "missing 'k'");
        let v: Result<i32> = Some(3).with_context(|| "unused");
        assert_eq!(v.unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
