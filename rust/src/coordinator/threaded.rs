//! Real-time threaded cluster runtime: one OS thread per node,
//! mpsc-channel "network", wall-clock compute windows — the
//! production-shaped runtime (MPI → channels substitution, DESIGN.md §2).
//!
//! Executes every [`Scheme`] of the unified [`RunSpec`]:
//!
//! * **AMB** (absolute schedule; NO barrier — this is the point of AMB):
//!   epoch t owns the real-time window [t₀ + (t−1)·(T+T_c), t₀ + t·(T+T_c)).
//!   Nodes loop gradient chunks until the T deadline (admission control
//!   via an EWMA chunk-time estimate); an optional per-node slowdown
//!   factor sleeps after each chunk to induce stragglers (paper App.
//!   I.3's background jobs).
//! * **FMB**: every node computes exactly b/n gradients, however long
//!   that takes; a barrier marks the compute phase's end (the slowest
//!   node gates everyone — the behaviour AMB exists to avoid), then the
//!   T_c consensus window runs relative to the barrier.
//! * **FMB + backup/coded**: nodes race to their (possibly redundant)
//!   quota; an atomic finish counter determines the first n−ignore
//!   survivors, stragglers abandon once the cutoff passes and their work
//!   is dropped (uncoded) — attribution shared with the simulator via
//!   [`epoch::backup_attribution`].
//!
//! Consensus realizes every [`ConsensusMode`]: synchronous gossip rounds
//! (a node waits for all peers' round-k messages but abandons consensus
//! at the window deadline, keeping its last completed round — variable
//! r_i(t)), per-node jittered round targets, or exact averaging via an
//! all-to-all exchange aggregated in f64 node-index order so it computes
//! the identical average as the simulator's `Consensus::exact_average`.
//!
//! Update phase is the shared state machine: z ← m⁽ʳ⁾ / b̂(t) (b̂ from
//! the scalar side channel), w ← dual-averaging step.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::churn::ChurnSchedule;
use crate::coordinator::epoch::{self, NodeState};
use crate::coordinator::{
    ConsensusMode, EngineFactory, NodeLog, RunOutput, RunSpec, Runtime, RuntimeKind, Scheme,
};
use crate::exec::ExecEngine;
use crate::metrics::{EpochStats, RunRecord};
use crate::optim::DelayedGradients;
use crate::topology::{MixMatrix, Topology};
use crate::util::matrix::NodeMatrix;
use crate::util::rng::Pcg64;

/// The real-time cluster runtime.
pub struct ThreadedRuntime;

impl Runtime for ThreadedRuntime {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Threaded
    }

    fn run(
        &self,
        spec: &RunSpec,
        topo: &Topology,
        make_engine: EngineFactory<'_>,
        f_star: Option<f64>,
    ) -> Result<RunOutput> {
        run_threaded(spec, topo, make_engine, f_star)
    }
}

/// One consensus message on the wire.  The payload is a refcounted row
/// snapshot: a broadcast materialises the node's message row ONCE and
/// every peer (and the frozen-value cache) shares it, instead of one
/// `Vec` clone per peer per round.
struct WireMsg {
    from: usize,
    epoch: usize,
    round: usize,
    payload: Arc<[f32]>,
}

/// Per-(node, epoch) report.
struct EpochRow {
    /// Batch COMPUTED this epoch (the node-log / straggler-spread view).
    b: usize,
    /// Batch APPLIED this epoch (= `b` for undelayed schemes; the
    /// delay-ripened pipeline batch for AMB-DG, 0 during warm-up).
    applied_b: usize,
    /// Loss sum over the APPLIED batch's samples.
    applied_loss: f64,
    /// Epochs between computing and applying the applied batch.
    staleness: usize,
    rounds: usize,
    /// Real seconds spent in the compute phase.
    compute_secs: f64,
}

/// Per-node output returned at join.
struct NodeResult {
    node: usize,
    rows: Vec<EpochRow>,
    /// error metric per epoch (only node 0 fills this)
    errors: Vec<f64>,
    final_w: Vec<f32>,
}

/// Everything a node thread needs (grouping keeps the spawn site sane).
struct NodeCtx {
    node: usize,
    n: usize,
    spec: RunSpec,
    ready: Arc<Barrier>,
    phase_barrier: Arc<Barrier>,
    start_cell: Arc<OnceLock<Instant>>,
    rx: Receiver<WireMsg>,
    /// Senders index-aligned with `peers`.
    peer_txs: Vec<Sender<WireMsg>>,
    peers: Vec<usize>,
    p: Arc<MixMatrix>,
    /// Per-epoch finish counters (FmbBackup cutoff detection).
    done_counts: Arc<Vec<AtomicUsize>>,
    /// The base topology — induced Metropolis rows for churned epochs
    /// are computed locally from neighbour lists + the shared schedule.
    topo: Arc<Topology>,
    /// Per-epoch membership, identical on every node (pure function of
    /// the spec): activity needs no coordination messages.
    churn: Arc<ChurnSchedule>,
}

fn run_threaded(
    spec: &RunSpec,
    topo: &Topology,
    make_engine: EngineFactory<'_>,
    f_star: Option<f64>,
) -> Result<RunOutput> {
    // `AmbDg { delay: 0 }` IS the paper's AMB; executing it through the
    // stock AMB path keeps "D = 0 degenerates to today's AMB" true by
    // construction on real threads (the pipelined arm below requires
    // delay ≥ 1: a pre-push pop cannot apply a batch in the epoch that
    // computes it).
    let spec_norm = {
        let mut s = spec.clone();
        s.scheme = s.scheme.normalized();
        s
    };
    let spec = &spec_norm;
    let n = topo.n();
    if n < 2 {
        bail!("threaded runtime needs at least 2 nodes (got {n})");
    }
    if !(spec.slowdown.is_empty() || spec.slowdown.len() == n) {
        bail!(
            "slowdown must be empty or one factor per node (got {} factors for {n} nodes)",
            spec.slowdown.len()
        );
    }
    if !spec.network.is_abstract() {
        bail!(
            "NetworkModel::Fabric is sim-only: the threaded runtime's channels ARE its network, \
             so measured rounds come from real wall-clock deadlines, not the event fabric — run \
             fabric specs with --runtime sim"
        );
    }
    if matches!(spec.consensus, ConsensusMode::Hierarchical { .. }) {
        bail!(
            "ConsensusMode::Hierarchical is sim-only: the threaded runtime has no \
             shard-aggregator wire protocol — run this spec on --runtime sim"
        );
    }
    spec.faults.validate(n)?;
    if spec.faults.has_link_faults() && spec.consensus == ConsensusMode::Exact {
        // Same policy (and wording) as the simulator's dispatch.
        bail!(
            "link faults (loss/flap) require a gossip consensus mode: Exact consensus \
             models a lossless master aggregation with no per-link messages to drop — \
             use crashes only, or switch to Gossip/GossipJitter"
        );
    }
    let p = Arc::new(topo.metropolis().lazy());

    // Under Exact consensus the communication graph is all-to-all
    // (paper Remark 1: ε = 0 recovers master aggregation); otherwise the
    // wire graph is the topology's neighbour lists.
    let exact = spec.consensus == ConsensusMode::Exact;
    let peer_ids: Vec<Vec<usize>> = (0..n)
        .map(|i| {
            if exact {
                (0..n).filter(|&j| j != i).collect()
            } else {
                topo.neighbors(i).to_vec()
            }
        })
        .collect();

    // Build the "network": one receiver per node, senders fanned out.
    let mut txs: Vec<Sender<WireMsg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<WireMsg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<WireMsg>();
        txs.push(tx);
        rxs.push(Some(rx));
    }

    // The common clock t0 is agreed on AFTER every node has built its
    // engine (PJRT compilation can take seconds) — otherwise the first
    // epochs would already be over before any node could compute.
    let ready = Arc::new(Barrier::new(n));
    let phase_barrier = Arc::new(Barrier::new(n));
    let start_cell: Arc<OnceLock<Instant>> = Arc::new(OnceLock::new());
    let done_counts: Arc<Vec<AtomicUsize>> =
        Arc::new((0..spec.epochs).map(|_| AtomicUsize::new(0)).collect());
    let topo_arc = Arc::new(topo.clone());
    let churn = Arc::new(ChurnSchedule::new(&spec.churn, n, spec.epochs));

    let results: Vec<NodeResult> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..n {
            let ctx = NodeCtx {
                node: i,
                n,
                spec: spec.clone(),
                ready: ready.clone(),
                phase_barrier: phase_barrier.clone(),
                start_cell: start_cell.clone(),
                // amb-lint: allow(D4, "each node thread takes its own rx exactly once")
                rx: rxs[i].take().unwrap(),
                peer_txs: peer_ids[i].iter().map(|&j| txs[j].clone()).collect(),
                peers: peer_ids[i].clone(),
                p: p.clone(),
                done_counts: done_counts.clone(),
                topo: topo_arc.clone(),
                churn: churn.clone(),
            };
            handles.push(scope.spawn(move || node_main(ctx, make_engine)));
        }
        drop(txs);
        // amb-lint: allow(D4, "join propagates a node-thread panic to the caller")
        handles.into_iter().map(|h| h.join().expect("node thread panicked")).collect()
    });

    Ok(assemble(spec, n, results, f_star, &churn))
}

/// Leader-side assembly of the per-node reports into the common
/// [`RunOutput`] (times converted back to spec units).
fn assemble(
    spec: &RunSpec,
    n: usize,
    mut results: Vec<NodeResult>,
    f_star: Option<f64>,
    // the SAME schedule instance the node threads evaluated (one build
    // per run; the table is a pure function of the spec either way)
    churn: &ChurnSchedule,
) -> RunOutput {
    results.sort_by_key(|r| r.node);
    let dim = results.first().map_or(0, |r| r.final_w.len());
    let scale = spec.time_scale;
    // Anytime-window schemes: undone work is unobservable in real time,
    // so the recorded potential is the applied batch.
    let is_anytime = matches!(spec.scheme, Scheme::Amb { .. } | Scheme::AmbDg { .. });

    let mut record = RunRecord::new(&spec.name, f_star);
    let mut node_log = spec.record_node_log.then(|| NodeLog::new(n));
    let mut rounds = vec![Vec::new(); n];
    let mut active_counts = Vec::with_capacity(spec.epochs);
    let mut wall = 0.0f64;
    for t in 1..=spec.epochs {
        // The epoch's effective membership: churn minus crashed nodes
        // (the same pure schedule every node thread evaluated).
        let churn_active = churn.active(t);
        let active: Vec<bool> =
            (0..n).map(|j| churn_active[j] && !spec.faults.crashed(j, t)).collect();
        let act_count = active.iter().filter(|&&a| a).count();
        active_counts.push(act_count);
        // Per-epoch quota over the ACTIVE cluster (None for AMB/AMB-DG).
        let quota = epoch::work_quota(&spec.scheme, act_count);
        let mut b_t = 0usize;
        let mut loss = 0.0f64;
        let mut min_b = usize::MAX;
        let mut max_b = 0usize;
        let mut max_compute = 0.0f64;
        let mut max_staleness = 0usize;
        let mut staleness_wsum = 0.0f64;
        for r in &results {
            let row = &r.rows[t - 1];
            // b(t) is what the epoch's update consumed; min/max stay the
            // COMPUTED per-node batches (the node-log view), matching
            // the simulator's convention.
            b_t += row.applied_b;
            loss += row.applied_loss;
            min_b = min_b.min(row.b);
            max_b = max_b.max(row.b);
            if row.applied_b > 0 {
                max_staleness = max_staleness.max(row.staleness);
                staleness_wsum += (row.applied_b * row.staleness) as f64;
            }
            // Dropped backup stragglers and absent nodes do not gate the
            // epoch (the sim's epoch_compute_time is the survivors'
            // cutoff); their time must not inflate the wall clock.
            if quota.is_none() || row.b > 0 {
                max_compute = max_compute.max(row.compute_secs);
            }
            if let Some(log) = node_log.as_mut() {
                let ct = match spec.scheme {
                    Scheme::Amb { t_compute, .. } if active[r.node] => t_compute,
                    Scheme::Amb { .. } => 0.0,
                    // AMB-DG's compute window is whatever the consensus
                    // head of the window left over — log the measured
                    // pipelined compute time.
                    _ => row.compute_secs / scale,
                };
                log.push(r.node, row.b, ct);
            }
            rounds[r.node].push(row.rounds);
        }
        wall = match spec.scheme {
            // The anytime schemes land on the absolute schedule by
            // construction — `Scheme::epoch_wall` is the ONE cadence
            // formula shared with the simulator's accumulation, so the
            // two runtimes' wall clocks cannot drift apart.
            Scheme::Amb { t_compute, .. } | Scheme::AmbDg { t_compute, .. } => {
                t as f64 * spec.scheme.epoch_wall(t_compute)
            }
            // Quota schemes are gated by the slowest (surviving) node.
            _ => wall + max_compute / scale + spec.scheme.t_consensus(),
        };
        // Potential work c(t): the quota schemes know exactly what was
        // assigned to each PRESENT node; an anytime window's undone work
        // is unobservable in real time, and absent nodes have none.
        let potential = if is_anytime {
            b_t
        } else {
            let work = quota.unwrap_or(0);
            results
                .iter()
                .map(|r| if active[r.node] { work.max(r.rows[t - 1].b) } else { 0 })
                .sum()
        };
        record.push(EpochStats {
            epoch: t,
            wall_time: wall,
            batch: b_t,
            potential,
            loss: if b_t > 0 { loss / b_t as f64 } else { f64::NAN },
            error: results[0].errors[t - 1],
            consensus_err: f64::NAN, // not observable without global state
            min_node_batch: min_b,
            max_node_batch: max_b,
            max_staleness,
            mean_staleness: if b_t > 0 { staleness_wsum / b_t as f64 } else { f64::NAN },
            // No global observer on real threads: under active faults
            // the drift exists but is not measurable here (the sim
            // reports it); all-clear runs are exactly conservative.
            conservation_drift: if spec.faults.is_none() { 0.0 } else { f64::NAN },
        });
    }
    let mut final_w = NodeMatrix::new(n, dim);
    for r in &results {
        final_w.row_mut(r.node).copy_from_slice(&r.final_w);
    }
    RunOutput { record, node_log, final_w, rounds, active_counts }
}

/// AMB's anytime gradient accumulation: admission-controlled chunks on
/// the node's canonical data stream until `deadline` (a gradient that
/// cannot finish in time is never started — Algorithm 1's
/// `while current_time − T0 ≤ T`), napping after each chunk per the
/// slowdown factor, EWMA-updating the chunk-duration estimate.  ONE
/// function serves both the serialized AMB compute window and the
/// pipelined AMB-DG window (which simply passes the epoch's end as the
/// deadline), so the two compute paths cannot drift.  Returns (batch,
/// loss sum); gradients accumulate into `st.grad_sum`.
fn anytime_compute(
    engine: &mut dyn ExecEngine,
    st: &mut NodeState,
    data_rng: &mut Pcg64,
    deadline: Instant,
    est_chunk: &mut Duration,
    slowdown: f64,
    grad_chunk: usize,
) -> (usize, f64) {
    let mut b_i = 0usize;
    let mut loss_i = 0.0f64;
    while Instant::now() + est_chunk.mul_f64(0.9) < deadline {
        let chunk_t0 = Instant::now();
        loss_i += engine.grad_chunk(&st.w, grad_chunk, data_rng, &mut st.grad_sum);
        b_i += grad_chunk;
        if slowdown > 1.0 {
            let busy = chunk_t0.elapsed();
            let nap = busy.mul_f64(slowdown - 1.0);
            if Instant::now() + nap < deadline + Duration::from_millis(2) {
                std::thread::sleep(nap);
            } else {
                sleep_until(deadline);
            }
        }
        // EWMA over observed chunk times, including the nap.
        let observed = chunk_t0.elapsed();
        *est_chunk = est_chunk.mul_f64(0.5) + observed.mul_f64(0.5);
    }
    if b_i == 0 {
        // Nothing admitted: the estimate may be stale-high (scheduler
        // spike, paging); decay it so the node can re-probe instead of
        // starving forever.
        *est_chunk = est_chunk.mul_f64(0.5);
    }
    (b_i, loss_i)
}

/// One epoch's consensus phase over the wire — every [`ConsensusMode`],
/// shared by the serialized (AMB/FMB: consensus after compute) and
/// pipelined (AMB-DG: consensus at the head of the window, overlapping
/// the compute that follows) epoch layouts, so the two cannot drift.
/// `m` is the node's encoded wire row; an absent node neither sends nor
/// mixes (nobody addresses it — every sender reads the same schedule)
/// and `m` comes back untouched.  Returns completed gossip rounds.
#[allow(clippy::too_many_arguments)]
fn consensus_phase(
    ctx: &NodeCtx,
    t: usize,
    on: bool,
    active: &[bool],
    act_count: usize,
    dim: usize,
    m: &mut [f32],
    inbox: &mut HashMap<(usize, usize, usize), Arc<[f32]>>,
    consensus_deadline: Instant,
) -> usize {
    let spec = &ctx.spec;
    let (i, n) = (ctx.node, ctx.n);
    let mut rounds_done = 0usize;
    match spec.consensus {
        // Absent this epoch: no sends, no mixing, m/z/w held.
        _ if !on => {}
        ConsensusMode::Exact => {
            // All-to-all exchange among the ACTIVE set; aggregate in
            // f64 node-index order over |A| rows so the result equals
            // the simulator's active-mean bit-for-bit given equal
            // inputs.
            let payload: Arc<[f32]> = Arc::from(&m[..]);
            for (idx, tx) in ctx.peer_txs.iter().enumerate() {
                if active[ctx.peers[idx]] {
                    let _ = tx.send(WireMsg {
                        from: i,
                        epoch: t,
                        round: 0,
                        payload: payload.clone(),
                    });
                }
            }
            let mut have: Vec<Option<Arc<[f32]>>> = (0..n).map(|_| None).collect();
            let mut missing = act_count - 1;
            for j in 0..n {
                if j != i && active[j] {
                    if let Some(pl) = inbox.remove(&(t, 0, j)) {
                        have[j] = Some(pl);
                        missing -= 1;
                    }
                }
            }
            while missing > 0 {
                let Some(msg) = recv_backoff(&ctx.rx, consensus_deadline) else { break };
                if msg.epoch == t && msg.round == 0 && msg.from != i
                    && active[msg.from]
                    && have[msg.from].is_none()
                {
                    have[msg.from] = Some(msg.payload);
                    missing -= 1;
                } else {
                    inbox.insert((msg.epoch, msg.round, msg.from), msg.payload);
                }
            }
            if missing == 0 {
                let mut sum = vec![0.0f64; dim + 1];
                for j in 0..n {
                    if !active[j] {
                        continue;
                    }
                    let pj: &[f32] =
                        // amb-lint: allow(D4, "missing == 0 checked above: every peer snapshot is present")
                        if j == i { &*m } else { have[j].as_deref().expect("missing == 0") };
                    for k in 0..=dim {
                        sum[k] += pj[k] as f64;
                    }
                }
                for (v, &s) in m.iter_mut().zip(&sum) {
                    *v = (s / act_count as f64) as f32;
                }
            }
            // else: T_c expired with peers missing — keep own m (the
            // node runs this epoch isolated, normalised by its own
            // n·b_i side channel).
        }
        ConsensusMode::Gossip { .. } | ConsensusMode::GossipJitter { .. } => {
            // Every node can derive every peer's round budget (the
            // jitter draw is a pure function of (seed, node, epoch)),
            // so when a peer has stopped gossiping we mix against its
            // last-sent (frozen) value instead of stalling until the
            // deadline — mirroring the simulator's `run_per_node`
            // freeze semantics.
            let budget_of = |node: usize| -> usize {
                match spec.consensus {
                    ConsensusMode::Gossip { rounds } => rounds,
                    ConsensusMode::GossipJitter { mean, jitter } => {
                        epoch::gossip_jitter_rounds(spec.seed, node, t, mean, jitter)
                    }
                    ConsensusMode::Exact | ConsensusMode::Hierarchical { .. } => {
                        // amb-lint: allow(D4, "loop exits only via the returns above")
                        unreachable!()
                    }
                }
            };
            // This epoch's gossip runs over the ACTIVE subgraph:
            // `epeers` indexes the active peers, and the mixing row
            // is the base lazy Metropolis row when everyone is
            // present (the static path, zero recompute) or the
            // induced-subgraph row — derived locally from neighbour
            // lists + the shared schedule, matching the simulator's
            // `Topology::induced(..).metropolis().lazy()` weights —
            // when somebody churned.
            // Link faults are decided at the RECEIVER: `dropped` is a
            // pure function of (spec, epoch, round, edge), the very
            // function the sim's per-epoch masks are built from, so
            // both runtimes lose the identical messages for a spec.
            // Senders stay oblivious (a real network's sender cannot
            // know a packet will be lost); receivers discard doomed
            // payloads on arrival and mix their own pre-mix row in the
            // lost peer's slot, keeping the mixing row stochastic.
            let faults = &spec.faults;
            let has_link = faults.has_link_faults();
            let epeers: Vec<usize> =
                (0..ctx.peers.len()).filter(|&idx| active[ctx.peers[idx]]).collect();
            let (pii, pw): (f32, Vec<f32>) = if act_count == n {
                (
                    ctx.p.at(i, i) as f32,
                    epeers.iter().map(|&idx| ctx.p.at(i, ctx.peers[idx]) as f32).collect(),
                )
            } else {
                // Gossip peers are the adjacency list in ascending
                // order, and `epeers` filters it in order, so the
                // helper's weights align 1:1 with `epeers`.
                let (d, w) = ctx.topo.induced_lazy_metropolis_row(active, i);
                debug_assert_eq!(w.len(), epeers.len());
                (d as f32, w.iter().map(|&x| x as f32).collect())
            };
            // A peer sends round 0 unconditionally, then round k after
            // its k-th mix — INCLUDING its final post-budget state, so
            // the frozen value neighbours fall back on is the peer's
            // post-B-mix state, exactly what `run_per_node` mixes
            // against for an exhausted node.
            let peer_sends = |node: usize, round: usize| -> bool {
                round <= budget_of(node)
            };
            let max_rounds = if epeers.is_empty() {
                // Nobody to exchange with (churn isolated us): the
                // induced row is eᵢ, so mixing is the identity —
                // skip it rather than spin against the deadline.
                0
            } else {
                budget_of(i)
            };
            // Frozen-peer tracking is only needed when budgets can
            // differ across nodes (jitter); under uniform Gossip the
            // fallback never triggers, so skip the per-message clones.
            let track_frozen =
                matches!(spec.consensus, ConsensusMode::GossipJitter { .. });
            // Round 0 is sent even on a zero budget (jitter lo = 0):
            // it is the frozen value active peers mix against.
            if !epeers.is_empty() {
                let payload: Arc<[f32]> = Arc::from(&m[..]);
                for &idx in &epeers {
                    let _ = ctx.peer_txs[idx].send(WireMsg {
                        from: i,
                        epoch: t,
                        round: 0,
                        payload: payload.clone(),
                    });
                }
            }
            // Most recent payload seen from each active peer this
            // epoch (per-sender mpsc order makes "latest" = highest
            // round).
            let mut latest: Vec<Option<Arc<[f32]>>> = vec![None; epeers.len()];
            // Round-k collection slots, reused across rounds.
            let mut have: Vec<Option<Arc<[f32]>>> = vec![None; epeers.len()];
            let mut round = 0usize;
            'rounds: while round < max_rounds {
                // This round's losses (receiver-side, pure): a dropped
                // peer is satisfied immediately — its slot mixes our
                // own pre-mix row below, never a payload.  The drop
                // verdict outranks the frozen fallback: the sim's
                // masked kernel substitutes the receiver's row even
                // when the source is a frozen (budget-exhausted) node.
                let drop_from: Vec<bool> = epeers
                    .iter()
                    .map(|&idx| has_link && faults.dropped(t, round, ctx.peers[idx], i))
                    .collect();
                // collect all active peers' round-`round` messages
                for h in have.iter_mut() {
                    *h = None;
                }
                let mut missing = epeers.len();
                // drain buffered messages; fall back to frozen values
                // for peers whose budget is exhausted
                for (e, &idx) in epeers.iter().enumerate() {
                    let j = ctx.peers[idx];
                    if drop_from[e] {
                        missing -= 1;
                    } else if let Some(pl) = inbox.remove(&(t, round, j)) {
                        if track_frozen {
                            latest[e] = Some(pl.clone());
                        }
                        have[e] = Some(pl);
                        missing -= 1;
                    } else if !peer_sends(j, round) {
                        if let Some(frozen) = latest[e].clone() {
                            have[e] = Some(frozen);
                            missing -= 1;
                        }
                        // else: j's round-0 is still in flight; wait
                        // for it below.
                    }
                }
                while missing > 0 {
                    // T_c exhausted mid-round: keep m as-is
                    let Some(msg) = recv_backoff(&ctx.rx, consensus_deadline) else {
                        break 'rounds;
                    };
                    if has_link && faults.dropped(msg.epoch, msg.round, msg.from, i) {
                        // Lost on the wire: never buffered, never
                        // frozen — the channel delivered it, the
                        // modeled link did not.
                        continue;
                    }
                    let peer_e = (msg.epoch == t)
                        .then(|| {
                            epeers
                                .iter()
                                .position(|&idx| ctx.peers[idx] == msg.from)
                        })
                        .flatten();
                    if let Some(e) = peer_e {
                        if track_frozen {
                            latest[e] = Some(msg.payload.clone());
                        }
                        if msg.round == round && have[e].is_none() && !drop_from[e] {
                            have[e] = Some(msg.payload);
                            missing -= 1;
                            // a frozen-eligible peer may have
                            // just delivered its round 0
                            continue;
                        }
                    }
                    // stale/early message: buffer for later rounds
                    inbox.insert((msg.epoch, msg.round, msg.from), msg.payload);
                    // re-check frozen fallbacks now that
                    // `latest` may have been filled
                    for (e, &idx) in epeers.iter().enumerate() {
                        let j = ctx.peers[idx];
                        if have[e].is_none() && !drop_from[e] && !peer_sends(j, round) {
                            if let Some(frozen) = latest[e].clone() {
                                have[e] = Some(frozen);
                                missing -= 1;
                            }
                        }
                    }
                }
                if missing > 0 {
                    break 'rounds;
                }
                // m ← P_ii m + Σ_{j ∈ A ∩ N(i)} P_ij (dropped(i←j) ? m : m_j)
                // — the substitution reads the PRE-mix row, so snapshot
                // it before scaling by P_ii (sim's `mix_into_masked`).
                let m_pre: Option<Vec<f32>> =
                    drop_from.iter().any(|&d| d).then(|| m.to_vec());
                for v in m.iter_mut() {
                    *v *= pii;
                }
                for (e, _) in epeers.iter().enumerate() {
                    let pij = pw[e];
                    let mj: &[f32] = if drop_from[e] {
                        // amb-lint: allow(D4, "a drop recorded for e implies its snapshot was taken")
                        m_pre.as_deref().expect("drop implies snapshot")
                    } else {
                        // amb-lint: allow(D4, "missing == 0 checked above: every peer snapshot is present")
                        have[e].as_deref().expect("missing == 0")
                    };
                    for k in 0..=dim {
                        m[k] += pij * mj[k];
                    }
                }
                round += 1;
                // Broadcast the post-mix state — peers at this round
                // consume it live; peers past our budget freeze on it
                // (the final broadcast at round == max_rounds exists
                // only for that freeze path, so uniform Gossip skips
                // it).  Don't start a send we can't finish inside the
                // window.
                if round == max_rounds && !track_frozen {
                    break;
                }
                if Instant::now() >= consensus_deadline {
                    break 'rounds;
                }
                let payload: Arc<[f32]> = Arc::from(&m[..]);
                for &idx in &epeers {
                    let _ = ctx.peer_txs[idx].send(WireMsg {
                        from: i,
                        epoch: t,
                        round,
                        payload: payload.clone(),
                    });
                }
            }
            rounds_done = round;
        }
        // Rejected with a clean error before any thread spawned
        // (run_threaded's upfront validation).
        ConsensusMode::Hierarchical { .. } => {
            // amb-lint: allow(D4, "Hierarchical is rejected by run_threaded before node_main runs")
            unreachable!("Hierarchical is rejected by run_threaded before node_main runs")
        }
    }
    rounds_done
}

fn node_main(ctx: NodeCtx, make_engine: EngineFactory<'_>) -> NodeResult {
    let spec = &ctx.spec;
    let (i, n) = (ctx.node, ctx.n);
    let scale = spec.time_scale;
    let t_consensus_real = spec.scheme.t_consensus() * scale;

    let mut engine = make_engine(i);
    let mut st = NodeState::new(&*engine);
    let dim = st.dim();
    // Defensive clamp: a spec built without the builder (e.g. struct
    // literal or JSON) could carry grad_chunk = 0, which would stall the
    // quota loop forever.
    let grad_chunk = spec.grad_chunk.max(1);
    let mut metric_rng = epoch::metric_rng(spec.seed, i);
    let mut warm_rng = epoch::warmup_rng(spec.seed, i);
    let mut redundant_rng = epoch::redundancy_rng(spec.seed, i);
    let slowdown = spec.slowdown.get(i).copied().unwrap_or(1.0);

    // Out-of-order message store: (epoch, round, from) -> shared payload.
    let mut inbox: HashMap<(usize, usize, usize), Arc<[f32]>> = HashMap::new();

    let mut rows = Vec::with_capacity(spec.epochs);
    let mut errors = Vec::with_capacity(spec.epochs);

    // The node's wire row, allocated once and re-encoded in place each
    // epoch (the sim's arena row, one node wide).
    let mut m = vec![0.0f32; dim + 1];

    // Warm up the engine and prime the chunk-duration estimate used for
    // admission control.  The FIRST call pays lazy-init costs (PJRT
    // compilation can take seconds) and must not poison the estimate —
    // an estimate ≥ the compute window would admit no chunk and, since
    // the EWMA only updates after an admitted chunk, could never
    // correct — so a SECOND call measures the steady state.  Warm-up
    // draws from a dedicated stream so the node's data sequence stays
    // identical to the simulator's (runtime-parity invariant).
    let mut est_chunk = {
        let mut scratch = vec![0.0f32; dim];
        let _ = engine.grad_chunk(&st.w, grad_chunk, &mut warm_rng, &mut scratch);
        let t0 = Instant::now();
        let _ = engine.grad_chunk(&st.w, grad_chunk, &mut warm_rng, &mut scratch);
        t0.elapsed()
    };

    // FmbBackup bookkeeping shared with the simulator's attribution.
    // `ignore` stays UNclamped here: under churn the per-epoch clamp is
    // against the ACTIVE count, computed inside the epoch loop.
    let (ignore, coded, per_node_batch) = match spec.scheme {
        Scheme::FmbBackup { per_node_batch, ignore, coded, .. } => (ignore, coded, per_node_batch),
        Scheme::Fmb { per_node_batch, .. } => (0, false, per_node_batch),
        Scheme::Amb { .. } | Scheme::AmbDg { .. } => (0, false, 0),
    };

    // AMB-DG pipeline ring (run_threaded normalized delay 0 away, so a
    // ring here always has delay ≥ 1 and uses the pre-push pop: the
    // batch it feeds to consensus was computed in an EARLIER epoch).
    let mut ring = match spec.scheme {
        Scheme::AmbDg { delay, .. } => Some(DelayedGradients::new(delay)),
        _ => None,
    };

    // Engine is built and warm; rendezvous, then agree on the common t0.
    ctx.ready.wait();
    let start = *ctx.start_cell.get_or_init(|| Instant::now() + Duration::from_millis(20));

    let has_crashes = spec.faults.has_crashes();

    for t in 1..=spec.epochs {
        st.begin_epoch();
        // Per-(node, epoch) stream, identical to the simulator's.
        let mut data_rng = epoch::data_rng(spec.seed, i, t);
        // Membership is a pure function of the spec: every node reads
        // the same table, so nobody waits on an absent peer.  Crashes
        // compose with churn via membership — a crashed node is simply
        // absent — but unlike churn's frozen absence the node LOSES its
        // state at onset and re-syncs from peers on rejoin.
        let churn_active = ctx.churn.active(t);
        let eff_active: Vec<bool>;
        let active: &[bool] = if has_crashes {
            eff_active =
                (0..n).map(|j| churn_active[j] && !spec.faults.crashed(j, t)).collect();
            &eff_active
        } else {
            churn_active
        };
        let on = active[i];
        let act_count = active.iter().filter(|&&a| a).count();
        if has_crashes && spec.faults.crash_onset(i, t) {
            // The crash forgets everything: fresh optimizer state,
            // empty pipeline ring, cleared wire row.  (`est_chunk`
            // survives — it estimates the hardware, not the model.)
            st = NodeState::new(&*engine);
            if let Scheme::AmbDg { delay, .. } = spec.scheme {
                ring = Some(DelayedGradients::new(delay));
            }
            m.fill(0.0);
        }
        // First epoch back: join consensus with a zero-mass row (no
        // compute), so the update gate hands this node the
        // neighborhood average — the re-sync happens exactly once.
        let rejoin = has_crashes && on && spec.faults.rejoining(i, t);
        let mut b_i = 0usize;
        let mut loss_i = 0.0f64;
        let compute_secs;
        let rounds_done;
        // What this epoch APPLIES: (batch, loss, staleness).  The
        // undelayed schemes overwrite it with the batch just computed;
        // the AMB-DG arm with the pipeline pop.
        let applied: (usize, f64, usize);

        match spec.scheme {
            Scheme::AmbDg { t_compute, t_consensus, delay: _ } => {
                // ---- pipelined epoch (AMB-DG): consensus at the head of
                // the window, compute filling everything after it ----
                // The absolute schedule ticks in max(T, T_c) steps: the
                // consensus for the PREVIOUS epoch's batch and this
                // epoch's compute share one window instead of being laid
                // end to end.  A node thread is single-threaded, so the
                // two are SERIALIZED within the window — the pipelining
                // win is that under a finite gossip budget the rounds
                // complete as soon as peers respond (milliseconds, not
                // the T_c deadline; all nodes enter consensus together
                // at the window head), and the ENTIRE residual window is
                // gradient time, where AMB idles from consensus
                // completion to its T_c deadline by construction.  A
                // deadline-bound budget (GOSSIP_UNTIL_DEADLINE) instead
                // spends the full T_c gossiping and leaves only
                // max(T, T_c) − T_c to compute — prefer finite budgets
                // for pipelined runs (DESIGN.md §pipelining).
                let epoch_len = spec.scheme.epoch_wall(t_compute) * scale;
                let epoch_start = start + Duration::from_secs_f64((t - 1) as f64 * epoch_len);
                let epoch_deadline = epoch_start + Duration::from_secs_f64(epoch_len);
                let consensus_deadline =
                    epoch_start + Duration::from_secs_f64(t_consensus * scale);
                sleep_until(epoch_start);
                // Encode the delay-ripened batch against the CURRENT
                // dual (the gradients saw the stale primal; the dual
                // weight is today's z — the sim's `encode_msg_into`
                // call, same kernel).
                if on {
                    // amb-lint: allow(D4, "AmbDg scheme always carries a delay ring")
                    match ring.as_mut().expect("AmbDg carries a ring").pop_ready_pre_push() {
                        Some(p) => {
                            epoch::encode_msg_into(&st.z, &p.grad_sum, n, p.batch, &mut m);
                            applied = (p.batch, p.loss, t - p.epoch);
                            // amb-lint: allow(D4, "AmbDg scheme always carries a delay ring")
                            ring.as_mut().unwrap().recycle(p);
                        }
                        None => {
                            // Warm-up: nothing aged enough — an empty
                            // message carries no mass, peers ignore it.
                            m.fill(0.0);
                            applied = (0, 0.0, 0);
                        }
                    }
                } else {
                    applied = (0, 0.0, 0);
                }
                rounds_done = consensus_phase(
                    &ctx,
                    t,
                    on,
                    active,
                    act_count,
                    dim,
                    &mut m,
                    &mut inbox,
                    consensus_deadline,
                );
                // Compute at the STALE primal w(t) until the window ends
                // — the dual/primal update below runs only after this,
                // so the gradients the ring records were evaluated at
                // the pre-update iterate, exactly the sim's delay model.
                // An absent node idles the window out (absolute schedule).
                if on && !rejoin {
                    let compute_t0 = Instant::now();
                    let (b, l) = anytime_compute(
                        &mut *engine,
                        &mut st,
                        &mut data_rng,
                        epoch_deadline,
                        &mut est_chunk,
                        slowdown,
                        grad_chunk,
                    );
                    b_i = b;
                    loss_i = l;
                    // amb-lint: allow(D4, "AmbDg scheme always carries a delay ring")
                    ring.as_mut().unwrap().push(t, b_i, loss_i, &st.grad_sum);
                    compute_secs = compute_t0.elapsed().as_secs_f64();
                } else if on {
                    // Rejoin: no compute, but the pipeline cadence must
                    // hold — push the empty batch so pops stay aligned
                    // with epochs.
                    // amb-lint: allow(D4, "AmbDg scheme always carries a delay ring")
                    ring.as_mut().unwrap().push(t, 0, 0.0, &st.grad_sum);
                    compute_secs = 0.0;
                } else {
                    compute_secs = 0.0;
                }
                sleep_until(epoch_deadline);
            }
            Scheme::Amb { t_compute, .. } => {
                // ---- compute phase: anytime gradient accumulation ----
                // Admission control lives in `anytime_compute` (a
                // gradient that cannot finish by T is never started).
                let epoch_len = spec.scheme.epoch_wall(t_compute) * scale;
                let epoch_start = start + Duration::from_secs_f64((t - 1) as f64 * epoch_len);
                let compute_deadline = epoch_start + Duration::from_secs_f64(t_compute * scale);
                let epoch_deadline = epoch_start + Duration::from_secs_f64(epoch_len);
                sleep_until(epoch_start);
                // An absent node idles the window out (the absolute
                // schedule ticks on regardless — DESIGN.md §churn); a
                // rejoining node idles too (zero-mass re-sync epoch).
                if on && !rejoin {
                    let (b, l) = anytime_compute(
                        &mut *engine,
                        &mut st,
                        &mut data_rng,
                        compute_deadline,
                        &mut est_chunk,
                        slowdown,
                        grad_chunk,
                    );
                    b_i = b;
                    loss_i = l;
                }
                sleep_until(compute_deadline);
                compute_secs = if on && !rejoin { t_compute * scale } else { 0.0 };
                if on {
                    st.encode_into(n, b_i, &mut m);
                }
                applied = (b_i, loss_i, 0);
                rounds_done = consensus_phase(
                    &ctx,
                    t,
                    on,
                    active,
                    act_count,
                    dim,
                    &mut m,
                    &mut inbox,
                    epoch_deadline,
                );
            }
            Scheme::Fmb { .. } | Scheme::FmbBackup { .. } => {
                // ---- compute phase: race to the quota ----
                // The epoch's effective cluster is its ACTIVE set: the
                // quota, the coded attribution, and the survivor count
                // all use |A(t)| — matching the simulator's plan (shared
                // helpers in `epoch`).  Absent nodes skip the race but
                // still hit both barriers, so phases stay aligned.
                let ignore_eff = ignore.min(act_count.saturating_sub(1));
                // amb-lint: allow(D4, "scheme validated at RunSpec construction; quota exists for every scheme")
                let work = epoch::work_quota(&spec.scheme, act_count).unwrap();
                // Gradients beyond this count are pure redundancy (coded):
                // they cost real time but their sums are never used.
                let attributed =
                    epoch::backup_attribution(true, coded, per_node_batch, act_count, ignore_eff);
                let survivors = act_count - ignore_eff;
                let is_backup = matches!(spec.scheme, Scheme::FmbBackup { .. });
                // Align the epoch start: without this, a node delayed in
                // the PREVIOUS epoch's consensus window could find the
                // finish counter already saturated and be dropped for
                // lateness it didn't have (the sim drops the `ignore`
                // slowest by compute time, never by consensus luck).
                ctx.phase_barrier.wait();
                if on && !rejoin {
                    let compute_t0 = Instant::now();
                    let mut done = 0usize;
                    let mut abandoned = false;
                    let mut scratch: Vec<f32> = Vec::new();
                    while done < work {
                        if is_backup
                            && ctx.done_counts[t - 1].load(Ordering::SeqCst) >= survivors
                        {
                            // Cutoff passed: this node is a dropped straggler.
                            abandoned = true;
                            break;
                        }
                        let chunk_t0 = Instant::now();
                        let take = grad_chunk.min(work - done);
                        let main_take =
                            if done < attributed { take.min(attributed - done) } else { 0 };
                        if main_take > 0 {
                            loss_i += engine.grad_chunk(
                                &st.w,
                                main_take,
                                &mut data_rng,
                                &mut st.grad_sum,
                            );
                        }
                        let redundant = take - main_take;
                        if redundant > 0 {
                            // Redundant work burns real compute time but its
                            // gradients are never attributed; a dedicated RNG
                            // stream keeps the attributed data sequence equal
                            // to the simulator's.
                            scratch.clear();
                            scratch.resize(dim, 0.0);
                            let _ = engine.grad_chunk(
                                &st.w,
                                redundant,
                                &mut redundant_rng,
                                &mut scratch,
                            );
                        }
                        done += take;
                        if slowdown > 1.0 {
                            std::thread::sleep(chunk_t0.elapsed().mul_f64(slowdown - 1.0));
                        }
                    }
                    let on_time = if abandoned {
                        false
                    } else {
                        // Only ACTIVE nodes enter the finish race.
                        let rank = ctx.done_counts[t - 1].fetch_add(1, Ordering::SeqCst);
                        !is_backup || rank < survivors
                    };
                    if on_time {
                        b_i = attributed;
                    } else {
                        // Straggler: work dropped (b_i = 0), state untouched.
                        b_i = 0;
                        loss_i = 0.0;
                        st.grad_sum.fill(0.0);
                    }
                    compute_secs = compute_t0.elapsed().as_secs_f64();
                } else {
                    // Absent (or rejoining with nothing to race for):
                    // no compute, no finish-counter entry; the barrier
                    // below keeps the cluster in phase.
                    compute_secs = 0.0;
                }
                // The epoch's compute phase ends for everyone together.
                ctx.phase_barrier.wait();
                let consensus_deadline =
                    Instant::now() + Duration::from_secs_f64(t_consensus_real);
                if on {
                    st.encode_into(n, b_i, &mut m);
                }
                applied = (b_i, loss_i, 0);
                rounds_done = consensus_phase(
                    &ctx,
                    t,
                    on,
                    active,
                    act_count,
                    dim,
                    &mut m,
                    &mut inbox,
                    consensus_deadline,
                );
            }
        }
        // purge stale buffered messages from this epoch
        // amb-lint: allow(D2, "retain applies a pure per-key predicate; iteration order cannot affect the result")
        inbox.retain(|&(e, _, _), _| e > t);

        // ---- update phase (shared state machine; absent nodes hold) ----
        if on {
            let b_hat = epoch::side_channel_b_hat(&m);
            if b_hat > 0.5 {
                st.set_dual(&m, b_hat);
                st.primal(&mut *engine, t + 1);
            }
        }
        rows.push(EpochRow {
            b: b_i,
            applied_b: applied.0,
            applied_loss: applied.1,
            staleness: applied.2,
            rounds: rounds_done,
            compute_secs,
        });
        errors.push(if i == 0 {
            engine.error_metric(&st.w, &mut metric_rng)
        } else {
            f64::NAN
        });
        if std::env::var_os("AMB_DEBUG").is_some() {
            eprintln!(
                "[node {i} epoch {t}] b={b_i} rounds={rounds_done} est_chunk={:.0}ms compute={:.0}ms",
                est_chunk.as_secs_f64() * 1e3,
                compute_secs * 1e3,
            );
        }
    }

    NodeResult { node: i, rows, errors, final_w: st.w }
}

fn sleep_until(t: Instant) {
    let now = Instant::now();
    if t > now {
        std::thread::sleep(t - now);
    }
}

/// Bounded receive with exponential backoff: waits in growing slices
/// (1 ms doubling to a 16 ms cap) instead of one blocking receive
/// pinned to the deadline, so a node waiting on a faulty or crashed
/// peer re-checks the clock at bounded intervals — a wakeup lost with a
/// dropped message can cost at most one slice, never the whole window.
/// Returns `None` once `deadline` passes or every sender hung up.
fn recv_backoff(rx: &Receiver<WireMsg>, deadline: Instant) -> Option<WireMsg> {
    let mut slice = Duration::from_millis(1);
    loop {
        let now = Instant::now();
        if now >= deadline {
            return None;
        }
        match rx.recv_timeout(slice.min(deadline - now)) {
            Ok(msg) => return Some(msg),
            Err(RecvTimeoutError::Timeout) => slice = (slice * 2).min(Duration::from_millis(16)),
            Err(RecvTimeoutError::Disconnected) => return None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LinRegStream;
    use crate::exec::{DataSource, ExecEngine, NativeExec};
    use crate::optim::{BetaSchedule, DualAveraging};
    use std::sync::Arc;

    fn small_spec(epochs: usize, slowdown: Vec<f64>) -> RunSpec {
        RunSpec::amb("amb-threaded", 0.06, 0.04, crate::coordinator::GOSSIP_UNTIL_DEADLINE, epochs, 5)
            .with_grad_chunk(16)
            .with_slowdown(slowdown)
            .with_node_log()
    }

    fn linreg_factory(
        d: usize,
        seed: u64,
    ) -> (impl Fn(usize) -> Box<dyn ExecEngine> + Send + Sync, Option<f64>) {
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
        let opt = DualAveraging::new(BetaSchedule::new(1.0, 500.0), 4.0 * (d as f64).sqrt());
        let f_star = src.f_star();
        (
            move |_i: usize| -> Box<dyn ExecEngine> {
                Box::new(NativeExec::new(src.clone(), opt.clone()))
            },
            f_star,
        )
    }

    fn run_small(epochs: usize, slowdown: Vec<f64>) -> RunOutput {
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 2);
        ThreadedRuntime.run(&small_spec(epochs, slowdown), &topo, &mk, f_star).unwrap()
    }

    #[test]
    fn produces_all_epochs_and_progress() {
        let out = run_small(8, vec![]);
        assert_eq!(out.record.epochs.len(), 8);
        // every epoch did real work on every node
        for e in &out.record.epochs {
            assert!(e.min_node_batch > 0, "some node computed nothing");
        }
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first, "no progress: {first} -> {last}");
        // consensus happened (some rounds completed)
        let total_rounds: usize = out.rounds.iter().flatten().sum();
        assert!(total_rounds > 0);
    }

    #[test]
    fn slowdown_shrinks_slow_nodes_batch() {
        let out = run_small(6, vec![3.0, 1.0, 1.0, 1.0]);
        let log = out.node_log.as_ref().unwrap();
        let slow: f64 = log.batches[0].iter().map(|&b| b as f64).sum::<f64>() / 6.0;
        let fast: f64 = log.batches[2].iter().map(|&b| b as f64).sum::<f64>() / 6.0;
        assert!(
            slow < 0.7 * fast,
            "slowdown not visible: slow={slow} fast={fast}"
        );
        // ... and the epoch still completed on schedule with b(t) > 0.
        for e in &out.record.epochs {
            assert!(e.batch > 0);
        }
    }

    #[test]
    fn churn_trace_absent_node_skips_epoch_on_real_threads() {
        use crate::churn::ChurnSpec;
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 8);
        // node 3 absent in epochs 2 and 4 (trace period 2)
        let trace = ChurnSpec::Trace {
            active: vec![vec![true], vec![true], vec![true], vec![true, false]],
        };
        let spec = small_spec(4, vec![]).with_churn(trace);
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        assert_eq!(out.record.epochs.len(), 4);
        assert_eq!(out.active_counts, vec![4, 3, 4, 3]);
        let log = out.node_log.as_ref().unwrap();
        // absent epochs: zero batch, zero rounds, zero logged compute
        assert_eq!(log.batches[3][1], 0);
        assert_eq!(log.batches[3][3], 0);
        assert_eq!(out.rounds[3][1], 0);
        assert_eq!(log.compute_times[3][1], 0.0);
        // present nodes keep making progress every epoch
        for t in 0..4 {
            for node in 0..3 {
                assert!(log.batches[node][t] > 0, "node {node} idle in epoch {}", t + 1);
            }
        }
        // the epoch 1 batch includes node 3, epoch 2's does not
        assert!(out.record.epochs[1].min_node_batch == 0);
    }

    #[test]
    fn fmb_churn_quota_tracks_active_set_on_real_threads() {
        use crate::churn::ChurnSpec;
        let topo = Topology::complete(4);
        let (mk, f_star) = linreg_factory(8, 5);
        let trace = ChurnSpec::Trace {
            active: vec![vec![true], vec![true, false], vec![true], vec![true]],
        };
        let spec = RunSpec::fmb("fmb-churn-threaded", 32, 0.04, 2, 4, 11)
            .with_grad_chunk(8)
            .with_churn(trace);
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        let batches: Vec<usize> = out.record.epochs.iter().map(|e| e.batch).collect();
        // epochs with node 1 absent lose exactly its quota
        assert_eq!(batches, vec![4 * 32, 3 * 32, 4 * 32, 3 * 32]);
        assert_eq!(out.active_counts, vec![4, 3, 4, 3]);
    }

    #[test]
    fn amb_dg_pipelines_and_records_staleness_on_real_threads() {
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 7);
        // Finite gossip budget: the rounds finish as soon as peers
        // respond, so nearly the whole max(T, T_c) window is compute —
        // the budget recommended for pipelined runs (a deadline-bound
        // GOSSIP_UNTIL_DEADLINE budget would spend all of T_c gossiping
        // and shrink the compute tail to max(T, T_c) − T_c).
        let spec = RunSpec::amb_dg("dg-threaded", 0.06, 0.04, 1, 4, 6, 5)
            .with_grad_chunk(16)
            .with_node_log();
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        assert_eq!(out.record.epochs.len(), 6);
        // warm-up: the first epoch applies nothing
        assert_eq!(out.record.epochs[0].batch, 0);
        assert!(out.record.epochs[0].mean_staleness.is_nan());
        for e in &out.record.epochs[1..] {
            assert!(e.batch > 0, "epoch {} applied nothing", e.epoch);
            assert_eq!(e.max_staleness, 1, "epoch {}", e.epoch);
            assert!((e.mean_staleness - 1.0).abs() < 1e-12);
        }
        // pipelined absolute schedule: epoch length max(T, T_c) = 0.06
        for (i, e) in out.record.epochs.iter().enumerate() {
            assert!((e.wall_time - 0.06 * (i + 1) as f64).abs() < 1e-9);
        }
        // every node really computed every epoch (the COMPUTED view)
        let log = out.node_log.as_ref().unwrap();
        for node in 0..4 {
            for t in 0..6 {
                assert!(log.batches[node][t] > 0, "node {node} idle in epoch {}", t + 1);
            }
        }
        assert!(out.record.epochs.last().unwrap().error.is_finite());
    }

    #[test]
    fn amb_dg_zero_delay_runs_the_stock_amb_path() {
        // delay = 0 normalizes to Scheme::Amb: the absolute schedule is
        // T + T_c, staleness columns are identically zero, and every
        // epoch applies the batch it computed.
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 3);
        let spec = RunSpec::amb_dg(
            "dg0-threaded",
            0.06,
            0.04,
            0,
            crate::coordinator::GOSSIP_UNTIL_DEADLINE,
            4,
            5,
        )
        .with_grad_chunk(16);
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        for (i, e) in out.record.epochs.iter().enumerate() {
            assert!(e.batch > 0, "no warm-up gap at D = 0");
            assert_eq!(e.max_staleness, 0);
            assert!((e.mean_staleness - 0.0).abs() < 1e-12);
            assert!((e.wall_time - 0.10 * (i + 1) as f64).abs() < 1e-9, "AMB cadence");
        }
    }

    #[test]
    fn fmb_computes_exact_quota_on_real_threads() {
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 3);
        let spec = RunSpec::fmb("fmb-threaded", 48, 0.04, 2, 4, 7)
            .with_grad_chunk(16)
            .with_node_log();
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        for e in &out.record.epochs {
            assert_eq!(e.min_node_batch, 48);
            assert_eq!(e.max_node_batch, 48);
            assert_eq!(e.batch, 4 * 48);
        }
    }

    #[test]
    fn backup_drops_exactly_ignore_nodes() {
        let topo = Topology::complete(4);
        let (mk, f_star) = linreg_factory(8, 4);
        let spec = RunSpec::new(
            "bk-threaded",
            Scheme::FmbBackup { per_node_batch: 64, t_consensus: 0.05, ignore: 1, coded: false },
            3,
            9,
        )
        .with_grad_chunk(8)
        .with_slowdown(vec![4.0, 1.0, 1.0, 1.0]);
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        for e in &out.record.epochs {
            // 3 survivors × 64; the straggler's work is dropped
            assert_eq!(e.batch, 3 * 64, "b(t)={}", e.batch);
            assert_eq!(e.min_node_batch, 0);
            assert_eq!(e.max_node_batch, 64);
        }
    }

    #[test]
    fn coded_attribution_keeps_full_batch() {
        let topo = Topology::complete(4);
        let (mk, f_star) = linreg_factory(8, 6);
        let spec = RunSpec::new(
            "coded-threaded",
            Scheme::FmbBackup { per_node_batch: 30, t_consensus: 0.05, ignore: 1, coded: true },
            3,
            11,
        )
        .with_grad_chunk(10)
        .with_slowdown(vec![4.0, 1.0, 1.0, 1.0]);
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        for e in &out.record.epochs {
            // survivors are charged b/(n-ignore) = 30·4/3 = 40 each
            assert_eq!(e.batch, 3 * 40, "b(t)={}", e.batch);
        }
    }

    #[test]
    fn unsupported_specs_are_rejected_with_clean_errors() {
        use crate::fault::FaultSpec;
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(8, 1);
        let reject = |spec: RunSpec, needle: &str| {
            let err = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "error {msg:?} missing {needle:?}");
        };
        reject(
            small_spec(2, vec![]).with_consensus(ConsensusMode::Hierarchical {
                shards: 2,
                intra_rounds: 2,
                inter_rounds: 1,
            }),
            "sim-only",
        );
        reject(
            small_spec(2, vec![])
                .with_network(crate::net::NetworkModel::Fabric(crate::net::FabricSpec::ideal())),
            "sim-only",
        );
        reject(
            small_spec(2, vec![])
                .with_consensus(ConsensusMode::Exact)
                .with_faults(FaultSpec { loss: 0.1, ..FaultSpec::none() }),
            "require a gossip consensus mode",
        );
        reject(
            small_spec(2, vec![]).with_faults(FaultSpec { loss: 1.5, ..FaultSpec::none() }),
            "not in [0, 1]",
        );
    }

    #[test]
    fn crashed_node_rejoins_with_zero_mass_on_real_threads() {
        use crate::fault::{CrashWindow, FaultSpec};
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 12);
        // node 1 dead in epochs 2–3, rejoins (zero-mass) in epoch 4
        let faults = FaultSpec {
            crashes: vec![CrashWindow { node: 1, from: 2, to: 3 }],
            ..FaultSpec::none()
        };
        let out = ThreadedRuntime
            .run(&small_spec(6, vec![]).with_faults(faults), &topo, &mk, f_star)
            .unwrap();
        assert_eq!(out.active_counts, vec![4, 3, 3, 4, 4, 4]);
        let log = out.node_log.as_ref().unwrap();
        // dead epochs: no work, no rounds; the rejoin epoch computes
        // nothing either (its row is the zero-mass re-sync message)
        assert_eq!(log.batches[1][1], 0);
        assert_eq!(log.batches[1][2], 0);
        assert_eq!(log.batches[1][3], 0);
        assert_eq!(out.rounds[1][1], 0);
        assert_eq!(out.rounds[1][2], 0);
        // back to real work the epoch after the re-sync
        assert!(log.batches[1][4] > 0, "node 1 idle after rejoin");
        // crashes are faults: the drift column reports "not measured"
        for e in &out.record.epochs {
            assert!(e.conservation_drift.is_nan());
        }
    }

    #[test]
    fn permanently_crashed_node_does_not_stall_the_cluster() {
        use crate::fault::{CrashWindow, FaultSpec};
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 9);
        // node 3 dies at epoch 2 and never returns; the surviving ring
        // keeps its absolute schedule (the test finishing at all IS the
        // wall-clock bound: every window is deadline-closed).
        let faults = FaultSpec {
            crashes: vec![CrashWindow { node: 3, from: 2, to: usize::MAX }],
            ..FaultSpec::none()
        };
        let out = ThreadedRuntime
            .run(&small_spec(5, vec![]).with_faults(faults), &topo, &mk, f_star)
            .unwrap();
        assert_eq!(out.active_counts, vec![4, 3, 3, 3, 3]);
        let log = out.node_log.as_ref().unwrap();
        for t in 1..5 {
            assert_eq!(log.batches[3][t], 0, "dead node computed in epoch {}", t + 1);
            for node in 0..3 {
                assert!(log.batches[node][t] > 0, "node {node} idle in epoch {}", t + 1);
            }
        }
        assert!(out.record.epochs.last().unwrap().error.is_finite());
    }

    #[test]
    fn packet_loss_on_real_threads_still_makes_progress() {
        use crate::fault::FaultSpec;
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 4);
        // Finite budget so dropped rounds cost substitution, not the
        // whole T_c window.
        let spec = RunSpec::amb("amb-lossy-threaded", 0.06, 0.04, 4, 8, 5)
            .with_grad_chunk(16)
            .with_faults(FaultSpec { loss: 0.15, seed: 7, ..FaultSpec::none() });
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        assert_eq!(out.record.epochs.len(), 8);
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first, "no progress under loss: {first} -> {last}");
        for e in &out.record.epochs {
            assert!(e.conservation_drift.is_nan(), "threaded drift is unmeasured");
        }
    }

    #[test]
    fn allclear_faultspec_keeps_drift_column_exact() {
        use crate::fault::FaultSpec;
        // A seed/timeout-only spec is all-clear: the run must report
        // exactly zero drift (the structural no-fault path).
        let topo = Topology::ring(4);
        let (mk, f_star) = linreg_factory(16, 2);
        let spec = small_spec(3, vec![])
            .with_faults(FaultSpec { seed: 123, round_timeout: 0.5, ..FaultSpec::none() });
        let out = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();
        for e in &out.record.epochs {
            assert_eq!(e.conservation_drift, 0.0);
        }
    }
}
