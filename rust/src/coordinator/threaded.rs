//! Real-time threaded cluster: one OS thread per node, mpsc-channel
//! "network", wall-clock compute windows — the production-shaped AMB
//! runtime used by the end-to-end example (MPI → channels substitution,
//! DESIGN.md §2).
//!
//! Protocol per epoch (absolute schedule; NO barrier — this is the point
//! of AMB):
//!   epoch t owns the real-time window [t₀ + (t−1)·(T+T_c), t₀ + t·(T+T_c)).
//!   compute:   loop gradient chunks until the T deadline; an optional
//!              per-node slowdown factor sleeps after each chunk to induce
//!              stragglers (paper App. I.3's background jobs).
//!   consensus: send m⁽⁰⁾, then synchronous gossip rounds — a node waits
//!              for all neighbours' round-k messages (paper Sec. 3) but
//!              abandons consensus at the epoch deadline, keeping its last
//!              completed round (variable r_i(t)).
//!   update:    z ← m⁽ʳ⁾ / b̂(t) (b̂ from the scalar side channel),
//!              w ← dual-averaging step.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier, OnceLock};
use std::time::{Duration, Instant};

use crate::coordinator::NodeLog;
use crate::exec::ExecEngine;
use crate::metrics::{EpochStats, RunRecord};
use crate::topology::Topology;
use crate::util::rng::Pcg64;

/// Configuration for a threaded (real-time) AMB run.
#[derive(Debug, Clone)]
pub struct ThreadedConfig {
    pub name: String,
    /// Fixed compute window per epoch (real seconds).
    pub t_compute: f64,
    /// Fixed communication window per epoch (real seconds).
    pub t_consensus: f64,
    pub epochs: usize,
    pub seed: u64,
    /// Samples per engine call inside the compute window (smaller =>
    /// finer-grained anytime behaviour, more per-call overhead).
    pub grad_chunk: usize,
    /// Per-node artificial slowdown factors (≥ 1.0); empty = none.
    /// Factor f makes the node ~f× slower by sleeping (f−1)·chunk_time
    /// after each chunk.
    pub slowdown: Vec<f64>,
}

/// One consensus message on the wire.
struct WireMsg {
    from: usize,
    epoch: usize,
    round: usize,
    payload: Vec<f32>,
}

/// Per-node output returned at join.
struct NodeResult {
    node: usize,
    /// (epoch, b_i, loss_sum_i, grads_done_in_window, rounds_done)
    epochs: Vec<(usize, usize, f64, usize)>,
    /// error metric per epoch (only node 0 fills this)
    errors: Vec<f64>,
    final_w: Vec<f32>,
}

/// Aggregated epoch view (leader side).
pub struct ThreadedOutput {
    pub record: RunRecord,
    pub node_log: NodeLog,
    pub final_w: Vec<f32>,
    /// consensus rounds completed per (node, epoch)
    pub rounds: Vec<Vec<usize>>,
}

/// Run AMB on a real threaded cluster.
///
/// `make_engine` is called once inside each node thread (engines need not
/// be `Send`; PJRT clients are thread-local).
pub fn run_amb<F>(
    cfg: &ThreadedConfig,
    topo: &Topology,
    make_engine: F,
    f_star: f64,
) -> ThreadedOutput
where
    F: Fn(usize) -> Box<dyn ExecEngine> + Send + Sync,
{
    let n = topo.n();
    assert!(cfg.slowdown.is_empty() || cfg.slowdown.len() == n);
    let p = Arc::new(topo.metropolis().lazy());

    // Build the "network": one receiver per node, senders fanned out.
    let mut txs: Vec<Sender<WireMsg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<WireMsg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<WireMsg>();
        txs.push(tx);
        rxs.push(Some(rx));
    }

    let epoch_len = cfg.t_compute + cfg.t_consensus;
    // The common clock t0 is agreed on AFTER every node has built its
    // engine (PJRT compilation can take seconds) — otherwise the first
    // epochs would already be over before any node could compute.
    let ready = Arc::new(Barrier::new(n));
    let start_cell: Arc<OnceLock<Instant>> = Arc::new(OnceLock::new());

    let results: Vec<NodeResult> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..n {
            let rx = rxs[i].take().unwrap();
            let neighbor_txs: Vec<(usize, Sender<WireMsg>)> =
                topo.neighbors(i).iter().map(|&j| (j, txs[j].clone())).collect();
            let neighbors: Vec<usize> = topo.neighbors(i).to_vec();
            let p = p.clone();
            let make_engine = &make_engine;
            let cfg = cfg.clone();
            let ready = ready.clone();
            let start_cell = start_cell.clone();
            handles.push(scope.spawn(move || {
                node_main(
                    i, n, cfg, ready, start_cell, epoch_len, rx, neighbor_txs, neighbors, p,
                    make_engine,
                )
            }));
        }
        drop(txs);
        handles.into_iter().map(|h| h.join().expect("node thread panicked")).collect()
    });

    // Assemble the leader view.
    let mut record = RunRecord::new(&cfg.name, f_star);
    let mut node_log = NodeLog::new(n);
    let mut rounds = vec![Vec::new(); n];
    let node0 = results.iter().find(|r| r.node == 0).unwrap();
    for t in 1..=cfg.epochs {
        let mut b_t = 0usize;
        let mut loss = 0.0f64;
        let mut min_b = usize::MAX;
        let mut max_b = 0usize;
        for r in &results {
            let (_, b, l, rd) = r.epochs[t - 1];
            b_t += b;
            loss += l;
            min_b = min_b.min(b);
            max_b = max_b.max(b);
            node_log.push(r.node, b, cfg.t_compute);
            rounds[r.node].push(rd);
        }
        record.push(EpochStats {
            epoch: t,
            wall_time: t as f64 * epoch_len,
            batch: b_t,
            potential: b_t,
            loss: if b_t > 0 { loss / b_t as f64 } else { f64::NAN },
            error: node0.errors[t - 1],
            consensus_err: f64::NAN, // not observable without global state
            min_node_batch: min_b,
            max_node_batch: max_b,
        });
    }
    ThreadedOutput { record, node_log, final_w: node0.final_w.clone(), rounds }
}

#[allow(clippy::too_many_arguments)]
fn node_main<F>(
    i: usize,
    n: usize,
    cfg: ThreadedConfig,
    ready: Arc<Barrier>,
    start_cell: Arc<OnceLock<Instant>>,
    epoch_len: f64,
    rx: Receiver<WireMsg>,
    neighbor_txs: Vec<(usize, Sender<WireMsg>)>,
    neighbors: Vec<usize>,
    p: Arc<crate::topology::MixMatrix>,
    make_engine: &F,
) -> NodeResult
where
    F: Fn(usize) -> Box<dyn ExecEngine> + Send + Sync,
{
    let mut engine = make_engine(i);
    let dim = engine.workload().dim();
    let mut w = engine.initial_primal();
    let mut z = vec![0.0f32; dim];
    let mut grad_acc = vec![0.0f32; dim];
    let mut data_rng = Pcg64::new(cfg.seed ^ (0xDA7A << 16) ^ i as u64);
    let mut metric_rng = Pcg64::new(cfg.seed ^ (0x3E77 << 16) ^ i as u64);
    let slowdown = cfg.slowdown.get(i).copied().unwrap_or(1.0);

    // Out-of-order message store: (epoch, round, from) -> payload.
    let mut inbox: std::collections::HashMap<(usize, usize, usize), Vec<f32>> =
        std::collections::HashMap::new();

    let mut epochs_out = Vec::with_capacity(cfg.epochs);
    let mut errors = Vec::with_capacity(cfg.epochs);

    // Warm up the engine (first PJRT execution pays lazy-init costs) and
    // prime the chunk-duration estimate used for admission control.
    let mut est_chunk = {
        let t0 = Instant::now();
        grad_acc.fill(0.0);
        let _ = engine.grad_chunk(&w, cfg.grad_chunk, &mut data_rng, &mut grad_acc);
        t0.elapsed()
    };
    grad_acc.fill(0.0);

    // Engine is built and warm; rendezvous, then agree on the common t0.
    ready.wait();
    let start = *start_cell.get_or_init(|| Instant::now() + Duration::from_millis(20));

    for t in 1..=cfg.epochs {
        let epoch_start = start + Duration::from_secs_f64((t - 1) as f64 * epoch_len);
        let compute_deadline = epoch_start + Duration::from_secs_f64(cfg.t_compute);
        let epoch_deadline = epoch_start + Duration::from_secs_f64(epoch_len);

        sleep_until(epoch_start);

        // ---- compute phase: anytime gradient accumulation ----
        // Admission control: only start a chunk expected to finish inside
        // the window (a gradient that cannot finish by T is abandoned —
        // Algorithm 1's `while current_time − T0 ≤ T`).  The estimate is
        // an EWMA over observed chunk times, including the slowdown nap.
        grad_acc.fill(0.0);
        let mut b_i = 0usize;
        let mut loss_i = 0.0f64;
        while Instant::now() + est_chunk.mul_f64(0.9) < compute_deadline {
            let chunk_t0 = Instant::now();
            loss_i += engine.grad_chunk(&w, cfg.grad_chunk, &mut data_rng, &mut grad_acc);
            b_i += cfg.grad_chunk;
            if slowdown > 1.0 {
                let busy = chunk_t0.elapsed();
                let nap = busy.mul_f64(slowdown - 1.0);
                if Instant::now() + nap < compute_deadline + Duration::from_millis(2) {
                    std::thread::sleep(nap);
                } else {
                    sleep_until(compute_deadline);
                }
            }
            let observed = chunk_t0.elapsed();
            est_chunk = est_chunk.mul_f64(0.5) + observed.mul_f64(0.5);
        }
        sleep_until(compute_deadline);

        // ---- consensus phase ----
        // m⁽⁰⁾ = n (b_i z + grad_acc), side channel n·b_i.
        let mut m: Vec<f32> = Vec::with_capacity(dim + 1);
        m.extend((0..dim).map(|k| n as f32 * (b_i as f32 * z[k] + grad_acc[k])));
        m.push(n as f32 * b_i as f32);
        for (_, tx) in &neighbor_txs {
            let _ = tx.send(WireMsg { from: i, epoch: t, round: 0, payload: m.clone() });
        }
        let mut round = 0usize;
        'rounds: loop {
            // collect all neighbours' round-`round` messages
            let mut have: Vec<Option<Vec<f32>>> = vec![None; neighbors.len()];
            let mut missing = neighbors.len();
            // drain anything already buffered
            for (idx, &j) in neighbors.iter().enumerate() {
                if let Some(pl) = inbox.remove(&(t, round, j)) {
                    have[idx] = Some(pl);
                    missing -= 1;
                }
            }
            while missing > 0 {
                let now = Instant::now();
                if now >= epoch_deadline {
                    break 'rounds; // T_c exhausted mid-round: keep m as-is
                }
                match rx.recv_timeout(epoch_deadline - now) {
                    Ok(msg) => {
                        if msg.epoch == t && msg.round == round {
                            if let Some(idx) = neighbors.iter().position(|&j| j == msg.from) {
                                if have[idx].is_none() {
                                    have[idx] = Some(msg.payload);
                                    missing -= 1;
                                    continue;
                                }
                            }
                        }
                        // stale/early message: buffer for later rounds
                        inbox.insert((msg.epoch, msg.round, msg.from), msg.payload);
                    }
                    Err(RecvTimeoutError::Timeout) => break 'rounds,
                    Err(RecvTimeoutError::Disconnected) => break 'rounds,
                }
            }
            if missing > 0 {
                break 'rounds;
            }
            // m ← P_ii m + Σ_j P_ij m_j
            let pii = p.at(i, i) as f32;
            for v in m.iter_mut() {
                *v *= pii;
            }
            for (idx, &j) in neighbors.iter().enumerate() {
                let pij = p.at(i, j) as f32;
                let mj = have[idx].as_ref().unwrap();
                for k in 0..=dim {
                    m[k] += pij * mj[k];
                }
            }
            round += 1;
            // Don't start a send we can't finish inside the window.
            if Instant::now() >= epoch_deadline {
                break 'rounds;
            }
            for (_, tx) in &neighbor_txs {
                let _ = tx.send(WireMsg { from: i, epoch: t, round, payload: m.clone() });
            }
        }
        // purge stale buffered messages from this epoch
        inbox.retain(|&(e, _, _), _| e > t);

        // ---- update phase ----
        let b_hat = (m[dim] / n as f32).max(1e-6) * n as f32; // == m[dim], kept explicit
        if b_hat > 0.5 {
            for k in 0..dim {
                z[k] = m[k] / b_hat;
            }
            engine.primal_step(&z, t + 1, &mut w);
        }
        epochs_out.push((t, b_i, loss_i, round));
        errors.push(if i == 0 { engine.error_metric(&w, &mut metric_rng) } else { f64::NAN });
        if std::env::var_os("AMB_DEBUG").is_some() {
            eprintln!(
                "[node {i} epoch {t}] b={b_i} rounds={round} est_chunk={:.0}ms lag_after_update={:.0}ms",
                est_chunk.as_secs_f64() * 1e3,
                (Instant::now() - epoch_start).as_secs_f64() * 1e3 - epoch_len * 1e3,
            );
        }
    }

    NodeResult { node: i, epochs: epochs_out, errors, final_w: w }
}

fn sleep_until(t: Instant) {
    let now = Instant::now();
    if t > now {
        std::thread::sleep(t - now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LinRegStream;
    use crate::exec::{DataSource, NativeExec};
    use crate::optim::{BetaSchedule, DualAveraging};
    use std::sync::Arc;

    fn small_cfg(epochs: usize, slowdown: Vec<f64>) -> ThreadedConfig {
        ThreadedConfig {
            name: "amb-threaded".into(),
            t_compute: 0.06,
            t_consensus: 0.04,
            epochs,
            seed: 5,
            grad_chunk: 16,
            slowdown,
        }
    }

    fn run_small(epochs: usize, slowdown: Vec<f64>) -> ThreadedOutput {
        let topo = Topology::ring(4);
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(16, 2)));
        let opt = DualAveraging::new(BetaSchedule::new(1.0, 500.0), 4.0 * 4.0);
        let f_star = src.f_star();
        let cfg = small_cfg(epochs, slowdown);
        run_amb(
            &cfg,
            &topo,
            move |_| Box::new(NativeExec::new(src.clone(), opt.clone())),
            f_star,
        )
    }

    #[test]
    fn produces_all_epochs_and_progress() {
        let out = run_small(8, vec![]);
        assert_eq!(out.record.epochs.len(), 8);
        // every epoch did real work on every node
        for e in &out.record.epochs {
            assert!(e.min_node_batch > 0, "some node computed nothing");
        }
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first, "no progress: {first} -> {last}");
        // consensus happened (some rounds completed)
        let total_rounds: usize = out.rounds.iter().flatten().sum();
        assert!(total_rounds > 0);
    }

    #[test]
    fn slowdown_shrinks_slow_nodes_batch() {
        let out = run_small(6, vec![3.0, 1.0, 1.0, 1.0]);
        let slow: f64 = out.node_log.batches[0].iter().map(|&b| b as f64).sum::<f64>() / 6.0;
        let fast: f64 = out.node_log.batches[2].iter().map(|&b| b as f64).sum::<f64>() / 6.0;
        assert!(
            slow < 0.7 * fast,
            "slowdown not visible: slow={slow} fast={fast}"
        );
        // ... and the epoch still completed on schedule with b(t) > 0.
        for e in &out.record.epochs {
            assert!(e.batch > 0);
        }
    }
}
