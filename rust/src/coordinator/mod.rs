//! L3 coordinator — the paper's system contribution, behind ONE runtime
//! API.
//!
//! Two interchangeable epoch schedulers over the same machinery:
//!
//! * **AMB** (Anytime Minibatch, this paper): every epoch gives each node
//!   a fixed compute window T — the per-node minibatch b_i(t) is whatever
//!   the node finished — then a fixed communication window T_c for
//!   averaging consensus on dual variables.  Epoch wall time is exactly
//!   T + T_c regardless of stragglers.
//! * **FMB** (fixed minibatch baseline): every node computes exactly b/n
//!   gradients; the epoch's compute phase lasts max_i T_i(t) (the slowest
//!   node gates everyone), then the same consensus window.
//! * **FMB + redundancy** ([`Scheme::FmbBackup`]): the related-work
//!   straggler mitigations (backup workers / gradient coding).
//!
//! One [`RunSpec`] describes a run; any [`Runtime`] executes it and
//! returns the same [`RunOutput`]:
//!
//! * [`sim::SimRuntime`] — single-process discrete-event simulator with a
//!   virtual clock driven by a [`crate::straggler::StragglerModel`];
//!   regenerates every figure deterministically.
//! * [`threaded::ThreadedRuntime`] — one OS thread per node,
//!   mpsc-channel "network", real wall-clock compute windows; the
//!   production-shaped runtime used by the end-to-end example.
//!
//! The shared per-epoch state machine (compute → consensus with the
//! n·b_i side channel → dual-averaging update) lives in [`epoch`]; the
//! runtimes differ only in how *time* is attributed.  Entry point:
//! [`crate::run`] (`amb run --runtime sim|threaded` on the CLI).

pub mod epoch;
pub mod sim;
pub mod threaded;

use crate::churn::ChurnSpec;
use crate::exec::ExecEngine;
use crate::fault::FaultSpec;
use crate::net::NetworkModel;
use crate::metrics::RunRecord;
use crate::topology::Topology;
use crate::util::matrix::NodeMatrix;

/// Epoch scheduling policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// Fixed compute time T and communication time T_c (seconds: virtual
    /// clock units in sim mode, real seconds × `time_scale` in threaded
    /// mode).
    Amb { t_compute: f64, t_consensus: f64 },
    /// Fixed per-node batch; epoch compute time = slowest node.
    Fmb { per_node_batch: usize, t_consensus: f64 },
    /// FMB with straggler mitigation via redundancy — the baseline family
    /// the paper's related work compares against (Chen et al. '17 backup
    /// workers; Tandon et al. '17 gradient coding):
    /// * `coded = false` (backup workers): the epoch ends when the
    ///   fastest n−ignore nodes finish b/n gradients; the stragglers'
    ///   work is DROPPED (b(t) = (n−ignore)·b/n).
    /// * `coded = true` (gradient coding): every node computes
    ///   (ignore+1)·b/n redundantly-assigned gradients so the full batch
    ///   is recoverable from any n−ignore nodes (b(t) = b, but each node
    ///   does (ignore+1)× work).
    FmbBackup { per_node_batch: usize, t_consensus: f64, ignore: usize, coded: bool },
    /// AMB with delayed gradients (AMB-DG, Al-Lawati & Draper,
    /// arXiv:2012.08616): nodes never idle through the consensus window.
    /// The gradient batch computed in epoch t (against the then-current
    /// primal) is held in a `delay`-deep pipeline ring and enters the
    /// dual update `delay` epochs later, so epoch t's consensus — which
    /// carries the batch from epoch t−D — overlaps epoch t's compute.
    /// Wall clock: `delay = 0` is EXACTLY the paper's AMB (epoch =
    /// T + T_c, bit-for-bit — the acceptance contract); `delay ≥ 1`
    /// pipelines the two windows, epoch = max(T, T_c).  β(t) is
    /// unchanged (DESIGN.md §pipelining).
    AmbDg { t_compute: f64, t_consensus: f64, delay: usize },
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Amb { .. } => "amb",
            Scheme::Fmb { .. } => "fmb",
            Scheme::FmbBackup { coded: false, .. } => "fmb-backup",
            Scheme::FmbBackup { coded: true, .. } => "fmb-coded",
            Scheme::AmbDg { .. } => "amb-dg",
        }
    }

    /// The consensus window every variant carries.
    pub fn t_consensus(&self) -> f64 {
        match *self {
            Scheme::Amb { t_consensus, .. }
            | Scheme::Fmb { t_consensus, .. }
            | Scheme::FmbBackup { t_consensus, .. }
            | Scheme::AmbDg { t_consensus, .. } => t_consensus,
        }
    }

    /// Gradient-pipeline depth: how many epochs separate computing a
    /// batch from applying it (0 for every undelayed scheme).
    pub fn delay(&self) -> usize {
        match *self {
            Scheme::AmbDg { delay, .. } => delay,
            _ => 0,
        }
    }

    /// Wall-clock length of one epoch given the compute phase's
    /// attributed duration.  Every undelayed scheme serializes compute
    /// and consensus (epoch = compute + T_c); a pipelined AMB-DG epoch
    /// overlaps the consensus of the previous batch with this epoch's
    /// compute, so only the longer of the two windows elapses.
    pub fn epoch_wall(&self, compute_time: f64) -> f64 {
        match *self {
            Scheme::AmbDg { t_compute, t_consensus, delay } if delay > 0 => {
                t_compute.max(t_consensus)
            }
            _ => compute_time + self.t_consensus(),
        }
    }

    /// Collapse the degenerate pipeline: `AmbDg { delay: 0 }` IS the
    /// paper's AMB (nothing is ever in flight), so the threaded runtime
    /// executes it through the stock AMB path.  The simulator does NOT
    /// normalize — it routes D = 0 through the pipeline ring so the
    /// `AmbDg { delay: 0 } ≡ Amb` bitwise contract is tested THROUGH the
    /// new code, not around it (`tests/amb_dg.rs`).
    pub fn normalized(self) -> Scheme {
        match self {
            Scheme::AmbDg { t_compute, t_consensus, delay: 0 } => {
                Scheme::Amb { t_compute, t_consensus }
            }
            s => s,
        }
    }
}

/// How dual variables are averaged in the consensus phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConsensusMode {
    /// Perfect averaging (ε = 0): hub-and-spoke master aggregation or the
    /// r → ∞ limit of Fig. 5.  The threaded runtime realizes it as an
    /// all-to-all exchange with f64 aggregation in node-index order, so
    /// both runtimes compute the identical average.
    Exact,
    /// Fixed number of synchronous gossip rounds for every node (the
    /// threaded runtime may complete fewer if T_c expires — the paper's
    /// variable r_i(t)).
    Gossip { rounds: usize },
    /// Per-node round counts r_i(t) ~ Uniform{mean−jitter, …, mean+jitter}
    /// (network-delay variability of paper Sec. 3).
    GossipJitter { mean: usize, jitter: usize },
    /// Two-level consensus for large n (sim only; DESIGN.md §consensus):
    /// `intra_rounds` of gossip inside each of `shards` contiguous node
    /// blocks (induced by the churn mask, shard-local edges only), then
    /// `inter_rounds` of aggregator exchange on a weighted ring of
    /// shards, broadcast back as a per-shard mean correction.  Conserves
    /// the global active-set mean; `shards = 1` is bitwise
    /// `Gossip { rounds: intra_rounds }`.
    Hierarchical { shards: usize, intra_rounds: usize, inter_rounds: usize },
}

/// Gossip budget meaning "as many rounds as fit in T_c" — a
/// threaded-runtime idiom (real deadline, variable r_i(t)).  The
/// simulator executes `Gossip { rounds }` literally and rejects this
/// sentinel with a clear panic (it has no per-round time model); specs
/// meant to replay on both runtimes should use a finite budget.
pub const GOSSIP_UNTIL_DEADLINE: usize = usize::MAX;

/// Which runtime executes a [`RunSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// Discrete-event simulator, virtual clock.
    Sim,
    /// One OS thread per node, real clock.
    Threaded,
}

impl RuntimeKind {
    pub fn parse(s: &str) -> Option<RuntimeKind> {
        match s {
            "sim" => Some(RuntimeKind::Sim),
            "threaded" => Some(RuntimeKind::Threaded),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RuntimeKind::Sim => "sim",
            RuntimeKind::Threaded => "threaded",
        }
    }
}

/// Full configuration of one run — the single spec both runtimes consume
/// (the union of the former sim-only `RunConfig` and threaded-only
/// `ThreadedConfig`).
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub name: String,
    pub scheme: Scheme,
    pub consensus: ConsensusMode,
    pub epochs: usize,
    pub seed: u64,
    /// If false (default), each node normalises its dual by a b(t)
    /// estimate obtained through the same consensus channel (an extra
    /// scalar component); if true, nodes magically know exact b(t).
    /// (Sim only: threaded nodes have no oracle for global b(t).)
    pub exact_bt: bool,
    /// Record per-(node, epoch) batch sizes and compute times (Fig. 6/8
    /// histograms).
    pub record_node_log: bool,
    /// Threaded: samples per engine call inside the compute window
    /// (smaller => finer-grained anytime behaviour, more per-call
    /// overhead).  Ignored by the simulator, whose compute phase is a
    /// single attributed call.
    pub grad_chunk: usize,
    /// Threaded: per-node artificial slowdown factors (≥ 1.0); empty =
    /// none.  Factor f makes the node ~f× slower by sleeping
    /// (f−1)·chunk_time after each chunk (paper App. I.3's background
    /// jobs).  The simulator expresses stragglers through its
    /// `StragglerModel` instead.
    pub slowdown: Vec<f64>,
    /// Threaded: real seconds per spec second.  Figures quote windows in
    /// paper units (e.g. T = 14.5 s); `time_scale = 0.01` replays them
    /// 100× faster while the records stay in spec units.
    pub time_scale: f64,
    /// Elastic membership (`ChurnSpec::None` = the paper's static
    /// graph): a deterministic per-epoch active-set process evaluated
    /// identically by both runtimes.  Inactive nodes contribute
    /// b_i = 0, are isolated in that epoch's consensus subgraph, and
    /// hold their dual/primal state until they rejoin (DESIGN.md
    /// §churn).
    pub churn: ChurnSpec,
    /// Communication model for the consensus phase.  `Abstract`
    /// (default) charges T_c for the configured round budget as-is —
    /// the paper's model, bit-for-bit today's behavior.
    /// `Fabric` measures per-node rounds from a discrete-event link
    /// simulation within T_c (sim runtime + `ConsensusMode::Gossip`
    /// only; the configured rounds become the per-epoch cap).  See
    /// DESIGN.md §network-fabric.
    pub network: NetworkModel,
    /// Fault-injection plane (`FaultSpec::none()` = today's reliable
    /// communication, bit-for-bit): deterministic per-edge packet loss,
    /// Markov link flaps, and unplanned crash/restart windows — all
    /// pure functions of `(faults.seed, epoch, round, edge)`.  See
    /// DESIGN.md §fault-injection.
    pub faults: FaultSpec,
}

impl RunSpec {
    /// A spec with the project-wide defaults: 5 gossip rounds (the
    /// paper's r ≈ 5), estimated b̂(t), no node log, 16-sample threaded
    /// chunks, no slowdown, unscaled time.
    pub fn new(name: &str, scheme: Scheme, epochs: usize, seed: u64) -> RunSpec {
        RunSpec {
            name: name.into(),
            scheme,
            consensus: ConsensusMode::Gossip { rounds: 5 },
            epochs,
            seed,
            exact_bt: false,
            record_node_log: false,
            grad_chunk: 16,
            slowdown: Vec::new(),
            time_scale: 1.0,
            churn: ChurnSpec::None,
            network: NetworkModel::Abstract,
            faults: FaultSpec::none(),
        }
    }

    pub fn amb(
        name: &str,
        t_compute: f64,
        t_consensus: f64,
        rounds: usize,
        epochs: usize,
        seed: u64,
    ) -> RunSpec {
        RunSpec::new(name, Scheme::Amb { t_compute, t_consensus }, epochs, seed)
            .with_consensus(ConsensusMode::Gossip { rounds })
    }

    pub fn fmb(
        name: &str,
        per_node_batch: usize,
        t_consensus: f64,
        rounds: usize,
        epochs: usize,
        seed: u64,
    ) -> RunSpec {
        RunSpec::new(name, Scheme::Fmb { per_node_batch, t_consensus }, epochs, seed)
            .with_consensus(ConsensusMode::Gossip { rounds })
    }

    /// Pipelined AMB-DG spec (same defaults as [`RunSpec::amb`]).
    pub fn amb_dg(
        name: &str,
        t_compute: f64,
        t_consensus: f64,
        delay: usize,
        rounds: usize,
        epochs: usize,
        seed: u64,
    ) -> RunSpec {
        RunSpec::new(name, Scheme::AmbDg { t_compute, t_consensus, delay }, epochs, seed)
            .with_consensus(ConsensusMode::Gossip { rounds })
    }

    pub fn with_consensus(mut self, mode: ConsensusMode) -> RunSpec {
        self.consensus = mode;
        self
    }

    pub fn with_node_log(mut self) -> RunSpec {
        self.record_node_log = true;
        self
    }

    pub fn with_exact_bt(mut self) -> RunSpec {
        self.exact_bt = true;
        self
    }

    pub fn with_grad_chunk(mut self, chunk: usize) -> RunSpec {
        assert!(chunk > 0, "grad_chunk must be positive");
        self.grad_chunk = chunk;
        self
    }

    pub fn with_slowdown(mut self, factors: Vec<f64>) -> RunSpec {
        assert!(
            factors.iter().all(|f| f.is_finite() && *f >= 1.0),
            "slowdown factors must be finite and ≥ 1.0 (got {factors:?})"
        );
        self.slowdown = factors;
        self
    }

    pub fn with_time_scale(mut self, scale: f64) -> RunSpec {
        assert!(scale > 0.0, "time_scale must be positive");
        self.time_scale = scale;
        self
    }

    pub fn with_churn(mut self, churn: ChurnSpec) -> RunSpec {
        self.churn = churn;
        self
    }

    pub fn with_network(mut self, network: NetworkModel) -> RunSpec {
        self.network = network;
        self
    }

    pub fn with_faults(mut self, faults: FaultSpec) -> RunSpec {
        self.faults = faults;
        self
    }
}

/// Per-(node, epoch) raw log for straggler histograms.
#[derive(Debug, Clone, Default)]
pub struct NodeLog {
    /// batches[node][epoch] = b_i(t).
    pub batches: Vec<Vec<usize>>,
    /// compute_times[node][epoch] = seconds node i spent computing in t
    /// (spec units on both runtimes).
    pub compute_times: Vec<Vec<f64>>,
}

impl NodeLog {
    pub fn new(n: usize) -> NodeLog {
        NodeLog { batches: vec![Vec::new(); n], compute_times: vec![Vec::new(); n] }
    }

    pub fn push(&mut self, node: usize, batch: usize, compute_time: f64) {
        self.batches[node].push(batch);
        self.compute_times[node].push(compute_time);
    }
}

/// What every runtime returns for a [`RunSpec`].
pub struct RunOutput {
    /// Per-epoch record (times in spec units on both runtimes).
    pub record: RunRecord,
    /// Per-(node, epoch) raw log when `spec.record_node_log`.
    pub node_log: Option<NodeLog>,
    /// Final primal variables, one arena row per node
    /// (`final_w.row(i)` = node i's w).
    pub final_w: NodeMatrix,
    /// Consensus rounds completed per (node, epoch); 0 under
    /// [`ConsensusMode::Exact`] (exact aggregation is not gossip).
    pub rounds: Vec<Vec<usize>>,
    /// |A(t)| per epoch — the number of active nodes (always n without
    /// churn).  The churn harness's membership diagnostic.
    pub active_counts: Vec<usize>,
}

/// Engine factory shared by both runtimes.  The threaded runtime invokes
/// it *inside* each node thread (engines themselves need not be `Send`;
/// PJRT clients are thread-local), so the factory must be `Send + Sync`.
pub type EngineFactory<'a> = &'a (dyn Fn(usize) -> Box<dyn ExecEngine> + Send + Sync);

/// A cluster runtime: executes any [`RunSpec`] over a topology.
///
/// `f_star` is the per-sample optimal loss used for regret accounting
/// when known (see [`crate::exec::DataSource::f_star`]).
///
/// Errors on spec combinations the runtime cannot execute (unsupported
/// consensus mode × network model × fault plane pairings) so the CLI
/// surfaces a clean message instead of a panic.
pub trait Runtime {
    fn kind(&self) -> RuntimeKind;

    fn run(
        &self,
        spec: &RunSpec,
        topo: &Topology,
        make_engine: EngineFactory<'_>,
        f_star: Option<f64>,
    ) -> anyhow::Result<RunOutput>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::Amb { t_compute: 1.0, t_consensus: 0.1 }.name(), "amb");
        assert_eq!(Scheme::Fmb { per_node_batch: 10, t_consensus: 0.1 }.name(), "fmb");
        assert_eq!(
            Scheme::FmbBackup { per_node_batch: 10, t_consensus: 0.1, ignore: 2, coded: true }
                .name(),
            "fmb-coded"
        );
        assert_eq!(Scheme::Fmb { per_node_batch: 10, t_consensus: 0.25 }.t_consensus(), 0.25);
        assert_eq!(
            Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 1 }.name(),
            "amb-dg"
        );
        assert_eq!(
            Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 3 }.t_consensus(),
            0.5
        );
    }

    #[test]
    fn scheme_delay_and_wall() {
        let amb = Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 };
        let dg0 = Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 0 };
        let dg2 = Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 2 };
        assert_eq!(amb.delay(), 0);
        assert_eq!(dg0.delay(), 0);
        assert_eq!(dg2.delay(), 2);
        // undelayed epochs serialize compute + consensus; pipelined
        // epochs take only the longer window
        assert_eq!(amb.epoch_wall(2.0), 2.5);
        assert_eq!(dg0.epoch_wall(2.0), 2.5);
        assert_eq!(dg2.epoch_wall(2.0), 2.0);
        assert_eq!(
            Scheme::AmbDg { t_compute: 1.0, t_consensus: 4.0, delay: 1 }.epoch_wall(1.0),
            4.0,
            "a comm-bound pipeline is gated by T_c"
        );
        // D = 0 normalizes to the stock AMB scheme; D >= 1 and the other
        // schemes are untouched
        assert_eq!(dg0.normalized(), amb);
        assert_eq!(dg2.normalized(), dg2);
        assert_eq!(amb.normalized(), amb);
    }

    #[test]
    fn builders() {
        let c = RunSpec::amb("a", 2.5, 0.5, 5, 20, 1).with_exact_bt().with_node_log();
        assert!(c.exact_bt && c.record_node_log);
        assert_eq!(c.consensus, ConsensusMode::Gossip { rounds: 5 });
        let f = RunSpec::fmb("f", 600, 0.5, 5, 20, 1)
            .with_consensus(ConsensusMode::Exact)
            .with_grad_chunk(32)
            .with_slowdown(vec![2.0, 1.0])
            .with_time_scale(0.1);
        assert_eq!(f.consensus, ConsensusMode::Exact);
        assert_eq!(f.grad_chunk, 32);
        assert_eq!(f.slowdown, vec![2.0, 1.0]);
        assert!((f.time_scale - 0.1).abs() < 1e-12);
        // churn defaults to the paper's static membership
        assert!(c.churn.is_none() && f.churn.is_none());
        let ch = RunSpec::amb("c", 1.0, 0.2, 5, 10, 1)
            .with_churn(ChurnSpec::IidDropout { p: 0.2, seed: 3 });
        assert_eq!(ch.churn, ChurnSpec::IidDropout { p: 0.2, seed: 3 });
        let dg = RunSpec::amb_dg("dg", 2.5, 0.5, 2, 7, 20, 1);
        assert_eq!(dg.scheme, Scheme::AmbDg { t_compute: 2.5, t_consensus: 0.5, delay: 2 });
        assert_eq!(dg.consensus, ConsensusMode::Gossip { rounds: 7 });
        // the network model defaults to the paper's abstract budget and
        // is opt-in per spec
        assert!(c.network.is_abstract() && dg.network.is_abstract());
        // the fault plane defaults to all-clear and is opt-in per spec
        assert!(c.faults.is_none() && dg.faults.is_none());
        let fz = RunSpec::amb("z", 1.0, 0.2, 5, 10, 1)
            .with_faults(FaultSpec { loss: 0.05, ..FaultSpec::none() });
        assert!(!fz.faults.is_none() && fz.faults.has_link_faults());
        let nf = RunSpec::amb("n", 1.0, 0.2, 5, 10, 1)
            .with_network(NetworkModel::Fabric(crate::net::FabricSpec::uniform(0.005, 2.0e5)));
        assert_eq!(
            nf.network,
            NetworkModel::Fabric(crate::net::FabricSpec::uniform(0.005, 2.0e5))
        );
    }

    #[test]
    fn runtime_kind_parse() {
        assert_eq!(RuntimeKind::parse("sim"), Some(RuntimeKind::Sim));
        assert_eq!(RuntimeKind::parse("threaded"), Some(RuntimeKind::Threaded));
        assert_eq!(RuntimeKind::parse("bogus"), None);
        assert_eq!(RuntimeKind::Sim.name(), "sim");
        assert_eq!(RuntimeKind::Threaded.name(), "threaded");
    }

    #[test]
    fn node_log_push() {
        let mut l = NodeLog::new(2);
        l.push(0, 5, 1.5);
        l.push(1, 7, 2.0);
        l.push(0, 6, 1.6);
        assert_eq!(l.batches[0], vec![5, 6]);
        assert_eq!(l.compute_times[1], vec![2.0]);
    }
}
