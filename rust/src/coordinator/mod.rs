//! L3 coordinator — the paper's system contribution.
//!
//! Two interchangeable epoch schedulers over the same machinery:
//!
//! * **AMB** (Anytime Minibatch, this paper): every epoch gives each node
//!   a fixed compute window T — the per-node minibatch b_i(t) is whatever
//!   the node finished — then a fixed communication window T_c for
//!   averaging consensus on dual variables.  Epoch wall time is exactly
//!   T + T_c regardless of stragglers.
//! * **FMB** (fixed minibatch baseline): every node computes exactly b/n
//!   gradients; the epoch's compute phase lasts max_i T_i(t) (the slowest
//!   node gates everyone), then the same consensus window.
//!
//! Two cluster runtimes execute these schedules:
//! * [`sim`] — single-process discrete-event simulator with a virtual
//!   clock driven by a [`crate::straggler::StragglerModel`]; regenerates
//!   every figure deterministically.
//! * [`threaded`] — one OS thread per node, mpsc-channel "network",
//!   real wall-clock compute windows; the production-shaped runtime used
//!   by the end-to-end example.

pub mod sim;
pub mod threaded;

/// Epoch scheduling policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// Fixed compute time T and communication time T_c (seconds, virtual
    /// clock units in sim mode).
    Amb { t_compute: f64, t_consensus: f64 },
    /// Fixed per-node batch; epoch compute time = slowest node.
    Fmb { per_node_batch: usize, t_consensus: f64 },
    /// FMB with straggler mitigation via redundancy — the baseline family
    /// the paper's related work compares against (Chen et al. '17 backup
    /// workers; Tandon et al. '17 gradient coding):
    /// * `coded = false` (backup workers): the epoch ends when the
    ///   fastest n−ignore nodes finish b/n gradients; the stragglers'
    ///   work is DROPPED (b(t) = (n−ignore)·b/n).
    /// * `coded = true` (gradient coding): every node computes
    ///   (ignore+1)·b/n redundantly-assigned gradients so the full batch
    ///   is recoverable from any n−ignore nodes (b(t) = b, but each node
    ///   does (ignore+1)× work).
    FmbBackup { per_node_batch: usize, t_consensus: f64, ignore: usize, coded: bool },
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Amb { .. } => "amb",
            Scheme::Fmb { .. } => "fmb",
            Scheme::FmbBackup { coded: false, .. } => "fmb-backup",
            Scheme::FmbBackup { coded: true, .. } => "fmb-coded",
        }
    }
}

/// How dual variables are averaged in the consensus phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConsensusMode {
    /// Perfect averaging (ε = 0): hub-and-spoke master aggregation or the
    /// r → ∞ limit of Fig. 5.
    Exact,
    /// Fixed number of synchronous gossip rounds for every node.
    Gossip { rounds: usize },
    /// Per-node round counts r_i(t) ~ Uniform{mean−jitter, …, mean+jitter}
    /// (network-delay variability of paper Sec. 3).
    GossipJitter { mean: usize, jitter: usize },
}

/// Full configuration of one simulated run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub name: String,
    pub scheme: Scheme,
    pub consensus: ConsensusMode,
    pub epochs: usize,
    pub seed: u64,
    /// If false (default), each node normalises its dual by a b(t)
    /// estimate obtained through the same consensus channel (an extra
    /// scalar component); if true, nodes magically know exact b(t).
    pub exact_bt: bool,
    /// Record per-(node, epoch) batch sizes and compute times (Fig. 6/8
    /// histograms).
    pub record_node_log: bool,
}

impl RunConfig {
    pub fn amb(name: &str, t_compute: f64, t_consensus: f64, rounds: usize, epochs: usize, seed: u64) -> RunConfig {
        RunConfig {
            name: name.into(),
            scheme: Scheme::Amb { t_compute, t_consensus },
            consensus: ConsensusMode::Gossip { rounds },
            epochs,
            seed,
            exact_bt: false,
            record_node_log: false,
        }
    }

    pub fn fmb(name: &str, per_node_batch: usize, t_consensus: f64, rounds: usize, epochs: usize, seed: u64) -> RunConfig {
        RunConfig {
            name: name.into(),
            scheme: Scheme::Fmb { per_node_batch, t_consensus },
            consensus: ConsensusMode::Gossip { rounds },
            epochs,
            seed,
            exact_bt: false,
            record_node_log: false,
        }
    }

    pub fn with_consensus(mut self, mode: ConsensusMode) -> RunConfig {
        self.consensus = mode;
        self
    }

    pub fn with_node_log(mut self) -> RunConfig {
        self.record_node_log = true;
        self
    }

    pub fn with_exact_bt(mut self) -> RunConfig {
        self.exact_bt = true;
        self
    }
}

/// Per-(node, epoch) raw log for straggler histograms.
#[derive(Debug, Clone, Default)]
pub struct NodeLog {
    /// batches[node][epoch] = b_i(t).
    pub batches: Vec<Vec<usize>>,
    /// compute_times[node][epoch] = seconds node i spent computing in t.
    pub compute_times: Vec<Vec<f64>>,
}

impl NodeLog {
    pub fn new(n: usize) -> NodeLog {
        NodeLog { batches: vec![Vec::new(); n], compute_times: vec![Vec::new(); n] }
    }

    pub fn push(&mut self, node: usize, batch: usize, compute_time: f64) {
        self.batches[node].push(batch);
        self.compute_times[node].push(compute_time);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::Amb { t_compute: 1.0, t_consensus: 0.1 }.name(), "amb");
        assert_eq!(Scheme::Fmb { per_node_batch: 10, t_consensus: 0.1 }.name(), "fmb");
    }

    #[test]
    fn builders() {
        let c = RunConfig::amb("a", 2.5, 0.5, 5, 20, 1).with_exact_bt().with_node_log();
        assert!(c.exact_bt && c.record_node_log);
        assert_eq!(c.consensus, ConsensusMode::Gossip { rounds: 5 });
        let f = RunConfig::fmb("f", 600, 0.5, 5, 20, 1)
            .with_consensus(ConsensusMode::Exact);
        assert_eq!(f.consensus, ConsensusMode::Exact);
    }

    #[test]
    fn node_log_push() {
        let mut l = NodeLog::new(2);
        l.push(0, 5, 1.5);
        l.push(1, 7, 2.0);
        l.push(0, 6, 1.6);
        assert_eq!(l.batches[0], vec![5, 6]);
        assert_eq!(l.compute_times[1], vec![2.0]);
    }
}
