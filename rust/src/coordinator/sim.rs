//! Discrete-event cluster simulator with a virtual wall clock.
//!
//! Gradients are *really* computed (via the node's [`ExecEngine`] — native
//! math or PJRT artifacts); *time* is attributed by the straggler model,
//! so a 400-virtual-second EC2 run replays in milliseconds and every
//! figure is deterministic given its seed (DESIGN.md §2 substitution 1).
//!
//! Epoch t (paper Sec. 3 / Algorithm 1):
//!   compute   b_i(t) ← profile.grads_in_time(T)         (AMB)
//!             b_i(t) = b/n, time = max_i T_i(t)          (FMB)
//!             grad_sum_i, loss_i ← engine.grad_chunk
//!   consensus m_i⁽⁰⁾ = n·(b_i·z_i + grad_sum_i)  [+ scalar n·b_i channel]
//!             r rounds of m ← P m  (or exact averaging)
//!   update    z_i(t+1) = m_i⁽ʳ⁾ / b̂(t);  w_i(t+1) = argmin ⟨w,z⟩+βh(w)

use crate::consensus::Consensus;
use crate::coordinator::{ConsensusMode, NodeLog, RunConfig, Scheme};
use crate::exec::ExecEngine;
use crate::metrics::{EpochStats, RunRecord};
use crate::straggler::StragglerModel;
use crate::topology::Topology;
use crate::util::rng::Pcg64;

/// Result of a simulated run.
pub struct SimOutput {
    pub record: RunRecord,
    pub node_log: Option<NodeLog>,
    /// Final primal variables per node.
    pub final_w: Vec<Vec<f32>>,
}

/// Run one configuration on a simulated cluster.
///
/// `make_engine(i)` constructs node i's execution engine (all nodes must
/// share the same workload); `f_star` is the per-sample optimal loss used
/// for regret accounting (see [`crate::exec::DataSource::f_star`]).
pub fn run<F>(
    cfg: &RunConfig,
    topo: &Topology,
    straggler: &dyn StragglerModel,
    mut make_engine: F,
    f_star: f64,
) -> SimOutput
where
    F: FnMut(usize) -> Box<dyn ExecEngine>,
{
    let n = topo.n();
    let mut engines: Vec<Box<dyn ExecEngine>> = (0..n).map(&mut make_engine).collect();
    let dim = engines[0].workload().dim();
    for e in &engines {
        assert_eq!(e.workload().dim(), dim, "engines must share a workload");
    }

    // Independent, deterministic RNG streams.
    let mut root = Pcg64::new(cfg.seed);
    let mut strag_rng = root.split(0x57);
    let mut data_rngs: Vec<Pcg64> = (0..n).map(|i| root.split(0xDA_00 + i as u64)).collect();
    let mut metric_rng = root.split(0x3E);
    let mut rounds_rng = root.split(0x20);

    // Consensus machinery (lazy P for the PSD assumption; see topology.rs).
    let mut cons = Consensus::new(topo.metropolis().lazy());

    // Node state; w(1) = argmin h(w) per engine (paper eq. (2)).
    let mut w: Vec<Vec<f32>> = (0..n).map(|i| engines[i].initial_primal()).collect();
    let mut z: Vec<Vec<f32>> = vec![vec![0.0f32; dim]; n];
    // Messages carry dim + 1 components: the dual payload and the n·b_i
    // side channel used to estimate b(t) distributively.
    let mut msgs: Vec<Vec<f32>> = vec![vec![0.0f32; dim + 1]; n];
    let mut grad_sums: Vec<Vec<f32>> = vec![vec![0.0f32; dim]; n];
    let mut rounds_buf = vec![0usize; n];

    let mut record = RunRecord::new(&cfg.name, f_star);
    let mut node_log = cfg.record_node_log.then(|| NodeLog::new(n));
    let mut wall = 0.0f64;

    for t in 1..=cfg.epochs {
        // ---- compute phase -------------------------------------------------
        let mut batches = vec![0usize; n];
        let mut potentials = vec![0usize; n];
        let mut compute_times = vec![0.0f64; n];
        let epoch_compute_time;
        match cfg.scheme {
            Scheme::Amb { t_compute, t_consensus } => {
                for i in 0..n {
                    let mut prof = straggler.draw(i, t, &mut strag_rng);
                    batches[i] = prof.grads_in_time(t_compute);
                    compute_times[i] = t_compute;
                    // potential work c_i(t): what the node could have done
                    // with the consensus window too (regret accounting,
                    // paper Sec. 4.2).  Fresh profile draw: an unbiased
                    // estimate with identical distribution.
                    let mut prof2 = straggler.draw(i, t, &mut strag_rng);
                    potentials[i] = prof2.grads_in_time(t_compute + t_consensus).max(batches[i]);
                }
                epoch_compute_time = t_compute;
            }
            Scheme::Fmb { per_node_batch, .. } => {
                let mut slowest = 0.0f64;
                for i in 0..n {
                    let mut prof = straggler.draw(i, t, &mut strag_rng);
                    batches[i] = per_node_batch;
                    compute_times[i] = prof.time_for_grads(per_node_batch);
                    slowest = slowest.max(compute_times[i]);
                }
                for p in potentials.iter_mut().zip(&batches) {
                    *p.0 = *p.1; // FMB: everyone computes exactly the quota
                }
                epoch_compute_time = slowest;
            }
            Scheme::FmbBackup { per_node_batch, ignore, coded, .. } => {
                // Redundancy baseline: wait only for the fastest
                // n-ignore nodes.  Coded variant makes every node compute
                // (ignore+1)x the quota so the batch stays whole.
                let ignore = ignore.min(n.saturating_sub(1));
                let work = if coded { per_node_batch * (ignore + 1) } else { per_node_batch };
                for i in 0..n {
                    let mut prof = straggler.draw(i, t, &mut strag_rng);
                    compute_times[i] = prof.time_for_grads(work);
                }
                let mut sorted = compute_times.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let cutoff = sorted[n - 1 - ignore];
                for i in 0..n {
                    let on_time = compute_times[i] <= cutoff;
                    batches[i] = if coded {
                        // full batch recoverable; attribute the quota to
                        // the on-time nodes (each decoded share is b/n on
                        // average — we charge b/(n-ignore) to survivors).
                        if on_time { per_node_batch * n / (n - ignore) } else { 0 }
                    } else if on_time {
                        per_node_batch
                    } else {
                        0
                    };
                    potentials[i] = work.max(batches[i]);
                }
                epoch_compute_time = cutoff;
            }
        }
        let b_t: usize = batches.iter().sum();
        let c_t: usize = potentials.iter().sum();

        let mut loss_sum = 0.0f64;
        for i in 0..n {
            grad_sums[i].fill(0.0);
            loss_sum += engines[i].grad_chunk(&w[i], batches[i], &mut data_rngs[i], &mut grad_sums[i]);
        }

        // ---- consensus phase ------------------------------------------------
        // m_i⁽⁰⁾ = n (b_i z_i + grad_sum_i); side channel n·b_i.
        for i in 0..n {
            let bi = batches[i] as f32;
            let m = &mut msgs[i];
            for k in 0..dim {
                m[k] = n as f32 * (bi * z[i][k] + grad_sums[i][k]);
            }
            m[dim] = n as f32 * bi;
        }
        let exact_avg = Consensus::exact_average(&msgs);
        match cfg.consensus {
            ConsensusMode::Exact => {
                for m in msgs.iter_mut() {
                    for k in 0..=dim {
                        m[k] = exact_avg[k] as f32;
                    }
                }
            }
            ConsensusMode::Gossip { rounds } => {
                cons.run(&mut msgs, rounds);
            }
            ConsensusMode::GossipJitter { mean, jitter } => {
                for r in rounds_buf.iter_mut() {
                    let lo = mean.saturating_sub(jitter);
                    let hi = mean + jitter;
                    *r = lo + rounds_rng.below((hi - lo + 1) as u64) as usize;
                }
                cons.run_per_node(&mut msgs, &rounds_buf);
            }
        }

        // ---- update phase ----------------------------------------------------
        let t_consensus = match cfg.scheme {
            Scheme::Amb { t_consensus, .. }
            | Scheme::Fmb { t_consensus, .. }
            | Scheme::FmbBackup { t_consensus, .. } => t_consensus,
        };
        wall += epoch_compute_time + t_consensus;

        let mut consensus_err = 0.0f64;
        if b_t > 0 {
            for i in 0..n {
                let b_hat = if cfg.exact_bt { b_t as f32 } else { msgs[i][dim].max(1e-6) };
                for k in 0..dim {
                    z[i][k] = msgs[i][k] / b_hat;
                }
                // node i's consensus error vs the exact normalised dual
                let mut ss = 0.0f64;
                for k in 0..dim {
                    let exact = exact_avg[k] / b_t as f64;
                    let diff = z[i][k] as f64 - exact;
                    ss += diff * diff;
                }
                consensus_err = consensus_err.max(ss.sqrt());
            }
            for i in 0..n {
                let zi = std::mem::take(&mut z[i]);
                engines[i].primal_step(&zi, t + 1, &mut w[i]);
                z[i] = zi;
            }
        }
        // (if b_t == 0 the epoch produced nothing; state carries over)

        if let Some(log) = node_log.as_mut() {
            for i in 0..n {
                log.push(i, batches[i], compute_times[i]);
            }
        }

        let error = engines[0].error_metric(&w[0], &mut metric_rng);
        record.push(EpochStats {
            epoch: t,
            wall_time: wall,
            batch: b_t,
            potential: c_t,
            loss: if b_t > 0 { loss_sum / b_t as f64 } else { f64::NAN },
            error,
            consensus_err,
            min_node_batch: batches.iter().copied().min().unwrap_or(0),
            max_node_batch: batches.iter().copied().max().unwrap_or(0),
        });
    }

    SimOutput { record, node_log, final_w: w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LinRegStream;
    use crate::exec::{DataSource, NativeExec};
    use crate::optim::{BetaSchedule, DualAveraging};
    use crate::straggler::{Deterministic, ShiftedExp};
    use std::sync::Arc;

    fn linreg_setup(d: usize, seed: u64) -> (Arc<DataSource>, DualAveraging) {
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
        // radius comfortably containing w* (E||w*|| ≈ sqrt(d))
        let opt = DualAveraging::new(BetaSchedule::new(1.0, 600.0), 4.0 * (d as f64).sqrt());
        (src, opt)
    }

    fn run_amb(epochs: usize, rounds: usize, seed: u64) -> SimOutput {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(32, 3);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 60 };
        let f_star = src.f_star();
        let cfg = RunConfig::amb("amb", 2.5, 0.5, rounds, epochs, seed);
        run(
            &cfg,
            &topo,
            &strag,
            |_| Box::new(NativeExec::new(src.clone(), opt.clone())),
            f_star,
        )
    }

    #[test]
    fn amb_wall_time_is_deterministic() {
        let out = run_amb(10, 5, 1);
        // epoch time == T + Tc exactly, stragglers or not
        for (i, e) in out.record.epochs.iter().enumerate() {
            assert!((e.wall_time - 3.0 * (i + 1) as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn amb_reduces_error() {
        let out = run_amb(25, 8, 2);
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first * 0.2, "first={first} last={last}");
    }

    #[test]
    fn amb_batches_vary_fmb_batches_fixed() {
        let out = run_amb(10, 5, 3);
        let varies = out
            .record
            .epochs
            .iter()
            .any(|e| e.min_node_batch != e.max_node_batch);
        assert!(varies, "AMB batches should vary across nodes");

        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(32, 3);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 60 };
        let cfg = RunConfig::fmb("fmb", 60, 0.5, 5, 10, 3);
        let fout = run(
            &cfg,
            &topo,
            &strag,
            |_| Box::new(NativeExec::new(src.clone(), opt.clone())),
            src.f_star(),
        );
        for e in &fout.record.epochs {
            assert_eq!(e.min_node_batch, 60);
            assert_eq!(e.max_node_batch, 60);
            assert_eq!(e.batch, 600);
        }
        // FMB wall time is gated by the max order statistic > mean
        let mean_unit = 1.0 + 1.5; // zeta + 1/lambda
        let total = fout.record.total_time();
        assert!(total > 10.0 * (mean_unit + 0.5), "total={total}");
    }

    #[test]
    fn seeded_runs_bit_reproducible() {
        let a = run_amb(8, 5, 7);
        let b = run_amb(8, 5, 7);
        for (x, y) in a.record.epochs.iter().zip(&b.record.epochs) {
            assert_eq!(x.batch, y.batch);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.error.to_bits(), y.error.to_bits());
        }
        let c = run_amb(8, 5, 8);
        assert_ne!(
            a.record.epochs[2].batch, c.record.epochs[2].batch,
            "different seeds should differ (overwhelmingly likely)"
        );
    }

    #[test]
    fn exact_consensus_zeroes_consensus_error() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 5);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 50 };
        let cfg = RunConfig::amb("amb", 1.0, 0.2, 5, 5, 9)
            .with_consensus(ConsensusMode::Exact);
        let out = run(
            &cfg,
            &topo,
            &strag,
            |_| Box::new(NativeExec::new(src.clone(), opt.clone())),
            src.f_star(),
        );
        for e in &out.record.epochs {
            assert!(e.consensus_err < 1e-5, "err={}", e.consensus_err);
        }
    }

    #[test]
    fn more_rounds_less_consensus_error() {
        let err_with = |rounds: usize| {
            let out = run_amb(6, rounds, 11);
            out.record.epochs.iter().map(|e| e.consensus_err).sum::<f64>() / 6.0
        };
        let e2 = err_with(2);
        let e10 = err_with(10);
        assert!(e10 < e2, "e2={e2} e10={e10}");
    }

    #[test]
    fn deterministic_model_all_nodes_equal_batches() {
        let topo = Topology::ring(6);
        let (src, opt) = linreg_setup(8, 6);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let cfg = RunConfig::amb("amb", 2.0, 0.5, 4, 4, 13).with_node_log();
        let out = run(
            &cfg,
            &topo,
            &strag,
            |_| Box::new(NativeExec::new(src.clone(), opt.clone())),
            src.f_star(),
        );
        let log = out.node_log.unwrap();
        for node in 0..6 {
            assert_eq!(log.batches[node], vec![80, 80, 80, 80]);
        }
    }

    #[test]
    fn bt_estimation_close_to_exact() {
        // With enough consensus rounds, normalising by the distributively
        // estimated b̂(t) must land each node's primal within a small
        // relative distance of the exact-b(t) run (single epoch so curves
        // cannot drift apart).
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 8);
        let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 50 };
        let mk = |exact: bool| {
            let mut cfg = RunConfig::amb("amb", 2.0, 0.5, 120, 1, 21);
            if exact {
                cfg = cfg.with_exact_bt();
            }
            run(
                &cfg,
                &topo,
                &strag,
                |_| Box::new(NativeExec::new(src.clone(), opt.clone())),
                src.f_star(),
            )
        };
        let est = mk(false);
        let ex = mk(true);
        for i in 0..10 {
            let (we, wx) = (&est.final_w[i], &ex.final_w[i]);
            let mut diff = 0.0f64;
            let mut norm = 0.0f64;
            for k in 0..we.len() {
                diff += ((we[k] - wx[k]) as f64).powi(2);
                norm += (wx[k] as f64).powi(2);
            }
            assert!(
                diff.sqrt() <= 0.02 * norm.sqrt().max(1e-9),
                "node {i}: rel diff {}",
                diff.sqrt() / norm.sqrt().max(1e-9)
            );
        }
    }

    #[test]
    fn gossip_jitter_runs() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(8, 9);
        let strag = ShiftedExp { zeta: 0.5, lambda: 1.0, unit_batch: 30 };
        let cfg = RunConfig::amb("amb", 2.0, 0.5, 5, 8, 31)
            .with_consensus(ConsensusMode::GossipJitter { mean: 5, jitter: 2 });
        let out = run(
            &cfg,
            &topo,
            &strag,
            |_| Box::new(NativeExec::new(src.clone(), opt.clone())),
            src.f_star(),
        );
        assert_eq!(out.record.epochs.len(), 8);
        assert!(out.record.epochs.last().unwrap().error.is_finite());
    }
}
