//! Discrete-event cluster runtime with a virtual wall clock.
//!
//! Gradients are *really* computed (via the node's [`ExecEngine`] — native
//! math or PJRT artifacts); *time* is attributed by the straggler model,
//! so a 400-virtual-second EC2 run replays in milliseconds and every
//! figure is deterministic given its seed (DESIGN.md §2 substitution 1).
//!
//! Epoch t (paper Sec. 3 / Algorithm 1) — the algebra lives in
//! [`crate::coordinator::epoch`], shared with the threaded runtime:
//!   compute   b_i(t) ← profile.grads_in_time(T)         (AMB)
//!             b_i(t) = b/n, time = max_i T_i(t)          (FMB)
//!             grad_sum_i, loss_i ← engine.grad_chunk
//!   consensus m_i⁽⁰⁾ = n·(b_i·z_i + grad_sum_i)  [+ scalar n·b_i channel]
//!             r rounds of m ← P m  (or exact averaging)
//!   update    z_i(t+1) = m_i⁽ʳ⁾ / b̂(t);  w_i(t+1) = argmin ⟨w,z⟩+βh(w)
//!
//! ## Execution (DESIGN.md §1 "threading model")
//!
//! Per-node work is independent within each phase (canonical per-(node,
//! epoch) RNG streams from [`epoch`]), so the epoch loop fans the
//! compute and update phases out across the worker pool
//! ([`crate::util::pool`]): each pool worker owns a CONTIGUOUS block of
//! nodes and builds its nodes' engines itself via the `Send + Sync`
//! factory (engines need not be `Send`; PJRT clients are thread-local).
//! The main thread keeps everything order-sensitive — straggler draws,
//! the consensus kernels (themselves row-partitioned), record keeping —
//! and exchanges per-phase messages with workers over mpsc channels.
//! Per-node values are identical at any thread count (same inputs, same
//! RNG streams, same op order), and the main thread folds them in node
//! order, so `threads = 1` and `threads = k` runs are BIT-IDENTICAL
//! (`tests/parallel_determinism.rs`).

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{bail, ensure, Result};

use crate::churn::ChurnSchedule;
use crate::consensus::churn::InducedConsensus;
use crate::consensus::hierarchical::HierarchicalConsensus;
use crate::consensus::Consensus;
use crate::coordinator::epoch::{self, NodeState};
use crate::coordinator::{
    ConsensusMode, EngineFactory, NodeLog, RunOutput, RunSpec, Runtime, RuntimeKind, Scheme,
};
use crate::exec::ExecEngine;
use crate::metrics::{EpochStats, RunRecord};
use crate::net::{FabricRounds, NetworkModel};
use crate::optim::DelayedGradients;
use crate::straggler::StragglerModel;
use crate::topology::Topology;
use crate::util::matrix::NodeMatrix;
use crate::util::pool;
use crate::util::rng::Pcg64;

/// Largest gossip-round budget the simulator will execute literally;
/// anything above is assumed to be the threaded runtime's "as many
/// rounds as fit in T_c" sentinel and rejected with a clear panic.
pub const MAX_SIM_GOSSIP_ROUNDS: usize = 100_000;

/// The simulated cluster: a straggler model supplies the virtual clock.
pub struct SimRuntime<'a> {
    straggler: &'a dyn StragglerModel,
}

impl<'a> SimRuntime<'a> {
    pub fn new(straggler: &'a dyn StragglerModel) -> SimRuntime<'a> {
        SimRuntime { straggler }
    }
}

impl Runtime for SimRuntime<'_> {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Sim
    }

    fn run(
        &self,
        spec: &RunSpec,
        topo: &Topology,
        make_engine: EngineFactory<'_>,
        f_star: Option<f64>,
    ) -> Result<RunOutput> {
        run_sim(spec, topo, self.straggler, make_engine, f_star)
    }
}

// ---------------------------------------------------------------------------
// Node-block executors: the per-node half of the epoch state machine,
// either inline (serial) or on pool workers (parallel).  Both produce
// bit-identical per-node values; the epoch loop is written once against
// this trait so the two paths cannot drift apart.
// ---------------------------------------------------------------------------

/// What one node's compute phase APPLIES this epoch: for the undelayed
/// schemes the batch it just computed; for AMB-DG the batch popped from
/// its pipeline ring (computed `staleness` epochs ago against the
/// then-current primal).
#[derive(Clone, Copy, Default)]
struct NodeApplied {
    b: usize,
    loss: f64,
    /// Epochs between compute and application; meaningful when b > 0.
    staleness: usize,
}

/// Compute phase over one contiguous node block `[lo, lo + k)`: per node
/// (ascending) `begin_epoch`, one attributed `grad_chunk` on the
/// canonical `data_rng(seed, node, epoch)` stream, then encode m⁽⁰⁾ into
/// the node's `dim + 1`-wide slot of `rows` (the block's slice of the
/// wire arena, or a worker-local staging buffer).  `rings` is the
/// AMB-DG pipeline (None for every undelayed scheme): the freshly
/// computed batch is pushed, the batch that has aged `delay` epochs is
/// popped and encoded against the node's CURRENT dual — for delay 0 the
/// push-then-pop round trip returns the batch just computed, so the
/// ring path is bit-identical to the direct encode.  Inactive nodes
/// neither push nor pop (absence freezes the pipeline; every batch is
/// still applied exactly once after rejoin).  Returns the block's
/// applied-batch reports in node order.  This ONE function is the
/// compute loop of both executors, so the serial and pooled paths
/// cannot drift apart.
#[allow(clippy::too_many_arguments)]
fn compute_block(
    engines: &mut [Box<dyn ExecEngine>],
    states: &mut [NodeState],
    rings: &mut Option<Vec<DelayedGradients>>,
    lo: usize,
    n_total: usize,
    seed: u64,
    epoch: usize,
    batches: &[usize],
    active: &[bool],
    rows: &mut [f32],
) -> Vec<NodeApplied> {
    let k = engines.len();
    let width = states[0].dim() + 1;
    debug_assert_eq!(batches.len(), k);
    debug_assert_eq!(active.len(), k);
    debug_assert_eq!(rows.len(), k * width);
    let mut applied = Vec::with_capacity(k);
    for li in 0..k {
        let st = &mut states[li];
        st.begin_epoch();
        let mut data_rng = epoch::data_rng(seed, lo + li, epoch);
        let loss = engines[li].grad_chunk(&st.w, batches[li], &mut data_rng, &mut st.grad_sum);
        let row = &mut rows[li * width..(li + 1) * width];
        match rings.as_mut() {
            None => {
                st.encode_into(n_total, batches[li], row);
                applied.push(NodeApplied { b: batches[li], loss, staleness: 0 });
            }
            Some(rings) => {
                let ring = &mut rings[li];
                if active[li] {
                    ring.push(epoch, batches[li], loss, &st.grad_sum);
                }
                let ready = if active[li] { ring.pop_ready() } else { None };
                match ready {
                    Some(p) => {
                        epoch::encode_msg_into(&st.z, &p.grad_sum, n_total, p.batch, row);
                        applied.push(NodeApplied {
                            b: p.batch,
                            loss: p.loss,
                            staleness: epoch - p.epoch,
                        });
                        ring.recycle(p);
                    }
                    None => {
                        // Warm-up (nothing aged enough) or absent: an
                        // empty message — n·(0·z + 0) — carries no mass,
                        // so consensus ignores it and the node's own
                        // update stays gated.
                        row.fill(0.0);
                        applied.push(NodeApplied::default());
                    }
                }
            }
        }
    }
    applied
}

/// Update phase over one contiguous node block: z ← m/b̂, w ← primal,
/// for the nodes `update` selects (a churn epoch's inactive nodes hold
/// their dual/primal state; an all-true mask is the static path).
/// `rows` holds the block's post-consensus messages, `dim + 1` wide each.
fn update_block(
    engines: &mut [Box<dyn ExecEngine>],
    states: &mut [NodeState],
    t_next: usize,
    rows: &[f32],
    b_hats: &[f32],
    update: &[bool],
) {
    let width = states[0].dim() + 1;
    for li in 0..engines.len() {
        if !update[li] {
            continue;
        }
        states[li].set_dual(&rows[li * width..(li + 1) * width], b_hats[li]);
        states[li].primal(&mut *engines[li], t_next);
    }
}

/// Copy a block's primal variables into a flat `[k × dim]` buffer.
fn write_primals(states: &[NodeState], dim: usize, out: &mut [f32]) {
    for (li, s) in states.iter().enumerate() {
        out[li * dim..(li + 1) * dim].copy_from_slice(&s.w);
    }
}

/// Build one node block's engines + states (the factory runs on the
/// CALLING thread) and return them with the shared workload dimension.
/// Shared by the serial executor and the pool workers so engine setup
/// cannot drift between the paths.
fn build_block(
    range: std::ops::Range<usize>,
    make_engine: EngineFactory<'_>,
) -> (Vec<Box<dyn ExecEngine>>, Vec<NodeState>, usize) {
    let engines: Vec<Box<dyn ExecEngine>> = range.map(make_engine).collect();
    let dim = engines[0].workload().dim();
    for e in &engines {
        assert_eq!(e.workload().dim(), dim, "engines must share a workload");
    }
    let states = engines.iter().map(|e| NodeState::new(&**e)).collect();
    (engines, states, dim)
}

trait NodeBlocks {
    fn dim(&self) -> usize;

    /// Compute phase for every node i (ascending): `begin_epoch`, one
    /// attributed `grad_chunk` on the canonical `data_rng(seed, i, t)`
    /// stream, then encode m_i⁽⁰⁾ — the freshly computed batch, or the
    /// delay-ripened one from the AMB-DG pipeline ring — into
    /// `msgs.row(i)`.  `active` masks the epoch's membership (the ring
    /// freezes across absence).  Returns the per-node applied-batch
    /// reports in node order.
    fn compute_and_encode(
        &mut self,
        epoch: usize,
        batches: &[usize],
        active: &[bool],
        msgs: &mut NodeMatrix,
    ) -> Vec<NodeApplied>;

    /// Update phase: z_i ← msgs.row(i)/b̂_i and w_i ← primal(t_next)
    /// for every node `update` selects (all-false when b(t) = 0;
    /// inactive churn nodes excluded — they hold state); always returns
    /// node 0's error metric on its (possibly carried-over) primal,
    /// drawn from the run-long sequential `metric_rng(seed, 0)` stream.
    fn update_and_error(
        &mut self,
        t_next: usize,
        msgs: &NodeMatrix,
        b_hats: &[f32],
        update: &[bool],
    ) -> f64;

    /// Crash-onset state reset for the nodes `which` selects: dual,
    /// primal, gradient accumulator, and (AMB-DG) the pipeline ring are
    /// rebuilt from scratch — the node forgets everything, unlike a
    /// churn absence which freezes and resumes.  Called once at the
    /// FIRST epoch of each crash window (`FaultSpec::crash_onset`).
    fn reset_nodes(&mut self, which: &[bool]);

    /// Final primal arena (one row per node).
    fn final_w(&mut self) -> NodeMatrix;
}

/// Build the per-node AMB-DG pipeline rings for a block of `k` nodes
/// (None for undelayed schemes — their hot path never touches a ring).
fn build_rings(delay: Option<usize>, k: usize) -> Option<Vec<DelayedGradients>> {
    delay.map(|d| (0..k).map(|_| DelayedGradients::new(d)).collect())
}

/// Serial executor: all engines and states on the calling thread — the
/// reference path (`--threads 1`).
struct SerialBlocks {
    seed: u64,
    dim: usize,
    engines: Vec<Box<dyn ExecEngine>>,
    states: Vec<NodeState>,
    rings: Option<Vec<DelayedGradients>>,
    /// AMB-DG pipeline depth, kept for crash-onset ring rebuilds.
    delay: Option<usize>,
    metric_rng: Pcg64,
}

impl SerialBlocks {
    fn new(
        n: usize,
        make_engine: EngineFactory<'_>,
        seed: u64,
        delay: Option<usize>,
    ) -> SerialBlocks {
        let (engines, states, dim) = build_block(0..n, make_engine);
        SerialBlocks {
            seed,
            dim,
            engines,
            states,
            rings: build_rings(delay, n),
            delay,
            metric_rng: epoch::metric_rng(seed, 0),
        }
    }
}

/// The ONE crash-reset body, shared by both executors (and the pool
/// workers) so the paths cannot drift: rebuild state from the engine's
/// initial workload and empty the AMB-DG ring.
fn reset_block(
    engines: &[Box<dyn ExecEngine>],
    states: &mut [NodeState],
    rings: &mut Option<Vec<DelayedGradients>>,
    delay: Option<usize>,
    which: &[bool],
) {
    for li in 0..states.len() {
        if !which[li] {
            continue;
        }
        states[li] = NodeState::new(&*engines[li]);
        if let (Some(rings), Some(d)) = (rings.as_mut(), delay) {
            rings[li] = DelayedGradients::new(d);
        }
    }
}

impl NodeBlocks for SerialBlocks {
    fn dim(&self) -> usize {
        self.dim
    }

    fn compute_and_encode(
        &mut self,
        epoch: usize,
        batches: &[usize],
        active: &[bool],
        msgs: &mut NodeMatrix,
    ) -> Vec<NodeApplied> {
        // The full arena is one contiguous block covering nodes 0..n.
        let n = self.engines.len();
        compute_block(
            &mut self.engines,
            &mut self.states,
            &mut self.rings,
            0,
            n,
            self.seed,
            epoch,
            batches,
            active,
            msgs.as_mut_slice(),
        )
    }

    fn update_and_error(
        &mut self,
        t_next: usize,
        msgs: &NodeMatrix,
        b_hats: &[f32],
        update: &[bool],
    ) -> f64 {
        if update.iter().any(|&u| u) {
            update_block(
                &mut self.engines,
                &mut self.states,
                t_next,
                msgs.as_slice(),
                b_hats,
                update,
            );
        }
        self.engines[0].error_metric(&self.states[0].w, &mut self.metric_rng)
    }

    fn reset_nodes(&mut self, which: &[bool]) {
        reset_block(&self.engines, &mut self.states, &mut self.rings, self.delay, which);
    }

    fn final_w(&mut self) -> NodeMatrix {
        let mut final_w = NodeMatrix::new(self.states.len(), self.dim);
        write_primals(&self.states, self.dim, final_w.as_mut_slice());
        final_w
    }
}

// ---------------------------------------------------------------------------
// Pooled executor: contiguous node blocks on run-long pool workers
// ---------------------------------------------------------------------------

/// One phase command to a worker (payloads are the worker's own nodes,
/// in node order).
enum Cmd {
    Compute { epoch: usize, batches: Vec<usize>, active: Vec<bool> },
    /// `update` masks the worker's nodes (node order within the block);
    /// `rows`/`b_hats` are empty when no node in the block updates.
    Update { t_next: usize, rows: Vec<f32>, b_hats: Vec<f32>, update: Vec<bool> },
    /// Crash-onset reset for the masked nodes of the worker's block.
    Reset { which: Vec<bool> },
    Finish,
}

/// A worker's phase result.
enum Reply {
    Ready { dim: usize },
    Computed { worker: usize, applied: Vec<NodeApplied>, rows: Vec<f32> },
    Updated { worker: usize, error: f64 },
    ResetDone,
    Finished { worker: usize, w_rows: Vec<f32> },
}

/// Main-thread handle to the worker set.  Dropping it disconnects the
/// command channels, so workers exit even when the epoch loop unwinds.
struct PooledBlocks {
    n: usize,
    dim: usize,
    /// Node range `[lo, hi)` per worker; worker 0 owns node 0.
    spans: Vec<(usize, usize)>,
    cmd_txs: Vec<Sender<Cmd>>,
    reply_rx: Receiver<Reply>,
}

impl PooledBlocks {
    fn send(&self, worker: usize, cmd: Cmd) {
        // amb-lint: allow(D4, "pool workers outlive the coordinator; a dead worker is a crashed run")
        self.cmd_txs[worker].send(cmd).expect("sim pool worker exited early");
    }

    fn recv(&self) -> Reply {
        // amb-lint: allow(D4, "pool workers outlive the coordinator; a dead worker is a crashed run")
        self.reply_rx.recv().expect("sim pool worker died")
    }
}

impl NodeBlocks for PooledBlocks {
    fn dim(&self) -> usize {
        self.dim
    }

    fn compute_and_encode(
        &mut self,
        epoch: usize,
        batches: &[usize],
        active: &[bool],
        msgs: &mut NodeMatrix,
    ) -> Vec<NodeApplied> {
        for (w, &(lo, hi)) in self.spans.iter().enumerate() {
            self.send(
                w,
                Cmd::Compute {
                    epoch,
                    batches: batches[lo..hi].to_vec(),
                    active: active[lo..hi].to_vec(),
                },
            );
        }
        let width = self.dim + 1;
        let mut applied = vec![NodeApplied::default(); self.n];
        for _ in 0..self.spans.len() {
            match self.recv() {
                Reply::Computed { worker, applied: ap, rows } => {
                    let (lo, hi) = self.spans[worker];
                    // block rows are contiguous in the arena
                    msgs.as_mut_slice()[lo * width..hi * width].copy_from_slice(&rows);
                    applied[lo..hi].copy_from_slice(&ap);
                }
                // amb-lint: allow(D4, "pool reply protocol: each request gets its matching reply variant")
                _ => unreachable!("sim pool protocol violation (expected Computed)"),
            }
        }
        applied
    }

    fn update_and_error(
        &mut self,
        t_next: usize,
        msgs: &NodeMatrix,
        b_hats: &[f32],
        update: &[bool],
    ) -> f64 {
        let width = self.dim + 1;
        for (w, &(lo, hi)) in self.spans.iter().enumerate() {
            let mask = update[lo..hi].to_vec();
            let (rows, bh) = if mask.iter().any(|&u| u) {
                (msgs.as_slice()[lo * width..hi * width].to_vec(), b_hats[lo..hi].to_vec())
            } else {
                (Vec::new(), Vec::new())
            };
            self.send(w, Cmd::Update { t_next, rows, b_hats: bh, update: mask });
        }
        let mut error = f64::NAN;
        for _ in 0..self.spans.len() {
            match self.recv() {
                Reply::Updated { worker, error: e } => {
                    if worker == 0 {
                        error = e;
                    }
                }
                // amb-lint: allow(D4, "pool reply protocol: each request gets its matching reply variant")
                _ => unreachable!("sim pool protocol violation (expected Updated)"),
            }
        }
        error
    }

    fn reset_nodes(&mut self, which: &[bool]) {
        for (w, &(lo, hi)) in self.spans.iter().enumerate() {
            self.send(w, Cmd::Reset { which: which[lo..hi].to_vec() });
        }
        for _ in 0..self.spans.len() {
            match self.recv() {
                Reply::ResetDone => {}
                // amb-lint: allow(D4, "pool reply protocol: each request gets its matching reply variant")
                _ => unreachable!("sim pool protocol violation (expected ResetDone)"),
            }
        }
    }

    fn final_w(&mut self) -> NodeMatrix {
        for w in 0..self.spans.len() {
            self.send(w, Cmd::Finish);
        }
        let mut final_w = NodeMatrix::new(self.n, self.dim);
        for _ in 0..self.spans.len() {
            match self.recv() {
                Reply::Finished { worker, w_rows } => {
                    let (lo, hi) = self.spans[worker];
                    final_w.as_mut_slice()[lo * self.dim..hi * self.dim]
                        .copy_from_slice(&w_rows);
                }
                // amb-lint: allow(D4, "pool reply protocol: each request gets its matching reply variant")
                _ => unreachable!("sim pool protocol violation (expected Finished)"),
            }
        }
        final_w
    }
}

/// Everything a pool worker needs (grouping keeps the spawn site sane,
/// like the threaded runtime's `NodeCtx`).
struct WorkerCtx {
    worker: usize,
    /// Owned node range `[lo, hi)`.
    lo: usize,
    hi: usize,
    n_total: usize,
    seed: u64,
    /// AMB-DG pipeline depth (None for undelayed schemes); workers own
    /// their nodes' rings for the whole run, like engines and states.
    delay: Option<usize>,
    rx: Receiver<Cmd>,
    tx: Sender<Reply>,
}

/// Worker body: build this block's engines (factory runs on THIS
/// thread, like the threaded runtime's node threads), then serve phase
/// commands until the channel disconnects.
fn sim_worker(ctx: WorkerCtx, make_engine: EngineFactory<'_>) {
    let WorkerCtx { worker, lo, hi, n_total, seed, delay, rx, tx } = ctx;
    // Nested pool calls from engine code must not multiply threads.
    crate::util::pool::mark_pool_worker();
    let (mut engines, mut states, dim) = build_block(lo..hi, make_engine);
    let mut rings = build_rings(delay, hi - lo);
    // The run-long sequential metric stream lives with node 0's owner.
    let mut metric_rng = (worker == 0).then(|| epoch::metric_rng(seed, 0));
    if tx.send(Reply::Ready { dim }).is_err() {
        return;
    }
    let width = dim + 1;
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Compute { epoch, batches, active } => {
                let mut rows = vec![0.0f32; (hi - lo) * width];
                let applied = compute_block(
                    &mut engines,
                    &mut states,
                    &mut rings,
                    lo,
                    n_total,
                    seed,
                    epoch,
                    &batches,
                    &active,
                    &mut rows,
                );
                if tx.send(Reply::Computed { worker, applied, rows }).is_err() {
                    break;
                }
            }
            Cmd::Update { t_next, rows, b_hats, update } => {
                if update.iter().any(|&u| u) {
                    update_block(&mut engines, &mut states, t_next, &rows, &b_hats, &update);
                }
                let error = match metric_rng.as_mut() {
                    Some(rng) => engines[0].error_metric(&states[0].w, rng),
                    None => f64::NAN,
                };
                if tx.send(Reply::Updated { worker, error }).is_err() {
                    break;
                }
            }
            Cmd::Reset { which } => {
                reset_block(&engines, &mut states, &mut rings, delay, &which);
                if tx.send(Reply::ResetDone).is_err() {
                    break;
                }
            }
            Cmd::Finish => {
                let mut w_rows = vec![0.0f32; (hi - lo) * dim];
                write_primals(&states, dim, &mut w_rows);
                let _ = tx.send(Reply::Finished { worker, w_rows });
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The epoch loop (shared by both executors) and the entry point
// ---------------------------------------------------------------------------

fn run_sim(
    spec: &RunSpec,
    topo: &Topology,
    straggler: &dyn StragglerModel,
    make_engine: EngineFactory<'_>,
    f_star: Option<f64>,
) -> Result<RunOutput> {
    let n = topo.n();
    spec.faults.validate(n)?;
    if spec.faults.has_link_faults() {
        match spec.consensus {
            ConsensusMode::Exact => bail!(
                "link faults (loss/flap) require a gossip consensus mode: Exact consensus \
                 models a lossless master aggregation with no per-link messages to drop — \
                 use crashes only, or switch to Gossip/GossipJitter"
            ),
            ConsensusMode::Hierarchical { .. } => bail!(
                "link faults (loss/flap) are not modeled for Hierarchical consensus (the \
                 aggregator exchange has no per-edge rounds); crashes compose with every \
                 mode via membership"
            ),
            ConsensusMode::Gossip { .. } | ConsensusMode::GossipJitter { .. } => {}
        }
    }
    // AMB-DG runs through the pipeline ring at EVERY delay, including 0:
    // the `AmbDg { delay: 0 } ≡ Amb` bitwise contract is then a test of
    // the pipeline code itself, not of a bypass around it.
    let delay = match spec.scheme {
        Scheme::AmbDg { delay, .. } => Some(delay),
        _ => None,
    };
    let threads = pool::current_threads().min(n);
    if threads <= 1 {
        let mut nodes = SerialBlocks::new(n, make_engine, spec.seed, delay);
        return epoch_loop(spec, topo, straggler, f_star, &mut nodes);
    }
    std::thread::scope(|scope| {
        let (reply_tx, reply_rx) = channel::<Reply>();
        let mut cmd_txs = Vec::with_capacity(threads);
        let mut spans = Vec::with_capacity(threads);
        let base = n / threads;
        let extra = n % threads;
        let mut lo = 0usize;
        for w in 0..threads {
            let hi = lo + base + usize::from(w < extra);
            spans.push((lo, hi));
            let (tx, rx) = channel::<Cmd>();
            cmd_txs.push(tx);
            let ctx = WorkerCtx {
                worker: w,
                lo,
                hi,
                n_total: n,
                seed: spec.seed,
                delay,
                rx,
                tx: reply_tx.clone(),
            };
            scope.spawn(move || sim_worker(ctx, make_engine));
            lo = hi;
        }
        drop(reply_tx);
        let mut dim: Option<usize> = None;
        for _ in 0..threads {
            // amb-lint: allow(D4, "pool workers outlive the coordinator; a dead worker is a crashed run")
            match reply_rx.recv().expect("sim pool worker died during engine construction") {
                Reply::Ready { dim: d } => match dim {
                    None => dim = Some(d),
                    Some(dd) => assert_eq!(dd, d, "engines must share a workload"),
                },
                // amb-lint: allow(D4, "pool reply protocol: each request gets its matching reply variant")
                _ => unreachable!("sim pool protocol violation (expected Ready)"),
            }
        }
        let mut nodes = PooledBlocks {
            n,
            // amb-lint: allow(D4, "pool construction rejects zero workers")
            dim: dim.expect("at least one worker"),
            spans,
            cmd_txs,
            reply_rx,
        };
        epoch_loop(spec, topo, straggler, f_star, &mut nodes)
        // `nodes` drops here: command channels disconnect, workers exit,
        // the scope joins them.
    })
}

fn epoch_loop<B: NodeBlocks>(
    spec: &RunSpec,
    topo: &Topology,
    straggler: &dyn StragglerModel,
    f_star: Option<f64>,
    nodes: &mut B,
) -> Result<RunOutput> {
    let n = topo.n();
    let dim = nodes.dim();

    // Fault plane (ISSUE 8): crashes compose with churn through the
    // effective active mask; link faults thread drop masks through the
    // consensus kernels and the fabric.  All-clear specs skip every
    // fault branch, reproducing the no-fault run bit-for-bit.
    let faults = &spec.faults;
    let has_crashes = faults.has_crashes();
    let has_link = faults.has_link_faults();
    let mut eff_active = vec![false; n];
    let mut reset_buf = vec![false; n];

    // Canonical per-purpose RNG streams (shared with the threaded
    // runtime so one spec replays the same data everywhere).
    let mut strag_rng = epoch::straggler_rng(spec.seed);

    // Per-epoch membership, precomputed from the spec (pure function of
    // seed — the threaded runtime derives the identical table).
    let churn = ChurnSchedule::new(&spec.churn, n, spec.epochs);

    // Consensus machinery (lazy P for the PSD assumption; see
    // topology.rs).  The induced engine's all-active path IS the static
    // matrix + the static kernels, so runs without churn — and churn
    // schedules that happen never to drop a node — are bit-for-bit the
    // pre-churn outputs; churned epochs take induced matrices memoized
    // by active-set key (consensus::churn).
    let mut cons = InducedConsensus::new(topo.clone());

    // Two-level engine, built only when the spec asks for it (the shard
    // partition and intra topology are fixed for the whole run; churn
    // composes per epoch through the active mask).
    let mut hier = match spec.consensus {
        ConsensusMode::Hierarchical { shards, .. } => {
            Some(HierarchicalConsensus::new(topo, shards))
        }
        _ => None,
    };

    // Network fabric (ISSUE 6): when the spec opts out of the abstract
    // round budget, a discrete-event link simulation measures how many
    // gossip rounds fit in T_c per node, with the configured Gossip
    // budget as the cap.  Wire bytes follow the codec: dim+1 f32 rows.
    // Fabric + Exact is rejected (exact aggregation abstracts the
    // master; there are no per-link rounds to measure) and so is
    // Fabric + GossipJitter (jitter IS the abstract stand-in for the
    // variability the fabric derives from first principles).
    let mut fabric = match (&spec.network, spec.consensus) {
        (NetworkModel::Abstract, _) => None,
        (NetworkModel::Fabric(fab), ConsensusMode::Gossip { rounds }) => Some(FabricRounds::new(
            fab.clone(),
            (dim + 1) * 4,
            spec.scheme.t_consensus(),
            rounds,
        )),
        (NetworkModel::Fabric(_), ConsensusMode::Exact) => bail!(
            "NetworkModel::Fabric requires ConsensusMode::Gossip: Exact consensus models a \
             master aggregation with no per-link gossip rounds to measure"
        ),
        (NetworkModel::Fabric(_), ConsensusMode::GossipJitter { .. }) => bail!(
            "NetworkModel::Fabric requires ConsensusMode::Gossip: GossipJitter is the abstract \
             stand-in for the per-node round variability the fabric measures — use one or the \
             other"
        ),
        (NetworkModel::Fabric(_), ConsensusMode::Hierarchical { .. }) => bail!(
            "NetworkModel::Fabric requires ConsensusMode::Gossip: the hierarchical scheme's \
             aggregator exchange has no per-link fabric model (only flat gossip rounds are \
             measured)"
        ),
    };

    // The consensus wire: one flat [n × (dim+1)] arena, encoded/decoded
    // in place every epoch (no per-node buffers, no per-epoch allocation).
    let mut msgs = NodeMatrix::new(n, dim + 1);
    let mut rounds_buf = vec![0usize; n];
    let mut b_hats = vec![0.0f32; n];
    let mut update_mask = vec![false; n];

    let mut record = RunRecord::new(&spec.name, f_star);
    let mut node_log = spec.record_node_log.then(|| NodeLog::new(n));
    let mut rounds_log: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut active_counts = Vec::with_capacity(spec.epochs);
    let mut wall = 0.0f64;

    for t in 1..=spec.epochs {
        // Effective membership: churn ∧ not-crashed.  A crashed epoch
        // looks like an absence to every phase (plan draws for everyone
        // — stream invariance — but attributes zero batches), EXCEPT
        // that state is reset at onset instead of frozen.
        let active: &[bool] = if has_crashes {
            let churn_active = churn.active(t);
            for i in 0..n {
                eff_active[i] = churn_active[i] && !faults.crashed(i, t);
            }
            &eff_active
        } else {
            churn.active(t)
        };
        let act = active.iter().filter(|&&a| a).count();
        let all_active = act == n;
        active_counts.push(act);

        if has_crashes {
            let mut any = false;
            for i in 0..n {
                reset_buf[i] = faults.crash_onset(i, t);
                any |= reset_buf[i];
            }
            if any {
                nodes.reset_nodes(&reset_buf);
            }
        }

        // ---- compute phase -------------------------------------------------
        let mut plan =
            epoch::plan_compute(&spec.scheme, n, t, straggler, &mut strag_rng, active);
        // A rejoining node spends its first alive epoch re-syncing: it
        // computes nothing (batch forced to 0 AFTER the plan drew its
        // straggler times, keeping the RNG stream invariant), so its
        // zero-mass row picks up the neighborhood average and the update
        // gate applies the peer re-sync exactly once.
        if has_crashes {
            for i in 0..n {
                if active[i] && faults.rejoining(i, t) {
                    plan.batches[i] = 0;
                }
            }
        }
        let c_t: usize = plan.potentials.iter().sum();

        let applied = nodes.compute_and_encode(t, &plan.batches, active, &mut msgs);
        // b(t) is what this epoch's update CONSUMES: the batches just
        // computed for the undelayed schemes, the delay-ripened pipeline
        // batches for AMB-DG (0 during warm-up).
        let b_t: usize = applied.iter().map(|a| a.b).sum();
        // fold in node order — the serial accumulation sequence
        let mut loss_sum = 0.0f64;
        for a in &applied {
            loss_sum += a.loss;
        }

        // ---- consensus phase ------------------------------------------------
        // The exact average of the epoch's initial messages — over ALL
        // rows when everyone is present (the static code path, column-
        // pooled), over the ACTIVE rows under churn (inactive rows are
        // isolated and must not dilute the target).  None ⇔ nobody is
        // present, in which case the epoch is a membership no-op.
        let exact_avg: Option<Vec<f64>> = if all_active {
            // amb-lint: allow(D4, "RunSpec validation rejects empty topologies")
            Some(Consensus::exact_average(&msgs).expect("topology guarantees n > 0 nodes"))
        } else {
            InducedConsensus::active_mean_f64(&msgs, active)
        };
        // Substitute-self applications fired by this epoch's mixing
        // (always 0 on the clean path — the gate for drift measurement).
        let mut drops_fired = 0usize;
        match spec.consensus {
            ConsensusMode::Exact => {
                if let Some(avg) = &exact_avg {
                    for i in 0..n {
                        if active[i] {
                            for (v, &a) in msgs.row_mut(i).iter_mut().zip(avg) {
                                *v = a as f32;
                            }
                        }
                    }
                }
                rounds_buf.fill(0);
            }
            ConsensusMode::Gossip { rounds } => {
                // The simulator executes EXACTLY `rounds` mixes; huge
                // values are the threaded-only "as many rounds as fit in
                // T_c" idiom and would loop for years here — fail loudly
                // instead of hanging.
                ensure!(
                    rounds <= MAX_SIM_GOSSIP_ROUNDS,
                    "Gossip {{ rounds: {rounds} }} on the simulator: this looks like the \
                     threaded-only GOSSIP_UNTIL_DEADLINE sentinel; the sim has no per-round \
                     time model and runs exactly `rounds` mixes — use a finite budget"
                );
                match fabric.as_mut() {
                    None => {
                        if act > 0 {
                            if has_link {
                                let masks = faults.epoch_masks(topo, active, t, rounds);
                                drops_fired =
                                    cons.run_faulty(&mut msgs, rounds, active, &masks);
                            } else {
                                cons.run(&mut msgs, rounds, active);
                            }
                        }
                        // Churn-isolated nodes (active, every neighbour
                        // down) log 0 rounds — they had nobody to gossip
                        // with, matching the threaded runtime's
                        // convention.  The all-active path keeps today's
                        // log bit-for-bit.
                        for (i, r) in rounds_buf.iter_mut().enumerate() {
                            let gossips = active[i]
                                && (all_active
                                    || topo.neighbors(i).iter().any(|&j| active[j]));
                            *r = if gossips { rounds } else { 0 };
                        }
                    }
                    Some(f) => {
                        // Measured per-node budgets (0 for inactive or
                        // churn-isolated nodes — the fabric applies the
                        // same participation rule as the abstract log
                        // above).  A node that measured fewer rounds
                        // freezes early via the same per-node machinery
                        // the jitter ablation uses; an ideal fabric
                        // measures the cap everywhere, making
                        // run_per_node's uniform-budget path bitwise
                        // identical to cons.run above.
                        if has_link {
                            // Fresh measurement per epoch (no memo: the
                            // SAME active set measures differently under
                            // a per-epoch loss pattern), with lost
                            // packets never arriving and a per-round
                            // timeout completing rounds with whatever
                            // neighborhood made it.  The measured masks
                            // then degrade the mixing consistently.
                            let masks = faults.epoch_masks(topo, active, t, f.cap());
                            rounds_buf
                                .copy_from_slice(f.rounds_faulty(topo, active, &masks, faults.round_timeout));
                            if act > 0 {
                                drops_fired = cons.run_per_node_faulty(
                                    &mut msgs,
                                    &rounds_buf,
                                    active,
                                    &masks,
                                );
                            }
                        } else {
                            rounds_buf.copy_from_slice(f.rounds(topo, active));
                            if act > 0 {
                                cons.run_per_node(&mut msgs, &rounds_buf, active);
                            }
                        }
                    }
                }
            }
            ConsensusMode::GossipJitter { mean, jitter } => {
                for (i, r) in rounds_buf.iter_mut().enumerate() {
                    let gossips = active[i]
                        && (all_active || topo.neighbors(i).iter().any(|&j| active[j]));
                    *r = if gossips {
                        epoch::gossip_jitter_rounds(spec.seed, i, t, mean, jitter)
                    } else {
                        0
                    };
                }
                if has_link {
                    let rmax = rounds_buf.iter().copied().max().unwrap_or(0);
                    let masks = faults.epoch_masks(topo, active, t, rmax);
                    drops_fired =
                        cons.run_per_node_faulty(&mut msgs, &rounds_buf, active, &masks);
                } else {
                    cons.run_per_node(&mut msgs, &rounds_buf, active);
                }
            }
            ConsensusMode::Hierarchical { intra_rounds, inter_rounds, .. } => {
                ensure!(
                    intra_rounds <= MAX_SIM_GOSSIP_ROUNDS
                        && inter_rounds <= MAX_SIM_GOSSIP_ROUNDS,
                    "Hierarchical {{ intra_rounds: {intra_rounds}, inter_rounds: \
                     {inter_rounds} }}: the sim executes these budgets literally — use \
                     finite values"
                );
                if act > 0 {
                    hier.as_mut()
                        // amb-lint: allow(D4, "engine built for Hierarchical mode in the arm above")
                        .expect("hierarchical engine built for Hierarchical mode")
                        .run(&mut msgs, intra_rounds, inter_rounds, active);
                }
                // The rounds log records per-node GOSSIP rounds; the
                // aggregator exchange is shard-level, so active nodes
                // log the intra budget and absent nodes 0.
                for (i, r) in rounds_buf.iter_mut().enumerate() {
                    *r = if active[i] { intra_rounds } else { 0 };
                }
            }
        }
        for i in 0..n {
            rounds_log[i].push(rounds_buf[i]);
        }

        // Conservation drift: lost messages make the degraded mix only
        // approximately mean-conserving — MEASURE the violation (L2
        // between the active-set mean before and after consensus, f64)
        // instead of pretending it away.  Exactly 0.0 whenever no drop
        // fired (clean epochs of a faulty run included).
        let conservation_drift = if drops_fired > 0 {
            // amb-lint: allow(D4, "a dropped message implies its sender was active this epoch")
            let before = exact_avg.as_ref().expect("drops imply an active node");
            let after = InducedConsensus::active_mean_f64(&msgs, active)
                // amb-lint: allow(D4, "consensus preserves the active-node key set")
                .expect("active set unchanged by consensus");
            let mut sq = 0.0f64;
            for (a, b) in after.iter().zip(before) {
                sq += (a - b) * (a - b);
            }
            sq.sqrt()
        } else {
            0.0
        };

        // ---- update phase ----------------------------------------------------
        // Undelayed schemes serialize compute + consensus; a pipelined
        // AMB-DG epoch overlaps them and only the longer window elapses.
        wall += spec.scheme.epoch_wall(plan.epoch_compute_time);

        let mut consensus_err = 0.0f64;
        let do_update = b_t > 0;
        if do_update {
            // amb-lint: allow(D4, "b_t > 0 implies at least one active node contributed")
            let avg = exact_avg.as_ref().expect("b_t > 0 requires an active node");
            consensus_err = if all_active {
                epoch::consensus_error(&msgs, avg, dim, b_t, spec.exact_bt)
            } else {
                epoch::consensus_error_active(&msgs, avg, dim, spec.exact_bt, active)
            };
            for i in 0..n {
                b_hats[i] = if !spec.exact_bt {
                    epoch::side_channel_b_hat(msgs.row(i))
                } else if all_active {
                    b_t as f32
                } else {
                    // churned oracle: perfect averaging over |A| nodes
                    // scales the side channel to n·b(t)/|A| — the exact
                    // value the ratio encoding divides back out.
                    avg[dim] as f32
                };
            }
        }
        // (if b_t == 0 the epoch produced nothing; state carries over —
        // and inactive nodes ALWAYS hold their state until they rejoin.)
        // The per-node gate on the node's OWN side channel mirrors the
        // threaded runtime: a node whose post-consensus message carries
        // no mass — e.g. churn isolated it with b_i = 0, so its row is
        // all-zero — holds its dual instead of zeroing it.  Gating on
        // the own side channel even under `exact_bt` matters: the
        // oracle b̂ only rescales the division, it cannot conjure mass
        // into a row nothing reached.
        for (i, u) in update_mask.iter_mut().enumerate() {
            *u = do_update
                && active[i]
                && epoch::side_channel_b_hat(msgs.row(i)) > 0.5;
        }
        let error = nodes.update_and_error(t + 1, &msgs, &b_hats, &update_mask);

        if let Some(log) = node_log.as_mut() {
            for i in 0..n {
                log.push(i, plan.batches[i], plan.compute_times[i]);
            }
        }

        // Staleness of what the epoch applied (0/0.0 for undelayed
        // schemes; NaN mean when nothing was applied, like `loss`).
        let mut max_staleness = 0usize;
        let mut staleness_wsum = 0.0f64;
        for a in &applied {
            if a.b > 0 {
                max_staleness = max_staleness.max(a.staleness);
                staleness_wsum += (a.b * a.staleness) as f64;
            }
        }
        record.push(EpochStats {
            epoch: t,
            wall_time: wall,
            batch: b_t,
            potential: c_t,
            loss: if b_t > 0 { loss_sum / b_t as f64 } else { f64::NAN },
            error,
            consensus_err,
            // min/max stay the COMPUTED per-node batches (the straggler
            // spread diagnostic, matching the node log), not the applied
            // ones.
            min_node_batch: plan.batches.iter().copied().min().unwrap_or(0),
            max_node_batch: plan.batches.iter().copied().max().unwrap_or(0),
            max_staleness,
            mean_staleness: if b_t > 0 { staleness_wsum / b_t as f64 } else { f64::NAN },
            conservation_drift,
        });
    }

    Ok(RunOutput {
        record,
        node_log,
        final_w: nodes.final_w(),
        rounds: rounds_log,
        active_counts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LinRegStream;
    use crate::exec::{DataSource, NativeExec};
    use crate::optim::{BetaSchedule, DualAveraging};
    use crate::straggler::{Deterministic, ShiftedExp};
    use std::sync::Arc;

    fn linreg_setup(d: usize, seed: u64) -> (Arc<DataSource>, DualAveraging) {
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
        // radius comfortably containing w* (E||w*|| ≈ sqrt(d))
        let opt = DualAveraging::new(BetaSchedule::new(1.0, 600.0), 4.0 * (d as f64).sqrt());
        (src, opt)
    }

    fn try_run_on(
        spec: &RunSpec,
        topo: &Topology,
        strag: &dyn StragglerModel,
        src: Arc<DataSource>,
        opt: DualAveraging,
    ) -> Result<RunOutput> {
        let f_star = src.f_star();
        let mk = move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        };
        SimRuntime::new(strag).run(spec, topo, &mk, f_star)
    }

    fn run_on(
        spec: &RunSpec,
        topo: &Topology,
        strag: &dyn StragglerModel,
        src: Arc<DataSource>,
        opt: DualAveraging,
    ) -> RunOutput {
        try_run_on(spec, topo, strag, src, opt).expect("spec should be runnable")
    }

    fn run_amb(epochs: usize, rounds: usize, seed: u64) -> RunOutput {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(32, 3);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 60 };
        let spec = RunSpec::amb("amb", 2.5, 0.5, rounds, epochs, seed);
        run_on(&spec, &topo, &strag, src, opt)
    }

    #[test]
    fn amb_wall_time_is_deterministic() {
        let out = run_amb(10, 5, 1);
        // epoch time == T + Tc exactly, stragglers or not
        for (i, e) in out.record.epochs.iter().enumerate() {
            assert!((e.wall_time - 3.0 * (i + 1) as f64).abs() < 1e-9);
        }
        // gossip rounds recorded for every (node, epoch)
        assert!(out.rounds.iter().all(|r| r == &vec![5usize; 10]));
    }

    #[test]
    fn amb_reduces_error() {
        let out = run_amb(25, 8, 2);
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first * 0.2, "first={first} last={last}");
    }

    #[test]
    fn amb_batches_vary_fmb_batches_fixed() {
        let out = run_amb(10, 5, 3);
        let varies = out
            .record
            .epochs
            .iter()
            .any(|e| e.min_node_batch != e.max_node_batch);
        assert!(varies, "AMB batches should vary across nodes");

        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(32, 3);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 60 };
        let spec = RunSpec::fmb("fmb", 60, 0.5, 5, 10, 3);
        let fout = run_on(&spec, &topo, &strag, src, opt);
        for e in &fout.record.epochs {
            assert_eq!(e.min_node_batch, 60);
            assert_eq!(e.max_node_batch, 60);
            assert_eq!(e.batch, 600);
        }
        // FMB wall time is gated by the max order statistic > mean
        let mean_unit = 1.0 + 1.5; // zeta + 1/lambda
        let total = fout.record.total_time();
        assert!(total > 10.0 * (mean_unit + 0.5), "total={total}");
    }

    #[test]
    fn seeded_runs_bit_reproducible() {
        let a = run_amb(8, 5, 7);
        let b = run_amb(8, 5, 7);
        for (x, y) in a.record.epochs.iter().zip(&b.record.epochs) {
            assert_eq!(x.batch, y.batch);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.error.to_bits(), y.error.to_bits());
        }
        let c = run_amb(8, 5, 8);
        assert_ne!(
            a.record.epochs[2].batch, c.record.epochs[2].batch,
            "different seeds should differ (overwhelmingly likely)"
        );
    }

    #[test]
    fn exact_consensus_zeroes_consensus_error() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 5);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 50 };
        let spec = RunSpec::amb("amb", 1.0, 0.2, 5, 5, 9)
            .with_consensus(ConsensusMode::Exact);
        let out = run_on(&spec, &topo, &strag, src, opt);
        for e in &out.record.epochs {
            assert!(e.consensus_err < 1e-5, "err={}", e.consensus_err);
        }
        // Exact aggregation records zero gossip rounds.
        assert!(out.rounds.iter().flatten().all(|&r| r == 0));
    }

    #[test]
    fn more_rounds_less_consensus_error() {
        let err_with = |rounds: usize| {
            let out = run_amb(6, rounds, 11);
            out.record.epochs.iter().map(|e| e.consensus_err).sum::<f64>() / 6.0
        };
        let e2 = err_with(2);
        let e10 = err_with(10);
        assert!(e10 < e2, "e2={e2} e10={e10}");
    }

    #[test]
    fn deterministic_model_all_nodes_equal_batches() {
        let topo = Topology::ring(6);
        let (src, opt) = linreg_setup(8, 6);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let spec = RunSpec::amb("amb", 2.0, 0.5, 4, 4, 13).with_node_log();
        let out = run_on(&spec, &topo, &strag, src, opt);
        let log = out.node_log.unwrap();
        for node in 0..6 {
            assert_eq!(log.batches[node], vec![80, 80, 80, 80]);
        }
    }

    #[test]
    fn bt_estimation_close_to_exact() {
        // With enough consensus rounds, normalising by the distributively
        // estimated b̂(t) must land each node's primal within a small
        // relative distance of the exact-b(t) run (single epoch so curves
        // cannot drift apart).
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 8);
        let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 50 };
        let mk = |exact: bool| {
            let mut spec = RunSpec::amb("amb", 2.0, 0.5, 120, 1, 21);
            if exact {
                spec = spec.with_exact_bt();
            }
            run_on(&spec, &topo, &strag, src.clone(), opt.clone())
        };
        let est = mk(false);
        let ex = mk(true);
        for i in 0..10 {
            let (we, wx) = (est.final_w.row(i), ex.final_w.row(i));
            let mut diff = 0.0f64;
            let mut norm = 0.0f64;
            for k in 0..we.len() {
                diff += ((we[k] - wx[k]) as f64).powi(2);
                norm += (wx[k] as f64).powi(2);
            }
            assert!(
                diff.sqrt() <= 0.02 * norm.sqrt().max(1e-9),
                "node {i}: rel diff {}",
                diff.sqrt() / norm.sqrt().max(1e-9)
            );
        }
    }

    #[test]
    fn gossip_jitter_runs() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(8, 9);
        let strag = ShiftedExp { zeta: 0.5, lambda: 1.0, unit_batch: 30 };
        let spec = RunSpec::amb("amb", 2.0, 0.5, 5, 8, 31)
            .with_consensus(ConsensusMode::GossipJitter { mean: 5, jitter: 2 });
        let out = run_on(&spec, &topo, &strag, src, opt);
        assert_eq!(out.record.epochs.len(), 8);
        assert!(out.record.epochs.last().unwrap().error.is_finite());
        // jitter draws stay inside the configured band
        assert!(out.rounds.iter().flatten().all(|&r| (3..=7).contains(&r)));
    }

    #[test]
    fn churn_trace_zeroes_absent_nodes_and_logs_membership() {
        use crate::churn::ChurnSpec;
        let topo = Topology::ring(4);
        let (src, opt) = linreg_setup(8, 7);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        // node 3 absent in even epochs, node 0 absent in epoch 3
        let trace = ChurnSpec::Trace {
            active: vec![
                vec![true, true, false, true],
                vec![true],
                vec![true],
                vec![true, false],
            ],
        };
        let spec = RunSpec::amb("churn-sim", 2.0, 0.5, 4, 4, 5)
            .with_node_log()
            .with_churn(trace);
        let out = run_on(&spec, &topo, &strag, src, opt);
        let log = out.node_log.unwrap();
        // deterministic model: present nodes compute 80, absent 0
        assert_eq!(log.batches[3], vec![80, 0, 80, 0]);
        assert_eq!(log.batches[0], vec![80, 80, 0, 80]);
        assert_eq!(log.batches[1], vec![80, 80, 80, 80]);
        // epoch 1 has everyone; afterwards exactly one node is out
        assert_eq!(out.active_counts, vec![4, 3, 3, 3]);
        // absent nodes complete zero gossip rounds
        assert_eq!(out.rounds[3], vec![4, 0, 4, 0]);
        // epoch batch sums only the present nodes
        let batches: Vec<usize> = out.record.epochs.iter().map(|e| e.batch).collect();
        assert_eq!(batches, vec![4 * 80, 3 * 80, 3 * 80, 3 * 80]);
        assert_eq!(out.record.epochs[1].min_node_batch, 0);
    }

    #[test]
    fn amb_dg_pipeline_warmup_staleness_and_wall() {
        let topo = Topology::ring(6);
        let (src, opt) = linreg_setup(8, 6);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let spec = RunSpec::amb_dg("dg", 2.0, 0.5, 1, 4, 5, 13).with_node_log();
        let out = run_on(&spec, &topo, &strag, src, opt);
        let batches: Vec<usize> = out.record.epochs.iter().map(|e| e.batch).collect();
        // D = 1: the first epoch applies nothing (warm-up); afterwards
        // every epoch applies the previous epoch's 6 × 80 samples.
        assert_eq!(batches, vec![0, 480, 480, 480, 480]);
        assert!(out.record.epochs[0].loss.is_nan());
        assert!(out.record.epochs[0].mean_staleness.is_nan());
        for e in &out.record.epochs[1..] {
            assert_eq!(e.max_staleness, 1);
            assert!((e.mean_staleness - 1.0).abs() < 1e-12);
            assert!(e.loss.is_finite());
        }
        // the node log records the COMPUTED batches: 80 every epoch
        let log = out.node_log.unwrap();
        for node in 0..6 {
            assert_eq!(log.batches[node], vec![80; 5]);
        }
        // pipelined wall clock: every epoch takes max(T, T_c) = 2.0
        for (i, e) in out.record.epochs.iter().enumerate() {
            assert!((e.wall_time - 2.0 * (i + 1) as f64).abs() < 1e-9);
        }
        // error still falls once the pipeline is warm
        let first = out.record.epochs[1].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first, "no progress: {first} -> {last}");
    }

    #[test]
    fn backup_and_coded_schemes_run() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 10);
        let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 50 };
        for coded in [false, true] {
            let spec = RunSpec::new(
                "bk",
                crate::coordinator::Scheme::FmbBackup {
                    per_node_batch: 50,
                    t_consensus: 0.5,
                    ignore: 3,
                    coded,
                },
                5,
                17,
            );
            let out = run_on(&spec, &topo, &strag, src.clone(), opt.clone());
            assert_eq!(out.record.epochs.len(), 5);
            for e in &out.record.epochs {
                assert!(e.batch > 0);
                // stragglers dropped => some node attributed 0
                assert_eq!(e.min_node_batch, 0);
            }
        }
    }

    #[test]
    fn ideal_fabric_matches_abstract_bitwise() {
        // The ISSUE 6 parity pin at unit-test granularity: a
        // zero-latency, unconstrained-bandwidth fabric measures the cap
        // everywhere, so the run reproduces the abstract path bit for
        // bit (rounds log, final primal, per-epoch stats).
        let run_with = |network: NetworkModel| {
            let topo = Topology::paper_fig2();
            let (src, opt) = linreg_setup(16, 4);
            let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
            let spec = RunSpec::amb("fab", 2.0, 0.5, 5, 6, 17).with_network(network);
            run_on(&spec, &topo, &strag, src, opt)
        };
        let abstract_ = run_with(NetworkModel::Abstract);
        let ideal = run_with(NetworkModel::Fabric(crate::net::FabricSpec::ideal()));
        assert_eq!(abstract_.rounds, ideal.rounds);
        assert_eq!(abstract_.final_w.as_slice().len(), ideal.final_w.as_slice().len());
        for (a, b) in abstract_.final_w.as_slice().iter().zip(ideal.final_w.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in abstract_.record.epochs.iter().zip(&ideal.record.epochs) {
            assert_eq!(a.batch, b.batch);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.error.to_bits(), b.error.to_bits());
            assert_eq!(a.consensus_err.to_bits(), b.consensus_err.to_bits());
        }
    }

    #[test]
    fn constrained_fabric_measures_fewer_rounds() {
        // 4100-byte rows at 100 kB/s with 5 ms latency: a T_c = 0.5
        // window fits ~2 round trips on the fig-2 degrees, so measured
        // rounds land strictly below an abstract cap of 8 — and the run
        // still converges sanely on what it measured.
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(1024, 4);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
        let fab = crate::net::FabricSpec::uniform(0.005, 1.0e5);
        let spec = RunSpec::amb("fab", 2.0, 0.5, 8, 5, 17)
            .with_network(NetworkModel::Fabric(fab));
        let out = run_on(&spec, &topo, &strag, src, opt);
        let measured: Vec<usize> = out.rounds.iter().map(|r| r[0]).collect();
        assert!(
            measured.iter().all(|&r| r > 0 && r < 8),
            "expected the link budget to bind below the cap: {measured:?}"
        );
        // epoch-invariant fabric + static membership: same measurement
        // every epoch
        for r in &out.rounds {
            assert!(r.iter().all(|&x| x == r[0]), "rounds drifted across epochs: {r:?}");
        }
        assert!(out.record.epochs.last().unwrap().error.is_finite());
    }

    #[test]
    fn fabric_runs_are_bit_reproducible() {
        let go = || {
            let topo = Topology::ring(6);
            let (src, opt) = linreg_setup(32, 6);
            let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 30 };
            let fab = crate::net::FabricSpec::uniform(0.002, 2.0e5).with_min_gap(0.001);
            let spec = RunSpec::amb("fab", 2.0, 0.5, 10, 6, 23)
                .with_network(NetworkModel::Fabric(fab));
            run_on(&spec, &topo, &strag, src, opt)
        };
        let a = go();
        let b = go();
        assert_eq!(a.rounds, b.rounds);
        for (x, y) in a.final_w.as_slice().iter().zip(b.final_w.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn fabric_under_churn_zeroes_absent_nodes() {
        use crate::churn::ChurnSpec;
        let topo = Topology::ring(4);
        let (src, opt) = linreg_setup(8, 7);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let trace = ChurnSpec::Trace {
            active: vec![
                vec![true, true, false, true],
                vec![true],
                vec![true],
                vec![true, false],
            ],
        };
        let spec = RunSpec::amb("fab-churn", 2.0, 0.5, 4, 4, 5)
            .with_churn(trace)
            .with_network(NetworkModel::Fabric(crate::net::FabricSpec::ideal()));
        let out = run_on(&spec, &topo, &strag, src, opt);
        // same membership log as the abstract churn test: absent nodes
        // measure zero rounds, present ones hit the ideal-fabric cap
        assert_eq!(out.rounds[3], vec![4, 0, 4, 0]);
        assert_eq!(out.active_counts, vec![4, 3, 3, 3]);
    }

    #[test]
    fn hierarchical_single_shard_matches_gossip_bitwise() {
        // shards = 1 keeps every edge and the inter ring never forms,
        // so a hierarchical run IS the flat Gossip run bit for bit.
        let go = |mode: ConsensusMode| {
            let topo = Topology::paper_fig2();
            let (src, opt) = linreg_setup(16, 5);
            let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 40 };
            let spec = RunSpec::amb("hier", 2.0, 0.5, 5, 6, 19).with_consensus(mode);
            run_on(&spec, &topo, &strag, src, opt)
        };
        let flat = go(ConsensusMode::Gossip { rounds: 5 });
        let hier = go(ConsensusMode::Hierarchical {
            shards: 1,
            intra_rounds: 5,
            inter_rounds: 3,
        });
        assert_eq!(flat.rounds, hier.rounds);
        for (a, b) in flat.final_w.as_slice().iter().zip(hier.final_w.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in flat.record.epochs.iter().zip(&hier.record.epochs) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.error.to_bits(), b.error.to_bits());
            assert_eq!(a.consensus_err.to_bits(), b.consensus_err.to_bits());
        }
    }

    #[test]
    fn hierarchical_converges_and_composes_with_churn() {
        use crate::churn::ChurnSpec;
        let topo = Topology::small_world(24, 3, 0.2, 11);
        let (src, opt) = linreg_setup(16, 5);
        let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 40 };
        let spec = RunSpec::amb("hier-churn", 2.0, 0.5, 5, 12, 19)
            .with_consensus(ConsensusMode::Hierarchical {
                shards: 4,
                intra_rounds: 6,
                inter_rounds: 4,
            })
            .with_churn(ChurnSpec::IidDropout { p: 0.15, seed: 9 });
        let out = run_on(&spec, &topo, &strag, src, opt);
        assert_eq!(out.record.epochs.len(), 12);
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first, "no progress: {first} -> {last}");
        // the rounds log follows membership: intra budget or 0
        for (i, rs) in out.rounds.iter().enumerate() {
            for (t, &r) in rs.iter().enumerate() {
                assert!(r == 6 || r == 0, "node {i} epoch {t}: rounds {r}");
            }
        }
        assert!(out.active_counts.iter().any(|&a| a < 24), "churn never bit");
    }

    /// The unsupported-combination specs must come back as clean `Err`s
    /// (CLI-printable), not panics — and the message must say why.
    fn assert_rejected(spec: RunSpec, needle: &str) {
        let topo = Topology::ring(4);
        let (src, opt) = linreg_setup(8, 7);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let err = try_run_on(&spec, &topo, &strag, src, opt)
            .expect_err("spec should be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "error '{msg}' missing '{needle}'");
    }

    #[test]
    fn fabric_with_hierarchical_consensus_is_rejected() {
        assert_rejected(
            RunSpec::amb("bad", 2.0, 0.5, 5, 2, 5)
                .with_consensus(ConsensusMode::Hierarchical {
                    shards: 2,
                    intra_rounds: 3,
                    inter_rounds: 2,
                })
                .with_network(NetworkModel::Fabric(crate::net::FabricSpec::ideal())),
            "requires ConsensusMode::Gossip",
        );
    }

    #[test]
    fn fabric_with_exact_consensus_is_rejected() {
        assert_rejected(
            RunSpec::amb("bad", 2.0, 0.5, 5, 2, 5)
                .with_consensus(ConsensusMode::Exact)
                .with_network(NetworkModel::Fabric(crate::net::FabricSpec::ideal())),
            "requires ConsensusMode::Gossip",
        );
    }

    #[test]
    fn fabric_with_jitter_consensus_is_rejected() {
        assert_rejected(
            RunSpec::amb("bad", 2.0, 0.5, 5, 2, 5)
                .with_consensus(ConsensusMode::GossipJitter { mean: 5, jitter: 2 })
                .with_network(NetworkModel::Fabric(crate::net::FabricSpec::ideal())),
            "requires ConsensusMode::Gossip",
        );
    }

    #[test]
    fn link_faults_with_exact_or_hierarchical_are_rejected() {
        use crate::fault::FaultSpec;
        let faults = FaultSpec { loss: 0.1, ..FaultSpec::none() };
        assert_rejected(
            RunSpec::amb("bad", 2.0, 0.5, 5, 2, 5)
                .with_consensus(ConsensusMode::Exact)
                .with_faults(faults.clone()),
            "require a gossip consensus mode",
        );
        assert_rejected(
            RunSpec::amb("bad", 2.0, 0.5, 5, 2, 5)
                .with_consensus(ConsensusMode::Hierarchical {
                    shards: 2,
                    intra_rounds: 3,
                    inter_rounds: 2,
                })
                .with_faults(faults),
            "not modeled for Hierarchical",
        );
        // and validate() failures surface the same way
        assert_rejected(
            RunSpec::amb("bad", 2.0, 0.5, 5, 2, 5)
                .with_faults(FaultSpec { loss: 2.0, ..FaultSpec::none() }),
            "not in [0, 1]",
        );
    }

    #[test]
    fn allclear_faultspec_reproduces_baseline_bitwise() {
        use crate::fault::FaultSpec;
        // A spec whose fault plane is present but all-clear (seed and
        // timeout set, no loss/flap/crash) must take the stock code
        // paths everywhere: bit-identical record, rounds log, and final
        // primals — including under churn.
        let go = |faulted: bool| {
            let topo = Topology::paper_fig2();
            let (src, opt) = linreg_setup(16, 4);
            let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
            let mut spec = RunSpec::amb("clear", 2.0, 0.5, 5, 6, 17)
                .with_churn(crate::churn::ChurnSpec::IidDropout { p: 0.2, seed: 3 });
            if faulted {
                spec = spec
                    .with_faults(FaultSpec { seed: 99, round_timeout: 0.25, ..FaultSpec::none() });
            }
            run_on(&spec, &topo, &strag, src, opt)
        };
        let base = go(false);
        let clear = go(true);
        assert_eq!(base.rounds, clear.rounds);
        for (a, b) in base.final_w.as_slice().iter().zip(clear.final_w.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in base.record.epochs.iter().zip(&clear.record.epochs) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.error.to_bits(), b.error.to_bits());
            assert_eq!(a.conservation_drift.to_bits(), 0.0f64.to_bits());
            assert_eq!(b.conservation_drift.to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn loss_produces_measured_drift_and_still_converges() {
        use crate::fault::FaultSpec;
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 4);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 60 };
        let spec = RunSpec::amb("lossy", 2.5, 0.5, 8, 20, 7)
            .with_faults(FaultSpec { loss: 0.05, seed: 1, ..FaultSpec::none() });
        let out = run_on(&spec, &topo, &strag, src, opt);
        // drift is measured (finite), and at 5% loss over 8 rounds some
        // epoch must actually drop something
        assert!(out.record.epochs.iter().all(|e| e.conservation_drift.is_finite()));
        assert!(
            out.record.epochs.iter().any(|e| e.conservation_drift > 0.0),
            "5% loss never fired a drop"
        );
        // degraded consensus still makes optimization progress
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first * 0.5, "no progress under loss: {first} -> {last}");
        // and the run is bit-reproducible
        let (src2, opt2) = linreg_setup(16, 4);
        let again = run_on(&spec, &topo, &strag, src2, opt2);
        for (a, b) in out.record.epochs.iter().zip(&again.record.epochs) {
            assert_eq!(a.error.to_bits(), b.error.to_bits());
            assert_eq!(a.conservation_drift.to_bits(), b.conservation_drift.to_bits());
        }
    }

    #[test]
    fn crash_resets_state_and_resyncs_from_peers_exactly_once() {
        use crate::fault::{CrashWindow, FaultSpec};
        let topo = Topology::ring(4);
        let (src, opt) = linreg_setup(8, 7);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        // node 1 dies during epochs 2..=3, rejoins at 4
        let faults = FaultSpec {
            crashes: vec![CrashWindow { node: 1, from: 2, to: 3 }],
            ..FaultSpec::none()
        };
        let spec = RunSpec::amb("crash", 2.0, 0.5, 6, 6, 5)
            .with_consensus(ConsensusMode::Exact)
            .with_node_log()
            .with_faults(faults);
        let out = run_on(&spec, &topo, &strag, src, opt);
        // membership: everyone, then 3 while dead, then everyone again
        assert_eq!(out.active_counts, vec![4, 3, 3, 4, 4, 4]);
        // epoch batches: dead epochs AND the rejoin epoch contribute 0
        // from node 1 (the rejoin epoch is the peer re-sync, compute
        // suppressed exactly once), full batches afterwards
        let batches: Vec<usize> = out.record.epochs.iter().map(|e| e.batch).collect();
        assert_eq!(batches, vec![4 * 80, 3 * 80, 3 * 80, 3 * 80, 4 * 80, 4 * 80]);
        // under Exact consensus the re-synced node lands bitwise on the
        // shared average — same primal as its peers from epoch 4 on
        for k in 0..out.final_w.d() {
            assert_eq!(
                out.final_w.row(1)[k].to_bits(),
                out.final_w.row(0)[k].to_bits(),
                "re-synced node drifted from peers at col {k}"
            );
        }
        // crashes alone never fire link drops: drift identically zero
        assert!(out.record.epochs.iter().all(|e| e.conservation_drift == 0.0));
    }

    #[test]
    fn permanent_crash_completes_with_gossip() {
        use crate::fault::{CrashWindow, FaultSpec};
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 4);
        let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 40 };
        let faults = FaultSpec {
            crashes: vec![CrashWindow { node: 3, from: 2, to: usize::MAX }],
            ..FaultSpec::none()
        };
        let spec = RunSpec::amb("perma", 2.0, 0.5, 5, 8, 11).with_faults(faults);
        let out = run_on(&spec, &topo, &strag, src, opt);
        assert_eq!(out.record.epochs.len(), 8);
        assert_eq!(out.active_counts[0], 10);
        assert!(out.active_counts[1..].iter().all(|&a| a == 9));
        // the dead node gossips no rounds after the onset
        assert!(out.rounds[3][1..].iter().all(|&r| r == 0));
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first, "survivors made no progress: {first} -> {last}");
    }
}
