//! Discrete-event cluster runtime with a virtual wall clock.
//!
//! Gradients are *really* computed (via the node's [`ExecEngine`] — native
//! math or PJRT artifacts); *time* is attributed by the straggler model,
//! so a 400-virtual-second EC2 run replays in milliseconds and every
//! figure is deterministic given its seed (DESIGN.md §2 substitution 1).
//!
//! Epoch t (paper Sec. 3 / Algorithm 1) — the algebra lives in
//! [`crate::coordinator::epoch`], shared with the threaded runtime:
//!   compute   b_i(t) ← profile.grads_in_time(T)         (AMB)
//!             b_i(t) = b/n, time = max_i T_i(t)          (FMB)
//!             grad_sum_i, loss_i ← engine.grad_chunk
//!   consensus m_i⁽⁰⁾ = n·(b_i·z_i + grad_sum_i)  [+ scalar n·b_i channel]
//!             r rounds of m ← P m  (or exact averaging)
//!   update    z_i(t+1) = m_i⁽ʳ⁾ / b̂(t);  w_i(t+1) = argmin ⟨w,z⟩+βh(w)

use crate::consensus::Consensus;
use crate::coordinator::epoch::{self, NodeState};
use crate::coordinator::{
    ConsensusMode, EngineFactory, NodeLog, RunOutput, RunSpec, Runtime, RuntimeKind,
};
use crate::exec::ExecEngine;
use crate::metrics::{EpochStats, RunRecord};
use crate::straggler::StragglerModel;
use crate::topology::Topology;
use crate::util::matrix::NodeMatrix;

/// Largest gossip-round budget the simulator will execute literally;
/// anything above is assumed to be the threaded runtime's "as many
/// rounds as fit in T_c" sentinel and rejected with a clear panic.
pub const MAX_SIM_GOSSIP_ROUNDS: usize = 100_000;

/// The simulated cluster: a straggler model supplies the virtual clock.
pub struct SimRuntime<'a> {
    straggler: &'a dyn StragglerModel,
}

impl<'a> SimRuntime<'a> {
    pub fn new(straggler: &'a dyn StragglerModel) -> SimRuntime<'a> {
        SimRuntime { straggler }
    }
}

impl Runtime for SimRuntime<'_> {
    fn kind(&self) -> RuntimeKind {
        RuntimeKind::Sim
    }

    fn run(
        &self,
        spec: &RunSpec,
        topo: &Topology,
        make_engine: EngineFactory<'_>,
        f_star: Option<f64>,
    ) -> RunOutput {
        run_sim(spec, topo, self.straggler, make_engine, f_star)
    }
}

fn run_sim(
    spec: &RunSpec,
    topo: &Topology,
    straggler: &dyn StragglerModel,
    make_engine: EngineFactory<'_>,
    f_star: Option<f64>,
) -> RunOutput {
    let n = topo.n();
    let mut engines: Vec<Box<dyn ExecEngine>> = (0..n).map(make_engine).collect();
    let dim = engines[0].workload().dim();
    for e in &engines {
        assert_eq!(e.workload().dim(), dim, "engines must share a workload");
    }

    // Canonical per-purpose RNG streams (shared with the threaded
    // runtime so one spec replays the same data everywhere).
    let mut strag_rng = epoch::straggler_rng(spec.seed);
    let mut metric_rng = epoch::metric_rng(spec.seed, 0);

    // Consensus machinery (lazy P for the PSD assumption; see topology.rs).
    let mut cons = Consensus::new(topo.metropolis().lazy());

    let mut states: Vec<NodeState> = engines.iter().map(|e| NodeState::new(&**e)).collect();
    // The consensus wire: one flat [n × (dim+1)] arena, encoded/decoded
    // in place every epoch (no per-node buffers, no per-epoch allocation).
    let mut msgs = NodeMatrix::new(n, dim + 1);
    let mut rounds_buf = vec![0usize; n];

    let mut record = RunRecord::new(&spec.name, f_star);
    let mut node_log = spec.record_node_log.then(|| NodeLog::new(n));
    let mut rounds_log: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut wall = 0.0f64;

    for t in 1..=spec.epochs {
        // ---- compute phase -------------------------------------------------
        let plan = epoch::plan_compute(&spec.scheme, n, t, straggler, &mut strag_rng);
        let b_t: usize = plan.batches.iter().sum();
        let c_t: usize = plan.potentials.iter().sum();

        let mut loss_sum = 0.0f64;
        for i in 0..n {
            let st = &mut states[i];
            st.begin_epoch();
            let mut data_rng = epoch::data_rng(spec.seed, i, t);
            loss_sum +=
                engines[i].grad_chunk(&st.w, plan.batches[i], &mut data_rng, &mut st.grad_sum);
        }

        // ---- consensus phase ------------------------------------------------
        for i in 0..n {
            states[i].encode_into(n, plan.batches[i], msgs.row_mut(i));
        }
        let exact_avg =
            Consensus::exact_average(&msgs).expect("topology guarantees n > 0 nodes");
        match spec.consensus {
            ConsensusMode::Exact => {
                for i in 0..n {
                    for (v, &a) in msgs.row_mut(i).iter_mut().zip(&exact_avg) {
                        *v = a as f32;
                    }
                }
                rounds_buf.fill(0);
            }
            ConsensusMode::Gossip { rounds } => {
                // The simulator executes EXACTLY `rounds` mixes; huge
                // values are the threaded-only "as many rounds as fit in
                // T_c" idiom and would loop for years here — fail loudly
                // instead of hanging.
                assert!(
                    rounds <= MAX_SIM_GOSSIP_ROUNDS,
                    "Gossip {{ rounds: {rounds} }} on the simulator: this looks like the \
                     threaded-only GOSSIP_UNTIL_DEADLINE sentinel; the sim has no per-round \
                     time model and runs exactly `rounds` mixes — use a finite budget"
                );
                cons.run(&mut msgs, rounds);
                rounds_buf.fill(rounds);
            }
            ConsensusMode::GossipJitter { mean, jitter } => {
                for (i, r) in rounds_buf.iter_mut().enumerate() {
                    *r = epoch::gossip_jitter_rounds(spec.seed, i, t, mean, jitter);
                }
                cons.run_per_node(&mut msgs, &rounds_buf);
            }
        }
        for i in 0..n {
            rounds_log[i].push(rounds_buf[i]);
        }

        // ---- update phase ----------------------------------------------------
        wall += plan.epoch_compute_time + spec.scheme.t_consensus();

        let mut consensus_err = 0.0f64;
        if b_t > 0 {
            consensus_err = epoch::consensus_error(&msgs, &exact_avg, dim, b_t, spec.exact_bt);
            for i in 0..n {
                let b_hat = if spec.exact_bt {
                    b_t as f32
                } else {
                    epoch::side_channel_b_hat(msgs.row(i))
                };
                states[i].set_dual(msgs.row(i), b_hat);
                states[i].primal(&mut *engines[i], t + 1);
            }
        }
        // (if b_t == 0 the epoch produced nothing; state carries over)

        if let Some(log) = node_log.as_mut() {
            for i in 0..n {
                log.push(i, plan.batches[i], plan.compute_times[i]);
            }
        }

        let error = engines[0].error_metric(&states[0].w, &mut metric_rng);
        record.push(EpochStats {
            epoch: t,
            wall_time: wall,
            batch: b_t,
            potential: c_t,
            loss: if b_t > 0 { loss_sum / b_t as f64 } else { f64::NAN },
            error,
            consensus_err,
            min_node_batch: plan.batches.iter().copied().min().unwrap_or(0),
            max_node_batch: plan.batches.iter().copied().max().unwrap_or(0),
        });
    }

    let mut final_w = NodeMatrix::new(n, dim);
    for (i, s) in states.iter().enumerate() {
        final_w.row_mut(i).copy_from_slice(&s.w);
    }
    RunOutput { record, node_log, final_w, rounds: rounds_log }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LinRegStream;
    use crate::exec::{DataSource, NativeExec};
    use crate::optim::{BetaSchedule, DualAveraging};
    use crate::straggler::{Deterministic, ShiftedExp};
    use std::sync::Arc;

    fn linreg_setup(d: usize, seed: u64) -> (Arc<DataSource>, DualAveraging) {
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
        // radius comfortably containing w* (E||w*|| ≈ sqrt(d))
        let opt = DualAveraging::new(BetaSchedule::new(1.0, 600.0), 4.0 * (d as f64).sqrt());
        (src, opt)
    }

    fn run_on(
        spec: &RunSpec,
        topo: &Topology,
        strag: &dyn StragglerModel,
        src: Arc<DataSource>,
        opt: DualAveraging,
    ) -> RunOutput {
        let f_star = src.f_star();
        let mk = move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        };
        SimRuntime::new(strag).run(spec, topo, &mk, f_star)
    }

    fn run_amb(epochs: usize, rounds: usize, seed: u64) -> RunOutput {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(32, 3);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 60 };
        let spec = RunSpec::amb("amb", 2.5, 0.5, rounds, epochs, seed);
        run_on(&spec, &topo, &strag, src, opt)
    }

    #[test]
    fn amb_wall_time_is_deterministic() {
        let out = run_amb(10, 5, 1);
        // epoch time == T + Tc exactly, stragglers or not
        for (i, e) in out.record.epochs.iter().enumerate() {
            assert!((e.wall_time - 3.0 * (i + 1) as f64).abs() < 1e-9);
        }
        // gossip rounds recorded for every (node, epoch)
        assert!(out.rounds.iter().all(|r| r == &vec![5usize; 10]));
    }

    #[test]
    fn amb_reduces_error() {
        let out = run_amb(25, 8, 2);
        let first = out.record.epochs[0].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(last < first * 0.2, "first={first} last={last}");
    }

    #[test]
    fn amb_batches_vary_fmb_batches_fixed() {
        let out = run_amb(10, 5, 3);
        let varies = out
            .record
            .epochs
            .iter()
            .any(|e| e.min_node_batch != e.max_node_batch);
        assert!(varies, "AMB batches should vary across nodes");

        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(32, 3);
        let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 60 };
        let spec = RunSpec::fmb("fmb", 60, 0.5, 5, 10, 3);
        let fout = run_on(&spec, &topo, &strag, src, opt);
        for e in &fout.record.epochs {
            assert_eq!(e.min_node_batch, 60);
            assert_eq!(e.max_node_batch, 60);
            assert_eq!(e.batch, 600);
        }
        // FMB wall time is gated by the max order statistic > mean
        let mean_unit = 1.0 + 1.5; // zeta + 1/lambda
        let total = fout.record.total_time();
        assert!(total > 10.0 * (mean_unit + 0.5), "total={total}");
    }

    #[test]
    fn seeded_runs_bit_reproducible() {
        let a = run_amb(8, 5, 7);
        let b = run_amb(8, 5, 7);
        for (x, y) in a.record.epochs.iter().zip(&b.record.epochs) {
            assert_eq!(x.batch, y.batch);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.error.to_bits(), y.error.to_bits());
        }
        let c = run_amb(8, 5, 8);
        assert_ne!(
            a.record.epochs[2].batch, c.record.epochs[2].batch,
            "different seeds should differ (overwhelmingly likely)"
        );
    }

    #[test]
    fn exact_consensus_zeroes_consensus_error() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 5);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 50 };
        let spec = RunSpec::amb("amb", 1.0, 0.2, 5, 5, 9)
            .with_consensus(ConsensusMode::Exact);
        let out = run_on(&spec, &topo, &strag, src, opt);
        for e in &out.record.epochs {
            assert!(e.consensus_err < 1e-5, "err={}", e.consensus_err);
        }
        // Exact aggregation records zero gossip rounds.
        assert!(out.rounds.iter().flatten().all(|&r| r == 0));
    }

    #[test]
    fn more_rounds_less_consensus_error() {
        let err_with = |rounds: usize| {
            let out = run_amb(6, rounds, 11);
            out.record.epochs.iter().map(|e| e.consensus_err).sum::<f64>() / 6.0
        };
        let e2 = err_with(2);
        let e10 = err_with(10);
        assert!(e10 < e2, "e2={e2} e10={e10}");
    }

    #[test]
    fn deterministic_model_all_nodes_equal_batches() {
        let topo = Topology::ring(6);
        let (src, opt) = linreg_setup(8, 6);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let spec = RunSpec::amb("amb", 2.0, 0.5, 4, 4, 13).with_node_log();
        let out = run_on(&spec, &topo, &strag, src, opt);
        let log = out.node_log.unwrap();
        for node in 0..6 {
            assert_eq!(log.batches[node], vec![80, 80, 80, 80]);
        }
    }

    #[test]
    fn bt_estimation_close_to_exact() {
        // With enough consensus rounds, normalising by the distributively
        // estimated b̂(t) must land each node's primal within a small
        // relative distance of the exact-b(t) run (single epoch so curves
        // cannot drift apart).
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 8);
        let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 50 };
        let mk = |exact: bool| {
            let mut spec = RunSpec::amb("amb", 2.0, 0.5, 120, 1, 21);
            if exact {
                spec = spec.with_exact_bt();
            }
            run_on(&spec, &topo, &strag, src.clone(), opt.clone())
        };
        let est = mk(false);
        let ex = mk(true);
        for i in 0..10 {
            let (we, wx) = (est.final_w.row(i), ex.final_w.row(i));
            let mut diff = 0.0f64;
            let mut norm = 0.0f64;
            for k in 0..we.len() {
                diff += ((we[k] - wx[k]) as f64).powi(2);
                norm += (wx[k] as f64).powi(2);
            }
            assert!(
                diff.sqrt() <= 0.02 * norm.sqrt().max(1e-9),
                "node {i}: rel diff {}",
                diff.sqrt() / norm.sqrt().max(1e-9)
            );
        }
    }

    #[test]
    fn gossip_jitter_runs() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(8, 9);
        let strag = ShiftedExp { zeta: 0.5, lambda: 1.0, unit_batch: 30 };
        let spec = RunSpec::amb("amb", 2.0, 0.5, 5, 8, 31)
            .with_consensus(ConsensusMode::GossipJitter { mean: 5, jitter: 2 });
        let out = run_on(&spec, &topo, &strag, src, opt);
        assert_eq!(out.record.epochs.len(), 8);
        assert!(out.record.epochs.last().unwrap().error.is_finite());
        // jitter draws stay inside the configured band
        assert!(out.rounds.iter().flatten().all(|&r| (3..=7).contains(&r)));
    }

    #[test]
    fn backup_and_coded_schemes_run() {
        let topo = Topology::paper_fig2();
        let (src, opt) = linreg_setup(16, 10);
        let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 50 };
        for coded in [false, true] {
            let spec = RunSpec::new(
                "bk",
                crate::coordinator::Scheme::FmbBackup {
                    per_node_batch: 50,
                    t_consensus: 0.5,
                    ignore: 3,
                    coded,
                },
                5,
                17,
            );
            let out = run_on(&spec, &topo, &strag, src.clone(), opt.clone());
            assert_eq!(out.record.epochs.len(), 5);
            for e in &out.record.epochs {
                assert!(e.batch > 0);
                // stragglers dropped => some node attributed 0
                assert_eq!(e.min_node_batch, 0);
            }
        }
    }
}
