//! Runtime-agnostic per-epoch state machine (paper Sec. 3 / Algorithm 1).
//!
//! Both cluster runtimes execute the identical epoch algebra; they differ
//! only in how *time* is attributed (virtual straggler draws vs real
//! deadlines).  Everything time-independent lives here:
//!
//! * [`NodeState`] — a node's (w, z, grad-sum) triple with the message
//!   encode/decode steps:
//!     encode   m_i⁽⁰⁾ = n·(b_i·z_i + grad_sum_i), side channel n·b_i
//!     decode   z_i(t+1) = m_i⁽ʳ⁾ / b̂(t);  w_i(t+1) = argmin ⟨w,z⟩+βh(w)
//! * [`plan_compute`] — the per-scheme compute-window accounting the
//!   simulator attributes from straggler draws ([`Scheme::Fmb`] /
//!   [`Scheme::FmbBackup`] batch accounting included).
//! * [`backup_attribution`] / [`work_quota`] — the redundancy-baseline
//!   bookkeeping, shared so the threaded runtime attributes coded /
//!   backup batches exactly like the simulator.
//! * Canonical RNG stream derivations, so one
//!   [`crate::coordinator::RunSpec`] replays the same data/metric sample
//!   sequences on BOTH runtimes (the sim-vs-threaded parity tests rely
//!   on this).

use crate::coordinator::Scheme;
use crate::exec::ExecEngine;
use crate::straggler::StragglerModel;
use crate::util::matrix::NodeMatrix;
use crate::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Canonical RNG streams (shared by both runtimes)
// ---------------------------------------------------------------------------

/// Node `node`'s data-sampling stream for epoch `epoch`.  Derived per
/// (node, epoch) — not one sequential stream — so a node that consumes
/// a different number of draws in some epoch (e.g. a dropped backup
/// straggler abandoning mid-quota, or AMB's runtime-dependent batch)
/// cannot shift every later epoch's samples: both runtimes start each
/// epoch at the identical stream position.
pub fn data_rng(seed: u64, node: usize, epoch: usize) -> Pcg64 {
    Pcg64::new(seed).split(0xDA7A_0000 ^ ((node as u64) << 24) ^ epoch as u64)
}

/// Node `node`'s error-metric stream (fresh-sample estimates).
pub fn metric_rng(seed: u64, node: usize) -> Pcg64 {
    Pcg64::new(seed).split(0x3E77_0000 + node as u64)
}

/// The simulator's straggler-draw stream.
pub fn straggler_rng(seed: u64) -> Pcg64 {
    Pcg64::new(seed).split(0x57)
}

/// Warm-up stream for the threaded runtime's engine priming; separate
/// from [`data_rng`] so warm-up samples never shift the data sequence.
pub fn warmup_rng(seed: u64, node: usize) -> Pcg64 {
    Pcg64::new(seed).split(0x3A_0000 + node as u64)
}

/// Stream for the coded-redundancy gradients whose sums are never used
/// (threaded `FmbBackup { coded: true }` computes (ignore+1)× the quota
/// for time realism); separate from [`data_rng`] so the *attributed*
/// sample sequence stays identical to the simulator's.
pub fn redundancy_rng(seed: u64, node: usize) -> Pcg64 {
    Pcg64::new(seed).split(0x0C0D_0000 + node as u64)
}

/// Per-(node, epoch) gossip-round draw for
/// [`crate::coordinator::ConsensusMode::GossipJitter`] — derived, not
/// sequential, so both runtimes draw identical r_i(t).
pub fn gossip_jitter_rounds(seed: u64, node: usize, epoch: usize, mean: usize, jitter: usize) -> usize {
    let lo = mean.saturating_sub(jitter);
    let hi = mean + jitter;
    let mut rng = Pcg64::new(seed).split(0x20_0000 ^ ((node as u64) << 24) ^ epoch as u64);
    lo + rng.below((hi - lo + 1) as u64) as usize
}

// ---------------------------------------------------------------------------
// Node state: the optimisation variables + wire codec
// ---------------------------------------------------------------------------

/// A node's per-run optimisation state.  Messages carry `dim + 1`
/// components: the dual payload and the n·b_i side channel used to
/// estimate b(t) distributively.
pub struct NodeState {
    /// Primal variables; w(1) = argmin h(w) per engine (paper eq. (2)).
    pub w: Vec<f32>,
    /// Dual (averaged-gradient) variables.
    pub z: Vec<f32>,
    /// Gradient-sum accumulator for the current epoch's compute phase.
    pub grad_sum: Vec<f32>,
}

impl NodeState {
    pub fn new(engine: &dyn ExecEngine) -> NodeState {
        let dim = engine.workload().dim();
        NodeState { w: engine.initial_primal(), z: vec![0.0; dim], grad_sum: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Reset the epoch accumulator before the compute phase.
    pub fn begin_epoch(&mut self) {
        self.grad_sum.fill(0.0);
    }

    /// Encode the consensus message m⁽⁰⁾ = n·(b_i·z + grad_sum) with the
    /// n·b_i side channel into `msg` — a caller-owned `dim + 1` slice,
    /// typically a [`NodeMatrix`] arena row, so encoding writes the wire
    /// buffer in place with no allocation.
    pub fn encode_into(&self, n: usize, b_i: usize, msg: &mut [f32]) {
        encode_msg_into(&self.z, &self.grad_sum, n, b_i, msg);
    }

    /// Decode the post-consensus message: z ← m / b̂.
    pub fn set_dual(&mut self, msg: &[f32], b_hat: f32) {
        let dim = self.dim();
        for k in 0..dim {
            self.z[k] = msg[k] / b_hat;
        }
    }

    /// Dual-averaging primal step for epoch `t_next` (= t + 1).
    pub fn primal(&mut self, engine: &mut dyn ExecEngine, t_next: usize) {
        engine.primal_step(&self.z, t_next, &mut self.w);
    }
}

/// Encode a consensus message from explicit components: m = n·(b·z + g)
/// with the n·b side channel.  [`NodeState::encode_into`] is the
/// (z, live grad_sum) view of this; the AMB-DG pipeline encodes a batch
/// popped from the delay ring — its gradients were computed against a
/// STALE primal, but the dual weight is the node's CURRENT z — through
/// the same kernel, so the two paths cannot drift.
pub fn encode_msg_into(z: &[f32], g: &[f32], n: usize, b_i: usize, msg: &mut [f32]) {
    let dim = z.len();
    assert_eq!(g.len(), dim, "gradient sum must match the dual's dimension");
    assert_eq!(msg.len(), dim + 1, "message row must be dim + 1 wide");
    let bi = b_i as f32;
    for k in 0..dim {
        msg[k] = n as f32 * (bi * z[k] + g[k]);
    }
    msg[dim] = n as f32 * bi;
}

/// The distributed b̂(t) estimate from a message's side channel, clamped
/// away from zero so the dual update is always well-defined.
pub fn side_channel_b_hat(msg: &[f32]) -> f32 {
    msg[msg.len() - 1].max(1e-6)
}

// ---------------------------------------------------------------------------
// Compute-phase accounting
// ---------------------------------------------------------------------------

/// One epoch's compute-phase accounting (per node + epoch aggregate).
pub struct ComputePlan {
    /// b_i(t) actually attributed per node.
    pub batches: Vec<usize>,
    /// Potential work c_i(t) ≥ b_i(t) (regret accounting, paper Sec. 4.2).
    pub potentials: Vec<usize>,
    /// Seconds node i spent computing in the epoch.
    pub compute_times: Vec<f64>,
    /// Epoch compute-phase duration (max over gating nodes).
    pub epoch_compute_time: f64,
}

/// Attribute one epoch's compute phase from straggler draws — the
/// simulator's time model (paper Sec. 3; Assumption 2's conditionally
/// linear progress).  Draw order is fixed (node-major, AMB drawing a
/// second "potential" profile) so runs are bit-reproducible per seed.
///
/// Churn: `active` masks the epoch's membership.  Profiles are STILL
/// drawn for inactive nodes (the shared straggler stream advances
/// identically whatever the schedule, so changing only the dropout rate
/// replays the same compute weather), but an absent node is attributed
/// zero batch, zero potential, zero compute time, and never gates the
/// epoch.  An all-true mask reproduces the static plan bit-for-bit.
pub fn plan_compute(
    scheme: &Scheme,
    n: usize,
    epoch: usize,
    straggler: &dyn StragglerModel,
    rng: &mut Pcg64,
    active: &[bool],
) -> ComputePlan {
    assert_eq!(active.len(), n, "active mask must cover every node");
    let mut batches = vec![0usize; n];
    let mut potentials = vec![0usize; n];
    let mut compute_times = vec![0.0f64; n];
    let act = active.iter().filter(|&&a| a).count();
    let epoch_compute_time;
    match *scheme {
        // AMB-DG shares AMB's compute weather EXACTLY (same window, same
        // two profile draws per node, so the straggler stream — and every
        // later epoch's draws — are identical whatever the delay).  The
        // delay only changes WHEN a batch enters the dual, which is the
        // executors' pipeline ring, not the plan.  The potential draw is
        // kept even though a pipelined node never idles (c_i(t) stays an
        // upper bound) — dropping it would shift the shared stream and
        // break the Amb ≡ AmbDg{delay: 0} bitwise contract.
        Scheme::Amb { t_compute, t_consensus }
        | Scheme::AmbDg { t_compute, t_consensus, .. } => {
            for i in 0..n {
                let mut prof = straggler.draw(i, epoch, rng);
                let b = prof.grads_in_time(t_compute);
                // potential work c_i(t): what the node could have done
                // with the consensus window too.  Fresh profile draw: an
                // unbiased estimate with identical distribution.
                let mut prof2 = straggler.draw(i, epoch, rng);
                let pot = prof2.grads_in_time(t_compute + t_consensus);
                if active[i] {
                    batches[i] = b;
                    compute_times[i] = t_compute;
                    potentials[i] = pot.max(b);
                }
            }
            // AMB's schedule is absolute: the window elapses whether or
            // not anyone is present.
            epoch_compute_time = t_compute;
        }
        Scheme::Fmb { per_node_batch, .. } => {
            let mut slowest = 0.0f64;
            for i in 0..n {
                let mut prof = straggler.draw(i, epoch, rng);
                let ct = prof.time_for_grads(per_node_batch);
                if active[i] {
                    batches[i] = per_node_batch;
                    compute_times[i] = ct;
                    slowest = slowest.max(ct);
                }
            }
            for (p, &b) in potentials.iter_mut().zip(&batches) {
                *p = b; // FMB: every PRESENT node computes exactly the quota
            }
            // only active nodes gate the epoch (absent nodes never block
            // progress); with nobody present the phase is instantaneous.
            epoch_compute_time = slowest;
        }
        Scheme::FmbBackup { per_node_batch, ignore, coded, .. } => {
            // Redundancy baseline: wait only for the fastest |A|−ignore
            // of the epoch's ACTIVE nodes.  Coded variant makes every
            // node compute (ignore+1)× the quota so the batch stays
            // whole.  EXACTLY |A|−ignore nodes survive — ties broken by
            // node index, matching the threaded runtime's atomic
            // finish-rank semantics (otherwise a deterministic model
            // would mark everyone on-time and coded attribution would
            // exceed the recoverable batch).
            let ignore = ignore.min(act.saturating_sub(1));
            // amb-lint: allow(D4, "scheme validated at RunSpec construction; quota exists for every scheme")
            let work = work_quota(scheme, act).unwrap();
            for i in 0..n {
                let mut prof = straggler.draw(i, epoch, rng);
                let ct = prof.time_for_grads(work);
                if active[i] {
                    compute_times[i] = ct;
                }
            }
            if act == 0 {
                epoch_compute_time = 0.0;
            } else {
                let mut order: Vec<usize> = (0..n).filter(|&i| active[i]).collect();
                order.sort_by(|&a, &b| {
                    compute_times[a]
                        .partial_cmp(&compute_times[b])
                        // amb-lint: allow(D4, "scheme validated at RunSpec construction; quota exists for every scheme")
                        .unwrap()
                        .then(a.cmp(&b))
                });
                let cutoff = compute_times[order[act - 1 - ignore]];
                for (rank, &i) in order.iter().enumerate() {
                    let on_time = rank < act - ignore;
                    batches[i] = backup_attribution(on_time, coded, per_node_batch, act, ignore);
                    potentials[i] = work.max(batches[i]);
                }
                epoch_compute_time = cutoff;
            }
        }
    }
    ComputePlan { batches, potentials, compute_times, epoch_compute_time }
}

/// Gradients a node must *compute* in one epoch, when the scheme fixes
/// that number (None for AMB's anytime window).  For the coded baseline
/// this includes the (ignore+1)× redundancy.
pub fn work_quota(scheme: &Scheme, n: usize) -> Option<usize> {
    match *scheme {
        Scheme::Amb { .. } | Scheme::AmbDg { .. } => None,
        Scheme::Fmb { per_node_batch, .. } => Some(per_node_batch),
        Scheme::FmbBackup { per_node_batch, ignore, coded, .. } => {
            let ignore = ignore.min(n.saturating_sub(1));
            Some(if coded { per_node_batch * (ignore + 1) } else { per_node_batch })
        }
    }
}

/// Batch attributed to a node under [`Scheme::FmbBackup`]:
/// * uncoded on-time: the quota; uncoded late: work DROPPED (0);
/// * coded on-time: the full batch is recoverable — each survivor is
///   charged b/(n−ignore) of it; coded late: 0.
///
/// Total in `n`: a churn epoch can leave ZERO nodes active, and the
/// threaded runtime evaluates the attribution before checking its own
/// membership — n = 0 must attribute 0, not divide by zero.
pub fn backup_attribution(
    on_time: bool,
    coded: bool,
    per_node_batch: usize,
    n: usize,
    ignore: usize,
) -> usize {
    if n == 0 {
        return 0;
    }
    let ignore = ignore.min(n.saturating_sub(1));
    if !on_time {
        0
    } else if coded {
        per_node_batch * n / (n - ignore)
    } else {
        per_node_batch
    }
}

/// Max over nodes of ‖z_i − z̄‖ where z̄ is the exactly-normalised dual —
/// the consensus-error diagnostic the simulator records.  `exact_bt`
/// must match the run's normalisation so the diagnostic measures the
/// dual the update actually used (oracle b(t) vs per-node side channel).
pub fn consensus_error(
    msgs: &NodeMatrix,
    exact_avg: &[f64],
    dim: usize,
    b_t: usize,
    exact_bt: bool,
) -> f64 {
    let mut worst = 0.0f64;
    for m in msgs.rows() {
        let b_hat = if exact_bt { b_t as f64 } else { side_channel_b_hat(m) as f64 };
        let mut ss = 0.0f64;
        for k in 0..dim {
            let exact = exact_avg[k] / b_t as f64;
            let diff = m[k] as f64 / b_hat - exact;
            ss += diff * diff;
        }
        worst = worst.max(ss.sqrt());
    }
    worst
}

/// [`consensus_error`] for a churn epoch: the dual target is the ratio
/// of the ACTIVE-set mean message to the ACTIVE-set mean side channel
/// (`active_avg`, length `dim + 1`) — the ratio encoding makes the
/// n/|A| scale factor cancel, so this is exactly Σ_A (b_i z_i + g_i) /
/// b(t) — and only active nodes (the ones that will decode) are scored.
pub fn consensus_error_active(
    msgs: &NodeMatrix,
    active_avg: &[f64],
    dim: usize,
    exact_bt: bool,
    active: &[bool],
) -> f64 {
    assert_eq!(active_avg.len(), dim + 1, "active_avg must include the side channel");
    let side = active_avg[dim].max(1e-6);
    let mut worst = 0.0f64;
    for (i, m) in msgs.rows().enumerate() {
        if !active[i] {
            continue;
        }
        let b_hat = if exact_bt { side } else { side_channel_b_hat(m) as f64 };
        let mut ss = 0.0f64;
        for k in 0..dim {
            let exact = active_avg[k] / side;
            let diff = m[k] as f64 / b_hat - exact;
            ss += diff * diff;
        }
        worst = worst.max(ss.sqrt());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LinRegStream;
    use crate::exec::{DataSource, NativeExec};
    use crate::optim::{BetaSchedule, DualAveraging};
    use crate::straggler::Deterministic;
    use std::sync::Arc;

    fn engine(d: usize) -> NativeExec {
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, 3)));
        NativeExec::new(src, DualAveraging::new(BetaSchedule::new(1.0, 100.0), 10.0))
    }

    #[test]
    fn encode_decode_roundtrip() {
        let e = engine(4);
        let mut st = NodeState::new(&e);
        st.z = vec![1.0, -2.0, 0.5, 0.0];
        st.grad_sum = vec![4.0, 4.0, 4.0, 4.0];
        let mut msg = vec![0.0f32; 5];
        st.encode_into(5, 2, &mut msg);
        // m = 5·(2·z + g), side = 5·2
        assert_eq!(msg.len(), 5);
        assert_eq!(msg[0], 5.0 * (2.0 * 1.0 + 4.0));
        assert_eq!(msg[4], 10.0);
        assert_eq!(side_channel_b_hat(&msg), 10.0);
        st.set_dual(&msg, 10.0);
        assert!((st.z[1] - (5.0 * (2.0 * -2.0 + 4.0)) / 10.0).abs() < 1e-6);
    }

    #[test]
    fn side_channel_clamped() {
        assert!(side_channel_b_hat(&[1.0, 0.0]) > 0.0);
        assert!(side_channel_b_hat(&[1.0, -3.0]) > 0.0);
    }

    #[test]
    fn rng_streams_distinct_and_reproducible() {
        let mut a = data_rng(7, 0, 1);
        let mut a2 = data_rng(7, 0, 1);
        let mut b = data_rng(7, 1, 1);
        let mut e = data_rng(7, 0, 2);
        let mut m = metric_rng(7, 0);
        let x = a.next_u64();
        assert_eq!(x, a2.next_u64(), "same (seed, node, epoch) ⇒ same stream");
        assert_ne!(x, b.next_u64(), "different node ⇒ different stream");
        assert_ne!(x, e.next_u64(), "different epoch ⇒ different stream");
        assert_ne!(x, m.next_u64(), "different purpose ⇒ different stream");
    }

    #[test]
    fn gossip_jitter_in_range_and_deterministic() {
        for epoch in 0..20 {
            let r = gossip_jitter_rounds(5, 3, epoch, 5, 2);
            assert!((3..=7).contains(&r), "r={r}");
            assert_eq!(r, gossip_jitter_rounds(5, 3, epoch, 5, 2));
        }
    }

    #[test]
    fn plan_amb_deterministic_model() {
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let scheme = Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 };
        let mut rng = Pcg64::new(1);
        let plan = plan_compute(&scheme, 3, 1, &strag, &mut rng, &[true; 3]);
        assert_eq!(plan.batches, vec![80, 80, 80]);
        assert!(plan.potentials.iter().all(|&p| p == 100));
        assert!((plan.epoch_compute_time - 2.0).abs() < 1e-12);
    }

    #[test]
    fn plan_amb_dg_matches_amb_bitwise_at_any_delay() {
        // AMB-DG's compute plan — batches, potentials, times, and the
        // straggler-stream position afterwards — must be identical to
        // AMB's for every delay (the delay lives in the pipeline ring,
        // not the plan).
        let se = crate::straggler::ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 40 };
        let scheme_amb = Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 };
        for delay in [0usize, 1, 4] {
            let scheme_dg = Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay };
            let mut rng_a = Pcg64::new(11);
            let mut rng_d = Pcg64::new(11);
            let pa = plan_compute(&scheme_amb, 4, 2, &se, &mut rng_a, &[true; 4]);
            let pd = plan_compute(&scheme_dg, 4, 2, &se, &mut rng_d, &[true; 4]);
            assert_eq!(pa.batches, pd.batches, "delay {delay}");
            assert_eq!(pa.potentials, pd.potentials);
            assert_eq!(pa.compute_times, pd.compute_times);
            assert_eq!(pa.epoch_compute_time, pd.epoch_compute_time);
            assert_eq!(rng_a.next_u64(), rng_d.next_u64(), "stream position diverged");
        }
    }

    #[test]
    fn encode_msg_into_matches_node_state_encode() {
        let e = engine(3);
        let mut st = NodeState::new(&e);
        st.z = vec![0.5, -1.0, 2.0];
        st.grad_sum = vec![3.0, 0.0, -2.0];
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        st.encode_into(7, 4, &mut a);
        encode_msg_into(&st.z, &st.grad_sum, 7, 4, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn plan_fmb_gated_by_quota() {
        let strag = Deterministic { unit_time: 2.0, unit_batch: 100 };
        let scheme = Scheme::Fmb { per_node_batch: 50, t_consensus: 0.5 };
        let mut rng = Pcg64::new(1);
        let plan = plan_compute(&scheme, 4, 1, &strag, &mut rng, &[true; 4]);
        assert_eq!(plan.batches, vec![50; 4]);
        assert!((plan.epoch_compute_time - 1.0).abs() < 1e-12);
    }

    #[test]
    fn plan_zeroes_inactive_nodes_and_keeps_draw_stream() {
        let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
        let scheme = Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 };
        let mut rng = Pcg64::new(1);
        let plan = plan_compute(&scheme, 3, 1, &strag, &mut rng, &[true, false, true]);
        assert_eq!(plan.batches, vec![80, 0, 80]);
        assert_eq!(plan.potentials, vec![100, 0, 100]);
        assert_eq!(plan.compute_times, vec![2.0, 0.0, 2.0]);
        // the straggler stream advances exactly as in the all-active
        // plan (profiles are drawn for absent nodes too), so the NEXT
        // epoch's weather is unchanged by churn — checked with a model
        // that actually consumes the stream.
        let se = crate::straggler::ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 40 };
        let mut rng_churn = Pcg64::new(9);
        let mut rng_full = Pcg64::new(9);
        let _ = plan_compute(&scheme, 3, 1, &se, &mut rng_churn, &[true, false, false]);
        let _ = plan_compute(&scheme, 3, 1, &se, &mut rng_full, &[true; 3]);
        assert_eq!(
            rng_churn.next_u64(),
            rng_full.next_u64(),
            "churn shifted the straggler stream"
        );
    }

    #[test]
    fn plan_fmb_inactive_nodes_never_gate() {
        // node 1 would be the 4x-slow straggler, but it's absent
        let strag = crate::straggler::HeterogeneousMeans {
            means: vec![1.0, 4.0, 1.0],
            jitter: 0.0,
            unit_batch: 50,
        };
        let scheme = Scheme::Fmb { per_node_batch: 50, t_consensus: 0.5 };
        let mut rng = Pcg64::new(2);
        let plan = plan_compute(&scheme, 3, 1, &strag, &mut rng, &[true, false, true]);
        assert_eq!(plan.batches, vec![50, 0, 50]);
        assert!((plan.epoch_compute_time - 1.0).abs() < 1e-9, "absent straggler gated the epoch");
    }

    #[test]
    fn plan_backup_survivor_count_tracks_active_set() {
        let strag = crate::straggler::HeterogeneousMeans {
            means: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            jitter: 0.0,
            unit_batch: 10,
        };
        let scheme =
            Scheme::FmbBackup { per_node_batch: 10, t_consensus: 0.5, ignore: 1, coded: false };
        let mut rng = Pcg64::new(3);
        // nodes 0 and 4 absent: 3 active, ignore 1 ⇒ the slowest active
        // node (3) is dropped; survivors 1 and 2 keep the quota.
        let plan =
            plan_compute(&scheme, 5, 1, &strag, &mut rng, &[false, true, true, true, false]);
        assert_eq!(plan.batches, vec![0, 10, 10, 0, 0]);
        assert_eq!(plan.potentials[0], 0);
        assert!((plan.epoch_compute_time - 3.0).abs() < 1e-9, "cutoff must be node 2's time");
    }

    #[test]
    fn consensus_error_active_scores_only_active_rows() {
        // two active rows at the exact active mean => zero error even
        // though the inactive row is wildly off.
        let mut msgs = NodeMatrix::new(3, 3); // dim = 2 + side channel
        msgs.row_mut(0).copy_from_slice(&[6.0, 2.0, 2.0]);
        msgs.row_mut(1).copy_from_slice(&[6.0, 2.0, 2.0]);
        msgs.row_mut(2).copy_from_slice(&[1e6, -1e6, 1.0]);
        let active = [true, true, false];
        let avg = vec![6.0, 2.0, 2.0];
        let err = consensus_error_active(&msgs, &avg, 2, false, &active);
        assert!(err < 1e-12, "err={err}");
        let err_oracle = consensus_error_active(&msgs, &avg, 2, true, &active);
        assert!(err_oracle < 1e-12, "err={err_oracle}");
        // perturb an active row: error registers
        msgs.row_mut(1)[0] = 8.0;
        assert!(consensus_error_active(&msgs, &avg, 2, false, &active) > 0.1);
    }

    #[test]
    fn backup_attribution_accounting() {
        // uncoded: survivors keep the quota, stragglers dropped
        assert_eq!(backup_attribution(true, false, 100, 10, 2), 100);
        assert_eq!(backup_attribution(false, false, 100, 10, 2), 0);
        // coded: survivors are charged b/(n-ignore) of the full batch
        assert_eq!(backup_attribution(true, true, 100, 10, 2), 125);
        assert_eq!(backup_attribution(false, true, 100, 10, 2), 0);
        // empty active set (churn): attribute 0, never divide by zero
        assert_eq!(backup_attribution(true, true, 100, 0, 2), 0);
        assert_eq!(backup_attribution(true, false, 100, 0, 2), 0);
    }

    #[test]
    fn work_quota_per_scheme() {
        let n = 10;
        assert_eq!(work_quota(&Scheme::Amb { t_compute: 1.0, t_consensus: 0.1 }, n), None);
        assert_eq!(
            work_quota(&Scheme::Fmb { per_node_batch: 60, t_consensus: 0.1 }, n),
            Some(60)
        );
        assert_eq!(
            work_quota(
                &Scheme::FmbBackup { per_node_batch: 60, t_consensus: 0.1, ignore: 2, coded: true },
                n
            ),
            Some(180)
        );
        assert_eq!(
            work_quota(
                &Scheme::FmbBackup { per_node_batch: 60, t_consensus: 0.1, ignore: 2, coded: false },
                n
            ),
            Some(60)
        );
    }
}
