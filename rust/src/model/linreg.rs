//! Native least-squares chunk gradient — mirror of
//! python/compile/kernels/ref.py::linreg_grad.
//!
//! Used (a) as the artifact-free execution backend, (b) as an independent
//! oracle to cross-check PJRT numerics in integration tests.

/// grad_sum = Xᵀ((Xw − y)⊙mask), loss_sum = ½·Σ mask·(Xw − y)².
/// `x` row-major c × d; outputs into `grad` (d, zeroed here).
///
/// The mask exists for the artifact chunk+mask convention (DESIGN.md §1)
/// where variable minibatches pad a fixed-shape tail.  Full chunks should
/// use [`grad_sum_dense`], which skips the mask multiply entirely; with an
/// all-ones mask both paths are bit-identical (`r * 1.0 == r`).
pub fn grad_sum(
    w: &[f32],
    x: &[f32],
    y: &[f32],
    mask: &[f32],
    grad: &mut [f32],
) -> f64 {
    assert_eq!(mask.len(), y.len());
    grad_sum_inner::<true>(w, x, y, mask, grad)
}

/// Mask-free fast path: every sample counts with weight 1, no per-sample
/// multiply and no `vec![1.0; c]` allocation at the call site.
pub fn grad_sum_dense(w: &[f32], x: &[f32], y: &[f32], grad: &mut [f32]) -> f64 {
    grad_sum_inner::<false>(w, x, y, &[], grad)
}

#[inline(always)]
fn grad_sum_inner<const MASKED: bool>(
    w: &[f32],
    x: &[f32],
    y: &[f32],
    mask: &[f32],
    grad: &mut [f32],
) -> f64 {
    let d = w.len();
    let c = y.len();
    assert_eq!(x.len(), c * d, "x must be c*d");
    assert_eq!(grad.len(), d);
    grad.fill(0.0);
    let mut loss = 0.0f64;
    for i in 0..c {
        if MASKED && mask[i] == 0.0 {
            continue;
        }
        let row = &x[i * d..(i + 1) * d];
        let r = crate::util::dot(row, w) - y[i];
        let rm = if MASKED { r * mask[i] } else { r };
        loss += 0.5 * (rm as f64) * (r as f64);
        crate::util::axpy(rm, row, grad);
    }
    loss
}

/// Single-sample prediction xᵀw.
pub fn predict(w: &[f32], x_row: &[f32]) -> f32 {
    crate::util::dot(w, x_row)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn zero_mask_zero_grad() {
        let w = [1.0f32, 2.0];
        let x = [1.0f32, 0.0, 0.0, 1.0];
        let y = [5.0f32, 5.0];
        let mask = [0.0f32, 0.0];
        let mut grad = [9.0f32; 2];
        let loss = grad_sum(&w, &x, &y, &mask, &mut grad);
        assert_eq!(grad, [0.0, 0.0]);
        assert_eq!(loss, 0.0);
    }

    #[test]
    fn hand_computed_case() {
        // x = [[1,2]], w = [3,4], y = [1]: r = 3+8-1 = 10
        // grad = x^T r = [10, 20], loss = 0.5*100 = 50
        let mut grad = [0.0f32; 2];
        let loss = grad_sum(&[3.0, 4.0], &[1.0, 2.0], &[1.0], &[1.0], &mut grad);
        assert_eq!(grad, [10.0, 20.0]);
        assert_eq!(loss, 50.0);
    }

    #[test]
    fn grad_zero_at_interpolating_solution() {
        forall(25, 0x11_01, |g| {
            let d = g.usize_in(1, 16);
            let c = g.usize_in(1, 12);
            let w = g.vec_normal_f32(d, 1.0);
            let x = g.vec_normal_f32(c * d, 1.0);
            let y: Vec<f32> = (0..c)
                .map(|i| crate::util::dot(&x[i * d..(i + 1) * d], &w))
                .collect();
            let mask = vec![1.0f32; c];
            let mut grad = vec![0.0f32; d];
            let loss = grad_sum(&w, &x, &y, &mask, &mut grad);
            crate::prop_assert!(crate::util::norm2(&grad) < 1e-3);
            crate::prop_assert!(loss < 1e-6);
            Ok(())
        });
    }

    #[test]
    fn dense_path_bitwise_equals_ones_mask() {
        forall(25, 0x11_03, |g| {
            let d = g.usize_in(1, 16);
            let c = g.usize_in(1, 12);
            let w = g.vec_normal_f32(d, 1.0);
            let x = g.vec_normal_f32(c * d, 1.0);
            let y = g.vec_normal_f32(c, 1.0);
            let ones = vec![1.0f32; c];
            let mut gm = vec![0.0f32; d];
            let mut gd = vec![0.0f32; d];
            let lm = grad_sum(&w, &x, &y, &ones, &mut gm);
            let ld = grad_sum_dense(&w, &x, &y, &mut gd);
            crate::prop_assert!(lm.to_bits() == ld.to_bits());
            for j in 0..d {
                crate::prop_assert!(gm[j].to_bits() == gd[j].to_bits());
            }
            Ok(())
        });
    }

    #[test]
    fn mask_linearity() {
        forall(25, 0x11_02, |g| {
            let d = g.usize_in(1, 10);
            let c = g.usize_in(2, 16);
            let w = g.vec_normal_f32(d, 1.0);
            let x = g.vec_normal_f32(c * d, 1.0);
            let y = g.vec_normal_f32(c, 1.0);
            let m1 = g.mask(c, 0.5);
            let m2: Vec<f32> = m1.iter().map(|&v| 1.0 - v).collect();
            let ones = vec![1.0f32; c];
            let mut g1 = vec![0.0f32; d];
            let mut g2 = vec![0.0f32; d];
            let mut gall = vec![0.0f32; d];
            let l1 = grad_sum(&w, &x, &y, &m1, &mut g1);
            let l2 = grad_sum(&w, &x, &y, &m2, &mut g2);
            let lall = grad_sum(&w, &x, &y, &ones, &mut gall);
            crate::prop_assert_close!(l1 + l2, lall, 1e-4);
            for j in 0..d {
                crate::prop_assert_close!(g1[j] + g2[j], gall[j], 1e-3);
            }
            Ok(())
        });
    }

    #[test]
    fn finite_difference_gradient() {
        let mut g = crate::prop::Gen::new(42);
        let d = 6;
        let c = 5;
        let w = g.vec_normal_f32(d, 0.5);
        let x = g.vec_normal_f32(c * d, 1.0);
        let y = g.vec_normal_f32(c, 1.0);
        let mask = vec![1.0f32; c];
        let mut grad = vec![0.0f32; d];
        grad_sum(&w, &x, &y, &mask, &mut grad);
        let loss_at = |wv: &[f32]| {
            let mut tmp = vec![0.0f32; d];
            grad_sum(wv, &x, &y, &mask, &mut tmp)
        };
        let eps = 1e-3f32;
        for j in 0..d {
            let mut wp = w.clone();
            wp[j] += eps;
            let mut wm = w.clone();
            wm[j] -= eps;
            let fd = (loss_at(&wp) - loss_at(&wm)) / (2.0 * eps as f64);
            assert!((fd - grad[j] as f64).abs() < 2e-2, "j={j} fd={fd} g={}", grad[j]);
        }
    }
}
