//! Native-Rust reference models mirroring the Python oracles
//! (python/compile/kernels/ref.py).  They serve two purposes:
//!
//! 1. artifact-free execution backend (`exec::NativeExec`) so the
//!    simulator, unit tests, and pure-algorithm benches run without the
//!    PJRT runtime;
//! 2. independent numerical oracle for the PJRT-loaded artifacts
//!    (rust/tests/pjrt_roundtrip.rs asserts Native == PJRT == ref.py).

pub mod linreg;
pub mod logreg;

/// Which workload a coordinator run is optimizing.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    /// Least squares; dimension d.
    LinReg { d: usize },
    /// Multiclass logistic regression; k classes × d features.
    LogReg { k: usize, d: usize },
    /// Flattened-parameter model executed only via artifacts (e2e LM).
    Opaque { dim: usize },
}

impl Workload {
    /// Parameter-vector dimension (the dual/primal variable size).
    pub fn dim(&self) -> usize {
        match *self {
            Workload::LinReg { d } => d,
            Workload::LogReg { k, d } => k * d,
            Workload::Opaque { dim } => dim,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_dims() {
        assert_eq!(Workload::LinReg { d: 7 }.dim(), 7);
        assert_eq!(Workload::LogReg { k: 10, d: 785 }.dim(), 7850);
        assert_eq!(Workload::Opaque { dim: 3 }.dim(), 3);
    }
}
