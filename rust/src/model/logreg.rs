//! Native multiclass logistic-regression chunk gradient — mirror of
//! python/compile/kernels/ref.py::logreg_grad (stable softmax).

/// grad_sum (k × d, zeroed here) and masked summed cross-entropy loss.
/// w row-major k × d; x row-major c × d.
///
/// The mask exists for the artifact chunk+mask convention (DESIGN.md §1);
/// full chunks should use [`grad_sum_dense`], which skips the per-sample
/// mask multiplies entirely — with an all-ones mask both paths are
/// bit-identical.
pub fn grad_sum(
    w: &[f32],
    x: &[f32],
    labels: &[i32],
    mask: &[f32],
    k: usize,
    grad: &mut [f32],
) -> f64 {
    assert_eq!(mask.len(), labels.len());
    grad_sum_inner::<true>(w, x, labels, mask, k, grad)
}

/// Mask-free fast path: every sample counts with weight 1, no per-sample
/// multiplies and no `vec![1.0; c]` allocation at the call site.
pub fn grad_sum_dense(w: &[f32], x: &[f32], labels: &[i32], k: usize, grad: &mut [f32]) -> f64 {
    grad_sum_inner::<false>(w, x, labels, &[], k, grad)
}

#[inline(always)]
fn grad_sum_inner<const MASKED: bool>(
    w: &[f32],
    x: &[f32],
    labels: &[i32],
    mask: &[f32],
    k: usize,
    grad: &mut [f32],
) -> f64 {
    let c = labels.len();
    assert!(k > 0 && w.len() % k == 0);
    let d = w.len() / k;
    assert_eq!(x.len(), c * d);
    assert_eq!(grad.len(), k * d);
    grad.fill(0.0);
    let mut loss = 0.0f64;
    let mut logits = vec![0.0f32; k];
    for i in 0..c {
        if MASKED && mask[i] == 0.0 {
            continue;
        }
        let row = &x[i * d..(i + 1) * d];
        let mut zmax = f32::NEG_INFINITY;
        for cls in 0..k {
            logits[cls] = crate::util::dot(&w[cls * d..(cls + 1) * d], row);
            zmax = zmax.max(logits[cls]);
        }
        let mut denom = 0.0f32;
        for cls in 0..k {
            logits[cls] = (logits[cls] - zmax).exp();
            denom += logits[cls];
        }
        let label = labels[i] as usize;
        assert!(label < k, "label {label} out of range k={k}");
        // p_cls = logits[cls]/denom; dlogits = (p - onehot) [* mask]
        for cls in 0..k {
            let p = logits[cls] / denom;
            let onehot = if cls == label { 1.0 } else { 0.0 };
            let dl = if MASKED { (p - onehot) * mask[i] } else { p - onehot };
            crate::util::axpy(dl, row, &mut grad[cls * d..(cls + 1) * d]);
        }
        let logp = (logits[label] / denom).max(f32::MIN_POSITIVE).ln();
        loss -= if MASKED { (mask[i] * logp) as f64 } else { logp as f64 };
    }
    loss
}

/// argmax-class prediction for one row.
pub fn predict(w: &[f32], x_row: &[f32], k: usize) -> usize {
    let d = x_row.len();
    let mut best = (f32::NEG_INFINITY, 0usize);
    for cls in 0..k {
        let s = crate::util::dot(&w[cls * d..(cls + 1) * d], x_row);
        if s > best.0 {
            best = (s, cls);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn uniform_weights_uniform_loss() {
        // w = 0 -> p uniform -> per-sample loss ln(k)
        let k = 5;
        let d = 3;
        let c = 4;
        let w = vec![0.0f32; k * d];
        let x = vec![1.0f32; c * d];
        let labels = [0, 1, 2, 3];
        let mask = vec![1.0f32; c];
        let mut grad = vec![0.0f32; k * d];
        let loss = grad_sum(&w, &x, &labels, &mask, k, &mut grad);
        assert!((loss - c as f64 * (k as f64).ln()).abs() < 1e-4);
    }

    #[test]
    fn dlogits_rows_sum_to_zero_in_grad_structure() {
        // Σ_cls grad[cls] = Σ_i x_i * Σ_cls dlogits = 0 for full mask
        forall(20, 0x12_01, |g| {
            let k = g.usize_in(2, 8);
            let d = g.usize_in(1, 8);
            let c = g.usize_in(1, 10);
            let w = g.vec_normal_f32(k * d, 1.0);
            let x = g.vec_normal_f32(c * d, 1.0);
            let labels: Vec<i32> = (0..c).map(|_| g.usize_in(0, k - 1) as i32).collect();
            let mask = vec![1.0f32; c];
            let mut grad = vec![0.0f32; k * d];
            grad_sum(&w, &x, &labels, &mask, k, &mut grad);
            for j in 0..d {
                let col: f32 = (0..k).map(|cls| grad[cls * d + j]).sum();
                crate::prop_assert!(col.abs() < 1e-3, "col sum {}", col);
            }
            Ok(())
        });
    }

    #[test]
    fn dense_path_bitwise_equals_ones_mask() {
        forall(20, 0x12_04, |g| {
            let k = g.usize_in(2, 6);
            let d = g.usize_in(1, 8);
            let c = g.usize_in(1, 10);
            let w = g.vec_normal_f32(k * d, 1.0);
            let x = g.vec_normal_f32(c * d, 1.0);
            let labels: Vec<i32> = (0..c).map(|_| g.usize_in(0, k - 1) as i32).collect();
            let ones = vec![1.0f32; c];
            let mut gm = vec![0.0f32; k * d];
            let mut gd = vec![0.0f32; k * d];
            let lm = grad_sum(&w, &x, &labels, &ones, k, &mut gm);
            let ld = grad_sum_dense(&w, &x, &labels, k, &mut gd);
            crate::prop_assert!(lm.to_bits() == ld.to_bits());
            for j in 0..k * d {
                crate::prop_assert!(gm[j].to_bits() == gd[j].to_bits());
            }
            Ok(())
        });
    }

    #[test]
    fn loss_nonnegative_and_mask_linearity() {
        forall(20, 0x12_02, |g| {
            let k = g.usize_in(2, 6);
            let d = g.usize_in(1, 6);
            let c = g.usize_in(2, 12);
            let w = g.vec_normal_f32(k * d, 1.0);
            let x = g.vec_normal_f32(c * d, 1.0);
            let labels: Vec<i32> = (0..c).map(|_| g.usize_in(0, k - 1) as i32).collect();
            let m1 = g.mask(c, 0.5);
            let m2: Vec<f32> = m1.iter().map(|&v| 1.0 - v).collect();
            let ones = vec![1.0f32; c];
            let mut g1 = vec![0.0f32; k * d];
            let mut g2 = vec![0.0f32; k * d];
            let mut gall = vec![0.0f32; k * d];
            let l1 = grad_sum(&w, &x, &labels, &m1, k, &mut g1);
            let l2 = grad_sum(&w, &x, &labels, &m2, k, &mut g2);
            let lall = grad_sum(&w, &x, &labels, &ones, k, &mut gall);
            crate::prop_assert!(l1 >= 0.0 && l2 >= 0.0);
            crate::prop_assert_close!(l1 + l2, lall, 1e-4);
            for j in 0..k * d {
                crate::prop_assert_close!(g1[j] + g2[j], gall[j], 1e-3);
            }
            Ok(())
        });
    }

    #[test]
    fn finite_difference_gradient() {
        let mut g = crate::prop::Gen::new(7);
        let (k, d, c) = (3, 4, 6);
        let w = g.vec_normal_f32(k * d, 0.5);
        let x = g.vec_normal_f32(c * d, 1.0);
        let labels: Vec<i32> = (0..c).map(|_| g.usize_in(0, k - 1) as i32).collect();
        let mask = vec![1.0f32; c];
        let mut grad = vec![0.0f32; k * d];
        grad_sum(&w, &x, &labels, &mask, k, &mut grad);
        let loss_at = |wv: &[f32]| {
            let mut tmp = vec![0.0f32; k * d];
            grad_sum(wv, &x, &labels, &mask, k, &mut tmp)
        };
        let eps = 1e-3f32;
        for j in 0..k * d {
            let mut wp = w.clone();
            wp[j] += eps;
            let mut wm = w.clone();
            wm[j] -= eps;
            let fd = (loss_at(&wp) - loss_at(&wm)) / (2.0 * eps as f64);
            assert!((fd - grad[j] as f64).abs() < 5e-3, "j={j} fd={fd} g={}", grad[j]);
        }
    }

    #[test]
    fn extreme_logits_stable() {
        let k = 3;
        let _d = 1;
        let w = [1000.0f32, 0.0, -1000.0];
        let x = [10.0f32];
        let labels = [0];
        let mask = [1.0f32];
        let mut grad = vec![0.0f32; 3];
        let loss = grad_sum(&w, &x, &labels, &mask, k, &mut grad);
        assert!(loss.is_finite() && loss < 1e-3);
        assert!(grad.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn training_improves_prediction() {
        // tiny GD run separates a 3-class mixture
        let mut g = crate::prop::Gen::new(9);
        let (k, raw_d) = (3usize, 8usize);
        let d = raw_d + 1;
        let data = crate::data::MnistLike::new(k, raw_d, 4.0, 1.0, 11);
        let mut rng = crate::util::rng::Pcg64::new(12);
        let mut w = g.vec_normal_f32(k * d, 0.01);
        let (mut x, mut labels) = (Vec::new(), Vec::new());
        let mut grad = vec![0.0f32; k * d];
        for _ in 0..60 {
            data.sample_chunk(&mut rng, 64, &mut x, &mut labels);
            let mask = vec![1.0f32; 64];
            grad_sum(&w, &x, &labels, &mask, k, &mut grad);
            for j in 0..k * d {
                w[j] -= 0.05 * grad[j] / 64.0;
            }
        }
        let acc = data.accuracy(&w, &mut rng, 1000);
        assert!(acc > 0.8, "acc={acc}");
    }
}
