//! Per-epoch subgraph consensus for elastic membership (churn).
//!
//! A churn run mixes over [`Topology::induced`] subgraphs — inactive
//! nodes are isolated (Metropolis row eᵢ) so they hold their message
//! bit-for-bit and contribute nothing, while the active block stays
//! doubly stochastic and conserves the ACTIVE-set mean.  Induced
//! matrices are built directly in CSR via
//! [`Topology::induced_metropolis_lazy_csr`] — O(n + E) per build, no
//! n² materialisation (bitwise the old dense composition, pinned in
//! `topology::tests`) — so a fresh active set per epoch costs an
//! edge-proportional rebuild, not a quadratic one.  A small LRU keyed
//! by the churned vertex set still absorbs periodic schedules (Markov
//! flapping, repeating traces), and the common "nobody churned" epoch
//! takes the preloaded base matrix with ZERO rebuild or
//! lookup-allocation cost.
//!
//! (The previous design memoized DENSE O(n²) copies behind a 64-entry
//! clear-on-overflow cache: under iid churn nearly every epoch is a
//! never-seen set, so the cache cleared constantly while each retained
//! entry cost n² memory.  With the CSR build a miss is cheap, so the
//! cache only needs to be big enough for short periodic schedules.)
//!
//! The rounds themselves are the stock [`MixMatrix::mix_into`] blocked
//! CSR kernel (row-partitioned across the worker pool, per-row op order
//! fixed), so every bitwise pin from PR 2/3 — and the threads=1 ≡
//! threads=k contract — holds for churn runs unchanged.
//!
//! **Fault degradation** (`run_faulty`/`run_per_node_faulty`): when the
//! fault plane drops a round's message `j → i`, receiver `i` absorbs
//! the missing Metropolis weight into its self-weight by mixing its OWN
//! pre-round row in `j`'s place (the substitute-self trick):
//!   out_i = Σ_j P_ij · (dropped(i←j) ? m_i : m_j).
//! The effective row weights are unchanged as a multiset, so each row
//! stays exactly as stochastic as the underlying matrix — node values
//! remain convex combinations and cannot blow up — but the mix is no
//! longer doubly stochastic, so the active-set mean is conserved only
//! approximately; the epoch loop MEASURES that drift
//! (`EpochStats::conservation_drift`).  Rounds with an empty drop mask
//! take the stock kernel byte-for-byte, so an all-clear fault spec
//! reproduces fault-free runs bitwise.

use std::collections::{HashMap, VecDeque};

use crate::fault::DropMask;
use crate::topology::{MixMatrix, Topology};
use crate::util::matrix::NodeMatrix;

/// Sparse synchronous consensus with a small per-active-set LRU.
///
/// The all-active matrix is exactly `topo.metropolis().lazy()` — the
/// matrix the static-membership [`super::Consensus`] engine uses — so a
/// schedule that never drops a node reproduces static runs bit-for-bit.
pub struct InducedConsensus {
    topo: Topology,
    /// The all-active (P + I)/2 Metropolis matrix (zero-rebuild path).
    base: MixMatrix,
    /// Induced lazy CSR matrices memoized by active-set key.
    cache: HashMap<Vec<bool>, MixMatrix>,
    /// Recency order of `cache`'s keys (front = least recently used).
    lru: VecDeque<Vec<bool>>,
    /// Scratch arena double-buffered against the caller's messages.
    scratch: NodeMatrix,
}

impl InducedConsensus {
    /// LRU capacity.  Each cached matrix is CSR — O(edges), not O(n²) —
    /// and a miss is an O(n + E) rebuild, so the cache exists only to
    /// absorb short periodic schedules (Markov flapping between a few
    /// sets, repeating traces); non-repeating iid churn just streams
    /// through it, evicting the oldest entry each epoch instead of the
    /// old clear-the-world behaviour.
    pub const MAX_CACHED_SETS: usize = 8;

    pub fn new(topo: Topology) -> InducedConsensus {
        let base = topo.metropolis().lazy();
        InducedConsensus {
            topo,
            base,
            cache: HashMap::new(),
            lru: VecDeque::new(),
            scratch: NodeMatrix::new(0, 0),
        }
    }

    pub fn n(&self) -> usize {
        self.topo.n()
    }

    /// Number of distinct (non-all-active) active sets currently cached
    /// — the memoization diagnostic: an all-active schedule stays at 0,
    /// and the count never exceeds [`Self::MAX_CACHED_SETS`].
    pub fn cached_sets(&self) -> usize {
        self.cache.len()
    }

    /// Whether `active`'s induced matrix is currently resident (cache
    /// diagnostic; the all-active set is always "cached" via the base).
    pub fn is_cached(&self, active: &[bool]) -> bool {
        active.iter().all(|&a| a) || self.cache.contains_key(active)
    }

    /// The ONE build-and-memoize site: make sure `active`'s induced
    /// matrix is cached (no-op for the all-active set, which
    /// short-circuits to the base matrix) and report whether the set is
    /// all-active.  `run`/`run_per_node`/`matrix_for` all go through
    /// here, then re-borrow field-disjointly.
    fn ensure_cached(&mut self, active: &[bool]) -> bool {
        assert_eq!(active.len(), self.topo.n(), "active mask must cover every node");
        let all = active.iter().all(|&a| a);
        if all {
            return true;
        }
        if self.cache.contains_key(active) {
            // refresh recency (cap is tiny, the scan is cheap)
            if let Some(pos) = self.lru.iter().position(|k| k == active) {
                // amb-lint: allow(D4, "pos was found by position() over this same deque")
                let k = self.lru.remove(pos).unwrap();
                self.lru.push_back(k);
            }
        } else {
            if self.cache.len() >= Self::MAX_CACHED_SETS {
                // amb-lint: allow(D4, "cache at capacity implies a non-empty lru deque")
                let oldest = self.lru.pop_front().expect("cache non-empty at cap");
                self.cache.remove(&oldest);
            }
            let m = self.topo.induced_metropolis_lazy_csr(active);
            self.cache.insert(active.to_vec(), m);
            self.lru.push_back(active.to_vec());
        }
        false
    }

    /// The mixing matrix for `active` (building + memoizing on first
    /// sight; the all-active set short-circuits to the base matrix).
    pub fn matrix_for(&mut self, active: &[bool]) -> &MixMatrix {
        if self.ensure_cached(active) {
            &self.base
        } else {
            // amb-lint: allow(D4, "entry inserted by the ensure() call just above")
            self.cache.get(active).unwrap()
        }
    }

    fn ensure_scratch(&mut self, n: usize, d: usize) {
        if self.scratch.n() != n || self.scratch.d() != d {
            self.scratch.reset(n, d);
        }
    }

    /// `rounds` synchronous rounds over the `active` subgraph, in place
    /// (mix into scratch, O(1) flip).  Inactive rows come back bitwise
    /// untouched (their row is eᵢ and 1.0 · x = x exactly).
    pub fn run(&mut self, msgs: &mut NodeMatrix, rounds: usize, active: &[bool]) {
        let n = self.topo.n();
        assert_eq!(msgs.n(), n);
        self.ensure_scratch(n, msgs.d());
        // Field-disjoint borrows: the matrix ref (base/cache) and the
        // scratch arena live in different fields.
        let all = self.ensure_cached(active);
        // amb-lint: allow(D4, "plan cached by ensure() at method entry")
        let p = if all { &self.base } else { self.cache.get(active).unwrap() };
        for _ in 0..rounds {
            p.mix_into(msgs, &mut self.scratch);
            msgs.swap(&mut self.scratch);
        }
    }

    /// Per-node round budgets r_i over the `active` subgraph — the
    /// freeze semantics of [`super::Consensus::run_per_node`], mixed
    /// with the induced matrix.  Callers pass 0 for inactive nodes
    /// (isolation already holds them; a 0 budget keeps the rounds log
    /// honest).
    pub fn run_per_node(&mut self, msgs: &mut NodeMatrix, rounds: &[usize], active: &[bool]) {
        let n = self.topo.n();
        assert_eq!(msgs.n(), n);
        assert_eq!(rounds.len(), n);
        let rmax = rounds.iter().copied().max().unwrap_or(0);
        self.ensure_scratch(n, msgs.d());
        let all = self.ensure_cached(active);
        // amb-lint: allow(D4, "plan cached by ensure() at method entry")
        let p = if all { &self.base } else { self.cache.get(active).unwrap() };
        for k in 0..rmax {
            p.mix_into(msgs, &mut self.scratch);
            msgs.swap(&mut self.scratch);
            // post-swap, scratch holds the pre-mix values: un-mix the
            // rows whose budget is spent
            for i in 0..n {
                if rounds[i] <= k {
                    msgs.row_mut(i).copy_from_slice(self.scratch.row(i));
                }
            }
        }
    }

    /// [`Self::run`] under a fault plane: `masks[k]` is round `k`'s drop
    /// set of `(dst, src)` pairs (missing/short `masks` mean clean
    /// rounds).  A dropped in-edge is absorbed into the receiver's
    /// self-weight (see the module docs), so rows stay stochastic but
    /// mean conservation becomes approximate.  Returns the number of
    /// substitute-self applications actually fired — 0 means the run was
    /// bitwise the clean path and the caller may pin
    /// `conservation_drift == 0.0`.
    pub fn run_faulty(
        &mut self,
        msgs: &mut NodeMatrix,
        rounds: usize,
        active: &[bool],
        masks: &[DropMask],
    ) -> usize {
        let n = self.topo.n();
        assert_eq!(msgs.n(), n);
        self.ensure_scratch(n, msgs.d());
        let all = self.ensure_cached(active);
        // amb-lint: allow(D4, "plan cached by ensure() at method entry")
        let p = if all { &self.base } else { self.cache.get(active).unwrap() };
        let mut drops = 0;
        for k in 0..rounds {
            match masks.get(k).filter(|m| !m.is_empty()) {
                None => p.mix_into(msgs, &mut self.scratch),
                Some(mask) => drops += mix_into_masked(p, msgs, &mut self.scratch, mask),
            }
            msgs.swap(&mut self.scratch);
        }
        drops
    }

    /// [`Self::run_per_node`] under a fault plane — per-node budgets
    /// (freeze semantics) with `masks[k]` dropping round `k`'s edges, as
    /// in [`Self::run_faulty`].  A substitution landing on an
    /// already-frozen receiver still counts as a fired drop (the message
    /// WAS lost on the wire) even though the freeze then discards the
    /// round for that row.
    pub fn run_per_node_faulty(
        &mut self,
        msgs: &mut NodeMatrix,
        rounds: &[usize],
        active: &[bool],
        masks: &[DropMask],
    ) -> usize {
        let n = self.topo.n();
        assert_eq!(msgs.n(), n);
        assert_eq!(rounds.len(), n);
        let rmax = rounds.iter().copied().max().unwrap_or(0);
        self.ensure_scratch(n, msgs.d());
        let all = self.ensure_cached(active);
        // amb-lint: allow(D4, "plan cached by ensure() at method entry")
        let p = if all { &self.base } else { self.cache.get(active).unwrap() };
        let mut drops = 0;
        for k in 0..rmax {
            match masks.get(k).filter(|m| !m.is_empty()) {
                None => p.mix_into(msgs, &mut self.scratch),
                Some(mask) => drops += mix_into_masked(p, msgs, &mut self.scratch, mask),
            }
            msgs.swap(&mut self.scratch);
            // post-swap, scratch holds the pre-mix values: un-mix the
            // rows whose budget is spent
            for i in 0..n {
                if rounds[i] <= k {
                    msgs.row_mut(i).copy_from_slice(self.scratch.row(i));
                }
            }
        }
        drops
    }

    /// Mean of the ACTIVE rows, accumulated in f64 in ascending-node
    /// order — what ε-perfect consensus over the active subgraph would
    /// deliver to every active node.  `None` when no node is active.
    pub fn active_mean_f64(msgs: &NodeMatrix, active: &[bool]) -> Option<Vec<f64>> {
        assert_eq!(msgs.n(), active.len());
        let count = active.iter().filter(|&&a| a).count();
        if count == 0 {
            return None;
        }
        let mut avg = vec![0.0f64; msgs.d()];
        for (i, row) in msgs.rows().enumerate() {
            if active[i] {
                for (a, &v) in avg.iter_mut().zip(row) {
                    *a += v as f64;
                }
            }
        }
        for a in avg.iter_mut() {
            *a /= count as f64;
        }
        Some(avg)
    }
}

/// One degraded mixing round: `out[i] = Σ_e w_e · src_e` where entry
/// `e = (i ← j)` sources the receiver's OWN pre-round row when the mask
/// drops it.  Per-row the weights are applied sequentially in ascending
/// CSR-entry order — `MixMatrix::mix_into`'s tiled axpy4 kernel is
/// documented bit-identical to exactly this order, so rows without a
/// dropped in-edge produce the same bits either way (and whole rounds
/// with an empty mask never reach this function at all).  Returns the
/// number of substitutions applied.
fn mix_into_masked(
    p: &MixMatrix,
    msgs: &NodeMatrix,
    out: &mut NodeMatrix,
    mask: &DropMask,
) -> usize {
    let n = msgs.n();
    let mut drops = 0;
    for i in 0..n {
        let (cols, ws) = p.row_entries(i);
        let row = out.row_mut(i);
        row.fill(0.0);
        for (&c, &w) in cols.iter().zip(ws) {
            let j = c as usize;
            let src = if j != i && mask.contains(&(i as u32, c)) {
                drops += 1;
                i // absorb the lost edge's weight into self
            } else {
                j
            };
            crate::util::axpy(w, msgs.row(src), row);
        }
    }
    drops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::Consensus;
    use crate::prop::forall;

    fn random_msgs(g: &mut crate::prop::Gen, n: usize, d: usize) -> NodeMatrix {
        let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect();
        NodeMatrix::from_rows(&rows)
    }

    /// A mask with at least one active node.
    fn random_active(g: &mut crate::prop::Gen, n: usize) -> Vec<bool> {
        let mut active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
        let forced = g.usize_in(0, n - 1);
        active[forced] = true;
        active
    }

    #[test]
    fn all_active_matches_static_engine_bitwise() {
        forall(15, 0xCE_01, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 16);
            let topo = Topology::erdos_connected(n, 0.4, g.u64());
            let rounds = g.usize_in(1, 6);
            let msgs0 = random_msgs(g, n, d);

            let mut stat = Consensus::new(topo.metropolis().lazy());
            let mut a = msgs0.clone();
            stat.run(&mut a, rounds);

            let mut ind = InducedConsensus::new(topo);
            let mut b = msgs0;
            ind.run(&mut b, rounds, &vec![true; n]);

            crate::prop_assert!(ind.cached_sets() == 0, "all-active must not build");
            for i in 0..n {
                for k in 0..d {
                    crate::prop_assert!(
                        a.row(i)[k].to_bits() == b.row(i)[k].to_bits(),
                        "({i},{k}) static={} induced={}",
                        a.row(i)[k],
                        b.row(i)[k]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn conserves_active_mean_and_freezes_inactive_rows() {
        forall(25, 0xCE_02, |g| {
            let n = g.usize_in(2, 14);
            let d = g.usize_in(1, 8);
            let topo = Topology::erdos_connected(n, 0.5, g.u64());
            let active = random_active(g, n);
            let msgs0 = random_msgs(g, n, d);
            let before = InducedConsensus::active_mean_f64(&msgs0, &active).unwrap();

            let mut ind = InducedConsensus::new(topo);
            let mut msgs = msgs0.clone();
            ind.run(&mut msgs, g.usize_in(1, 25), &active);

            // active-set mean conserved (double stochasticity over the
            // active block)
            let after = InducedConsensus::active_mean_f64(&msgs, &active).unwrap();
            for k in 0..d {
                crate::prop_assert_close!(before[k], after[k], 1e-4);
            }
            // inactive rows bitwise frozen
            for i in 0..n {
                if !active[i] {
                    crate::prop_assert!(
                        msgs.row(i) == msgs0.row(i),
                        "inactive row {i} drifted"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn active_component_converges_to_active_mean() {
        // On a complete graph the active subgraph stays connected, so
        // active nodes must converge to the mean of the ACTIVE initial
        // values (not the all-node mean).
        let n = 8;
        let topo = Topology::complete(n);
        let mut g = crate::prop::Gen::new(0xCE_03);
        let msgs0 = random_msgs(&mut g, n, 4);
        let active = vec![true, false, true, true, false, true, true, false];
        let want = InducedConsensus::active_mean_f64(&msgs0, &active).unwrap();

        let mut ind = InducedConsensus::new(topo);
        let mut msgs = msgs0.clone();
        ind.run(&mut msgs, 200, &active);
        for i in 0..n {
            if active[i] {
                for k in 0..4 {
                    assert!(
                        (msgs.row(i)[k] as f64 - want[k]).abs() < 1e-4,
                        "node {i} col {k}: {} vs {}",
                        msgs.row(i)[k],
                        want[k]
                    );
                }
            }
        }
    }

    #[test]
    fn memoizes_by_active_set_key() {
        let topo = Topology::ring(6);
        let mut ind = InducedConsensus::new(topo);
        let mut g = crate::prop::Gen::new(0xCE_04);
        let mut msgs = random_msgs(&mut g, 6, 3);
        let a1 = vec![true, true, false, true, true, true];
        let a2 = vec![true, false, true, true, true, true];
        let all = vec![true; 6];
        for _ in 0..50 {
            ind.run(&mut msgs, 1, &a1);
            ind.run(&mut msgs, 1, &a2);
            ind.run(&mut msgs, 1, &all);
        }
        assert_eq!(ind.cached_sets(), 2, "one build per distinct churned set");
    }

    #[test]
    fn lru_evicts_oldest_not_everything() {
        // Fill the cache to the cap, touch the first entry again, then
        // insert one more: the refreshed entry must survive (true LRU),
        // and the count stays pinned at the cap.
        let n = 12;
        let topo = Topology::complete(n);
        let mut ind = InducedConsensus::new(topo);
        let mut g = crate::prop::Gen::new(0xCE_07);
        let mut msgs = random_msgs(&mut g, n, 2);
        let mask = |drop: usize| -> Vec<bool> {
            (0..n).map(|i| i != drop).collect()
        };
        for drop in 0..InducedConsensus::MAX_CACHED_SETS {
            ind.run(&mut msgs, 1, &mask(drop));
        }
        assert_eq!(ind.cached_sets(), InducedConsensus::MAX_CACHED_SETS);
        ind.run(&mut msgs, 1, &mask(0)); // refresh the oldest
        ind.run(&mut msgs, 1, &mask(InducedConsensus::MAX_CACHED_SETS)); // evicts mask(1)
        assert_eq!(ind.cached_sets(), InducedConsensus::MAX_CACHED_SETS);
        assert!(ind.is_cached(&mask(0)), "refreshed entry must survive eviction");
        assert!(!ind.is_cached(&mask(1)), "least-recently-used entry must be the one evicted");
        assert!(ind.is_cached(&mask(InducedConsensus::MAX_CACHED_SETS)));
    }

    #[test]
    fn cache_is_bounded_under_nonrepeating_active_sets() {
        // 10 nodes admit > MAX_CACHED_SETS distinct active sets; the
        // cache must never exceed the cap (oldest-entry eviction), and
        // results stay correct after eviction (rebuild on demand).
        let n = 10;
        let topo = Topology::complete(n);
        let mut ind = InducedConsensus::new(topo);
        let mut g = crate::prop::Gen::new(0xCE_06);
        let mut msgs = random_msgs(&mut g, n, 2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..(InducedConsensus::MAX_CACHED_SETS * 3) {
            let mut active: Vec<bool> = (0..n).map(|_| g.bool(0.5)).collect();
            active[0] = true; // keep at least one node up
            if active.iter().all(|&a| a) {
                active[1] = false; // force a churned (cacheable) set
            }
            seen.insert(active.clone());
            ind.run(&mut msgs, 1, &active);
            assert!(
                ind.cached_sets() <= InducedConsensus::MAX_CACHED_SETS,
                "cache grew past the cap: {}",
                ind.cached_sets()
            );
        }
        // the sweep really did exceed the cap, so eviction was exercised
        assert!(seen.len() > InducedConsensus::MAX_CACHED_SETS, "distinct sets: {}", seen.len());
    }

    /// A mask of random (dst, src) pairs over n nodes (may name
    /// non-edges; those are no-ops by construction).
    fn random_mask(g: &mut crate::prop::Gen, n: usize) -> DropMask {
        let mut m = DropMask::new();
        for _ in 0..g.usize_in(0, 2 * n) {
            let dst = g.usize_in(0, n - 1) as u32;
            let src = g.usize_in(0, n - 1) as u32;
            if dst != src {
                m.insert((dst, src));
            }
        }
        m
    }

    #[test]
    fn empty_masks_are_bitwise_the_clean_path() {
        forall(15, 0xFA_01, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 8);
            let topo = Topology::erdos_connected(n, 0.5, g.u64());
            let active = random_active(g, n);
            let rounds = g.usize_in(1, 5);
            let msgs0 = random_msgs(g, n, d);

            let mut ind = InducedConsensus::new(topo.clone());
            let mut clean = msgs0.clone();
            ind.run(&mut clean, rounds, &active);

            // all-empty masks, short masks, and no masks at all must all
            // take the stock kernel and report zero fired drops
            for masks in [vec![], vec![DropMask::new(); rounds]] {
                let mut ind2 = InducedConsensus::new(topo.clone());
                let mut m = msgs0.clone();
                let drops = ind2.run_faulty(&mut m, rounds, &active, &masks);
                crate::prop_assert!(drops == 0, "clean masks fired {drops} drops");
                for i in 0..n {
                    crate::prop_assert!(m.row(i) == clean.row(i), "row {i} diverged");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn masked_rows_stay_stochastic_constant_fixed_point() {
        // Substitution permutes which SOURCE each weight multiplies but
        // never the weights themselves, so on a constant matrix (every
        // row identical) a masked round is bitwise the unmasked round —
        // for ANY drop mask.  This is the row-stochasticity property at
        // kernel level: had substitution gained or lost weight, the
        // constant fixed point would move.
        forall(20, 0xFA_02, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 8);
            let topo = Topology::erdos_connected(n, 0.6, g.u64());
            let active = random_active(g, n);
            let row: Vec<f32> = g.vec_normal_f32(d, 2.0);
            let msgs0 = NodeMatrix::from_rows(&vec![row; n]);
            let masks: Vec<DropMask> = (0..3).map(|_| random_mask(g, n)).collect();

            let mut a = InducedConsensus::new(topo.clone());
            let mut clean = msgs0.clone();
            a.run(&mut clean, 3, &active);

            let mut b = InducedConsensus::new(topo);
            let mut masked = msgs0;
            b.run_faulty(&mut masked, 3, &active, &masks);

            for i in 0..n {
                for k in 0..d {
                    crate::prop_assert!(
                        clean.row(i)[k].to_bits() == masked.row(i)[k].to_bits(),
                        "({i},{k}): clean={} masked={}",
                        clean.row(i)[k],
                        masked.row(i)[k]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn drops_are_local_to_the_receiver() {
        // Drop edges INTO node 0 only: every other row must come back
        // bitwise identical to the unmasked round, and the drop count
        // must equal the number of masked entries that are real edges.
        let n = 6;
        let topo = Topology::complete(n);
        let mut g = crate::prop::Gen::new(0xFA_03);
        let msgs0 = random_msgs(&mut g, n, 4);
        let all = vec![true; n];
        let mut mask = DropMask::new();
        mask.insert((0, 1));
        mask.insert((0, 3));

        let mut a = InducedConsensus::new(topo.clone());
        let mut clean = msgs0.clone();
        a.run(&mut clean, 1, &all);

        let mut b = InducedConsensus::new(topo);
        let mut masked = msgs0.clone();
        let drops = b.run_faulty(&mut masked, 1, &all, std::slice::from_ref(&mask));
        assert_eq!(drops, 2, "complete graph: both masked pairs are edges");
        for i in 1..n {
            assert_eq!(masked.row(i), clean.row(i), "undropped row {i} diverged");
        }
        assert_ne!(masked.row(0), clean.row(0), "dropped receiver must differ");
    }

    #[test]
    fn per_node_faulty_with_empty_masks_matches_per_node() {
        let topo = Topology::complete(5);
        let mut g = crate::prop::Gen::new(0xFA_04);
        let msgs0 = random_msgs(&mut g, 5, 3);
        let active = vec![true, true, false, true, true];
        let budgets = [4usize, 4, 0, 1, 4];

        let mut a = InducedConsensus::new(Topology::complete(5));
        let mut want = msgs0.clone();
        a.run_per_node(&mut want, &budgets, &active);

        let mut b = InducedConsensus::new(topo);
        let mut got = msgs0;
        let drops = b.run_per_node_faulty(&mut got, &budgets, &active, &[]);
        assert_eq!(drops, 0);
        for i in 0..5 {
            assert_eq!(got.row(i), want.row(i), "row {i}");
        }
    }

    #[test]
    fn per_node_budgets_freeze_with_churn() {
        let topo = Topology::complete(5);
        let mut g = crate::prop::Gen::new(0xCE_05);
        let msgs0 = random_msgs(&mut g, 5, 3);
        let active = vec![true, true, false, true, true];
        let mut ind = InducedConsensus::new(topo);

        let mut m = msgs0.clone();
        // node 3 stops after 1 round; inactive node 2 has budget 0
        ind.run_per_node(&mut m, &[4, 4, 0, 1, 4], &active);
        assert_eq!(m.row(2), msgs0.row(2), "inactive row must hold");
        assert_ne!(m.row(0), msgs0.row(0));

        // node 3's frozen value equals its state after exactly 1 round
        let mut one = msgs0.clone();
        ind.run(&mut one, 1, &active);
        assert_eq!(m.row(3), one.row(3), "frozen row drifted");
    }
}
