//! Push-sum (ratio) consensus — Tsianos, Lawlor & Rabbat (2012), cited by
//! the paper as the directed-graph generalisation of averaging consensus.
//!
//! Each node keeps a value vector x_i and a weight φ_i (init 1).  Per
//! round, node i splits (x_i, φ_i) equally among its out-neighbours and
//! itself; estimates are the ratios x_i/φ_i, which converge to the true
//! average on any strongly-connected digraph even though the column-
//! stochastic mixing is not doubly stochastic.  This lets AMB run on
//! asymmetric communication graphs (e.g. radio networks) where Metropolis
//! weights don't exist.

use crate::util::matrix::{NodeMatrix, NodeMatrixF64};
use crate::util::rng::Pcg64;

/// Directed graph as out-neighbour lists.
#[derive(Debug, Clone)]
pub struct Digraph {
    out: Vec<Vec<usize>>,
}

impl Digraph {
    pub fn new(n: usize, edges: &[(usize, usize)]) -> Digraph {
        let mut out = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n && a != b);
            if !out[a].contains(&b) {
                out[a].push(b);
            }
        }
        Digraph { out }
    }

    /// Directed ring 0→1→…→(n−1)→0 (strongly connected, maximally
    /// asymmetric — the classic push-sum stress test).
    pub fn ring(n: usize) -> Digraph {
        Digraph::new(n, &(0..n).map(|i| (i, (i + 1) % n)).collect::<Vec<_>>())
    }

    /// Random strongly-connected digraph: directed ring + extra arcs.
    pub fn random_strongly_connected(n: usize, p: f64, seed: u64) -> Digraph {
        // amb-lint: allow(D3, "stream root: caller-supplied seed is this generator's namespace")
        let mut rng = Pcg64::new(seed);
        let mut edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        for i in 0..n {
            for j in 0..n {
                if i != j && rng.f64() < p {
                    edges.push((i, j));
                }
            }
        }
        Digraph::new(n, &edges)
    }

    /// Make every directed edge bidirectional (view of an undirected G).
    pub fn from_undirected(topo: &crate::topology::Topology) -> Digraph {
        let n = topo.n();
        let mut edges = Vec::new();
        for i in 0..n {
            for &j in topo.neighbors(i) {
                edges.push((i, j));
            }
        }
        Digraph::new(n, &edges)
    }

    pub fn n(&self) -> usize {
        self.out.len()
    }

    pub fn out_degree(&self, i: usize) -> usize {
        self.out[i].len()
    }

    /// Subgraph induced by `active`, keeping the node indexing: arcs
    /// touching an inactive endpoint are dropped, so inactive nodes keep
    /// all their mass to themselves (share 1) and active nodes split
    /// only among active out-neighbours — the push-sum face of the churn
    /// semantics in [`crate::topology::Topology::induced`].
    pub fn induced(&self, active: &[bool]) -> Digraph {
        assert_eq!(active.len(), self.n(), "active mask must cover every node");
        let out = self
            .out
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if active[i] {
                    l.iter().copied().filter(|&j| active[j]).collect()
                } else {
                    Vec::new()
                }
            })
            .collect();
        Digraph { out }
    }
}

/// Push-sum state for n nodes over d-dim values.  Values and scratch
/// live in flat [`NodeMatrixF64`] arenas (the f64-accumulation twin of
/// the consensus message arena): rounds are allocation-free and flip
/// the two buffers in O(1).
///
/// Rounds run in *gather* form over an in-edge CSR built once at
/// construction: destination row j sums `share_i · x_i` over its
/// in-neighbours (self included) in ascending-source order — the exact
/// per-element op sequence of the textbook scatter loop (each source i,
/// in ascending order, adds its share to every out-neighbour), so the
/// rewrite is bit-identical (pinned by
/// `tests::gather_round_matches_legacy_scatter_bitwise`).  Gather makes
/// every destination row independent, so rounds row-partition across
/// the worker pool like the averaging kernels.
pub struct PushSum {
    g: Digraph,
    /// In-edge CSR over destinations: row j's sources (ascending, self
    /// included) and their shares 1/(1 + out_degree(source)).
    in_ptr: Vec<usize>,
    in_src: Vec<u32>,
    in_share: Vec<f64>,
    /// values x_i (n × d arena)
    x: NodeMatrixF64,
    /// weights φ_i
    phi: Vec<f64>,
    // scratch
    x_next: NodeMatrixF64,
    phi_next: Vec<f64>,
}

/// (Re)build the in-edge CSR of `g` into the caller's buffers, scanning
/// sources in ascending order so every destination's list is ascending
/// by construction and gather accumulation replays the scatter loop's op
/// order.  Shared by construction and the per-active-set rebuild, so the
/// two paths cannot drift; buffers are cleared and refilled in place
/// (steady-state capacity, no per-epoch allocation once warm).
fn build_in_csr(g: &Digraph, in_ptr: &mut Vec<usize>, in_src: &mut Vec<u32>, in_share: &mut Vec<f64>) {
    let n = g.n();
    let mut in_lists: Vec<Vec<u32>> = vec![Vec::new(); n];
    for i in 0..n {
        in_lists[i].push(i as u32); // self share
        for &j in &g.out[i] {
            in_lists[j].push(i as u32);
        }
    }
    in_ptr.clear();
    in_src.clear();
    in_share.clear();
    in_ptr.push(0);
    for list in &in_lists {
        for &i in list {
            in_src.push(i);
            in_share.push(1.0 / (1.0 + g.out_degree(i as usize) as f64));
        }
        in_ptr.push(in_src.len());
    }
}

/// Build the in-edge CSR of `g.induced(active)` straight from the base
/// digraph + mask — O(n + E) with flat temporaries only: neither the
/// induced [`Digraph`] (one heap list per node, rebuilt per churn epoch)
/// nor a per-destination list-of-lists is materialised.  Count pass,
/// prefix sum, then an ascending-source fill pass, so each destination's
/// source list is ascending exactly as [`build_in_csr`] produces it;
/// shares use INDUCED out-degrees (inactive source ⇒ degree 0 ⇒ self
/// share 1).  Pinned bitwise against the composed build by
/// `tests::induced_in_csr_matches_materialised_build_bitwise`.
fn build_induced_in_csr(
    g: &Digraph,
    active: &[bool],
    in_ptr: &mut Vec<usize>,
    in_src: &mut Vec<u32>,
    in_share: &mut Vec<f64>,
) {
    let n = g.n();
    assert_eq!(active.len(), n, "active mask must cover every node");
    let deg: Vec<usize> = (0..n)
        .map(|i| {
            if active[i] {
                g.out[i].iter().filter(|&&j| active[j]).count()
            } else {
                0
            }
        })
        .collect();
    // in-degree counts: every node keeps its self edge
    let mut count = vec![1usize; n];
    for i in 0..n {
        if active[i] {
            for &j in &g.out[i] {
                if active[j] {
                    count[j] += 1;
                }
            }
        }
    }
    in_ptr.clear();
    in_ptr.push(0);
    let mut total = 0usize;
    for &c in &count {
        total += c;
        in_ptr.push(total);
    }
    in_src.clear();
    in_src.resize(total, 0);
    in_share.clear();
    in_share.resize(total, 0.0);
    let mut cur: Vec<usize> = in_ptr[..n].to_vec();
    for i in 0..n {
        let share = 1.0 / (1.0 + deg[i] as f64);
        in_src[cur[i]] = i as u32;
        in_share[cur[i]] = share;
        cur[i] += 1;
        if active[i] {
            for &j in &g.out[i] {
                if active[j] {
                    in_src[cur[j]] = i as u32;
                    in_share[cur[j]] = share;
                    cur[j] += 1;
                }
            }
        }
    }
}

impl PushSum {
    /// Initialise from the per-node value arena.
    pub fn new(g: Digraph, values: &NodeMatrix) -> PushSum {
        let n = g.n();
        assert_eq!(values.n(), n);
        let d = values.d();
        let mut x = NodeMatrixF64::new(n, d);
        for i in 0..n {
            for (xv, &v) in x.row_mut(i).iter_mut().zip(values.row(i)) {
                *xv = v as f64;
            }
        }
        let mut in_ptr = Vec::with_capacity(n + 1);
        let mut in_src = Vec::new();
        let mut in_share = Vec::new();
        build_in_csr(&g, &mut in_ptr, &mut in_src, &mut in_share);
        PushSum {
            g,
            in_ptr,
            in_src,
            in_share,
            x,
            phi: vec![1.0; n],
            x_next: NodeMatrixF64::new(n, d),
            phi_next: vec![0.0; n],
        }
    }

    /// Restrict subsequent rounds to the `active` subgraph: the in-edge
    /// CSR is rebuilt in place over the induced arc set while (x, φ)
    /// carry over — an inactive node's only in-edge is its self-share 1,
    /// so it holds its state bit-for-bit and a rejoining node re-enters
    /// the ratio average with whatever it held (churn semantics,
    /// DESIGN.md §churn).  Total mass over the whole vertex set is still
    /// conserved, so the active-set mass is too.  The build reads the
    /// base digraph + mask directly ([`build_induced_in_csr`]) — no
    /// induced [`Digraph`] is materialised on the per-epoch churn path.
    pub fn set_active(&mut self, active: &[bool]) {
        build_induced_in_csr(
            &self.g,
            active,
            &mut self.in_ptr,
            &mut self.in_src,
            &mut self.in_share,
        );
    }

    /// Undo [`PushSum::set_active`]: rebuild the CSR over the full base
    /// digraph.
    pub fn set_all_active(&mut self) {
        build_in_csr(&self.g, &mut self.in_ptr, &mut self.in_src, &mut self.in_share);
    }

    /// One synchronous push-sum round (gather form, row-partitioned).
    pub fn round(&mut self) {
        let n = self.g.n();
        let d = self.x.d();
        let x = &self.x;
        let (in_ptr, in_src, in_share) = (&self.in_ptr, &self.in_src, &self.in_share);
        if d > 0 {
            crate::util::pool::par_chunks(self.x_next.as_mut_slice(), d, |row0, block| {
                let rows = block.len() / d;
                for r in 0..rows {
                    let j = row0 + r;
                    let out_row = &mut block[r * d..(r + 1) * d];
                    out_row.fill(0.0);
                    for e in in_ptr[j]..in_ptr[j + 1] {
                        let share = in_share[e];
                        let xi = x.row(in_src[e] as usize);
                        for (o, &v) in out_row.iter_mut().zip(xi) {
                            *o += share * v;
                        }
                    }
                }
            });
        }
        for j in 0..n {
            let mut acc = 0.0f64;
            for e in in_ptr[j]..in_ptr[j + 1] {
                acc += in_share[e] * self.phi[in_src[e] as usize];
            }
            self.phi_next[j] = acc;
        }
        self.x.swap(&mut self.x_next);
        std::mem::swap(&mut self.phi, &mut self.phi_next);
    }

    pub fn run(&mut self, rounds: usize) {
        for _ in 0..rounds {
            self.round();
        }
    }

    /// Node i's current average estimate x_i/φ_i.
    pub fn estimate(&self, i: usize) -> Vec<f64> {
        self.x.row(i).iter().map(|&v| v / self.phi[i]).collect()
    }

    /// max_i ‖estimate_i − avg‖₂.
    pub fn max_error(&self, avg: &[f64]) -> f64 {
        let mut worst = 0.0f64;
        for i in 0..self.g.n() {
            let est = self.estimate(i);
            let mut ss = 0.0;
            for (k, &a) in avg.iter().enumerate() {
                ss += (est[k] - a) * (est[k] - a);
            }
            worst = worst.max(ss.sqrt());
        }
        worst
    }

    /// Mass-conservation diagnostics: Σφ_i must stay n, Σx must stay put.
    pub fn total_weight(&self) -> f64 {
        self.phi.iter().sum()
    }

    pub fn total_value(&self) -> Vec<f64> {
        let mut tot = vec![0.0; self.x.d()];
        for xi in self.x.rows() {
            for (t, &v) in tot.iter_mut().zip(xi) {
                *t += v;
            }
        }
        tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    fn random_values(g: &mut crate::prop::Gen, n: usize, d: usize, std: f64) -> NodeMatrix {
        let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, std)).collect();
        NodeMatrix::from_rows(&rows)
    }

    #[test]
    fn converges_on_directed_ring() {
        let n = 8;
        let mut g = crate::prop::Gen::new(1);
        let values = random_values(&mut g, n, 4, 3.0);
        let avg = values.mean_rows_f64().unwrap();
        let mut ps = PushSum::new(Digraph::ring(n), &values);
        ps.run(300);
        assert!(ps.max_error(&avg) < 1e-6, "err={}", ps.max_error(&avg));
    }

    #[test]
    fn conserves_mass_every_round() {
        forall(20, 0x50_01, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 6);
            let dg = Digraph::random_strongly_connected(n, 0.3, g.u64());
            let values = random_values(g, n, d, 2.0);
            let tot0 = PushSum::new(dg.clone(), &values).total_value();
            let mut ps = PushSum::new(dg, &values);
            for _ in 0..g.usize_in(1, 20) {
                ps.round();
                crate::prop_assert_close!(ps.total_weight(), n as f64, 1e-9);
                let tot = ps.total_value();
                for k in 0..tot.len() {
                    crate::prop_assert_close!(tot[k], tot0[k], 1e-9);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn converges_on_random_digraphs() {
        forall(15, 0x50_02, |g| {
            let n = g.usize_in(3, 15);
            let dg = Digraph::random_strongly_connected(n, 0.4, g.u64());
            let values = random_values(g, n, 3, 5.0);
            let avg = values.mean_rows_f64().unwrap();
            let mut ps = PushSum::new(dg, &values);
            ps.run(400);
            crate::prop_assert!(ps.max_error(&avg) < 1e-5, "err={}", ps.max_error(&avg));
            Ok(())
        });
    }

    #[test]
    fn matches_metropolis_on_undirected_graph() {
        // Same average, different algorithm: push-sum on the symmetrised
        // paper graph agrees with dense Metropolis mixing.
        let topo = crate::topology::Topology::paper_fig2();
        let mut g = crate::prop::Gen::new(3);
        let values = random_values(&mut g, 10, 5, 1.0);
        let avg = values.mean_rows_f64().unwrap();

        let mut ps = PushSum::new(Digraph::from_undirected(&topo), &values);
        ps.run(200);
        assert!(ps.max_error(&avg) < 1e-6);

        let mut cons = crate::consensus::Consensus::new(topo.metropolis().lazy());
        let mut msgs = values;
        cons.run(&mut msgs, 500);
        let dense_err = crate::consensus::Consensus::max_error(&msgs, &avg).unwrap();
        assert!(dense_err < 1e-3);
    }

    #[test]
    fn induced_drops_arcs_touching_inactive_nodes() {
        let g = Digraph::random_strongly_connected(8, 0.4, 3);
        let active = vec![true, false, true, true, false, true, true, true];
        let s = g.induced(&active);
        assert_eq!(s.n(), 8);
        assert_eq!(s.out_degree(1), 0);
        assert_eq!(s.out_degree(4), 0);
        for i in 0..8 {
            for &j in &s.out[i] {
                assert!(active[i] && active[j], "arc ({i},{j}) touches an inactive node");
                assert!(g.out[i].contains(&j), "induced invented arc ({i},{j})");
            }
        }
    }

    #[test]
    fn induced_in_csr_matches_materialised_build_bitwise() {
        // The mask-direct CSR build must reproduce the composed
        // `build_in_csr(&g.induced(active), ..)` exactly — same pointers,
        // same ascending source lists, bit-identical shares.
        forall(20, 0x50_06, |g| {
            let n = g.usize_in(2, 14);
            let dg = Digraph::random_strongly_connected(n, 0.4, g.u64());
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.6)).collect();

            let (mut fp, mut fs, mut fw) = (Vec::new(), Vec::new(), Vec::new());
            build_induced_in_csr(&dg, &active, &mut fp, &mut fs, &mut fw);

            let (mut sp, mut ss, mut sw) = (Vec::new(), Vec::new(), Vec::new());
            build_in_csr(&dg.induced(&active), &mut sp, &mut ss, &mut sw);

            crate::prop_assert!(fp == sp, "in_ptr mismatch");
            crate::prop_assert!(fs == ss, "in_src mismatch");
            crate::prop_assert!(
                fw.iter().zip(&sw).all(|(a, b)| a.to_bits() == b.to_bits()),
                "in_share drifted"
            );
            Ok(())
        });
    }

    #[test]
    fn set_active_freezes_inactive_and_conserves_active_mass() {
        forall(20, 0x50_04, |g| {
            let n = g.usize_in(3, 12);
            let d = g.usize_in(1, 5);
            let dg = Digraph::random_strongly_connected(n, 0.4, g.u64());
            let values = random_values(g, n, d, 2.0);
            let mut active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
            active[g.usize_in(0, n - 1)] = true;

            let mut ps = PushSum::new(dg, &values);
            ps.set_active(&active);
            let x0 = ps.x.clone();
            let phi0 = ps.phi.clone();
            for _ in 0..g.usize_in(1, 15) {
                ps.round();
                // global mass conserved (self-shares of inactive nodes
                // are 1), hence active-set mass conserved too
                crate::prop_assert_close!(ps.total_weight(), n as f64, 1e-9);
                let tot = ps.total_value();
                let tot0: Vec<f64> = (0..d)
                    .map(|k| x0.rows().map(|r| r[k]).sum::<f64>())
                    .collect();
                for k in 0..d {
                    crate::prop_assert_close!(tot[k], tot0[k], 1e-9);
                }
                // inactive rows bitwise frozen
                for i in 0..n {
                    if !active[i] {
                        crate::prop_assert!(
                            ps.phi[i].to_bits() == phi0[i].to_bits(),
                            "inactive phi[{i}] drifted"
                        );
                        for k in 0..d {
                            crate::prop_assert!(
                                ps.x.row(i)[k].to_bits() == x0.row(i)[k].to_bits(),
                                "inactive x[{i}][{k}] drifted"
                            );
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rejoin_after_set_all_active_converges_to_global_mean() {
        // Phase 1: run with node 2 absent.  Phase 2: rejoin and keep
        // pushing — mass never left the system, so estimates converge to
        // the ORIGINAL global average (absent nodes never block or skew
        // long-run progress).
        let n = 6;
        let mut g = crate::prop::Gen::new(0x50_05);
        let values = random_values(&mut g, n, 3, 2.0);
        let avg = values.mean_rows_f64().unwrap();
        let dg = Digraph::random_strongly_connected(n, 0.5, 9);
        let mut ps = PushSum::new(dg, &values);
        let mut active = vec![true; n];
        active[2] = false;
        ps.set_active(&active);
        ps.run(40);
        ps.set_all_active();
        ps.run(400);
        assert!(ps.max_error(&avg) < 1e-6, "err={}", ps.max_error(&avg));
    }

    #[test]
    fn estimate_unbiased_at_round_zero() {
        let values = NodeMatrix::from_rows(&[vec![2.0f32], vec![4.0f32]]);
        let ps = PushSum::new(Digraph::ring(2), &values);
        assert_eq!(ps.estimate(0), vec![2.0]);
        assert_eq!(ps.estimate(1), vec![4.0]);
    }

    /// The pre-pool scatter round, kept verbatim as the baseline for the
    /// gather rewrite: each source i (ascending) splits its mass among
    /// itself and its out-neighbours.
    fn legacy_scatter_round(
        g: &Digraph,
        x: &NodeMatrixF64,
        phi: &[f64],
        x_next: &mut NodeMatrixF64,
        phi_next: &mut [f64],
    ) {
        let n = g.n();
        x_next.fill(0.0);
        phi_next.fill(0.0);
        for i in 0..n {
            let share = 1.0 / (1.0 + g.out_degree(i) as f64);
            for (o, &v) in x_next.row_mut(i).iter_mut().zip(x.row(i)) {
                *o += share * v;
            }
            phi_next[i] += share * phi[i];
            for &j in &g.out[i] {
                for (o, &v) in x_next.row_mut(j).iter_mut().zip(x.row(i)) {
                    *o += share * v;
                }
                phi_next[j] += share * phi[i];
            }
        }
    }

    /// Bitwise pin: the in-edge-CSR gather round must reproduce the
    /// legacy scatter round EXACTLY — per destination element, adds
    /// apply in ascending-source order in both forms, so row
    /// partitioning over the pool cannot perturb any seeded run.
    #[test]
    fn gather_round_matches_legacy_scatter_bitwise() {
        forall(15, 0x50_03, |g| {
            let n = g.usize_in(2, 14);
            let d = g.usize_in(1, 9);
            let dg = Digraph::random_strongly_connected(n, 0.4, g.u64());
            let values = random_values(g, n, d, 3.0);
            let rounds = g.usize_in(1, 8);

            let mut ps = PushSum::new(dg.clone(), &values);
            ps.run(rounds);

            // legacy: replay the same rounds with the scatter kernel
            let mut x = NodeMatrixF64::new(n, d);
            for i in 0..n {
                for (xv, &v) in x.row_mut(i).iter_mut().zip(values.row(i)) {
                    *xv = v as f64;
                }
            }
            let mut phi = vec![1.0f64; n];
            let mut x_next = NodeMatrixF64::new(n, d);
            let mut phi_next = vec![0.0f64; n];
            for _ in 0..rounds {
                legacy_scatter_round(&dg, &x, &phi, &mut x_next, &mut phi_next);
                x.swap(&mut x_next);
                std::mem::swap(&mut phi, &mut phi_next);
            }

            for i in 0..n {
                crate::prop_assert!(
                    ps.phi[i].to_bits() == phi[i].to_bits(),
                    "phi[{i}]: gather={} scatter={}",
                    ps.phi[i],
                    phi[i]
                );
                for k in 0..d {
                    crate::prop_assert!(
                        ps.x.row(i)[k].to_bits() == x.row(i)[k].to_bits(),
                        "x[{i}][{k}]: gather={} scatter={}",
                        ps.x.row(i)[k],
                        x.row(i)[k]
                    );
                }
            }
            Ok(())
        });
    }
}
