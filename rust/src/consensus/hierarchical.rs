//! Two-level (hierarchical) consensus for large n (ROADMAP item 2).
//!
//! Flat gossip needs Θ(1/(1−λ₂)) rounds, and λ₂ → 1 as sparse graphs
//! grow — at n ≈ 10⁵ a ring needs millions of rounds per epoch.  The
//! standard systems answer is hierarchy: partition the nodes into
//! `shards` contiguous blocks, gossip INSIDE each shard (cheap: small
//! diameter), then let one aggregator per shard exchange shard-level
//! aggregates on a ring of shards, and broadcast the resulting
//! correction back to its members.  One epoch of
//! [`HierarchicalConsensus::run`] is:
//!
//! 1. **intra**: `intra_rounds` of induced-subgraph gossip over the base
//!    topology MINUS every cross-shard edge ([`InducedConsensus`] — so
//!    churn composes exactly like the flat engine: inactive nodes are
//!    isolated and hold their rows bit-for-bit);
//! 2. **aggregate**: per-shard f64 means over the ACTIVE members (the
//!    shard mean is invariant under step 1 — intra mixing is doubly
//!    stochastic — so the aggregator's estimate is exact);
//! 3. **inter**: `inter_rounds` of serial f64 mixing of the shard means
//!    over the lazy WEIGHTED Metropolis ring of non-empty shards.  The
//!    chain targets π_s ∝ A_s (the shard's active count):
//!    `Q_st = (1/d_s)·min(1, A_t/A_s)` for ring neighbours, made lazy as
//!    (Q+I)/2.  Rows sum to 1 and detailed balance `A_s Q_st = A_t Q_ts`
//!    holds, so `Σ_s A_s v_s` is INVARIANT every round and the means
//!    converge to the global active mean `Σ A_s v_s / Σ A_s`;
//! 4. **broadcast**: every active node shifts by its shard's mean-shift,
//!    `y_i += v_s(after) − v_s(before)`, computed in f64 and cast back
//!    to f32.  The correction sums to zero across the active set (step
//!    3's invariant), so the GLOBAL active mean is conserved to f64/f32
//!    rounding; intra-shard disagreement left by finite `intra_rounds`
//!    is preserved, not papered over — `inter_rounds = 0` is pure
//!    shard-local gossip, and `shards = 1` is bitwise the flat engine.
//!
//! Everything here is O(n + E + shards·inter_rounds·d) per epoch; the
//! inter stage runs serially on the main thread (shard counts are tiny
//! next to n), so the threads=1 ≡ threads=k bitwise contract holds via
//! the intra stage's pooled-but-order-fixed kernel alone.

use crate::consensus::churn::InducedConsensus;
use crate::topology::Topology;
use crate::util::matrix::NodeMatrix;

/// Two-level consensus: intra-shard induced gossip + inter-shard
/// aggregator exchange.  See the module docs for the epoch algebra.
pub struct HierarchicalConsensus {
    n: usize,
    shards: usize,
    /// node → shard id (contiguous balanced blocks).
    shard_of: Vec<usize>,
    /// shard → `[lo, hi)` node range.
    bounds: Vec<(usize, usize)>,
    /// Induced-gossip engine over the base topology minus cross-shard
    /// edges (shard-local mixing that composes with churn).
    intra: InducedConsensus,
    /// Scratch: per-shard active counts, flattened `[shards × d]` mean
    /// buffers (current / next / initial) — reused across epochs.
    counts: Vec<usize>,
    v: Vec<f64>,
    v_next: Vec<f64>,
    v0: Vec<f64>,
}

impl HierarchicalConsensus {
    /// Partition `topo`'s nodes into `shards` contiguous balanced blocks
    /// (the first `n % shards` blocks get one extra node) and build the
    /// shard-local intra topology.  `shards` is clamped to `[1, n]`.
    pub fn new(topo: &Topology, shards: usize) -> HierarchicalConsensus {
        let n = topo.n();
        let shards = shards.clamp(1, n);
        let base = n / shards;
        let extra = n % shards;
        let mut bounds = Vec::with_capacity(shards);
        let mut shard_of = vec![0usize; n];
        let mut lo = 0usize;
        for s in 0..shards {
            let hi = lo + base + usize::from(s < extra);
            bounds.push((lo, hi));
            for node in shard_of.iter_mut().take(hi).skip(lo) {
                *node = s;
            }
            lo = hi;
        }
        // Shard-local subgraph: drop every cross-shard edge.
        let mut edges = Vec::new();
        for i in 0..n {
            for &j in topo.neighbors(i) {
                if i < j && shard_of[i] == shard_of[j] {
                    edges.push((i, j));
                }
            }
        }
        let intra_topo = Topology::from_edges(n, &edges);
        HierarchicalConsensus {
            n,
            shards,
            shard_of,
            bounds,
            intra: InducedConsensus::new(intra_topo),
            counts: vec![0; shards],
            v: Vec::new(),
            v_next: Vec::new(),
            v0: Vec::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn shard_of(&self, i: usize) -> usize {
        self.shard_of[i]
    }

    /// One consensus phase: intra gossip, aggregate, inter exchange,
    /// broadcast.  Inactive rows come back bitwise untouched.
    pub fn run(
        &mut self,
        msgs: &mut NodeMatrix,
        intra_rounds: usize,
        inter_rounds: usize,
        active: &[bool],
    ) {
        let n = self.n;
        assert_eq!(msgs.n(), n);
        assert_eq!(active.len(), n, "active mask must cover every node");
        let d = msgs.d();

        // 1. intra-shard gossip (induced by the churn mask).
        self.intra.run(msgs, intra_rounds, active);
        if inter_rounds == 0 {
            return;
        }

        // 2. per-shard active counts + f64 means (ascending node order
        // within each shard — the serial op sequence).
        for (s, &(lo, hi)) in self.bounds.iter().enumerate() {
            self.counts[s] = (lo..hi).filter(|&i| active[i]).count();
        }
        let ranks: Vec<usize> =
            (0..self.shards).filter(|&s| self.counts[s] > 0).collect();
        let m = ranks.len();
        if m < 2 {
            return; // nothing to exchange with
        }
        self.v.clear();
        self.v.resize(m * d, 0.0);
        for (r, &s) in ranks.iter().enumerate() {
            let (lo, hi) = self.bounds[s];
            let acc = &mut self.v[r * d..(r + 1) * d];
            for i in lo..hi {
                if active[i] {
                    for (a, &x) in acc.iter_mut().zip(msgs.row(i)) {
                        *a += x as f64;
                    }
                }
            }
            let c = self.counts[s] as f64;
            for a in acc.iter_mut() {
                *a /= c;
            }
        }
        self.v0.clear();
        self.v0.extend_from_slice(&self.v);

        // 3. inter exchange on the lazy weighted-Metropolis ring of the
        // m non-empty shards (π_s ∝ A_s; Σ A_s v_s invariant).  Rows are
        // built once per call — (col, weight) in ascending-rank order —
        // then applied serially in f64.
        let rows = self.inter_ring_rows(&ranks, m);
        self.v_next.clear();
        self.v_next.resize(m * d, 0.0);
        for _ in 0..inter_rounds {
            for (r, row) in rows.iter().enumerate() {
                let out = &mut self.v_next[r * d..(r + 1) * d];
                out.fill(0.0);
                for &(c, w) in row {
                    let src = &self.v[c * d..(c + 1) * d];
                    for (o, &x) in out.iter_mut().zip(src) {
                        *o += w * x;
                    }
                }
            }
            std::mem::swap(&mut self.v, &mut self.v_next);
        }

        // 4. broadcast the shard's mean-shift to its active members.
        let mut rank_of = vec![usize::MAX; self.shards];
        for (r, &s) in ranks.iter().enumerate() {
            rank_of[s] = r;
        }
        for i in 0..n {
            if !active[i] {
                continue;
            }
            let r = rank_of[self.shard_of[i]];
            let (after, before) =
                (&self.v[r * d..(r + 1) * d], &self.v0[r * d..(r + 1) * d]);
            for (k, y) in msgs.row_mut(i).iter_mut().enumerate() {
                *y = (*y as f64 + (after[k] - before[k])) as f32;
            }
        }
    }

    /// The lazy weighted-Metropolis ring rows over `m` non-empty shards:
    /// row r is a sorted `(rank, weight)` list.  Target weights are the
    /// active counts A; `Q_st = (1/d_s)·min(1, A_t/A_s)` for ring
    /// neighbours (`d_s` = 1 when m = 2, else 2), then (Q+I)/2, so rows
    /// sum to 1, `A_s Q_st = A_t Q_ts` (detailed balance), and every
    /// diagonal is ≥ 0.5 (aperiodic — an unweighted even ring would
    /// oscillate forever without the lazy step).
    fn inter_ring_rows(&self, ranks: &[usize], m: usize) -> Vec<Vec<(usize, f64)>> {
        debug_assert!(m >= 2);
        let deg = if m == 2 { 1.0 } else { 2.0 };
        let mut rows = Vec::with_capacity(m);
        for r in 0..m {
            let a_r = self.counts[ranks[r]] as f64;
            let mut nbrs = vec![(r + 1) % m, (r + m - 1) % m];
            nbrs.sort_unstable();
            nbrs.dedup();
            nbrs.retain(|&c| c != r);
            let mut row: Vec<(usize, f64)> = Vec::with_capacity(nbrs.len() + 1);
            let mut off = 0.0f64;
            for &c in &nbrs {
                let a_c = self.counts[ranks[c]] as f64;
                let q = (1.0 / deg) * (a_c / a_r).min(1.0);
                off += q;
                row.push((c, q * 0.5)); // lazy halving
            }
            let diag = (1.0 - off) * 0.5 + 0.5;
            row.push((r, diag));
            row.sort_unstable_by_key(|&(c, _)| c);
            rows.push(row);
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    fn random_msgs(g: &mut crate::prop::Gen, n: usize, d: usize) -> NodeMatrix {
        let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect();
        NodeMatrix::from_rows(&rows)
    }

    fn random_active(g: &mut crate::prop::Gen, n: usize) -> Vec<bool> {
        let mut active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
        let forced = g.usize_in(0, n - 1);
        active[forced] = true;
        active
    }

    #[test]
    fn partition_is_contiguous_and_balanced() {
        let topo = Topology::ring(10);
        let h = HierarchicalConsensus::new(&topo, 3);
        assert_eq!(h.shards(), 3);
        // 10 = 4 + 3 + 3, contiguous
        let sizes: Vec<usize> =
            (0..3).map(|s| (0..10).filter(|&i| h.shard_of(i) == s).count()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        for i in 1..10 {
            assert!(h.shard_of(i) >= h.shard_of(i - 1), "blocks must be contiguous");
        }
        // shards > n clamps to n (singleton shards)
        let h1 = HierarchicalConsensus::new(&Topology::ring(4), 99);
        assert_eq!(h1.shards(), 4);
    }

    #[test]
    fn single_shard_is_the_flat_engine_bitwise() {
        // shards = 1 keeps every edge and never builds an inter ring, so
        // the result is bit-for-bit the flat induced-gossip engine.
        forall(15, 0x41_01, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 8);
            let topo = Topology::erdos_connected(n, 0.4, g.u64());
            let active = random_active(g, n);
            let rounds = g.usize_in(0, 6);
            let msgs0 = random_msgs(g, n, d);

            let mut flat = InducedConsensus::new(topo.clone());
            let mut a = msgs0.clone();
            flat.run(&mut a, rounds, &active);

            let mut h = HierarchicalConsensus::new(&topo, 1);
            let mut b = msgs0;
            h.run(&mut b, rounds, 3, &active);

            for i in 0..n {
                for k in 0..d {
                    crate::prop_assert!(
                        a.row(i)[k].to_bits() == b.row(i)[k].to_bits(),
                        "({i},{k}) flat={} hier={}",
                        a.row(i)[k],
                        b.row(i)[k]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_inter_rounds_is_pure_intra_gossip_bitwise() {
        // inter_rounds = 0 must be exactly shard-local induced gossip —
        // no broadcast, no hidden averaging.
        forall(15, 0x41_02, |g| {
            let n = g.usize_in(4, 16);
            let d = g.usize_in(1, 6);
            let shards = g.usize_in(2, 4);
            let topo = Topology::erdos_connected(n, 0.5, g.u64());
            let active = random_active(g, n);
            let rounds = g.usize_in(1, 5);
            let msgs0 = random_msgs(g, n, d);

            let mut h = HierarchicalConsensus::new(&topo, shards);
            let mut a = msgs0.clone();
            h.run(&mut a, rounds, 0, &active);

            // reference: induced gossip over the shard-local subgraph
            let intra_edges: Vec<(usize, usize)> = (0..n)
                .flat_map(|i| {
                    let h = &h;
                    topo.neighbors(i)
                        .iter()
                        .filter(move |&&j| i < j && h.shard_of(i) == h.shard_of(j))
                        .map(move |&j| (i, j))
                })
                .collect();
            let mut flat = InducedConsensus::new(Topology::from_edges(n, &intra_edges));
            let mut b = msgs0;
            flat.run(&mut b, rounds, &active);

            for i in 0..n {
                crate::prop_assert!(a.row(i) == b.row(i), "row {i} differs");
            }
            Ok(())
        });
    }

    #[test]
    fn conserves_global_active_mean() {
        // The tentpole invariant: across random topologies, shard
        // counts, churn masks, and round budgets, the ACTIVE-set mean is
        // conserved (intra mixing is doubly stochastic; the inter
        // correction sums to zero by the weighted chain's π-invariance).
        forall(30, 0x41_03, |g| {
            let n = g.usize_in(4, 20);
            let d = g.usize_in(1, 6);
            let shards = g.usize_in(1, 5);
            let topo = Topology::erdos_connected(n, 0.4, g.u64());
            let active = random_active(g, n);
            let msgs0 = random_msgs(g, n, d);
            let before = InducedConsensus::active_mean_f64(&msgs0, &active).unwrap();

            let mut h = HierarchicalConsensus::new(&topo, shards);
            let mut msgs = msgs0;
            h.run(&mut msgs, g.usize_in(0, 8), g.usize_in(0, 12), &active);

            let after = InducedConsensus::active_mean_f64(&msgs, &active).unwrap();
            for k in 0..d {
                crate::prop_assert_close!(before[k], after[k], 1e-4);
            }
            Ok(())
        });
    }

    #[test]
    fn inactive_rows_bitwise_held() {
        forall(20, 0x41_04, |g| {
            let n = g.usize_in(4, 16);
            let shards = g.usize_in(1, 4);
            let topo = Topology::erdos_connected(n, 0.5, g.u64());
            let active = random_active(g, n);
            let msgs0 = random_msgs(g, n, 4);
            let mut h = HierarchicalConsensus::new(&topo, shards);
            let mut msgs = msgs0.clone();
            h.run(&mut msgs, g.usize_in(0, 5), g.usize_in(0, 5), &active);
            for i in 0..n {
                if !active[i] {
                    crate::prop_assert!(msgs.row(i) == msgs0.row(i), "inactive row {i} drifted");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn converges_to_global_active_mean() {
        // Enough intra AND inter rounds drive every active node to the
        // GLOBAL active mean — the hierarchy is consensus, not just
        // shard-local averaging.  Complete base graph keeps every shard
        // block internally connected under this mask.
        let n = 12;
        let topo = Topology::complete(n);
        let mut g = crate::prop::Gen::new(0x41_05);
        let msgs0 = random_msgs(&mut g, n, 4);
        let mut active = vec![true; n];
        active[2] = false;
        active[9] = false;
        let want = InducedConsensus::active_mean_f64(&msgs0, &active).unwrap();

        let mut h = HierarchicalConsensus::new(&topo, 3);
        let mut msgs = msgs0;
        h.run(&mut msgs, 200, 400, &active);
        for i in 0..n {
            if active[i] {
                for k in 0..4 {
                    assert!(
                        (msgs.row(i)[k] as f64 - want[k]).abs() < 1e-4,
                        "node {i} col {k}: {} vs {}",
                        msgs.row(i)[k],
                        want[k]
                    );
                }
            }
        }
    }

    #[test]
    fn inter_ring_rows_are_stochastic_and_detailed_balanced() {
        // Unequal shard populations: rows sum to 1 and A_s·Q_st = A_t·Q_ts
        // (the invariance that makes the broadcast conserve the mean).
        let topo = Topology::ring(10);
        let mut h = HierarchicalConsensus::new(&topo, 4); // blocks 3,3,2,2
        let active = vec![true; 10];
        // populate counts the way run() does
        for (s, &(lo, hi)) in h.bounds.clone().iter().enumerate() {
            h.counts[s] = (lo..hi).filter(|&i| active[i]).count();
        }
        let ranks: Vec<usize> = (0..4).collect();
        let rows = h.inter_ring_rows(&ranks, 4);
        let q = |r: usize, c: usize| -> f64 {
            rows[r].iter().find(|&&(cc, _)| cc == c).map_or(0.0, |&(_, w)| w)
        };
        for (r, row) in rows.iter().enumerate() {
            let sum: f64 = row.iter().map(|&(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-12, "row {r} sums to {sum}");
            assert!(q(r, r) >= 0.5, "lazy diagonal must dominate");
        }
        for r in 0..4 {
            for c in 0..4 {
                let lhs = h.counts[r] as f64 * q(r, c);
                let rhs = h.counts[c] as f64 * q(c, r);
                assert!((lhs - rhs).abs() < 1e-12, "detailed balance ({r},{c})");
            }
        }
    }
}
