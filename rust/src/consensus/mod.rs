//! Averaging consensus engine (paper Sec. 3, consensus phase).
//!
//! Each node i starts from message m_i⁽⁰⁾ = n·b_i(t)·[z_i(t) + g_i(t)] and
//! runs synchronous rounds m⁽ᵏ⁾ = P m⁽ᵏ⁻¹⁾; after r_i(t) rounds the node
//! sets z_i(t+1) = m_i^(r_i)/b(t).  Perfect consensus would give every
//! node the average (4); finite rounds leave error ξ_i(t) bounded by
//! Lemma 1.
//!
//! Messages live in a [`NodeMatrix`] arena (one flat `[n × d]` buffer,
//! DESIGN.md §1 "data plane"); a gossip round is one pass of the blocked
//! flat kernel [`MixMatrix::mix_into`] followed by an O(1) buffer flip —
//! zero heap allocations after the first `run` sizes the scratch arena.
//! Each round row-partitions its output across the worker pool
//! (`util::pool`, DESIGN.md §1 "threading model") with per-row op order
//! untouched, so results are bit-identical at any thread count.

pub mod churn;
pub mod hierarchical;
pub mod push_sum;
pub mod sparse;

use anyhow::{bail, Result};

use crate::topology::MixMatrix;
use crate::util::matrix::NodeMatrix;

/// Dense synchronous consensus over an arena of row-stacked f32 messages.
pub struct Consensus {
    p: MixMatrix,
    /// Scratch arena double-buffered against the caller's messages; sized
    /// on first use, reused allocation-free from then on.
    scratch: NodeMatrix,
}

impl Consensus {
    pub fn new(p: MixMatrix) -> Consensus {
        Consensus { p, scratch: NodeMatrix::new(0, 0) }
    }

    pub fn n(&self) -> usize {
        self.p.n()
    }

    pub fn matrix(&self) -> &MixMatrix {
        &self.p
    }

    fn ensure_scratch(&mut self, n: usize, d: usize) {
        if self.scratch.n() != n || self.scratch.d() != d {
            self.scratch.reset(n, d);
        }
    }

    /// Run `rounds` synchronous rounds in place (mix into scratch, flip
    /// buffers — no per-round copies or allocations).
    pub fn run(&mut self, msgs: &mut NodeMatrix, rounds: usize) {
        let n = self.p.n();
        assert_eq!(msgs.n(), n);
        self.ensure_scratch(n, msgs.d());
        for _ in 0..rounds {
            self.p.mix_into(msgs, &mut self.scratch);
            msgs.swap(&mut self.scratch);
        }
    }

    /// Run with *per-node* round counts r_i (nodes stop listening after
    /// their budget; stragglers in the communication phase).  Nodes with
    /// fewer rounds keep their last value — this models the paper's
    /// variable r_i(t) within a fixed T_c.
    ///
    /// Implementation note: we run max(r_i) global rounds, flip buffers,
    /// and restore only the FROZEN rows from the pre-mix buffer — per
    /// round the copy cost is proportional to exhausted nodes (zero in
    /// early rounds), not active ones.  Freezing breaks exact mass
    /// conservation (as it does in the real protocol when a node drops
    /// out early); Lemma 1's error bound still applies to each node's
    /// own estimate.
    pub fn run_per_node(&mut self, msgs: &mut NodeMatrix, rounds: &[usize]) {
        let n = self.p.n();
        assert_eq!(msgs.n(), n);
        assert_eq!(rounds.len(), n);
        let rmax = rounds.iter().copied().max().unwrap_or(0);
        self.ensure_scratch(n, msgs.d());
        for k in 0..rmax {
            self.p.mix_into(msgs, &mut self.scratch);
            msgs.swap(&mut self.scratch);
            // post-swap, scratch holds the pre-mix values: un-mix the
            // rows whose budget is spent
            for i in 0..n {
                if rounds[i] <= k {
                    msgs.row_mut(i).copy_from_slice(self.scratch.row(i));
                }
            }
        }
    }

    /// Exact average of the initial messages (what ε-perfect consensus
    /// would deliver to every node), accumulated in f64.  Errors on an
    /// empty arena instead of index-panicking.
    pub fn exact_average(msgs: &NodeMatrix) -> Result<Vec<f64>> {
        match msgs.mean_rows_f64() {
            Some(avg) => Ok(avg),
            None => bail!("exact_average: message arena has no rows (n = 0)"),
        }
    }

    /// max_i ‖m_i − avg‖₂ — the consensus error ε achieved.  Errors on an
    /// empty arena (a silent 0.0 would read as perfect consensus).
    pub fn max_error(msgs: &NodeMatrix, avg: &[f64]) -> Result<f64> {
        if msgs.n() == 0 {
            bail!("max_error: message arena has no rows (n = 0)");
        }
        assert_eq!(msgs.d(), avg.len(), "average length must match message width");
        let mut worst = 0.0f64;
        for m in msgs.rows() {
            let mut ss = 0.0f64;
            for (k, &a) in avg.iter().enumerate() {
                let diff = m[k] as f64 - a;
                ss += diff * diff;
            }
            worst = worst.max(ss.sqrt());
        }
        Ok(worst)
    }
}

/// Lemma 1 round count: r ≥ ⌈ log(2√n (1 + 2L/ε)) / (1 − λ₂(P)) ⌉
/// guarantees additive accuracy ε given Lipschitz constant L.
pub fn rounds_for_accuracy(n: usize, lambda2: f64, lipschitz: f64, eps: f64) -> usize {
    assert!(eps > 0.0 && lambda2 < 1.0);
    let num = (2.0 * (n as f64).sqrt() * (1.0 + 2.0 * lipschitz / eps)).ln();
    (num / (1.0 - lambda2)).ceil().max(1.0) as usize
}

/// Predicted error after r rounds from the spectral contraction:
/// ‖m⁽ʳ⁾ − avg‖ ≤ λ₂ʳ ‖m⁽⁰⁾ − avg‖ (symmetric P).
pub fn predicted_error(initial_error: f64, lambda2: f64, rounds: usize) -> f64 {
    initial_error * lambda2.powi(rounds as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::topology::Topology;

    fn random_msgs(g: &mut crate::prop::Gen, n: usize, d: usize) -> NodeMatrix {
        let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect();
        NodeMatrix::from_rows(&rows)
    }

    #[test]
    fn converges_to_average() {
        forall(20, 0xC0_01, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 8);
            let t = Topology::erdos_connected(n, 0.5, g.u64());
            let mut cons = Consensus::new(t.metropolis().lazy());
            let mut msgs = random_msgs(g, n, d);
            let avg = Consensus::exact_average(&msgs).unwrap();
            cons.run(&mut msgs, 400);
            let err = Consensus::max_error(&msgs, &avg).unwrap();
            crate::prop_assert!(err < 1e-3, "err={}", err);
            Ok(())
        });
    }

    #[test]
    fn error_contracts_at_lambda2_rate() {
        let t = Topology::ring(8);
        let p = t.metropolis().lazy();
        let l2 = p.lambda2();
        let mut cons = Consensus::new(p);
        let mut g = crate::prop::Gen::new(1);
        let mut msgs = random_msgs(&mut g, 8, 4);
        let avg = Consensus::exact_average(&msgs).unwrap();
        let e0 = Consensus::max_error(&msgs, &avg).unwrap();
        cons.run(&mut msgs, 25);
        let e25 = Consensus::max_error(&msgs, &avg).unwrap();
        // within 2x of the spectral prediction (max-norm vs 2-norm slack)
        let bound = predicted_error(e0, l2, 25) * (8f64).sqrt() * 2.0;
        assert!(e25 <= bound, "e25={e25} bound={bound}");
    }

    #[test]
    fn conservation_under_uniform_rounds() {
        forall(20, 0xC0_02, |g| {
            let n = g.usize_in(2, 10);
            let d = g.usize_in(1, 6);
            let t = Topology::erdos_connected(n, 0.4, g.u64());
            let mut cons = Consensus::new(t.metropolis());
            let mut msgs = random_msgs(g, n, d);
            let before = Consensus::exact_average(&msgs).unwrap();
            cons.run(&mut msgs, g.usize_in(0, 30));
            let after = Consensus::exact_average(&msgs).unwrap();
            for k in 0..d {
                crate::prop_assert!((before[k] - after[k]).abs() < 1e-3);
            }
            Ok(())
        });
    }

    #[test]
    fn zero_rounds_is_identity() {
        let t = Topology::ring(5);
        let mut cons = Consensus::new(t.metropolis());
        let mut g = crate::prop::Gen::new(2);
        let msgs0 = random_msgs(&mut g, 5, 3);
        let mut msgs = msgs0.clone();
        cons.run(&mut msgs, 0);
        assert_eq!(msgs, msgs0);
    }

    #[test]
    fn per_node_rounds_freeze_early_stoppers() {
        let t = Topology::ring(6);
        let mut cons = Consensus::new(t.metropolis().lazy());
        let mut g = crate::prop::Gen::new(3);
        let msgs0 = random_msgs(&mut g, 6, 4);

        // node 0 does zero rounds: keeps the initial message
        let mut msgs = msgs0.clone();
        cons.run_per_node(&mut msgs, &[0, 5, 5, 5, 5, 5]);
        assert_eq!(msgs.row(0), msgs0.row(0));
        assert_ne!(msgs.row(1), msgs0.row(1));

        // equal per-node budgets == uniform run
        let mut a = msgs0.clone();
        cons.run_per_node(&mut a, &[4; 6]);
        let mut b = msgs0.clone();
        cons.run(&mut b, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn per_node_freezing_is_per_row_exact() {
        // A frozen node's row must be BITWISE the value it held when its
        // budget ran out, while still feeding neighbours as a sender.
        let t = Topology::ring(5);
        let mut cons = Consensus::new(t.metropolis().lazy());
        let mut g = crate::prop::Gen::new(0xC0_05);
        let msgs0 = random_msgs(&mut g, 5, 3);

        // Reference: node 2's value after exactly 2 uniform rounds.
        let mut two = msgs0.clone();
        cons.run(&mut two, 2);

        let mut m = msgs0.clone();
        cons.run_per_node(&mut m, &[6, 6, 2, 6, 6]);
        assert_eq!(m.row(2), two.row(2), "frozen row drifted");
        // the others kept mixing past round 2
        for i in [0usize, 1, 3, 4] {
            assert_ne!(m.row(i), two.row(i), "node {i} should have kept mixing");
        }
    }

    #[test]
    fn more_per_node_rounds_no_worse() {
        // A node that listens longer ends closer to the average.
        let t = Topology::paper_fig2();
        let p = t.metropolis().lazy();
        let mut cons = Consensus::new(p);
        let mut g = crate::prop::Gen::new(4);
        let msgs0 = random_msgs(&mut g, 10, 8);
        let avg = Consensus::exact_average(&msgs0).unwrap();
        let mut err_of = |r: usize| {
            let mut m = msgs0.clone();
            let mut rounds = vec![r; 10];
            rounds[3] = r; // probe node 3
            cons.run_per_node(&mut m, &rounds);
            let mut ss = 0.0f64;
            for (k, &a) in avg.iter().enumerate() {
                let d = m.row(3)[k] as f64 - a;
                ss += d * d;
            }
            ss.sqrt()
        };
        let e2 = err_of(2);
        let e10 = err_of(10);
        assert!(e10 <= e2 * 1.01, "e2={e2} e10={e10}");
    }

    #[test]
    fn empty_arena_is_an_error_not_a_panic() {
        let empty = NodeMatrix::new(0, 4);
        assert!(Consensus::exact_average(&empty).is_err());
        assert!(Consensus::max_error(&empty, &[0.0; 4]).is_err());
    }

    /// Bitwise pin: the blocked flat kernel must reproduce the legacy
    /// nested-`Vec<Vec<f32>>` gossip results EXACTLY — same non-zero
    /// skip, same ascending-j accumulation order per element, tiling
    /// only re-chunks the k axis.  This is the contract that let the
    /// arena swap land without perturbing any seeded run.  The baseline
    /// is the single shared definition in `bench_harness`, the same one
    /// the hotpath speedup grid times.
    #[test]
    fn flat_kernel_matches_legacy_nested_vec_bitwise() {
        use crate::bench_harness::legacy_vecvec_mix_into as legacy_mix_into;
        forall(12, 0xC0_06, |g| {
            let n = g.usize_in(2, 12);
            // straddle the tile boundary in some cases
            let d = if g.f64_in(0.0, 1.0) < 0.5 {
                g.usize_in(1, 64)
            } else {
                crate::topology::MixMatrix::MIX_TILE + g.usize_in(0, 8)
            };
            let t = Topology::erdos_connected(n, 0.4, g.u64());
            let p = t.metropolis().lazy();
            let rounds = g.usize_in(1, 6);

            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect();

            // legacy gossip: mix + swap on nested Vecs
            let mut legacy = rows.clone();
            let mut legacy_scratch = vec![vec![0.0f32; d]; n];
            for _ in 0..rounds {
                legacy_mix_into(&p, &legacy, &mut legacy_scratch);
                std::mem::swap(&mut legacy, &mut legacy_scratch);
            }

            // flat gossip through the engine
            let mut cons = Consensus::new(p);
            let mut flat = NodeMatrix::from_rows(&rows);
            cons.run(&mut flat, rounds);

            for i in 0..n {
                for k in 0..d {
                    crate::prop_assert!(
                        flat.row(i)[k].to_bits() == legacy[i][k].to_bits(),
                        "({i},{k}): flat={} legacy={}",
                        flat.row(i)[k],
                        legacy[i][k]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lemma1_round_count_sane() {
        // More accuracy or a worse graph demands more rounds.
        let r_loose = rounds_for_accuracy(10, 0.888, 1.0, 0.1);
        let r_tight = rounds_for_accuracy(10, 0.888, 1.0, 0.001);
        assert!(r_tight > r_loose);
        let r_good_graph = rounds_for_accuracy(10, 0.3, 1.0, 0.01);
        assert!(r_good_graph < r_tight);
        assert!(r_loose >= 1);
    }

    #[test]
    fn lemma1_rounds_actually_achieve_eps() {
        // Empirical check: with messages scaled to the Lipschitz bound,
        // the Lemma-1 round count drives error below ε.
        let t = Topology::paper_fig2();
        let p = t.metropolis().lazy();
        let l2 = p.lambda2();
        let lipschitz = 1.0f64;
        let eps = 0.05f64;
        let rounds = rounds_for_accuracy(10, l2, lipschitz, eps);
        let mut cons = Consensus::new(p);
        let mut g = crate::prop::Gen::new(5);
        // messages bounded by L in norm
        let mut msgs = NodeMatrix::new(10, 4);
        for i in 0..10 {
            let mut v = g.vec_normal_f32(4, 1.0);
            let n = crate::util::norm2(&v).max(1e-9);
            for x in v.iter_mut() {
                *x *= (lipschitz as f32) / n;
            }
            msgs.row_mut(i).copy_from_slice(&v);
        }
        let avg = Consensus::exact_average(&msgs).unwrap();
        cons.run(&mut msgs, rounds);
        let err = Consensus::max_error(&msgs, &avg).unwrap();
        assert!(err < eps, "err={err} eps={eps} rounds={rounds}");
    }
}
