//! Averaging consensus engine (paper Sec. 3, consensus phase).
//!
//! Each node i starts from message m_i⁽⁰⁾ = n·b_i(t)·[z_i(t) + g_i(t)] and
//! runs synchronous rounds m⁽ᵏ⁾ = P m⁽ᵏ⁻¹⁾; after r_i(t) rounds the node
//! sets z_i(t+1) = m_i^(r_i)/b(t).  Perfect consensus would give every
//! node the average (4); finite rounds leave error ξ_i(t) bounded by
//! Lemma 1.

pub mod push_sum;
pub mod sparse;

use crate::topology::MixMatrix;

/// Dense synchronous consensus over row-stacked f32 messages.
pub struct Consensus {
    p: MixMatrix,
    /// Scratch buffer to avoid re-allocating per round.
    scratch: Vec<Vec<f32>>,
}

impl Consensus {
    pub fn new(p: MixMatrix) -> Consensus {
        let n = p.n();
        Consensus { p, scratch: vec![Vec::new(); n] }
    }

    pub fn n(&self) -> usize {
        self.p.n()
    }

    pub fn matrix(&self) -> &MixMatrix {
        &self.p
    }

    /// Run `rounds` synchronous rounds in place.
    pub fn run(&mut self, msgs: &mut Vec<Vec<f32>>, rounds: usize) {
        let n = self.p.n();
        assert_eq!(msgs.len(), n);
        let d = msgs[0].len();
        for s in &mut self.scratch {
            s.resize(d, 0.0);
        }
        for _ in 0..rounds {
            self.p.mix_into(msgs, &mut self.scratch);
            std::mem::swap(msgs, &mut self.scratch);
        }
    }

    /// Run with *per-node* round counts r_i (nodes stop listening after
    /// their budget; stragglers in the communication phase).  Nodes with
    /// fewer rounds keep their last value — this models the paper's
    /// variable r_i(t) within a fixed T_c.
    ///
    /// Implementation note: we run max(r_i) global rounds and freeze node
    /// i's row after r_i rounds.  Freezing breaks exact mass conservation
    /// (as it does in the real protocol when a node drops out early);
    /// Lemma 1's error bound still applies to each node's own estimate.
    pub fn run_per_node(&mut self, msgs: &mut Vec<Vec<f32>>, rounds: &[usize]) {
        let n = self.p.n();
        assert_eq!(msgs.len(), n);
        assert_eq!(rounds.len(), n);
        let rmax = rounds.iter().copied().max().unwrap_or(0);
        let d = msgs[0].len();
        for s in &mut self.scratch {
            s.resize(d, 0.0);
        }
        for k in 0..rmax {
            self.p.mix_into(msgs, &mut self.scratch);
            for i in 0..n {
                if rounds[i] > k {
                    std::mem::swap(&mut msgs[i], &mut self.scratch[i]);
                }
            }
        }
    }

    /// Exact average of the initial messages (what ε-perfect consensus
    /// would deliver to every node).
    pub fn exact_average(msgs: &[Vec<f32>]) -> Vec<f64> {
        let n = msgs.len();
        let d = msgs[0].len();
        let mut avg = vec![0.0f64; d];
        for m in msgs {
            for k in 0..d {
                avg[k] += m[k] as f64;
            }
        }
        for v in avg.iter_mut() {
            *v /= n as f64;
        }
        avg
    }

    /// max_i ‖m_i − avg‖₂ — the consensus error ε achieved.
    pub fn max_error(msgs: &[Vec<f32>], avg: &[f64]) -> f64 {
        let mut worst = 0.0f64;
        for m in msgs {
            let mut ss = 0.0f64;
            for k in 0..avg.len() {
                let diff = m[k] as f64 - avg[k];
                ss += diff * diff;
            }
            worst = worst.max(ss.sqrt());
        }
        worst
    }
}

/// Lemma 1 round count: r ≥ ⌈ log(2√n (1 + 2L/ε)) / (1 − λ₂(P)) ⌉
/// guarantees additive accuracy ε given Lipschitz constant L.
pub fn rounds_for_accuracy(n: usize, lambda2: f64, lipschitz: f64, eps: f64) -> usize {
    assert!(eps > 0.0 && lambda2 < 1.0);
    let num = (2.0 * (n as f64).sqrt() * (1.0 + 2.0 * lipschitz / eps)).ln();
    (num / (1.0 - lambda2)).ceil().max(1.0) as usize
}

/// Predicted error after r rounds from the spectral contraction:
/// ‖m⁽ʳ⁾ − avg‖ ≤ λ₂ʳ ‖m⁽⁰⁾ − avg‖ (symmetric P).
pub fn predicted_error(initial_error: f64, lambda2: f64, rounds: usize) -> f64 {
    initial_error * lambda2.powi(rounds as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::topology::Topology;

    fn random_msgs(g: &mut crate::prop::Gen, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect()
    }

    #[test]
    fn converges_to_average() {
        forall(20, 0xC0_01, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 8);
            let t = Topology::erdos_connected(n, 0.5, g.u64());
            let mut cons = Consensus::new(t.metropolis().lazy());
            let mut msgs = random_msgs(g, n, d);
            let avg = Consensus::exact_average(&msgs);
            cons.run(&mut msgs, 400);
            let err = Consensus::max_error(&msgs, &avg);
            crate::prop_assert!(err < 1e-3, "err={}", err);
            Ok(())
        });
    }

    #[test]
    fn error_contracts_at_lambda2_rate() {
        let t = Topology::ring(8);
        let p = t.metropolis().lazy();
        let l2 = p.lambda2();
        let mut cons = Consensus::new(p);
        let mut g = crate::prop::Gen::new(1);
        let mut msgs = random_msgs(&mut g, 8, 4);
        let avg = Consensus::exact_average(&msgs);
        let e0 = Consensus::max_error(&msgs, &avg);
        cons.run(&mut msgs, 25);
        let e25 = Consensus::max_error(&msgs, &avg);
        // within 2x of the spectral prediction (max-norm vs 2-norm slack)
        let bound = predicted_error(e0, l2, 25) * (8f64).sqrt() * 2.0;
        assert!(e25 <= bound, "e25={e25} bound={bound}");
    }

    #[test]
    fn conservation_under_uniform_rounds() {
        forall(20, 0xC0_02, |g| {
            let n = g.usize_in(2, 10);
            let d = g.usize_in(1, 6);
            let t = Topology::erdos_connected(n, 0.4, g.u64());
            let mut cons = Consensus::new(t.metropolis());
            let mut msgs = random_msgs(g, n, d);
            let before = Consensus::exact_average(&msgs);
            cons.run(&mut msgs, g.usize_in(0, 30));
            let after = Consensus::exact_average(&msgs);
            for k in 0..d {
                crate::prop_assert!((before[k] - after[k]).abs() < 1e-3);
            }
            Ok(())
        });
    }

    #[test]
    fn zero_rounds_is_identity() {
        let t = Topology::ring(5);
        let mut cons = Consensus::new(t.metropolis());
        let mut g = crate::prop::Gen::new(2);
        let msgs0 = random_msgs(&mut g, 5, 3);
        let mut msgs = msgs0.clone();
        cons.run(&mut msgs, 0);
        assert_eq!(msgs, msgs0);
    }

    #[test]
    fn per_node_rounds_freeze_early_stoppers() {
        let t = Topology::ring(6);
        let mut cons = Consensus::new(t.metropolis().lazy());
        let mut g = crate::prop::Gen::new(3);
        let msgs0 = random_msgs(&mut g, 6, 4);

        // node 0 does zero rounds: keeps the initial message
        let mut msgs = msgs0.clone();
        cons.run_per_node(&mut msgs, &[0, 5, 5, 5, 5, 5]);
        assert_eq!(msgs[0], msgs0[0]);
        assert_ne!(msgs[1], msgs0[1]);

        // equal per-node budgets == uniform run
        let mut a = msgs0.clone();
        cons.run_per_node(&mut a, &[4; 6]);
        let mut b = msgs0.clone();
        cons.run(&mut b, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn more_per_node_rounds_no_worse() {
        // A node that listens longer ends closer to the average.
        let t = Topology::paper_fig2();
        let p = t.metropolis().lazy();
        let mut cons = Consensus::new(p);
        let mut g = crate::prop::Gen::new(4);
        let msgs0 = random_msgs(&mut g, 10, 8);
        let avg = Consensus::exact_average(&msgs0);
        let mut err_of = |r: usize| {
            let mut m = msgs0.clone();
            let mut rounds = vec![r; 10];
            rounds[3] = r; // probe node 3
            cons.run_per_node(&mut m, &rounds);
            let mut ss = 0.0f64;
            for k in 0..avg.len() {
                let d = m[3][k] as f64 - avg[k];
                ss += d * d;
            }
            ss.sqrt()
        };
        let e2 = err_of(2);
        let e10 = err_of(10);
        assert!(e10 <= e2 * 1.01, "e2={e2} e10={e10}");
    }

    #[test]
    fn lemma1_round_count_sane() {
        // More accuracy or a worse graph demands more rounds.
        let r_loose = rounds_for_accuracy(10, 0.888, 1.0, 0.1);
        let r_tight = rounds_for_accuracy(10, 0.888, 1.0, 0.001);
        assert!(r_tight > r_loose);
        let r_good_graph = rounds_for_accuracy(10, 0.3, 1.0, 0.01);
        assert!(r_good_graph < r_tight);
        assert!(r_loose >= 1);
    }

    #[test]
    fn lemma1_rounds_actually_achieve_eps() {
        // Empirical check: with messages scaled to the Lipschitz bound,
        // the Lemma-1 round count drives error below ε.
        let t = Topology::paper_fig2();
        let p = t.metropolis().lazy();
        let l2 = p.lambda2();
        let lipschitz = 1.0f64;
        let eps = 0.05f64;
        let rounds = rounds_for_accuracy(10, l2, lipschitz, eps);
        let mut cons = Consensus::new(p);
        let mut g = crate::prop::Gen::new(5);
        // messages bounded by L in norm
        let mut msgs: Vec<Vec<f32>> = (0..10)
            .map(|_| {
                let mut v = g.vec_normal_f32(4, 1.0);
                let n = crate::util::norm2(&v).max(1e-9);
                for x in v.iter_mut() {
                    *x *= (lipschitz as f32) / n;
                }
                v
            })
            .collect();
        let avg = Consensus::exact_average(&msgs);
        cons.run(&mut msgs, rounds);
        let err = Consensus::max_error(&msgs, &avg);
        assert!(err < eps, "err={err} eps={eps} rounds={rounds}");
    }
}
