//! Sparse (neighbour-list) consensus engine.
//!
//! The dense engine multiplies by the full n×n matrix every round —
//! O(n²d) even though real communication graphs are sparse (the paper's
//! Fig-2 graph has 11 edges for n = 10).  This engine stores only the
//! non-zero Metropolis weights per node and mixes in O(|E|·d), which is
//! what an actual message-passing implementation costs.  Messages live
//! in the same flat [`NodeMatrix`] arena as the dense engine; a round is
//! tiled over the d axis and allocation-free.  Produces *bit-different
//! but numerically equivalent* results to the dense engine (same
//! weights, different summation order); equivalence is property-tested
//! below and it backs the perf-pass numbers in EXPERIMENTS.md §Perf.

use crate::topology::{accumulate_row_tile, MixMatrix, Topology};
use crate::util::matrix::NodeMatrix;

/// Per-node compressed mixing row: self weight + CSR neighbour lists
/// (the same layout [`MixMatrix`] caches, minus the diagonal, so both
/// engines share one tile kernel).
#[derive(Debug, Clone)]
pub struct SparseMix {
    n: usize,
    self_w: Vec<f32>,
    edge_ptr: Vec<usize>,
    edge_cols: Vec<u32>,
    edge_w: Vec<f32>,
}

impl SparseMix {
    /// Metropolis–Hastings weights from the graph (same formula as
    /// `Topology::metropolis`), optionally lazified ((P+I)/2).
    pub fn metropolis(topo: &Topology, lazy: bool) -> SparseMix {
        let n = topo.n();
        let mut self_w = vec![0.0f32; n];
        let mut edge_ptr = Vec::with_capacity(n + 1);
        let mut edge_cols = Vec::new();
        let mut edge_w = Vec::new();
        edge_ptr.push(0);
        for i in 0..n {
            let mut off = 0.0f64;
            for &j in topo.neighbors(i) {
                let w = 1.0 / (1.0 + topo.degree(i).max(topo.degree(j)) as f64);
                let w = if lazy { w * 0.5 } else { w };
                edge_cols.push(j as u32);
                edge_w.push(w as f32);
                off += w;
            }
            edge_ptr.push(edge_cols.len());
            self_w[i] = (1.0 - off) as f32;
        }
        SparseMix { n, self_w, edge_ptr, edge_cols, edge_w }
    }

    /// Metropolis weights over the subgraph induced by `active`
    /// ([`Topology::induced`]): inactive nodes get self-weight 1 and no
    /// edges (their message is held bit-for-bit), active nodes mix over
    /// active neighbours with induced degrees — the sparse engine's face
    /// of the churn semantics, numerically equivalent to the dense
    /// induced engine (tested below).  Built straight from the base
    /// graph + mask in O(n + E) — the induced `Topology` (one heap
    /// adjacency list per node, per epoch under churn) is never
    /// materialised; the weight arithmetic replays
    /// [`SparseMix::metropolis`]-over-the-induced-graph exactly.
    pub fn metropolis_active(topo: &Topology, lazy: bool, active: &[bool]) -> SparseMix {
        assert_eq!(active.len(), topo.n(), "active mask must cover every node");
        let n = topo.n();
        let deg_act: Vec<usize> = (0..n)
            .map(|i| {
                if active[i] {
                    topo.neighbors(i).iter().filter(|&&k| active[k]).count()
                } else {
                    0
                }
            })
            .collect();
        let mut self_w = vec![0.0f32; n];
        let mut edge_ptr = Vec::with_capacity(n + 1);
        let mut edge_cols = Vec::new();
        let mut edge_w = Vec::new();
        edge_ptr.push(0);
        for i in 0..n {
            let mut off = 0.0f64;
            if active[i] {
                for &j in topo.neighbors(i) {
                    if !active[j] {
                        continue;
                    }
                    let w = 1.0 / (1.0 + deg_act[i].max(deg_act[j]) as f64);
                    let w = if lazy { w * 0.5 } else { w };
                    edge_cols.push(j as u32);
                    edge_w.push(w as f32);
                    off += w;
                }
            }
            edge_ptr.push(edge_cols.len());
            self_w[i] = (1.0 - off) as f32;
        }
        SparseMix { n, self_w, edge_ptr, edge_cols, edge_w }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Non-zero off-diagonal entries (directed count).
    pub fn nnz(&self) -> usize {
        self.edge_cols.len()
    }

    /// One round: out.row(i) = w_ii·msgs.row(i) + Σ_{j∈N(i)} w_ij·msgs.row(j),
    /// tiled over the d axis with the same fused tile kernel as the
    /// dense engine ([`accumulate_row_tile`]).  Row-partitioned across
    /// the worker pool like the dense kernel: disjoint output blocks,
    /// shared read-only source arena, per-row op order untouched — so
    /// pooled rounds are bit-identical to serial ones.
    pub fn mix_into(&self, msgs: &NodeMatrix, out: &mut NodeMatrix) {
        assert_eq!(msgs.n(), self.n);
        assert_eq!(out.n(), self.n);
        assert_eq!(msgs.d(), out.d());
        let d = msgs.d();
        if d == 0 {
            return;
        }
        crate::util::pool::par_chunks(out.as_mut_slice(), d, |row0, block| {
            self.mix_rows(msgs, row0, block);
        });
    }

    /// Serial kernel over one contiguous block of output rows.
    fn mix_rows(&self, msgs: &NodeMatrix, row0: usize, block: &mut [f32]) {
        let d = msgs.d();
        let rows = block.len() / d;
        let mut k0 = 0usize;
        loop {
            let k1 = (k0 + MixMatrix::MIX_TILE).min(d);
            for r in 0..rows {
                let i = row0 + r;
                let wi = self.self_w[i];
                let ot = &mut block[r * d + k0..r * d + k1];
                for (o, &m) in ot.iter_mut().zip(&msgs.row(i)[k0..k1]) {
                    *o = wi * m;
                }
                let (lo, hi) = (self.edge_ptr[i], self.edge_ptr[i + 1]);
                accumulate_row_tile(
                    &self.edge_w[lo..hi],
                    &self.edge_cols[lo..hi],
                    msgs,
                    k0,
                    k1,
                    ot,
                );
            }
            if k1 == d {
                break;
            }
            k0 = k1;
        }
    }

    /// Run `rounds` rounds in place; `scratch` is (re)shaped on first use
    /// and the two arenas ping-pong with O(1) flips thereafter.
    pub fn run(&self, msgs: &mut NodeMatrix, scratch: &mut NodeMatrix, rounds: usize) {
        if scratch.n() != msgs.n() || scratch.d() != msgs.d() {
            scratch.reset(msgs.n(), msgs.d());
        }
        for _ in 0..rounds {
            self.mix_into(msgs, scratch);
            msgs.swap(scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::Consensus;
    use crate::prop::forall;

    #[test]
    fn matches_dense_engine() {
        forall(25, 0x5A_01, |g| {
            let n = g.usize_in(2, 16);
            let d = g.usize_in(1, 12);
            let topo = Topology::erdos_connected(n, g.f64_in(0.1, 0.8), g.u64());
            let rounds = g.usize_in(0, 12);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect();
            let msgs0 = NodeMatrix::from_rows(&rows);

            let mut dense = Consensus::new(topo.metropolis().lazy());
            let mut a = msgs0.clone();
            dense.run(&mut a, rounds);

            let sparse = SparseMix::metropolis(&topo, true);
            let mut b = msgs0;
            let mut scratch = NodeMatrix::new(0, 0);
            sparse.run(&mut b, &mut scratch, rounds);

            for i in 0..n {
                for k in 0..d {
                    crate::prop_assert!(
                        (a.row(i)[k] - b.row(i)[k]).abs() < 1e-3 * (1.0 + a.row(i)[k].abs()),
                        "({},{}) dense={} sparse={}",
                        i,
                        k,
                        a.row(i)[k],
                        b.row(i)[k]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn induced_sparse_matches_induced_dense() {
        forall(20, 0x5A_03, |g| {
            let n = g.usize_in(2, 14);
            let d = g.usize_in(1, 8);
            let topo = Topology::erdos_connected(n, 0.4, g.u64());
            let mut active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
            active[g.usize_in(0, n - 1)] = true;
            let rounds = g.usize_in(1, 8);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect();
            let msgs0 = NodeMatrix::from_rows(&rows);

            let mut dense =
                crate::consensus::churn::InducedConsensus::new(topo.clone());
            let mut a = msgs0.clone();
            dense.run(&mut a, rounds, &active);

            let sparse = SparseMix::metropolis_active(&topo, true, &active);
            let mut b = msgs0.clone();
            let mut scratch = NodeMatrix::new(0, 0);
            sparse.run(&mut b, &mut scratch, rounds);

            for i in 0..n {
                for k in 0..d {
                    crate::prop_assert!(
                        (a.row(i)[k] - b.row(i)[k]).abs() < 1e-3 * (1.0 + a.row(i)[k].abs()),
                        "({i},{k}) dense={} sparse={}",
                        a.row(i)[k],
                        b.row(i)[k]
                    );
                }
                // both engines hold inactive rows bitwise
                if !active[i] {
                    crate::prop_assert!(b.row(i) == msgs0.row(i), "sparse moved inactive row {i}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn metropolis_active_matches_materialised_induced_build_bitwise() {
        // The O(n+E) mask-direct build must reproduce the old
        // `metropolis(&topo.induced(active))` composition field for
        // field, bit for bit.
        forall(20, 0x5A_04, |g| {
            let n = g.usize_in(2, 18);
            let topo = Topology::erdos_connected(n, 0.4, g.u64());
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.6)).collect();
            for lazy in [false, true] {
                let fast = SparseMix::metropolis_active(&topo, lazy, &active);
                let slow = SparseMix::metropolis(&topo.induced(&active), lazy);
                crate::prop_assert!(fast.edge_ptr == slow.edge_ptr, "edge_ptr (lazy={lazy})");
                crate::prop_assert!(fast.edge_cols == slow.edge_cols, "edge_cols (lazy={lazy})");
                crate::prop_assert!(
                    fast.self_w.iter().zip(&slow.self_w).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "self weights drifted (lazy={lazy})"
                );
                crate::prop_assert!(
                    fast.edge_w.iter().zip(&slow.edge_w).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "edge weights drifted (lazy={lazy})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn nnz_counts_directed_edges() {
        let topo = Topology::ring(6);
        let s = SparseMix::metropolis(&topo, false);
        assert_eq!(s.nnz(), 12); // 6 undirected edges, both directions
        assert_eq!(s.n(), 6);
    }

    #[test]
    fn rows_sum_to_one() {
        forall(20, 0x5A_02, |g| {
            let n = g.usize_in(2, 20);
            let topo = Topology::erdos_connected(n, 0.3, g.u64());
            for lazy in [false, true] {
                let s = SparseMix::metropolis(&topo, lazy);
                for i in 0..n {
                    let edge_sum: f32 =
                        s.edge_w[s.edge_ptr[i]..s.edge_ptr[i + 1]].iter().sum();
                    crate::prop_assert_close!(s.self_w[i] + edge_sum, 1.0, 1e-5);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn converges_to_average() {
        let topo = Topology::paper_fig2();
        let s = SparseMix::metropolis(&topo, true);
        let mut g = crate::prop::Gen::new(2);
        let rows: Vec<Vec<f32>> = (0..10).map(|_| g.vec_normal_f32(4, 2.0)).collect();
        let mut msgs = NodeMatrix::from_rows(&rows);
        let avg = Consensus::exact_average(&msgs).unwrap();
        let mut scratch = NodeMatrix::new(0, 0);
        s.run(&mut msgs, &mut scratch, 500);
        assert!(Consensus::max_error(&msgs, &avg).unwrap() < 1e-3);
    }
}
