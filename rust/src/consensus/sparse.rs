//! Sparse (neighbour-list) consensus engine.
//!
//! The dense engine multiplies by the full n×n matrix every round —
//! O(n²d) even though real communication graphs are sparse (the paper's
//! Fig-2 graph has 11 edges for n = 10).  This engine stores only the
//! non-zero Metropolis weights per node and mixes in O(|E|·d), which is
//! what an actual message-passing implementation costs.  Produces
//! *bit-different but numerically equivalent* results to the dense
//! engine (same weights, different summation order); equivalence is
//! property-tested below and it backs the perf-pass numbers in
//! EXPERIMENTS.md §Perf.

use crate::topology::Topology;

/// Per-node compressed mixing row: self weight + (neighbour, weight).
#[derive(Debug, Clone)]
pub struct SparseMix {
    n: usize,
    self_w: Vec<f32>,
    edges: Vec<Vec<(usize, f32)>>,
}

impl SparseMix {
    /// Metropolis–Hastings weights from the graph (same formula as
    /// `Topology::metropolis`), optionally lazified ((P+I)/2).
    pub fn metropolis(topo: &Topology, lazy: bool) -> SparseMix {
        let n = topo.n();
        let mut self_w = vec![0.0f32; n];
        let mut edges = vec![Vec::new(); n];
        for i in 0..n {
            let mut off = 0.0f64;
            for &j in topo.neighbors(i) {
                let w = 1.0 / (1.0 + topo.degree(i).max(topo.degree(j)) as f64);
                let w = if lazy { w * 0.5 } else { w };
                edges[i].push((j, w as f32));
                off += w;
            }
            self_w[i] = (1.0 - off) as f32;
        }
        SparseMix { n, self_w, edges }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Non-zero off-diagonal entries (directed count).
    pub fn nnz(&self) -> usize {
        self.edges.iter().map(|e| e.len()).sum()
    }

    /// One round: out[i] = w_ii·msgs[i] + Σ_{j∈N(i)} w_ij·msgs[j].
    pub fn mix_into(&self, msgs: &[Vec<f32>], out: &mut [Vec<f32>]) {
        assert_eq!(msgs.len(), self.n);
        assert_eq!(out.len(), self.n);
        let d = msgs[0].len();
        for i in 0..self.n {
            let oi = &mut out[i];
            oi.resize(d, 0.0);
            let wi = self.self_w[i];
            let mi = &msgs[i];
            for k in 0..d {
                oi[k] = wi * mi[k];
            }
            for &(j, w) in &self.edges[i] {
                let mj = &msgs[j];
                for k in 0..d {
                    oi[k] += w * mj[k];
                }
            }
        }
    }

    /// Run `rounds` rounds in place with an internal scratch buffer.
    pub fn run(&self, msgs: &mut Vec<Vec<f32>>, scratch: &mut Vec<Vec<f32>>, rounds: usize) {
        scratch.resize(self.n, Vec::new());
        for s in scratch.iter_mut() {
            s.resize(msgs[0].len(), 0.0);
        }
        for _ in 0..rounds {
            self.mix_into(msgs, scratch);
            std::mem::swap(msgs, scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::Consensus;
    use crate::prop::forall;

    #[test]
    fn matches_dense_engine() {
        forall(25, 0x5A_01, |g| {
            let n = g.usize_in(2, 16);
            let d = g.usize_in(1, 12);
            let topo = Topology::erdos_connected(n, g.f64_in(0.1, 0.8), g.u64());
            let rounds = g.usize_in(0, 12);
            let msgs0: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 3.0)).collect();

            let mut dense = Consensus::new(topo.metropolis().lazy());
            let mut a = msgs0.clone();
            dense.run(&mut a, rounds);

            let sparse = SparseMix::metropolis(&topo, true);
            let mut b = msgs0;
            let mut scratch = Vec::new();
            sparse.run(&mut b, &mut scratch, rounds);

            for i in 0..n {
                for k in 0..d {
                    crate::prop_assert!(
                        (a[i][k] - b[i][k]).abs() < 1e-3 * (1.0 + a[i][k].abs()),
                        "({},{}) dense={} sparse={}",
                        i, k, a[i][k], b[i][k]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nnz_counts_directed_edges() {
        let topo = Topology::ring(6);
        let s = SparseMix::metropolis(&topo, false);
        assert_eq!(s.nnz(), 12); // 6 undirected edges, both directions
        assert_eq!(s.n(), 6);
    }

    #[test]
    fn rows_sum_to_one() {
        forall(20, 0x5A_02, |g| {
            let n = g.usize_in(2, 20);
            let topo = Topology::erdos_connected(n, 0.3, g.u64());
            for lazy in [false, true] {
                let s = SparseMix::metropolis(&topo, lazy);
                for i in 0..n {
                    let sum: f32 =
                        s.self_w[i] + s.edges[i].iter().map(|&(_, w)| w).sum::<f32>();
                    crate::prop_assert_close!(sum, 1.0, 1e-5);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn converges_to_average() {
        let topo = Topology::paper_fig2();
        let s = SparseMix::metropolis(&topo, true);
        let mut g = crate::prop::Gen::new(2);
        let mut msgs: Vec<Vec<f32>> = (0..10).map(|_| g.vec_normal_f32(4, 2.0)).collect();
        let avg = Consensus::exact_average(&msgs);
        let mut scratch = Vec::new();
        s.run(&mut msgs, &mut scratch, 500);
        assert!(Consensus::max_error(&msgs, &avg) < 1e-3);
    }
}
