//! Optimization layer: dual averaging (the paper's workhorse), its β(t)
//! schedule, and the delay-aware gradient pipeline for AMB-DG.

pub mod dual_avg;

pub use dual_avg::{BetaSchedule, DelayedGradients, DualAveraging, PendingBatch};
