//! Optimization layer: dual averaging (the paper's workhorse) and its
//! β(t) schedule.

pub mod dual_avg;

pub use dual_avg::{BetaSchedule, DualAveraging};
