//! Distributed dual averaging (paper Sec. 3, eq. (2)/(7)).
//!
//! State per node: primal w_i(t), dual z_i(t).  The update phase solves
//!
//!   w(t+1) = argmin_w { <w, z(t+1)> + β(t+1)·h(w) },   h(w) = ½‖w‖²,
//!   W = {‖w‖ ≤ R}  ⇒  w = clip_to_ball(−z/β, R),
//!
//! with the paper's step schedule β(t) = K + α(t), α(t) = √(t/μ̂)
//! (App. B, Lemma 8), where μ̂ estimates the per-epoch global sample
//! count c̄ and K is the gradient-smoothness constant.

/// β(t) schedule: K + sqrt(t / mu).
#[derive(Debug, Clone, Copy)]
pub struct BetaSchedule {
    /// Smoothness constant K (offset).
    pub k: f64,
    /// Expected global per-epoch sample count μ (scales α).
    pub mu: f64,
}

impl BetaSchedule {
    pub fn new(k: f64, mu: f64) -> BetaSchedule {
        assert!(k >= 0.0 && mu > 0.0);
        BetaSchedule { k, mu }
    }

    /// β(t) for epoch t (1-based, matching the paper).
    pub fn beta(&self, t: usize) -> f64 {
        assert!(t >= 1, "epochs are 1-based");
        self.k + (t as f64 / self.mu).sqrt()
    }
}

/// Dual-averaging optimizer over a flat f32 parameter vector.
#[derive(Debug, Clone)]
pub struct DualAveraging {
    pub schedule: BetaSchedule,
    /// Radius R of the feasible ball W.
    pub radius: f64,
}

impl DualAveraging {
    pub fn new(schedule: BetaSchedule, radius: f64) -> DualAveraging {
        assert!(radius > 0.0);
        DualAveraging { schedule, radius }
    }

    /// w(1) = argmin h(w) = 0 (paper eq. (2) with h = ½‖·‖²).
    pub fn initial_primal(&self, dim: usize) -> Vec<f32> {
        vec![0.0; dim]
    }

    /// Native primal step: w = clip_to_ball(−z/β(t), R).  Mirrors the
    /// dual_update artifact; used by NativeExec and as the PJRT oracle.
    pub fn primal_step(&self, z: &[f32], t: usize, w: &mut [f32]) {
        assert_eq!(z.len(), w.len());
        let beta = self.schedule.beta(t) as f32;
        let mut ss = 0.0f64;
        for (wi, &zi) in w.iter_mut().zip(z.iter()) {
            let v = -zi / beta;
            *wi = v;
            ss += (v as f64) * (v as f64);
        }
        let norm = ss.sqrt();
        if norm > self.radius {
            let scale = (self.radius / norm) as f32;
            for wi in w.iter_mut() {
                *wi *= scale;
            }
        }
    }

    /// The β value used at epoch t (exposed for the PJRT path, which
    /// passes β as a scalar input to the dual_update artifact).
    pub fn beta_at(&self, t: usize) -> f64 {
        self.schedule.beta(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn beta_monotone_nondecreasing() {
        let s = BetaSchedule::new(1.0, 600.0);
        let mut prev = 0.0;
        for t in 1..200 {
            let b = s.beta(t);
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn beta_formula() {
        let s = BetaSchedule::new(2.0, 4.0);
        assert!((s.beta(1) - (2.0 + 0.5)).abs() < 1e-12);
        assert!((s.beta(16) - (2.0 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn primal_step_interior() {
        let da = DualAveraging::new(BetaSchedule::new(0.0, 1.0), 100.0);
        // beta(4) = 2; w = -z/2
        let z = [2.0f32, -4.0];
        let mut w = [0.0f32; 2];
        da.primal_step(&z, 4, &mut w);
        assert_eq!(w, [-1.0, 2.0]);
    }

    #[test]
    fn primal_step_projects_to_ball() {
        forall(40, 0x0F_01, |g| {
            let dim = g.usize_in(1, 64);
            let da = DualAveraging::new(
                BetaSchedule::new(g.f64_in(0.0, 5.0), g.f64_in(0.5, 100.0)),
                g.f64_in(0.01, 3.0),
            );
            let z = g.vec_normal_f32(dim, 50.0);
            let mut w = vec![0.0f32; dim];
            da.primal_step(&z, g.usize_in(1, 50), &mut w);
            crate::prop_assert!(
                crate::util::norm2(&w) as f64 <= da.radius * (1.0 + 1e-5)
            );
            Ok(())
        });
    }

    #[test]
    fn primal_step_first_order_optimality() {
        // <u - w, z + beta*w> >= 0 for all feasible u (eq. 7 KKT).
        forall(25, 0x0F_02, |g| {
            let dim = g.usize_in(2, 16);
            let da = DualAveraging::new(BetaSchedule::new(1.0, 10.0), 1.0);
            let t = g.usize_in(1, 20);
            let z = g.vec_normal_f32(dim, 5.0);
            let mut w = vec![0.0f32; dim];
            da.primal_step(&z, t, &mut w);
            let beta = da.beta_at(t) as f32;
            for _ in 0..20 {
                let mut u = g.vec_normal_f32(dim, 1.0);
                let norm = crate::util::norm2(&u);
                if norm as f64 > da.radius {
                    let s = (da.radius / norm as f64) as f32;
                    for v in u.iter_mut() {
                        *v *= s;
                    }
                }
                let mut inner = 0.0f64;
                for j in 0..dim {
                    inner += ((u[j] - w[j]) * (z[j] + beta * w[j])) as f64;
                }
                crate::prop_assert!(inner >= -1e-3, "KKT violated: {}", inner);
            }
            Ok(())
        });
    }

    #[test]
    fn initial_primal_is_zero() {
        let da = DualAveraging::new(BetaSchedule::new(1.0, 1.0), 5.0);
        assert_eq!(da.initial_primal(4), vec![0.0f32; 4]);
    }

    #[test]
    fn dual_averaging_converges_on_quadratic() {
        // Centralized dual averaging on F(w)=0.5||w - w*||^2 with exact
        // gradients converges to (the projection of) w*.
        let dim = 8;
        let mut gen = crate::prop::Gen::new(5);
        let mut w_star = gen.vec_normal_f32(dim, 0.5);
        // keep w* inside the ball
        let n = crate::util::norm2(&w_star);
        if n > 0.9 {
            for v in w_star.iter_mut() {
                *v *= 0.9 / n;
            }
        }
        let da = DualAveraging::new(BetaSchedule::new(1.0, 1.0), 1.0);
        let mut z = vec![0.0f32; dim];
        let mut w = da.initial_primal(dim);
        for t in 1..4000 {
            for j in 0..dim {
                z[j] += w[j] - w_star[j]; // grad of 0.5||w-w*||^2
            }
            da.primal_step(&z, t + 1, &mut w);
        }
        let mut err = 0.0f64;
        for j in 0..dim {
            err += ((w[j] - w_star[j]) as f64).powi(2);
        }
        assert!(err.sqrt() < 0.05, "dist={}", err.sqrt());
    }
}
