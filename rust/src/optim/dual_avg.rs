//! Distributed dual averaging (paper Sec. 3, eq. (2)/(7)).
//!
//! State per node: primal w_i(t), dual z_i(t).  The update phase solves
//!
//!   w(t+1) = argmin_w { <w, z(t+1)> + β(t+1)·h(w) },   h(w) = ½‖w‖²,
//!   W = {‖w‖ ≤ R}  ⇒  w = clip_to_ball(−z/β, R),
//!
//! with the paper's step schedule β(t) = K + α(t), α(t) = √(t/μ̂)
//! (App. B, Lemma 8), where μ̂ estimates the per-epoch global sample
//! count c̄ and K is the gradient-smoothness constant.

/// β(t) schedule: K + sqrt(t / mu).
#[derive(Debug, Clone, Copy)]
pub struct BetaSchedule {
    /// Smoothness constant K (offset).
    pub k: f64,
    /// Expected global per-epoch sample count μ (scales α).
    pub mu: f64,
}

impl BetaSchedule {
    pub fn new(k: f64, mu: f64) -> BetaSchedule {
        assert!(k >= 0.0 && mu > 0.0);
        BetaSchedule { k, mu }
    }

    /// β(t) for epoch t (1-based, matching the paper).
    pub fn beta(&self, t: usize) -> f64 {
        assert!(t >= 1, "epochs are 1-based");
        self.k + (t as f64 / self.mu).sqrt()
    }
}

/// Dual-averaging optimizer over a flat f32 parameter vector.
#[derive(Debug, Clone)]
pub struct DualAveraging {
    pub schedule: BetaSchedule,
    /// Radius R of the feasible ball W.
    pub radius: f64,
}

impl DualAveraging {
    pub fn new(schedule: BetaSchedule, radius: f64) -> DualAveraging {
        assert!(radius > 0.0);
        DualAveraging { schedule, radius }
    }

    /// w(1) = argmin h(w) = 0 (paper eq. (2) with h = ½‖·‖²).
    pub fn initial_primal(&self, dim: usize) -> Vec<f32> {
        vec![0.0; dim]
    }

    /// Native primal step: w = clip_to_ball(−z/β(t), R).  Mirrors the
    /// dual_update artifact; used by NativeExec and as the PJRT oracle.
    pub fn primal_step(&self, z: &[f32], t: usize, w: &mut [f32]) {
        assert_eq!(z.len(), w.len());
        let beta = self.schedule.beta(t) as f32;
        let mut ss = 0.0f64;
        for (wi, &zi) in w.iter_mut().zip(z.iter()) {
            let v = -zi / beta;
            *wi = v;
            ss += (v as f64) * (v as f64);
        }
        let norm = ss.sqrt();
        if norm > self.radius {
            let scale = (self.radius / norm) as f32;
            for wi in w.iter_mut() {
                *wi *= scale;
            }
        }
    }

    /// The β value used at epoch t (exposed for the PJRT path, which
    /// passes β as a scalar input to the dual_update artifact).
    pub fn beta_at(&self, t: usize) -> f64 {
        self.schedule.beta(t)
    }
}

// ---------------------------------------------------------------------------
// Delay-aware dual accumulation (AMB-DG, arXiv:2012.08616)
// ---------------------------------------------------------------------------

/// One in-flight minibatch: a gradient sum tagged with the epoch whose
/// primal it was evaluated at.
#[derive(Debug, Clone)]
pub struct PendingBatch {
    /// Epoch the batch was computed in (its gradients saw that epoch's
    /// primal).
    pub epoch: usize,
    /// b_i for the batch (0 when the node's compute window produced
    /// nothing — the slot still advances the pipeline).
    pub batch: usize,
    /// Loss sum over the batch's samples.
    pub loss: f64,
    /// The gradient sum Σ ∇f(w(epoch); x).
    pub grad_sum: Vec<f32>,
}

/// Fixed-staleness gradient pipeline for the AMB-DG scheme: batches are
/// pushed tagged with their compute epoch and become ready for the dual
/// update exactly when more than `delay` batches are in flight, so with
/// static membership every gradient enters z with staleness `delay`
/// (and `delay = 0` degenerates to the immediate AMB update bit-for-bit
/// — push then pop returns the same values).
///
/// β(t) needs NO change for delayed gradients: dual averaging only
/// requires that z(t) be a running sum of subgradients and β(t) be
/// non-decreasing; a fixed delay moves each gradient's *evaluation
/// point* (the regret bound pays an O(D) additive term — AMB-DG Thm. 1),
/// not the schedule.  See DESIGN.md §pipelining.
///
/// Churn: callers push/pop only on epochs where the node participates,
/// so absence freezes the pipeline and every computed batch is still
/// applied EXACTLY once after the node rejoins (its recorded staleness
/// then exceeds `delay` by the epochs missed).
#[derive(Debug)]
pub struct DelayedGradients {
    delay: usize,
    /// FIFO, oldest first; length never exceeds `delay + 1`.
    ring: std::collections::VecDeque<PendingBatch>,
    /// Recycled grad-sum buffers from popped entries, so steady-state
    /// operation allocates nothing.
    spare: Vec<Vec<f32>>,
}

impl DelayedGradients {
    pub fn new(delay: usize) -> DelayedGradients {
        DelayedGradients {
            delay,
            ring: std::collections::VecDeque::with_capacity(delay + 1),
            spare: Vec::new(),
        }
    }

    pub fn delay(&self) -> usize {
        self.delay
    }

    /// Batches computed but not yet applied.
    pub fn in_flight(&self) -> usize {
        self.ring.len()
    }

    /// Samples computed but not yet applied (end-of-run conservation
    /// diagnostic: computed = applied + in-flight).
    pub fn in_flight_samples(&self) -> usize {
        self.ring.iter().map(|p| p.batch).sum()
    }

    /// Record epoch `epoch`'s computed batch.  Call exactly once per
    /// epoch the node participates in.
    pub fn push(&mut self, epoch: usize, batch: usize, loss: f64, grad_sum: &[f32]) {
        let mut buf = self.spare.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(grad_sum);
        self.ring.push_back(PendingBatch { epoch, batch, loss, grad_sum: buf });
    }

    /// The batch ready to enter the dual this epoch, for callers that
    /// ALREADY pushed this epoch's batch (the simulator's epoch order:
    /// compute, push, pop, encode): the oldest entry once more than
    /// `delay` are in flight.  `None` during warm-up (the first `delay`
    /// participating epochs apply nothing).  Return the entry to
    /// [`Self::recycle`] after encoding to keep the pipeline
    /// allocation-free.
    pub fn pop_ready(&mut self) -> Option<PendingBatch> {
        if self.ring.len() > self.delay {
            self.ring.pop_front()
        } else {
            None
        }
    }

    /// The batch ready this epoch, for callers that have NOT yet pushed
    /// this epoch's batch (the threaded runtime's epoch order: the pop
    /// feeds the consensus that runs BEFORE the overlapped compute, so
    /// the current epoch's push happens later).  Counting the pending
    /// push keeps the application schedule identical to
    /// [`Self::pop_ready`]'s: the batch of epoch t is applied at epoch
    /// t + delay on both runtimes.  Only meaningful for `delay ≥ 1`
    /// (the degenerate D = 0 pipeline applies a batch in the epoch that
    /// computes it, which a pre-push pop cannot express — the threaded
    /// runtime normalizes D = 0 to the stock AMB path instead).
    pub fn pop_ready_pre_push(&mut self) -> Option<PendingBatch> {
        assert!(self.delay >= 1, "pre-push pop is undefined for the D = 0 pipeline");
        if self.ring.len() >= self.delay {
            self.ring.pop_front()
        } else {
            None
        }
    }

    /// Hand a popped entry's buffer back for reuse.
    pub fn recycle(&mut self, p: PendingBatch) {
        self.spare.push(p.grad_sum);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    // The β(t)-strictly-increasing, ‖primal_step‖ ≤ R, and w(1) = 0
    // properties live in the central `crate::prop::domain_props` suite
    // (randomized over schedules, dimensions, and radii).

    #[test]
    fn beta_formula() {
        let s = BetaSchedule::new(2.0, 4.0);
        assert!((s.beta(1) - (2.0 + 0.5)).abs() < 1e-12);
        assert!((s.beta(16) - (2.0 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn primal_step_interior() {
        let da = DualAveraging::new(BetaSchedule::new(0.0, 1.0), 100.0);
        // beta(4) = 2; w = -z/2
        let z = [2.0f32, -4.0];
        let mut w = [0.0f32; 2];
        da.primal_step(&z, 4, &mut w);
        assert_eq!(w, [-1.0, 2.0]);
    }

    #[test]
    fn primal_step_first_order_optimality() {
        // <u - w, z + beta*w> >= 0 for all feasible u (eq. 7 KKT).
        forall(25, 0x0F_02, |g| {
            let dim = g.usize_in(2, 16);
            let da = DualAveraging::new(BetaSchedule::new(1.0, 10.0), 1.0);
            let t = g.usize_in(1, 20);
            let z = g.vec_normal_f32(dim, 5.0);
            let mut w = vec![0.0f32; dim];
            da.primal_step(&z, t, &mut w);
            let beta = da.beta_at(t) as f32;
            for _ in 0..20 {
                let mut u = g.vec_normal_f32(dim, 1.0);
                let norm = crate::util::norm2(&u);
                if norm as f64 > da.radius {
                    let s = (da.radius / norm as f64) as f32;
                    for v in u.iter_mut() {
                        *v *= s;
                    }
                }
                let mut inner = 0.0f64;
                for j in 0..dim {
                    inner += ((u[j] - w[j]) * (z[j] + beta * w[j])) as f64;
                }
                crate::prop_assert!(inner >= -1e-3, "KKT violated: {}", inner);
            }
            Ok(())
        });
    }

    #[test]
    fn delayed_gradients_schedule() {
        // D = 0: push-then-pop returns the same epoch's batch — the
        // degenerate pipeline IS the immediate AMB update.
        let mut r = DelayedGradients::new(0);
        r.push(1, 10, 0.5, &[1.0, 2.0]);
        let p = r.pop_ready().expect("D = 0 applies immediately");
        assert_eq!((p.epoch, p.batch), (1, 10));
        assert_eq!(p.grad_sum, vec![1.0, 2.0]);
        r.recycle(p);
        assert_eq!(r.in_flight(), 0);

        // D = 2: two warm-up epochs, then staleness exactly 2.
        let mut r = DelayedGradients::new(2);
        for t in 1..=2 {
            r.push(t, 10 * t, 0.0, &[t as f32]);
            assert!(r.pop_ready().is_none(), "warm-up epoch {t} applied a batch");
        }
        for t in 3..=6 {
            r.push(t, 10 * t, 0.0, &[t as f32]);
            let p = r.pop_ready().unwrap();
            assert_eq!(p.epoch, t - 2, "staleness must be exactly D");
            assert_eq!(p.batch, 10 * (t - 2));
            r.recycle(p);
        }
        assert_eq!(r.in_flight(), 2);
        assert_eq!(r.in_flight_samples(), 10 * 5 + 10 * 6);
    }

    #[test]
    fn delayed_gradients_pre_push_matches_post_push_schedule() {
        // The threaded runtime pops before pushing (consensus runs before
        // the overlapped compute); both orders must apply epoch t's batch
        // at epoch t + D — including across skipped (churned) epochs,
        // where every batch is still applied exactly once, later.
        for delay in [1usize, 2, 4] {
            let participate = [true, true, false, true, true, false, false, true, true, true];
            let mut post = DelayedGradients::new(delay);
            let mut pre = DelayedGradients::new(delay);
            let mut applied_post = Vec::new();
            let mut applied_pre = Vec::new();
            for (t0, &on) in participate.iter().enumerate() {
                let t = t0 + 1;
                if !on {
                    continue;
                }
                post.push(t, t, 0.0, &[0.0]);
                if let Some(p) = post.pop_ready() {
                    applied_post.push((t, p.epoch, p.batch));
                }
                if let Some(p) = pre.pop_ready_pre_push() {
                    applied_pre.push((t, p.epoch, p.batch));
                }
                pre.push(t, t, 0.0, &[0.0]);
            }
            assert_eq!(applied_post, applied_pre, "delay {delay}: schedules diverged");
            // exactly-once conservation: everything pushed is either
            // applied or still in flight
            let pushed: usize = participate.iter().filter(|&&on| on).count();
            assert_eq!(applied_post.len() + post.in_flight(), pushed);
        }
    }

    #[test]
    fn dual_averaging_converges_on_quadratic() {
        // Centralized dual averaging on F(w)=0.5||w - w*||^2 with exact
        // gradients converges to (the projection of) w*.
        let dim = 8;
        let mut gen = crate::prop::Gen::new(5);
        let mut w_star = gen.vec_normal_f32(dim, 0.5);
        // keep w* inside the ball
        let n = crate::util::norm2(&w_star);
        if n > 0.9 {
            for v in w_star.iter_mut() {
                *v *= 0.9 / n;
            }
        }
        let da = DualAveraging::new(BetaSchedule::new(1.0, 1.0), 1.0);
        let mut z = vec![0.0f32; dim];
        let mut w = da.initial_primal(dim);
        for t in 1..4000 {
            for j in 0..dim {
                z[j] += w[j] - w_star[j]; // grad of 0.5||w-w*||^2
            }
            da.primal_step(&z, t + 1, &mut w);
        }
        let mut err = 0.0f64;
        for j in 0..dim {
            err += ((w[j] - w_star[j]) as f64).powi(2);
        }
        assert!(err.sqrt() < 0.05, "dist={}", err.sqrt());
    }
}
