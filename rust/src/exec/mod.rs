//! Execution engines: how a node's gradient chunks and primal updates are
//! actually computed.
//!
//! * [`NativeExec`] — pure-Rust math (model::linreg/logreg); artifact-free,
//!   used by unit tests, pure-algorithm benches, and as a PJRT oracle.
//! * `runtime::PjrtExec` — loads the AOT artifacts and executes via the
//!   xla-crate PJRT CPU client (the production hot path).
//!
//! Both present the same [`ExecEngine`] interface so the coordinator is
//! backend-agnostic.  Gradient *sums* accumulate into caller buffers
//! (chunk+mask convention — DESIGN.md §1): `grad_chunk(w, n, rng, acc)`
//! draws `n` fresh samples from the node's data distribution, adds the
//! gradient-sum into `acc`, and returns the loss-sum.

use crate::data::{LinRegStream, MnistLike};
use crate::model::Workload;
use crate::optim::DualAveraging;
use crate::util::rng::Pcg64;

/// A node's data distribution (shared across nodes: the paper's i.i.d. Q).
pub enum DataSource {
    LinReg(LinRegStream),
    Mnist(MnistLike),
}

impl DataSource {
    pub fn workload(&self) -> Workload {
        match self {
            DataSource::LinReg(s) => Workload::LinReg { d: s.d },
            DataSource::Mnist(m) => Workload::LogReg { k: m.classes, d: m.d() },
        }
    }

    /// Per-sample optimal loss F(w*) when known analytically:
    /// linreg: ½·E[η²] = ½·noise_var.  logreg: `None` — F(w*) has no
    /// closed form for the mixture, and silently substituting a 0.0
    /// lower bound would let regret accounting mix true and bounded
    /// baselines across schemes (the caller decides; see
    /// [`crate::metrics::RunRecord::regret_series`]).
    pub fn f_star(&self) -> Option<f64> {
        match self {
            DataSource::LinReg(s) => Some(0.5 * s.noise_std * s.noise_std),
            DataSource::Mnist(_) => None,
        }
    }
}

/// Backend-agnostic per-node compute interface.
///
/// Not `Send`: the PJRT client is thread-local (Rc internally), so the
/// threaded cluster constructs one engine *inside* each node thread via a
/// `Send + Sync` factory.
pub trait ExecEngine {
    /// Draw `n_samples` fresh samples, accumulate the gradient *sum* into
    /// `acc` (len = workload.dim()) and return the loss *sum*.
    fn grad_chunk(&mut self, w: &[f32], n_samples: usize, rng: &mut Pcg64, acc: &mut [f32])
        -> f64;

    /// Primal step w = clip_ball(−z/β(t), R) (eq. (7)); engines with a
    /// centred h(w) = ½‖w − w₀‖² add the centre back (transformer).
    fn primal_step(&mut self, z: &[f32], t: usize, w: &mut [f32]);

    /// w(1) = argmin h(w) (paper eq. (2)): 0 for the ball-centred
    /// regressions, the build-time init for the transformer.
    fn initial_primal(&self) -> Vec<f32> {
        vec![0.0; self.workload().dim()]
    }

    /// Workload executed by this engine.
    fn workload(&self) -> Workload;

    /// Workload-specific error metric at `w` (fresh-sample estimate);
    /// NaN when the engine cannot compute one.
    fn error_metric(&mut self, w: &[f32], rng: &mut Pcg64) -> f64;
}

/// Pure-Rust execution over a shared data source.
pub struct NativeExec {
    pub source: std::sync::Arc<DataSource>,
    pub optimizer: DualAveraging,
    // scratch buffers to keep the hot loop allocation-free
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
    label_buf: Vec<i32>,
    grad_buf: Vec<f32>,
    /// Samples used per error_metric estimate.
    pub error_samples: usize,
}

impl NativeExec {
    pub fn new(source: std::sync::Arc<DataSource>, optimizer: DualAveraging) -> NativeExec {
        NativeExec {
            source,
            optimizer,
            x_buf: Vec::new(),
            y_buf: Vec::new(),
            label_buf: Vec::new(),
            grad_buf: Vec::new(),
            error_samples: 256,
        }
    }
}

impl ExecEngine for NativeExec {
    fn grad_chunk(
        &mut self,
        w: &[f32],
        n_samples: usize,
        rng: &mut Pcg64,
        acc: &mut [f32],
    ) -> f64 {
        if n_samples == 0 {
            return 0.0;
        }
        // Native chunks are always full, so they take the mask-free fast
        // path (bit-identical to an all-ones mask, zero allocations); the
        // chunk+mask convention only pays its tail cost on the AOT
        // artifact path where shapes are static.
        match &*self.source {
            DataSource::LinReg(s) => {
                s.sample_chunk(rng, n_samples, &mut self.x_buf, &mut self.y_buf);
                self.grad_buf.resize(s.d, 0.0);
                let loss = crate::model::linreg::grad_sum_dense(
                    w, &self.x_buf, &self.y_buf, &mut self.grad_buf,
                );
                crate::util::axpy(1.0, &self.grad_buf, acc);
                loss
            }
            DataSource::Mnist(m) => {
                m.sample_chunk(rng, n_samples, &mut self.x_buf, &mut self.label_buf);
                self.grad_buf.resize(m.classes * m.d(), 0.0);
                let loss = crate::model::logreg::grad_sum_dense(
                    w, &self.x_buf, &self.label_buf, m.classes, &mut self.grad_buf,
                );
                crate::util::axpy(1.0, &self.grad_buf, acc);
                loss
            }
        }
    }

    fn primal_step(&mut self, z: &[f32], t: usize, w: &mut [f32]) {
        self.optimizer.primal_step(z, t, w);
    }

    fn workload(&self) -> Workload {
        self.source.workload()
    }

    fn error_metric(&mut self, w: &[f32], rng: &mut Pcg64) -> f64 {
        match &*self.source {
            DataSource::LinReg(s) => s.excess_risk(w),
            DataSource::Mnist(m) => {
                // fresh-sample average logistic cost (the paper's Fig. 1b
                // y-axis).
                let n = self.error_samples;
                m.sample_chunk(rng, n, &mut self.x_buf, &mut self.label_buf);
                self.grad_buf.resize(m.classes * m.d(), 0.0);
                let loss = crate::model::logreg::grad_sum_dense(
                    w, &self.x_buf, &self.label_buf, m.classes, &mut self.grad_buf,
                );
                loss / n as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::BetaSchedule;
    use std::sync::Arc;

    fn linreg_exec(d: usize) -> NativeExec {
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, 7)));
        NativeExec::new(src, DualAveraging::new(BetaSchedule::new(1.0, 100.0), 50.0))
    }

    #[test]
    fn grad_chunk_accumulates() {
        let mut e = linreg_exec(8);
        let w = vec![0.0f32; 8];
        let mut acc = vec![0.0f32; 8];
        let mut rng = Pcg64::new(1);
        let l1 = e.grad_chunk(&w, 16, &mut rng, &mut acc);
        let snapshot = acc.clone();
        let l2 = e.grad_chunk(&w, 16, &mut rng, &mut acc);
        assert!(l1 > 0.0 && l2 > 0.0);
        // second call adds on top
        assert!(acc.iter().zip(&snapshot).any(|(a, s)| a != s));
    }

    #[test]
    fn zero_samples_noop() {
        let mut e = linreg_exec(4);
        let w = vec![0.0f32; 4];
        let mut acc = vec![1.0f32; 4];
        let mut rng = Pcg64::new(2);
        let loss = e.grad_chunk(&w, 0, &mut rng, &mut acc);
        assert_eq!(loss, 0.0);
        assert_eq!(acc, vec![1.0f32; 4]);
    }

    #[test]
    fn error_metric_linreg_is_excess_risk() {
        let mut e = linreg_exec(4);
        let mut rng = Pcg64::new(3);
        let w_star = match &*e.source {
            DataSource::LinReg(s) => s.w_star.clone(),
            _ => unreachable!(),
        };
        assert_eq!(e.error_metric(&w_star, &mut rng), 0.0);
        let w0 = vec![0.0f32; 4];
        assert!(e.error_metric(&w0, &mut rng) > 0.0);
    }

    #[test]
    fn mnist_error_metric_decreases_with_training() {
        let src = Arc::new(DataSource::Mnist(MnistLike::new(4, 16, 4.0, 1.0, 9)));
        let mut e = NativeExec::new(src, DualAveraging::new(BetaSchedule::new(1.0, 64.0), 50.0));
        let dim = e.workload().dim();
        let mut w = vec![0.0f32; dim];
        let mut z = vec![0.0f32; dim];
        let mut rng = Pcg64::new(5);
        let err0 = e.error_metric(&w, &mut rng);
        for t in 1..=40 {
            let mut acc = vec![0.0f32; dim];
            e.grad_chunk(&w.clone(), 64, &mut rng, &mut acc);
            for j in 0..dim {
                z[j] += acc[j] / 64.0;
            }
            e.primal_step(&z, t + 1, &mut w);
        }
        let err1 = e.error_metric(&w, &mut rng);
        assert!(err1 < err0 * 0.7, "err0={err0} err1={err1}");
    }

    #[test]
    fn f_star_linreg_known_mnist_unknown() {
        let src = DataSource::LinReg(LinRegStream::new(4, 0));
        assert!((src.f_star().unwrap() - 0.5e-3).abs() < 1e-9);
        let mn = DataSource::Mnist(MnistLike::new(4, 16, 4.0, 1.0, 9));
        assert_eq!(mn.f_star(), None, "no silent 0.0 lower bound");
    }
}
