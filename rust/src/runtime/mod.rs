//! PJRT runtime: load the AOT artifacts (HLO text) and execute them on the
//! xla-crate CPU client — the production hot path (Python never runs here).
//!
//! Pattern follows /opt/xla-example/load_hlo: HloModuleProto::from_text_file
//! → XlaComputation::from_proto → client.compile → execute.  Executables
//! are compiled once and cached; `PjrtExec` adapts the runtime to the
//! coordinator's [`ExecEngine`] interface with the chunk+mask convention.
//!
//! Thread-locality: `PjRtClient` is Rc-based (not Send); the threaded
//! cluster creates one runtime per node thread via a factory.

// amb-lint: allow-file(D4, "PJRT bridge: literals and decodes on shapes validated at exec setup")
pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Result};

pub use manifest::{Dtype, Entry, Manifest, TensorSpec};

use crate::data::TokenStream;
use crate::exec::{DataSource, ExecEngine};
use crate::model::Workload;
use crate::optim::DualAveraging;
use crate::util::rng::Pcg64;

/// Compiled-executable cache over one PJRT CPU client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

thread_local! {
    /// Per-thread runtime cache for [`PjrtRuntime::load_shared`]: the
    /// simulator's n engines (one thread) share a single client and
    /// executable cache, while each threaded-cluster node thread gets
    /// its own (PJRT clients are Rc-based and must not cross threads).
    static RUNTIME_CACHE: RefCell<HashMap<std::path::PathBuf, Rc<PjrtRuntime>>> =
        RefCell::new(HashMap::new());
}

impl PjrtRuntime {
    /// Load `<dir>/manifest.json` and create the CPU client.
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtRuntime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Load through the per-thread cache: repeated calls with the same
    /// directory on the same thread return the same runtime (one client,
    /// one compiled-executable cache) instead of re-loading per engine.
    pub fn load_shared(dir: &Path) -> Result<Rc<PjrtRuntime>> {
        RUNTIME_CACHE.with(|cache| {
            if let Some(rt) = cache.borrow().get(dir) {
                return Ok(rt.clone());
            }
            let rt = Rc::new(PjrtRuntime::load(dir)?);
            cache.borrow_mut().insert(dir.to_path_buf(), rt.clone());
            Ok(rt)
        })
    }

    /// Compile (or fetch cached) an entry's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry with literal inputs; returns the decomposed output
    /// tuple (aot.py lowers with return_tuple=True).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let entry = self.manifest.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{name}: got {} inputs, artifact expects {}",
                inputs.len(),
                entry.inputs.len()
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} output: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("decomposing {name} tuple: {e:?}"))
    }
}

/// f32 literal with shape from a host slice.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32 shape {:?} != data len {}", shape, data.len());
    }
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("lit_f32: {e:?}"))
}

/// i32 literal with shape from a host slice.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32 shape {:?} != data len {}", shape, data.len());
    }
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("lit_i32: {e:?}"))
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Copy a literal back into an f32 vec.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_f32: {e:?}"))
}

/// Scalar f32 from a literal ((), (1,), or any single-element shape).
pub fn to_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow::anyhow!("to_scalar: {e:?}"))
}

// ---------------------------------------------------------------------------
// ExecEngine over PJRT artifacts
// ---------------------------------------------------------------------------

/// Artifact-backed execution engine for the regression workloads.
///
/// Variable minibatches are decomposed into fixed-size chunks of the
/// artifact's static batch C with {0,1} masking of the tail (DESIGN.md §1).
pub struct PjrtExec {
    rt: Rc<PjrtRuntime>,
    source: Arc<DataSource>,
    optimizer: DualAveraging,
    grad_entry: String,
    dual_entry: String,
    chunk: usize,
    // reusable host buffers
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
    label_buf: Vec<i32>,
    mask_buf: Vec<f32>,
    /// Native twin used only for the error metric (not the hot path).
    native_metric: crate::exec::NativeExec,
}

impl PjrtExec {
    pub fn new(
        rt: Rc<PjrtRuntime>,
        source: Arc<DataSource>,
        optimizer: DualAveraging,
    ) -> Result<PjrtExec> {
        let (grad_entry, chunk, dim) = match &*source {
            DataSource::LinReg(s) => {
                if s.d != rt.manifest.linreg_d {
                    bail!(
                        "linreg d={} but artifacts built for d={} (rebuild with matching sizes)",
                        s.d,
                        rt.manifest.linreg_d
                    );
                }
                (rt.manifest.linreg_entry_name(), rt.manifest.linreg_c, s.d)
            }
            DataSource::Mnist(m) => {
                if m.d() != rt.manifest.logreg_d || m.classes != rt.manifest.logreg_k {
                    bail!(
                        "logreg k={} d={} but artifacts built for k={} d={}",
                        m.classes,
                        m.d(),
                        rt.manifest.logreg_k,
                        rt.manifest.logreg_d
                    );
                }
                (rt.manifest.logreg_entry_name(), rt.manifest.logreg_c, m.classes * m.d())
            }
        };
        let dual_entry = rt.manifest.dual_update_entry_name(dim);
        // Compile eagerly so first-epoch latency is not misattributed.
        rt.executable(&grad_entry)?;
        rt.executable(&dual_entry)?;
        let native_metric =
            crate::exec::NativeExec::new(source.clone(), optimizer.clone());
        Ok(PjrtExec {
            rt,
            source,
            optimizer,
            grad_entry,
            dual_entry,
            chunk,
            x_buf: Vec::new(),
            y_buf: Vec::new(),
            label_buf: Vec::new(),
            mask_buf: Vec::new(),
            native_metric,
        })
    }

    fn grad_chunk_linreg(
        &mut self,
        s: &crate::data::LinRegStream,
        w: &[f32],
        n: usize,
        rng: &mut Pcg64,
        acc: &mut [f32],
    ) -> Result<f64> {
        let c = self.chunk;
        let d = s.d;
        let mut remaining = n;
        let mut loss = 0.0f64;
        let w_lit = lit_f32(&[d], w)?;
        while remaining > 0 {
            let take = remaining.min(c);
            s.sample_chunk(rng, take, &mut self.x_buf, &mut self.y_buf);
            // pad to the static chunk
            self.x_buf.resize(c * d, 0.0);
            self.y_buf.resize(c, 0.0);
            self.mask_buf.clear();
            self.mask_buf.resize(c, 0.0);
            for m in self.mask_buf.iter_mut().take(take) {
                *m = 1.0;
            }
            let outs = self.rt.execute(
                &self.grad_entry,
                &[
                    w_lit.clone(),
                    lit_f32(&[c, d], &self.x_buf)?,
                    lit_f32(&[c], &self.y_buf)?,
                    lit_f32(&[c], &self.mask_buf)?,
                ],
            )?;
            let g = to_f32(&outs[0])?;
            crate::util::axpy(1.0, &g, acc);
            loss += to_scalar(&outs[1])? as f64;
            remaining -= take;
        }
        Ok(loss)
    }

    fn grad_chunk_logreg(
        &mut self,
        m: &crate::data::MnistLike,
        w: &[f32],
        n: usize,
        rng: &mut Pcg64,
        acc: &mut [f32],
    ) -> Result<f64> {
        let c = self.chunk;
        let d = m.d();
        let k = m.classes;
        let mut remaining = n;
        let mut loss = 0.0f64;
        let w_lit = lit_f32(&[k, d], w)?;
        while remaining > 0 {
            let take = remaining.min(c);
            m.sample_chunk(rng, take, &mut self.x_buf, &mut self.label_buf);
            self.x_buf.resize(c * d, 0.0);
            self.label_buf.resize(c, 0);
            self.mask_buf.clear();
            self.mask_buf.resize(c, 0.0);
            for mm in self.mask_buf.iter_mut().take(take) {
                *mm = 1.0;
            }
            let outs = self.rt.execute(
                &self.grad_entry,
                &[
                    w_lit.clone(),
                    lit_f32(&[c, d], &self.x_buf)?,
                    lit_i32(&[c], &self.label_buf)?,
                    lit_f32(&[c], &self.mask_buf)?,
                ],
            )?;
            let g = to_f32(&outs[0])?;
            crate::util::axpy(1.0, &g, acc);
            loss += to_scalar(&outs[1])? as f64;
            remaining -= take;
        }
        Ok(loss)
    }
}

impl ExecEngine for PjrtExec {
    fn grad_chunk(
        &mut self,
        w: &[f32],
        n_samples: usize,
        rng: &mut Pcg64,
        acc: &mut [f32],
    ) -> f64 {
        if n_samples == 0 {
            return 0.0;
        }
        let source = self.source.clone();
        match &*source {
            DataSource::LinReg(s) => self
                .grad_chunk_linreg(s, w, n_samples, rng, acc)
                .expect("pjrt linreg grad failed"),
            DataSource::Mnist(m) => self
                .grad_chunk_logreg(m, w, n_samples, rng, acc)
                .expect("pjrt logreg grad failed"),
        }
    }

    fn primal_step(&mut self, z: &[f32], t: usize, w: &mut [f32]) {
        let beta = self.optimizer.beta_at(t) as f32;
        let radius = self.optimizer.radius as f32;
        let outs = self
            .rt
            .execute(
                &self.dual_entry,
                &[lit_f32(&[z.len()], z).unwrap(), lit_scalar(beta), lit_scalar(radius)],
            )
            .expect("pjrt dual_update failed");
        let wv = to_f32(&outs[0]).expect("dual_update output");
        w.copy_from_slice(&wv);
    }

    fn workload(&self) -> Workload {
        self.source.workload()
    }

    fn error_metric(&mut self, w: &[f32], rng: &mut Pcg64) -> f64 {
        self.native_metric.error_metric(w, rng)
    }
}

// ---------------------------------------------------------------------------
// Transformer engine (e2e example): opaque flat-parameter workload
// ---------------------------------------------------------------------------

/// Artifact-backed transformer-LM gradient engine.  The "sample unit" is
/// one sequence; the artifact consumes a fixed batch of `batch` sequences
/// with a per-sequence mask, so variable minibatches chunk exactly like
/// the regression engines.
///
/// Dual averaging is *centred* at the build-time init parameters w₀:
/// h(w) = ½‖w − w₀‖² (still 1-strongly convex, paper eq. (2)/(7) hold
/// verbatim), so w(1) = w₀ and the primal step is w = w₀ + clip(−z/β).
pub struct TransformerExec {
    rt: Rc<PjrtRuntime>,
    tokens: Arc<TokenStream>,
    optimizer: DualAveraging,
    grad_entry: String,
    dual_entry: String,
    pub batch: usize,
    pub seq_len: usize,
    tok_buf: Vec<i32>,
    mask_buf: Vec<f32>,
    /// h's centre (the build-time init).
    center: Vec<f32>,
    /// Tokens contributing to the last grad_chunk (loss normalizer).
    pub last_token_count: f64,
}

impl TransformerExec {
    pub fn new(
        rt: Rc<PjrtRuntime>,
        tokens: Arc<TokenStream>,
        optimizer: DualAveraging,
    ) -> Result<TransformerExec> {
        let t = &rt.manifest.transformer;
        if tokens.vocab != t.vocab {
            bail!("token stream vocab {} != artifact vocab {}", tokens.vocab, t.vocab);
        }
        let grad_entry = rt.manifest.transformer_entry_name();
        let dual_entry = rt.manifest.dual_update_entry_name(t.param_count);
        rt.executable(&grad_entry)?;
        rt.executable(&dual_entry)?;
        let center = rt.manifest.transformer_init()?;
        Ok(TransformerExec {
            batch: t.batch,
            seq_len: t.seq_len,
            tokens,
            optimizer,
            grad_entry,
            dual_entry,
            rt,
            tok_buf: Vec::new(),
            mask_buf: Vec::new(),
            center,
            last_token_count: 0.0,
        })
    }

    pub fn init_params(&self) -> &[f32] {
        &self.center
    }
}

impl ExecEngine for TransformerExec {
    fn grad_chunk(
        &mut self,
        w: &[f32],
        n_samples: usize,
        rng: &mut Pcg64,
        acc: &mut [f32],
    ) -> f64 {
        self.last_token_count = 0.0;
        if n_samples == 0 {
            return 0.0;
        }
        let b = self.batch;
        let l = self.seq_len + 1;
        let p = w.len();
        let w_lit = lit_f32(&[p], w).unwrap();
        let mut remaining = n_samples;
        let mut loss = 0.0f64;
        while remaining > 0 {
            let take = remaining.min(b);
            self.tokens.sample_batch(rng, take, l, &mut self.tok_buf);
            self.tok_buf.resize(b * l, 0);
            self.mask_buf.clear();
            self.mask_buf.resize(b, 0.0);
            for m in self.mask_buf.iter_mut().take(take) {
                *m = 1.0;
            }
            let outs = self
                .rt
                .execute(
                    &self.grad_entry,
                    &[
                        w_lit.clone(),
                        lit_i32(&[b, l], &self.tok_buf).unwrap(),
                        lit_f32(&[b], &self.mask_buf).unwrap(),
                    ],
                )
                .expect("pjrt transformer grad failed");
            let g = to_f32(&outs[0]).expect("grad output");
            crate::util::axpy(1.0, &g, acc);
            loss += to_scalar(&outs[1]).expect("loss output") as f64;
            self.last_token_count += to_scalar(&outs[2]).expect("count output") as f64;
            remaining -= take;
        }
        loss
    }

    fn primal_step(&mut self, z: &[f32], t: usize, w: &mut [f32]) {
        let beta = self.optimizer.beta_at(t) as f32;
        let radius = self.optimizer.radius as f32;
        let outs = self
            .rt
            .execute(
                &self.dual_entry,
                &[lit_f32(&[z.len()], z).unwrap(), lit_scalar(beta), lit_scalar(radius)],
            )
            .expect("pjrt dual_update failed");
        let delta = to_f32(&outs[0]).expect("dual output");
        // centred h: w = w0 + clip_ball(−z/β, R)
        for k in 0..w.len() {
            w[k] = self.center[k] + delta[k];
        }
    }

    fn initial_primal(&self) -> Vec<f32> {
        self.center.clone()
    }

    fn workload(&self) -> Workload {
        Workload::Opaque { dim: self.rt.manifest.transformer.param_count }
    }

    fn error_metric(&mut self, _w: &[f32], _rng: &mut Pcg64) -> f64 {
        f64::NAN // per-token loss is already the tracked metric
    }
}
