//! Artifact manifest: the static-shape contract written by
//! python/compile/aot.py and consumed by the PJRT runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Input/output tensor spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Transformer artifact parameters.
#[derive(Debug, Clone)]
pub struct TransformerParams {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_count: usize,
    pub init_file: PathBuf,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub small: bool,
    pub linreg_c: usize,
    pub linreg_d: usize,
    pub logreg_c: usize,
    pub logreg_d: usize,
    pub logreg_k: usize,
    pub mix_n: usize,
    pub mix_d: usize,
    pub transformer: TransformerParams,
    pub entries: BTreeMap<String, Entry>,
}

fn req_usize(j: &Json, path: &str) -> Result<usize> {
    j.path(path)
        .and_then(|v| v.as_usize())
        .with_context(|| format!("manifest missing numeric field '{path}'"))
}

fn parse_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(|v| v.as_usize_arr())
        .context("spec missing shape")?;
    let dtype = match j.get("dtype").and_then(|v| v.as_str()) {
        Some("f32") => Dtype::F32,
        Some("i32") => Dtype::I32,
        other => bail!("unsupported dtype {other:?}"),
    };
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Manifest::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json invalid")?;
        if j.get("format").and_then(|v| v.as_str()) != Some("hlo-text-v1") {
            bail!("unsupported manifest format (want hlo-text-v1)");
        }
        let mut entries = BTreeMap::new();
        for e in j.get("entries").and_then(|v| v.as_arr()).context("no entries")? {
            let name = e.get("name").and_then(|v| v.as_str()).context("entry name")?;
            let file = e.get("file").and_then(|v| v.as_str()).context("entry file")?;
            let inputs = e
                .get("inputs")
                .and_then(|v| v.as_arr())
                .context("entry inputs")?
                .iter()
                .map(parse_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(|v| v.as_arr())
                .context("entry outputs")?
                .iter()
                .map(parse_spec)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.to_string(),
                Entry { name: name.to_string(), file: dir.join(file), inputs, outputs },
            );
        }
        let t = j.path("params.transformer").context("params.transformer")?;
        let transformer = TransformerParams {
            vocab: req_usize(t, "vocab")?,
            d_model: req_usize(t, "d_model")?,
            n_heads: req_usize(t, "n_heads")?,
            n_layers: req_usize(t, "n_layers")?,
            d_ff: req_usize(t, "d_ff")?,
            seq_len: req_usize(t, "seq_len")?,
            batch: req_usize(t, "batch")?,
            param_count: req_usize(t, "param_count")?,
            init_file: dir.join(
                t.get("init_file").and_then(|v| v.as_str()).unwrap_or("transformer_init.f32.bin"),
            ),
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            small: j.get("small").and_then(|v| v.as_bool()).unwrap_or(false),
            linreg_c: req_usize(&j, "params.linreg_c")?,
            linreg_d: req_usize(&j, "params.linreg_d")?,
            logreg_c: req_usize(&j, "params.logreg_c")?,
            logreg_d: req_usize(&j, "params.logreg_d")?,
            logreg_k: req_usize(&j, "params.logreg_k")?,
            mix_n: req_usize(&j, "params.mix_n")?,
            mix_d: req_usize(&j, "params.mix_d")?,
            transformer,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact entry '{name}' not in manifest"))
    }

    pub fn linreg_entry_name(&self) -> String {
        format!("linreg_grad_c{}_d{}", self.linreg_c, self.linreg_d)
    }

    pub fn logreg_entry_name(&self) -> String {
        format!("logreg_grad_c{}_k{}_d{}", self.logreg_c, self.logreg_k, self.logreg_d)
    }

    pub fn dual_update_entry_name(&self, dim: usize) -> String {
        format!("dual_update_d{dim}")
    }

    pub fn mix_entry_name(&self) -> String {
        format!("mix_n{}_d{}", self.mix_n, self.mix_d)
    }

    pub fn transformer_entry_name(&self) -> String {
        format!(
            "transformer_grad_p{}_b{}_t{}",
            self.transformer.param_count, self.transformer.batch, self.transformer.seq_len
        )
    }

    /// Read the transformer init-parameter blob.
    pub fn transformer_init(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&self.transformer.init_file)
            .with_context(|| format!("reading {}", self.transformer.init_file.display()))?;
        if bytes.len() != self.transformer.param_count * 4 {
            bail!(
                "init blob has {} bytes, expected {}",
                bytes.len(),
                self.transformer.param_count * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "small": true,
      "params": {
        "linreg_c": 32, "linreg_d": 64,
        "logreg_c": 16, "logreg_d": 24, "logreg_k": 4,
        "mix_n": 6, "mix_d": 64,
        "transformer": {"vocab": 64, "d_model": 32, "n_heads": 2,
                        "n_layers": 1, "d_ff": 64, "seq_len": 16,
                        "batch": 2, "param_count": 13088,
                        "init_file": "transformer_init.f32.bin"}
      },
      "entries": [
        {"name": "linreg_grad_c32_d64", "file": "linreg_grad_c32_d64.hlo.txt",
         "inputs": [{"shape": [64], "dtype": "f32"},
                    {"shape": [32, 64], "dtype": "f32"},
                    {"shape": [32], "dtype": "f32"},
                    {"shape": [32], "dtype": "f32"}],
         "outputs": [{"shape": [64], "dtype": "f32"},
                     {"shape": [], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert!(m.small);
        assert_eq!(m.linreg_c, 32);
        assert_eq!(m.transformer.param_count, 13088);
        assert_eq!(m.linreg_entry_name(), "linreg_grad_c32_d64");
        let e = m.entry("linreg_grad_c32_d64").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[1].shape, vec![32, 64]);
        assert_eq!(e.inputs[1].elements(), 2048);
        assert_eq!(e.outputs[1].shape, Vec::<usize>::new());
        assert_eq!(e.file, Path::new("/tmp/a/linreg_grad_c32_d64.hlo.txt"));
    }

    #[test]
    fn missing_entry_errors() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn bad_format_rejected() {
        let bad = SAMPLE.replace("hlo-text-v1", "v0");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn entry_names() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.logreg_entry_name(), "logreg_grad_c16_k4_d24");
        assert_eq!(m.dual_update_entry_name(64), "dual_update_d64");
        assert_eq!(m.mix_entry_name(), "mix_n6_d64");
        assert_eq!(m.transformer_entry_name(), "transformer_grad_p13088_b2_t16");
    }
}
