//! Deterministic fault-injection plane (ISSUE 8 tentpole).
//!
//! A [`FaultSpec`] on `RunSpec` describes three failure processes the
//! reliable baseline never exercises:
//!
//! * **iid packet loss** — every directed gossip message `(src → dst)`
//!   at consensus round `r` of epoch `t` is lost independently with
//!   probability `loss`;
//! * **Markov link flaps** — every undirected edge carries a two-state
//!   up/down chain stepped once per consensus round (fresh chain per
//!   epoch, started from the stationary distribution
//!   `π_down = p_down / (p_down + p_up)`), and a down link loses BOTH
//!   directions of that round's exchange;
//! * **crash windows** — a node is dead for an inclusive epoch range
//!   `[from, to]`.  Unlike planned churn (which freezes state and
//!   resumes it on rejoin), a crash LOSES the node's state: it is reset
//!   at onset, and the first post-crash epoch contributes zero mass to
//!   consensus so the update gate pulls the node back onto the
//!   neighborhood average (peer re-sync) before it computes again.
//!
//! Everything is a pure function of `(spec.seed, epoch, round, edge)`
//! evaluated through a fresh [`Pcg64`] stream per query — no draw-order
//! coupling, so fault runs join the threads=1 ≡ threads=k bitwise
//! contract, and the threaded runtime's receivers can recompute the
//! exact drop decisions the simulator made without any coordination.
//!
//! An all-clear spec ([`FaultSpec::none`], or any spec with zero loss,
//! no flap chain, and no crash windows) routes every consumer through
//! the stock fault-free code paths, so it reproduces the no-fault run
//! bit-for-bit by construction (DESIGN.md §fault-injection).

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::topology::Topology;
use crate::util::rng::Pcg64;

/// Stream-namespace tag for iid per-message loss draws.
const LOSS_NS: u64 = 0xFA17_1055;
/// Stream-namespace tag for per-edge flap chains.
const FLAP_NS: u64 = 0xFA17_F1A9;

/// SplitMix64 finalizer: avalanche a word so structured inputs
/// (small epoch/round/node indices) land on uncorrelated tags.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Collapse (namespace, epoch, round, src, dst) into one split tag.
/// Chained finalizers (not a single xor of shifted fields) so that no
/// two distinct coordinate tuples can collide by field overlap.
fn tag(ns: u64, epoch: usize, round: usize, src: usize, dst: usize) -> u64 {
    let a = mix64(ns.wrapping_add(epoch as u64));
    let b = mix64(a.wrapping_add(round as u64));
    mix64(b.wrapping_add(((src as u64) << 32) | dst as u64))
}

/// Markov link-flap parameters: per-round transition probabilities of
/// the undirected edge's up/down chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Flap {
    /// P(up → down) per consensus round.
    pub p_down: f64,
    /// P(down → up) per consensus round.
    pub p_up: f64,
}

impl Flap {
    /// Stationary probability of the down state — the chain's start
    /// distribution, so round 0 is already in steady state.
    pub fn pi_down(&self) -> f64 {
        if self.p_down + self.p_up <= 0.0 {
            0.0
        } else {
            self.p_down / (self.p_down + self.p_up)
        }
    }
}

/// One unplanned crash: `node` is dead for epochs `from..=to`
/// (`to == usize::MAX` never recovers).  Distinct from churn: state is
/// LOST at onset and rebuilt from peers at rejoin, not frozen/resumed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashWindow {
    pub node: usize,
    /// First dead epoch (1-based, like the epoch loop).
    pub from: usize,
    /// Last dead epoch, inclusive; `usize::MAX` = permanent.
    pub to: usize,
}

/// Directed drop set for one consensus round: `(dst, src)` pairs whose
/// round message was lost.  Keyed receiver-first because the mixing
/// kernel walks receivers' CSR rows.
pub type DropMask = HashSet<(u32, u32)>;

/// The fault plane: per-edge loss + flaps + crash windows, all derived
/// from `seed` (see module docs for semantics and determinism).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// iid loss probability per directed message, in `[0, 1]`.
    pub loss: f64,
    /// Optional Markov up/down chain per undirected edge.
    pub flap: Option<Flap>,
    /// Unplanned crash/restart windows.
    pub crashes: Vec<CrashWindow>,
    /// Fabric-only: seconds after a measured round STARTS before the
    /// receiver completes it with whatever neighborhood arrived
    /// (lost packets must not stall the event loop).  `0.0` = auto
    /// (`t_c / cap`, one fair share of the budget per round).
    pub round_timeout: f64,
    /// Dedicated fault seed (decoupled from the run seed so fault
    /// patterns can be varied against a fixed data/straggler draw).
    pub seed: u64,
}

impl FaultSpec {
    /// The all-clear plane: no losses, no flaps, no crashes.  Every
    /// consumer short-circuits on [`FaultSpec::is_none`], so this spec
    /// reproduces the fault-free run bit-for-bit.
    pub fn none() -> FaultSpec {
        FaultSpec { loss: 0.0, flap: None, crashes: Vec::new(), round_timeout: 0.0, seed: 0 }
    }

    /// True when the spec cannot produce any fault — the gate for the
    /// stock code paths (seed/timeout alone change nothing).
    pub fn is_none(&self) -> bool {
        self.loss <= 0.0 && self.flap.is_none() && self.crashes.is_empty()
    }

    /// True when messages can be lost (loss or flaps) — the part of the
    /// plane that degrades mixing rows and fabric rounds.
    pub fn has_link_faults(&self) -> bool {
        self.loss > 0.0 || self.flap.is_some()
    }

    pub fn has_crashes(&self) -> bool {
        !self.crashes.is_empty()
    }

    /// Shape/range validation against an `n`-node run (parse accepts
    /// any node id; the run knows the cluster size).
    pub fn validate(&self, n: usize) -> Result<()> {
        if !(0.0..=1.0).contains(&self.loss) {
            bail!("fault loss = {} not in [0, 1]", self.loss);
        }
        if let Some(f) = self.flap {
            for (name, p) in [("flap p_down", f.p_down), ("flap p_up", f.p_up)] {
                if !(0.0..=1.0).contains(&p) {
                    bail!("{name} = {p} not in [0, 1]");
                }
            }
        }
        if !(self.round_timeout.is_finite() && self.round_timeout >= 0.0) {
            bail!("fault round timeout must be finite and >= 0 (got {})", self.round_timeout);
        }
        for c in &self.crashes {
            if c.node >= n {
                bail!("crash window names node {} but the run has {n} nodes", c.node);
            }
            if c.from == 0 || c.from > c.to {
                bail!(
                    "crash window {}@{}..{} is empty or starts before epoch 1",
                    c.node,
                    c.from,
                    c.to
                );
            }
        }
        Ok(())
    }

    // ---- crash schedule (pure per (node, epoch)) ----

    /// Is `node` dead at epoch `t`?
    pub fn crashed(&self, node: usize, t: usize) -> bool {
        self.crashes.iter().any(|c| c.node == node && c.from <= t && t <= c.to)
    }

    /// Epoch `t` is the FIRST dead epoch of a window: the node's state
    /// (dual/primal/gradient ring) is reset exactly here.
    pub fn crash_onset(&self, node: usize, t: usize) -> bool {
        self.crashed(node, t) && (t == 0 || !self.crashed(node, t - 1))
    }

    /// Epoch `t` is the first ALIVE epoch after a window: the node
    /// participates in consensus with zero mass (no compute), so the
    /// update gate re-syncs it onto the neighborhood average.
    pub fn rejoining(&self, node: usize, t: usize) -> bool {
        t > 0 && !self.crashed(node, t) && self.crashed(node, t - 1)
    }

    /// Any node crashed at epoch `t`?
    pub fn any_crashed(&self, t: usize) -> bool {
        self.crashes.iter().any(|c| c.from <= t && t <= c.to)
    }

    // ---- link faults (pure per (epoch, round, edge)) ----

    /// Is the directed round-`round` message `src → dst` of epoch
    /// `epoch` lost?  Rounds are 0-based within the epoch's consensus
    /// phase.  This is THE canonical decision — the sim's per-epoch
    /// masks and the threaded receivers both evaluate it.
    pub fn dropped(&self, epoch: usize, round: usize, src: usize, dst: usize) -> bool {
        self.iid_dropped(epoch, round, src, dst) || self.flap_down(epoch, round, src, dst)
    }

    fn iid_dropped(&self, epoch: usize, round: usize, src: usize, dst: usize) -> bool {
        self.loss > 0.0
            && Pcg64::new(self.seed).split(tag(LOSS_NS, epoch, round, src, dst)).f64() < self.loss
    }

    /// Flap-chain state of the undirected edge `{a, b}` at round
    /// `round` of epoch `epoch` (true = down, both directions lost).
    /// Steps the chain from its stationary round-0 draw, so the cost is
    /// O(round) — fine for per-epoch round budgets; the sim batches
    /// whole epochs through [`FaultSpec::epoch_masks`] instead.
    pub fn flap_down(&self, epoch: usize, round: usize, a: usize, b: usize) -> bool {
        let Some(f) = self.flap else { return false };
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let mut rng = Pcg64::new(self.seed).split(tag(FLAP_NS, epoch, 0, lo, hi));
        let mut down = rng.f64() < f.pi_down();
        for _ in 0..round {
            down = if down { rng.f64() >= f.p_up } else { rng.f64() < f.p_down };
        }
        down
    }

    /// Materialize one epoch's drop masks for `rounds` consensus rounds
    /// over the ACTIVE edges of `topo` — the batched (edge-major) walk
    /// of [`FaultSpec::dropped`], stepping each flap chain once.
    /// `masks[r]` holds the `(dst, src)` pairs lost at round `r`.
    pub fn epoch_masks(
        &self,
        topo: &Topology,
        active: &[bool],
        epoch: usize,
        rounds: usize,
    ) -> Vec<DropMask> {
        let mut masks = vec![DropMask::new(); rounds];
        if !self.has_link_faults() || rounds == 0 {
            return masks;
        }
        let n = topo.n();
        for i in 0..n {
            if !active[i] {
                continue;
            }
            for &j in topo.neighbors(i) {
                // undirected edges once (i < j), active endpoints only
                if j <= i || !active[j] {
                    continue;
                }
                // one sequential chain walk per (edge, epoch)
                if let Some(f) = self.flap {
                    let mut rng = Pcg64::new(self.seed).split(tag(FLAP_NS, epoch, 0, i, j));
                    let mut down = rng.f64() < f.pi_down();
                    for mask in masks.iter_mut() {
                        if down {
                            mask.insert((i as u32, j as u32));
                            mask.insert((j as u32, i as u32));
                        }
                        down = if down { rng.f64() >= f.p_up } else { rng.f64() < f.p_down };
                    }
                }
                if self.loss > 0.0 {
                    for (r, mask) in masks.iter_mut().enumerate() {
                        if self.iid_dropped(epoch, r, i, j) {
                            mask.insert((j as u32, i as u32));
                        }
                        if self.iid_dropped(epoch, r, j, i) {
                            mask.insert((i as u32, j as u32));
                        }
                    }
                }
            }
        }
        masks
    }

    // ---- CLI / display ----

    /// Parse the `--faults` grammar: comma-separated `key=value` items
    /// (`crash=` may repeat).
    ///
    /// ```text
    /// loss=0.05,flap=0.1:0.5,crash=2@5..8,crash=3@4..,timeout=0.1,seed=7
    /// ```
    ///
    /// `flap=P_DOWN:P_UP`; `crash=NODE@FROM..TO` (inclusive epochs,
    /// `TO` omitted = permanent).  `default_seed` applies when no
    /// `seed=` item is given.
    pub fn parse(s: &str, default_seed: u64) -> Result<FaultSpec> {
        let mut spec = FaultSpec { seed: default_seed, ..FaultSpec::none() };
        for item in s.split(',').map(str::trim).filter(|it| !it.is_empty()) {
            let (key, val) = item
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault item '{item}' is not key=value"))?;
            match key {
                "loss" => {
                    spec.loss = val
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("fault loss '{val}' is not a number"))?;
                }
                "flap" => {
                    let (pd, pu) = val.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("flap '{val}' must be P_DOWN:P_UP")
                    })?;
                    let parse_p = |name: &str, s: &str| -> Result<f64> {
                        s.parse::<f64>()
                            .map_err(|_| anyhow::anyhow!("flap {name} '{s}' is not a number"))
                    };
                    spec.flap = Some(Flap {
                        p_down: parse_p("p_down", pd)?,
                        p_up: parse_p("p_up", pu)?,
                    });
                }
                "crash" => {
                    let (node, range) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("crash '{val}' must be NODE@FROM..TO")
                    })?;
                    let node = node
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("crash node '{node}' is not an index"))?;
                    let (from, to) = range.split_once("..").ok_or_else(|| {
                        anyhow::anyhow!("crash range '{range}' must be FROM..TO (or FROM..)")
                    })?;
                    let from = from
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("crash from '{from}' is not an epoch"))?;
                    let to = if to.is_empty() {
                        usize::MAX
                    } else {
                        to.parse::<usize>()
                            .map_err(|_| anyhow::anyhow!("crash to '{to}' is not an epoch"))?
                    };
                    spec.crashes.push(CrashWindow { node, from, to });
                }
                "timeout" => {
                    spec.round_timeout = val.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("fault timeout '{val}' is not a number")
                    })?;
                }
                "seed" => {
                    spec.seed = val
                        .parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("fault seed '{val}' is not an integer"))?;
                }
                other => bail!(
                    "unknown fault key '{other}' (expected loss/flap/crash/timeout/seed)"
                ),
            }
        }
        // Grammar-level range checks (node-count checks wait for the run).
        if !(0.0..=1.0).contains(&spec.loss) {
            bail!("fault loss = {} not in [0, 1]", spec.loss);
        }
        Ok(spec)
    }

    /// Short human label for run headers and CSV rows.
    pub fn label(&self) -> String {
        if self.is_none() {
            return "none".to_string();
        }
        let mut parts = Vec::new();
        if self.loss > 0.0 {
            parts.push(format!("loss={}", self.loss));
        }
        if let Some(f) = self.flap {
            parts.push(format!("flap={}:{}", f.p_down, f.p_up));
        }
        for c in &self.crashes {
            if c.to == usize::MAX {
                parts.push(format!("crash={}@{}..", c.node, c.from));
            } else {
                parts.push(format!("crash={}@{}..{}", c.node, c.from, c.to));
            }
        }
        parts.join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_none_and_all_clear() {
        let f = FaultSpec::none();
        assert!(f.is_none());
        assert!(!f.has_link_faults());
        assert!(!f.has_crashes());
        assert!(!f.dropped(3, 2, 0, 1));
        assert!(!f.crashed(0, 5));
        assert_eq!(f.label(), "none");
        // seed/timeout alone keep the spec all-clear
        let g = FaultSpec { seed: 99, round_timeout: 0.5, ..FaultSpec::none() };
        assert!(g.is_none());
        f.validate(4).unwrap();
    }

    #[test]
    fn drops_are_deterministic_pure_functions() {
        let f = FaultSpec { loss: 0.3, ..FaultSpec::none() };
        for (e, r, s, d) in [(1, 0, 0, 1), (1, 1, 0, 1), (2, 0, 1, 0), (7, 3, 4, 2)] {
            assert_eq!(f.dropped(e, r, s, d), f.dropped(e, r, s, d));
        }
        // directed: src→dst and dst→src are independent draws — over
        // many edges they must disagree somewhere at 30% loss
        let mut asym = false;
        for e in 1..40 {
            if f.dropped(e, 0, 0, 1) != f.dropped(e, 0, 1, 0) {
                asym = true;
            }
        }
        assert!(asym, "iid loss should be per-direction");
        // a different fault seed changes the pattern
        let g = FaultSpec { seed: 1, ..f.clone() };
        let diff = (1..60).any(|e| f.dropped(e, 0, 0, 1) != g.dropped(e, 0, 0, 1));
        assert!(diff, "fault seed must matter");
    }

    #[test]
    fn loss_rate_is_roughly_honored() {
        let f = FaultSpec { loss: 0.25, ..FaultSpec::none() };
        let mut hits = 0usize;
        let total = 4000usize;
        for k in 0..total {
            if f.dropped(k / 10, k % 10, 0, 1) {
                hits += 1;
            }
        }
        let rate = hits as f64 / total as f64;
        assert!((rate - 0.25).abs() < 0.03, "empirical loss {rate}");
    }

    #[test]
    fn flap_is_symmetric_and_markov() {
        let f = FaultSpec {
            flap: Some(Flap { p_down: 0.2, p_up: 0.4 }),
            ..FaultSpec::none()
        };
        // undirected: both orientations read the same chain
        for e in 1..20 {
            for r in 0..6 {
                assert_eq!(f.flap_down(e, r, 2, 5), f.flap_down(e, r, 5, 2));
            }
        }
        // persistence: a down round is more often followed by down than
        // the stationary rate would give (p_up = 0.4 ⇒ P(down→down)=0.6
        // vs π_down = 1/3)
        let (mut down_then_down, mut downs) = (0usize, 0usize);
        for e in 1..400 {
            if f.flap_down(e, 0, 0, 1) {
                downs += 1;
                if f.flap_down(e, 1, 0, 1) {
                    down_then_down += 1;
                }
            }
        }
        assert!(downs > 50, "stationary start should produce downs");
        let persist = down_then_down as f64 / downs as f64;
        assert!(persist > 0.45, "flap chain not persistent: {persist}");
        // degenerate chains
        let up_only = FaultSpec {
            flap: Some(Flap { p_down: 0.0, p_up: 0.5 }),
            ..FaultSpec::none()
        };
        for r in 0..8 {
            assert!(!up_only.flap_down(1, r, 0, 1), "p_down=0 can never go down");
        }
    }

    #[test]
    fn epoch_masks_match_pointwise_queries() {
        let topo = Topology::ring(6);
        let f = FaultSpec {
            loss: 0.2,
            flap: Some(Flap { p_down: 0.15, p_up: 0.5 }),
            ..FaultSpec::none()
        };
        let active = vec![true, true, false, true, true, true];
        let rounds = 5;
        for epoch in 1..=4 {
            let masks = f.epoch_masks(&topo, &active, epoch, rounds);
            assert_eq!(masks.len(), rounds);
            for (r, mask) in masks.iter().enumerate() {
                for i in 0..topo.n() {
                    for &j in topo.neighbors(i) {
                        let expect = active[i] && active[j] && f.dropped(epoch, r, j, i);
                        assert_eq!(
                            mask.contains(&(i as u32, j as u32)),
                            expect,
                            "epoch {epoch} round {r} edge {j}->{i}"
                        );
                    }
                }
                // masks never name inactive endpoints
                for &(d, s) in mask {
                    assert!(active[d as usize] && active[s as usize]);
                }
            }
        }
        // all-clear spec: every mask empty
        for mask in FaultSpec::none().epoch_masks(&topo, &active, 1, rounds) {
            assert!(mask.is_empty());
        }
    }

    #[test]
    fn crash_schedule_onset_and_rejoin() {
        let f = FaultSpec {
            crashes: vec![
                CrashWindow { node: 2, from: 3, to: 5 },
                CrashWindow { node: 0, from: 7, to: usize::MAX },
            ],
            ..FaultSpec::none()
        };
        assert!(!f.crashed(2, 2));
        assert!(f.crashed(2, 3) && f.crashed(2, 4) && f.crashed(2, 5));
        assert!(!f.crashed(2, 6));
        assert!(f.crash_onset(2, 3) && !f.crash_onset(2, 4));
        assert!(f.rejoining(2, 6) && !f.rejoining(2, 7) && !f.rejoining(2, 5));
        // permanent crash never rejoins
        assert!(f.crashed(0, 7) && f.crashed(0, 1_000_000));
        assert!(f.crash_onset(0, 7));
        assert!(!f.rejoining(0, 1_000_000));
        // other nodes untouched
        assert!(!f.crashed(1, 4));
        assert!(f.any_crashed(4) && !f.any_crashed(2));
        f.validate(3).unwrap();
        assert!(f.validate(2).is_err(), "node 2 out of range for n=2");
    }

    #[test]
    fn parse_grammar_roundtrips() {
        let f = FaultSpec::parse("loss=0.05", 42).unwrap();
        assert_eq!(f.loss, 0.05);
        assert_eq!(f.seed, 42);
        assert!(f.flap.is_none() && f.crashes.is_empty());

        let f = FaultSpec::parse("loss=0.1,flap=0.2:0.5,crash=2@5..8,crash=3@4..,seed=7", 0)
            .unwrap();
        assert_eq!(f.loss, 0.1);
        assert_eq!(f.flap, Some(Flap { p_down: 0.2, p_up: 0.5 }));
        assert_eq!(
            f.crashes,
            vec![
                CrashWindow { node: 2, from: 5, to: 8 },
                CrashWindow { node: 3, from: 4, to: usize::MAX },
            ]
        );
        assert_eq!(f.seed, 7);
        assert_eq!(f.label(), "loss=0.1,flap=0.2:0.5,crash=2@5..8,crash=3@4..");

        let f = FaultSpec::parse("timeout=0.25", 0).unwrap();
        assert!(f.is_none());
        assert_eq!(f.round_timeout, 0.25);

        for bad in [
            "loss=2",        // out of range
            "loss=abc",      // not a number
            "flap=0.5",      // missing p_up
            "crash=2",       // missing window
            "crash=2@5",     // missing range
            "wat=1",         // unknown key
            "loss",          // not key=value
        ] {
            assert!(FaultSpec::parse(bad, 0).is_err(), "'{bad}' should fail");
        }
        // validate catches empty/0-based windows
        let f = FaultSpec::parse("crash=1@0..3", 0).unwrap();
        assert!(f.validate(4).is_err());
        let f = FaultSpec::parse("crash=1@5..3", 0).unwrap();
        assert!(f.validate(4).is_err());
    }

    #[test]
    fn tags_do_not_collide_across_coordinates() {
        // smoke: distinct (epoch, round, src, dst) tuples map to
        // distinct tags over a small grid (collisions here would couple
        // supposedly independent drop decisions)
        let mut seen = HashSet::new();
        for e in 0..6 {
            for r in 0..6 {
                for s in 0..6 {
                    for d in 0..6 {
                        assert!(seen.insert(tag(LOSS_NS, e, r, s, d)));
                    }
                }
            }
        }
    }
}
