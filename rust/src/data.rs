//! Synthetic data generators for the paper's workloads (online streams).
//!
//! * [`LinRegStream`] — paper Sec. 6.1: w* ~ N(0, I), x ~ N(0, I),
//!   y = xᵀw* + η with η ~ N(0, 10⁻³).  The paper uses d = 10⁵; our
//!   figures use a smaller d (configurable) since the AMB-vs-FMB
//!   comparison is dimension-shape independent (DESIGN.md §2).
//! * [`MnistLike`] — substitution for MNIST (no network in the build
//!   env): a seeded 10-class Gaussian-mixture in 784-d with a bias
//!   coordinate appended (d = 785), matching the logistic-regression
//!   geometry of paper Sec. 6.2.2.
//! * [`TokenStream`] — synthetic language for the end-to-end transformer
//!   example: per-sequence affine progressions over the vocabulary, so
//!   next-token prediction is learnable but not trivial.
//!
//! All generators are deterministic functions of their seed.

use crate::util::rng::Pcg64;

/// Streaming linear-regression source with known ground truth.
pub struct LinRegStream {
    pub d: usize,
    pub w_star: Vec<f32>,
    pub noise_std: f64,
}

impl LinRegStream {
    pub fn new(d: usize, seed: u64) -> LinRegStream {
        let mut rng = Pcg64::new(seed ^ 0x11_22);
        let mut w_star = vec![0.0f32; d];
        rng.fill_normal_f32(&mut w_star, 1.0);
        LinRegStream { d, w_star, noise_std: (1e-3f64).sqrt() }
    }

    /// Sample `c` rows into row-major `x` (c × d) and targets `y`.
    pub fn sample_chunk(&self, rng: &mut Pcg64, c: usize, x: &mut Vec<f32>, y: &mut Vec<f32>) {
        x.resize(c * self.d, 0.0);
        y.resize(c, 0.0);
        for i in 0..c {
            let row = &mut x[i * self.d..(i + 1) * self.d];
            rng.fill_normal_f32(row, 1.0);
            let clean = crate::util::dot(row, &self.w_star);
            y[i] = clean + (rng.normal() * self.noise_std) as f32;
        }
    }

    /// Population excess risk of `w`:
    /// F(w) − F(w*) = 0.5‖w − w*‖² for x ~ N(0, I) — the error metric the
    /// paper's Fig. 1a/4/5 plot (up to the additive noise floor).
    pub fn excess_risk(&self, w: &[f32]) -> f64 {
        assert_eq!(w.len(), self.d);
        let mut ss = 0.0f64;
        for i in 0..self.d {
            let diff = (w[i] - self.w_star[i]) as f64;
            ss += diff * diff;
        }
        0.5 * ss
    }
}

/// 10-class Gaussian mixture standing in for MNIST (c classes, d features
/// including the trailing bias-1 coordinate).
pub struct MnistLike {
    pub classes: usize,
    /// Feature count *excluding* bias.
    pub raw_d: usize,
    /// mean matrix, classes × raw_d.
    means: Vec<f32>,
    pub noise_std: f32,
    /// Separation scale between class means.
    pub sep: f32,
}

impl MnistLike {
    /// MNIST geometry: 10 classes × 784 pixels (+bias ⇒ 785).
    pub fn mnist_shaped(seed: u64) -> MnistLike {
        MnistLike::new(10, 784, 1.0, 1.0, seed)
    }

    pub fn new(classes: usize, raw_d: usize, sep: f32, noise_std: f32, seed: u64) -> MnistLike {
        let mut rng = Pcg64::new(seed ^ 0x33_44);
        let mut means = vec![0.0f32; classes * raw_d];
        rng.fill_normal_f32(&mut means, sep / (raw_d as f32).sqrt());
        MnistLike { classes, raw_d, means, noise_std, sep }
    }

    /// Total feature dimension (bias included).
    pub fn d(&self) -> usize {
        self.raw_d + 1
    }

    /// Sample `c` labelled rows: x (c × d(), bias last), labels (c).
    pub fn sample_chunk(
        &self,
        rng: &mut Pcg64,
        c: usize,
        x: &mut Vec<f32>,
        labels: &mut Vec<i32>,
    ) {
        let d = self.d();
        x.resize(c * d, 0.0);
        labels.resize(c, 0);
        for i in 0..c {
            let cls = rng.below(self.classes as u64) as usize;
            labels[i] = cls as i32;
            let mean = &self.means[cls * self.raw_d..(cls + 1) * self.raw_d];
            let row = &mut x[i * d..(i + 1) * d];
            for j in 0..self.raw_d {
                row[j] = mean[j] + (rng.normal() as f32) * self.noise_std / (self.raw_d as f32).sqrt();
            }
            row[self.raw_d] = 1.0; // bias
        }
    }

    /// Bayes-optimal-ish accuracy of weights `w` (classes × d) on fresh
    /// samples — a sanity metric for training progress.
    pub fn accuracy(&self, w: &[f32], rng: &mut Pcg64, samples: usize) -> f64 {
        let d = self.d();
        assert_eq!(w.len(), self.classes * d);
        let mut x = Vec::new();
        let mut labels = Vec::new();
        self.sample_chunk(rng, samples, &mut x, &mut labels);
        let mut correct = 0usize;
        for i in 0..samples {
            let row = &x[i * d..(i + 1) * d];
            let mut best = (f32::NEG_INFINITY, 0usize);
            for k in 0..self.classes {
                let s = crate::util::dot(&w[k * d..(k + 1) * d], row);
                if s > best.0 {
                    best = (s, k);
                }
            }
            if best.1 as i32 == labels[i] {
                correct += 1;
            }
        }
        correct as f64 / samples as f64
    }
}

/// Synthetic token sequences: each sequence follows
/// x_{s+1} = (a·x_s + b) mod V for per-sequence (a, b) drawn from a small
/// set, so the conditional next-token distribution is deterministic given
/// context — learnable by a small LM, with loss → 0 as it learns.
pub struct TokenStream {
    pub vocab: usize,
    pairs: Vec<(u32, u32)>,
}

impl TokenStream {
    pub fn new(vocab: usize, seed: u64) -> TokenStream {
        assert!(vocab >= 8);
        let mut rng = Pcg64::new(seed ^ 0x55_66);
        // 8 distinct affine rules with a odd (invertible mod 2^k vocabs)
        let mut pairs = Vec::new();
        while pairs.len() < 8 {
            let a = (rng.below(vocab as u64 / 2) * 2 + 1) as u32;
            let b = rng.below(vocab as u64) as u32;
            if !pairs.contains(&(a, b)) {
                pairs.push((a, b));
            }
        }
        TokenStream { vocab, pairs }
    }

    /// Sample `batch` sequences of `len` tokens (i32 for the i32 HLO
    /// input), row-major batch × len.
    pub fn sample_batch(&self, rng: &mut Pcg64, batch: usize, len: usize, out: &mut Vec<i32>) {
        out.resize(batch * len, 0);
        for s in 0..batch {
            let (a, b) = self.pairs[rng.below(self.pairs.len() as u64) as usize];
            let mut x = rng.below(self.vocab as u64) as u32;
            let row = &mut out[s * len..(s + 1) * len];
            for t in row.iter_mut() {
                *t = x as i32;
                x = (a.wrapping_mul(x).wrapping_add(b)) % self.vocab as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn linreg_labels_near_clean_signal() {
        let s = LinRegStream::new(64, 0);
        let mut rng = Pcg64::new(1);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        s.sample_chunk(&mut rng, 500, &mut x, &mut y);
        let mut resid = 0.0f64;
        for i in 0..500 {
            let clean = crate::util::dot(&x[i * 64..(i + 1) * 64], &s.w_star);
            resid += ((y[i] - clean) as f64).powi(2);
        }
        let mse = resid / 500.0;
        assert!((mse - 1e-3).abs() < 5e-4, "noise mse={mse}");
    }

    #[test]
    fn linreg_excess_risk_zero_at_optimum() {
        let s = LinRegStream::new(32, 2);
        assert_eq!(s.excess_risk(&s.w_star), 0.0);
        let w0 = vec![0.0f32; 32];
        let expect: f64 = 0.5 * s.w_star.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        assert!((s.excess_risk(&w0) - expect).abs() < 1e-6);
    }

    #[test]
    fn linreg_deterministic_given_seed() {
        let a = LinRegStream::new(16, 9);
        let b = LinRegStream::new(16, 9);
        assert_eq!(a.w_star, b.w_star);
        let (mut xa, mut ya) = (Vec::new(), Vec::new());
        let (mut xb, mut yb) = (Vec::new(), Vec::new());
        a.sample_chunk(&mut Pcg64::new(5), 8, &mut xa, &mut ya);
        b.sample_chunk(&mut Pcg64::new(5), 8, &mut xb, &mut yb);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn mnist_like_shapes_and_bias() {
        let m = MnistLike::mnist_shaped(0);
        assert_eq!(m.d(), 785);
        let mut rng = Pcg64::new(0);
        let (mut x, mut labels) = (Vec::new(), Vec::new());
        m.sample_chunk(&mut rng, 10, &mut x, &mut labels);
        assert_eq!(x.len(), 10 * 785);
        for i in 0..10 {
            assert_eq!(x[i * 785 + 784], 1.0); // bias coordinate
            assert!((0..10).contains(&labels[i]));
        }
    }

    #[test]
    fn mnist_like_mean_classifier_beats_chance() {
        // Classifier built from the true means should be well above 10%.
        let m = MnistLike::new(10, 64, 4.0, 1.0, 3);
        let d = m.d();
        let mut w = vec![0.0f32; 10 * d];
        for k in 0..10 {
            for j in 0..64 {
                w[k * d + j] = m.means[k * 64 + j];
            }
        }
        let mut rng = Pcg64::new(7);
        let acc = m.accuracy(&w, &mut rng, 2000);
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn mnist_like_all_classes_sampled() {
        let m = MnistLike::new(10, 8, 1.0, 1.0, 5);
        let mut rng = Pcg64::new(8);
        let (mut x, mut labels) = (Vec::new(), Vec::new());
        m.sample_chunk(&mut rng, 2000, &mut x, &mut labels);
        let mut seen = [false; 10];
        for &l in &labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn token_stream_in_vocab_and_deterministic_rule() {
        forall(20, 0xDA_7A, |g| {
            let ts = TokenStream::new(64, g.u64());
            let mut rng = Pcg64::new(g.u64());
            let mut out = Vec::new();
            ts.sample_batch(&mut rng, 4, 20, &mut out);
            crate::prop_assert!(out.iter().all(|&t| (0..64).contains(&t)));
            // consecutive tokens follow one of the 8 affine rules
            for s in 0..4 {
                let row = &out[s * 20..(s + 1) * 20];
                let consistent = ts.pairs.iter().any(|&(a, b)| {
                    row.windows(2).all(|w| {
                        (a.wrapping_mul(w[0] as u32).wrapping_add(b)) % 64 == w[1] as u32
                    })
                });
                crate::prop_assert!(consistent);
            }
            Ok(())
        });
    }

    #[test]
    fn token_stream_uses_multiple_rules() {
        let ts = TokenStream::new(128, 1);
        let mut rng = Pcg64::new(2);
        let mut out = Vec::new();
        ts.sample_batch(&mut rng, 64, 8, &mut out);
        // with 64 sequences over 8 rules, first-step deltas should vary
        let mut firsts = std::collections::BTreeSet::new();
        for s in 0..64 {
            let a = out[s * 8] as i64;
            let b = out[s * 8 + 1] as i64;
            firsts.insert((b - a).rem_euclid(128));
        }
        assert!(firsts.len() > 2);
    }
}
