//! Deterministic discrete-event queue — the fabric's scheduler core.
//!
//! A [`std::collections::BinaryHeap`] min-heap of `(time, seq)`-ordered
//! entries (the executor pattern of SNIPPETS.md Snippet 1): absolute
//! `f64` timestamps compared with `total_cmp`, plus a monotone sequence
//! number breaking ties so two events at the same instant pop in push
//! order (FIFO).  Every pop order — and everything derived from one —
//! is therefore a pure function of the push sequence, independent of
//! heap internals, which is what lets fabric-measured round counts join
//! the bitwise determinism contract (DESIGN.md §network-fabric).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event.  The `Ord` is REVERSED (earlier time = greater)
/// because `BinaryHeap` is a max-heap and we need the earliest event on
/// top — SNIPPETS.md Snippet 1's `other.cmp(&self)` trick, extended
/// with the sequence tie-break.
struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed on time (min-heap), then reversed on seq (FIFO ties)
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Event queue with an absolute virtual clock.
///
/// `now` only moves forward ([`EventQueue::pop`] advances it to the
/// popped event's timestamp); scheduling into the past is a logic error
/// and panics rather than silently reordering causality.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    now: f64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current virtual time (the timestamp of the last popped event;
    /// 0.0 before the first pop).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute `time` (≥ `now`, finite).
    pub fn push(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "event time must be finite (got {time})");
        assert!(
            time >= self.now,
            "event scheduled in the past: {time} < now {}",
            self.now
        );
        self.heap.push(Entry { time, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Timestamp of the earliest pending event, if any — lets a driver
    /// stop cleanly at a deadline without popping past it.
    pub fn next_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.payload))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.next_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_times_pop_in_push_order() {
        // The tie-break is the determinism linchpin: an ideal (zero
        // latency, unconstrained bandwidth) fabric schedules EVERYTHING
        // at t = 0, and the pop order must still be reproducible.
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(0.0, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((0.0, i)), "FIFO violated at {i}");
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(1.0, 10);
        q.push(5.0, 50);
        assert_eq!(q.pop(), Some((1.0, 10)));
        assert_eq!(q.now(), 1.0);
        // scheduling from a handler: at `now`, and later
        q.push(1.0, 11);
        q.push(2.0, 20);
        assert_eq!(q.pop(), Some((1.0, 11)));
        assert_eq!(q.pop(), Some((2.0, 20)));
        assert_eq!(q.pop(), Some((5.0, 50)));
    }

    #[test]
    fn now_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(0.5, ());
        q.push(0.5, ());
        q.push(1.5, ());
        let mut last = 0.0;
        while let Some((t, ())) = q.pop() {
            assert!(t >= last);
            assert_eq!(q.now(), t);
            last = t;
        }
    }

    #[test]
    #[should_panic(expected = "in the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push(2.0, ());
        q.pop();
        q.push(1.0, ());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_infinite_time() {
        let mut q = EventQueue::new();
        q.push(f64::INFINITY, ());
    }
}
