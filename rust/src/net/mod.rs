//! Network fabric for the consensus plane (ISSUE 6).
//!
//! `Abstract` is the paper's model: T_c buys a fixed, configured number
//! of gossip rounds regardless of topology or message size.  `Fabric`
//! replaces that free parameter with a measurement — a deterministic
//! discrete-event simulation of per-link transmissions (latency,
//! bandwidth, port contention, optional pacing) that derives "rounds
//! completed within T_c" per node, then feeds the per-node budgets to
//! the same freeze machinery the jitter ablation uses.  Message size
//! comes from the wire-row codec: `dim + 1` f32s per gossip row.
//!
//! Everything is a pure function of (spec, seed): the event queue
//! breaks timestamp ties by push order, so fabric runs join the
//! threads=1 ≡ threads=k bitwise contract and the golden-trace gate.

pub mod event;
pub mod fabric;
pub mod link;

pub use event::EventQueue;
pub use fabric::{measure_rounds, FabricRounds, FabricSpec};
pub use link::{LinkClass, Port, RateLimiter};

use anyhow::{bail, Result};

/// How the consensus phase's communication is modeled.
#[derive(Debug, Clone, PartialEq)]
pub enum NetworkModel {
    /// Abstract round budget (paper model, default): `ConsensusMode`
    /// alone decides how many gossip rounds run.
    Abstract,
    /// Discrete-event link fabric: per-node rounds are measured from
    /// topology, message size, and congestion within `T_c`.
    Fabric(FabricSpec),
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel::Abstract
    }
}

impl NetworkModel {
    pub fn is_abstract(&self) -> bool {
        matches!(self, NetworkModel::Abstract)
    }

    pub fn name(&self) -> &'static str {
        match self {
            NetworkModel::Abstract => "abstract",
            NetworkModel::Fabric(_) => "fabric",
        }
    }

    /// Parse the `--net` CLI value.
    ///
    /// * `abstract` — the default paper model;
    /// * `ideal` — zero-latency, unconstrained-bandwidth fabric (the
    ///   bitwise-parity configuration);
    /// * `key=val,...` — a fabric from keys `lat` (s), `bw` (bytes/s,
    ///   `inf` allowed), `wan-lat`, `wan-bw`, `groups`, `gap` (s).
    ///   WAN keys default to the local values; `groups` defaults to 1.
    pub fn parse(s: &str) -> Result<NetworkModel> {
        let s = s.trim();
        match s {
            "" => bail!("empty --net value (try 'abstract', 'ideal', or 'lat=...,bw=...')"),
            "abstract" => return Ok(NetworkModel::Abstract),
            "ideal" => return Ok(NetworkModel::Fabric(FabricSpec::ideal())),
            _ => {}
        }
        let mut lat = 0.0f64;
        let mut bw = f64::INFINITY;
        let mut wan_lat: Option<f64> = None;
        let mut wan_bw: Option<f64> = None;
        let mut groups = 1usize;
        let mut gap = 0.0f64;
        for part in s.split(',') {
            let Some((k, v)) = part.split_once('=') else {
                bail!("--net: expected key=value, got '{part}'");
            };
            let (k, v) = (k.trim(), v.trim());
            let fval = |key: &str| -> Result<f64> {
                match v.parse::<f64>() {
                    Ok(x) => Ok(x),
                    Err(_) => bail!("--net: {key}='{v}' is not a number"),
                }
            };
            match k {
                "lat" => lat = fval(k)?,
                "bw" => bw = fval(k)?,
                "wan-lat" => wan_lat = Some(fval(k)?),
                "wan-bw" => wan_bw = Some(fval(k)?),
                "gap" => gap = fval(k)?,
                "groups" => {
                    groups = match v.parse::<usize>() {
                        Ok(g) if g >= 1 => g,
                        _ => bail!("--net: groups='{v}' must be an integer >= 1"),
                    }
                }
                _ => bail!(
                    "--net: unknown key '{k}' (known: lat, bw, wan-lat, wan-bw, groups, gap)"
                ),
            }
        }
        if !(lat.is_finite() && lat >= 0.0) {
            bail!("--net: lat must be finite and >= 0");
        }
        if !(bw > 0.0) {
            bail!("--net: bw must be > 0 (use 'inf' for unconstrained)");
        }
        let mut fab = FabricSpec::uniform(lat, bw).with_min_gap(gap);
        if wan_lat.is_some() || wan_bw.is_some() || groups > 1 {
            let wl = wan_lat.unwrap_or(lat);
            let wb = wan_bw.unwrap_or(bw);
            if !(wl.is_finite() && wl >= 0.0) {
                bail!("--net: wan-lat must be finite and >= 0");
            }
            if !(wb > 0.0) {
                bail!("--net: wan-bw must be > 0 (use 'inf' for unconstrained)");
            }
            fab = fab.with_wan(wl, wb, groups);
        }
        Ok(NetworkModel::Fabric(fab))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_named_forms() {
        assert_eq!(NetworkModel::parse("abstract").unwrap(), NetworkModel::Abstract);
        assert_eq!(
            NetworkModel::parse("ideal").unwrap(),
            NetworkModel::Fabric(FabricSpec::ideal())
        );
        assert!(NetworkModel::parse("").is_err());
        assert!(NetworkModel::parse("bogus").is_err());
    }

    #[test]
    fn parse_uniform_fabric() {
        let m = NetworkModel::parse("lat=0.005,bw=2e5").unwrap();
        assert_eq!(m, NetworkModel::Fabric(FabricSpec::uniform(0.005, 2.0e5)));
        assert_eq!(m.name(), "fabric");
        assert!(!m.is_abstract());
    }

    #[test]
    fn parse_inf_bandwidth_and_gap() {
        let m = NetworkModel::parse("lat=0.01,bw=inf,gap=0.002").unwrap();
        assert_eq!(
            m,
            NetworkModel::Fabric(FabricSpec::uniform(0.01, f64::INFINITY).with_min_gap(0.002))
        );
    }

    #[test]
    fn parse_wan_split() {
        let m = NetworkModel::parse("lat=0.001,bw=1e6,wan-lat=0.05,wan-bw=1e5,groups=2").unwrap();
        let want = FabricSpec::uniform(0.001, 1.0e6).with_wan(0.05, 1.0e5, 2);
        assert_eq!(m, NetworkModel::Fabric(want));
        // groups alone (WAN class defaults to local values)
        let m = NetworkModel::parse("lat=0.001,bw=1e6,groups=4").unwrap();
        let want = FabricSpec::uniform(0.001, 1.0e6).with_wan(0.001, 1.0e6, 4);
        assert_eq!(m, NetworkModel::Fabric(want));
    }

    #[test]
    fn parse_rejections() {
        assert!(NetworkModel::parse("lat=fast").is_err());
        assert!(NetworkModel::parse("bw=0").is_err());
        assert!(NetworkModel::parse("lat=-1").is_err());
        assert!(NetworkModel::parse("groups=0").is_err());
        assert!(NetworkModel::parse("speed=9").is_err());
        assert!(NetworkModel::parse("lat").is_err());
        assert!(NetworkModel::parse("wan-bw=0,groups=2").is_err());
    }

    #[test]
    fn default_is_abstract() {
        assert_eq!(NetworkModel::default(), NetworkModel::Abstract);
        assert!(NetworkModel::default().is_abstract());
        assert_eq!(NetworkModel::Abstract.name(), "abstract");
    }
}
