//! Link-level building blocks: latency/bandwidth link classes, NIC
//! ports with store-and-forward serialization, and an optional
//! rate-limiter pacing egress transmissions (the clocked-engine idiom
//! from the gwr reference in SNIPPETS.md).
//!
//! All times are absolute seconds on the fabric's virtual clock; all
//! sizes are bytes.  `bandwidth = f64::INFINITY` means unconstrained
//! (zero serialization time), which is what makes the ideal fabric
//! reproduce the abstract consensus path bitwise.

/// A class of physical link: propagation latency (seconds, one-way) and
/// bandwidth (bytes/second).  `Copy` so edge classification stays
/// allocation-free in the event loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkClass {
    pub latency: f64,
    pub bandwidth: f64,
}

impl LinkClass {
    /// Zero-latency, unconstrained-bandwidth link — the fabric that must
    /// reproduce `NetworkModel::Abstract` bit for bit.
    pub const IDEAL: LinkClass = LinkClass { latency: 0.0, bandwidth: f64::INFINITY };

    pub fn new(latency: f64, bandwidth: f64) -> LinkClass {
        assert!(
            latency.is_finite() && latency >= 0.0,
            "link latency must be finite and >= 0 (got {latency})"
        );
        assert!(
            bandwidth > 0.0,
            "link bandwidth must be > 0 bytes/s or infinite (got {bandwidth})"
        );
        LinkClass { latency, bandwidth }
    }

    /// Serialization (transmission) time for `bytes` on this link.
    pub fn tx_time(&self, bytes: usize) -> f64 {
        if self.bandwidth.is_finite() {
            bytes as f64 / self.bandwidth
        } else {
            0.0
        }
    }
}

/// Paces transmission STARTS to at least `min_gap` seconds apart —
/// models a token-bucket-style shaper on a node's egress.
#[derive(Debug, Clone)]
pub struct RateLimiter {
    min_gap: f64,
    next_start: f64,
}

impl RateLimiter {
    pub fn new(min_gap: f64) -> RateLimiter {
        assert!(
            min_gap.is_finite() && min_gap >= 0.0,
            "rate-limiter min gap must be finite and >= 0 (got {min_gap})"
        );
        RateLimiter { min_gap, next_start: 0.0 }
    }

    /// Earliest permitted start at or after `t`; reserves the slot.
    pub fn reserve(&mut self, t: f64) -> f64 {
        let start = t.max(self.next_start);
        self.next_start = start + self.min_gap;
        start
    }
}

/// One NIC port (egress or ingress) on a node.  Store-and-forward: the
/// port serializes one message at a time, so a second message queued at
/// the same instant starts only when the first finishes — this is where
/// hub-spoke uplink contention comes from.
#[derive(Debug, Clone)]
pub struct Port {
    free_at: f64,
    limiter: Option<RateLimiter>,
}

impl Port {
    /// `min_gap > 0` attaches a rate limiter; 0 means unpaced.
    pub fn new(min_gap: f64) -> Port {
        let limiter = if min_gap > 0.0 { Some(RateLimiter::new(min_gap)) } else { None };
        Port { free_at: 0.0, limiter }
    }

    /// Occupy the port for a transmission of duration `dur` requested at
    /// time `now`; returns `(start, end)`.  Queueing delay (port busy)
    /// and pacing (limiter) both push `start` later.
    pub fn occupy(&mut self, now: f64, dur: f64) -> (f64, f64) {
        let mut start = now.max(self.free_at);
        if let Some(l) = self.limiter.as_mut() {
            start = l.reserve(start);
        }
        let end = start + dur;
        self.free_at = end;
        (start, end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx_time_math() {
        let l = LinkClass::new(0.01, 1.0e5);
        assert_eq!(l.tx_time(1000), 0.01); // 1000 B at 100 kB/s
        assert_eq!(l.tx_time(0), 0.0);
        assert_eq!(LinkClass::IDEAL.tx_time(1_000_000), 0.0);
        assert_eq!(LinkClass::IDEAL.latency, 0.0);
    }

    #[test]
    fn port_serializes_back_to_back() {
        // Three messages requested at t=0 on one port: they queue.
        let mut p = Port::new(0.0);
        assert_eq!(p.occupy(0.0, 0.1), (0.0, 0.1));
        assert_eq!(p.occupy(0.0, 0.1), (0.1, 0.2));
        assert_eq!(p.occupy(0.0, 0.1), (0.2, 0.30000000000000004));
        // A later request after the port drains starts immediately.
        assert_eq!(p.occupy(1.0, 0.1), (1.0, 1.1));
    }

    #[test]
    fn ideal_port_is_transparent() {
        // Zero-duration transmissions never occupy the port: every
        // request at t starts and ends at t — the bitwise-parity path.
        let mut p = Port::new(0.0);
        for _ in 0..5 {
            assert_eq!(p.occupy(0.0, 0.0), (0.0, 0.0));
        }
        assert_eq!(p.occupy(2.5, 0.0), (2.5, 2.5));
    }

    #[test]
    fn rate_limiter_paces_starts() {
        let mut r = RateLimiter::new(0.5);
        assert_eq!(r.reserve(0.0), 0.0);
        assert_eq!(r.reserve(0.0), 0.5);
        assert_eq!(r.reserve(0.6), 1.0);
        assert_eq!(r.reserve(3.0), 3.0); // gap already elapsed
    }

    #[test]
    fn port_with_limiter_combines_queueing_and_pacing() {
        // dur 0.1 but min gap 0.3: pacing dominates the start spacing.
        let mut p = Port::new(0.3);
        assert_eq!(p.occupy(0.0, 0.1), (0.0, 0.1));
        let (s2, e2) = p.occupy(0.0, 0.1);
        assert_eq!((s2, e2), (0.3, 0.4));
    }

    #[test]
    #[should_panic(expected = "bandwidth")]
    fn rejects_zero_bandwidth() {
        LinkClass::new(0.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "latency")]
    fn rejects_negative_latency() {
        LinkClass::new(-1.0, 1.0);
    }
}
