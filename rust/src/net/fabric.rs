//! The network fabric: schedules one consensus phase's per-link gossip
//! transmissions as discrete events and measures how many synchronous
//! gossip rounds each node completes within the communication budget
//! `T_c` (ISSUE 6 tentpole).
//!
//! Protocol model.  Gossip round `k` at node `i`: transmit `i`'s
//! round-`k` row to every active neighbor, and complete round `k` once
//! round-`k` rows from ALL active neighbors have been received (the
//! synchronous Metropolis mix of `consensus::Protocol` needs every
//! neighbor's row before it can average).  Round `k+1` sends start the
//! instant round `k` completes.  The per-node result `r_i` = rounds
//! completed by `T_c`, capped at the configured round budget — fed to
//! `InducedConsensus::run_per_node`, the same per-node freeze machinery
//! the jitter ablation uses, so a node that measured fewer rounds stops
//! mixing early and holds its value (DESIGN.md §network-fabric).
//!
//! Timing model per message on edge `(i, j)` with class `c`:
//! sender-egress serialization (`c.tx_time(bytes)`, queued FIFO behind
//! `i`'s other sends, optionally paced by a rate limiter) → propagation
//! `c.latency` → receiver-ingress serialization (queued behind `j`'s
//! other receives).  Both ports store-and-forward one message at a
//! time, which is what produces hub-spoke uplink contention: the hub's
//! single egress port serializes a row per spoke, back to back.

use std::collections::HashMap;

use crate::fault::DropMask;
use crate::net::event::EventQueue;
use crate::net::link::{LinkClass, Port};
use crate::topology::Topology;

/// Fabric parameters: a local (LAN) link class for every edge, an
/// optional WAN class for edges crossing contiguous node groups, and an
/// optional per-node egress rate-limiter gap.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricSpec {
    /// Link class for intra-group edges (and ALL edges when `groups <= 1`).
    pub local: LinkClass,
    /// Link class for inter-group edges.  Equal to `local` unless
    /// configured, so a uniform fabric needs no group awareness.
    pub wan: LinkClass,
    /// Number of contiguous node groups for WAN/LAN classification
    /// (`<= 1` means a single site — every edge is `local`).
    pub groups: usize,
    /// Minimum gap (seconds) between egress transmission STARTS at each
    /// node; 0 disables pacing.
    pub min_gap: f64,
}

impl FabricSpec {
    /// Uniform fabric: every edge shares one latency/bandwidth class.
    pub fn uniform(latency: f64, bandwidth: f64) -> FabricSpec {
        let c = LinkClass::new(latency, bandwidth);
        FabricSpec { local: c, wan: c, groups: 1, min_gap: 0.0 }
    }

    /// Zero-latency, unconstrained-bandwidth fabric — must reproduce the
    /// abstract round budget bitwise (every participant measures the cap).
    pub fn ideal() -> FabricSpec {
        FabricSpec::uniform(0.0, f64::INFINITY)
    }

    /// Split the node range into `groups` contiguous blocks and give
    /// cross-block edges the `wan` class.
    pub fn with_wan(mut self, latency: f64, bandwidth: f64, groups: usize) -> FabricSpec {
        assert!(groups >= 1, "WAN split needs at least one group");
        self.wan = LinkClass::new(latency, bandwidth);
        self.groups = groups;
        self
    }

    pub fn with_min_gap(mut self, min_gap: f64) -> FabricSpec {
        assert!(
            min_gap.is_finite() && min_gap >= 0.0,
            "min_gap must be finite and >= 0 (got {min_gap})"
        );
        self.min_gap = min_gap;
        self
    }

    /// Group of node `i` out of `n`: contiguous equal blocks (the same
    /// integer split `Topology::induced` uses for ranges).
    pub fn group_of(&self, i: usize, n: usize) -> usize {
        if self.groups <= 1 {
            0
        } else {
            i * self.groups / n
        }
    }

    /// Link class of edge `(i, j)` in an `n`-node run.
    pub fn class(&self, i: usize, j: usize, n: usize) -> LinkClass {
        if self.group_of(i, n) == self.group_of(j, n) {
            self.local
        } else {
            self.wan
        }
    }
}

/// Fabric events.  `Arrive` = the message's last bit reaches `dst`'s
/// ingress (after egress serialization + propagation); `Deliver` = the
/// ingress port finished serializing it to `dst`; `Timeout` = `node`
/// gives up waiting for round `round` and completes it with whatever
/// neighborhood arrived (fault runs only — a lost packet must not stall
/// the protocol forever).
#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive { src: usize, dst: usize, round: usize },
    Deliver { src: usize, dst: usize, round: usize },
    Timeout { node: usize, round: usize },
}

/// Queue node `src`'s round-`round` transmissions to all its active
/// neighbors at time `t` (free function: `egress` is borrowed per-node
/// while the event queue is borrowed whole).  A message the fault plane
/// drops (`drops` holds the round's `(dst, src)` losses) still occupies
/// the egress port — the sender spent the wire time — but never arrives.
#[allow(clippy::too_many_arguments)]
fn send_round(
    q: &mut EventQueue<Ev>,
    egress: &mut Port,
    fab: &FabricSpec,
    topo: &Topology,
    active: &[bool],
    src: usize,
    round: usize,
    t: f64,
    msg_bytes: usize,
    drops: Option<&DropMask>,
) {
    let n = topo.n();
    for &dst in topo.neighbors(src) {
        if !active[dst] {
            continue;
        }
        let c = fab.class(src, dst, n);
        let (_start, end) = egress.occupy(t, c.tx_time(msg_bytes));
        let lost = drops.is_some_and(|m| m.contains(&(dst as u32, src as u32)));
        if !lost {
            q.push(end + c.latency, Ev::Arrive { src, dst, round });
        }
    }
}

/// Measure per-node completed gossip rounds within `t_c`.
///
/// `out[i]` is set to the measured rounds for every node: 0 for
/// inactive nodes and for active nodes with no active neighbor (which
/// the epoch loop also excludes from participation), otherwise the
/// number of fully completed rounds at virtual time `<= t_c`, capped at
/// `cap`.  Deterministic: event order is a pure function of the
/// adjacency lists and `(fab, msg_bytes, t_c, cap, active)`.
pub fn measure_rounds(
    fab: &FabricSpec,
    topo: &Topology,
    active: &[bool],
    msg_bytes: usize,
    t_c: f64,
    cap: usize,
    out: &mut [usize],
) {
    measure_rounds_inner(fab, topo, active, msg_bytes, t_c, cap, None, out);
}

/// [`measure_rounds`] under a fault plane: `masks[k-1]` lists round
/// `k`'s lost `(dst, src)` messages (they occupy the sender's egress but
/// never arrive), and each round a node starts also starts a timeout
/// clock — at `round_timeout` seconds (`0` = auto: `t_c / cap`, one
/// fair share of the budget per round) the node completes the round
/// with whatever neighborhood arrived, so a dead edge costs mixing
/// weight, not the rest of the window.  The clean path above never
/// schedules timeouts and never consults masks, so all-clear fault
/// specs reproduce it bitwise.
#[allow(clippy::too_many_arguments)]
pub fn measure_rounds_faulty(
    fab: &FabricSpec,
    topo: &Topology,
    active: &[bool],
    msg_bytes: usize,
    t_c: f64,
    cap: usize,
    masks: &[DropMask],
    round_timeout: f64,
    out: &mut [usize],
) {
    let timeout = if round_timeout > 0.0 { round_timeout } else { t_c / cap.max(1) as f64 };
    measure_rounds_inner(fab, topo, active, msg_bytes, t_c, cap, Some((masks, timeout)), out);
}

#[allow(clippy::too_many_arguments)]
fn measure_rounds_inner(
    fab: &FabricSpec,
    topo: &Topology,
    active: &[bool],
    msg_bytes: usize,
    t_c: f64,
    cap: usize,
    faults: Option<(&[DropMask], f64)>,
    out: &mut [usize],
) {
    let n = topo.n();
    assert_eq!(active.len(), n, "active mask shape");
    assert_eq!(out.len(), n, "output shape");
    assert!(t_c.is_finite() && t_c >= 0.0, "T_c must be finite and >= 0 (got {t_c})");
    out.fill(0);
    if cap == 0 {
        return;
    }

    // Round-k drop mask (1-based round; None on the clean path AND for
    // rounds past the supplied masks).
    let drops_for = |round: usize| -> Option<&DropMask> {
        faults.and_then(|(masks, _)| masks.get(round - 1)).filter(|m| !m.is_empty())
    };

    // A node participates iff active with at least one active neighbor
    // — the same rule `coordinator::sim` uses for its rounds log.
    let need: Vec<usize> = (0..n)
        .map(|i| {
            if active[i] {
                topo.neighbors(i).iter().filter(|&&j| active[j]).count()
            } else {
                0
            }
        })
        .collect();

    let mut egress: Vec<Port> = (0..n).map(|_| Port::new(fab.min_gap)).collect();
    let mut ingress: Vec<Port> = (0..n).map(|_| Port::new(0.0)).collect();
    // got[i][k-1]: round-k rows received at i so far.
    let mut got: Vec<Vec<usize>> = (0..n).map(|_| vec![0; cap]).collect();
    let mut done: Vec<usize> = vec![0; n];
    let mut q: EventQueue<Ev> = EventQueue::new();

    // Round 1 starts at t = 0 on every participant.
    for i in 0..n {
        if need[i] > 0 {
            send_round(&mut q, &mut egress[i], fab, topo, active, i, 1, 0.0, msg_bytes, drops_for(1));
            if let Some((_, timeout)) = faults {
                q.push(timeout, Ev::Timeout { node: i, round: 1 });
            }
        }
    }

    while q.next_time().map(|t| t <= t_c).unwrap_or(false) {
        // amb-lint: allow(D4, "pop follows the successful peek above")
        let (t, ev) = q.pop().expect("peeked");
        match ev {
            Ev::Arrive { src, dst, round } => {
                let c = fab.class(src, dst, n);
                let (_start, end) = ingress[dst].occupy(t, c.tx_time(msg_bytes));
                q.push(end, Ev::Deliver { src, dst, round });
            }
            Ev::Deliver { src: _, dst, round } => {
                got[dst][round - 1] += 1;
                // Completing round k can cascade: the row that closes
                // round k may already have banked everything round k+1
                // needs (counterpart rows can arrive out of round order
                // thanks to per-edge timing).
                while done[dst] < cap && got[dst][done[dst]] == need[dst] {
                    done[dst] += 1;
                    if done[dst] < cap {
                        let next = done[dst] + 1;
                        send_round(
                            &mut q,
                            &mut egress[dst],
                            fab,
                            topo,
                            active,
                            dst,
                            next,
                            t,
                            msg_bytes,
                            drops_for(next),
                        );
                        if let Some((_, timeout)) = faults {
                            q.push(t + timeout, Ev::Timeout { node: dst, round: next });
                        }
                    }
                }
            }
            Ev::Timeout { node, round } => {
                // Still waiting on this round?  Complete it with the
                // partial neighborhood (the mixing kernel absorbs the
                // missing weight receiver-side); stale timeouts for
                // rounds that closed on time are no-ops.  The forced
                // completion can cascade like a closing Deliver: later
                // rounds may already be fully banked.
                if done[node] == round - 1 && done[node] < cap {
                    done[node] = round;
                    loop {
                        if done[node] < cap {
                            let next = done[node] + 1;
                            send_round(
                                &mut q,
                                &mut egress[node],
                                fab,
                                topo,
                                active,
                                node,
                                next,
                                t,
                                msg_bytes,
                                drops_for(next),
                            );
                            if let Some((_, timeout)) = faults {
                                q.push(t + timeout, Ev::Timeout { node, round: next });
                            }
                        }
                        if done[node] < cap && got[node][done[node]] == need[node] {
                            done[node] += 1;
                        } else {
                            break;
                        }
                    }
                }
            }
        }
    }

    for i in 0..n {
        if need[i] > 0 {
            out[i] = done[i];
        }
    }
}

/// Per-epoch fabric driver with memoized measurements: round counts
/// depend only on the active set (the fabric itself is epoch-invariant),
/// so churn patterns that revisit an active set reuse the measurement.
/// Cache policy mirrors `InducedConsensus`: clear on overflow past
/// `MAX_CACHED_SETS` rather than LRU bookkeeping.
pub struct FabricRounds {
    spec: FabricSpec,
    msg_bytes: usize,
    t_c: f64,
    cap: usize,
    cache: HashMap<Vec<bool>, Vec<usize>>,
    /// Scratch for fault-run measurements, which NEVER hit the memo:
    /// the cache key is the active set alone, but under link faults the
    /// SAME active set measures differently every epoch (per-epoch drop
    /// masks), so memoizing would silently replay epoch 1's losses
    /// forever.
    faulty_buf: Vec<usize>,
}

impl FabricRounds {
    const MAX_CACHED_SETS: usize = 64;

    pub fn new(spec: FabricSpec, msg_bytes: usize, t_c: f64, cap: usize) -> FabricRounds {
        FabricRounds { spec, msg_bytes, t_c, cap, cache: HashMap::new(), faulty_buf: Vec::new() }
    }

    /// The configured round budget (mask length for fault runs).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Measured rounds for this active set (computed on first sight).
    pub fn rounds(&mut self, topo: &Topology, active: &[bool]) -> &[usize] {
        if !self.cache.contains_key(active) {
            if self.cache.len() >= Self::MAX_CACHED_SETS {
                self.cache.clear();
            }
            let mut out = vec![0; topo.n()];
            measure_rounds(
                &self.spec,
                topo,
                active,
                self.msg_bytes,
                self.t_c,
                self.cap,
                &mut out,
            );
            self.cache.insert(active.to_vec(), out);
        }
        &self.cache[active]
    }

    /// Fresh (uncached) measurement under this epoch's drop masks — see
    /// `faulty_buf` for why the memo must be bypassed.
    pub fn rounds_faulty(
        &mut self,
        topo: &Topology,
        active: &[bool],
        masks: &[DropMask],
        round_timeout: f64,
    ) -> &[usize] {
        self.faulty_buf.resize(topo.n(), 0);
        measure_rounds_faulty(
            &self.spec,
            topo,
            active,
            self.msg_bytes,
            self.t_c,
            self.cap,
            masks,
            round_timeout,
            &mut self.faulty_buf,
        );
        &self.faulty_buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_active(n: usize) -> Vec<bool> {
        vec![true; n]
    }

    fn mean(xs: &[usize]) -> f64 {
        xs.iter().sum::<usize>() as f64 / xs.len() as f64
    }

    #[test]
    fn ideal_fabric_hits_cap_everywhere() {
        // Zero latency + unconstrained bandwidth: all cap rounds finish
        // at t = 0 regardless of topology — the abstract-parity pin.
        for topo in [Topology::ring(8), Topology::hub_spoke(7), Topology::paper_fig2()] {
            let n = topo.n();
            let mut out = vec![0; n];
            measure_rounds(&FabricSpec::ideal(), &topo, &all_active(n), 4100, 0.5, 7, &mut out);
            assert_eq!(out, vec![7; n], "topology n={n}");
        }
    }

    #[test]
    fn serialization_math_on_a_pair() {
        // complete(2): each round = egress tx + latency + ingress tx, so
        // with tx = 1000 B / 1e5 B/s = 0.01 and latency 0.03 a round
        // takes 0.05; T_c = 0.26 fits exactly 5 rounds (5th at 0.25).
        let topo = Topology::complete(2);
        let fab = FabricSpec::uniform(0.03, 1.0e5);
        let mut out = vec![0; 2];
        measure_rounds(&fab, &topo, &all_active(2), 1000, 0.26, 10, &mut out);
        assert_eq!(out, vec![5, 5]);
        // One microsecond under the 5th completion: only 4 rounds.
        measure_rounds(&fab, &topo, &all_active(2), 1000, 0.2499, 10, &mut out);
        assert_eq!(out, vec![4, 4]);
    }

    #[test]
    fn rate_limiter_bounds_round_rate() {
        // Ideal links but a 0.1 s egress gap: round k's send can start
        // no earlier than (k-1) * 0.1, so T_c = 0.45 fits 5 rounds
        // (sends at 0.0..0.4) and not 6.
        let topo = Topology::complete(2);
        let fab = FabricSpec::ideal().with_min_gap(0.1);
        let mut out = vec![0; 2];
        measure_rounds(&fab, &topo, &all_active(2), 1000, 0.45, 100, &mut out);
        assert_eq!(out, vec![5, 5]);
    }

    #[test]
    fn wan_edges_slow_cross_group_rounds() {
        let topo = Topology::complete(4);
        let lan = FabricSpec::uniform(0.001, 1.0e6);
        let mixed = FabricSpec::uniform(0.001, 1.0e6).with_wan(0.05, 1.0e5, 2);
        // Sanity on the classifier: nodes {0,1} vs {2,3}.
        assert_eq!(mixed.group_of(1, 4), 0);
        assert_eq!(mixed.group_of(2, 4), 1);
        assert_eq!(mixed.class(0, 1, 4), mixed.local);
        assert_ne!(mixed.class(1, 2, 4), mixed.local);
        let mut fast = vec![0; 4];
        let mut slow = vec![0; 4];
        measure_rounds(&lan, &topo, &all_active(4), 4100, 0.5, 50, &mut fast);
        measure_rounds(&mixed, &topo, &all_active(4), 4100, 0.5, 50, &mut slow);
        assert!(fast.iter().all(|&r| r > 0));
        assert!(
            mean(&slow) < mean(&fast),
            "WAN-crossing rounds should complete slower: {slow:?} vs {fast:?}"
        );
    }

    #[test]
    fn hub_uplink_contention_vs_ring() {
        // The acceptance shape: 20 nodes, same uniform links, same
        // deadline — the hub's egress port serializes 19 rows per round
        // while ring nodes send 2, so hub-spoke completes fewer rounds.
        let ring = Topology::ring(20);
        let hub = Topology::hub_spoke(19);
        let fab = FabricSpec::uniform(0.005, 2.0e5);
        let mut r_ring = vec![0; 20];
        let mut r_hub = vec![0; 20];
        measure_rounds(&fab, &ring, &all_active(20), 4100, 0.5, 8, &mut r_ring);
        measure_rounds(&fab, &hub, &all_active(20), 4100, 0.5, 8, &mut r_hub);
        assert!(mean(&r_ring) > 0.0, "ring must make progress: {r_ring:?}");
        assert!(
            mean(&r_hub) < mean(&r_ring),
            "hub-spoke should complete fewer rounds: hub {r_hub:?} vs ring {r_ring:?}"
        );
    }

    #[test]
    fn inactive_and_isolated_nodes_measure_zero() {
        // Path 0-1-2 induced from ring(4) by deactivating 3... use
        // ring(4) with node 2 down: 1 and 3 keep one active neighbor
        // each (0), 0 keeps two; 2 contributes nothing.
        let topo = Topology::ring(4);
        let active = vec![true, true, false, true];
        let mut out = vec![0; 4];
        measure_rounds(&FabricSpec::ideal(), &topo, &active, 100, 0.5, 3, &mut out);
        assert_eq!(out[2], 0, "inactive node");
        assert_eq!(out, vec![3, 3, 0, 3]);
        // All nodes isolated: everyone measures 0 rounds.
        let alone = vec![true, false, false, false];
        measure_rounds(&FabricSpec::ideal(), &topo, &alone, 100, 0.5, 3, &mut out);
        assert_eq!(out, vec![0; 4]);
    }

    #[test]
    fn zero_cap_and_zero_deadline() {
        let topo = Topology::ring(4);
        let mut out = vec![7; 4];
        measure_rounds(&FabricSpec::ideal(), &topo, &all_active(4), 100, 0.5, 0, &mut out);
        assert_eq!(out, vec![0; 4], "cap 0 measures 0");
        // T_c = 0 still completes ideal rounds (they finish AT t = 0).
        measure_rounds(&FabricSpec::ideal(), &topo, &all_active(4), 100, 0.0, 4, &mut out);
        assert_eq!(out, vec![4; 4]);
        // ...but any positive latency pushes everything past a zero deadline.
        measure_rounds(&FabricSpec::uniform(0.01, 1e6), &topo, &all_active(4), 100, 0.0, 4, &mut out);
        assert_eq!(out, vec![0; 4]);
    }

    #[test]
    fn measurement_is_deterministic() {
        let topo = Topology::hub_spoke(9);
        let fab = FabricSpec::uniform(0.002, 1.0e5).with_min_gap(0.001);
        let mut a = vec![0; 10];
        let mut b = vec![0; 10];
        measure_rounds(&fab, &topo, &all_active(10), 4100, 0.5, 20, &mut a);
        measure_rounds(&fab, &topo, &all_active(10), 4100, 0.5, 20, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn dropped_packets_time_out_instead_of_stalling() {
        // complete(2) pair maths (see serialization_math_on_a_pair): a
        // round costs 0.05, T_c = 0.26 fits 5 clean rounds.  Drop the
        // round-1 message 1 → 0: without a timeout node 0 would wait the
        // whole window; with a 0.06 timeout it closes round 1 partial
        // and keeps gossiping.
        let topo = Topology::complete(2);
        let fab = FabricSpec::uniform(0.03, 1.0e5);
        let mut mask1 = DropMask::new();
        mask1.insert((0, 1));
        let masks = vec![mask1, DropMask::new(), DropMask::new()];
        let mut out = vec![0; 2];
        measure_rounds_faulty(&fab, &topo, &all_active(2), 1000, 0.26, 10, &masks, 0.06, &mut out);
        assert!(out[0] >= 3, "timed-out node should keep making rounds: {out:?}");
        assert!(out[1] >= 3, "unaffected node should keep making rounds: {out:?}");
        // the lost round costs node 0 some progress vs the clean run
        let mut clean = vec![0; 2];
        measure_rounds(&fab, &topo, &all_active(2), 1000, 0.26, 10, &mut clean);
        assert!(out[0] <= clean[0], "loss cannot speed a node up: {out:?} vs {clean:?}");
    }

    #[test]
    fn same_active_set_measures_differently_across_epochs_under_loss() {
        // The memo-bypass pin (ISSUE 8 satellite): FabricRounds keys its
        // cache by active set, but per-epoch drop masks make the SAME
        // set measure differently — rounds_faulty must never serve a
        // cached measurement.
        use crate::fault::FaultSpec;
        let topo = Topology::ring(8);
        let all = all_active(8);
        // ring round ≈ 0.05 s (two serialized 0.01 s sends + 0.02 s
        // latency + ingress), so T_c = 0.3 fits ~6 clean rounds under a
        // cap of 8 — drops (timeout 0.06 > round time) cost real rounds
        // instead of disappearing under a slack budget.
        let fab = FabricSpec::uniform(0.02, 1.0e5);
        let mut fr = FabricRounds::new(fab, 1000, 0.3, 8);
        // prime the clean memo for this exact active set
        let clean = fr.rounds(&topo, &all).to_vec();
        assert_eq!(fr.cache.len(), 1);
        let spec = FaultSpec { loss: 0.4, ..FaultSpec::none() };
        let per_epoch: Vec<Vec<usize>> = (1..=6)
            .map(|t| {
                let masks = spec.epoch_masks(&topo, &all, t, fr.cap());
                fr.rounds_faulty(&topo, &all, &masks, 0.06).to_vec()
            })
            .collect();
        assert_eq!(fr.cache.len(), 1, "fault measurements must not touch the memo");
        assert!(
            per_epoch.iter().any(|r| r != &clean),
            "40% loss never moved a measurement off the clean baseline"
        );
        let differs = per_epoch.iter().any(|r| r != &per_epoch[0]);
        assert!(
            differs,
            "two epochs at the same active set must be able to measure differently: {per_epoch:?}"
        );
    }

    #[test]
    fn fabric_rounds_caches_by_active_set() {
        let topo = Topology::ring(6);
        let mut fr = FabricRounds::new(FabricSpec::uniform(0.01, 1.0e5), 1000, 0.5, 10);
        let all = all_active(6);
        let first = fr.rounds(&topo, &all).to_vec();
        assert_eq!(fr.cache.len(), 1);
        let again = fr.rounds(&topo, &all).to_vec();
        assert_eq!(first, again);
        assert_eq!(fr.cache.len(), 1, "revisited set must not grow the cache");
        let partial = vec![true, true, true, true, false, true];
        let _ = fr.rounds(&topo, &partial);
        assert_eq!(fr.cache.len(), 2);
    }
}
