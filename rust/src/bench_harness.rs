//! Mini benchmarking harness (criterion is not in the offline vendor set —
//! DESIGN.md §7).  Provides warmup, timed iterations, and robust summary
//! stats; `cargo bench` targets are `harness = false` binaries that call
//! into this module and print paper-comparable rows.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::coordinator::{EngineFactory, RunSpec, Runtime};
use crate::topology::{MixMatrix, Topology};
use crate::util::stats;

/// The pre-`NodeMatrix` dense gossip kernel, kept VERBATIM as the
/// before/after baseline for the arena data plane: one heap row per
/// node, full-row read-modify-write axpys, zero-skip on the fly.  Both
/// the bitwise pin test
/// (`consensus::tests::flat_kernel_matches_legacy_nested_vec_bitwise`)
/// and the `benches/hotpath.rs` speedup grid compare against THIS
/// definition, so the two baselines cannot drift apart.
pub fn legacy_vecvec_mix_into(p: &MixMatrix, msgs: &[Vec<f32>], out: &mut [Vec<f32>]) {
    let n = p.n();
    let d = msgs[0].len();
    for i in 0..n {
        let oi = &mut out[i];
        for v in oi.iter_mut() {
            *v = 0.0;
        }
        for j in 0..n {
            let pij = p.at(i, j) as f32;
            if pij == 0.0 {
                continue;
            }
            let mj = &msgs[j];
            for k in 0..d {
                oi[k] += pij * mj[k];
            }
        }
    }
}

/// One benchmark's timing summary (per-iteration, seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub stddev: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
}

impl Summary {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<42} {:>10} {:>10} {:>10} {:>10}  (n={})",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
            fmt_time(self.min),
            self.iters,
        )
    }
}

/// Human time formatting.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: usize,
    results: Vec<Summary>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Modest defaults: figure benches run full experiment epochs.
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            max_iters: 1_000,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; the closure's return value is black_boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Summary {
        // Warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let summary = Summary {
            name: name.to_string(),
            iters: samples.len(),
            mean: stats::mean(&samples),
            stddev: stats::stddev(&samples),
            p50: stats::quantile(&samples, 0.5),
            p95: stats::quantile(&samples, 0.95),
            min: stats::min(&samples),
        };
        self.results.push(summary);
        // amb-lint: allow(D4, "run() pushes a result before this accessor is reachable")
        self.results.last().unwrap()
    }

    /// Time a full [`crate::run`] invocation — the standard row every
    /// figure bench records, identical for either runtime.
    pub fn bench_run(
        &mut self,
        name: &str,
        runtime: &dyn Runtime,
        spec: &RunSpec,
        topo: &Topology,
        make_engine: EngineFactory<'_>,
        f_star: Option<f64>,
    ) -> &Summary {
        self.bench(name, || {
            crate::run(runtime, spec, topo, make_engine, f_star)
                // amb-lint: allow(D4, "bench harness: an unrunnable spec is fatal by design")
                .expect("bench spec must be runnable")
                .record
                .total_samples()
        })
    }

    /// Print the standard header + all recorded results.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<42} {:>10} {:>10} {:>10} {:>10}",
            "benchmark", "mean", "p50", "p95", "min"
        );
        for r in &self.results {
            println!("{r}");
        }
    }

    pub fn results(&self) -> &[Summary] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            max_iters: 500,
            results: Vec::new(),
        };
        let s = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.iters > 0);
        assert!(s.mean > 0.0 && s.min <= s.mean);
        assert!(s.p50 <= s.p95);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-6).ends_with("µs"));
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with('s'));
    }

    #[test]
    fn throughput() {
        let s = Summary {
            name: "x".into(),
            iters: 1,
            mean: 0.5,
            stddev: 0.0,
            p50: 0.5,
            p95: 0.5,
            min: 0.5,
        };
        assert_eq!(s.throughput(100.0), 200.0);
    }
}
