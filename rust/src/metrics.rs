//! Run metrics: per-epoch records, regret accounting, CSV/JSON export.
//!
//! The figures plot error (or cost) vs *wall time*; the regret bound of
//! Thm. 2 is tracked as the running sum of (observed loss − F(w*))·b(t).

use std::path::Path;

use crate::util::csv::Csv;
use crate::util::json::Json;
use crate::util::matrix::NodeMatrix;

/// One epoch's summary.
#[derive(Debug, Clone)]
pub struct EpochStats {
    /// 1-based epoch index.
    pub epoch: usize,
    /// Wall-clock time at the END of this epoch (seconds, virtual or real).
    pub wall_time: f64,
    /// Global minibatch size b(t) actually used.
    pub batch: usize,
    /// Total potential samples c(t) (b(t) + undone work; regret accounting).
    pub potential: usize,
    /// Average per-sample training loss over the epoch's minibatch.
    pub loss: f64,
    /// Workload-specific error metric (e.g. linreg excess risk ‖w−w*‖²/2,
    /// or fresh-sample logistic cost); NaN when unavailable.
    pub error: f64,
    /// Consensus error max_i ‖z_i − z̄‖ at the end of the epoch.
    pub consensus_err: f64,
    /// min / max per-node minibatch (straggler spread diagnostic).
    pub min_node_batch: usize,
    pub max_node_batch: usize,
    /// Largest staleness (epochs between computing a gradient batch and
    /// applying it) over the batches that entered this epoch's update.
    /// 0 for every undelayed scheme; D in AMB-DG steady state; > D when
    /// a churned-out node's in-flight batch lands after it rejoins.
    pub max_staleness: usize,
    /// Sample-weighted mean staleness of the epoch's applied batches
    /// (Σ b_i·D_i / b(t)); NaN when the epoch applied nothing (AMB-DG
    /// warm-up, or b(t) = 0).
    pub mean_staleness: f64,
    /// Mean-conservation drift under fault injection: L2 distance
    /// between the active-set mean message row before and after the
    /// consensus phase.  Gossip conserves the mean exactly; a dropped
    /// message absorbed into a receiver's self-weight does not, and
    /// this column measures by how much.  Exactly 0.0 on epochs where
    /// no drop fired (and always 0.0 under `FaultSpec::none()`); NaN on
    /// the threaded runtime under active faults (no global observer).
    pub conservation_drift: f64,
}

/// A complete run: scheme label + epoch series.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub name: String,
    pub epochs: Vec<EpochStats>,
    /// Optimal per-sample loss F(w*) when known analytically (regret
    /// baseline).  `None` — e.g. the MNIST-like mixture — means regret
    /// is NOT computed rather than silently lower-bounded with 0.0, so
    /// true and bounded baselines can never be mixed across schemes.
    pub f_star: Option<f64>,
}

impl RunRecord {
    pub fn new(name: &str, f_star: Option<f64>) -> RunRecord {
        RunRecord { name: name.to_string(), epochs: Vec::new(), f_star }
    }

    pub fn push(&mut self, e: EpochStats) {
        if let Some(last) = self.epochs.last() {
            assert!(e.epoch == last.epoch + 1, "epochs must be contiguous");
            assert!(e.wall_time >= last.wall_time, "wall time must be monotone");
        }
        self.epochs.push(e);
    }

    /// Total wall time.
    pub fn total_time(&self) -> f64 {
        self.epochs.last().map(|e| e.wall_time).unwrap_or(0.0)
    }

    /// Total samples processed Σ b(t).
    pub fn total_samples(&self) -> usize {
        self.epochs.iter().map(|e| e.batch).sum()
    }

    /// Running regret estimate after each epoch:
    /// R̂(τ) = Σ_{t≤τ} b(t)·(loss(t) − F(w*))   (paper eq. (16) with the
    /// observed minibatch as the sample set).  `None` when F(w*) is
    /// unknown — callers must choose a baseline explicitly instead of
    /// inheriting a silent 0.0 bound.
    pub fn regret_series(&self) -> Option<Vec<f64>> {
        let f_star = self.f_star?;
        let mut acc = 0.0;
        Some(
            self.epochs
                .iter()
                .map(|e| {
                    // A b(t) = 0 epoch (an all-absent churn epoch, or
                    // AMB-DG warm-up) records loss = NaN; zero samples
                    // incur zero regret, and 0 · NaN = NaN must not
                    // poison the running sum.
                    if e.batch > 0 {
                        acc += e.batch as f64 * (e.loss - f_star);
                    }
                    acc
                })
                .collect(),
        )
    }

    /// First wall time at which `error` drops (and stays) below `target`;
    /// None if never reached.  The "time-to-target" metric used for the
    /// AMB-vs-FMB speedup claims.
    pub fn time_to_error(&self, target: f64) -> Option<f64> {
        let mut hit: Option<f64> = None;
        for e in &self.epochs {
            if e.error <= target {
                if hit.is_none() {
                    hit = Some(e.wall_time);
                }
            } else {
                hit = None;
            }
        }
        hit
    }

    /// Export the per-epoch series as CSV.  The regret column is `NaN`
    /// when F(w*) is unknown.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "epoch", "wall_time", "batch", "potential", "loss", "error",
            "consensus_err", "min_node_batch", "max_node_batch",
            "max_staleness", "mean_staleness", "conservation_drift", "regret",
        ]);
        let regret = self
            .regret_series()
            .unwrap_or_else(|| vec![f64::NAN; self.epochs.len()]);
        for (e, r) in self.epochs.iter().zip(regret) {
            csv.push_nums(&[
                e.epoch as f64,
                e.wall_time,
                e.batch as f64,
                e.potential as f64,
                e.loss,
                e.error,
                e.consensus_err,
                e.min_node_batch as f64,
                e.max_node_batch as f64,
                e.max_staleness as f64,
                e.mean_staleness,
                e.conservation_drift,
                r,
            ]);
        }
        csv
    }

    /// Staleness over the whole run: (sample-weighted mean over every
    /// applied batch, max over epochs).  (0.0, 0) for a run that never
    /// applied anything — undelayed schemes report exactly that shape
    /// with mean 0.0, since all their batches apply at staleness 0.
    pub fn staleness_summary(&self) -> (f64, usize) {
        let mut wsum = 0.0f64;
        let mut samples = 0usize;
        let mut max = 0usize;
        for e in &self.epochs {
            if e.batch > 0 && e.mean_staleness.is_finite() {
                wsum += e.mean_staleness * e.batch as f64;
                samples += e.batch;
                max = max.max(e.max_staleness);
            }
        }
        (if samples > 0 { wsum / samples as f64 } else { 0.0 }, max)
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        self.to_csv().save(path)
    }

    /// Compact JSON summary (for EXPERIMENTS.md tables).  `final_regret`
    /// is `null` when F(w*) is unknown.
    pub fn summary_json(&self) -> Json {
        let last = self.epochs.last();
        let final_regret = self
            .regret_series()
            .and_then(|r| r.last().copied())
            .map(Json::num)
            .unwrap_or(Json::Null);
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("epochs", Json::num(self.epochs.len() as f64)),
            ("total_time", Json::num(self.total_time())),
            ("total_samples", Json::num(self.total_samples() as f64)),
            ("final_loss", Json::num(last.map(|e| e.loss).unwrap_or(f64::NAN))),
            ("final_error", Json::num(last.map(|e| e.error).unwrap_or(f64::NAN))),
            ("final_regret", final_regret),
        ])
    }
}

/// Compare two runs on time-to-target: returns (t_a, t_b, speedup b/a).
pub fn speedup_at(a: &RunRecord, b: &RunRecord, target: f64) -> Option<(f64, f64, f64)> {
    let ta = a.time_to_error(target)?;
    let tb = b.time_to_error(target)?;
    Some((ta, tb, tb / ta))
}

/// Max pairwise L2 distance between per-node primal rows of a
/// [`crate::coordinator::RunOutput::final_w`] arena — the "did consensus
/// keep the models together" diagnostic (0 for a single node or under
/// perfect consensus).  Panics on an empty arena: a silent 0.0 there
/// would read as perfect consensus.
pub fn max_primal_spread(final_w: &NodeMatrix) -> f64 {
    assert!(final_w.n() > 0, "max_primal_spread over an empty arena");
    let n = final_w.n();
    let mut worst = 0.0f64;
    for i in 0..n {
        for j in (i + 1)..n {
            let mut ss = 0.0f64;
            for (&a, &b) in final_w.row(i).iter().zip(final_w.row(j)) {
                ss += ((a - b) as f64).powi(2);
            }
            worst = worst.max(ss.sqrt());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(epoch: usize, t: f64, batch: usize, loss: f64, error: f64) -> EpochStats {
        EpochStats {
            epoch,
            wall_time: t,
            batch,
            potential: batch,
            loss,
            error,
            consensus_err: 0.0,
            min_node_batch: batch / 2,
            max_node_batch: batch,
            max_staleness: 0,
            mean_staleness: if batch > 0 { 0.0 } else { f64::NAN },
            conservation_drift: 0.0,
        }
    }

    #[test]
    fn regret_accumulates() {
        let mut r = RunRecord::new("amb", Some(1.0));
        r.push(stats(1, 1.0, 10, 3.0, 1.0));
        r.push(stats(2, 2.0, 20, 2.0, 0.5));
        assert_eq!(r.regret_series().unwrap(), vec![20.0, 40.0]);
        assert_eq!(r.total_samples(), 30);
        assert_eq!(r.total_time(), 2.0);
    }

    #[test]
    fn empty_epochs_do_not_nan_poison_regret() {
        // AMB-DG warm-up (and all-absent churn epochs) record batch = 0
        // with loss = NaN; zero samples incur zero regret, so the series
        // must carry through finite.
        let mut r = RunRecord::new("dg", Some(1.0));
        r.push(stats(1, 1.0, 0, f64::NAN, 1.0));
        r.push(stats(2, 2.0, 10, 3.0, 0.5));
        r.push(stats(3, 3.0, 0, f64::NAN, 0.5));
        r.push(stats(4, 4.0, 10, 2.0, 0.4));
        assert_eq!(r.regret_series().unwrap(), vec![0.0, 20.0, 20.0, 30.0]);
    }

    #[test]
    fn time_to_error_requires_staying_below() {
        let mut r = RunRecord::new("x", Some(0.0));
        r.push(stats(1, 1.0, 1, 0.0, 0.5));
        r.push(stats(2, 2.0, 1, 0.0, 0.05)); // below
        r.push(stats(3, 3.0, 1, 0.0, 0.2)); // bounce back up
        r.push(stats(4, 4.0, 1, 0.0, 0.04));
        r.push(stats(5, 5.0, 1, 0.0, 0.03));
        assert_eq!(r.time_to_error(0.1), Some(4.0));
        assert_eq!(r.time_to_error(0.001), None);
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn non_contiguous_epochs_panic() {
        let mut r = RunRecord::new("x", Some(0.0));
        r.push(stats(1, 1.0, 1, 0.0, 0.0));
        r.push(stats(3, 2.0, 1, 0.0, 0.0));
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn non_monotone_time_panics() {
        let mut r = RunRecord::new("x", Some(0.0));
        r.push(stats(1, 5.0, 1, 0.0, 0.0));
        r.push(stats(2, 2.0, 1, 0.0, 0.0));
    }

    #[test]
    fn primal_spread_over_arena_rows() {
        let w = NodeMatrix::from_rows(&[vec![0.0f32, 0.0], vec![3.0, 4.0], vec![0.0, 0.0]]);
        assert!((max_primal_spread(&w) - 5.0).abs() < 1e-9);
        assert_eq!(max_primal_spread(&NodeMatrix::new(1, 4)), 0.0);
    }

    #[test]
    fn unknown_f_star_never_fakes_regret() {
        let mut r = RunRecord::new("mnist", None);
        r.push(stats(1, 1.0, 10, 3.0, 1.0));
        assert!(r.regret_series().is_none(), "no silent 0.0 baseline");
        // CSV still has the column, explicitly NaN
        let text = r.to_csv().to_string();
        assert!(text.contains("regret"));
        assert!(text.contains("NaN"));
        // JSON reports null, not a bounded number
        assert_eq!(r.summary_json().get("final_regret"), Some(&Json::Null));
    }

    #[test]
    fn csv_has_all_epochs() {
        let mut r = RunRecord::new("x", Some(0.0));
        r.push(stats(1, 1.0, 5, 1.0, 1.0));
        r.push(stats(2, 2.0, 6, 0.5, 0.5));
        let csv = r.to_csv();
        assert_eq!(csv.len(), 2);
        assert!(csv.to_string().contains("regret"));
        assert!(csv.to_string().contains("mean_staleness"));
        assert!(csv.to_string().contains("conservation_drift"));
    }

    #[test]
    fn staleness_summary_weights_by_batch() {
        let mut r = RunRecord::new("dg", Some(0.0));
        // warm-up epoch applies nothing; then staleness 1 on 30 samples
        // and 2 on 10 samples => mean (30 + 20)/40 = 1.25, max 2
        let mut e1 = stats(1, 1.0, 0, f64::NAN, 1.0);
        e1.mean_staleness = f64::NAN;
        r.push(e1);
        let mut e2 = stats(2, 2.0, 30, 0.2, 0.5);
        e2.max_staleness = 1;
        e2.mean_staleness = 1.0;
        r.push(e2);
        let mut e3 = stats(3, 3.0, 10, 0.2, 0.4);
        e3.max_staleness = 2;
        e3.mean_staleness = 2.0;
        r.push(e3);
        let (mean, max) = r.staleness_summary();
        assert!((mean - 1.25).abs() < 1e-12, "mean={mean}");
        assert_eq!(max, 2);
        // an undelayed run reports (0.0, 0)
        let mut plain = RunRecord::new("amb", Some(0.0));
        plain.push(stats(1, 1.0, 10, 0.1, 0.1));
        assert_eq!(plain.staleness_summary(), (0.0, 0));
    }

    #[test]
    fn speedup_ratio() {
        let mut a = RunRecord::new("amb", Some(0.0));
        let mut b = RunRecord::new("fmb", Some(0.0));
        for t in 1..=5 {
            a.push(stats(t, t as f64, 1, 0.0, 1.0 / t as f64));
            b.push(stats(t, 2.0 * t as f64, 1, 0.0, 1.0 / t as f64));
        }
        let (ta, tb, s) = speedup_at(&a, &b, 0.4).unwrap();
        assert_eq!(ta, 3.0);
        assert_eq!(tb, 6.0);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_json_fields() {
        let mut r = RunRecord::new("amb", Some(0.0));
        r.push(stats(1, 1.5, 7, 0.25, 0.1));
        let j = r.summary_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("amb"));
        assert_eq!(j.get("total_samples").unwrap().as_usize(), Some(7));
    }
}
