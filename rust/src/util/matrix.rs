//! Per-node state arenas: the crate's data plane (DESIGN.md §1).
//!
//! Consensus, the coordinator runtimes, and the exec layer all move
//! "one vector per node" collections.  Storing them as `Vec<Vec<f32>>`
//! costs one heap allocation per node, defeats hardware prefetching
//! (rows land wherever the allocator put them), and forces every gossip
//! round through pointer-chasing.  [`NodeMatrix`] flattens the whole
//! collection into ONE row-major `[n × d]` buffer:
//!
//! * `row(i)` / `row_mut(i)` — contiguous per-node views, so all
//!   existing slice-based kernels (`dot`, `axpy`, the model gradients)
//!   apply unchanged;
//! * `rows_mut_pair(i, j)` — two disjoint mutable rows at once (swap /
//!   exchange patterns without `unsafe` at the call site);
//! * `swap(&mut other)` — O(1) double-buffer flip for iterated kernels
//!   (gossip rounds ping-pong between the message and scratch arenas
//!   with zero copies and zero allocations after setup);
//! * [`NodeMatrixF64`] — the paired f64-accumulation variant for exact
//!   averaging and push-sum, where f32 summation error would compound
//!   across rounds.
//!
//! The arena is deliberately NOT growable per row: every row has the
//! same length `d`, fixed at construction (messages are `dim + 1` wide,
//! primals `dim` wide — both known before the first epoch).

macro_rules! node_matrix_impl {
    ($name:ident, $elem:ty) => {
        impl $name {
            /// Zero-filled n × d arena.
            pub fn new(n: usize, d: usize) -> $name {
                $name { n, d, data: vec![0.0; n * d] }
            }

            /// Build from nested rows (interop / test convenience).
            /// Panics if rows are ragged.
            pub fn from_rows(rows: &[Vec<$elem>]) -> $name {
                let n = rows.len();
                let d = rows.first().map_or(0, |r| r.len());
                let mut m = $name::new(n, d);
                for (i, r) in rows.iter().enumerate() {
                    assert_eq!(r.len(), d, "row {i} has length {} != {d}", r.len());
                    m.row_mut(i).copy_from_slice(r);
                }
                m
            }

            /// Number of rows (nodes).
            pub fn n(&self) -> usize {
                self.n
            }

            /// Row width (per-node dimension).
            pub fn d(&self) -> usize {
                self.d
            }

            #[inline]
            pub fn row(&self, i: usize) -> &[$elem] {
                &self.data[i * self.d..(i + 1) * self.d]
            }

            #[inline]
            pub fn row_mut(&mut self, i: usize) -> &mut [$elem] {
                &mut self.data[i * self.d..(i + 1) * self.d]
            }

            /// Two disjoint mutable rows (i ≠ j, any order).
            pub fn rows_mut_pair(&mut self, i: usize, j: usize) -> (&mut [$elem], &mut [$elem]) {
                assert_ne!(i, j, "rows_mut_pair needs distinct rows");
                let d = self.d;
                if i < j {
                    let (lo, hi) = self.data.split_at_mut(j * d);
                    (&mut lo[i * d..(i + 1) * d], &mut hi[..d])
                } else {
                    let (lo, hi) = self.data.split_at_mut(i * d);
                    let (a, b) = (&mut hi[..d], &mut lo[j * d..(j + 1) * d]);
                    (a, b)
                }
            }

            /// The whole flat buffer (row-major).
            pub fn as_slice(&self) -> &[$elem] {
                &self.data
            }

            pub fn as_mut_slice(&mut self) -> &mut [$elem] {
                &mut self.data
            }

            /// Iterate rows in node order.
            pub fn rows(&self) -> impl Iterator<Item = &[$elem]> {
                let d = self.d;
                let data = &self.data;
                (0..self.n).map(move |i| &data[i * d..(i + 1) * d])
            }

            pub fn fill(&mut self, v: $elem) {
                self.data.fill(v);
            }

            /// O(1) double-buffer flip with an equally-shaped arena — the
            /// per-round "swap message and scratch" step of iterated
            /// kernels.
            pub fn swap(&mut self, other: &mut $name) {
                assert_eq!(self.n, other.n, "swap needs equal shapes");
                assert_eq!(self.d, other.d, "swap needs equal shapes");
                std::mem::swap(&mut self.data, &mut other.data);
            }

            /// Reshape in place (contents zeroed).  Reallocates only when
            /// the new arena is larger than any previous shape — scratch
            /// buffers reach a steady state after the first use.
            pub fn reset(&mut self, n: usize, d: usize) {
                self.n = n;
                self.d = d;
                self.data.clear();
                self.data.resize(n * d, 0.0);
            }

            /// Copy nested rows out (interop / serialization convenience).
            pub fn to_rows(&self) -> Vec<Vec<$elem>> {
                (0..self.n).map(|i| self.row(i).to_vec()).collect()
            }
        }
    };
}

/// Row-major `[n × d]` f32 arena — one contiguous allocation for all
/// per-node vectors.  See the module docs for the accessor contract.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeMatrix {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

/// Row-major `[n × d]` f64 arena — the accumulation-precision twin of
/// [`NodeMatrix`] (exact averaging, push-sum mass bookkeeping).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeMatrixF64 {
    n: usize,
    d: usize,
    data: Vec<f64>,
}

node_matrix_impl!(NodeMatrix, f32);
node_matrix_impl!(NodeMatrixF64, f64);

/// Below this row width the column-partitioned mean degenerates: each
/// worker owns so few columns that its strided pass touches every cache
/// line of the `[n × d]` buffer anyway, multiplying memory traffic by
/// the worker count.  Narrow arenas (the large-n consensus plane, where
/// d is a handful and n reaches 10⁵) stream row-major serially instead.
const COL_PAR_MIN_WIDTH: usize = 256;

impl NodeMatrix {
    /// Column-wise mean accumulated in f64 (the exact row average that
    /// ε-perfect consensus would deliver).  `None` when the arena has no
    /// rows — callers must decide, not index-panic.
    ///
    /// Column-partitioned across the worker pool for wide arenas: each
    /// worker owns a contiguous span of output columns and sums them
    /// over all rows in ascending-row order — the serial op sequence per
    /// column — so pooled and serial results are bit-identical.  (The
    /// grain scales with `n` because each output element costs `n`
    /// reads.)  Narrow arenas take a single row-major streaming pass:
    /// the per-column accumulation order is ascending-row in BOTH loop
    /// nestings, so the two paths are bit-identical too — the width
    /// threshold is a pure performance knob.
    pub fn mean_rows_f64(&self) -> Option<Vec<f64>> {
        if self.n == 0 {
            return None;
        }
        let mut avg = vec![0.0f64; self.d];
        if self.d == 0 {
            return Some(avg);
        }
        let (n, d, data) = (self.n, self.d, &self.data);
        if d < COL_PAR_MIN_WIDTH {
            for i in 0..n {
                let row = &data[i * d..(i + 1) * d];
                for (a, &v) in avg.iter_mut().zip(row) {
                    *a += v as f64;
                }
            }
            for a in avg.iter_mut() {
                *a /= n as f64;
            }
            return Some(avg);
        }
        let grain = (crate::util::pool::MIN_ELEMS_PER_THREAD / n.max(1)).max(1);
        crate::util::pool::par_chunks_grained(&mut avg, 1, grain, |c0, cols| {
            for i in 0..n {
                let row = &data[i * d + c0..i * d + c0 + cols.len()];
                for (a, &v) in cols.iter_mut().zip(row) {
                    *a += v as f64;
                }
            }
            for a in cols.iter_mut() {
                *a /= n as f64;
            }
        });
        Some(avg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_and_layout() {
        let mut m = NodeMatrix::new(3, 4);
        assert_eq!((m.n(), m.d()), (3, 4));
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
        m.row_mut(1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(0), &[0.0; 4]);
        // row-major layout: row 1 occupies elements 4..8
        assert_eq!(&m.as_slice()[4..8], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.rows().count(), 3);
    }

    #[test]
    fn from_rows_roundtrip() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let m = NodeMatrix::from_rows(&rows);
        assert_eq!(m.to_rows(), rows);
    }

    #[test]
    #[should_panic(expected = "row 1")]
    fn from_rows_rejects_ragged() {
        NodeMatrix::from_rows(&[vec![1.0f32], vec![1.0, 2.0]]);
    }

    #[test]
    fn rows_mut_pair_disjoint_both_orders() {
        let mut m = NodeMatrix::from_rows(&[vec![1.0f32], vec![2.0], vec![3.0]]);
        {
            let (a, b) = m.rows_mut_pair(0, 2);
            std::mem::swap(&mut a[0], &mut b[0]);
        }
        assert_eq!(m.row(0), &[3.0]);
        assert_eq!(m.row(2), &[1.0]);
        {
            let (a, b) = m.rows_mut_pair(2, 0);
            assert_eq!((a[0], b[0]), (1.0, 3.0));
        }
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn rows_mut_pair_rejects_same_row() {
        let mut m = NodeMatrix::new(2, 1);
        let _ = m.rows_mut_pair(1, 1);
    }

    #[test]
    fn swap_is_a_buffer_flip() {
        let mut a = NodeMatrix::from_rows(&[vec![1.0f32, 2.0]]);
        let mut b = NodeMatrix::from_rows(&[vec![9.0f32, 8.0]]);
        a.swap(&mut b);
        assert_eq!(a.row(0), &[9.0, 8.0]);
        assert_eq!(b.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn reset_reshapes_and_zeroes() {
        let mut m = NodeMatrix::from_rows(&[vec![7.0f32; 8]; 4]);
        m.reset(2, 3);
        assert_eq!((m.n(), m.d()), (2, 3));
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mean_rows_f64_exact_and_guarded() {
        let m = NodeMatrix::from_rows(&[vec![1.0f32, -2.0], vec![3.0, 4.0]]);
        assert_eq!(m.mean_rows_f64().unwrap(), vec![2.0, 1.0]);
        assert_eq!(NodeMatrix::new(0, 5).mean_rows_f64(), None);
    }

    #[test]
    fn mean_rows_streaming_and_column_paths_agree_bitwise() {
        // One arena straddling the width threshold from below and one
        // from above, same deterministic contents column-for-column: the
        // narrow (row-major streaming) and wide (column-partitioned)
        // paths must produce bit-identical column means, because both
        // accumulate each column in ascending-row order.
        let n = 513; // odd, not a multiple of any worker count
        let narrow_d = COL_PAR_MIN_WIDTH - 1;
        let wide_d = COL_PAR_MIN_WIDTH;
        let val = |i: usize, c: usize| ((i * 31 + c * 7) % 97) as f32 * 0.25 - 11.5;
        let mut narrow = NodeMatrix::new(n, narrow_d);
        let mut wide = NodeMatrix::new(n, wide_d);
        for i in 0..n {
            for c in 0..narrow_d {
                narrow.row_mut(i)[c] = val(i, c);
            }
            for c in 0..wide_d {
                wide.row_mut(i)[c] = val(i, c);
            }
        }
        let a = narrow.mean_rows_f64().unwrap();
        let b = wide.mean_rows_f64().unwrap();
        for c in 0..narrow_d {
            assert_eq!(
                a[c].to_bits(),
                b[c].to_bits(),
                "column {c}: streaming and column-split means diverged"
            );
        }
    }

    #[test]
    fn f64_variant_same_contract() {
        let mut m = NodeMatrixF64::new(2, 2);
        m.row_mut(0)[1] = 0.5;
        let (a, b) = m.rows_mut_pair(0, 1);
        b[0] = a[1] * 2.0;
        assert_eq!(m.row(1), &[1.0, 0.0]);
        let mut s = NodeMatrixF64::new(2, 2);
        m.swap(&mut s);
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(s.row(0), &[0.0, 0.5]);
    }
}
