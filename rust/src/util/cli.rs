//! Minimal CLI argument parser (no clap in the offline vendor set —
//! DESIGN.md §7).  Supports `--key value`, `--key=value`, `--flag`, and
//! positional arguments; typed getters with defaults and error messages.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Invalid(String, String, &'static str),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(k) => write!(f, "missing required option --{k}"),
            CliError::Invalid(k, v, want) => {
                write!(f, "option --{k} has invalid value '{v}': expected {want}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Boolean flags must be declared so `--verbose out.csv` parses as a flag
/// plus a positional rather than `verbose=out.csv` (standard CLI
/// disambiguation without a full schema).
pub const KNOWN_FLAGS: &[&str] = &[
    "verbose", "help", "quiet", "dry-run", "small", "exact-bt", "node-log",
    "pjrt", "native", "quick", "exact-consensus",
];

impl Args {
    /// Parse an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        Args::parse_with_flags(raw, KNOWN_FLAGS)
    }

    /// Parse with an explicit boolean-flag vocabulary.
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(raw: I, known: &[&str]) -> Args {
        let mut it = raw.into_iter().peekable();
        let mut args = Args { positional: Vec::new(), options: BTreeMap::new(), flags: Vec::new() };
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known.contains(&rest) {
                    args.flags.push(rest.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(name.into(), v.into(), "unsigned integer")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| CliError::Invalid(name.into(), v.into(), "u64"))
            }
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::Invalid(name.into(), v.into(), "float")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::Missing(name.into()))
    }

    /// First positional argument (usually the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

/// Parse the worker-pool size flag `--threads N` (`amb run` /
/// `amb figures`).  `None` when absent — then `AMB_THREADS`, then
/// `available_parallelism()`, decide (see `util::pool`).  `--threads 0`
/// is rejected with a pointer at the serial spelling: every run needs
/// at least the calling thread.
pub fn threads_arg(args: &Args) -> Result<Option<usize>, CliError> {
    match args.get("threads") {
        None => Ok(None),
        Some(v) => match v.parse::<usize>() {
            Ok(0) => Err(CliError::Invalid(
                "threads".into(),
                v.into(),
                "an integer >= 1 (use --threads 1 for the serial path)",
            )),
            Ok(n) => Ok(Some(n)),
            Err(_) => Err(CliError::Invalid("threads".into(), v.into(), "an integer >= 1")),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("run --nodes 10 --seed=42 --verbose out.csv");
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 10);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "out.csv"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize_or("nodes", 7).unwrap(), 7);
        assert_eq!(a.f64_or("t", 1.5).unwrap(), 1.5);
        assert!(!a.flag("verbose"));
        assert_eq!(a.str_or("fig", "all"), "all");
    }

    #[test]
    fn invalid_value_errors() {
        let a = parse("--nodes banana");
        assert!(a.usize_or("nodes", 1).is_err());
    }

    #[test]
    fn missing_required_errors() {
        let a = parse("run");
        assert!(a.require("out").is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--dry-run --nodes 3");
        assert!(a.flag("dry-run"));
        assert_eq!(a.usize_or("nodes", 0).unwrap(), 3);
    }

    #[test]
    fn unknown_trailing_option_is_flag() {
        // Unknown `--thing` at end of line (no value available) => flag.
        let a = parse("run --thing");
        assert!(a.flag("thing"));
    }

    #[test]
    fn custom_flag_vocabulary() {
        let a = Args::parse_with_flags(
            "--fast out.csv".split_whitespace().map(|s| s.to_string()),
            &["fast"],
        );
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn negative_number_as_value() {
        // `--shift -1.5`: "-1.5" doesn't start with "--" so it's a value.
        let a = parse("--shift -1.5");
        assert_eq!(a.f64_or("shift", 0.0).unwrap(), -1.5);
    }

    #[test]
    fn threads_flag_parsing() {
        assert_eq!(threads_arg(&parse("run")).unwrap(), None);
        assert_eq!(threads_arg(&parse("run --threads 4")).unwrap(), Some(4));
        assert_eq!(threads_arg(&parse("run --threads=1")).unwrap(), Some(1));
        // 0 and junk are errors, and the 0 message points at --threads 1
        let zero = threads_arg(&parse("run --threads 0")).unwrap_err();
        assert!(zero.to_string().contains("--threads 1"), "{zero}");
        assert!(threads_arg(&parse("run --threads lots")).is_err());
    }
}
