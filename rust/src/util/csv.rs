//! Tiny CSV writer for figure series (results/*.csv).  Quoting is applied
//! only when needed; floats use shortest round-trip formatting.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// In-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Push a row of already-formatted cells; panics on width mismatch
    /// (catching column bugs at the call site).
    pub fn push(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "csv row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: all-numeric row.
    pub fn push_nums(&mut self, cells: &[f64]) {
        self.push(&cells.iter().map(|x| fmt_f64(*x)).collect::<Vec<_>>());
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        writeln_row(&mut out, &self.header);
        for row in &self.rows {
            writeln_row(&mut out, row);
        }
        out
    }

    /// Write to disk, creating parent directories.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_string())
    }
}

fn writeln_row(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains([',', '"', '\n']) {
            let escaped = c.replace('"', "\"\"");
            let _ = write!(out, "\"{escaped}\"");
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

/// Shortest clean float formatting for CSV cells.
pub fn fmt_f64(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rows() {
        let mut c = Csv::new(&["t", "loss"]);
        c.push_nums(&[1.0, 0.5]);
        c.push_nums(&[2.0, 0.25]);
        let s = c.to_string();
        assert!(s.starts_with("t,loss\n1,"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn quotes_when_needed() {
        let mut c = Csv::new(&["name", "v"]);
        c.push(&["a,b".into(), "x\"y".into()]);
        let s = c.to_string();
        assert!(s.contains("\"a,b\""));
        assert!(s.contains("\"x\"\"y\""));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn width_mismatch_panics() {
        let mut c = Csv::new(&["a", "b"]);
        c.push(&["1".into()]);
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join("amb_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = Csv::new(&["x"]);
        c.push_nums(&[1.5]);
        let path = dir.join("sub/out.csv");
        c.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("1.5"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
