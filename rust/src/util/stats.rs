//! Summary statistics, histograms and order-statistic bounds used by the
//! straggler analysis (paper Sec. 5, App. G/H) and the bench harness.

/// Running mean/variance (Welford) — numerically stable one-pass moments.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (n in the denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance of a slice.
pub fn variance(xs: &[f64]) -> f64 {
    let mut w = Welford::new();
    for &x in xs {
        w.push(x);
    }
    w.variance()
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Quantile by linear interpolation on the sorted copy; q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Arnold–Groeneveld / Bertsimas-Natarajan-Teo bound on the expected
/// maximum of n i.i.d. samples (paper eq. (75)):
/// E[max_i T_i] <= mu + sigma * sqrt(n - 1).
pub fn expected_max_bound(mu: f64, sigma: f64, n: usize) -> f64 {
    mu + sigma * ((n.max(1) - 1) as f64).sqrt()
}

/// Expected maximum of n i.i.d. shifted exponentials (paper eq. (81)):
/// E[max] = zeta + H_n / lambda  (harmonic number; the paper writes the
/// large-n log(n) form).
pub fn shifted_exp_expected_max(zeta: f64, lambda: f64, n: usize) -> f64 {
    let h: f64 = (1..=n).map(|k| 1.0 / k as f64).sum();
    zeta + h / lambda
}

/// Fixed-width histogram over [lo, hi); values outside clamp to end bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn push(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1);
        self.counts[idx as usize] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Center of bin i.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// (center, count) rows — what the figure benches print.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        (0..self.counts.len()).map(|i| (self.center(i), self.counts[i])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        let naive = xs.iter().map(|x| (x - 6.2) * (x - 6.2)).sum::<f64>() / 5.0;
        assert!((w.variance() - naive).abs() < 1e-9);
    }

    #[test]
    fn quantile_endpoints_and_median() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 2.0);
        assert_eq!(quantile(&xs, 1.0), 3.0);
    }

    #[test]
    fn expected_max_bound_monotone_in_n() {
        let b2 = expected_max_bound(1.0, 0.5, 2);
        let b10 = expected_max_bound(1.0, 0.5, 10);
        assert!(b10 > b2);
        assert_eq!(expected_max_bound(1.0, 0.5, 1), 1.0);
    }

    #[test]
    fn shifted_exp_max_matches_simulation() {
        let (zeta, lambda, n) = (1.0, 2.0 / 3.0, 10);
        let analytic = shifted_exp_expected_max(zeta, lambda, n);
        let mut rng = Pcg64::new(0);
        let trials = 20_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let m = (0..n)
                .map(|_| rng.shifted_exp(zeta, lambda))
                .fold(f64::NEG_INFINITY, f64::max);
            acc += m;
        }
        let sim = acc / trials as f64;
        assert!((sim - analytic).abs() / analytic < 0.02, "sim={sim} analytic={analytic}");
    }

    #[test]
    fn empirical_max_obeys_bnt_bound() {
        // E[max] <= mu + sigma*sqrt(n-1) for any distribution (paper eq. 75).
        let mut rng = Pcg64::new(1);
        let n = 8;
        let (zeta, lambda) = (1.0, 0.5);
        let mu = zeta + 1.0 / lambda;
        let sigma = 1.0 / lambda;
        let trials = 20_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let m = (0..n)
                .map(|_| rng.shifted_exp(zeta, lambda))
                .fold(f64::NEG_INFINITY, f64::max);
            acc += m;
        }
        assert!(acc / trials as f64 <= expected_max_bound(mu, sigma, n));
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(0.5);
        h.push(9.99);
        h.push(-5.0); // clamps to bin 0
        h.push(50.0); // clamps to last bin
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total(), 4);
        assert!((h.center(0) - 0.5).abs() < 1e-12);
    }
}
