//! Scoped worker pool over `std::thread` — the crate's parallel
//! execution layer (no rayon/crossbeam in the offline vendor set,
//! DESIGN.md §7).
//!
//! Design goals, in order:
//!
//! 1. **Bitwise determinism.**  Every helper partitions work in a FIXED
//!    order into DISJOINT outputs; a worker never changes *what* is
//!    computed, only *where*.  `threads = 1` and `threads = k` runs are
//!    bit-identical by construction (pinned by
//!    `tests/parallel_determinism.rs`), so the thread count is a pure
//!    performance knob.
//! 2. **No `unsafe`.**  Parallel regions are `std::thread::scope` blocks;
//!    borrowed inputs flow into workers through ordinary scoped borrows
//!    and mutable outputs through `split_at_mut` row blocks.  The cost is
//!    a thread spawn per region (~tens of µs), which is why the helpers
//!    gate on a minimum work size and callers hoist parallelism to the
//!    largest safe granularity (a whole mix round, a whole epoch compute
//!    phase, a whole sweep item).
//! 3. **No nested oversubscription.**  Threads spawned here mark
//!    themselves as pool workers; any pool call *from inside a worker*
//!    runs serial.  A concurrent experiment sweep therefore runs each
//!    inner simulation single-threaded instead of multiplying thread
//!    counts.
//!
//! Sizing: `--threads N` on the CLI (via [`set_threads`]) beats the
//! `AMB_THREADS` environment variable, which beats
//! `std::thread::available_parallelism()`.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::OnceLock;

/// Process-wide override (0 = unset): `--threads` / [`set_threads`].
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Cached `AMB_THREADS` parse (read once; `None` = absent or invalid).
static ENV_THREADS: OnceLock<Option<usize>> = OnceLock::new();

thread_local! {
    /// Set on threads spawned by this module; see module docs.
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
}

/// Below this many elements of output per worker a thread spawn costs
/// more than it saves; the helpers fall back to the serial path.
pub const MIN_ELEMS_PER_THREAD: usize = 1 << 15;

fn env_threads() -> Option<usize> {
    *ENV_THREADS.get_or_init(|| match std::env::var("AMB_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => {
                eprintln!("warning: ignoring AMB_THREADS='{s}' (want an integer >= 1)");
                None
            }
        },
        Err(_) => None,
    })
}

/// Override the pool size for this process (the CLI's `--threads N`).
/// `1` means "always take the serial path".
pub fn set_threads(n: usize) {
    assert!(n >= 1, "thread count must be >= 1 (use 1 for the serial path)");
    OVERRIDE.store(n, Ordering::SeqCst);
}

/// Drop a [`set_threads`] override (tests and benches restore the
/// environment-driven default this way).
pub fn clear_threads_override() {
    OVERRIDE.store(0, Ordering::SeqCst);
}

/// Is the calling thread a pool worker?  (Pool calls made from workers
/// run serial — see module docs.)
pub fn is_pool_worker() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

pub(crate) fn mark_pool_worker() {
    IN_POOL_WORKER.with(|f| f.set(true));
}

/// The pool size parallel regions will use from the calling thread:
/// 1 inside a pool worker, else `--threads` override, else `AMB_THREADS`,
/// else `available_parallelism()`.
pub fn current_threads() -> usize {
    if is_pool_worker() {
        return 1;
    }
    let o = OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    if let Some(n) = env_threads() {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Partition a flat `[rows × width]` buffer into contiguous row blocks,
/// one per worker, and run `f(first_row, block)` on each concurrently.
///
/// The partition is a pure function of `(data.len(), width, threads)`
/// and every block is disjoint, so as long as `f` computes each row
/// independently of the partition (true of every caller: mix kernels,
/// column sums), results are bit-identical to `f(0, data)`.
pub fn par_chunks<T, F>(data: &mut [T], width: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_grained(data, width, MIN_ELEMS_PER_THREAD, f)
}

/// [`par_chunks`] with an explicit serial-fallback grain: spawn at most
/// `data.len() / grain` workers.  Callers whose per-element cost is far
/// from one flop (e.g. a column sum touching `n` rows per output
/// element) scale the grain accordingly.
pub fn par_chunks_grained<T, F>(data: &mut [T], width: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(width > 0, "par_chunks needs a positive row width");
    debug_assert_eq!(data.len() % width, 0, "data must be whole rows");
    let rows = data.len() / width;
    let threads = current_threads().min(rows).min((data.len() / grain.max(1)).max(1));
    if threads <= 1 {
        f(0, data);
        return;
    }
    let base = rows / threads;
    let extra = rows % threads;
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = data;
        let mut row0 = 0usize;
        for w in 0..threads {
            let take = base + usize::from(w < extra);
            let (block, tail) = rest.split_at_mut(take * width);
            rest = tail;
            let r0 = row0;
            s.spawn(move || {
                mark_pool_worker();
                f(r0, block);
            });
            row0 += take;
        }
    });
}

/// Run `f(0), f(1), …, f(count − 1)` on the pool and return the results
/// **in index order**, whatever order workers finish in.  Workers pull
/// indices from a shared counter (work stealing), so uneven items
/// balance; each result lands in its own slot, so ordering is exact.
pub fn par_indexed<T, F>(count: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = current_threads().min(count);
    if threads <= 1 {
        return (0..count).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut slots: Vec<Option<T>> = Vec::with_capacity(count);
    slots.resize_with(count, || None);
    std::thread::scope(|s| {
        let f = &f;
        let next = &next;
        for _ in 0..threads {
            let tx = tx.clone();
            s.spawn(move || {
                mark_pool_worker();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= count {
                        break;
                    }
                    if tx.send((i, f(i))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            slots[i] = Some(v);
        }
    });
    slots
        .into_iter()
        // amb-lint: allow(D4, "a worker that died without replying already panicked the pool")
        .map(|o| o.expect("pool worker died before returning its result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Pool configuration is process-global; tests that touch it
    /// serialize here so they can't observe each other's overrides.
    static CONFIG_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn par_chunks_matches_serial_bitwise() {
        let _g = CONFIG_LOCK.lock().unwrap();
        set_threads(4);
        let rows = 37usize;
        let width = 11usize;
        let mut serial: Vec<f32> = (0..rows * width).map(|i| i as f32 * 0.5).collect();
        let mut parallel = serial.clone();
        let work = |row0: usize, block: &mut [f32]| {
            for (r, row) in block.chunks_mut(width).enumerate() {
                let i = row0 + r;
                for (k, v) in row.iter_mut().enumerate() {
                    *v = (*v + i as f32) * (k as f32 + 1.0);
                }
            }
        };
        work(0, &mut serial);
        // grain 1 so the tiny buffer still fans out
        par_chunks_grained(&mut parallel, width, 1, work);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        clear_threads_override();
    }

    #[test]
    fn par_indexed_preserves_index_order() {
        let _g = CONFIG_LOCK.lock().unwrap();
        set_threads(4);
        // later items are cheap, early items spin — completion order is
        // (very likely) inverted, result order must not be
        let out = par_indexed(16, |i| {
            let mut acc = 0u64;
            for k in 0..(16 - i) * 20_000 {
                acc = acc.wrapping_add(k as u64);
            }
            std::hint::black_box(acc);
            i * 10
        });
        assert_eq!(out, (0..16).map(|i| i * 10).collect::<Vec<_>>());
        clear_threads_override();
    }

    #[test]
    fn nested_pool_calls_run_serial() {
        let _g = CONFIG_LOCK.lock().unwrap();
        set_threads(4);
        assert!(!is_pool_worker());
        let inner_threads = par_indexed(4, |_| current_threads());
        // every worker sees a serial pool
        assert_eq!(inner_threads, vec![1; 4]);
        clear_threads_override();
    }

    #[test]
    fn override_and_clear() {
        let _g = CONFIG_LOCK.lock().unwrap();
        set_threads(3);
        assert_eq!(current_threads(), 3);
        clear_threads_override();
        assert!(current_threads() >= 1);
    }

    #[test]
    #[should_panic(expected = ">= 1")]
    fn zero_threads_rejected() {
        set_threads(0);
    }
}
