//! Dependency-free utilities: PRNG, statistics, JSON, CSV, CLI parsing,
//! and the scoped worker pool ([`pool`]).
//!
//! The offline vendor set ships no rand/serde/clap (DESIGN.md §7), so
//! these are small, fully-tested local implementations.

pub mod cli;
pub mod csv;
pub mod json;
pub mod matrix;
pub mod pool;
pub mod rng;
pub mod stats;

/// Dot product over f32 slices (panics on length mismatch).
///
/// Perf: 8 independent accumulators break the loop-carried dependency so
/// the compiler can vectorize (EXPERIMENTS.md §Perf iteration 2).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for lane in 0..8 {
            acc[lane] += a[i + lane] * b[i + lane];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    acc.iter().sum::<f32>() + tail
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Four sequential axpys fused into ONE sweep over `y`:
///   y[k] = (((y[k] + w[0]·x[0][k]) + w[1]·x[1][k]) + w[2]·x[2][k]) + w[3]·x[3][k]
///
/// The parenthesisation forces the exact per-element op order of applying
/// the four axpys one at a time, so the result is BIT-IDENTICAL to the
/// unfused form (Rust never reassociates float ops) — but `y` is read and
/// written once instead of four times and the four independent multiplies
/// pipeline.  This is what makes the flat consensus kernel beat the
/// legacy row-at-a-time loop on memory-bound shapes.
#[inline]
pub fn axpy4(w: [f32; 4], x: [&[f32]; 4], y: &mut [f32]) {
    let n = y.len();
    for xi in &x {
        assert_eq!(xi.len(), n);
    }
    let (x0, x1, x2, x3) = (x[0], x[1], x[2], x[3]);
    for k in 0..n {
        y[k] = (((y[k] + w[0] * x0[k]) + w[1] * x1[k]) + w[2] * x2[k]) + w[3] * x3[k];
    }
}

/// L2 norm.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for &v in a {
        acc += (v as f64) * (v as f64);
    }
    acc.sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn axpy4_bitwise_equals_four_axpys() {
        let mut g = crate::prop::Gen::new(0xA4);
        for _ in 0..50 {
            let n = g.usize_in(1, 40);
            let w = [
                g.f64_in(-2.0, 2.0) as f32,
                g.f64_in(-2.0, 2.0) as f32,
                g.f64_in(-2.0, 2.0) as f32,
                g.f64_in(-2.0, 2.0) as f32,
            ];
            let xs: Vec<Vec<f32>> = (0..4).map(|_| g.vec_normal_f32(n, 3.0)).collect();
            let y0 = g.vec_normal_f32(n, 3.0);

            let mut seq = y0.clone();
            for (wi, xi) in w.iter().zip(&xs) {
                axpy(*wi, xi, &mut seq);
            }
            let mut fused = y0;
            axpy4(w, [&xs[0], &xs[1], &xs[2], &xs[3]], &mut fused);
            for k in 0..n {
                assert_eq!(seq[k].to_bits(), fused[k].to_bits(), "k={k}");
            }
        }
    }
}
