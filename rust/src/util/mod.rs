//! Dependency-free utilities: PRNG, statistics, JSON, CSV, CLI parsing.
//!
//! The offline vendor set ships no rand/serde/clap (DESIGN.md §7), so
//! these are small, fully-tested local implementations.

pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;

/// Dot product over f32 slices (panics on length mismatch).
///
/// Perf: 8 independent accumulators break the loop-carried dependency so
/// the compiler can vectorize (EXPERIMENTS.md §Perf iteration 2).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for lane in 0..8 {
            acc[lane] += a[i + lane] * b[i + lane];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    acc.iter().sum::<f32>() + tail
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// L2 norm.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for &v in a {
        acc += (v as f64) * (v as f64);
    }
    acc.sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
