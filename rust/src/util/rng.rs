//! Deterministic PRNG + samplers (no external crates; the vendored set has
//! no `rand`).  PCG64 (XSL-RR 128/64) — fast, seedable, good statistical
//! quality for simulation workloads.  Every experiment takes an explicit
//! seed so runs are bit-reproducible (DESIGN.md §6).

/// PCG64 XSL-RR generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create from a 64-bit seed (stream fixed) via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = state.wrapping_add(rng.inc);
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-node streams).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (no cached spare: simpler, branch-free
    /// determinism when splitting streams).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// N(mu, sigma^2).
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Shifted exponential: shift + Exp(lambda) — the straggler model of
    /// paper App. H / I.2.
    pub fn shifted_exp(&mut self, shift: f64, lambda: f64) -> f64 {
        shift + self.exponential(lambda)
    }

    /// Fill a slice with N(0, scale^2) f32 values.
    ///
    /// Perf (EXPERIMENTS.md §Perf iterations 1+3): Marsaglia polar method
    /// — one (ln, sqrt) and no trigonometry per TWO outputs (≈27%
    /// rejection).  Data generation dominates the native gradient hot
    /// path; vs the naive per-value Box–Muller this is ≈2× on the
    /// 256×1024 linreg chunk.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], scale: f32) {
        let mut i = 0;
        let n = out.len();
        while i + 1 < n {
            let (v1, v2, s) = loop {
                let v1 = 2.0 * self.f64() - 1.0;
                let v2 = 2.0 * self.f64() - 1.0;
                let s = v1 * v1 + v2 * v2;
                if s < 1.0 && s > 0.0 {
                    break (v1, v2, s);
                }
            };
            let mul = (-2.0 * s.ln() / s).sqrt();
            out[i] = (v1 * mul) as f32 * scale;
            out[i + 1] = (v2 * mul) as f32 * scale;
            i += 2;
        }
        if i < n {
            out[i] = self.normal() as f32 * scale;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — used only to expand seeds for PCG64.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(13);
        let lambda = 2.0 / 3.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shifted_exp_min_is_shift() {
        let mut r = Pcg64::new(17);
        let min = (0..10_000)
            .map(|_| r.shifted_exp(1.0, 0.5))
            .fold(f64::INFINITY, f64::min);
        assert!(min >= 1.0);
        assert!(min < 1.01);
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fill_normal_moments_and_determinism() {
        let mut r = Pcg64::new(31);
        let mut buf = vec![0.0f32; 200_001]; // odd length exercises the tail
        r.fill_normal_f32(&mut buf, 2.0);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 4.0).abs() < 0.08, "var={var}");
        // deterministic per seed
        let mut r2 = Pcg64::new(31);
        let mut buf2 = vec![0.0f32; 200_001];
        r2.fill_normal_f32(&mut buf2, 2.0);
        assert_eq!(buf, buf2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
