//! Minimal JSON reader/writer (no serde in the offline vendor set —
//! DESIGN.md §7).  Covers the subset the project needs: the artifact
//! manifest written by aot.py, experiment configs and metrics output.
//!
//! Parser is a straightforward recursive-descent over the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are sorted (BTreeMap) so serialization
/// is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `a.b.c` path lookup.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize> (shapes in the manifest).
    pub fn as_usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- parse -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy raw continuation bytes
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        // amb-lint: allow(D4, "number lexer scanned only ASCII digit/sign/exponent bytes")
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"file":"a.hlo.txt","shape":[2,3]}],"n":10,"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t\\ö""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\\ö"));
        // serialize escapes control chars
        let s = Json::Str("a\"b\n".into()).to_string();
        assert_eq!(s, r#""a\"b\n""#);
    }

    #[test]
    fn usize_array() {
        let v = Json::parse("[4, 8, 15]").unwrap();
        assert_eq!(v.as_usize_arr(), Some(vec![4, 8, 15]));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": "hlo-text-v1",
          "params": {"linreg_c": 32, "transformer": {"param_count": 13088}},
          "entries": [
            {"name": "linreg_grad_c32_d64", "file": "linreg_grad_c32_d64.hlo.txt",
             "inputs": [{"shape": [64], "dtype": "f32"}],
             "outputs": [{"shape": [], "dtype": "f32"}]}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("params.linreg_c").unwrap().as_usize(), Some(32));
        assert_eq!(
            v.path("params.transformer.param_count").unwrap().as_usize(),
            Some(13088)
        );
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("inputs").unwrap().as_arr().unwrap()[0]
                       .get("shape").unwrap().as_usize_arr(), Some(vec![64]));
    }
}
