//! # anytime-mb
//!
//! Production-grade reproduction of **"Anytime Minibatch: Exploiting
//! Stragglers in Online Distributed Optimization"** (Ferdinand, Al-Lawati,
//! Draper, Nokleby — ICLR 2019) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the coordinator: AMB/FMB/redundancy epoch
//!   schedulers behind ONE runtime API ([`RunSpec`] → [`run`]), executed
//!   by a discrete-event cluster simulator or a real threaded cluster,
//!   averaging consensus over arbitrary topologies, dual averaging,
//!   straggler models, metrics, and per-figure experiment harnesses.
//! * **L2/L1 (python/compile, build-time only)** — JAX compute graphs
//!   calling Pallas kernels, AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **Runtime bridge** — [`runtime`] loads the artifacts through the
//!   xla-crate PJRT CPU client; Python never runs on the request path.
//!
//! See DESIGN.md for the full system inventory (and §3 for the runtime
//! API, including the migration table from the old two-API surface) and
//! EXPERIMENTS.md for the paper-vs-measured results.

#![forbid(unsafe_code)]
#![deny(clippy::dbg_macro, clippy::todo, clippy::unimplemented, clippy::mem_forget)]

pub mod analysis;
pub mod bench_harness;
pub mod churn;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod experiments;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod net;
pub mod optim;
pub mod prop;
pub mod runtime;
pub mod straggler;
pub mod topology;
pub mod util;

pub use churn::{ChurnSchedule, ChurnSpec};
pub use coordinator::sim::SimRuntime;
pub use coordinator::threaded::ThreadedRuntime;
pub use coordinator::{
    ConsensusMode, EngineFactory, RunOutput, RunSpec, Runtime, RuntimeKind, Scheme,
};
pub use fault::{CrashWindow, FaultSpec, Flap};
pub use net::{FabricSpec, NetworkModel};

/// THE entry point: execute one [`RunSpec`] on any [`Runtime`].
///
/// ```no_run
/// use anytime_mb::{RunSpec, SimRuntime, ThreadedRuntime};
/// # use anytime_mb::exec::{DataSource, NativeExec, ExecEngine};
/// # use anytime_mb::data::LinRegStream;
/// # use anytime_mb::optim::{BetaSchedule, DualAveraging};
/// # use anytime_mb::straggler::ShiftedExp;
/// # use std::sync::Arc;
/// let topo = anytime_mb::topology::Topology::paper_fig2();
/// let spec = RunSpec::amb("demo", 2.5, 0.5, 5, 10, 42);
/// let strag = ShiftedExp::paper_i2();
/// let src = Arc::new(DataSource::LinReg(LinRegStream::new(64, 0)));
/// let opt = DualAveraging::new(BetaSchedule::new(1.0, 6000.0), 32.0);
/// let f_star = src.f_star();
/// let mk = move |_i: usize| -> Box<dyn ExecEngine> {
///     Box::new(NativeExec::new(src.clone(), opt.clone()))
/// };
/// // same spec, either runtime:
/// let sim_out = anytime_mb::run(&SimRuntime::new(&strag), &spec, &topo, &mk, f_star).unwrap();
/// let thr_out = anytime_mb::run(&ThreadedRuntime, &spec, &topo, &mk, f_star).unwrap();
/// # let _ = (sim_out, thr_out);
/// ```
///
/// Errors on unsupported spec combinations (e.g. the packet fabric with
/// a non-gossip consensus mode, or link faults under exact averaging) —
/// surfaced as clean CLI messages rather than panics.
pub fn run(
    runtime: &dyn Runtime,
    spec: &RunSpec,
    topo: &topology::Topology,
    make_engine: EngineFactory<'_>,
    f_star: Option<f64>,
) -> anyhow::Result<RunOutput> {
    runtime.run(spec, topo, make_engine, f_star)
}

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Default results directory for figure CSVs.
pub const RESULTS_DIR: &str = "results";

/// Resolve the artifacts directory: $AMB_ARTIFACTS, else ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("AMB_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(ARTIFACTS_DIR))
}
