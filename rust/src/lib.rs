//! # anytime-mb
//!
//! Production-grade reproduction of **"Anytime Minibatch: Exploiting
//! Stragglers in Online Distributed Optimization"** (Ferdinand, Al-Lawati,
//! Draper, Nokleby — ICLR 2019) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the coordinator: AMB/FMB epoch schedulers, a
//!   discrete-event cluster simulator and a real threaded cluster,
//!   averaging consensus over arbitrary topologies, dual averaging,
//!   straggler models, metrics, and per-figure experiment harnesses.
//! * **L2/L1 (python/compile, build-time only)** — JAX compute graphs
//!   calling Pallas kernels, AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **Runtime bridge** — [`runtime`] loads the artifacts through the
//!   xla-crate PJRT CPU client; Python never runs on the request path.
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod bench_harness;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod prop;
pub mod runtime;
pub mod straggler;
pub mod topology;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Default results directory for figure CSVs.
pub const RESULTS_DIR: &str = "results";

/// Resolve the artifacts directory: $AMB_ARTIFACTS, else ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("AMB_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(ARTIFACTS_DIR))
}
