//! Elastic membership: per-epoch node churn schedules.
//!
//! The paper (Sec. 3) fixes the graph G(V,E) and the doubly-stochastic P
//! for the whole run, but its own premise — cloud nodes whose speed
//! varies with latent load — extends naturally to nodes that *disappear
//! and return*: maintenance reboots, spot-instance preemption, network
//! partitions.  "Anytime Minibatch with Delayed Gradients" (Al-Lawati &
//! Draper) relaxes synchrony across epochs and "Redundancy Techniques
//! for Straggler Mitigation" (Karakus et al.) treats outright failure;
//! this module supplies the membership process both need.
//!
//! A [`ChurnSpec`] describes the process (part of
//! [`crate::coordinator::RunSpec`], so one spec drives both runtimes and
//! round-trips through config JSON); a [`ChurnSchedule`] is the
//! materialised per-epoch active-set table, a **pure function of
//! (spec, n, epochs)** — every sim worker and every threaded node thread
//! derives the identical table, so membership needs no coordination
//! channel, exactly like the derived RNG streams in
//! [`crate::coordinator::epoch`].
//!
//! Semantics (DESIGN.md §churn): an inactive node contributes b_i = 0,
//! is *isolated* in the epoch's consensus graph (nobody mixes against
//! it, it mixes against nobody), and holds its dual/primal state; on
//! rejoining it simply re-enters the weighted average with its held
//! state — "wasted work never blocks progress" extended to "absent
//! nodes never block progress".  The i.i.d./Markov/trace family mirrors
//! the [`crate::straggler::StragglerModel`] family: dropout is the
//! memoryless baseline, the Markov chain models correlated outages
//! (maintenance windows), and traces replay digitised real logs.

use crate::util::rng::Pcg64;

/// Declarative churn process — lives in `RunSpec`, serialises to config
/// JSON, and is materialised per run by [`ChurnSchedule::new`].
#[derive(Debug, Clone, PartialEq)]
pub enum ChurnSpec {
    /// Static membership: every node active in every epoch (the paper's
    /// setting).  Runs with `None` take the exact pre-churn code paths,
    /// so their outputs are bit-for-bit unchanged.
    None,
    /// Every (node, epoch) is independently down with probability `p`.
    /// `p = 0` reproduces the static schedule (and therefore today's
    /// outputs bit-for-bit — pinned by `tests/churn.rs`).
    IidDropout { p: f64, seed: u64 },
    /// Per-node two-state Markov chain: an up node goes down with
    /// `p_down` per epoch, a down node recovers with `p_up`.  Models
    /// correlated outages (a rebooting node is likely still down next
    /// epoch).  Chains start up and evolve deterministically from
    /// (seed, node) — one sequential pass, never an O(T²) replay.
    Markov { p_down: f64, p_up: f64, seed: u64 },
    /// Explicit trace: `active[node][epoch % active[node].len()]`
    /// (1-based epochs map to index `epoch - 1`), wrapping like
    /// [`crate::straggler::TraceReplay`].
    Trace { active: Vec<Vec<bool>> },
}

impl ChurnSpec {
    pub fn is_none(&self) -> bool {
        matches!(self, ChurnSpec::None)
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChurnSpec::None => "none",
            ChurnSpec::IidDropout { .. } => "iid",
            ChurnSpec::Markov { .. } => "markov",
            ChurnSpec::Trace { .. } => "trace",
        }
    }

    /// Parse the CLI surface (`amb run --churn SPEC`):
    ///   `none` | `iid:P[:SEED]` | `markov:P_DOWN:P_UP[:SEED]`
    /// with SEED defaulting to `default_seed` (the run seed) so churn
    /// weather is reproducible per run by default.
    pub fn parse(s: &str, default_seed: u64) -> anyhow::Result<ChurnSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        let prob = |v: &str, what: &str| -> anyhow::Result<f64> {
            let p: f64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--churn: {what} '{v}' is not a number"))?;
            anyhow::ensure!((0.0..=1.0).contains(&p), "--churn: {what} {p} not in [0, 1]");
            Ok(p)
        };
        let seed = |v: Option<&&str>| -> anyhow::Result<u64> {
            match v {
                None => Ok(default_seed),
                Some(s) => s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--churn: seed '{s}' is not a u64")),
            }
        };
        match parts.as_slice() {
            ["none"] => Ok(ChurnSpec::None),
            ["iid", p, rest @ ..] if rest.len() <= 1 => Ok(ChurnSpec::IidDropout {
                p: prob(p, "dropout probability")?,
                seed: seed(rest.first())?,
            }),
            ["markov", pd, pu, rest @ ..] if rest.len() <= 1 => Ok(ChurnSpec::Markov {
                p_down: prob(pd, "p_down")?,
                p_up: prob(pu, "p_up")?,
                seed: seed(rest.first())?,
            }),
            _ => anyhow::bail!(
                "--churn: expected none | iid:P[:SEED] | markov:P_DOWN:P_UP[:SEED] (got '{s}')"
            ),
        }
    }
}

/// Is `node` down in `epoch` under i.i.d. dropout?  A pure function of
/// (seed, node, epoch) via a derived stream — the same derivation idiom
/// as [`crate::coordinator::epoch::gossip_jitter_rounds`], so any
/// process can evaluate any (node, epoch) without shared state.
fn iid_down(seed: u64, node: usize, epoch: usize, p: f64) -> bool {
    let mut rng = Pcg64::new(seed).split(0xC8A2_0000 ^ ((node as u64) << 24) ^ epoch as u64);
    rng.f64() < p
}

/// The materialised per-epoch active-set table for one run.
///
/// Rows are precomputed in ONE pass at construction (O(n · epochs)
/// bools), which is what keeps the Markov variant linear — the chain is
/// never replayed from epoch 0 per query (the bug class fixed in
/// `MarkovModulated::bursting`).  `ChurnSpec::None` stores a single
/// shared all-active row, so static runs pay no per-epoch storage.
pub struct ChurnSchedule {
    n: usize,
    /// Active set per epoch (row `t - 1` for epoch `t`); a single row
    /// when `static_all`.
    rows: Vec<Vec<bool>>,
    counts: Vec<usize>,
    static_all: bool,
}

impl ChurnSchedule {
    pub fn new(spec: &ChurnSpec, n: usize, epochs: usize) -> ChurnSchedule {
        assert!(n > 0, "churn schedule needs at least one node");
        let mut static_all = false;
        let rows: Vec<Vec<bool>> = match spec {
            ChurnSpec::None => {
                static_all = true;
                vec![vec![true; n]]
            }
            ChurnSpec::IidDropout { p, seed } => {
                assert!(
                    (0.0..=1.0).contains(p),
                    "IidDropout probability {p} not in [0, 1]"
                );
                (1..=epochs)
                    .map(|t| (0..n).map(|i| !iid_down(*seed, i, t, *p)).collect())
                    .collect()
            }
            ChurnSpec::Markov { p_down, p_up, seed } => {
                assert!(
                    (0.0..=1.0).contains(p_down) && (0.0..=1.0).contains(p_up),
                    "Markov churn probabilities must lie in [0, 1]"
                );
                let mut rows = vec![vec![true; n]; epochs];
                for node in 0..n {
                    // One sequential chain per node — O(epochs), computed
                    // once; deterministic from (seed, node).
                    let mut rng = Pcg64::new(seed ^ ((node as u64) << 20) ^ 0xC4A1);
                    let mut up = true;
                    for row in rows.iter_mut() {
                        let u = rng.f64();
                        up = if up { u >= *p_down } else { u < *p_up };
                        row[node] = up;
                    }
                }
                rows
            }
            ChurnSpec::Trace { active } => {
                assert_eq!(active.len(), n, "trace churn needs one row per node");
                assert!(
                    active.iter().all(|r| !r.is_empty()),
                    "trace churn rows must be non-empty"
                );
                (1..=epochs)
                    .map(|t| (0..n).map(|i| active[i][(t - 1) % active[i].len()]).collect())
                    .collect()
            }
        };
        let counts = rows
            .iter()
            .map(|r| r.iter().filter(|&&a| a).count())
            .collect();
        ChurnSchedule { n, rows, counts, static_all }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    fn row_index(&self, epoch: usize) -> usize {
        assert!(epoch >= 1, "epochs are 1-based");
        if self.static_all {
            0
        } else {
            assert!(
                epoch <= self.rows.len(),
                "epoch {epoch} beyond the schedule horizon {}",
                self.rows.len()
            );
            epoch - 1
        }
    }

    /// The active set for (1-based) `epoch`.
    pub fn active(&self, epoch: usize) -> &[bool] {
        &self.rows[self.row_index(epoch)]
    }

    /// |A(t)| — number of active nodes in `epoch`.
    pub fn active_count(&self, epoch: usize) -> usize {
        self.counts[self.row_index(epoch)]
    }

    /// Whether every node is active in `epoch` (the zero-rebuild fast
    /// path: the base mixing matrix applies unchanged).
    pub fn is_all_active(&self, epoch: usize) -> bool {
        self.active_count(epoch) == self.n
    }

    /// Mean active fraction over epochs `1..=epochs` (harness summary).
    pub fn mean_active_fraction(&self, epochs: usize) -> f64 {
        if epochs == 0 {
            return 1.0;
        }
        let total: usize = (1..=epochs).map(|t| self.active_count(t)).sum();
        total as f64 / (epochs * self.n) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_static_all_active() {
        let s = ChurnSchedule::new(&ChurnSpec::None, 5, 100);
        for t in 1..=100 {
            assert!(s.is_all_active(t));
            assert_eq!(s.active_count(t), 5);
            assert!(s.active(t).iter().all(|&a| a));
        }
        assert_eq!(s.mean_active_fraction(100), 1.0);
    }

    #[test]
    fn iid_zero_dropout_matches_none() {
        let a = ChurnSchedule::new(&ChurnSpec::None, 8, 20);
        let b = ChurnSchedule::new(&ChurnSpec::IidDropout { p: 0.0, seed: 7 }, 8, 20);
        for t in 1..=20 {
            assert_eq!(a.active(t), b.active(t));
        }
    }

    #[test]
    fn iid_dropout_rate_and_determinism() {
        let spec = ChurnSpec::IidDropout { p: 0.25, seed: 11 };
        let s1 = ChurnSchedule::new(&spec, 10, 400);
        let s2 = ChurnSchedule::new(&spec, 10, 400);
        for t in 1..=400 {
            assert_eq!(s1.active(t), s2.active(t), "schedule must be deterministic");
        }
        let frac = s1.mean_active_fraction(400);
        assert!((frac - 0.75).abs() < 0.03, "frac={frac}");
        // a different seed gives different weather
        let s3 = ChurnSchedule::new(&ChurnSpec::IidDropout { p: 0.25, seed: 12 }, 10, 400);
        assert!((1..=400).any(|t| s1.active(t) != s3.active(t)));
    }

    #[test]
    fn markov_stationary_fraction_and_persistence() {
        // stationary up fraction = p_up / (p_up + p_down) = 0.8
        let spec = ChurnSpec::Markov { p_down: 0.05, p_up: 0.2, seed: 3 };
        let s = ChurnSchedule::new(&spec, 20, 2000);
        let frac = s.mean_active_fraction(2000);
        assert!((frac - 0.8).abs() < 0.05, "frac={frac}");
        // down spells persist: P(down at t+1 | down at t) = 1 - p_up = 0.8,
        // far above the marginal down rate 0.2.
        let (mut down_pairs, mut down_down) = (0usize, 0usize);
        for node in 0..20 {
            for t in 1..2000 {
                if !s.active(t)[node] {
                    down_pairs += 1;
                    down_down += usize::from(!s.active(t + 1)[node]);
                }
            }
        }
        let persist = down_down as f64 / down_pairs as f64;
        assert!(persist > 0.7, "persist={persist}");
    }

    #[test]
    fn trace_wraps_like_trace_replay() {
        let spec = ChurnSpec::Trace {
            active: vec![vec![true, false], vec![true], vec![false, true, true]],
        };
        let s = ChurnSchedule::new(&spec, 3, 7);
        // node 0 alternates starting active; node 1 always active; node 2
        // has period 3 starting inactive.
        assert_eq!(s.active(1), &[true, true, false]);
        assert_eq!(s.active(2), &[false, true, true]);
        assert_eq!(s.active(3), &[true, true, true]);
        assert_eq!(s.active(4), &[false, true, false]);
        assert_eq!(s.active_count(1), 2);
    }

    #[test]
    fn parse_cli_forms() {
        assert_eq!(ChurnSpec::parse("none", 9).unwrap(), ChurnSpec::None);
        assert_eq!(
            ChurnSpec::parse("iid:0.2", 9).unwrap(),
            ChurnSpec::IidDropout { p: 0.2, seed: 9 }
        );
        assert_eq!(
            ChurnSpec::parse("iid:0.2:44", 9).unwrap(),
            ChurnSpec::IidDropout { p: 0.2, seed: 44 }
        );
        assert_eq!(
            ChurnSpec::parse("markov:0.05:0.25", 9).unwrap(),
            ChurnSpec::Markov { p_down: 0.05, p_up: 0.25, seed: 9 }
        );
        for bad in ["", "iid", "iid:1.5", "markov:0.1", "bogus:1", "iid:x"] {
            assert!(ChurnSpec::parse(bad, 9).is_err(), "'{bad}' should be rejected");
        }
    }

    #[test]
    #[should_panic(expected = "horizon")]
    fn beyond_horizon_panics() {
        let s = ChurnSchedule::new(&ChurnSpec::IidDropout { p: 0.5, seed: 1 }, 4, 10);
        let _ = s.active(11);
    }
}
