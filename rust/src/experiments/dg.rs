//! Beyond-paper workload: pipelined delayed gradients (AMB-DG,
//! Al-Lawati & Draper, arXiv:2012.08616).
//!
//! AMB still serializes each epoch — compute T, then sit idle through
//! the consensus window T_c.  AMB-DG overlaps them: epoch t's compute
//! runs while the consensus for the batch of epoch t−D is in flight, so
//! the epoch cadence drops from T + T_c to max(T, T_c) at the price of
//! applying every gradient D epochs stale.
//!
//! This harness quantifies that trade under the paper's fig-6 induced
//! straggler profile (EC2 background jobs: 3 nodes ×3, 2 nodes ×2, 5
//! clean — `InducedGroups::paper_i3`): **wall-time AMB vs AMB-DG vs
//! FMB**, with a delay sweep D ∈ {0, 1, 2, 4}.  Outputs one CSV per run
//! plus `dg_summary.csv` (scheme, delay, total wall time, final error,
//! time-to-target, staleness columns).
//!
//! Shape asserted: the D = 0 column reproduces the AMB run **bit for
//! bit** on the simulator (the pipeline ring is exercised, not
//! bypassed); every pipelined run finishes its epochs in T/(T+T_c) of
//! AMB's wall time; steady-state staleness columns read exactly D; and
//! D = 1 reaches the common error target no later than AMB in wall
//! time.

use anyhow::{Context as _, Result};

use super::{final_error, sweep, Ctx, FigReport};
use crate::coordinator::{RunOutput, RunSpec, RuntimeKind};
use crate::straggler::InducedGroups;
use crate::topology::Topology;
use crate::util::csv::{fmt_f64, Csv};

const DELAYS: [usize; 4] = [0, 1, 2, 4];
const DELAYS_QUICK: [usize; 2] = [0, 2];

/// Paper fig-6 windows: T = 12 s, T_c = 3 s, FMB batch 585.
const T_COMPUTE: f64 = 12.0;
const T_CONSENSUS: f64 = 3.0;
const FMB_BATCH: usize = 585;

pub fn dg(ctx: &Ctx) -> Result<FigReport> {
    let epochs = ctx.scaled(24);
    let topo = Topology::paper_fig2();
    let strag = InducedGroups::paper_i3();
    let source = super::linreg_source(ctx.seed);
    let opt = super::optimizer_for(&source, (topo.n() * FMB_BATCH) as f64);
    let delays: &[usize] = if ctx.quick { &DELAYS_QUICK } else { &DELAYS };

    // Grid: AMB, FMB, then one AMB-DG run per delay.
    let mut specs: Vec<RunSpec> = vec![
        RunSpec::amb("dg-amb", T_COMPUTE, T_CONSENSUS, 5, epochs, ctx.seed),
        RunSpec::fmb("dg-fmb", FMB_BATCH, T_CONSENSUS, 5, epochs, ctx.seed),
    ];
    for &d in delays {
        specs.push(RunSpec::amb_dg(
            &format!("dg-ambdg-d{d}"),
            T_COMPUTE,
            T_CONSENSUS,
            d,
            5,
            epochs,
            ctx.seed,
        ));
    }

    // Independent sim runs fan out on the worker pool (serial when the
    // ctx targets the real-time threaded runtime).
    let outs: Vec<RunOutput> = sweep::sweep_if(
        ctx.runtime != RuntimeKind::Threaded,
        specs.len(),
        |idx| ctx.run(&specs[idx], &topo, &strag, &source, &opt),
    )?;
    let amb = &outs[0];
    let fmb = &outs[1];
    let dg_outs = &outs[2..];

    // Common error target: generous enough that every scheme reaches and
    // stays below it (the time-to-target comparison needs every column).
    let mut worst_final = 0.0f64;
    for out in &outs {
        worst_final = worst_final.max(final_error(&out.record)?);
    }
    let target = worst_final * 1.5;

    let mut summary = Csv::new(&[
        "scheme", "delay", "epochs", "total_time", "final_error", "time_to_target",
        "mean_staleness", "max_staleness", "total_samples",
    ]);
    let mut outputs = Vec::new();
    let mut all_finite = true;
    for (spec, out) in specs.iter().zip(&outs) {
        let fin = final_error(&out.record)?;
        if !fin.is_finite() {
            all_finite = false;
        }
        let (mean_st, max_st) = out.record.staleness_summary();
        let delay = spec.scheme.delay();
        summary.push(&[
            spec.scheme.name().to_string(),
            delay.to_string(),
            out.record.epochs.len().to_string(),
            fmt_f64(out.record.total_time()),
            fmt_f64(fin),
            fmt_f64(out.record.time_to_error(target).unwrap_or(f64::NAN)),
            fmt_f64(mean_st),
            max_st.to_string(),
            fmt_f64(out.record.total_samples() as f64),
        ]);
        let p = ctx.out_dir.join(format!("dg_{}.csv", spec.name));
        out.record.save_csv(&p)?;
        outputs.push(p);
    }
    let sp = ctx.out_dir.join("dg_summary.csv");
    summary.save(&sp)?;
    outputs.push(sp);

    // --- shape checks -----------------------------------------------------
    // (1) D = 0 ≡ AMB bit for bit (sim only: the threaded runtime's real
    // clock makes no two runs bitwise comparable — its D = 0 contract is
    // structural and pinned in tests/amb_dg.rs instead).
    let d0 = &dg_outs[0];
    let anchor_bitwise = if ctx.runtime == RuntimeKind::Sim {
        d0.final_w == amb.final_w
            && amb
                .record
                .epochs
                .iter()
                .zip(&d0.record.epochs)
                .all(|(a, b)| {
                    a.batch == b.batch
                        && a.loss.to_bits() == b.loss.to_bits()
                        && a.error.to_bits() == b.error.to_bits()
                        && a.wall_time.to_bits() == b.wall_time.to_bits()
                        && b.max_staleness == 0
                })
    } else {
        true
    };

    // (2) pipelined cadence: every D ≥ 1 run finishes its epochs in
    // max(T, T_c)/(T + T_c) of AMB's wall time (exactly, per epoch).
    let expected_ratio = T_COMPUTE.max(T_CONSENSUS) / (T_COMPUTE + T_CONSENSUS);
    let wall_pipelined = dg_outs
        .iter()
        .zip(delays)
        .filter(|(_, &d)| d >= 1)
        .all(|(out, _)| {
            let ratio = out.record.total_time() / amb.record.total_time();
            (ratio - expected_ratio).abs() < 1e-9
        });

    // (3) staleness columns read exactly D in steady state (no churn:
    // the first D epochs apply nothing, every later epoch applies at
    // staleness exactly D).
    let staleness_exact = dg_outs.iter().zip(delays).all(|(out, &d)| {
        out.record.epochs.iter().enumerate().all(|(idx, e)| {
            if idx < d {
                e.batch == 0 && !e.mean_staleness.is_finite()
            } else {
                e.max_staleness == d
                    && (e.mean_staleness - d as f64).abs() < 1e-12
            }
        })
    });

    // (4) the pipeline pays off: D = 1 reaches the common target no
    // later than AMB in wall time (same per-epoch batches, 20% shorter
    // epochs, one epoch of staleness).  Quick mode skips D = 1.
    let d1_speedup = match delays.iter().position(|&d| d == 1) {
        None => true,
        Some(pos) => {
            let t_amb = amb.record.time_to_error(target);
            let t_d1 = dg_outs[pos].record.time_to_error(target);
            match (t_amb, t_d1) {
                (Some(a), Some(d)) => d <= a,
                _ => false,
            }
        }
    };

    let amb_t = amb.record.total_time();
    let fmb_t = fmb.record.total_time();
    let d_last = dg_outs.last().context("dg grid sweeps at least one delay")?;
    Ok(FigReport {
        id: "dg",
        title: "pipelined delayed gradients: wall-time AMB vs AMB-DG vs FMB (fig-6 stragglers)",
        paper: "AMB-DG (arXiv:2012.08616): no idle consensus window — epoch cadence \
                max(T,Tc) vs AMB's T+Tc at fixed staleness D; D=0 IS AMB"
            .into(),
        measured: format!(
            "wall {amb_t:.0}s (AMB) vs {:.0}s (AMB-DG) vs {fmb_t:.0}s (FMB); D=0 bitwise: \
             {anchor_bitwise}; pipelined cadence exact: {wall_pipelined}; staleness columns \
             exact: {staleness_exact}; D=1 time-to-target ≤ AMB: {d1_speedup}",
            d_last.record.total_time(),
        ),
        shape_holds: all_finite
            && anchor_bitwise
            && wall_pipelined
            && staleness_exact
            && d1_speedup,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dg_quick() {
        let dir = std::env::temp_dir().join("amb_dg_harness_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = dg(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        assert!(rep.outputs.iter().any(|p| p.ends_with("dg_summary.csv")));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
