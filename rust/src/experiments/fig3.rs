//! Figure 3 (App. I.1): hub-and-spoke (master–worker) MNIST logistic
//! regression.  19 workers + 1 master, FMB b = 3990 (210/worker),
//! AMB T = 3 s, T_c = 1 s; the master aggregates exactly (ε = 0).
//! Paper: AMB "far outperforms" FMB.

use anyhow::Result;

use super::{Ctx, FigReport};
use crate::coordinator::{ConsensusMode, RunSpec};
use crate::straggler::ShiftedExp;
use crate::topology::Topology;

pub fn fig3(ctx: &Ctx) -> Result<FigReport> {
    // Workers only participate in compute; the master is modelled by
    // exact consensus over the 19 workers (remark 1 of the paper: ε = 0
    // recovers the master-worker setup).
    let topo = Topology::complete(19); // communication graph is irrelevant under Exact
    let strag = ShiftedExp { zeta: 2.0, lambda: 1.0, unit_batch: 210 };
    let source = super::mnist_source(ctx.seed);
    let epochs = ctx.scaled(24);
    let opt = super::optimizer_for(&source, 3990.0);

    let amb_spec = RunSpec::amb("amb-hub", 3.0, 1.0, 1, epochs, ctx.seed)
        .with_consensus(ConsensusMode::Exact);
    let amb = ctx.run(&amb_spec, &topo, &strag, &source, &opt)?.record;

    let fmb_spec = RunSpec::fmb("fmb-hub", 210, 1.0, 1, epochs, ctx.seed)
        .with_consensus(ConsensusMode::Exact);
    let fmb = ctx.run(&fmb_spec, &topo, &strag, &source, &opt)?.record;

    let target = super::final_error(&amb)?.max(super::final_error(&fmb)?) * 1.5;
    let speedup = crate::metrics::speedup_at(&amb, &fmb, target)
        .map(|(_, _, s)| s)
        .unwrap_or(f64::NAN);

    let p_amb = ctx.out_dir.join("fig3_amb.csv");
    let p_fmb = ctx.out_dir.join("fig3_fmb.csv");
    amb.save_csv(&p_amb)?;
    fmb.save_csv(&p_fmb)?;

    Ok(FigReport {
        id: "f3",
        title: "hub-and-spoke MNIST logistic regression (19 workers + master)",
        paper: "AMB far outperforms FMB in the master-worker topology".into(),
        measured: format!(
            "time-to-cost({:.3}) speedup {:.2}x (AMB {:.0}s vs FMB {:.0}s total)",
            target,
            speedup,
            amb.total_time(),
            fmb.total_time()
        ),
        shape_holds: speedup > 1.0,
        outputs: vec![p_amb, p_fmb],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick() {
        let dir = std::env::temp_dir().join("amb_fig3_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig3(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
