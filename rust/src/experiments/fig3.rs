//! Figure 3 (App. I.1): hub-and-spoke (master–worker) MNIST logistic
//! regression.  19 workers + 1 master, FMB b = 3990 (210/worker),
//! AMB T = 3 s, T_c = 1 s; the master aggregates exactly (ε = 0).
//! Paper: AMB "far outperforms" FMB.

use anyhow::Result;

use super::{Ctx, FigReport};
use crate::coordinator::{ConsensusMode, RunSpec};
use crate::net::{FabricSpec, NetworkModel};
use crate::straggler::ShiftedExp;
use crate::topology::Topology;

pub fn fig3(ctx: &Ctx) -> Result<FigReport> {
    // Workers only participate in compute; the master is modelled by
    // exact consensus over the 19 workers (remark 1 of the paper: ε = 0
    // recovers the master-worker setup).
    let topo = Topology::complete(19); // communication graph is irrelevant under Exact
    let strag = ShiftedExp { zeta: 2.0, lambda: 1.0, unit_batch: 210 };
    let source = super::mnist_source(ctx.seed);
    let epochs = ctx.scaled(24);
    let opt = super::optimizer_for(&source, 3990.0);

    let amb_spec = RunSpec::amb("amb-hub", 3.0, 1.0, 1, epochs, ctx.seed)
        .with_consensus(ConsensusMode::Exact);
    let amb = ctx.run(&amb_spec, &topo, &strag, &source, &opt)?.record;

    let fmb_spec = RunSpec::fmb("fmb-hub", 210, 1.0, 1, epochs, ctx.seed)
        .with_consensus(ConsensusMode::Exact);
    let fmb = ctx.run(&fmb_spec, &topo, &strag, &source, &opt)?.record;

    let target = super::final_error(&amb)?.max(super::final_error(&fmb)?) * 1.5;
    let speedup = crate::metrics::speedup_at(&amb, &fmb, target)
        .map(|(_, _, s)| s)
        .unwrap_or(f64::NAN);

    let p_amb = ctx.out_dir.join("fig3_amb.csv");
    let p_fmb = ctx.out_dir.join("fig3_fmb.csv");
    amb.save_csv(&p_amb)?;
    fmb.save_csv(&p_fmb)?;

    Ok(FigReport {
        id: "f3",
        title: "hub-and-spoke MNIST logistic regression (19 workers + master)",
        paper: "AMB far outperforms FMB in the master-worker topology".into(),
        measured: format!(
            "time-to-cost({:.3}) speedup {:.2}x (AMB {:.0}s vs FMB {:.0}s total)",
            target,
            speedup,
            amb.total_time(),
            fmb.total_time()
        ),
        shape_holds: speedup > 1.0,
        outputs: vec![p_amb, p_fmb],
    })
}

/// Measured-rounds mode (`f3n`, ISSUE 6): the paper's hub-and-spoke
/// setup with the master made EXPLICIT — gossip over
/// `Topology::hub_spoke(19)` on the event fabric instead of abstract
/// exact aggregation.  MNIST rows are 7851 f32s (31 404 bytes), so on a
/// 2 MB/s uplink the hub's egress alone costs ~0.6 s per round and the
/// T_c = 1 s window measurably starves the round budget relative to an
/// ideal (zero-latency, unconstrained) fabric with the same cap.
pub fn fig3_net(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::hub_spoke(19); // node 0 = master, 19 spokes
    let strag = ShiftedExp { zeta: 2.0, lambda: 1.0, unit_batch: 210 };
    let source = super::mnist_source(ctx.seed);
    let epochs = ctx.scaled(16);
    let opt = super::optimizer_for(&source, 4200.0);
    let cap = 10;

    let cases = [
        ("ideal", NetworkModel::Fabric(FabricSpec::ideal())),
        ("fabric", NetworkModel::Fabric(FabricSpec::uniform(0.005, 2.0e6))),
    ];
    let mut outputs = Vec::new();
    let mut means = Vec::new();
    let mut errors = Vec::new();
    let mut rounds_csv = String::from("network,node,rounds_per_tc\n");
    for (name, network) in &cases {
        let spec = RunSpec::amb(&format!("hub-{name}"), 3.0, 1.0, cap, epochs, ctx.seed)
            .with_network(network.clone());
        let out = ctx.run(&spec, &topo, &strag, &source, &opt)?;
        let per_node: Vec<usize> = out.rounds.iter().map(|r| r[0]).collect();
        for (i, r) in per_node.iter().enumerate() {
            rounds_csv.push_str(&format!("{name},{i},{r}\n"));
        }
        means.push(per_node.iter().sum::<usize>() as f64 / per_node.len() as f64);
        errors.push(super::final_error(&out.record)?);
        let p = ctx.out_dir.join(format!("fig3_net_{name}.csv"));
        out.record.save_csv(&p)?;
        outputs.push(p);
    }
    let rounds_path = ctx.out_dir.join("fig3_net_rounds.csv");
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(&rounds_path, rounds_csv)?;
    outputs.push(rounds_path);

    let (ideal_mean, fabric_mean) = (means[0], means[1]);
    Ok(FigReport {
        id: "f3n",
        title: "hub-and-spoke MNIST on the event fabric: measured uplink rounds",
        paper: "beyond the paper: fig 3's master link modeled as a congested uplink".into(),
        measured: format!(
            "mean rounds/T_c: ideal {ideal_mean:.2} (cap {cap}), constrained {fabric_mean:.2}; final errors {:.3e} / {:.3e}",
            errors[0], errors[1]
        ),
        shape_holds: ideal_mean == cap as f64
            && fabric_mean < ideal_mean
            && fabric_mean > 0.0
            && errors.iter().all(|e| e.is_finite()),
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick() {
        let dir = std::env::temp_dir().join("amb_fig3_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig3(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fig3_net_quick() {
        let dir = std::env::temp_dir().join("amb_fig3_net_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig3_net(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let csv = std::fs::read_to_string(dir.join("fig3_net_rounds.csv")).unwrap();
        assert_eq!(csv.lines().count(), 1 + 2 * 20, "{csv}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
