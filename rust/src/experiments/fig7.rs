//! Figure 7 (App. I.3): MNIST logistic regression with induced stragglers
//! on "EC2" — same setup as Fig. 6, plotting cost vs wall time.
//!
//! Paper: with induced stragglers AMB becomes ≈2× faster than FMB
//! (vs ≈1.5-1.7× in the clean Fig. 1b run) — the gap *grows* with
//! straggler variability.

use anyhow::{Context as _, Result};

use super::{Ctx, FigReport};

pub fn fig7(ctx: &Ctx) -> Result<FigReport> {
    let epochs = ctx.scaled(24);
    let (amb, fmb) = super::fig6::run_induced(ctx, epochs)?;

    let p_amb = ctx.out_dir.join("fig7_amb.csv");
    let p_fmb = ctx.out_dir.join("fig7_fmb.csv");
    amb.record.save_csv(&p_amb)?;
    fmb.record.save_csv(&p_fmb)?;

    let ea = amb.record.epochs.last().context("runs record at least one epoch")?.error;
    let ef = fmb.record.epochs.last().context("runs record at least one epoch")?.error;
    let target = ea.max(ef) * 1.5;
    let speedup = crate::metrics::speedup_at(&amb.record, &fmb.record, target)
        .map(|(_, _, s)| s)
        .unwrap_or(f64::NAN);

    Ok(FigReport {
        id: "f7",
        title: "MNIST logistic regression with induced stragglers (EC2)",
        paper: "AMB ≈2x faster than FMB (≈50% time reduction to target cost)".into(),
        measured: format!(
            "time-to-cost({target:.3}) speedup {speedup:.2}x (AMB {:.0}s vs FMB {:.0}s total)",
            amb.record.total_time(),
            fmb.record.total_time()
        ),
        shape_holds: speedup > 1.3,
        outputs: vec![p_amb, p_fmb],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_quick() {
        let dir = std::env::temp_dir().join("amb_fig7_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig7(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
