//! Theorem 7 / Lemma 6 / App. H: wall-time speedup of AMB over FMB as a
//! function of cluster size n — pure timing simulation (no learning).
//!
//! With T = (1 + n/b)·μ:
//!   Lemma 6:   E[b(t)] ≥ b                      (AMB batch at least FMB's)
//!   Thm 7:     S_F ≤ (1 + (σ/μ)√(n−1))·S_A      (any distribution)
//!   App. H:    S_F/S_A → log(n)/(1 + λζ)        (shifted exponential)

use anyhow::{Context as _, Result};

use super::{sweep, Ctx, FigReport};
use crate::straggler::{ShiftedExp, StragglerModel};
use crate::util::csv::Csv;
use crate::util::rng::Pcg64;
use crate::util::stats;

/// Empirical epoch-time ratio S_F/S_A for n nodes under a model.
pub struct SpeedupPoint {
    pub n: usize,
    pub measured: f64,
    pub thm7_bound: f64,
    pub shifted_exp_analytic: f64,
    pub mean_amb_batch: f64,
    pub fmb_batch: f64,
}

/// Simulate `epochs` epochs for both schemes and return the ratio point.
pub fn speedup_for_n(
    model: &ShiftedExp,
    n: usize,
    per_node_batch: usize,
    epochs: usize,
    seed: u64,
) -> SpeedupPoint {
    assert_eq!(
        per_node_batch, model.unit_batch,
        "FMB per-node quota must equal the model's unit batch (paper setup)"
    );
    // amb-lint: allow(D4, "ShiftedExp always has analytic moments")
    let m = model.unit_moments().unwrap();
    let b = (per_node_batch * n) as f64;
    // Lemma 6 compute-time choice.
    let t_amb = (1.0 + n as f64 / b) * m.mean;
    // amb-lint: allow(D3, "stream root: caller-supplied seed is this generator's namespace")
    let mut rng = Pcg64::new(seed);

    let mut s_f = 0.0f64; // total FMB compute time
    let mut amb_batches = Vec::with_capacity(epochs);
    for t in 0..epochs {
        let mut slowest = 0.0f64;
        let mut b_amb = 0usize;
        for i in 0..n {
            // Paper Assumption 2 (linear progress) is what EpochProfile
            // implements: per-grad speed = T_i / unit_batch.
            let mut prof_f = model.draw(i, t, &mut rng);
            slowest = slowest.max(prof_f.time_for_grads(per_node_batch));
            let mut prof_a = model.draw(i, t, &mut rng);
            b_amb += prof_a.grads_in_time(t_amb);
        }
        s_f += slowest;
        amb_batches.push(b_amb as f64);
    }
    let s_a = epochs as f64 * t_amb;
    SpeedupPoint {
        n,
        measured: s_f / s_a,
        thm7_bound: 1.0 + (m.stddev / m.mean) * ((n - 1) as f64).sqrt(),
        shifted_exp_analytic: (stats::shifted_exp_expected_max(model.zeta, 1.0 / (m.mean - model.zeta), n))
            / m.mean,
        mean_amb_batch: stats::mean(&amb_batches),
        fmb_batch: b,
    }
}

pub fn thm7(ctx: &Ctx) -> Result<FigReport> {
    let model = ShiftedExp::paper_i2(); // zeta=1, lambda=2/3, unit 600
    let epochs = ctx.scaled(400);
    // The paper's curve stops at n=100; the sparse consensus plane
    // (ISSUE 7) runs clusters of 10⁵, so the speedup curve extends two
    // orders of magnitude past it.  The MC cost is O(n·epochs) draws, so
    // the epoch budget shrinks at the largest n to keep the whole curve
    // in seconds — the max of n shifted exponentials concentrates, so
    // fewer epochs suffice there.
    let ns = [2usize, 5, 10, 20, 50, 100, 1_000, 10_000, 100_000];

    // Each curve point is an independent Monte-Carlo simulation (its own
    // derived seed), so the n grid sweeps concurrently on the pool;
    // points come back in grid order.
    let points = sweep::sweep(ns.len(), |idx| {
        let n = ns[idx];
        let e = epochs.min((8_000_000 / n).max(2));
        Ok(speedup_for_n(&model, n, 600, e, ctx.seed + idx as u64))
    })?;

    let mut csv = Csv::new(&[
        "n", "speedup_measured", "thm7_bound", "shifted_exp_analytic",
        "mean_amb_batch", "fmb_batch",
    ]);
    for p in &points {
        csv.push_nums(&[
            p.n as f64,
            p.measured,
            p.thm7_bound,
            p.shifted_exp_analytic,
            p.mean_amb_batch,
            p.fmb_batch,
        ]);
    }
    let path = ctx.out_dir.join("thm7_speedup.csv");
    csv.save(&path)?;

    // Shapes: (a) measured speedup grows with n; (b) bounded by Thm 7;
    // (c) Lemma 6: mean AMB batch >= FMB batch (within MC noise);
    // (d) tracks the shifted-exp log(n) analytic form.
    let monotone = points.windows(2).all(|w| w[1].measured >= w[0].measured * 0.98);
    let bounded = points.iter().all(|p| p.measured <= p.thm7_bound * 1.02);
    let lemma6 = points.iter().all(|p| p.mean_amb_batch >= p.fmb_batch * 0.98);
    let tracks = points
        .iter()
        .all(|p| (p.measured / p.shifted_exp_analytic - 1.0).abs() < 0.15);

    let last = points.last().context("thm7 sweeps at least one n")?;
    Ok(FigReport {
        id: "thm7",
        title: "wall-time speedup vs n (Lemma 6, Thm 7, App. H)",
        paper: "S_F ≤ (1+σ/μ·√(n−1))·S_A; Θ(log n) for shifted-exp; E[b_AMB] ≥ b".into(),
        measured: format!(
            "n={}: measured {:.2}x ≤ bound {:.2}x; analytic {:.2}x; monotone={monotone} lemma6={lemma6} tracks_logn={tracks}",
            last.n, last.measured, last.thm7_bound, last.shifted_exp_analytic
        ),
        shape_holds: monotone && bounded && lemma6 && tracks,
        outputs: vec![path],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma6_expected_batch_at_least_b() {
        let model = ShiftedExp::paper_i2();
        let p = speedup_for_n(&model, 10, 600, 400, 3);
        assert!(p.mean_amb_batch >= p.fmb_batch * 0.98,
                "E[b]={} b={}", p.mean_amb_batch, p.fmb_batch);
    }

    #[test]
    fn thm7_bound_respected() {
        let model = ShiftedExp::paper_i2();
        for n in [2, 10, 50] {
            let p = speedup_for_n(&model, n, 600, 300, 7);
            assert!(p.measured <= p.thm7_bound * 1.02,
                    "n={n}: {} > {}", p.measured, p.thm7_bound);
        }
    }

    #[test]
    fn speedup_grows_with_n() {
        let model = ShiftedExp::paper_i2();
        let s2 = speedup_for_n(&model, 2, 600, 400, 11).measured;
        let s50 = speedup_for_n(&model, 50, 600, 400, 11).measured;
        assert!(s50 > s2, "s2={s2} s50={s50}");
    }

    #[test]
    fn fig_thm7_quick() {
        let dir = std::env::temp_dir().join("amb_thm7_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = thm7(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
