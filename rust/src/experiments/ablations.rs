//! Ablations over the design choices DESIGN.md calls out — beyond the
//! paper's own figures:
//!
//! * A1 consensus rounds r: error vs r (Lemma 1's knob) at fixed T_c cost.
//! * A2 b(t) normalisation: consensus-estimated b̂(t) vs oracle b(t).
//! * A3 consensus engine: dense P-matmul vs sparse neighbour-list vs
//!   push-sum (timing + accuracy at equal rounds).
//! * A4 baseline family: AMB vs FMB vs backup-workers vs gradient coding
//!   under induced stragglers (the related-work comparison — AMB uses
//!   ALL completed work, redundancy schemes pay for it).
//! * A5 topology: time-to-target vs λ₂(P) at fixed round budget.

use anyhow::{Context as _, Result};

use super::{sweep, Ctx, FigReport};
use crate::consensus::{push_sum::Digraph, push_sum::PushSum, sparse::SparseMix, Consensus};
use crate::coordinator::{RunSpec, Scheme};
use crate::metrics::RunRecord;
use crate::straggler::{InducedGroups, ShiftedExp};
use crate::topology::Topology;
use crate::util::csv::Csv;
use crate::util::matrix::NodeMatrix;

/// A1: consensus-round sweep.
pub fn ablate_rounds(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed);
    let opt = super::optimizer_for(&source, 6000.0);
    let epochs = ctx.scaled(16);

    let round_grid = [1usize, 2, 5, 10, 20, 50];
    let specs: Vec<RunSpec> = round_grid
        .iter()
        .map(|&r| RunSpec::amb(&format!("amb-r{r}"), 2.5, 0.5, r, epochs, ctx.seed))
        .collect();
    let outs = sweep::run_specs(ctx, &topo, &strag, &source, &opt, &specs)?;

    let mut csv = Csv::new(&["rounds", "final_error", "mean_consensus_err"]);
    let mut errs = Vec::new();
    for (&rounds, out) in round_grid.iter().zip(&outs) {
        let rec = &out.record;
        let final_err = super::final_error(rec)?;
        let cons: f64 =
            rec.epochs.iter().map(|e| e.consensus_err).sum::<f64>() / rec.epochs.len() as f64;
        csv.push_nums(&[rounds as f64, final_err, cons]);
        errs.push((rounds, final_err, cons));
    }
    let path = ctx.out_dir.join("ablation_rounds.csv");
    csv.save(&path)?;

    // consensus error must decay monotonically in r; optimization error
    // should not degrade with more rounds.  The threaded runtime cannot
    // observe consensus error (records NaN) — nothing to falsify there.
    let observable = errs.iter().all(|e| e.2.is_finite());
    let cons_monotone = !observable || errs.windows(2).all(|w| w[1].2 <= w[0].2 * 1.05);
    Ok(FigReport {
        id: "a1",
        title: "ablation: consensus rounds r",
        paper: "Lemma 1: more rounds ⇒ smaller ε; diminishing returns past r ≈ 5".into(),
        measured: format!(
            "r=1 cons-err {:.2e} → r=50 {:.2e}; final errors within {:.1}x",
            errs[0].2,
            errs.last().context("r-sweep is non-empty")?.2,
            errs.iter().map(|e| e.1).fold(0.0f64, f64::max)
                / errs.iter().map(|e| e.1).fold(f64::INFINITY, f64::min)
        ),
        shape_holds: cons_monotone,
        outputs: vec![path],
    })
}

/// A2: estimated vs oracle b(t).
pub fn ablate_bt(ctx: &Ctx) -> Result<FigReport> {
    // The exact-b(t) oracle is sim-only (threaded nodes have no global
    // view); on the threaded runtime both arms would run identically and
    // fake a comparison, so report the ablation as not applicable.
    if ctx.runtime == crate::coordinator::RuntimeKind::Threaded {
        return Ok(FigReport {
            id: "a2",
            title: "ablation: consensus-estimated b̂(t) vs oracle b(t)",
            paper: "(ours) the side-channel estimate should be free".into(),
            measured: "skipped: exact-b(t) oracle is sim-only".into(),
            shape_holds: true,
            outputs: vec![],
        });
    }
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed);
    let opt = super::optimizer_for(&source, 6000.0);
    let epochs = ctx.scaled(16);

    let run = |exact: bool| -> Result<RunRecord> {
        let mut spec =
            RunSpec::amb(if exact { "bt-exact" } else { "bt-est" }, 2.5, 0.5, 8, epochs, ctx.seed);
        if exact {
            spec = spec.with_exact_bt();
        }
        Ok(ctx.run(&spec, &topo, &strag, &source, &opt)?.record)
    };
    let est = run(false)?;
    let exact = run(true)?;
    let mut csv = Csv::new(&["epoch", "err_estimated_bt", "err_exact_bt"]);
    for (a, b) in est.epochs.iter().zip(&exact.epochs) {
        csv.push_nums(&[a.epoch as f64, a.error, b.error]);
    }
    let path = ctx.out_dir.join("ablation_bt.csv");
    csv.save(&path)?;

    let ee = super::final_error(&est)?;
    let ex = super::final_error(&exact)?;
    Ok(FigReport {
        id: "a2",
        title: "ablation: consensus-estimated b̂(t) vs oracle b(t)",
        paper: "(ours) the side-channel estimate should be free".into(),
        measured: format!("final error est {ee:.3e} vs oracle {ex:.3e} (ratio {:.2})", ee / ex),
        // Claim: estimation never makes things materially WORSE (being
        // better is fine; on short, steeply-decaying error curves small
        // normalisation differences produce large final-error ratios in
        // either direction).
        shape_holds: ee < ex * 10.0,
        outputs: vec![path],
    })
}

/// A3: consensus engine comparison (accuracy at equal rounds + relative
/// cost measured here, timed properly in benches/hotpath.rs).
pub fn ablate_engines(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::paper_fig2();
    let n = topo.n();
    let d = 512usize;
    let mut g = crate::prop::Gen::new(ctx.seed);
    let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 2.0)).collect();
    let msgs0 = NodeMatrix::from_rows(&rows);
    let avg = Consensus::exact_average(&msgs0)?;
    let rounds = 20;

    let mut dense = Consensus::new(topo.metropolis().lazy());
    let mut a = msgs0.clone();
    // amb-lint: allow(D1, "host wall-time of the dense mix kernel for the perf column; not simulated time")
    let t0 = std::time::Instant::now();
    dense.run(&mut a, rounds);
    let t_dense = t0.elapsed().as_secs_f64();
    let e_dense = Consensus::max_error(&a, &avg)?;

    let sp = SparseMix::metropolis(&topo, true);
    let mut b = msgs0.clone();
    let mut scratch = NodeMatrix::new(0, 0);
    // amb-lint: allow(D1, "host wall-time of the sparse mix kernel for the perf column; not simulated time")
    let t0 = std::time::Instant::now();
    sp.run(&mut b, &mut scratch, rounds);
    let t_sparse = t0.elapsed().as_secs_f64();
    let e_sparse = Consensus::max_error(&b, &avg)?;

    let mut ps = PushSum::new(Digraph::from_undirected(&topo), &msgs0);
    // amb-lint: allow(D1, "host wall-time of the push-sum kernel for the perf column; not simulated time")
    let t0 = std::time::Instant::now();
    ps.run(rounds);
    let t_push = t0.elapsed().as_secs_f64();
    let e_push = ps.max_error(&avg);

    let mut csv = Csv::new(&["engine", "rounds", "max_error", "seconds"]);
    csv.push(&["dense".into(), rounds.to_string(), format!("{e_dense:e}"), format!("{t_dense:e}")]);
    csv.push(&["sparse".into(), rounds.to_string(), format!("{e_sparse:e}"), format!("{t_sparse:e}")]);
    csv.push(&["push_sum".into(), rounds.to_string(), format!("{e_push:e}"), format!("{t_push:e}")]);
    let path = ctx.out_dir.join("ablation_engines.csv");
    csv.save(&path)?;

    Ok(FigReport {
        id: "a3",
        title: "ablation: dense vs sparse vs push-sum consensus",
        paper: "(ours) same contraction; sparse pays O(|E|d) not O(n²d)".into(),
        measured: format!(
            "err@{rounds}r dense {e_dense:.2e} sparse {e_sparse:.2e} push {e_push:.2e}; \
             time dense {:.0}µs sparse {:.0}µs push {:.0}µs",
            t_dense * 1e6, t_sparse * 1e6, t_push * 1e6
        ),
        shape_holds: (e_dense - e_sparse).abs() < 1e-3 && e_push < e_dense * 10.0 + 1e-3,
        outputs: vec![path],
    })
}

/// A4: AMB vs the redundancy baselines under induced stragglers.
pub fn ablate_baselines(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::paper_fig2();
    let strag = InducedGroups::paper_i3();
    let source = super::mnist_source(ctx.seed);
    let opt = super::optimizer_for(&source, 5850.0);
    let epochs = ctx.scaled(24);

    let schemes: Vec<(&str, Scheme)> = vec![
        ("amb", Scheme::Amb { t_compute: 12.0, t_consensus: 3.0 }),
        ("fmb", Scheme::Fmb { per_node_batch: 585, t_consensus: 3.0 }),
        (
            "fmb-backup2",
            Scheme::FmbBackup { per_node_batch: 585, t_consensus: 3.0, ignore: 2, coded: false },
        ),
        (
            "fmb-coded2",
            Scheme::FmbBackup { per_node_batch: 585, t_consensus: 3.0, ignore: 2, coded: true },
        ),
    ];
    let specs: Vec<RunSpec> = schemes
        .iter()
        .map(|(name, scheme)| {
            RunSpec::new(name, *scheme, epochs, ctx.seed)
                .with_consensus(crate::coordinator::ConsensusMode::Gossip { rounds: 5 })
        })
        .collect();
    let outs = sweep::run_specs(ctx, &topo, &strag, &source, &opt, &specs)?;

    let mut csv = Csv::new(&["scheme", "total_time", "total_samples", "final_error"]);
    let mut recs = Vec::new();
    for ((name, _), out) in schemes.iter().zip(outs) {
        let rec = out.record;
        csv.push(&[
            name.to_string(),
            format!("{:.1}", rec.total_time()),
            rec.total_samples().to_string(),
            format!("{:.4e}", super::final_error(&rec)?),
        ]);
        recs.push(rec);
    }
    let path = ctx.out_dir.join("ablation_baselines.csv");
    csv.save(&path)?;

    // AMB should dominate on time-to-target: compute the common target.
    let mut target = 0.0f64;
    for r in &recs {
        target = target.max(super::final_error(r)?);
    }
    let target = target * 1.5;
    let times: Vec<Option<f64>> = recs.iter().map(|r| r.time_to_error(target)).collect();
    let amb_t = times[0].unwrap_or(f64::INFINITY);
    let best_other = times[1..]
        .iter()
        .map(|t| t.unwrap_or(f64::INFINITY))
        .fold(f64::INFINITY, f64::min);
    Ok(FigReport {
        id: "a4",
        title: "ablation: AMB vs FMB vs backup workers vs gradient coding",
        paper: "related work: AMB uses all completed work; redundancy schemes discard or duplicate".into(),
        measured: format!(
            "time-to-error({target:.3}): amb {amb_t:.0}s vs best-redundancy {best_other:.0}s ({:.2}x)",
            best_other / amb_t
        ),
        shape_holds: amb_t < best_other,
        outputs: vec![path],
    })
}

/// A5: topology sweep — λ₂ vs achieved consensus error in the full loop.
pub fn ablate_topology(ctx: &Ctx) -> Result<FigReport> {
    let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 200 };
    let source = super::linreg_source(ctx.seed);
    let opt = super::optimizer_for(&source, 2000.0);
    let epochs = ctx.scaled(10);

    let topos: Vec<(&str, Topology)> = vec![
        ("ring", Topology::ring(10)),
        ("paper_fig2", Topology::paper_fig2()),
        ("erdos_p0.4", Topology::erdos_connected(10, 0.4, 3)),
        ("complete", Topology::complete(10)),
    ];
    // Topology varies per item, so this grid goes through the generic
    // sweep (serial on the real-time threaded runtime).
    let measured = sweep::sweep_if(
        ctx.runtime != crate::coordinator::RuntimeKind::Threaded,
        topos.len(),
        |idx| {
            let (name, topo) = &topos[idx];
            let l2 = topo.metropolis().lazy().lambda2();
            let spec = RunSpec::amb(name, 2.0, 0.5, 5, epochs, ctx.seed);
            let rec = ctx.run(&spec, topo, &strag, &source, &opt)?.record;
            Ok((l2, rec))
        },
    )?;

    let mut csv = Csv::new(&["topology", "lambda2", "mean_consensus_err", "final_error"]);
    let mut rows = Vec::new();
    for ((name, _), (l2, rec)) in topos.iter().zip(&measured) {
        let cons: f64 =
            rec.epochs.iter().map(|e| e.consensus_err).sum::<f64>() / rec.epochs.len() as f64;
        csv.push(&[
            name.to_string(),
            format!("{l2:.4}"),
            format!("{cons:.4e}"),
            format!("{:.4e}", rec.epochs.last().context("runs record epochs")?.error),
        ]);
        rows.push((*l2, cons));
    }
    let path = ctx.out_dir.join("ablation_topology.csv");
    csv.save(&path)?;

    // Smaller λ₂ ⇒ smaller consensus error (rank agreement).  Threaded
    // runs record NaN consensus error — nothing to falsify there.
    let observable = rows.iter().all(|r| r.1.is_finite());
    let mut sorted = rows.clone();
    sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
    let rank_ok = !observable || sorted.windows(2).all(|w| w[0].1 <= w[1].1 * 1.5);
    Ok(FigReport {
        id: "a5",
        title: "ablation: topology λ₂ vs consensus error",
        paper: "Lemma 1: contraction rate is λ₂(P)".into(),
        measured: rows
            .iter()
            .zip(&topos)
            .map(|((l2, c), (n, _))| format!("{n}: λ₂={l2:.3} err={c:.1e}"))
            .collect::<Vec<_>>()
            .join("; "),
        shape_holds: rank_ok,
        outputs: vec![path],
    })
}

/// Run all ablations.
pub fn run_all(ctx: &Ctx) -> Result<Vec<FigReport>> {
    Ok(vec![
        ablate_rounds(ctx)?,
        ablate_bt(ctx)?,
        ablate_engines(ctx)?,
        ablate_baselines(ctx)?,
        ablate_topology(ctx)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn ablations_quick_all_hold() {
        let dir = std::env::temp_dir().join("amb_ablations_test");
        let ctx = Ctx::native(&dir).quick();
        for rep in run_all(&ctx).unwrap() {
            assert!(rep.shape_holds, "{rep}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn backup_scheme_drops_straggler_work() {
        // backup (non-coded) processes fewer samples than plain FMB in
        // the same epochs; coded keeps the full batch.
        let dir = std::env::temp_dir().join("amb_backup_test");
        let ctx = Ctx::native(Path::new(&dir)).quick();
        let topo = Topology::paper_fig2();
        let strag = InducedGroups::paper_i3();
        let source = super::super::mnist_source(1);
        let opt = super::super::optimizer_for(&source, 5850.0);
        let run_scheme = |scheme: Scheme| {
            let spec = RunSpec::new("x", scheme, 4, 5)
                .with_consensus(crate::coordinator::ConsensusMode::Gossip { rounds: 3 });
            ctx.run(&spec, &topo, &strag, &source, &opt).unwrap().record
        };
        let fmb = run_scheme(Scheme::Fmb { per_node_batch: 100, t_consensus: 1.0 });
        let backup = run_scheme(Scheme::FmbBackup {
            per_node_batch: 100,
            t_consensus: 1.0,
            ignore: 3,
            coded: false,
        });
        let coded = run_scheme(Scheme::FmbBackup {
            per_node_batch: 100,
            t_consensus: 1.0,
            ignore: 3,
            coded: true,
        });
        assert!(backup.total_samples() < fmb.total_samples());
        assert_eq!(fmb.total_samples(), 4 * 1000);
        // coded keeps the whole batch up to integer-division rounding of
        // the per-survivor attribution (≤ n samples per epoch).
        assert!((coded.total_samples() as i64 - 4 * 1000).abs() <= 4 * 10, "{}", coded.total_samples());
        // both mitigations finish epochs faster than vanilla FMB
        assert!(backup.total_time() < fmb.total_time());
        // coded pays more per-node work so it is slower than backup
        assert!(coded.total_time() > backup.total_time());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
