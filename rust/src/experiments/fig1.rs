//! Figure 1 (paper Sec. 6.2): AMB vs FMB error/cost vs wall time on
//! "EC2" (simulated steady-state compute-time distribution fitted to the
//! paper's reported means — DESIGN.md §2 substitution 1).
//!
//! * Fig 1a — linear regression, n = 10 (Fig-2 topology), FMB b/n = 600,
//!   mean unit time 14.5 s ⇒ AMB T = 14.5 s, T_c = 4.5 s, r ≈ 5.
//!   Paper: FMB needs ~25% more time for the same error (~30% excluding
//!   communication); AMB error at 300 s ≈ FMB error at 400 s.
//! * Fig 1b — logistic regression (MNIST-shaped), FMB b/n = 800,
//!   T = 12 s, T_c = 3 s, r = 5.  Paper: AMB ≈ 1.7× faster.

use anyhow::Result;

use super::{Ctx, FigReport};
use crate::coordinator::RunSpec;
use crate::metrics::RunRecord;
use crate::straggler::ShiftedExp;
use crate::topology::Topology;

/// Shared harness: run AMB and FMB on the same workload/straggler model
/// and report the time-to-target speedup.
pub struct PairOutcome {
    pub amb: RunRecord,
    pub fmb: RunRecord,
    pub speedup: f64,
    pub target: f64,
}

#[allow(clippy::too_many_arguments)]
pub fn run_pair(
    ctx: &Ctx,
    source: std::sync::Arc<crate::exec::DataSource>,
    strag: &dyn crate::straggler::StragglerModel,
    topo: &Topology,
    t_compute: f64,
    t_consensus: f64,
    rounds: usize,
    per_node_batch: usize,
    epochs: usize,
    expected_batch: f64,
) -> Result<PairOutcome> {
    let opt = super::optimizer_for(&source, expected_batch);

    let amb_spec = RunSpec::amb("amb", t_compute, t_consensus, rounds, epochs, ctx.seed);
    let amb = ctx.run(&amb_spec, topo, strag, &source, &opt)?.record;

    let fmb_spec = RunSpec::fmb("fmb", per_node_batch, t_consensus, rounds, epochs, ctx.seed);
    let fmb = ctx.run(&fmb_spec, topo, strag, &source, &opt)?.record;

    // Target: the error both runs can reach (80th-percentile of final
    // errors, conservatively the worse of the two finals × 1.5).
    let fa = super::final_error(&amb)?;
    let ff = super::final_error(&fmb)?;
    let target = fa.max(ff) * 1.5;
    let speedup = crate::metrics::speedup_at(&amb, &fmb, target)
        .map(|(_, _, s)| s)
        .unwrap_or(f64::NAN);
    Ok(PairOutcome { amb, fmb, speedup, target })
}

/// Fig 1a: linear regression on simulated EC2.
pub fn fig1a(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::paper_fig2();
    // Steady-state EC2: mean 14.5 s per 600 gradients, modest variance
    // (t2.micro steady state, paper Sec. 6.2.1).
    let strag = ShiftedExp { zeta: 12.5, lambda: 0.5, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed);
    let epochs = ctx.scaled(24);
    let out = run_pair(ctx, source, &strag, &topo, 14.5, 4.5, 5, 600, epochs, 6000.0)?;

    let p_amb = ctx.out_dir.join("fig1a_amb.csv");
    let p_fmb = ctx.out_dir.join("fig1a_fmb.csv");
    out.amb.save_csv(&p_amb)?;
    out.fmb.save_csv(&p_fmb)?;

    Ok(FigReport {
        id: "f1a",
        title: "linear regression error vs wall time (EC2, n=10)",
        paper: "FMB ~25% slower to equal error (AMB@300s ≈ FMB@400s)".into(),
        measured: format!(
            "AMB {:.0}s vs FMB {:.0}s total; time-to-error({:.2e}) speedup {:.2}x",
            out.amb.total_time(),
            out.fmb.total_time(),
            out.target,
            out.speedup
        ),
        shape_holds: out.speedup > 1.0,
        outputs: vec![p_amb, p_fmb],
    })
}

/// Fig 1b: logistic regression (MNIST-shaped) on simulated EC2.
pub fn fig1b(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::paper_fig2();
    // Mean 12 s per 800 gradients with higher dispersion (paper observes
    // a 1.7x wall-time gap).
    let strag = ShiftedExp { zeta: 8.0, lambda: 0.25, unit_batch: 800 };
    let source = super::mnist_source(ctx.seed);
    let epochs = ctx.scaled(20);
    let out = run_pair(ctx, source, &strag, &topo, 12.0, 3.0, 5, 800, epochs, 8000.0)?;

    let p_amb = ctx.out_dir.join("fig1b_amb.csv");
    let p_fmb = ctx.out_dir.join("fig1b_fmb.csv");
    out.amb.save_csv(&p_amb)?;
    out.fmb.save_csv(&p_fmb)?;

    Ok(FigReport {
        id: "f1b",
        title: "MNIST logistic-regression cost vs wall time (EC2, n=10)",
        paper: "AMB ≈1.7x faster to equal cost".into(),
        measured: format!(
            "AMB {:.0}s vs FMB {:.0}s total; time-to-cost({:.3}) speedup {:.2}x",
            out.amb.total_time(),
            out.fmb.total_time(),
            out.target,
            out.speedup
        ),
        shape_holds: out.speedup > 1.0,
        outputs: vec![p_amb, p_fmb],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1a_quick_amb_beats_fmb() {
        let dir = std::env::temp_dir().join("amb_fig1_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig1a(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        assert!(rep.outputs.iter().all(|p| p.exists()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
