//! Figure 4 (App. I.2): linear regression under the shifted-exponential
//! straggler model, 20 sample paths of {T_i(t)}.
//!
//! Paper parameters: n = 20 nodes, λ = 2/3, ζ = 1 per 600 gradients,
//! T = (1 + n/b)·μ = 2.5 s, r = 5 consensus rounds, 20 epochs.
//! Paper: AMB beats FMB on *every* sample path, with modest variance
//! across paths (slightly more for FMB).

use anyhow::Result;

use super::{Ctx, FigReport};
use crate::coordinator::RunSpec;
use crate::straggler::ShiftedExp;
use crate::topology::Topology;
use crate::util::csv::Csv;

pub fn fig4(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::erdos_connected(20, 0.2, 7);
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed);
    let epochs = ctx.scaled(20);
    let paths = ctx.scaled(20);
    let opt = super::optimizer_for(&source, 12_000.0);

    // One CSV per scheme: columns = path id, rows = epochs.
    let mut amb_csv = Csv::new(&["path", "epoch", "wall_time", "error"]);
    let mut fmb_csv = Csv::new(&["path", "epoch", "wall_time", "error"]);
    let mut amb_wins = 0usize;
    let mut amb_final_errs = Vec::new();
    let mut fmb_final_errs = Vec::new();

    for path in 0..paths {
        let seed = ctx.seed.wrapping_add(1000 + path as u64);
        let amb_spec = RunSpec::amb("amb", 2.5, 0.5, 5, epochs, seed);
        let amb = ctx.run(&amb_spec, &topo, &strag, &source, &opt)?.record;

        let fmb_spec = RunSpec::fmb("fmb", 600, 0.5, 5, epochs, seed);
        let fmb = ctx.run(&fmb_spec, &topo, &strag, &source, &opt)?.record;

        for e in &amb.epochs {
            amb_csv.push_nums(&[path as f64, e.epoch as f64, e.wall_time, e.error]);
        }
        for e in &fmb.epochs {
            fmb_csv.push_nums(&[path as f64, e.epoch as f64, e.wall_time, e.error]);
        }
        // "AMB wins on this path" = at AMB's finishing wall time, AMB's
        // error is below FMB's error at that same wall time (the paper's
        // plot shows the AMB curve under the FMB curve at any time;
        // comparing *final* errors at equal epoch counts would be a coin
        // flip by construction since Lemma 6 matches the batch sizes).
        let t_amb = amb.total_time();
        let fmb_at_t = fmb
            .epochs
            .iter()
            .take_while(|e| e.wall_time <= t_amb)
            .last()
            .map(|e| e.error)
            .unwrap_or(f64::INFINITY);
        let win = super::final_error(&amb)? <= fmb_at_t;
        amb_wins += win as usize;
        amb_final_errs.push(super::final_error(&amb)?);
        fmb_final_errs.push(super::final_error(&fmb)?);
    }

    let p_amb = ctx.out_dir.join("fig4_amb_paths.csv");
    let p_fmb = ctx.out_dir.join("fig4_fmb_paths.csv");
    amb_csv.save(&p_amb)?;
    fmb_csv.save(&p_fmb)?;

    let spread = |xs: &[f64]| {
        let lo = crate::util::stats::min(xs);
        let hi = crate::util::stats::max(xs);
        hi / lo.max(1e-300)
    };

    Ok(FigReport {
        id: "f4",
        title: "20 sample paths, shifted-exponential stragglers (linreg, n=20)",
        paper: "AMB outperforms FMB on all 20 paths; small cross-path variance".into(),
        measured: format!(
            "AMB wins {amb_wins}/{paths} paths; final-error spread AMB {:.2}x vs FMB {:.2}x",
            spread(&amb_final_errs),
            spread(&fmb_final_errs)
        ),
        shape_holds: amb_wins == paths,
        outputs: vec![p_amb, p_fmb],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_quick() {
        let dir = std::env::temp_dir().join("amb_fig4_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig4(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
