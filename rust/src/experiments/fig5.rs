//! Figure 5 (App. I.2): the effect of imperfect consensus — r = 5 rounds
//! vs perfect averaging (r = ∞), for both AMB and FMB.
//!
//! Paper: per *epoch* AMB ≈ FMB (5a — expected batch sizes matched by
//! construction); per *wall time* AMB reaches 1e-3 in less than half the
//! time (5b, 2.24× exactly); r = 5 tracks r = ∞ closely for both.

use anyhow::{Context as _, Result};

use super::{sweep, Ctx, FigReport};
use crate::coordinator::{ConsensusMode, RunSpec};
use crate::net::{FabricSpec, NetworkModel};
use crate::straggler::ShiftedExp;
use crate::topology::Topology;

pub fn fig5(ctx: &Ctx) -> Result<FigReport> {
    let topo = Topology::erdos_connected(20, 0.2, 7);
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed);
    let epochs = ctx.scaled(20);
    let opt = super::optimizer_for(&source, 12_000.0);

    let mk_spec = |name: &str, amb: bool, exact: bool| -> RunSpec {
        let mut spec = if amb {
            RunSpec::amb(name, 2.5, 0.5, 5, epochs, ctx.seed)
        } else {
            RunSpec::fmb(name, 600, 0.5, 5, epochs, ctx.seed)
        };
        if exact {
            spec = spec.with_consensus(ConsensusMode::Exact);
        }
        spec
    };

    // The consensus grid runs concurrently on the pool; outputs come
    // back in spec order.
    let specs = [
        mk_spec("amb-r5", true, false),
        mk_spec("amb-rinf", true, true),
        mk_spec("fmb-r5", false, false),
        mk_spec("fmb-rinf", false, true),
    ];
    let mut outs =
        sweep::run_specs(ctx, &topo, &strag, &source, &opt, &specs)?.into_iter();
    let amb_r5 = outs.next().context("fig5 sweep yields 4 runs")?.record;
    let amb_inf = outs.next().context("fig5 sweep yields 4 runs")?.record;
    let fmb_r5 = outs.next().context("fig5 sweep yields 4 runs")?.record;
    let fmb_inf = outs.next().context("fig5 sweep yields 4 runs")?.record;

    let mut outputs = Vec::new();
    for rec in [&amb_r5, &amb_inf, &fmb_r5, &fmb_inf] {
        let p = ctx.out_dir.join(format!("fig5_{}.csv", rec.name));
        rec.save_csv(&p)?;
        outputs.push(p);
    }

    // 5a shape: per-epoch error of AMB ≈ FMB (ratio near 1 at the final
    // epoch).  5b shape: per-wall-time, AMB is materially faster.
    let ea = super::final_error(&amb_r5)?;
    let ef = super::final_error(&fmb_r5)?;
    let per_epoch_ratio = ea / ef;
    let target = ea.max(ef) * 1.5;
    let time_speedup = crate::metrics::speedup_at(&amb_r5, &fmb_r5, target)
        .map(|(_, _, s)| s)
        .unwrap_or(f64::NAN);
    // r=5 vs r=inf degradation (both schemes) should be modest.
    let amb_degrade = super::final_error(&amb_r5)? / super::final_error(&amb_inf)?;

    Ok(FigReport {
        id: "f5",
        title: "imperfect consensus: r=5 vs r=inf, per epoch and per wall time",
        paper: "per-epoch AMB ≈ FMB; per-wall-time AMB ≈ 2.24x faster; r=5 tracks r=∞".into(),
        measured: format!(
            "per-epoch final-error ratio AMB/FMB {per_epoch_ratio:.2}; wall-time speedup {time_speedup:.2}x; AMB r5/r∞ degradation {amb_degrade:.2}x"
        ),
        shape_holds: per_epoch_ratio < 3.0 && time_speedup > 1.0 && amb_degrade < 10.0,
        outputs,
    })
}

/// Measured-rounds mode (`f5n`, ISSUE 6): instead of GRANTING r = 5
/// rounds, run the fig-5 consensus comparison on the event fabric and
/// MEASURE how many rounds fit in T_c = 0.5 s on two 20-node graphs with
/// identical links — a ring and a hub-spoke.  The hub's single egress
/// port serializes one 4100-byte row per spoke per round, so the same
/// link budget buys it far fewer rounds: the congestion the abstract
/// budget can't see, surfaced per node in `fig5_net_rounds.csv`.
pub fn fig5_net(ctx: &Ctx) -> Result<FigReport> {
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed); // d = 1024 → 4100 B rows
    let epochs = ctx.scaled(12);
    let opt = super::optimizer_for(&source, 12_000.0);
    // 5 ms, 200 kB/s uniform links; the Gossip budget (8) is the cap the
    // measurement may not exceed, not a grant.
    let fabric = NetworkModel::Fabric(FabricSpec::uniform(0.005, 2.0e5));

    let topos = [("ring", Topology::ring(20)), ("hub-spoke", Topology::hub_spoke(19))];
    let mut outputs = Vec::new();
    let mut means = Vec::new();
    let mut rounds_csv = String::from("topology,node,rounds_per_tc\n");
    let mut errors = Vec::new();
    for (name, topo) in &topos {
        let spec = RunSpec::amb(&format!("net-{name}"), 2.5, 0.5, 8, epochs, ctx.seed)
            .with_network(fabric.clone());
        let out = ctx.run(&spec, topo, &strag, &source, &opt)?;
        // static membership + epoch-invariant fabric: epoch 0's
        // measurement is THE measurement
        let per_node: Vec<usize> = out.rounds.iter().map(|r| r[0]).collect();
        for (i, r) in per_node.iter().enumerate() {
            rounds_csv.push_str(&format!("{name},{i},{r}\n"));
        }
        means.push(per_node.iter().sum::<usize>() as f64 / per_node.len() as f64);
        errors.push(super::final_error(&out.record)?);
        let p = ctx.out_dir.join(format!("fig5_net_{name}.csv"));
        out.record.save_csv(&p)?;
        outputs.push(p);
    }
    let rounds_path = ctx.out_dir.join("fig5_net_rounds.csv");
    std::fs::create_dir_all(&ctx.out_dir)?;
    std::fs::write(&rounds_path, rounds_csv)?;
    outputs.push(rounds_path);

    let (ring_mean, hub_mean) = (means[0], means[1]);
    Ok(FigReport {
        id: "f5n",
        title: "measured gossip rounds per T_c: ring vs hub-spoke on identical links",
        paper: "beyond the paper: the fixed round budget r becomes a measured property".into(),
        measured: format!(
            "mean rounds/T_c: ring {ring_mean:.2}, hub-spoke {hub_mean:.2} (cap 8); final errors {:.3e} / {:.3e}",
            errors[0], errors[1]
        ),
        shape_holds: ring_mean > 0.0
            && hub_mean < ring_mean
            && errors.iter().all(|e| e.is_finite()),
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_quick() {
        let dir = std::env::temp_dir().join("amb_fig5_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig5(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fig5_net_quick() {
        let dir = std::env::temp_dir().join("amb_fig5_net_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig5_net(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        // the rounds CSV lists both topologies, one row per node
        let csv = std::fs::read_to_string(dir.join("fig5_net_rounds.csv")).unwrap();
        assert_eq!(csv.lines().count(), 1 + 2 * 20, "{csv}");
        assert!(csv.contains("hub-spoke,0,"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
