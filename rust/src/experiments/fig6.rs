//! Figure 6 (App. I.3): induced-straggler histograms on "EC2".
//!
//! Ten nodes; 3 run two background jobs (bad, ×3), 2 run one (×2), 5 are
//! clean (×1).  6a: FMB per-batch completion times cluster near 10/20/30 s
//! (batch fixed at 585).  6b: AMB per-epoch batch sizes with T = 12 s
//! cluster near 234/351/702 (bad/mid/fast — "first cluster centered
//! around batch size of 230" in the paper).

use anyhow::{Context as _, Result};

use super::{Ctx, FigReport};
use crate::coordinator::{RunOutput, RunSpec};
use crate::straggler::InducedGroups;
use crate::topology::Topology;
use crate::util::csv::Csv;
use crate::util::stats::Histogram;

/// Run the induced-straggler pair and return (amb_out, fmb_out) with node
/// logs attached.
pub fn run_induced(ctx: &Ctx, epochs: usize) -> Result<(RunOutput, RunOutput)> {
    let topo = Topology::paper_fig2();
    let strag = InducedGroups::paper_i3();
    let source = super::mnist_source(ctx.seed);
    let opt = super::optimizer_for(&source, 5850.0);

    let amb_spec = RunSpec::amb("amb-induced", 12.0, 3.0, 5, epochs, ctx.seed).with_node_log();
    let amb = ctx.run(&amb_spec, &topo, &strag, &source, &opt)?;

    let fmb_spec = RunSpec::fmb("fmb-induced", 585, 3.0, 5, epochs, ctx.seed).with_node_log();
    let fmb = ctx.run(&fmb_spec, &topo, &strag, &source, &opt)?;
    Ok((amb, fmb))
}

pub fn fig6(ctx: &Ctx) -> Result<FigReport> {
    let epochs = ctx.scaled(40);
    let (amb, fmb) = run_induced(ctx, epochs)?;

    // 6a: FMB per-(node, epoch) compute times.
    let fmb_log = fmb.node_log.as_ref().context("node_log recorded for fig6 runs")?;
    let mut h_times = Histogram::new(0.0, 45.0, 45);
    for node in 0..10 {
        for &t in &fmb_log.compute_times[node] {
            h_times.push(t);
        }
    }
    // 6b: AMB per-(node, epoch) batch sizes.
    let amb_log = amb.node_log.as_ref().context("node_log recorded for fig6 runs")?;
    let mut h_batches = Histogram::new(0.0, 900.0, 45);
    for node in 0..10 {
        for &b in &amb_log.batches[node] {
            h_batches.push(b as f64);
        }
    }

    let mut csv_a = Csv::new(&["compute_time_s", "count"]);
    for (c, n) in h_times.rows() {
        csv_a.push_nums(&[c, n as f64]);
    }
    let mut csv_b = Csv::new(&["batch_size", "count"]);
    for (c, n) in h_batches.rows() {
        csv_b.push_nums(&[c, n as f64]);
    }
    let p_a = ctx.out_dir.join("fig6a_fmb_times_hist.csv");
    let p_b = ctx.out_dir.join("fig6b_amb_batches_hist.csv");
    csv_a.save(&p_a)?;
    csv_b.save(&p_b)?;

    // Cluster check: mean FMB time per group and mean AMB batch per group.
    let group_mean = |per_node: &[Vec<f64>], lo: usize, hi: usize| -> f64 {
        let mut acc = 0.0;
        let mut cnt = 0usize;
        for row in per_node.iter().take(hi).skip(lo) {
            for &v in row {
                acc += v;
                cnt += 1;
            }
        }
        acc / cnt as f64
    };
    let batches_f64: Vec<Vec<f64>> = amb_log
        .batches
        .iter()
        .map(|r| r.iter().map(|&b| b as f64).collect())
        .collect();
    let t_bad = group_mean(&fmb_log.compute_times, 0, 3);
    let t_mid = group_mean(&fmb_log.compute_times, 3, 5);
    let t_fast = group_mean(&fmb_log.compute_times, 5, 10);
    let b_bad = group_mean(&batches_f64, 0, 3);
    let b_fast = group_mean(&batches_f64, 5, 10);

    // Paper's linear-progress check: intermediate nodes do ~50% of fast
    // nodes' work in fixed time; bad nodes' batch ≈ 585·12/30 ≈ 234.
    let shape = (t_bad / t_fast - 3.0).abs() < 0.5
        && (t_mid / t_fast - 2.0).abs() < 0.4
        && (b_bad - 234.0).abs() < 40.0
        && (b_fast - 702.0).abs() < 80.0;

    Ok(FigReport {
        id: "f6",
        title: "induced-straggler histograms (EC2): FMB times / AMB batches",
        paper: "FMB clusters ≈10/20/30 s; AMB bad-node batches ≈230; linear progress".into(),
        measured: format!(
            "FMB time clusters {t_fast:.1}/{t_mid:.1}/{t_bad:.1} s; AMB batches bad {b_bad:.0} fast {b_fast:.0}"
        ),
        shape_holds: shape,
        outputs: vec![p_a, p_b],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_quick() {
        let dir = std::env::temp_dir().join("amb_fig6_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig6(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
