//! Concurrent sweep driver: run independent experiment items on the
//! shared worker pool instead of back-to-back.
//!
//! Figure harnesses are grids of independent [`RunSpec`]s — fig5's
//! consensus grid, the ablation grids, thm7's per-n speedup curves —
//! and each item is deterministic given its spec (DESIGN.md §5), so
//! running them concurrently changes nothing but wall-clock time.
//! Results always come back **in item order**, whatever order workers
//! finish in ([`crate::util::pool::par_indexed`] places each result in
//! its input slot).
//!
//! Two guards keep sweeps honest:
//!
//! * items running on pool workers see a serial pool
//!   (`pool::current_threads() == 1` inside a worker), so an inner
//!   simulation never multiplies thread counts under the sweep;
//! * [`run_specs`] refuses to parallelise *threaded-runtime* items —
//!   those measure real wall clock, and concurrent runs would perturb
//!   each other's deadlines.

use std::sync::Arc;

use anyhow::Result;

use super::Ctx;
use crate::coordinator::{RunOutput, RunSpec, RuntimeKind};
use crate::exec::DataSource;
use crate::optim::DualAveraging;
use crate::straggler::StragglerModel;
use crate::topology::Topology;
use crate::util::pool;

/// Run `f(0), …, f(count − 1)` on the pool; results in item order, first
/// error wins.  `f` must be independent across items (no shared mutable
/// state) — everything it borrows is shared read-only across workers.
pub fn sweep<T, F>(count: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    pool::par_indexed(count, f).into_iter().collect()
}

/// [`sweep`], with a switch for callers that must sometimes stay serial
/// (e.g. grids that may run on the real-time threaded runtime).
pub fn sweep_if<T, F>(parallel: bool, count: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if parallel {
        sweep(count, f)
    } else {
        (0..count).map(f).collect()
    }
}

/// Execute a grid of [`RunSpec`]s over one (topology, straggler,
/// workload) through [`Ctx::run`], concurrently on the simulator and
/// serially on the threaded runtime (real deadlines must not contend).
/// Outputs are in spec order.
pub fn run_specs(
    ctx: &Ctx,
    topo: &Topology,
    straggler: &dyn StragglerModel,
    source: &Arc<DataSource>,
    optimizer: &DualAveraging,
    specs: &[RunSpec],
) -> Result<Vec<RunOutput>> {
    sweep_if(ctx.runtime != RuntimeKind::Threaded, specs.len(), |i| {
        ctx.run(&specs[i], topo, straggler, source, optimizer)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::Deterministic;
    use std::path::Path;

    #[test]
    fn sweep_keeps_item_order_and_propagates_errors() {
        let out = sweep(6, |i| Ok(i * i)).unwrap();
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25]);
        let err = sweep(4, |i| {
            if i == 2 {
                anyhow::bail!("item {i} failed")
            } else {
                Ok(i)
            }
        });
        assert!(err.is_err());
    }

    #[test]
    fn run_specs_returns_outputs_in_spec_order() {
        let topo = Topology::ring(4);
        let strag = Deterministic { unit_time: 1.0, unit_batch: 30 };
        let source = crate::experiments::linreg_source(3);
        let opt = crate::experiments::optimizer_for(&source, 400.0);
        let ctx = Ctx::native(Path::new("/tmp/amb_sweep_test"));
        // different epoch counts => different work per item
        let specs: Vec<RunSpec> = [5usize, 2, 4, 3]
            .iter()
            .map(|&e| RunSpec::amb(&format!("sw-{e}"), 1.0, 0.2, 3, e, 7))
            .collect();
        let outs = run_specs(&ctx, &topo, &strag, &source, &opt, &specs).unwrap();
        assert_eq!(outs.len(), specs.len());
        for (spec, out) in specs.iter().zip(&outs) {
            assert_eq!(out.record.name, spec.name, "sweep reordered results");
            assert_eq!(out.record.epochs.len(), spec.epochs);
        }
    }
}
