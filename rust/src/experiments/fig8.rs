//! Figures 8–9 (App. I.4): HPC pause-model experiment.
//!
//! 50 workers + master (hub-and-spoke, exact aggregation), 5 groups of 10
//! with per-gradient pauses N(μ_j, σ_j²)⁺, μ = (5,10,20,35,55) ms,
//! σ_j = j ms.  FMB: 10 gradients/worker (b = 500).  AMB: T = 115 ms
//! (empirical mean batch ≈ 504 in the paper).
//!
//! Fig 8a/8b: five visible per-group modes in the FMB-time / AMB-batch
//! histograms.  Fig 9: AMB reaches its floor cost ≈5× sooner
//! (2.45 s vs 12.7 s in the paper).

use anyhow::{Context as _, Result};

use super::{Ctx, FigReport};
use crate::coordinator::{ConsensusMode, RunOutput, RunSpec};
use crate::straggler::PauseModel;
use crate::topology::Topology;
use crate::util::csv::Csv;
use crate::util::stats::Histogram;

fn run_hpc(ctx: &Ctx, epochs: usize) -> Result<(RunOutput, RunOutput)> {
    let strag = PauseModel::paper_i4();
    let n = strag.n();
    let topo = Topology::complete(n); // irrelevant under Exact (master aggregation)
    let source = super::mnist_source(ctx.seed);
    let opt = super::optimizer_for(&source, 500.0);
    // Times in milliseconds (pause model units); T_c = 10 ms.
    let amb_spec = RunSpec::amb("amb-hpc", 115.0, 10.0, 1, epochs, ctx.seed)
        .with_consensus(ConsensusMode::Exact)
        .with_node_log();
    let amb = ctx.run(&amb_spec, &topo, &strag, &source, &opt)?;

    let fmb_spec = RunSpec::fmb("fmb-hpc", 10, 10.0, 1, epochs, ctx.seed)
        .with_consensus(ConsensusMode::Exact)
        .with_node_log();
    let fmb = ctx.run(&fmb_spec, &topo, &strag, &source, &opt)?;
    Ok((amb, fmb))
}

pub fn fig8(ctx: &Ctx) -> Result<FigReport> {
    let epochs = ctx.scaled(60);
    let (amb, fmb) = run_hpc(ctx, epochs)?;

    let fmb_log = fmb.node_log.as_ref().context("node_log recorded for fig8 runs")?;
    let mut h_times = Histogram::new(0.0, 800.0, 80);
    for node in 0..50 {
        for &t in &fmb_log.compute_times[node] {
            h_times.push(t);
        }
    }
    let amb_log = amb.node_log.as_ref().context("node_log recorded for fig8 runs")?;
    let mut h_batches = Histogram::new(0.0, 30.0, 30);
    for node in 0..50 {
        for &b in &amb_log.batches[node] {
            h_batches.push(b as f64);
        }
    }

    let mut csv_a = Csv::new(&["compute_time_ms", "count"]);
    for (c, n) in h_times.rows() {
        csv_a.push_nums(&[c, n as f64]);
    }
    let mut csv_b = Csv::new(&["batch_size", "count"]);
    for (c, n) in h_batches.rows() {
        csv_b.push_nums(&[c, n as f64]);
    }
    let p_a = ctx.out_dir.join("fig8a_fmb_times_hist.csv");
    let p_b = ctx.out_dir.join("fig8b_amb_batches_hist.csv");
    csv_a.save(&p_a)?;
    csv_b.save(&p_b)?;

    // Shape: group means ordered; fastest group ≈ 115/6 ≈ 19 grads,
    // slowest ≈ 115/56 ≈ 2; FMB group times ≈ 10·(base+μ_j).
    let group_mean_batch = |g: usize| -> f64 {
        let mut acc = 0.0;
        let mut cnt = 0;
        for node in g * 10..(g + 1) * 10 {
            for &b in &amb_log.batches[node] {
                acc += b as f64;
                cnt += 1;
            }
        }
        acc / cnt as f64
    };
    let b0 = group_mean_batch(0);
    let b4 = group_mean_batch(4);
    let monotone = (0..4).all(|g| group_mean_batch(g) >= group_mean_batch(g + 1));
    // Global mean batch across workers ≈ paper's 504/50 ≈ 10.
    let mean_batch: f64 = amb
        .record
        .epochs
        .iter()
        .map(|e| e.batch as f64)
        .sum::<f64>()
        / amb.record.epochs.len() as f64;

    Ok(FigReport {
        id: "f8",
        title: "HPC pause-model histograms: FMB times / AMB batches (50 workers, 5 groups)",
        paper: "five distinct modes; fastest group most work; E[b(t)] ≈ 504 ≈ b".into(),
        measured: format!(
            "group batches fast {b0:.1} … slow {b4:.1} (monotone {monotone}); E[b(t)] = {mean_batch:.0}"
        ),
        shape_holds: monotone && b0 > 3.0 * b4 && (mean_batch - 500.0).abs() < 120.0,
        outputs: vec![p_a, p_b],
    })
}

pub fn fig9(ctx: &Ctx) -> Result<FigReport> {
    let epochs = ctx.scaled(60);
    let (amb, fmb) = run_hpc(ctx, epochs)?;

    let p_amb = ctx.out_dir.join("fig9_amb.csv");
    let p_fmb = ctx.out_dir.join("fig9_fmb.csv");
    amb.record.save_csv(&p_amb)?;
    fmb.record.save_csv(&p_fmb)?;

    let ea = amb.record.epochs.last().context("runs record at least one epoch")?.error;
    let ef = fmb.record.epochs.last().context("runs record at least one epoch")?.error;
    let target = ea.max(ef) * 1.5;
    let speedup = crate::metrics::speedup_at(&amb.record, &fmb.record, target)
        .map(|(_, _, s)| s)
        .unwrap_or(f64::NAN);

    Ok(FigReport {
        id: "f9",
        title: "HPC MNIST logistic regression with pause-model stragglers",
        paper: "AMB >5x faster to floor cost (2.45 s vs 12.7 s)".into(),
        measured: format!(
            "time-to-cost({target:.3}) speedup {speedup:.2}x (AMB {:.2} vs FMB {:.2} total, model units)",
            amb.record.total_time(),
            fmb.record.total_time()
        ),
        shape_holds: speedup > 2.0,
        outputs: vec![p_amb, p_fmb],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_quick() {
        let dir = std::env::temp_dir().join("amb_fig8_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = fig8(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
