//! Beyond-paper workload: elastic membership (node churn).
//!
//! Sweeps **dropout rate × topology × scheme** through the concurrent
//! [`sweep`] driver: for each topology in {ring-10, paper Fig-2,
//! expander-16} and each scheme in {AMB, FMB}, runs i.i.d. dropout rates
//! p ∈ {0, 0.1, 0.2, 0.3} and records final error, time-to-target, and
//! the observed membership fraction.  The p = 0 column doubles as the
//! regression anchor: the harness re-runs one cell with an explicit
//! `IidDropout { p: 0.0 }` schedule and requires it to reproduce the
//! static-membership run **bit-for-bit** (all-active epochs take the
//! zero-rebuild base-matrix path).
//!
//! Shape asserted: every run completes with finite error, observed
//! active fractions track 1 − p, and AMB still makes progress at 30%
//! dropout — "absent nodes never block progress".

use anyhow::{Context as _, Result};

use super::{sweep, Ctx, FigReport};
use crate::churn::ChurnSpec;
use crate::coordinator::{RunOutput, RunSpec};
use crate::straggler::ShiftedExp;
use crate::topology::Topology;
use crate::util::csv::{fmt_f64, Csv};

const DROPOUTS: [f64; 4] = [0.0, 0.1, 0.2, 0.3];
const DROPOUTS_QUICK: [f64; 2] = [0.0, 0.3];

pub fn churn(ctx: &Ctx) -> Result<FigReport> {
    let epochs = ctx.scaled(16);
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed);

    let mut topos: Vec<(&str, Topology)> = vec![
        ("ring10", Topology::ring(10)),
        ("fig2", Topology::paper_fig2()),
    ];
    if !ctx.quick {
        topos.push(("expander16", Topology::expander(16, 4, ctx.seed ^ 0xE)));
    }
    let dropouts: &[f64] = if ctx.quick { &DROPOUTS_QUICK } else { &DROPOUTS };

    // One grid item per (topology, dropout, scheme).
    struct Item {
        topo: usize,
        label: String,
        p: f64,
        spec: RunSpec,
    }
    let mut items: Vec<Item> = Vec::new();
    for (ti, (tname, _)) in topos.iter().enumerate() {
        for &p in dropouts {
            for amb in [true, false] {
                let scheme = if amb { "amb" } else { "fmb" };
                let label = format!("{tname}-{scheme}-p{:02}", (p * 100.0).round() as u32);
                let mut spec = if amb {
                    RunSpec::amb(&format!("churn-{label}"), 2.5, 0.5, 5, epochs, ctx.seed)
                } else {
                    RunSpec::fmb(&format!("churn-{label}"), 600, 0.5, 5, epochs, ctx.seed)
                };
                if p > 0.0 {
                    // p = 0 keeps ChurnSpec::None: the static baseline
                    // column the bitwise anchor below compares against.
                    spec = spec.with_churn(ChurnSpec::IidDropout { p, seed: ctx.seed ^ 0xC4 });
                }
                items.push(Item { topo: ti, label, p, spec });
            }
        }
    }

    // Independent sim runs fan out on the worker pool (serial if the ctx
    // targets the real-time threaded runtime).
    let opts: Vec<_> = topos
        .iter()
        .map(|(_, t)| super::optimizer_for(&source, (t.n() * 600) as f64))
        .collect();
    let outs: Vec<RunOutput> = sweep::sweep_if(
        ctx.runtime != crate::coordinator::RuntimeKind::Threaded,
        items.len(),
        |idx| {
            let it = &items[idx];
            ctx.run(&it.spec, &topos[it.topo].1, &strag, &source, &opts[it.topo])
        },
    )?;

    // Bitwise anchor: IidDropout { p: 0 } must reproduce the static
    // ring10-amb run exactly (every epoch is all-active, so every epoch
    // takes the pre-churn code paths).
    let anchor_spec = items[0]
        .spec
        .clone()
        .with_churn(ChurnSpec::IidDropout { p: 0.0, seed: ctx.seed ^ 0xC4 });
    let anchor = ctx.run(&anchor_spec, &topos[0].1, &strag, &source, &opts[0])?;
    let baseline = &outs[0];
    let anchor_bitwise = baseline.final_w == anchor.final_w
        && baseline
            .record
            .epochs
            .iter()
            .zip(&anchor.record.epochs)
            .all(|(a, b)| {
                a.batch == b.batch
                    && a.loss.to_bits() == b.loss.to_bits()
                    && a.error.to_bits() == b.error.to_bits()
            });

    // Summary CSV + per-run series.
    let mut summary = Csv::new(&[
        "topology", "scheme", "dropout", "mean_active_frac", "final_error", "total_time",
        "total_samples",
    ]);
    let mut outputs = Vec::new();
    let mut frac_ok = true;
    let mut all_finite = true;
    for (it, out) in items.iter().zip(&outs) {
        let n = topos[it.topo].1.n();
        let frac = out.active_counts.iter().sum::<usize>() as f64
            / (out.active_counts.len() * n) as f64;
        // deterministic schedules: a generous band is stable run-to-run
        if (frac - (1.0 - it.p)).abs() > 0.2 {
            frac_ok = false;
        }
        let final_err = super::final_error(&out.record)?;
        if !final_err.is_finite() {
            all_finite = false;
        }
        let (tname, _) = &topos[it.topo];
        let scheme = if it.spec.name.contains("-amb-") { "amb" } else { "fmb" };
        summary.push(&[
            tname.to_string(),
            scheme.to_string(),
            fmt_f64(it.p),
            fmt_f64(frac),
            fmt_f64(final_err),
            fmt_f64(out.record.total_time()),
            fmt_f64(out.record.total_samples() as f64),
        ]);
        let p = ctx.out_dir.join(format!("churn_{}.csv", it.label));
        out.record.save_csv(&p)?;
        outputs.push(p);
    }
    let sp = ctx.out_dir.join("churn_summary.csv");
    summary.save(&sp)?;
    outputs.push(sp);

    // AMB keeps learning at 30% dropout on ring10: error falls from the
    // first epoch to the last.
    let heavy = items
        .iter()
        .position(|it| it.topo == 0 && it.p == 0.3 && it.spec.name.contains("-amb-"))
        .context("grid contains ring10 amb p=0.3")?;
    let heavy_rec = &outs[heavy].record;
    let amb_progress_under_churn = heavy_rec
        .epochs
        .first()
        .zip(heavy_rec.epochs.last())
        .map(|(f, l)| l.error < f.error)
        .unwrap_or(false);

    Ok(FigReport {
        id: "churn",
        title: "elastic membership: dropout rate x topology x scheme",
        paper: "beyond paper — static G(V,E); churn engine: absent nodes never block progress, \
                p=0 reproduces the static run bit-for-bit"
            .into(),
        measured: format!(
            "{} runs; membership tracks 1-p: {}; p=0 anchor bitwise: {}; AMB progresses at \
             p=0.3: {}",
            outs.len(),
            frac_ok,
            anchor_bitwise,
            amb_progress_under_churn
        ),
        shape_holds: frac_ok && all_finite && anchor_bitwise && amb_progress_under_churn,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_quick() {
        let dir = std::env::temp_dir().join("amb_churn_harness_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = churn(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        // per-run CSVs plus the summary table
        assert!(rep.outputs.iter().any(|p| p.ends_with("churn_summary.csv")));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
