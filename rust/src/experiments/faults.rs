//! Beyond-paper workload: deterministic fault injection (lossy links
//! and Markov link flaps).
//!
//! Sweeps **packet loss × link flaps × scheme** on the fig-5 topology
//! (connected Erdős–Rényi, n = 20) through the concurrent [`sweep`]
//! driver, recording final error, time-to-target, and the measured
//! per-epoch conservation drift the degraded mixing introduces (lost
//! rows are absorbed into the receiver's self-weight, so the active
//! mean is no longer exactly preserved — the drift column quantifies
//! by how much).
//!
//! The all-clear column doubles as the regression anchor: the harness
//! re-runs one cell with an explicit [`FaultSpec`] whose knobs are all
//! zero but whose fault seed is non-default, and requires it to
//! reproduce the no-fault run **bit-for-bit** — the spec-level contract
//! `FaultSpec::is_none() ⇒ the untouched clean code path`.
//!
//! Shape asserted (sim runtime): every run completes with finite error;
//! 5% loss still reaches the no-fault target error; drift is exactly
//! 0.0 in the all-clear column and strictly positive somewhere once
//! drops fire; the all-clear anchor is bitwise.  On the threaded
//! runtime drift is unobservable (no global state) and runs are
//! wall-clock, so those two checks are reported but not enforced.

use anyhow::Result;

use super::{sweep, Ctx, FigReport};
use crate::coordinator::{RunOutput, RunSpec, RuntimeKind};
use crate::fault::{FaultSpec, Flap};
use crate::straggler::ShiftedExp;
use crate::topology::Topology;
use crate::util::csv::{fmt_f64, Csv};

/// One fault column of the grid.
struct Cell {
    label: &'static str,
    loss: f64,
    flap: Option<Flap>,
}

const CELLS: [Cell; 5] = [
    Cell { label: "clear", loss: 0.0, flap: None },
    Cell { label: "loss05", loss: 0.05, flap: None },
    Cell { label: "loss20", loss: 0.20, flap: None },
    Cell { label: "flap", loss: 0.0, flap: Some(Flap { p_down: 0.1, p_up: 0.5 }) },
    Cell { label: "loss05flap", loss: 0.05, flap: Some(Flap { p_down: 0.1, p_up: 0.5 }) },
];
const CELLS_QUICK: [Cell; 2] = [
    Cell { label: "clear", loss: 0.0, flap: None },
    Cell { label: "loss05", loss: 0.05, flap: None },
];

pub fn faults(ctx: &Ctx) -> Result<FigReport> {
    let epochs = ctx.scaled(16);
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 };
    let source = super::linreg_source(ctx.seed);
    // The fig-5 comparison graph: sparse enough that gossip really
    // mixes over multiple hops, so lost rows visibly perturb the mean.
    let topo = Topology::erdos_connected(20, 0.2, 7);
    let opt = super::optimizer_for(&source, (topo.n() * 600) as f64);
    let cells: &[Cell] = if ctx.quick { &CELLS_QUICK } else { &CELLS };

    struct Item {
        label: String,
        scheme: &'static str,
        cell: usize,
        spec: RunSpec,
    }
    let mut items: Vec<Item> = Vec::new();
    for (ci, cell) in cells.iter().enumerate() {
        for scheme in ["amb", "fmb"] {
            let label = format!("{scheme}-{}", cell.label);
            let mut spec = if scheme == "amb" {
                RunSpec::amb(&format!("faults-{label}"), 2.5, 0.5, 5, epochs, ctx.seed)
            } else {
                RunSpec::fmb(&format!("faults-{label}"), 600, 0.5, 5, epochs, ctx.seed)
            };
            if cell.loss > 0.0 || cell.flap.is_some() {
                // The clear column keeps FaultSpec::none(): the
                // no-fault baseline the bitwise anchor compares to.
                spec = spec.with_faults(FaultSpec {
                    loss: cell.loss,
                    flap: cell.flap,
                    seed: ctx.seed ^ 0xFA,
                    ..FaultSpec::none()
                });
            }
            items.push(Item { label, scheme, cell: ci, spec });
        }
    }

    let outs: Vec<RunOutput> = sweep::sweep_if(
        ctx.runtime != RuntimeKind::Threaded,
        items.len(),
        |idx| ctx.run(&items[idx].spec, &topo, &strag, &source, &opt),
    )?;
    let sim = ctx.runtime == RuntimeKind::Sim;

    // Bitwise anchor: an all-clear FaultSpec (every knob zero, fault
    // seed deliberately non-default) must reproduce the no-fault
    // amb-clear run exactly, drift bits included.
    let anchor_spec = items[0]
        .spec
        .clone()
        .with_faults(FaultSpec { seed: ctx.seed ^ 0x5EED, round_timeout: 0.25, ..FaultSpec::none() });
    let anchor = ctx.run(&anchor_spec, &topo, &strag, &source, &opt)?;
    let baseline = &outs[0];
    let anchor_bitwise = baseline.final_w == anchor.final_w
        && baseline.rounds == anchor.rounds
        && baseline
            .record
            .epochs
            .iter()
            .zip(&anchor.record.epochs)
            .all(|(a, b)| {
                a.batch == b.batch
                    && a.loss.to_bits() == b.loss.to_bits()
                    && a.error.to_bits() == b.error.to_bits()
                    && a.conservation_drift.to_bits() == b.conservation_drift.to_bits()
            });

    // Time-to-target measures resilience against the no-fault run's
    // own achievement (fig-5 convention: 1.5× its final error).
    let target = super::final_error(&baseline.record)? * 1.5;

    let mut summary = Csv::new(&[
        "scheme",
        "faults",
        "final_error",
        "time_to_target",
        "mean_drift",
        "max_drift",
        "total_time",
    ]);
    let mut outputs = Vec::new();
    let mut all_finite = true;
    let mut drift_consistent = true;
    let mut loss05_reaches_target = true;
    for (it, out) in items.iter().zip(&outs) {
        let cell = &cells[it.cell];
        let final_err = super::final_error(&out.record)?;
        if !final_err.is_finite() {
            all_finite = false;
        }
        let drifts: Vec<f64> =
            out.record.epochs.iter().map(|e| e.conservation_drift).collect();
        let max_drift = drifts.iter().cloned().fold(0.0f64, f64::max);
        let mean_drift = drifts.iter().sum::<f64>() / drifts.len().max(1) as f64;
        if sim {
            let faulty = cell.loss > 0.0 || cell.flap.is_some();
            // all-clear: exactly zero; faulty: finite, measured, and
            // visible somewhere (hundreds of messages per epoch make a
            // zero-drop epoch-set astronomically unlikely at these
            // rates).
            let ok = if faulty {
                drifts.iter().all(|d| d.is_finite()) && max_drift > 0.0
            } else {
                drifts.iter().all(|&d| d == 0.0)
            };
            if !ok {
                drift_consistent = false;
            }
        }
        let tt = out.record.time_to_error(target);
        if sim && it.scheme == "amb" && cell.label == "loss05" && tt.is_none() {
            loss05_reaches_target = false;
        }
        summary.push(&[
            it.scheme.to_string(),
            cell.label.to_string(),
            fmt_f64(final_err),
            fmt_f64(tt.unwrap_or(f64::NAN)),
            fmt_f64(mean_drift),
            fmt_f64(max_drift),
            fmt_f64(out.record.total_time()),
        ]);
        let p = ctx.out_dir.join(format!("faults_{}.csv", it.label));
        out.record.save_csv(&p)?;
        outputs.push(p);
    }
    let sp = ctx.out_dir.join("faults_summary.csv");
    summary.save(&sp)?;
    outputs.push(sp);

    let anchor_ok = anchor_bitwise || !sim;
    Ok(FigReport {
        id: "faults",
        title: "fault injection: packet loss x link flaps x scheme",
        paper: "beyond paper — lossless links assumed; fault plane: degraded consensus stays \
                row-stochastic, drift is measured not assumed, all-clear spec is bit-for-bit \
                the no-fault run"
            .into(),
        measured: format!(
            "{} runs; all-clear anchor bitwise: {}; drift columns consistent: {}; amb at 5% \
             loss reaches the no-fault target: {}",
            outs.len(),
            anchor_bitwise,
            drift_consistent,
            loss05_reaches_target
        ),
        shape_holds: all_finite && anchor_ok && drift_consistent && loss05_reaches_target,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_quick() {
        let dir = std::env::temp_dir().join("amb_faults_harness_test");
        let ctx = Ctx::native(&dir).quick();
        let rep = faults(&ctx).unwrap();
        assert!(rep.shape_holds, "{rep}");
        assert!(rep.outputs.iter().any(|p| p.ends_with("faults_summary.csv")));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
