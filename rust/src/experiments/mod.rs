//! Per-figure experiment harnesses: one function per table/figure in the
//! paper's evaluation (DESIGN.md §4 maps each id to its modules).
//!
//! Every harness writes CSV series into `results/` and returns a
//! [`FigReport`] with the paper's expected shape vs our measured numbers;
//! `amb figures --fig all` regenerates everything, and each `cargo bench`
//! target wraps the corresponding harness.

pub mod ablations;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod thm7;

use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use crate::data::{LinRegStream, MnistLike};
use crate::exec::{DataSource, ExecEngine, NativeExec};
use crate::optim::{BetaSchedule, DualAveraging};
use crate::runtime::{PjrtExec, PjrtRuntime};

/// Which execution backend figure runs use.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Pure-Rust math (fast, artifact-free).
    Native,
    /// PJRT artifacts from this directory (the production path; workload
    /// sizes must match the manifest).
    Pjrt(PathBuf),
}

/// Shared context for all harnesses.
pub struct Ctx {
    pub backend: Backend,
    pub out_dir: PathBuf,
    /// Reduced epochs/paths for bench wrappers.
    pub quick: bool,
    pub seed: u64,
}

impl Ctx {
    pub fn native(out_dir: &Path) -> Ctx {
        Ctx { backend: Backend::Native, out_dir: out_dir.to_path_buf(), quick: false, seed: 42 }
    }

    pub fn quick(mut self) -> Ctx {
        self.quick = true;
        self
    }

    /// Scale an epoch/path count down in quick mode.
    pub fn scaled(&self, full: usize) -> usize {
        if self.quick {
            (full / 4).max(2)
        } else {
            full
        }
    }

    /// Build an engine factory for a workload (shared data distribution,
    /// per-node engines).  PJRT backend shares one runtime across the
    /// (single-threaded) simulator's engines.
    pub fn engine_factory(
        &self,
        source: Arc<DataSource>,
        optimizer: DualAveraging,
    ) -> Result<Box<dyn FnMut(usize) -> Box<dyn ExecEngine>>> {
        match &self.backend {
            Backend::Native => {
                let f = move |_i: usize| -> Box<dyn ExecEngine> {
                    Box::new(NativeExec::new(source.clone(), optimizer.clone()))
                };
                Ok(Box::new(f))
            }
            Backend::Pjrt(dir) => {
                let rt = Rc::new(PjrtRuntime::load(dir)?);
                let f = move |_i: usize| -> Box<dyn ExecEngine> {
                    Box::new(
                        PjrtExec::new(rt.clone(), source.clone(), optimizer.clone())
                            .expect("PjrtExec init (artifact sizes must match workload)"),
                    )
                };
                Ok(Box::new(f))
            }
        }
    }
}

/// One figure's verdict: measured numbers vs the paper's claimed shape.
#[derive(Debug, Clone)]
pub struct FigReport {
    pub id: &'static str,
    pub title: &'static str,
    /// What the paper reports (qualitative shape / factor).
    pub paper: String,
    /// What we measured.
    pub measured: String,
    /// Did the qualitative shape hold?
    pub shape_holds: bool,
    /// CSV files written.
    pub outputs: Vec<PathBuf>,
}

impl std::fmt::Display for FigReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "[{}] {}", self.id, self.title)?;
        writeln!(f, "  paper:    {}", self.paper)?;
        writeln!(f, "  measured: {}", self.measured)?;
        writeln!(f, "  shape:    {}", if self.shape_holds { "HOLDS" } else { "DIVERGES" })?;
        for o in &self.outputs {
            writeln!(f, "  -> {}", o.display())?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Workload builders shared across figures
// ---------------------------------------------------------------------------

/// Linear-regression workload matching the default artifact sizes
/// (d = 1024; the paper uses d = 10⁵ — the AMB-vs-FMB comparison is
/// dimension-independent, see DESIGN.md §2).
pub fn linreg_source(seed: u64) -> Arc<DataSource> {
    Arc::new(DataSource::LinReg(LinRegStream::new(1024, seed)))
}

/// MNIST-shaped logistic-regression workload (10 × 785).
pub fn mnist_source(seed: u64) -> Arc<DataSource> {
    Arc::new(DataSource::Mnist(MnistLike::mnist_shaped(seed)))
}

/// Dual-averaging setup for a workload: β(t) = K + √(t/μ) with μ set to
/// the expected global per-epoch batch and a radius generous enough to
/// contain the optimum.
pub fn optimizer_for(source: &DataSource, expected_batch: f64) -> DualAveraging {
    match source {
        DataSource::LinReg(s) => {
            // E‖w*‖ ≈ √d; K for least squares ≈ λmax(E xxᵀ) = 1.
            DualAveraging::new(BetaSchedule::new(1.0, expected_batch), 4.0 * (s.d as f64).sqrt())
        }
        DataSource::Mnist(m) => {
            let dim = (m.classes * m.d()) as f64;
            DualAveraging::new(BetaSchedule::new(1.0, expected_batch), 4.0 * dim.sqrt())
        }
    }
}

/// Run every figure harness; returns reports in paper order.
pub fn run_all(ctx: &Ctx) -> Result<Vec<FigReport>> {
    Ok(vec![
        fig1::fig1a(ctx)?,
        fig1::fig1b(ctx)?,
        fig3::fig3(ctx)?,
        fig4::fig4(ctx)?,
        fig5::fig5(ctx)?,
        fig6::fig6(ctx)?,
        fig7::fig7(ctx)?,
        fig8::fig8(ctx)?,
        fig8::fig9(ctx)?,
        thm7::thm7(ctx)?,
    ])
}

/// Run one figure by id ("f1a", "f1b", "f3", ... "thm7").
pub fn run_one(ctx: &Ctx, id: &str) -> Result<FigReport> {
    match id {
        "f1a" => fig1::fig1a(ctx),
        "f1b" => fig1::fig1b(ctx),
        "f3" => fig3::fig3(ctx),
        "f4" => fig4::fig4(ctx),
        "f5" => fig5::fig5(ctx),
        "f6" => fig6::fig6(ctx),
        "f7" => fig7::fig7(ctx),
        "f8" => fig8::fig8(ctx),
        "f9" => fig8::fig9(ctx),
        "thm7" => thm7::thm7(ctx),
        other => anyhow::bail!("unknown figure id '{other}' (try f1a f1b f3 f4 f5 f6 f7 f8 f9 thm7)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_scaling() {
        let c = Ctx::native(Path::new("/tmp/r"));
        assert_eq!(c.scaled(20), 20);
        let q = c.quick();
        assert_eq!(q.scaled(20), 5);
        assert_eq!(q.scaled(4), 2);
    }

    #[test]
    fn run_one_rejects_unknown() {
        let ctx = Ctx::native(Path::new("/tmp/amb_results_test"));
        assert!(run_one(&ctx, "bogus").is_err());
    }

    #[test]
    fn optimizer_radius_contains_linreg_optimum() {
        let src = linreg_source(1);
        let opt = optimizer_for(&src, 6000.0);
        if let DataSource::LinReg(s) = &*src {
            let norm = crate::util::norm2(&s.w_star) as f64;
            assert!(opt.radius > norm, "radius {} vs ‖w*‖ {}", opt.radius, norm);
        }
    }
}
