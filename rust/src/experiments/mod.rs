//! Per-figure experiment harnesses: one function per table/figure in the
//! paper's evaluation (DESIGN.md §4 maps each id to its modules).
//!
//! Every harness writes CSV series into `results/` and returns a
//! [`FigReport`] with the paper's expected shape vs our measured numbers;
//! `amb figures --fig all` regenerates everything, and each `cargo bench`
//! target wraps the corresponding harness.
//!
//! Harnesses are runtime-agnostic: they build [`RunSpec`]s and execute
//! them through [`Ctx::run`], which dispatches on [`Ctx::runtime`] —
//! `amb figures --runtime threaded --time-scale 0.01` replays any figure
//! on the real threaded cluster (straggler models map to per-node
//! slowdown factors via
//! [`crate::straggler::StragglerModel::slowdown_factors`]).
//!
//! Grids of independent specs (fig5's consensus grid, the ablation
//! grids, thm7's speedup curve) run concurrently on the worker pool via
//! the [`sweep`] driver — results stay in spec order, and threaded
//! (real-time) grids stay serial so runs can't perturb each other's
//! deadlines.

pub mod ablations;
pub mod churn;
pub mod dg;
pub mod faults;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod scale;
pub mod sweep;
pub mod thm7;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::sim::SimRuntime;
use crate::coordinator::threaded::ThreadedRuntime;
use crate::coordinator::{RunOutput, RunSpec, RuntimeKind};
use crate::data::{LinRegStream, MnistLike};
use crate::exec::{DataSource, ExecEngine, NativeExec};
use crate::optim::{BetaSchedule, DualAveraging};
use crate::runtime::{PjrtExec, PjrtRuntime};
use crate::straggler::StragglerModel;
use crate::topology::Topology;

/// Which execution backend figure runs use.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Pure-Rust math (fast, artifact-free).
    Native,
    /// PJRT artifacts from this directory (the production path; workload
    /// sizes must match the manifest).
    Pjrt(PathBuf),
}

/// Shared context for all harnesses.
pub struct Ctx {
    pub backend: Backend,
    pub out_dir: PathBuf,
    /// Reduced epochs/paths for bench wrappers.
    pub quick: bool,
    pub seed: u64,
    /// Which cluster runtime executes the harness's RunSpecs.
    pub runtime: RuntimeKind,
    /// Threaded only: real seconds per spec second (figures quote paper
    /// units; 0.01 replays them 100× faster).
    pub time_scale: f64,
}

impl Ctx {
    pub fn native(out_dir: &Path) -> Ctx {
        Ctx {
            backend: Backend::Native,
            out_dir: out_dir.to_path_buf(),
            quick: false,
            seed: 42,
            runtime: RuntimeKind::Sim,
            time_scale: 1.0,
        }
    }

    pub fn quick(mut self) -> Ctx {
        self.quick = true;
        self
    }

    pub fn with_runtime(mut self, runtime: RuntimeKind) -> Ctx {
        self.runtime = runtime;
        self
    }

    /// Build a harness context from the common CLI flags — `--pjrt`
    /// [`--artifacts DIR`], `--quick`, `--seed N`, `--runtime
    /// sim|threaded`, `--time-scale S` — shared by `amb
    /// figures`/`ablations` and the example binaries so the entry
    /// points cannot drift apart.  The threaded default time scale is
    /// 0.01: figure specs quote paper-unit windows (tens of seconds).
    pub fn from_args(out_dir: &Path, args: &crate::util::cli::Args) -> Result<Ctx> {
        let mut ctx = Ctx::native(out_dir);
        ctx.seed = args.u64_or("seed", 42)?;
        if args.flag("pjrt") {
            ctx.backend = Backend::Pjrt(
                args.get("artifacts")
                    .map(PathBuf::from)
                    .unwrap_or_else(crate::artifacts_dir),
            );
        }
        if args.flag("quick") {
            ctx = ctx.quick();
        }
        if let Some(rt) = args.get("runtime") {
            ctx.runtime = RuntimeKind::parse(rt)
                .ok_or_else(|| anyhow::anyhow!("unknown runtime '{rt}' (sim|threaded)"))?;
        }
        let default_scale = if ctx.runtime == RuntimeKind::Threaded { 0.01 } else { 1.0 };
        ctx.time_scale = args.f64_or("time-scale", default_scale)?;
        anyhow::ensure!(ctx.time_scale > 0.0, "--time-scale must be positive");
        Ok(ctx)
    }

    /// Scale an epoch/path count down in quick mode.
    pub fn scaled(&self, full: usize) -> usize {
        if self.quick {
            (full / 4).max(2)
        } else {
            full
        }
    }

    /// Build an engine factory for a workload (shared data distribution,
    /// per-node engines).  The factory is `Send + Sync` so the threaded
    /// runtime can invoke it from node threads; PJRT engines therefore
    /// load one (thread-local) runtime per node.
    pub fn engine_factory(
        &self,
        source: Arc<DataSource>,
        optimizer: DualAveraging,
    ) -> Result<Box<dyn Fn(usize) -> Box<dyn ExecEngine> + Send + Sync>> {
        match &self.backend {
            Backend::Native => {
                let f = move |_i: usize| -> Box<dyn ExecEngine> {
                    Box::new(NativeExec::new(source.clone(), optimizer.clone()))
                };
                Ok(Box::new(f))
            }
            Backend::Pjrt(dir) => {
                // Probe eagerly so a missing manifest fails at harness
                // setup, not inside a node thread (this also warms the
                // calling thread's cache for the simulator path).
                let _probe = PjrtRuntime::load_shared(dir)?;
                let dir = dir.clone();
                let f = move |_i: usize| -> Box<dyn ExecEngine> {
                    // Per-thread cache: the sim's engines share one
                    // runtime; each threaded node thread loads its own.
                    let rt = PjrtRuntime::load_shared(&dir)
                        // amb-lint: allow(D4, "engine-factory closure is infallible; PJRT load was probed at setup")
                        .expect("PJRT runtime load (probed at setup)");
                    Box::new(
                        PjrtExec::new(rt, source.clone(), optimizer.clone())
                            // amb-lint: allow(D4, "engine-factory closure is infallible; artifact sizes were probed at setup")
                            .expect("PjrtExec init (artifact sizes must match workload)"),
                    )
                };
                Ok(Box::new(f))
            }
        }
    }

    /// Execute one [`RunSpec`] on the context's runtime — the single
    /// path every harness goes through.
    ///
    /// * Sim: the straggler model drives the virtual clock.
    /// * Threaded: the spec inherits the context's `time_scale`, and —
    ///   unless it already carries explicit slowdown factors — the
    ///   straggler model's persistent per-node structure maps onto
    ///   `RunSpec::slowdown`.
    pub fn run(
        &self,
        spec: &RunSpec,
        topo: &Topology,
        straggler: &dyn StragglerModel,
        source: &Arc<DataSource>,
        optimizer: &DualAveraging,
    ) -> Result<RunOutput> {
        let mk = self.engine_factory(source.clone(), optimizer.clone())?;
        let f_star = source.f_star();
        match self.runtime {
            RuntimeKind::Sim => {
                crate::run(&SimRuntime::new(straggler), spec, topo, &*mk, f_star)
            }
            RuntimeKind::Threaded => {
                // Context values fill in only where the spec kept its
                // defaults — a non-default with_time_scale / non-empty
                // with_slowdown on the spec wins.  (A spec time_scale of
                // exactly 1.0 IS the default and inherits the context's
                // scale; request 1.0 explicitly via ctx.time_scale.)
                let mut spec = spec.clone();
                if spec.time_scale == 1.0 {
                    spec = spec.with_time_scale(self.time_scale);
                }
                if spec.slowdown.is_empty() {
                    spec.slowdown = straggler.slowdown_factors(topo.n());
                    // i.i.d. models carry no persistent per-node structure,
                    // so their threaded replay is a homogeneous cluster —
                    // figures that rely on dispersion will not reproduce.
                    let homogeneous = spec.slowdown.iter().all(|&f| f == 1.0);
                    let dispersed =
                        straggler.unit_moments().map(|m| m.stddev > 0.0).unwrap_or(false);
                    if homogeneous && dispersed {
                        eprintln!(
                            "note: straggler model is i.i.d. — threaded replay of '{}' runs \
                             a homogeneous cluster (use RunSpec::with_slowdown for induced \
                             stragglers)",
                            spec.name
                        );
                    }
                }
                crate::run(&ThreadedRuntime, &spec, topo, &*mk, f_star)
            }
        }
    }
}

/// One figure's verdict: measured numbers vs the paper's claimed shape.
#[derive(Debug, Clone)]
pub struct FigReport {
    pub id: &'static str,
    pub title: &'static str,
    /// What the paper reports (qualitative shape / factor).
    pub paper: String,
    /// What we measured.
    pub measured: String,
    /// Did the qualitative shape hold?
    pub shape_holds: bool,
    /// CSV files written.
    pub outputs: Vec<PathBuf>,
}

impl std::fmt::Display for FigReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "[{}] {}", self.id, self.title)?;
        writeln!(f, "  paper:    {}", self.paper)?;
        writeln!(f, "  measured: {}", self.measured)?;
        writeln!(f, "  shape:    {}", if self.shape_holds { "HOLDS" } else { "DIVERGES" })?;
        for o in &self.outputs {
            writeln!(f, "  -> {}", o.display())?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Workload builders shared across figures
// ---------------------------------------------------------------------------

/// Linear-regression workload matching the default artifact sizes
/// (d = 1024; the paper uses d = 10⁵ — the AMB-vs-FMB comparison is
/// dimension-independent, see DESIGN.md §2).
pub fn linreg_source(seed: u64) -> Arc<DataSource> {
    Arc::new(DataSource::LinReg(LinRegStream::new(1024, seed)))
}

/// MNIST-shaped logistic-regression workload (10 × 785).
pub fn mnist_source(seed: u64) -> Arc<DataSource> {
    Arc::new(DataSource::Mnist(MnistLike::mnist_shaped(seed)))
}

/// Final-epoch error of a run, as a clean `anyhow` error instead of a
/// panic when the record is empty (e.g. an epochs = 0 spec) — the
/// harness-side companion of the PR-2 `Consensus::{exact_average,
/// max_error}` Result migration, so no experiment unwraps its way into
/// a panic on a degenerate run.
pub fn final_error(rec: &crate::metrics::RunRecord) -> Result<f64> {
    rec.epochs
        .last()
        .map(|e| e.error)
        .ok_or_else(|| anyhow::anyhow!("run '{}' recorded no epochs", rec.name))
}

/// Dual-averaging setup for a workload: β(t) = K + √(t/μ) with μ set to
/// the expected global per-epoch batch and a radius generous enough to
/// contain the optimum.
pub fn optimizer_for(source: &DataSource, expected_batch: f64) -> DualAveraging {
    match source {
        DataSource::LinReg(s) => {
            // E‖w*‖ ≈ √d; K for least squares ≈ λmax(E xxᵀ) = 1.
            DualAveraging::new(BetaSchedule::new(1.0, expected_batch), 4.0 * (s.d as f64).sqrt())
        }
        DataSource::Mnist(m) => {
            let dim = (m.classes * m.d()) as f64;
            DualAveraging::new(BetaSchedule::new(1.0, expected_batch), 4.0 * dim.sqrt())
        }
    }
}

/// Run every figure harness; returns reports in paper order.
pub fn run_all(ctx: &Ctx) -> Result<Vec<FigReport>> {
    Ok(vec![
        fig1::fig1a(ctx)?,
        fig1::fig1b(ctx)?,
        fig3::fig3(ctx)?,
        fig4::fig4(ctx)?,
        fig5::fig5(ctx)?,
        fig6::fig6(ctx)?,
        fig7::fig7(ctx)?,
        fig8::fig8(ctx)?,
        fig8::fig9(ctx)?,
        thm7::thm7(ctx)?,
    ])
}

/// Run one figure by id ("f1a", "f1b", "f3", ... "thm7").
pub fn run_one(ctx: &Ctx, id: &str) -> Result<FigReport> {
    match id {
        "f1a" => fig1::fig1a(ctx),
        "f1b" => fig1::fig1b(ctx),
        "f3" => fig3::fig3(ctx),
        "f3n" => fig3::fig3_net(ctx),
        "f4" => fig4::fig4(ctx),
        "f5" => fig5::fig5(ctx),
        "f5n" => fig5::fig5_net(ctx),
        "f6" => fig6::fig6(ctx),
        "f7" => fig7::fig7(ctx),
        "f8" => fig8::fig8(ctx),
        "f9" => fig8::fig9(ctx),
        "thm7" => thm7::thm7(ctx),
        "churn" => churn::churn(ctx),
        "faults" => faults::faults(ctx),
        "dg" => dg::dg(ctx),
        "scale" => scale::scale(ctx),
        other => anyhow::bail!(
            "unknown figure id '{other}' (try f1a f1b f3 f3n f4 f5 f5n f6 f7 f8 f9 thm7 churn \
             faults dg scale)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::Deterministic;

    #[test]
    fn ctx_scaling() {
        let c = Ctx::native(Path::new("/tmp/r"));
        assert_eq!(c.scaled(20), 20);
        let q = c.quick();
        assert_eq!(q.scaled(20), 5);
        assert_eq!(q.scaled(4), 2);
    }

    #[test]
    fn run_one_rejects_unknown() {
        let ctx = Ctx::native(Path::new("/tmp/amb_results_test"));
        assert!(run_one(&ctx, "bogus").is_err());
    }

    #[test]
    fn final_error_is_a_result_not_a_panic() {
        let empty = crate::metrics::RunRecord::new("empty", None);
        let err = final_error(&empty).unwrap_err();
        assert!(err.to_string().contains("no epochs"));
        let mut one = crate::metrics::RunRecord::new("one", None);
        one.push(crate::metrics::EpochStats {
            epoch: 1,
            wall_time: 1.0,
            batch: 2,
            potential: 2,
            loss: 0.5,
            error: 0.25,
            consensus_err: 0.0,
            min_node_batch: 1,
            max_node_batch: 1,
            max_staleness: 0,
            mean_staleness: 0.0,
            conservation_drift: 0.0,
        });
        assert_eq!(final_error(&one).unwrap(), 0.25);
    }

    #[test]
    fn optimizer_radius_contains_linreg_optimum() {
        let src = linreg_source(1);
        let opt = optimizer_for(&src, 6000.0);
        if let DataSource::LinReg(s) = &*src {
            let norm = crate::util::norm2(&s.w_star) as f64;
            assert!(opt.radius > norm, "radius {} vs ‖w*‖ {}", opt.radius, norm);
        }
    }

    #[test]
    fn ctx_run_dispatches_to_both_runtimes() {
        let topo = Topology::ring(3);
        let strag = Deterministic { unit_time: 0.02, unit_batch: 32 };
        let src = Arc::new(DataSource::LinReg(LinRegStream::new(8, 1)));
        let opt = optimizer_for(&src, 100.0);
        let spec = RunSpec::amb("dispatch", 0.04, 0.03, 2, 2, 3).with_grad_chunk(8);

        let sim_ctx = Ctx::native(Path::new("/tmp/amb_ctx_run_test"));
        let sim_out = sim_ctx.run(&spec, &topo, &strag, &src, &opt).unwrap();
        assert_eq!(sim_out.record.epochs.len(), 2);

        let thr_ctx = Ctx::native(Path::new("/tmp/amb_ctx_run_test"))
            .with_runtime(RuntimeKind::Threaded);
        let thr_out = thr_ctx.run(&spec, &topo, &strag, &src, &opt).unwrap();
        assert_eq!(thr_out.record.epochs.len(), 2);
        assert!(thr_out.record.epochs.iter().all(|e| e.batch > 0));
    }
}
