//! ISSUE 7 n-scaling acceptance harness: the consensus plane two orders
//! of magnitude past the paper's n ≈ 64 — small-world clusters under
//! i.i.d. churn, run end-to-end on the sim runtime with flat gossip and
//! with the hierarchical (shard + aggregator-ring) scheme.
//!
//! What it certifies, per grid point:
//!
//! * the mixing layer's footprint scales with EDGES, not n² (the CSR
//!   build path never materialises dense rows — `nnz ≤ 8n` on the
//!   small-world family, vs n² dense entries);
//! * a full optimisation run at n = 10⁵ completes in wall-clock minutes
//!   (the old dense plane was n² per gossip round — 10¹⁰ multiplies —
//!   before it ran out of memory building P);
//! * both consensus schemes drive the workload to a finite, sane final
//!   loss with churn resampling the active set every epoch.
//!
//! The grid runs SERIALLY (unlike the figure sweeps): each point's
//! wall-clock is part of the acceptance evidence, so points must not
//! perturb each other's timing.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context as _, Result};

use super::{Ctx, FigReport};
use crate::churn::ChurnSpec;
use crate::coordinator::sim::SimRuntime;
use crate::coordinator::{ConsensusMode, RunSpec, Runtime, Scheme};
use crate::data::LinRegStream;
use crate::exec::{DataSource, ExecEngine, NativeExec};
use crate::straggler::Deterministic;
use crate::topology::Topology;
use crate::util::csv::Csv;

/// One (n, consensus) grid point's acceptance evidence.
pub struct ScalePoint {
    pub n: usize,
    pub consensus: &'static str,
    /// Stored entries in the mixing matrix (CSR).
    pub nnz: usize,
    pub wall_secs: f64,
    pub final_loss: f64,
    pub final_error: f64,
    pub final_consensus_err: f64,
}

/// Run one end-to-end sim at cluster size `n` and measure it.
///
/// The workload is deliberately narrow (d = 16 linear regression): the
/// quantity under test is the consensus plane, and a narrow model keeps
/// the per-epoch gradient cost at O(n) so mixing dominates.
pub fn scale_point(
    n: usize,
    consensus: ConsensusMode,
    label: &'static str,
    epochs: usize,
    seed: u64,
) -> Result<ScalePoint> {
    let topo = Topology::small_world(n, 3, 0.1, seed ^ 0x5c);
    let nnz = topo.metropolis().nnz();

    let src = Arc::new(DataSource::LinReg(LinRegStream::new(16, seed)));
    let f_star = src.f_star();
    let opt = super::optimizer_for(&src, (4 * n) as f64);
    let mk = {
        let src = src.clone();
        move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        }
    };
    // Deterministic unit speed: every node contributes 2·unit_batch
    // gradients per T = 2.0 compute phase — stragglers are not under
    // test here, the plane is.
    let strag = Deterministic { unit_time: 1.0, unit_batch: 4 };
    let spec = RunSpec::new(
        label,
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        epochs,
        seed,
    )
    .with_consensus(consensus)
    .with_churn(ChurnSpec::IidDropout { p: 0.1, seed: seed ^ 0xC4 });

    // amb-lint: allow(D1, "host wall-time of the whole run for the perf column; not simulated time")
    let t0 = Instant::now();
    let out = SimRuntime::new(&strag).run(&spec, &topo, &mk, f_star)?;
    let wall_secs = t0.elapsed().as_secs_f64();

    let last = out
        .record
        .epochs
        .last()
        .ok_or_else(|| anyhow::anyhow!("scale run '{label}' (n={n}) recorded no epochs"))?;
    Ok(ScalePoint {
        n,
        consensus: label,
        nnz,
        wall_secs,
        final_loss: last.loss,
        final_error: last.error,
        final_consensus_err: last.consensus_err,
    })
}

/// The per-n consensus configurations under test: flat sparse gossip and
/// the two-level hierarchy (~1000-node shards, budget 3 intra + 2 inter).
fn modes_for(n: usize) -> [(ConsensusMode, &'static str); 2] {
    [
        (ConsensusMode::Gossip { rounds: 3 }, "gossip3"),
        (
            ConsensusMode::Hierarchical {
                shards: (n / 1000).max(4),
                intra_rounds: 3,
                inter_rounds: 2,
            },
            "hier",
        ),
    ]
}

pub fn scale(ctx: &Ctx) -> Result<FigReport> {
    // Quick mode (the CI smoke) stops at n = 10⁴; the full harness runs
    // the 10⁵ acceptance point.
    let ns: &[usize] = if ctx.quick { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
    let epochs = ctx.scaled(4);

    let mut points = Vec::new();
    for &n in ns {
        for (mode, label) in modes_for(n) {
            points.push(scale_point(n, mode, label, epochs, ctx.seed)?);
        }
    }

    let mut csv = Csv::new(&[
        "n", "consensus", "nnz", "dense_entries", "wall_secs", "loss", "error", "consensus_err",
    ]);
    for p in &points {
        csv.push(&[
            p.n.to_string(),
            p.consensus.to_string(),
            p.nnz.to_string(),
            (p.n * p.n).to_string(),
            format!("{:.3}", p.wall_secs),
            format!("{:e}", p.final_loss),
            format!("{:e}", p.final_error),
            format!("{:e}", p.final_consensus_err),
        ]);
    }
    let path = ctx.out_dir.join("scale_sweep.csv");
    csv.save(&path)?;

    // Acceptance shapes: (a) sparse footprint — stored entries a small
    // constant multiple of n on the small-world family (dense is n²);
    // (b) every run finishes with finite, non-degenerate numerics;
    // (c) each point completes within a generous per-run wall budget
    // (the 10⁵ point takes seconds when mixing is O(E·d); the budget
    // only trips if the plane regresses toward n²).
    let sparse = points.iter().all(|p| p.nnz <= 8 * p.n);
    let finite = points
        .iter()
        .all(|p| p.final_loss.is_finite() && p.final_error.is_finite());
    let fast = points.iter().all(|p| p.wall_secs < 600.0);

    let big = points.iter().max_by_key(|p| p.n).context("non-empty scale grid")?;
    Ok(FigReport {
        id: "scale",
        title: "consensus plane at n up to 1e5 (sparse-first mixing + hierarchy)",
        paper: "mixing memory/time ∝ edges (not n²); 1e5-node churn sweep in minutes".into(),
        measured: format!(
            "n={}: nnz={} ({}x n, dense would be {:.1e}), wall {:.1}s/run; sparse={sparse} \
             finite={finite} fast={fast}",
            big.n,
            big.nnz,
            big.nnz / big.n,
            (big.n * big.n) as f64,
            big.wall_secs,
        ),
        shape_holds: sparse && finite && fast,
        outputs: vec![path],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature grid exercises the exact harness path (both consensus
    /// modes, churn, CSV row shape) without large-n cost; the full grid
    /// is covered by `amb figures --fig scale` / the CI quick smoke.
    #[test]
    fn scale_point_runs_both_modes_small() {
        for (mode, label) in modes_for(512) {
            let p = scale_point(512, mode, label, 3, 11).unwrap();
            assert_eq!(p.n, 512);
            assert!(p.nnz <= 8 * p.n, "{label}: nnz {} vs n {}", p.nnz, p.n);
            assert!(p.nnz >= 2 * p.n, "{label}: small-world P should have ≥ ring nnz");
            assert!(p.final_loss.is_finite() && p.final_error.is_finite(), "{label}");
            assert!(p.wall_secs >= 0.0);
        }
    }

    #[test]
    fn hier_shard_count_scales_with_n() {
        let (m, _) = modes_for(100_000)[1];
        match m {
            ConsensusMode::Hierarchical { shards, .. } => assert_eq!(shards, 100),
            other => panic!("expected hierarchical, got {other:?}"),
        }
    }
}
