//! Straggler (compute-time) models — the paper's experimental substrate.
//!
//! The paper runs on EC2/HPC where node speed varies with latent load; it
//! models steady-state behaviour as *conditionally linear progress*: node
//! i draws an epoch-level speed and computes gradients at that constant
//! rate within the epoch (App. I.2, validated empirically in App. I.3).
//! We implement exactly that family plus the per-gradient pause model of
//! App. I.4:
//!
//! * [`Deterministic`] — homogeneous cluster (no stragglers; baseline).
//! * [`ShiftedExp`] — T_i(t) ~ ζ + Exp(λ) per node per epoch for a unit
//!   batch (App. H, I.2; the standard straggler model in the coded-
//!   computation literature).
//! * [`InducedGroups`] — EC2 background-job experiment (App. I.3): node
//!   groups with integer slowdown factors over a common base draw
//!   (3 "bad" ×3, 2 intermediate ×2, 5 fast ×1 in the paper).
//! * [`PauseModel`] — HPC experiment (App. I.4): fixed per-gradient
//!   compute time plus a N(μ_j, σ_j²)⁺ pause after every gradient, with
//!   group-dependent μ_j, σ_j.
//! * [`TraceReplay`] — replay explicit per-(node, epoch) unit times, e.g.
//!   digitised from a real testbed.
//!
//! A model draws an [`EpochProfile`] per (node, epoch); the coordinator
//! asks the profile either "how many gradients fit in T?" (AMB) or "how
//! long do k gradients take?" (FMB) — never both in one epoch.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::rng::Pcg64;

/// A node's compute behaviour within a single epoch.
pub enum EpochProfile {
    /// Linear progress at `sec_per_grad` seconds per gradient.
    Linear { sec_per_grad: f64 },
    /// Per-gradient base time plus i.i.d. N(mu, sigma²) pauses clipped at
    /// zero (App. I.4).  Owns its RNG stream so draws are reproducible.
    PerGradient { base: f64, mu: f64, sigma: f64, rng: Pcg64 },
}

impl EpochProfile {
    /// Number of whole gradients finishing within time budget `t`
    /// (AMB compute phase, paper eq. (72) in the linear case).
    pub fn grads_in_time(&mut self, t: f64) -> usize {
        assert!(t >= 0.0);
        match self {
            EpochProfile::Linear { sec_per_grad } => {
                if *sec_per_grad <= 0.0 {
                    // amb-lint: allow(D4, "spec validation: a non-positive rate is a programming error")
                    panic!("sec_per_grad must be positive");
                }
                // A RELATIVE epsilon before the floor: when t was itself
                // computed as sec_per_grad · k (`time_for_grads`), the
                // division can land an ulp below the integer k and a raw
                // floor returns k − 1 — the inverse relationship
                // grads_in_time(time_for_grads(k)) == k must hold without
                // callers fudging t.  The nudge is 1e-9 · q (plus 1e-9
                // absolute for q near 0), far above f64 rounding noise
                // and far below any physically distinct batch count.
                let q = t / *sec_per_grad;
                (q + q * 1e-9 + 1e-9).floor() as usize
            }
            EpochProfile::PerGradient { base, mu, sigma, rng } => {
                let mut elapsed = 0.0;
                let mut k = 0usize;
                loop {
                    let step = *base + rng.normal_ms(*mu, *sigma).max(0.0);
                    if elapsed + step > t {
                        // paper App. I.4: if the remaining time is shorter
                        // than the sampled pause, the node idles out the
                        // epoch — no further gradients.
                        return k;
                    }
                    elapsed += step;
                    k += 1;
                    if k > 100_000_000 {
                        // amb-lint: allow(D4, "spec validation: degenerate timing params are a programming error")
                        panic!("grads_in_time runaway (base+pause ~ 0)");
                    }
                }
            }
        }
    }

    /// Wall time for `k` gradients (FMB compute phase).
    pub fn time_for_grads(&mut self, k: usize) -> f64 {
        match self {
            EpochProfile::Linear { sec_per_grad } => *sec_per_grad * k as f64,
            EpochProfile::PerGradient { base, mu, sigma, rng } => {
                let mut elapsed = 0.0;
                for _ in 0..k {
                    elapsed += *base + rng.normal_ms(*mu, *sigma).max(0.0);
                }
                elapsed
            }
        }
    }
}

/// Moments of the *unit-batch* completion time, when known analytically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Moments {
    pub mean: f64,
    pub stddev: f64,
}

/// A straggler model: per-(node, epoch) compute profiles.
pub trait StragglerModel: Send + Sync {
    /// Draw node `node`'s profile for epoch `epoch`.
    fn draw(&self, node: usize, epoch: usize, rng: &mut Pcg64) -> EpochProfile;

    /// Size of the reference "unit batch" whose completion time the model
    /// parameterises (e.g. 600 gradients in App. I.2).
    fn unit_batch(&self) -> usize;

    /// Analytic moments of the unit-batch time, if known (used by the
    /// Thm. 7 harness to set T = (1 + n/b)·μ).
    fn unit_moments(&self) -> Option<Moments> {
        None
    }

    /// Relative per-node slowdown factors (≥ 1.0, fastest node = 1.0)
    /// for the threaded runtime, which induces stragglers by napping
    /// instead of drawing virtual times (`RunSpec::slowdown`).  The
    /// default — an i.i.d. model — is a homogeneous cluster; models with
    /// persistent per-node structure override this so a figure harness
    /// can replay its straggler shape on real threads.
    fn slowdown_factors(&self, n: usize) -> Vec<f64> {
        vec![1.0; n]
    }
}

// ---------------------------------------------------------------------------

/// Homogeneous cluster: every node, every epoch, the same speed.
#[derive(Debug, Clone)]
pub struct Deterministic {
    pub unit_time: f64,
    pub unit_batch: usize,
}

impl StragglerModel for Deterministic {
    fn draw(&self, _node: usize, _epoch: usize, _rng: &mut Pcg64) -> EpochProfile {
        EpochProfile::Linear { sec_per_grad: self.unit_time / self.unit_batch as f64 }
    }

    fn unit_batch(&self) -> usize {
        self.unit_batch
    }

    fn unit_moments(&self) -> Option<Moments> {
        Some(Moments { mean: self.unit_time, stddev: 0.0 })
    }
}

/// T_i(t) ~ zeta + Exp(lambda), i.i.d. across nodes and epochs, for
/// `unit_batch` gradients (paper App. H / I.2: λ=2/3, ζ=1, unit=600).
#[derive(Debug, Clone)]
pub struct ShiftedExp {
    pub zeta: f64,
    pub lambda: f64,
    pub unit_batch: usize,
}

impl ShiftedExp {
    /// Paper App. I.2 parameters.
    pub fn paper_i2() -> ShiftedExp {
        ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 600 }
    }
}

impl StragglerModel for ShiftedExp {
    fn draw(&self, _node: usize, _epoch: usize, rng: &mut Pcg64) -> EpochProfile {
        let t_unit = rng.shifted_exp(self.zeta, self.lambda);
        EpochProfile::Linear { sec_per_grad: t_unit / self.unit_batch as f64 }
    }

    fn unit_batch(&self) -> usize {
        self.unit_batch
    }

    fn unit_moments(&self) -> Option<Moments> {
        Some(Moments { mean: self.zeta + 1.0 / self.lambda, stddev: 1.0 / self.lambda })
    }
}

/// EC2 induced-straggler experiment (App. I.3): per-node slowdown factors
/// over a common shifted-exponential base.  The paper's setup:
/// 3 nodes ×3 ("two background jobs"), 2 nodes ×2, 5 nodes ×1, with FMB
/// unit batches clustering near 10 s/20 s/30 s.
#[derive(Debug, Clone)]
pub struct InducedGroups {
    /// slowdown factor per node (length = n).
    pub factors: Vec<f64>,
    /// base unit-batch time distribution.
    pub base_zeta: f64,
    pub base_lambda: f64,
    pub unit_batch: usize,
}

impl InducedGroups {
    /// The paper's 10-node arrangement: nodes 0-2 bad (×3), 3-4
    /// intermediate (×2), 5-9 fast (×1); base ≈ 10 s per 585 gradients.
    pub fn paper_i3() -> InducedGroups {
        let mut factors = vec![3.0, 3.0, 3.0, 2.0, 2.0];
        factors.extend(std::iter::repeat(1.0).take(5));
        InducedGroups { factors, base_zeta: 9.0, base_lambda: 1.0, unit_batch: 585 }
    }

    pub fn n(&self) -> usize {
        self.factors.len()
    }
}

impl StragglerModel for InducedGroups {
    fn draw(&self, node: usize, _epoch: usize, rng: &mut Pcg64) -> EpochProfile {
        let base = rng.shifted_exp(self.base_zeta, self.base_lambda);
        let factor = self.factors[node];
        EpochProfile::Linear { sec_per_grad: factor * base / self.unit_batch as f64 }
    }

    fn unit_batch(&self) -> usize {
        self.unit_batch
    }
    // No closed-form mixture moments exposed; harnesses estimate them.

    fn slowdown_factors(&self, n: usize) -> Vec<f64> {
        assert_eq!(n, self.n(), "InducedGroups has intrinsic n={}", self.n());
        self.factors.clone()
    }
}

/// HPC induced-straggler experiment (App. I.4): after each gradient the
/// node pauses for max(0, N(mu_j, sigma_j²)); group j's parameters apply
/// to a contiguous block of nodes.  All times in the same unit as
/// `per_grad_base` (the paper uses milliseconds: μ = 5..55 ms,
/// σ_j = j ms, T = 115 ms, b = 500 over 50 workers).
#[derive(Debug, Clone)]
pub struct PauseModel {
    /// (nodes_in_group, mu, sigma) per group.
    pub groups: Vec<(usize, f64, f64)>,
    pub per_grad_base: f64,
}

impl PauseModel {
    /// Paper App. I.4: 50 workers in 5 groups of 10;
    /// μ = (5,10,20,35,55), σ_j = j; base per-gradient ≈ 1 (ms units).
    pub fn paper_i4() -> PauseModel {
        PauseModel {
            groups: vec![
                (10, 5.0, 1.0),
                (10, 10.0, 2.0),
                (10, 20.0, 3.0),
                (10, 35.0, 4.0),
                (10, 55.0, 5.0),
            ],
            per_grad_base: 1.0,
        }
    }

    pub fn n(&self) -> usize {
        self.groups.iter().map(|g| g.0).sum()
    }

    fn group_of(&self, node: usize) -> (f64, f64) {
        let mut off = 0;
        for &(cnt, mu, sigma) in &self.groups {
            if node < off + cnt {
                return (mu, sigma);
            }
            off += cnt;
        }
        // amb-lint: allow(D4, "spec validation: out-of-range node is a programming error")
        panic!("node {node} out of range for PauseModel with n={}", self.n());
    }
}

impl StragglerModel for PauseModel {
    fn draw(&self, node: usize, epoch: usize, rng: &mut Pcg64) -> EpochProfile {
        let (mu, sigma) = self.group_of(node);
        // Independent per-(node, epoch) stream so FMB/AMB comparisons are
        // reproducible regardless of query order.
        let stream = rng.split((node as u64) << 32 | epoch as u64);
        EpochProfile::PerGradient { base: self.per_grad_base, mu, sigma, rng: stream }
    }

    fn unit_batch(&self) -> usize {
        1
    }

    fn slowdown_factors(&self, n: usize) -> Vec<f64> {
        assert_eq!(n, self.n(), "PauseModel has intrinsic n={}", self.n());
        // Mean per-gradient time ratio vs the fastest group.
        let base = self.per_grad_base;
        let fastest = self
            .groups
            .iter()
            .map(|&(_, mu, _)| base + mu)
            .fold(f64::INFINITY, f64::min);
        (0..n)
            .map(|i| {
                let (mu, _) = self.group_of(i);
                (base + mu) / fastest
            })
            .collect()
    }
}

/// Markov-modulated speeds: each node is in a hidden {Normal, Burst}
/// state with per-epoch transition probabilities; Burst multiplies the
/// unit time.  Models the paper's observation that steady-state EC2
/// workers keep "their processor speed relatively constant except for
/// occasional bursts" (Sec. 6.2).  State evolves deterministically from
/// (node, epoch, seed) so FMB/AMB comparisons see identical weather.
#[derive(Debug)]
pub struct MarkovModulated {
    pub base_zeta: f64,
    pub base_lambda: f64,
    pub unit_batch: usize,
    /// P(Normal -> Burst) per epoch.
    pub p_burst: f64,
    /// P(Burst -> Normal) per epoch.
    pub p_recover: f64,
    /// Unit-time multiplier while bursting.
    pub burst_factor: f64,
    /// Chain seed (decoupled from the draw RNG so the hidden weather is
    /// identical across schemes).
    pub chain_seed: u64,
    /// Per-node chain cache, extended incrementally: the old code
    /// replayed every chain from epoch 0 on EVERY query — O(T²) per run
    /// and a quadratic blowup for long-horizon sweeps.  Each node's
    /// cached (rng, state, history) advances exactly the legacy draw
    /// sequence, so the weather is bit-for-bit unchanged (pinned by
    /// `markov_cached_chain_matches_legacy_replay_bitwise`).
    chains: Mutex<HashMap<usize, NodeChain>>,
}

#[derive(Debug)]
struct NodeChain {
    rng: Pcg64,
    burst: bool,
    states: Vec<bool>,
}

impl MarkovModulated {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        base_zeta: f64,
        base_lambda: f64,
        unit_batch: usize,
        p_burst: f64,
        p_recover: f64,
        burst_factor: f64,
        chain_seed: u64,
    ) -> MarkovModulated {
        MarkovModulated {
            base_zeta,
            base_lambda,
            unit_batch,
            p_burst,
            p_recover,
            burst_factor,
            chain_seed,
            chains: Mutex::new(HashMap::new()),
        }
    }

    /// Is node `i` bursting in `epoch`?  O(1) amortised: the cached
    /// chain extends forward only as far as the highest epoch queried,
    /// drawing the identical sequence the legacy from-zero replay drew.
    pub fn bursting(&self, node: usize, epoch: usize) -> bool {
        // amb-lint: allow(D4, "lock poisoning propagates the original worker panic")
        let mut chains = self.chains.lock().unwrap();
        let chain = chains.entry(node).or_insert_with(|| NodeChain {
            rng: Pcg64::new(self.chain_seed ^ ((node as u64) << 20) ^ 0xB00),
            burst: false,
            states: Vec::new(),
        });
        while chain.states.len() <= epoch {
            let u = chain.rng.f64();
            chain.burst = if chain.burst { u >= self.p_recover } else { u < self.p_burst };
            let state = chain.burst;
            chain.states.push(state);
        }
        chain.states[epoch]
    }
}

impl Clone for MarkovModulated {
    /// Clones share parameters but start a fresh cache (a pure memo of
    /// the deterministic chain, so clones still see identical weather).
    fn clone(&self) -> MarkovModulated {
        MarkovModulated::new(
            self.base_zeta,
            self.base_lambda,
            self.unit_batch,
            self.p_burst,
            self.p_recover,
            self.burst_factor,
            self.chain_seed,
        )
    }
}

impl StragglerModel for MarkovModulated {
    fn draw(&self, node: usize, epoch: usize, rng: &mut Pcg64) -> EpochProfile {
        let mut t_unit = rng.shifted_exp(self.base_zeta, self.base_lambda);
        if self.bursting(node, epoch) {
            t_unit *= self.burst_factor;
        }
        EpochProfile::Linear { sec_per_grad: t_unit / self.unit_batch as f64 }
    }

    fn unit_batch(&self) -> usize {
        self.unit_batch
    }
}

/// Persistently heterogeneous cluster: node i's *mean* unit time is
/// drawn once (from the given range) and fixed for the whole run, with
/// small per-epoch jitter.  Models mixed instance generations.
#[derive(Debug, Clone)]
pub struct HeterogeneousMeans {
    /// per-node mean unit time.
    pub means: Vec<f64>,
    /// multiplicative jitter half-width (e.g. 0.1 ⇒ ±10%).
    pub jitter: f64,
    pub unit_batch: usize,
}

impl HeterogeneousMeans {
    pub fn uniform(n: usize, lo: f64, hi: f64, jitter: f64, unit_batch: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed ^ 0x4E7);
        let means = (0..n).map(|_| rng.range_f64(lo, hi)).collect();
        HeterogeneousMeans { means, jitter, unit_batch }
    }
}

impl StragglerModel for HeterogeneousMeans {
    fn draw(&self, node: usize, _epoch: usize, rng: &mut Pcg64) -> EpochProfile {
        let m = self.means[node];
        let t_unit = m * (1.0 + self.jitter * (2.0 * rng.f64() - 1.0));
        EpochProfile::Linear { sec_per_grad: t_unit / self.unit_batch as f64 }
    }

    fn unit_batch(&self) -> usize {
        self.unit_batch
    }

    fn slowdown_factors(&self, n: usize) -> Vec<f64> {
        assert_eq!(n, self.means.len(), "HeterogeneousMeans has intrinsic n={}", self.means.len());
        let fastest = self.means.iter().copied().fold(f64::INFINITY, f64::min);
        self.means.iter().map(|&m| m / fastest).collect()
    }
}

/// Replay explicit per-node, per-epoch unit-batch times (row = node).
#[derive(Debug, Clone)]
pub struct TraceReplay {
    /// times[node][epoch % len] = unit-batch completion time.
    pub times: Vec<Vec<f64>>,
    pub unit_batch: usize,
}

impl StragglerModel for TraceReplay {
    fn draw(&self, node: usize, epoch: usize, _rng: &mut Pcg64) -> EpochProfile {
        let row = &self.times[node];
        let t = row[epoch % row.len()];
        EpochProfile::Linear { sec_per_grad: t / self.unit_batch as f64 }
    }

    fn unit_batch(&self) -> usize {
        self.unit_batch
    }
}

/// Estimate unit-batch moments by Monte-Carlo over nodes and epochs
/// (used when `unit_moments` is None).
pub fn estimate_unit_moments<M: StragglerModel + ?Sized>(
    model: &M,
    n: usize,
    samples: usize,
    seed: u64,
) -> Moments {
    // amb-lint: allow(D3, "stream root: caller-supplied seed is this generator's namespace")
    let mut rng = Pcg64::new(seed);
    let mut w = crate::util::stats::Welford::new();
    let unit = model.unit_batch();
    for s in 0..samples {
        let node = s % n;
        let mut prof = model.draw(node, s / n, &mut rng);
        w.push(prof.time_for_grads(unit));
    }
    Moments { mean: w.mean(), stddev: w.stddev() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn deterministic_linear_progress() {
        let m = Deterministic { unit_time: 10.0, unit_batch: 100 };
        let mut rng = Pcg64::new(0);
        let mut p = m.draw(0, 0, &mut rng);
        assert_eq!(p.grads_in_time(1.0), 10);
        assert_eq!(p.grads_in_time(0.05), 0);
        assert!((p.time_for_grads(50) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn linear_inverse_relationship() {
        // grads_in_time(time_for_grads(k)) == k for linear profiles — the
        // EXACT boundary, no caller-side slop: the relative epsilon lives
        // inside grads_in_time where it belongs.
        forall(30, 0x51_01, |g| {
            let m = ShiftedExp { zeta: g.f64_in(0.1, 2.0), lambda: g.f64_in(0.2, 3.0), unit_batch: 600 };
            let mut rng = Pcg64::new(g.u64());
            let mut p = m.draw(0, 0, &mut rng);
            let k = g.usize_in(1, 5000);
            let t = p.time_for_grads(k);
            crate::prop_assert!(p.grads_in_time(t) == k, "round-trip lost a gradient");
            crate::prop_assert!(p.grads_in_time(t * 0.999) < k);
            Ok(())
        });
    }

    #[test]
    fn linear_boundary_exact_at_worst_case_rates() {
        // Deterministic worst cases: sec_per_grad values whose reciprocal
        // is inexact in binary, where t/spg lands an ulp below k.
        for &(unit_time, unit_batch) in
            &[(1.0f64, 3usize), (1.0, 7), (1.0, 49), (0.3, 10), (2.0, 600), (14.5, 585)]
        {
            let m = Deterministic { unit_time, unit_batch };
            let mut rng = Pcg64::new(0);
            for k in [1usize, 2, 3, 599, 600, 601, 4999] {
                let mut p = m.draw(0, 0, &mut rng);
                let t = p.time_for_grads(k);
                assert_eq!(
                    p.grads_in_time(t),
                    k,
                    "unit_time={unit_time} unit_batch={unit_batch} k={k}"
                );
            }
        }
    }

    #[test]
    fn shifted_exp_moments_match_samples() {
        let m = ShiftedExp::paper_i2();
        let est = estimate_unit_moments(&m, 10, 40_000, 7);
        let a = m.unit_moments().unwrap();
        assert!((est.mean - a.mean).abs() / a.mean < 0.02, "est={est:?}");
        assert!((est.stddev - a.stddev).abs() / a.stddev < 0.05, "est={est:?}");
    }

    #[test]
    fn shifted_exp_minimum_is_zeta() {
        let m = ShiftedExp::paper_i2();
        let mut rng = Pcg64::new(3);
        for e in 0..2000 {
            let mut p = m.draw(e % 10, e, &mut rng);
            let t = p.time_for_grads(600);
            assert!(t >= m.zeta);
        }
    }

    #[test]
    fn induced_groups_ordering() {
        // Bad nodes are, on average, ~3x slower than fast nodes.
        let m = InducedGroups::paper_i3();
        let mut rng = Pcg64::new(11);
        let avg_time = |node: usize, rng: &mut Pcg64| -> f64 {
            let mut acc = 0.0;
            for e in 0..3000 {
                let mut p = m.draw(node, e, rng);
                acc += p.time_for_grads(m.unit_batch());
            }
            acc / 3000.0
        };
        let bad = avg_time(0, &mut rng);
        let mid = avg_time(3, &mut rng);
        let fast = avg_time(7, &mut rng);
        assert!((bad / fast - 3.0).abs() < 0.25, "bad/fast={}", bad / fast);
        assert!((mid / fast - 2.0).abs() < 0.2, "mid/fast={}", mid / fast);
        // Clusters land near the paper's 10/20/30 s (base ≈ 10 s).
        assert!((fast - 10.0).abs() < 1.0, "fast={fast}");
        assert!((bad - 30.0).abs() < 2.0, "bad={bad}");
    }

    #[test]
    fn pause_model_group_lookup() {
        let m = PauseModel::paper_i4();
        assert_eq!(m.n(), 50);
        assert_eq!(m.group_of(0), (5.0, 1.0));
        assert_eq!(m.group_of(9), (5.0, 1.0));
        assert_eq!(m.group_of(10), (10.0, 2.0));
        assert_eq!(m.group_of(49), (55.0, 5.0));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn pause_model_bad_node_panics() {
        PauseModel::paper_i4().group_of(50);
    }

    #[test]
    fn pause_model_slower_groups_fewer_grads() {
        let m = PauseModel::paper_i4();
        let mut rng = Pcg64::new(13);
        let avg_grads = |node: usize, rng: &mut Pcg64| -> f64 {
            let mut acc = 0.0;
            for e in 0..400 {
                let mut p = m.draw(node, e, rng);
                acc += p.grads_in_time(115.0) as f64;
            }
            acc / 400.0
        };
        let fast = avg_grads(0, &mut rng); // mu=5  -> ~115/6  ≈ 19
        let slow = avg_grads(45, &mut rng); // mu=55 -> ~115/56 ≈ 2
        assert!(fast > 3.0 * slow, "fast={fast} slow={slow}");
        assert!((fast - 115.0 / 6.0).abs() < 2.5, "fast={fast}");
    }

    #[test]
    fn pause_model_amb_vs_fmb_queries_consistent() {
        // time_for_grads(k) where k = grads_in_time(T) must be <= T for
        // the same profile draw (fresh draws, same stream).
        let m = PauseModel::paper_i4();
        let mut rng_a = Pcg64::new(17);
        let mut rng_b = Pcg64::new(17);
        for e in 0..100 {
            let mut pa = m.draw(7, e, &mut rng_a);
            let k = pa.grads_in_time(115.0);
            let mut pb = m.draw(7, e, &mut rng_b);
            let t = pb.time_for_grads(k);
            assert!(t <= 115.0 + 1e-9, "t={t} k={k}");
        }
    }

    #[test]
    fn trace_replay_wraps() {
        let m = TraceReplay { times: vec![vec![1.0, 2.0], vec![4.0, 4.0]], unit_batch: 10 };
        let mut rng = Pcg64::new(0);
        let mut p = m.draw(0, 3, &mut rng); // epoch 3 -> index 1 -> 2.0
        assert!((p.time_for_grads(10) - 2.0).abs() < 1e-12);
        let mut p2 = m.draw(1, 0, &mut rng);
        assert_eq!(p2.grads_in_time(2.0), 5);
    }

    #[test]
    fn estimate_moments_deterministic_zero_var() {
        let m = Deterministic { unit_time: 3.0, unit_batch: 30 };
        let est = estimate_unit_moments(&m, 4, 100, 0);
        assert!((est.mean - 3.0).abs() < 1e-9);
        assert!(est.stddev < 1e-9);
    }

    /// The pre-cache chain query, kept verbatim as the baseline: replay
    /// the hidden chain from epoch 0 on every call.
    fn legacy_bursting(m: &MarkovModulated, node: usize, epoch: usize) -> bool {
        let mut rng = Pcg64::new(m.chain_seed ^ ((node as u64) << 20) ^ 0xB00);
        let mut burst = false;
        for _ in 0..=epoch {
            let u = rng.f64();
            burst = if burst { u >= m.p_recover } else { u < m.p_burst };
        }
        burst
    }

    #[test]
    fn markov_cached_chain_matches_legacy_replay_bitwise() {
        let m = MarkovModulated::new(1.0, 2.0, 100, 0.15, 0.4, 4.0, 99);
        // out-of-order and repeated queries exercise the incremental
        // extension; every answer must equal the from-zero replay.
        for &(node, epoch) in &[
            (0usize, 37usize), (0, 3), (2, 0), (2, 80), (1, 11), (0, 37), (1, 11), (4, 200),
        ] {
            assert_eq!(
                m.bursting(node, epoch),
                legacy_bursting(&m, node, epoch),
                "node {node} epoch {epoch}"
            );
        }
        for node in 0..5 {
            for epoch in 0..120 {
                assert_eq!(m.bursting(node, epoch), legacy_bursting(&m, node, epoch));
            }
        }
        // a clone (fresh cache) still sees the same weather
        let c = m.clone();
        for node in 0..5 {
            for epoch in (0..120).rev() {
                assert_eq!(c.bursting(node, epoch), m.bursting(node, epoch));
            }
        }
    }

    #[test]
    fn markov_queries_are_linear_not_quadratic() {
        // The cache must consume each node's chain RNG exactly once per
        // epoch regardless of how many queries arrive: a full ascending
        // sweep over T epochs leaves the cached history at length T, and
        // re-querying is pure lookup (the O(T²) replay consumed Θ(T²)
        // draws).  We can't time here, but we can verify the cached
        // prefix is consistent under heavy re-querying.
        let m = MarkovModulated::new(1.0, 2.0, 100, 0.2, 0.5, 4.0, 5);
        let first: Vec<bool> = (0..3000).map(|e| m.bursting(3, e)).collect();
        for _ in 0..10 {
            let again: Vec<bool> = (0..3000).map(|e| m.bursting(3, e)).collect();
            assert_eq!(first, again);
        }
    }

    #[test]
    fn markov_chain_deterministic_and_bursty() {
        let m = MarkovModulated::new(1.0, 2.0, 100, 0.2, 0.5, 4.0, 7);
        // weather identical regardless of draw rng
        for node in 0..5 {
            for epoch in 0..20 {
                assert_eq!(m.bursting(node, epoch), m.bursting(node, epoch));
            }
        }
        // stationary burst fraction ≈ p_burst/(p_burst + p_recover) = 2/7
        let mut bursts = 0usize;
        let total = 5 * 400;
        for node in 0..5 {
            for epoch in 0..400 {
                bursts += m.bursting(node, epoch) as usize;
            }
        }
        let frac = bursts as f64 / total as f64;
        assert!((frac - 2.0 / 7.0).abs() < 0.06, "frac={frac}");
        // bursting epochs are slower on average
        let mut rng = Pcg64::new(1);
        let (mut tb, mut nb, mut tn, mut nn) = (0.0, 0, 0.0, 0);
        for epoch in 0..400 {
            let mut p = m.draw(2, epoch, &mut rng);
            let t = p.time_for_grads(100);
            if m.bursting(2, epoch) {
                tb += t;
                nb += 1;
            } else {
                tn += t;
                nn += 1;
            }
        }
        if nb > 10 && nn > 10 {
            assert!(tb / nb as f64 > 2.5 * (tn / nn as f64));
        }
    }

    #[test]
    fn slowdown_factors_mirror_persistent_structure() {
        // i.i.d. models are homogeneous on real threads
        assert_eq!(ShiftedExp::paper_i2().slowdown_factors(4), vec![1.0; 4]);
        // induced groups replay their exact factors
        let ig = InducedGroups::paper_i3();
        let f = ig.slowdown_factors(10);
        assert_eq!(f[0], 3.0);
        assert_eq!(f[4], 2.0);
        assert_eq!(f[9], 1.0);
        // pause model: mean per-grad ratio vs the fastest group
        let pm = PauseModel::paper_i4();
        let f = pm.slowdown_factors(50);
        assert!((f[0] - 1.0).abs() < 1e-12);
        assert!((f[49] - 56.0 / 6.0).abs() < 1e-9, "f49={}", f[49]);
        // heterogeneous means normalise to the fastest node
        let hm = HeterogeneousMeans::uniform(6, 1.0, 4.0, 0.0, 100, 3);
        let f = hm.slowdown_factors(6);
        assert!(f.iter().all(|&x| x >= 1.0));
        assert!(f.iter().any(|&x| (x - 1.0).abs() < 1e-12));
    }

    #[test]
    fn heterogeneous_means_persistent_ordering() {
        let m = HeterogeneousMeans::uniform(6, 1.0, 4.0, 0.05, 100, 3);
        let mut rng = Pcg64::new(2);
        // per-node averages track the drawn means
        for node in 0..6 {
            let mut acc = 0.0;
            for e in 0..300 {
                let mut p = m.draw(node, e, &mut rng);
                acc += p.time_for_grads(100);
            }
            let avg = acc / 300.0;
            assert!(
                (avg - m.means[node]).abs() / m.means[node] < 0.05,
                "node {node}: avg={avg} mean={}",
                m.means[node]
            );
        }
    }
}
