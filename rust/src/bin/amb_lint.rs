//! `amb-lint` CLI — walk the given roots and enforce the determinism
//! contract (DESIGN.md §determinism-contract).
//!
//! ```text
//! cargo run --bin amb-lint -- rust/src rust/tests examples
//! cargo run --bin amb-lint -- --rules
//! ```
//!
//! Exit status: 0 when the tree is clean, 1 on any violation (including
//! `meta` findings for malformed or stale suppressions), 2 on I/O errors.

#![forbid(unsafe_code)]
#![deny(clippy::dbg_macro, clippy::todo, clippy::unimplemented, clippy::mem_forget)]

use std::path::PathBuf;
use std::process::ExitCode;

use anytime_mb::analysis::{lint_tree, RULES};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("usage: amb-lint [--rules] <root>...");
        println!("lints every .rs file under the given files/directories");
        return ExitCode::SUCCESS;
    }
    if args.iter().any(|a| a == "--rules") {
        for (id, what) in RULES {
            println!("{id:5} {what}");
        }
        return ExitCode::SUCCESS;
    }
    let roots: Vec<PathBuf> = if args.is_empty() {
        // Repo-root default, mirroring the CI invocation.
        ["rust/src", "rust/tests", "rust/benches", "examples"]
            .iter()
            .map(PathBuf::from)
            .filter(|p| p.exists())
            .collect()
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    if roots.is_empty() {
        eprintln!("amb-lint: no roots to lint (run from the repo root or pass paths)");
        return ExitCode::from(2);
    }
    match lint_tree(&roots) {
        Ok(report) => {
            print!("{}", report.render());
            if report.is_clean() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("amb-lint: {e:#}");
            ExitCode::from(2)
        }
    }
}
