//! Communication topologies and doubly-stochastic mixing matrices.
//!
//! The paper (Sec. 3) assumes a connected undirected graph G(V,E) and a
//! positive semi-definite doubly-stochastic matrix P consistent with G;
//! consensus speed is governed by λ₂(P) (Lemma 1).  We build P with
//! Metropolis–Hastings weights (symmetric, doubly stochastic for any
//! graph) and expose the lazy transform (P+I)/2 which guarantees PSD.
//!
//! `paper_fig2` reconstructs the 10-node experiment topology of App. I.1;
//! the exact edge set is not published, so we use a 10-node sparse graph
//! tuned so λ₂(P) ≈ 0.888, the value the paper reports — consensus speed,
//! which is all that enters the algorithm, then matches the testbed.

use crate::util::matrix::NodeMatrix;
use crate::util::rng::Pcg64;

/// Undirected graph with sorted adjacency lists.
#[derive(Debug, Clone)]
pub struct Topology {
    n: usize,
    adj: Vec<Vec<usize>>,
}

impl Topology {
    /// Build from an edge list; self-loops and duplicates are ignored.
    ///
    /// Duplicates (in either orientation) are removed by sort + dedup —
    /// O(E log E) total.  The old per-insert `contains` scan was
    /// O(E · deg): quadratic for `complete(n)` / `expander`, which now
    /// sit on the churn hot path (`induced` rebuilds per active set).
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Topology {
        assert!(n > 0);
        let mut adj = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n, "edge ({a},{b}) out of range n={n}");
            if a == b {
                continue;
            }
            adj[a].push(b);
            adj[b].push(a);
        }
        for l in &mut adj {
            l.sort_unstable();
            l.dedup();
        }
        Topology { n, adj }
    }

    /// Ring lattice: i — (i+1) mod n.
    pub fn ring(n: usize) -> Topology {
        assert!(n >= 2);
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Topology::from_edges(n, &edges)
    }

    /// Fully connected.
    pub fn complete(n: usize) -> Topology {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        Topology::from_edges(n, &edges)
    }

    /// rows × cols 4-neighbour grid.
    pub fn grid(rows: usize, cols: usize) -> Topology {
        let n = rows * cols;
        let mut edges = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let i = r * cols + c;
                if c + 1 < cols {
                    edges.push((i, i + 1));
                }
                if r + 1 < rows {
                    edges.push((i, i + cols));
                }
            }
        }
        Topology::from_edges(n, &edges)
    }

    /// Hub-and-spoke (master–worker, App. I.1): node 0 is the hub
    /// connected to `workers` spokes.
    pub fn hub_spoke(workers: usize) -> Topology {
        assert!(workers >= 1);
        let edges: Vec<_> = (1..=workers).map(|w| (0usize, w)).collect();
        Topology::from_edges(workers + 1, &edges)
    }

    /// Watts–Strogatz small world: ring lattice with k nearest neighbours
    /// per side, each chord rewired with probability beta (rewiring keeps
    /// the underlying ring so the graph stays connected).  Requires
    /// 2k ≤ n (chords up to the antipode; longer ones would only
    /// duplicate the other side) — the documented n = 4, k = 2 minimum
    /// is valid, which the old `k < n/2` assert wrongly rejected.
    pub fn small_world(n: usize, k: usize, beta: f64, seed: u64) -> Topology {
        assert!(n >= 4 && k >= 1 && 2 * k <= n);
        let mut rng = Pcg64::new(seed ^ 0x5_3A11);
        let mut edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        for dist in 2..=k {
            // At the antipode (2·dist == n) the chord (i, i+dist) and
            // (i+dist, i) are the SAME edge; enumerating all n starts
            // would draw two independent rewires for it (survival
            // probability (1−β)² instead of 1−β, plus phantom extra
            // chords).  Each undirected chord gets exactly one draw.
            let starts = if 2 * dist == n { n / 2 } else { n };
            for i in 0..starts {
                let j = (i + dist) % n;
                if rng.f64() < beta {
                    // rewire to a uniform non-self target (dups dropped
                    // by from_edges)
                    let mut t = rng.below(n as u64) as usize;
                    if t == i {
                        t = (t + 1) % n;
                    }
                    edges.push((i, t));
                } else {
                    edges.push((i, j));
                }
            }
        }
        Topology::from_edges(n, &edges)
    }

    /// Random d-regular-ish expander: d/2 superimposed random ring
    /// permutations (connected by construction via the first ring;
    /// degrees concentrate near d).  Expanders give λ₂ bounded away
    /// from 1 independent of n — the best-case consensus topology.
    pub fn expander(n: usize, d: usize, seed: u64) -> Topology {
        assert!(d >= 2 && n >= 4);
        let mut rng = Pcg64::new(seed ^ 0xE_9A4D);
        let mut edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        for _ in 1..(d / 2).max(1) {
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            for i in 0..n {
                edges.push((perm[i], perm[(i + 1) % n]));
            }
        }
        Topology::from_edges(n, &edges)
    }

    /// Connected Erdős–Rényi: G(n, p) plus a ring to guarantee
    /// connectivity (deterministic given the seed).
    pub fn erdos_connected(n: usize, p: f64, seed: u64) -> Topology {
        // amb-lint: allow(D3, "stream root: caller-supplied seed is this generator's namespace")
        let mut rng = Pcg64::new(seed);
        let mut edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        for i in 0..n {
            for j in (i + 2)..n {
                if rng.f64() < p {
                    edges.push((i, j));
                }
            }
        }
        Topology::from_edges(n, &edges)
    }

    /// The 10-node fully-distributed experiment topology (App. I.1,
    /// Fig. 2).  Edge set reconstructed so that λ₂(P_metropolis) matches
    /// the paper's reported 0.888 (see module docs); asserted by test
    /// `paper_fig2_lambda2`.
    pub fn paper_fig2() -> Topology {
        // Ring of 10 plus one short chord: λ₂(P_metropolis) = 0.8916,
        // within 0.4% of the paper's reported 0.888.
        Topology::from_edges(
            10,
            &[
                (0, 1), (1, 2), (2, 3), (3, 4), (4, 5),
                (5, 6), (6, 7), (7, 8), (8, 9), (9, 0),
                (0, 3),
            ],
        )
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|l| l.len()).sum::<usize>() / 2
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    /// Graph diameter via BFS from every node (small n).
    pub fn diameter(&self) -> usize {
        let mut diam = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            dist[s] = 0;
            let mut q = std::collections::VecDeque::from([s]);
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        q.push_back(v);
                    }
                }
            }
            // amb-lint: allow(D4, "BFS distance vector is non-empty for n >= 1")
            diam = diam.max(dist.iter().copied().max().unwrap());
        }
        diam
    }

    /// Metropolis–Hastings mixing matrix:
    ///   P_ij = 1 / (1 + max(d_i, d_j))   for (i,j) ∈ E
    ///   P_ii = 1 − Σ_{j≠i} P_ij
    /// Symmetric and doubly stochastic for any graph.
    ///
    /// Built directly in CSR, O(n + E): per row, the off-diagonal sum
    /// runs over the ascending neighbour list — bitwise the dense row
    /// sum, whose interleaved structural zeros were exact additive
    /// identities on the non-negative accumulator — and the diagonal is
    /// emitted at its sorted column slot.  n² is never materialised
    /// (pinned against an in-test dense reference by
    /// `csr_metropolis_matches_dense_reference_bitwise`).
    pub fn metropolis(&self) -> MixMatrix {
        let n = self.n;
        let mut m = MixMatrix::with_capacity(n, 2 * self.edge_count() + n);
        let mut ws: Vec<f64> = Vec::new();
        for i in 0..n {
            let di = self.degree(i);
            ws.clear();
            ws.extend(
                self.adj[i].iter().map(|&j| 1.0 / (1.0 + di.max(self.degree(j)) as f64)),
            );
            let off: f64 = ws.iter().sum();
            m.push_row_with_diag(i, &self.adj[i], &ws, 1.0 - off);
        }
        m
    }

    /// Subgraph induced by the per-node `active` mask, KEEPING the node
    /// indexing: inactive nodes stay in the vertex set but lose every
    /// incident edge (degree 0 ⇒ Metropolis row eᵢ, so they hold their
    /// message bit-for-bit through any number of mixing rounds), while
    /// active nodes keep exactly their active neighbours.  This is the
    /// per-epoch consensus graph of a churn run (DESIGN.md §churn):
    /// `induced(active).metropolis()` is doubly stochastic over all n
    /// rows, so mixing conserves the ACTIVE-set sum — absent nodes
    /// neither receive nor contribute mass.  An all-true mask returns a
    /// graph identical to `self`.
    pub fn induced(&self, active: &[bool]) -> Topology {
        assert_eq!(active.len(), self.n, "active mask must cover every node");
        let adj = (0..self.n)
            .map(|i| {
                if active[i] {
                    self.adj[i].iter().copied().filter(|&j| active[j]).collect()
                } else {
                    Vec::new()
                }
            })
            .collect();
        Topology { n: self.n, adj }
    }

    /// Row `i` of the induced LAZY Metropolis matrix
    /// `induced(active).metropolis().lazy()`, computed in O(deg²)
    /// without materialising the matrix: returns `(P_ii, weights)` with
    /// one weight per ACTIVE neighbour of `i`, in adjacency (ascending)
    /// order.  This is THE induced-weight definition — the threaded
    /// runtime mixes with it per epoch, the simulator builds the full
    /// matrix from the same formula — so the two runtimes cannot drift.
    /// The op sequence replays `metropolis()` + `lazy()` exactly
    /// (unhalved Metropolis weights summed in ascending-j order, then
    /// the (P+I)/2 transform), so the row is BITWISE the materialised
    /// one (pinned by `induced_row_matches_materialised_matrix`).
    /// An inactive `i` gets `(1.0, [])` — the held-message identity row.
    pub fn induced_lazy_metropolis_row(&self, active: &[bool], i: usize) -> (f64, Vec<f64>) {
        assert_eq!(active.len(), self.n, "active mask must cover every node");
        let deg_act =
            |j: usize| -> usize { self.adj[j].iter().filter(|&&k| active[k]).count() };
        if !active[i] {
            return (1.0, Vec::new());
        }
        let di = deg_act(i);
        // metropolis(): w_ij = 1/(1 + max(d_i, d_j)) over induced degrees
        let w_met: Vec<f64> = self.adj[i]
            .iter()
            .filter(|&&j| active[j])
            .map(|&j| 1.0 / (1.0 + di.max(deg_act(j)) as f64))
            .collect();
        let off: f64 = w_met.iter().sum();
        // lazy(): every entry halved, then +0.5 on the diagonal
        let pii = (1.0 - off) * 0.5 + 0.5;
        (pii, w_met.into_iter().map(|x| x * 0.5).collect())
    }

    /// The full induced LAZY Metropolis matrix
    /// `induced(active).metropolis().lazy()` built directly in CSR in
    /// O(n + E): induced degrees are precomputed once, then every row
    /// replays [`Topology::induced_lazy_metropolis_row`]'s op sequence
    /// (itself pinned bitwise against the materialised composition), so
    /// the result is entry-for-entry BITWISE the dense build — without
    /// materialising the induced graph, a dense matrix, or any O(n) row.
    /// This is the churn engine's per-epoch build path
    /// (`consensus::churn::InducedConsensus`): at n = 10⁵ under iid
    /// churn the dense composition cost O(n²) per epoch; this costs
    /// O(edges).  Inactive rows are the identity eᵢ (held messages).
    pub fn induced_metropolis_lazy_csr(&self, active: &[bool]) -> MixMatrix {
        assert_eq!(active.len(), self.n, "active mask must cover every node");
        let n = self.n;
        let deg_act: Vec<usize> = (0..n)
            .map(|i| {
                if active[i] {
                    self.adj[i].iter().filter(|&&k| active[k]).count()
                } else {
                    0
                }
            })
            .collect();
        let mut m = MixMatrix::with_capacity(n, 2 * self.edge_count() + n);
        let mut cols: Vec<usize> = Vec::new();
        let mut ws: Vec<f64> = Vec::new();
        for i in 0..n {
            if !active[i] {
                // induced().metropolis() gives the identity row; lazy()
                // maps it to fl(1.0·0.5) + 0.5 = 1.0 exactly.
                m.push_entry(i, 1.0);
                m.seal_row();
                continue;
            }
            let di = deg_act[i];
            cols.clear();
            ws.clear();
            for &j in &self.adj[i] {
                if active[j] {
                    cols.push(j);
                    ws.push(1.0 / (1.0 + di.max(deg_act[j]) as f64));
                }
            }
            let off: f64 = ws.iter().sum();
            let pii = (1.0 - off) * 0.5 + 0.5;
            for w in ws.iter_mut() {
                *w *= 0.5;
            }
            m.push_row_with_diag(i, &cols, &ws, pii);
        }
        m
    }
}

/// Doubly-stochastic mixing matrix stored sparse-first: CSR over the
/// non-zero entries of each row, in ascending column order, at BOTH
/// precisions — f64 (what the dense representation used to store; feeds
/// `at`, `lazy`, and the spectral diagnostics) and f32 (the exact
/// entries and accumulation order the flat mixing kernel always used,
/// so mixing stays bit-identical to the legacy nested-Vec kernel).
/// Memory scales with edges, never n² — the paper's graphs (ring,
/// torus, small-world, hub-spoke) all have O(n) edges, so this is what
/// lets the consensus plane reach n ≈ 10⁵ (ROADMAP item 2).  Dense is
/// the derived special case via [`MixMatrix::from_rows`].
#[derive(Debug, Clone)]
pub struct MixMatrix {
    n: usize,
    /// Row i's entries live at `nz_ptr[i]..nz_ptr[i+1]`.
    nz_ptr: Vec<usize>,
    /// Ascending column indices (the diagonal sits at its sorted slot).
    nz_cols: Vec<u32>,
    /// f32 kernel weights (filter: entries whose f32 cast is zero are
    /// not stored — the pattern the kernel always skipped).
    nz_w: Vec<f32>,
    /// The same entries at full f64 precision.
    nz_w64: Vec<f64>,
}

impl MixMatrix {
    /// Build from a dense row-major n×n matrix — the dense-interop /
    /// test constructor (dense is now the derived special case; the
    /// Metropolis builders emit CSR directly and never touch n²).
    pub fn from_rows(n: usize, p: Vec<f64>) -> MixMatrix {
        assert_eq!(p.len(), n * n);
        let mut m = MixMatrix::with_capacity(n, 0);
        for i in 0..n {
            for j in 0..n {
                m.push_entry(j, p[i * n + j]);
            }
            m.seal_row();
        }
        m
    }

    /// Empty matrix ready for row-by-row construction.
    fn with_capacity(n: usize, nnz_hint: usize) -> MixMatrix {
        let mut nz_ptr = Vec::with_capacity(n + 1);
        nz_ptr.push(0);
        MixMatrix {
            n,
            nz_ptr,
            nz_cols: Vec::with_capacity(nnz_hint),
            nz_w: Vec::with_capacity(nnz_hint),
            nz_w64: Vec::with_capacity(nnz_hint),
        }
    }

    /// Append one entry to the row under construction.  Columns must
    /// arrive in ascending order (caller's contract); entries whose f32
    /// cast is zero are dropped — the exact filter `from_rows` always
    /// applied, so direct CSR builds match the dense path entry for
    /// entry.
    fn push_entry(&mut self, j: usize, w: f64) {
        let wf = w as f32;
        if wf != 0.0 {
            self.nz_cols.push(j as u32);
            self.nz_w.push(wf);
            self.nz_w64.push(w);
        }
    }

    /// Close the row under construction.
    fn seal_row(&mut self) {
        self.nz_ptr.push(self.nz_cols.len());
    }

    /// Append a row given its off-diagonal entries `(cols[k], ws[k])` in
    /// ascending column order (none equal to `i`), inserting `diag` at
    /// column `i`'s sorted slot.  Seals the row.
    fn push_row_with_diag(&mut self, i: usize, cols: &[usize], ws: &[f64], diag: f64) {
        debug_assert_eq!(cols.len(), ws.len());
        let mut placed = false;
        for (k, &j) in cols.iter().enumerate() {
            if !placed && j > i {
                self.push_entry(i, diag);
                placed = true;
            }
            self.push_entry(j, ws[k]);
        }
        if !placed {
            self.push_entry(i, diag);
        }
        self.seal_row();
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored non-zero count — the memory footprint scales with this,
    /// not n².
    pub fn nnz(&self) -> usize {
        self.nz_cols.len()
    }

    /// Row `i`'s stored pattern: (ascending columns, f32 weights),
    /// index-aligned.  The degraded-mixing kernel walks this directly
    /// so it can substitute sources per entry (fault plane) while
    /// keeping the stock kernel's ascending accumulation order.
    #[inline]
    pub fn row_entries(&self, i: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.nz_ptr[i], self.nz_ptr[i + 1]);
        (&self.nz_cols[lo..hi], &self.nz_w[lo..hi])
    }

    /// Entry (i, j) at f64 precision; structural zeros return 0.0.
    /// Binary search over the row's ascending columns — O(log deg).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        let (lo, hi) = (self.nz_ptr[i], self.nz_ptr[i + 1]);
        match self.nz_cols[lo..hi].binary_search(&(j as u32)) {
            Ok(k) => self.nz_w64[lo + k],
            Err(_) => 0.0,
        }
    }

    /// Lazy (PSD) version: (P + I)/2.  Keeps double stochasticity and
    /// makes all eigenvalues non-negative, matching the paper's PSD
    /// assumption.  Pure pattern-preserving map over the stored entries
    /// (plus a 0.5 diagonal insertion for any row that stored none),
    /// replaying the dense op order — halve every entry, then add 0.5 on
    /// the diagonal — so the result is bitwise the dense composition.
    pub fn lazy(&self) -> MixMatrix {
        let n = self.n;
        let mut m = MixMatrix::with_capacity(n, self.nz_cols.len() + n);
        for i in 0..n {
            let (lo, hi) = (self.nz_ptr[i], self.nz_ptr[i + 1]);
            let mut placed = false;
            for e in lo..hi {
                let j = self.nz_cols[e] as usize;
                let h = self.nz_w64[e] * 0.5;
                if j == i {
                    m.push_entry(i, h + 0.5);
                    placed = true;
                } else {
                    if !placed && j > i {
                        m.push_entry(i, 0.5);
                        placed = true;
                    }
                    m.push_entry(j, h);
                }
            }
            if !placed {
                m.push_entry(i, 0.5);
            }
            m.seal_row();
        }
        m
    }

    /// max |row sum − 1|, max |col sum − 1|, min entry — stochasticity
    /// diagnostics.  Row/column sums accumulate the stored entries in
    /// ascending row/column order (the structural zeros the dense loop
    /// added were exact additive identities); when the pattern is not
    /// full, structural zeros participate in the min.
    pub fn stochasticity_error(&self) -> (f64, f64, f64) {
        let n = self.n;
        let mut row_err = 0.0f64;
        let mut col_sums = vec![0.0f64; n];
        for i in 0..n {
            let (lo, hi) = (self.nz_ptr[i], self.nz_ptr[i + 1]);
            let rs: f64 = self.nz_w64[lo..hi].iter().sum();
            row_err = row_err.max((rs - 1.0).abs());
            for e in lo..hi {
                col_sums[self.nz_cols[e] as usize] += self.nz_w64[e];
            }
        }
        let mut col_err = 0.0f64;
        for &cs in &col_sums {
            col_err = col_err.max((cs - 1.0).abs());
        }
        let mut min_entry = f64::INFINITY;
        for &v in &self.nz_w64 {
            min_entry = min_entry.min(v);
        }
        if self.nz_cols.len() < n * n {
            min_entry = min_entry.min(0.0);
        }
        (row_err, col_err, min_entry)
    }

    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        let (r, c, m) = self.stochasticity_error();
        r < tol && c < tol && m > -tol
    }

    /// Second-largest eigenvalue magnitude via power iteration on P
    /// deflated by the known top eigenpair (λ=1, v=1/√n).  For symmetric
    /// P this converges to |λ₂|; the consensus error contracts by this
    /// factor per round.
    pub fn lambda2(&self) -> f64 {
        let n = self.n;
        if n == 1 {
            return 0.0;
        }
        let mut v: Vec<f64> = (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 - 0.5).collect();
        deflate(&mut v);
        normalize(&mut v);
        let mut lambda = 0.0;
        let mut w = vec![0.0f64; n];
        for _ in 0..2000 {
            // w = P v over the CSR pattern in ascending-column order —
            // the dense loop's op sequence minus its exact-identity
            // zero terms.
            for i in 0..n {
                let mut acc = 0.0;
                let (lo, hi) = (self.nz_ptr[i], self.nz_ptr[i + 1]);
                for e in lo..hi {
                    acc += self.nz_w64[e] * v[self.nz_cols[e] as usize];
                }
                w[i] = acc;
            }
            deflate(&mut w);
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return 0.0;
            }
            let new_lambda = norm; // since v normalized: |P v| ≈ |λ|
            for i in 0..n {
                v[i] = w[i] / norm;
            }
            if (new_lambda - lambda).abs() < 1e-12 {
                return new_lambda;
            }
            lambda = new_lambda;
        }
        lambda
    }

    /// Column-tile width of the flat mixing kernel: 8 KiB of f32 keeps
    /// the output tile pinned in L1 while every source row's matching
    /// tile streams through, and — because all rows share one arena —
    /// an n-row tile block stays L2-resident across output rows, so the
    /// same source tile is never refetched from memory once per edge.
    pub const MIX_TILE: usize = 2048;

    /// One synchronous consensus round over a flat arena:
    /// out.row(i) = Σ_j P_ij · msgs.row(j).
    ///
    /// Blocked sparse row kernel: iterates the precomputed non-zero
    /// pattern only, in ascending-j order per output element — the exact
    /// accumulation order of the old nested-Vec kernel, so results are
    /// bit-identical (pinned by `consensus::tests::flat_kernel_matches_
    /// legacy_nested_vec_bitwise`) — tiles the d axis so the hot working
    /// set fits the cache hierarchy, and fuses four sources per sweep
    /// ([`crate::util::axpy4`]) so the output tile is traversed ~deg/4
    /// times instead of deg times.  Allocation-free.
    ///
    /// Output rows are computed independently, so the round is
    /// row-partitioned across the worker pool
    /// ([`crate::util::pool::par_chunks`]): each worker owns a
    /// contiguous block of output rows while the source arena is shared
    /// read-only.  Per-row op order is untouched, so pooled and serial
    /// rounds are bit-identical (the PR-2 pin holds at any thread
    /// count).
    pub fn mix_into(&self, msgs: &NodeMatrix, out: &mut NodeMatrix) {
        let n = self.n;
        assert_eq!(msgs.n(), n);
        assert_eq!(out.n(), n);
        assert_eq!(msgs.d(), out.d());
        let d = msgs.d();
        if d == 0 {
            return;
        }
        crate::util::pool::par_chunks(out.as_mut_slice(), d, |row0, block| {
            self.mix_rows(msgs, row0, block);
        });
    }

    /// The serial kernel over one contiguous block of output rows
    /// (`block` holds rows `row0..row0 + block.len()/d`).
    fn mix_rows(&self, msgs: &NodeMatrix, row0: usize, block: &mut [f32]) {
        let d = msgs.d();
        let rows = block.len() / d;
        let mut k0 = 0usize;
        loop {
            let k1 = (k0 + Self::MIX_TILE).min(d);
            for r in 0..rows {
                let i = row0 + r;
                let ot = &mut block[r * d + k0..r * d + k1];
                ot.fill(0.0);
                let (lo, hi) = (self.nz_ptr[i], self.nz_ptr[i + 1]);
                accumulate_row_tile(&self.nz_w[lo..hi], &self.nz_cols[lo..hi], msgs, k0, k1, ot);
            }
            if k1 == d {
                break;
            }
            k0 = k1;
        }
    }
}

/// Shared inner kernel of the dense and sparse flat mixers: accumulate
/// one output tile from a compressed row,
///   ot[k] += Σ_e ws[e] · msgs.row(cols[e])[k0 + k],
/// four sources fused per sweep ([`crate::util::axpy4`]); per output
/// element the adds apply in ascending-e order, so the result is
/// bit-identical to applying the sources one at a time.
pub(crate) fn accumulate_row_tile(
    ws: &[f32],
    cols: &[u32],
    msgs: &NodeMatrix,
    k0: usize,
    k1: usize,
    ot: &mut [f32],
) {
    assert_eq!(ws.len(), cols.len());
    let (mut e, hi) = (0usize, ws.len());
    while e + 4 <= hi {
        crate::util::axpy4(
            [ws[e], ws[e + 1], ws[e + 2], ws[e + 3]],
            [
                &msgs.row(cols[e] as usize)[k0..k1],
                &msgs.row(cols[e + 1] as usize)[k0..k1],
                &msgs.row(cols[e + 2] as usize)[k0..k1],
                &msgs.row(cols[e + 3] as usize)[k0..k1],
            ],
            ot,
        );
        e += 4;
    }
    while e < hi {
        crate::util::axpy(ws[e], &msgs.row(cols[e] as usize)[k0..k1], ot);
        e += 1;
    }
}

fn deflate(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

fn normalize(v: &mut [f64]) {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    #[test]
    fn ring_structure() {
        let t = Topology::ring(5);
        assert_eq!(t.n(), 5);
        assert_eq!(t.neighbors(0), &[1, 4]);
        assert_eq!(t.edge_count(), 5);
        assert!(t.is_connected());
        assert_eq!(t.diameter(), 2);
    }

    #[test]
    fn complete_diameter_one() {
        let t = Topology::complete(6);
        assert_eq!(t.edge_count(), 15);
        assert_eq!(t.diameter(), 1);
    }

    #[test]
    fn grid_structure() {
        let t = Topology::grid(2, 3);
        assert_eq!(t.n(), 6);
        assert_eq!(t.edge_count(), 7);
        assert!(t.is_connected());
    }

    #[test]
    fn hub_spoke_star() {
        let t = Topology::hub_spoke(19);
        assert_eq!(t.n(), 20);
        assert_eq!(t.degree(0), 19);
        for w in 1..20 {
            assert_eq!(t.neighbors(w), &[0]);
        }
        assert_eq!(t.diameter(), 2);
    }

    #[test]
    fn small_world_connected_and_shortcuts_cut_diameter() {
        forall(15, 0x70_03, |g| {
            let n = g.usize_in(12, 40);
            let t = Topology::small_world(n, 2, 0.3, g.u64());
            crate::prop_assert!(t.is_connected());
            crate::prop_assert!(t.metropolis().is_doubly_stochastic(1e-9));
            Ok(())
        });
        // beta=1 (all chords random) has smaller diameter than beta=0
        let lattice = Topology::small_world(40, 2, 0.0, 1);
        let random = Topology::small_world(40, 2, 1.0, 1);
        assert!(random.diameter() <= lattice.diameter());
    }

    #[test]
    fn expander_lambda2_beats_ring_at_scale() {
        let ring = Topology::ring(64).metropolis().lambda2();
        let exp = Topology::expander(64, 6, 2).metropolis().lambda2();
        assert!(exp < ring, "expander {exp} vs ring {ring}");
        let t = Topology::expander(64, 6, 2);
        assert!(t.is_connected());
        assert!(t.metropolis().is_doubly_stochastic(1e-9));
    }

    #[test]
    fn disconnected_detected() {
        let t = Topology::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!t.is_connected());
    }

    #[test]
    fn from_edges_dedups_both_orientations_and_sorts() {
        // duplicates in both orientations plus self-loops collapse to the
        // clean sorted adjacency (the sort+dedup path must agree with the
        // old per-insert contains() scan).
        let t = Topology::from_edges(
            4,
            &[(0, 1), (1, 0), (0, 1), (2, 2), (3, 0), (0, 3), (1, 2), (2, 1), (1, 2)],
        );
        assert_eq!(t.neighbors(0), &[1, 3]);
        assert_eq!(t.neighbors(1), &[0, 2]);
        assert_eq!(t.neighbors(2), &[1]);
        assert_eq!(t.neighbors(3), &[0]);
        assert_eq!(t.edge_count(), 3);
        // and matches a duplicate-free build exactly
        let clean = Topology::from_edges(4, &[(0, 1), (0, 3), (1, 2)]);
        for i in 0..4 {
            assert_eq!(t.neighbors(i), clean.neighbors(i));
        }
    }

    #[test]
    fn small_world_accepts_documented_minimum() {
        // n = 4, k = 2 (chords to the antipode) was rejected by the old
        // `k < n/2` assert; it is a valid Watts–Strogatz lattice (= K4 at
        // beta = 0).
        let t = Topology::small_world(4, 2, 0.0, 1);
        assert!(t.is_connected());
        assert_eq!(t.edge_count(), 6, "beta=0, n=4, k=2 is the complete graph");
        assert!(t.metropolis().is_doubly_stochastic(1e-9));
        // antipodal chords are enumerated once at 2k == n
        let t6 = Topology::small_world(6, 3, 0.0, 1);
        assert!(t6.is_connected());
        assert_eq!(t6.edge_count(), 6 * 5 / 2);
        // ... and with beta > 0 at 2k == n (each antipodal chord draws
        // exactly ONE rewire — see the `starts` bound in small_world):
        // the graph stays connected and its mixing matrix valid.
        for s in 0..50u64 {
            let t = Topology::small_world(20, 10, 0.7, s);
            assert!(t.is_connected());
            assert!(t.metropolis().is_doubly_stochastic(1e-9));
        }
    }

    #[test]
    fn induced_isolates_inactive_and_keeps_active_subgraph() {
        let t = Topology::paper_fig2();
        let mut active = vec![true; 10];
        active[3] = false;
        active[7] = false;
        let s = t.induced(&active);
        assert_eq!(s.n(), 10);
        assert_eq!(s.degree(3), 0);
        assert_eq!(s.degree(7), 0);
        for i in 0..10 {
            for &j in s.neighbors(i) {
                assert!(active[i] && active[j], "edge ({i},{j}) touches an inactive node");
                assert!(t.neighbors(i).contains(&j), "induced invented edge ({i},{j})");
            }
        }
        // active nodes keep exactly their active neighbours
        for i in 0..10 {
            if active[i] {
                let want: Vec<usize> =
                    t.neighbors(i).iter().copied().filter(|&j| active[j]).collect();
                assert_eq!(s.neighbors(i), &want[..]);
            }
        }
        // all-active mask is the identity
        let full = t.induced(&vec![true; 10]);
        for i in 0..10 {
            assert_eq!(full.neighbors(i), t.neighbors(i));
        }
    }

    #[test]
    fn induced_row_matches_materialised_matrix() {
        // The O(deg²) per-row helper the threaded runtime mixes with
        // must be BITWISE the row of the full induced lazy matrix the
        // simulator builds — same formula, same op order.
        forall(25, 0x70_06, |g| {
            let n = g.usize_in(2, 16);
            let t = Topology::erdos_connected(n, g.f64_in(0.1, 0.7), g.u64());
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
            let m = t.induced(&active).metropolis().lazy();
            for i in 0..n {
                let (pii, w) = t.induced_lazy_metropolis_row(&active, i);
                crate::prop_assert!(
                    pii.to_bits() == m.at(i, i).to_bits(),
                    "diag {i}: helper {pii} vs matrix {}",
                    m.at(i, i)
                );
                let mut e = 0usize;
                for &j in t.neighbors(i) {
                    if active[i] && active[j] {
                        crate::prop_assert!(
                            w[e].to_bits() == m.at(i, j).to_bits(),
                            "({i},{j}): helper {} vs matrix {}",
                            w[e],
                            m.at(i, j)
                        );
                        e += 1;
                    }
                }
                crate::prop_assert!(e == w.len(), "row {i}: weight count mismatch");
            }
            Ok(())
        });
    }

    // The induced-Metropolis doubly-stochastic / inactive-row-isolation
    // property moved to the central `crate::prop::domain_props` suite,
    // where it runs over random topology FAMILIES × random active sets.

    /// Reference implementation of the pre-sparse dense Metropolis
    /// build: full n² row-major matrix, off-diagonal sums taken over the
    /// whole row including structural zeros.  The CSR-direct build must
    /// reproduce it bitwise.
    fn dense_metropolis_reference(t: &Topology) -> MixMatrix {
        let n = t.n();
        let mut p = vec![0.0f64; n * n];
        for i in 0..n {
            for &j in t.neighbors(i) {
                p[i * n + j] = 1.0 / (1.0 + t.degree(i).max(t.degree(j)) as f64);
            }
        }
        for i in 0..n {
            let off: f64 = (0..n).filter(|&j| j != i).map(|j| p[i * n + j]).sum();
            p[i * n + i] = 1.0 - off;
        }
        MixMatrix::from_rows(n, p)
    }

    #[test]
    fn csr_metropolis_matches_dense_reference_bitwise() {
        forall(25, 0x70_07, |g| {
            let n = g.usize_in(2, 24);
            let t = Topology::erdos_connected(n, g.f64_in(0.05, 0.9), g.u64());
            let direct = t.metropolis();
            let dense = dense_metropolis_reference(&t);
            crate::prop_assert!(direct.nnz() == dense.nnz(), "nnz {} vs {}", direct.nnz(), dense.nnz());
            for i in 0..n {
                for j in 0..n {
                    crate::prop_assert!(
                        direct.at(i, j).to_bits() == dense.at(i, j).to_bits(),
                        "({i},{j}): direct {} vs dense {}",
                        direct.at(i, j),
                        dense.at(i, j)
                    );
                }
            }
            // ... and the lazy transform composes identically.
            let dl = direct.lazy();
            let rl = dense.lazy();
            for i in 0..n {
                for j in 0..n {
                    crate::prop_assert!(dl.at(i, j).to_bits() == rl.at(i, j).to_bits());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn induced_lazy_csr_matches_dense_composition_bitwise() {
        // The O(n+E) churn build path must be entry-for-entry bitwise
        // the three-step dense composition it replaces.
        forall(25, 0x70_08, |g| {
            let n = g.usize_in(2, 20);
            let t = Topology::erdos_connected(n, g.f64_in(0.1, 0.7), g.u64());
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
            let fast = t.induced_metropolis_lazy_csr(&active);
            let slow = t.induced(&active).metropolis().lazy();
            crate::prop_assert!(fast.nnz() == slow.nnz(), "nnz {} vs {}", fast.nnz(), slow.nnz());
            for i in 0..n {
                for j in 0..n {
                    crate::prop_assert!(
                        fast.at(i, j).to_bits() == slow.at(i, j).to_bits(),
                        "({i},{j}): fast {} vs slow {}",
                        fast.at(i, j),
                        slow.at(i, j)
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mix_matrix_memory_scales_with_edges_not_n_squared() {
        // ring: every row stores 2 neighbours + the diagonal.
        let n = 4096;
        let m = Topology::ring(n).metropolis();
        assert_eq!(m.nnz(), 3 * n);
        assert_eq!(m.lazy().nnz(), 3 * n);
        // small-world stays O(n·k), nowhere near n².
        let sw = Topology::small_world(n, 3, 0.1, 7).metropolis();
        assert!(sw.nnz() <= n * (2 * 3 + 1) + n, "nnz {}", sw.nnz());
    }

    #[test]
    fn metropolis_doubly_stochastic_on_many_graphs() {
        forall(40, 0x70_01, |g| {
            let n = g.usize_in(2, 24);
            let p = g.f64_in(0.05, 0.9);
            let t = Topology::erdos_connected(n, p, g.u64());
            let m = t.metropolis();
            crate::prop_assert!(m.is_doubly_stochastic(1e-9));
            // symmetry
            for i in 0..n {
                for j in 0..n {
                    crate::prop_assert!((m.at(i, j) - m.at(j, i)).abs() < 1e-12);
                }
            }
            // sparsity pattern consistent with G
            for i in 0..n {
                for j in 0..n {
                    if i != j && m.at(i, j) > 0.0 {
                        crate::prop_assert!(t.neighbors(i).contains(&j));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lambda2_known_values() {
        // Complete graph metropolis: P = (1/n) J exactly? With metropolis
        // weights P_ij = 1/n for i≠j, P_ii = 1/n as well -> lambda2 = 0.
        let m = Topology::complete(8).metropolis();
        assert!(m.lambda2() < 1e-9, "lambda2={}", m.lambda2());
        // Ring lambda2 grows towards 1 with n.
        let l6 = Topology::ring(6).metropolis().lambda2();
        let l20 = Topology::ring(20).metropolis().lambda2();
        assert!(l6 < l20 && l20 < 1.0);
    }

    #[test]
    fn lambda2_two_node_exact() {
        // n=2: P = [[1/2,1/2],[1/2,1/2]] -> eigenvalues {1, 0}.
        let m = Topology::ring2().metropolis();
        assert!(m.lambda2().abs() < 1e-9);
    }

    #[test]
    fn paper_fig2_lambda2() {
        let t = Topology::paper_fig2();
        assert_eq!(t.n(), 10);
        assert!(t.is_connected());
        let l2 = t.metropolis().lambda2();
        // Paper App. I.1 reports 0.888 for their (unpublished) edge set;
        // our reconstruction must land close so consensus speed matches.
        assert!((l2 - 0.888).abs() < 0.01, "lambda2={l2}");
    }

    #[test]
    fn lazy_is_psd_stochastic() {
        let m = Topology::ring(9).metropolis().lazy();
        assert!(m.is_doubly_stochastic(1e-9));
        // lazy halves the spectral gap but keeps contraction < 1
        let l2 = m.lambda2();
        assert!(l2 < 1.0 && l2 > 0.0);
    }

    #[test]
    fn mix_preserves_mean_and_contracts() {
        forall(25, 0x70_02, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 16);
            let t = Topology::erdos_connected(n, 0.4, g.u64());
            let m = t.metropolis();
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal_f32(d, 2.0)).collect();
            let msgs = NodeMatrix::from_rows(&rows);
            let mean = msgs.mean_rows_f64().unwrap();
            let mut out = NodeMatrix::new(n, d);
            m.mix_into(&msgs, &mut out);
            // conservation
            let mean2 = out.mean_rows_f64().unwrap();
            for k in 0..d {
                crate::prop_assert!((mean[k] - mean2[k]).abs() < 1e-3);
            }
            // contraction: max deviation must not grow
            let dev = |ms: &NodeMatrix| -> f64 {
                let mut worst = 0.0f64;
                for msg in ms.rows() {
                    let mut ss = 0.0f64;
                    for k in 0..d {
                        let diff = msg[k] as f64 - mean[k];
                        ss += diff * diff;
                    }
                    worst = worst.max(ss.sqrt());
                }
                worst
            };
            crate::prop_assert!(dev(&out) <= dev(&msgs) * (1.0 + 1e-6));
            Ok(())
        });
    }

    #[test]
    fn mix_tiling_boundary_matches_untiled_expectation() {
        // d straddling the tile width must give the same result as the
        // per-element definition out[i][k] = Σ_j P_ij m[j][k].
        let t = Topology::ring(5);
        let m = t.metropolis().lazy();
        let d = MixMatrix::MIX_TILE + 3;
        let mut g = crate::prop::Gen::new(0x70_04);
        let rows: Vec<Vec<f32>> = (0..5).map(|_| g.vec_normal_f32(d, 1.0)).collect();
        let msgs = NodeMatrix::from_rows(&rows);
        let mut out = NodeMatrix::new(5, d);
        m.mix_into(&msgs, &mut out);
        for i in 0..5 {
            for &k in &[0usize, MixMatrix::MIX_TILE - 1, MixMatrix::MIX_TILE, d - 1] {
                let mut want = 0.0f32;
                for j in 0..5 {
                    let pij = m.at(i, j) as f32;
                    if pij != 0.0 {
                        want += pij * rows[j][k];
                    }
                }
                assert_eq!(out.row(i)[k], want, "({i},{k})");
            }
        }
    }
}

impl Topology {
    /// Two-node path (test helper; `ring` requires n>=2 but produces a
    /// double edge for n=2, which from_edges dedups — this is explicit).
    pub fn ring2() -> Topology {
        Topology::from_edges(2, &[(0, 1)])
    }
}
