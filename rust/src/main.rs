//! `amb` — CLI for the Anytime Minibatch reproduction.
//!
//! Subcommands:
//!   figures   regenerate paper figures (CSV into results/) and print the
//!             paper-vs-measured report
//!   run       one AMB/FMB/backup/coded run with explicit parameters on
//!             either runtime (--runtime sim|threaded)
//!   train     end-to-end threaded AMB run (transformer LM via PJRT
//!             artifacts, or native linreg)
//!   info      artifact manifest + topology diagnostics
//!
//! Examples:
//!   amb figures --fig all
//!   amb figures --fig f1a --pjrt
//!   amb run --scheme amb --workload linreg --nodes 10 --epochs 25 \
//!       --t-compute 14.5 --t-consensus 4.5 --rounds 5 --out run.csv
//!   amb run --scheme fmb-coded --ignore 2 --runtime threaded \
//!       --t-compute 0.5 --t-consensus 0.2 --time-scale 1.0
//!   amb run --scheme amb-dg:12:3:1 --workload linreg --nodes 10 --epochs 24
//!   amb dg --quick
//!   amb train --epochs 40 --t-compute 0.5 --t-consensus 0.2
//!   amb info

#![forbid(unsafe_code)]
#![deny(clippy::dbg_macro, clippy::todo, clippy::unimplemented, clippy::mem_forget)]

use std::path::Path;
use std::process::ExitCode;

use anytime_mb::coordinator::{ConsensusMode, RunSpec, RuntimeKind, Scheme, GOSSIP_UNTIL_DEADLINE};
use anytime_mb::experiments::{self, Backend, Ctx};
use anytime_mb::straggler::{InducedGroups, PauseModel, ShiftedExp, StragglerModel};
use anytime_mb::topology::Topology;
use anytime_mb::util::cli::Args;
use anytime_mb::ThreadedRuntime;

fn main() -> ExitCode {
    let args = Args::from_env();
    // Pool sizing first: `--threads N` beats AMB_THREADS beats detected
    // cores (util::pool), and applies to every subcommand (`run` and
    // `figures` are the documented consumers).
    match anytime_mb::util::cli::threads_arg(&args) {
        Ok(Some(t)) => anytime_mb::util::pool::set_threads(t),
        Ok(None) => {}
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    }
    let res = match args.subcommand() {
        Some("figures") => cmd_figures(&args),
        Some("ablations") => cmd_ablations(&args),
        Some("churn") => cmd_churn(&args),
        Some("faults") => cmd_faults(&args),
        Some("dg") => cmd_dg(&args),
        Some("run") => cmd_run(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            return ExitCode::from(2);
        }
    };
    match res {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    eprintln!(
        "amb — Anytime Minibatch (ICLR 2019) reproduction\n\
         \n\
         usage: amb <figures|ablations|churn|faults|dg|run|train|info> [options]\n\
         \n\
         figures --fig <id|all> [--out-dir results] [--pjrt] [--quick] [--seed N]\n\
         \u{20}       [--runtime sim|threaded] [--time-scale S] [--threads N]\n\
         churn   elastic-membership sweep (dropout x topology x scheme);\n\
         \u{20}       same options as figures\n\
         faults  resilience sweep (packet loss x link flaps x scheme):\n\
         \u{20}       time-to-target + conservation drift; same options as figures\n\
         dg      pipelined delayed-gradient sweep: wall-time AMB vs AMB-DG vs FMB\n\
         \u{20}       under the fig-6 straggler profile, delay D in {0,1,2,4};\n\
         \u{20}       same options as figures\n\
         run     --scheme <amb|fmb|fmb-backup|fmb-coded|amb-dg[:T:Tc:D]>\n\
         \u{20}       --workload <linreg|logreg>\n\
         \u{20}       [--runtime sim|threaded] [--nodes N] [--epochs N]\n\
         \u{20}       [--t-compute S] [--t-consensus S] [--rounds R] [--exact-consensus]\n\
         \u{20}       [--shards S [--intra R] [--inter R]] (hierarchical consensus, sim only)\n\
         \u{20}       [--topology <ring|small-world|expander|erdos|fig2>]\n\
         \u{20}       [--per-node-batch B] [--ignore K] [--delay D]\n\
         \u{20}       [--straggler <shiftedexp|induced|pause|none>]\n\
         \u{20}       [--churn <none|iid:P[:SEED]|markov:PDOWN:PUP[:SEED]>]\n\
         \u{20}       [--net <abstract|ideal|lat=S,bw=B[,wan-lat=S,wan-bw=B,groups=G,gap=S]>]\n\
         \u{20}       [--faults <loss=P,flap=PD:PU,crash=N@F..T,timeout=S,seed=N>]\n\
         \u{20}       [--grad-chunk C] [--slowdown f1,f2,...] [--time-scale S]\n\
         \u{20}       [--pjrt] [--seed N] [--threads N] [--out FILE.csv]\n\
         train   [--workload <transformer|linreg>] [--nodes N] [--epochs N]\n\
         \u{20}       [--t-compute S] [--t-consensus S] [--grad-chunk C]\n\
         \u{20}       [--slowdown f1,f2,...] [--artifacts DIR] [--out FILE.csv]\n\
         info    [--artifacts DIR]\n\
         \n\
         --threads N sizes the worker pool (sim epoch fan-out, consensus\n\
         kernels, figure sweeps); precedence: --threads > AMB_THREADS >\n\
         detected cores.  Results are bit-identical at any thread count."
    );
}

fn backend(args: &Args) -> Backend {
    if args.flag("pjrt") {
        Backend::Pjrt(
            args.get("artifacts")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(anytime_mb::artifacts_dir),
        )
    } else {
        Backend::Native
    }
}

fn runtime_kind(args: &Args) -> anyhow::Result<RuntimeKind> {
    let s = args.str_or("runtime", "sim");
    RuntimeKind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown runtime '{s}' (sim|threaded)"))
}

fn harness_ctx(args: &Args) -> anyhow::Result<Ctx> {
    let out_dir = std::path::PathBuf::from(args.str_or("out-dir", anytime_mb::RESULTS_DIR));
    std::fs::create_dir_all(&out_dir)?;
    Ctx::from_args(&out_dir, args)
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let ctx = harness_ctx(args)?;
    let fig = args.str_or("fig", "all");
    let reports = if fig == "all" {
        experiments::run_all(&ctx)?
    } else {
        vec![experiments::run_one(&ctx, fig)?]
    };
    let mut bad = 0;
    for r in &reports {
        println!("{r}");
        bad += (!r.shape_holds) as usize;
    }
    println!(
        "{}/{} figures reproduce the paper's shape",
        reports.len() - bad,
        reports.len()
    );
    anyhow::ensure!(bad == 0, "{bad} figure(s) diverged from the paper's shape");
    Ok(())
}

fn cmd_ablations(args: &Args) -> anyhow::Result<()> {
    let ctx = harness_ctx(args)?;
    let reports = experiments::ablations::run_all(&ctx)?;
    let mut bad = 0;
    for r in &reports {
        println!("{r}");
        bad += (!r.shape_holds) as usize;
    }
    anyhow::ensure!(bad == 0, "{bad} ablation(s) diverged");
    Ok(())
}

fn cmd_churn(args: &Args) -> anyhow::Result<()> {
    let ctx = harness_ctx(args)?;
    let report = experiments::churn::churn(&ctx)?;
    println!("{report}");
    anyhow::ensure!(report.shape_holds, "churn harness diverged");
    Ok(())
}

fn cmd_faults(args: &Args) -> anyhow::Result<()> {
    let ctx = harness_ctx(args)?;
    let report = experiments::faults::faults(&ctx)?;
    println!("{report}");
    anyhow::ensure!(report.shape_holds, "fault harness diverged");
    Ok(())
}

fn cmd_dg(args: &Args) -> anyhow::Result<()> {
    let ctx = harness_ctx(args)?;
    let report = experiments::dg::dg(&ctx)?;
    println!("{report}");
    anyhow::ensure!(report.shape_holds, "AMB-DG harness diverged");
    Ok(())
}

/// Parse the compact AMB-DG scheme syntax `amb-dg:T:Tc:D`.
fn parse_amb_dg(s: &str) -> anyhow::Result<Scheme> {
    // amb-lint: allow(D4, "caller matched the amb-dg: prefix before dispatching here")
    let rest = s.strip_prefix("amb-dg:").expect("caller matched the prefix");
    let parts: Vec<&str> = rest.split(':').collect();
    anyhow::ensure!(
        parts.len() == 3,
        "--scheme amb-dg:T:Tc:D takes exactly three parameters (got '{s}')"
    );
    let t_compute: f64 = parts[0]
        .parse()
        .map_err(|_| anyhow::anyhow!("amb-dg: invalid T '{}'", parts[0]))?;
    let t_consensus: f64 = parts[1]
        .parse()
        .map_err(|_| anyhow::anyhow!("amb-dg: invalid Tc '{}'", parts[1]))?;
    let delay: usize = parts[2]
        .parse()
        .map_err(|_| anyhow::anyhow!("amb-dg: invalid delay '{}'", parts[2]))?;
    anyhow::ensure!(
        t_compute > 0.0 && t_consensus > 0.0,
        "amb-dg windows must be positive (got T={t_compute}, Tc={t_consensus})"
    );
    Ok(Scheme::AmbDg { t_compute, t_consensus, delay })
}

fn parse_slowdown(args: &Args) -> anyhow::Result<Vec<f64>> {
    match args.get("slowdown") {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(|v| -> anyhow::Result<f64> {
                let f: f64 = v.trim().parse().map_err(|_| {
                    anyhow::anyhow!(
                        "invalid --slowdown factor '{v}' (expected comma-separated floats)"
                    )
                })?;
                anyhow::ensure!(
                    f.is_finite() && f >= 1.0,
                    "--slowdown factors must be ≥ 1.0 (got {f})"
                );
                Ok(f)
            })
            .collect(),
    }
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let nodes = args.usize_or("nodes", 10)?;
    let epochs = args.usize_or("epochs", 20)?;
    let rounds = args.usize_or("rounds", 5)?;
    let t_compute = args.f64_or("t-compute", 14.5)?;
    let t_consensus = args.f64_or("t-consensus", 4.5)?;
    let per_node_batch = args.usize_or("per-node-batch", 600)?;
    let ignore = args.usize_or("ignore", 1)?;
    let seed = args.u64_or("seed", 42)?;

    // --topology picks the graph family explicitly; the default keeps the
    // historical behaviour (fig-2 at n=10, Erdős–Rényi otherwise).  The
    // sparse families (ring/small-world/expander) are O(n·k) to build and
    // the intended choice at large --nodes — erdos is O(n²) edge sampling.
    let topo = match args.get("topology") {
        None => {
            if nodes == 10 {
                Topology::paper_fig2()
            } else {
                Topology::erdos_connected(nodes, 0.3, seed ^ 0x70)
            }
        }
        Some("fig2") => {
            anyhow::ensure!(nodes == 10, "--topology fig2 has intrinsic n=10 (got --nodes {nodes})");
            Topology::paper_fig2()
        }
        Some("ring") => Topology::ring(nodes),
        Some("small-world") => Topology::small_world(nodes, 3, 0.1, seed ^ 0x70),
        Some("expander") => Topology::expander(nodes, 6, seed ^ 0x70),
        Some("erdos") => Topology::erdos_connected(nodes, 0.3, seed ^ 0x70),
        Some(other) => {
            anyhow::bail!("unknown topology '{other}' (ring|small-world|expander|erdos|fig2)")
        }
    };

    let source = match args.str_or("workload", "linreg") {
        "linreg" => experiments::linreg_source(seed),
        "logreg" => experiments::mnist_source(seed),
        other => anyhow::bail!("unknown workload '{other}'"),
    };

    let strag: Box<dyn StragglerModel> = match args.str_or("straggler", "shiftedexp") {
        "shiftedexp" => Box::new(ShiftedExp {
            zeta: args.f64_or("zeta", 1.0)?,
            lambda: args.f64_or("lambda", 2.0 / 3.0)?,
            unit_batch: per_node_batch,
        }),
        "induced" => {
            let m = InducedGroups::paper_i3();
            anyhow::ensure!(
                nodes == m.n(),
                "--straggler induced has intrinsic n={} (got --nodes {nodes})",
                m.n()
            );
            Box::new(m)
        }
        "pause" => {
            let m = PauseModel::paper_i4();
            anyhow::ensure!(
                nodes == m.n(),
                "--straggler pause has intrinsic n={} (got --nodes {nodes})",
                m.n()
            );
            Box::new(m)
        }
        "none" => Box::new(anytime_mb::straggler::Deterministic {
            unit_time: args.f64_or("unit-time", 1.0)?,
            unit_batch: per_node_batch,
        }),
        other => anyhow::bail!("unknown straggler model '{other}'"),
    };

    let scheme = match args.str_or("scheme", "amb") {
        "amb" => Scheme::Amb { t_compute, t_consensus },
        "fmb" => Scheme::Fmb { per_node_batch, t_consensus },
        "fmb-backup" => Scheme::FmbBackup { per_node_batch, t_consensus, ignore, coded: false },
        "fmb-coded" => Scheme::FmbBackup { per_node_batch, t_consensus, ignore, coded: true },
        // Pipelined delayed gradients: `amb-dg` takes the windows from
        // --t-compute/--t-consensus and the staleness from --delay
        // (default 1); the compact `amb-dg:T:Tc:D` spells out all three.
        "amb-dg" => Scheme::AmbDg { t_compute, t_consensus, delay: args.usize_or("delay", 1)? },
        s if s.starts_with("amb-dg:") => parse_amb_dg(s)?,
        other => anyhow::bail!("unknown scheme '{other}'"),
    };
    let consensus = if args.get("shards").is_some() {
        anyhow::ensure!(
            !args.flag("exact-consensus"),
            "--shards selects hierarchical consensus; drop --exact-consensus"
        );
        let shards = args.usize_or("shards", 1)?;
        anyhow::ensure!(shards >= 1, "--shards must be >= 1");
        // intra budget defaults to --rounds so `--shards S` alone mirrors
        // the flat gossip budget inside each shard.
        ConsensusMode::Hierarchical {
            shards,
            intra_rounds: args.usize_or("intra", rounds)?,
            inter_rounds: args.usize_or("inter", 3)?,
        }
    } else if args.flag("exact-consensus") {
        ConsensusMode::Exact
    } else {
        ConsensusMode::Gossip { rounds }
    };
    let churn = match args.get("churn") {
        None => anytime_mb::ChurnSpec::None,
        Some(s) => anytime_mb::ChurnSpec::parse(s, seed)?,
    };
    let network = match args.get("net") {
        None => anytime_mb::NetworkModel::Abstract,
        Some(s) => anytime_mb::NetworkModel::parse(s)?,
    };
    let faults = match args.get("faults") {
        None => anytime_mb::FaultSpec::none(),
        Some(s) => anytime_mb::FaultSpec::parse(s, seed)?,
    };
    let spec = RunSpec::new(scheme.name(), scheme, epochs, seed)
        .with_consensus(consensus)
        .with_grad_chunk(args.usize_or("grad-chunk", 16)?)
        .with_slowdown(parse_slowdown(args)?)
        .with_churn(churn)
        .with_network(network)
        .with_faults(faults);

    let expected_batch = (nodes * per_node_batch) as f64;
    let opt = experiments::optimizer_for(&source, expected_batch);

    let mut ctx = Ctx::native(Path::new(".")).with_runtime(runtime_kind(args)?);
    ctx.backend = backend(args);
    ctx.seed = seed;
    // Unlike `figures` (paper-unit windows, 0.01 threaded default),
    // `run` takes explicit --t-compute/--t-consensus, so seconds mean
    // seconds unless the user scales them.
    ctx.time_scale = args.f64_or("time-scale", 1.0)?;
    anyhow::ensure!(ctx.time_scale > 0.0, "--time-scale must be positive");
    let out = ctx.run(&spec, &topo, &*strag, &source, &opt)?;

    println!(
        "# runtime={} scheme={} consensus={:?} churn={} net={} faults={}",
        ctx.runtime.name(),
        spec.scheme.name(),
        spec.consensus,
        spec.churn.name(),
        spec.network.name(),
        spec.faults.label()
    );
    println!(
        "{:<6} {:>10} {:>8} {:>12} {:>12} {:>12}",
        "epoch", "wall_time", "batch", "loss", "error", "cons_err"
    );
    for e in &out.record.epochs {
        println!(
            "{:<6} {:>10.2} {:>8} {:>12.5e} {:>12.5e} {:>12.3e}",
            e.epoch, e.wall_time, e.batch, e.loss, e.error, e.consensus_err
        );
    }
    println!("summary: {}", out.record.summary_json());
    if let Some(path) = args.get("out") {
        out.record.save_csv(Path::new(path))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let epochs = args.usize_or("epochs", 30)?;
    let t_compute = args.f64_or("t-compute", 0.5)?;
    let t_consensus = args.f64_or("t-consensus", 0.2)?;
    let seed = args.u64_or("seed", 42)?;
    let nodes = args.usize_or("nodes", 4)?;
    let grad_chunk = args.usize_or("grad-chunk", 8)?;
    let slowdown = parse_slowdown(args)?;
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(anytime_mb::artifacts_dir);

    let topo = Topology::ring(nodes.max(2));
    // As many gossip rounds as fit in T_c (the pre-unification threaded
    // behaviour); epochs land on the absolute real-time schedule.
    let spec = RunSpec::amb("amb-train", t_compute, t_consensus, GOSSIP_UNTIL_DEADLINE, epochs, seed)
        .with_grad_chunk(grad_chunk)
        .with_slowdown(slowdown)
        .with_node_log();

    let workload = args.str_or("workload", "transformer").to_string();
    let out = match workload.as_str() {
        "transformer" => {
            use anytime_mb::data::TokenStream;
            use anytime_mb::optim::{BetaSchedule, DualAveraging};
            use anytime_mb::runtime::{PjrtRuntime, TransformerExec};
            use std::sync::Arc;

            // Probe the manifest once for sizes (threads re-load privately).
            let probe = anytime_mb::runtime::Manifest::load(&artifacts)?;
            println!(
                "transformer: {} params, vocab {}, seq {}, artifact batch {}",
                probe.transformer.param_count,
                probe.transformer.vocab,
                probe.transformer.seq_len,
                probe.transformer.batch
            );
            let spec = spec.with_grad_chunk(probe.transformer.batch);
            let tokens = Arc::new(TokenStream::new(probe.transformer.vocab, seed ^ 0x70_6B));
            let dir = artifacts.clone();
            let opt = DualAveraging::new(
                BetaSchedule::new(args.f64_or("beta-k", 1.0)?, args.f64_or("beta-mu", 50.0)?),
                args.f64_or("radius", 1000.0)?,
            );
            let mk = move |_i: usize| -> Box<dyn anytime_mb::exec::ExecEngine> {
                // amb-lint: allow(D4, "CLI startup: missing artifacts are fatal with an actionable message")
                let rt = PjrtRuntime::load_shared(&dir).expect("load artifacts");
                Box::new(
                    TransformerExec::new(rt, tokens.clone(), opt.clone())
                        // amb-lint: allow(D4, "CLI startup: missing artifacts are fatal with an actionable message")
                        .expect("transformer exec"),
                )
            };
            anytime_mb::run(&ThreadedRuntime, &spec, &topo, &mk, None)?
        }
        "linreg" => {
            use anytime_mb::exec::NativeExec;
            let source = experiments::linreg_source(seed);
            let opt = experiments::optimizer_for(&source, 5000.0);
            let f_star = source.f_star();
            let src = source.clone();
            let mk = move |_i: usize| -> Box<dyn anytime_mb::exec::ExecEngine> {
                Box::new(NativeExec::new(src.clone(), opt.clone()))
            };
            anytime_mb::run(&ThreadedRuntime, &spec, &topo, &mk, f_star)?
        }
        other => anyhow::bail!("unknown train workload '{other}'"),
    };

    println!(
        "{:<6} {:>10} {:>8} {:>14} {:>12}",
        "epoch", "wall_time", "batch", "loss/sample", "error"
    );
    for e in &out.record.epochs {
        println!(
            "{:<6} {:>10.2} {:>8} {:>14.5} {:>12.5}",
            e.epoch, e.wall_time, e.batch, e.loss, e.error
        );
    }
    if let Some(path) = args.get("out") {
        out.record.save_csv(Path::new(path))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let topo = Topology::paper_fig2();
    let p = topo.metropolis();
    println!(
        "paper Fig-2 topology: n={} edges={} diameter={}",
        topo.n(),
        topo.edge_count(),
        topo.diameter()
    );
    println!("  lambda2(P) = {:.4} (paper: 0.888)", p.lambda2());
    println!("  lambda2(lazy P) = {:.4}", p.lazy().lambda2());

    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(anytime_mb::artifacts_dir);
    match anytime_mb::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "artifacts @ {}: {} entries (small={})",
                dir.display(),
                m.entries.len(),
                m.small
            );
            for (name, e) in &m.entries {
                println!(
                    "  {name}: {} inputs, {} outputs, file {}",
                    e.inputs.len(),
                    e.outputs.len(),
                    // amb-lint: allow(D4, "walked directory entries always carry a file name")
                    e.file.file_name().unwrap().to_string_lossy()
                );
            }
        }
        Err(e) => println!("artifacts @ {}: unavailable ({e})", dir.display()),
    }
    Ok(())
}
