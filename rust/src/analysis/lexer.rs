//! Comment/string-aware Rust tokenizer for `amb-lint`.
//!
//! Hand-rolled in the `util::pool` dependency-free style: no syn, no
//! proc-macro2, no crates.io.  The lint rules (see [`super::rules`]) only
//! need a *lexical* view of the source — identifiers, punctuation, and
//! literals with accurate line/column spans, with comments lexed
//! separately so suppression directives can be read and so the word
//! `unsafe` inside a doc comment or a string literal never trips D5.
//!
//! Supported surface (everything this repository uses, plus the common
//! cases): line + nested block comments, string literals with escapes,
//! raw/byte/C strings (`r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `c"…"`),
//! char literals vs lifetimes, idents, numbers (including `0x…`, floats,
//! exponents, suffixes, and `1..n` ranges), and single-char punctuation
//! (multi-char operators arrive as adjacent `Punct` tokens, which is all
//! the rules need — `::` is two `:` tokens).

/// Lexical class of a [`Tok`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `HashMap`, `for`, …).
    Ident,
    /// Lifetime or loop label (`'a`, `'outer`).
    Lifetime,
    /// Numeric literal, suffix included (`42`, `0xFA17`, `1.5e-3f64`).
    Number,
    /// String literal of any flavour, delimiters included.
    Str,
    /// Char literal, delimiters included.
    Char,
    /// One punctuation character (`.`, `:`, `#`, `{`, …).
    Punct,
}

/// One code token with its source position (1-based line and column).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// One comment (line or block), delimiters included.  Block comments keep
/// only their starting line: suppression directives are line comments.
#[derive(Debug, Clone)]
pub struct Comment {
    pub text: String,
    pub line: u32,
}

/// Token stream + comment stream for one source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek(0)?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn take_while(&mut self, out: &mut String, f: impl Fn(char) -> bool) {
        while let Some(c) = self.peek(0) {
            if !f(c) {
                break;
            }
            out.push(c);
            self.bump();
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_ascii_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

/// Would an ident be a raw/byte/C string prefix given the next char?
/// (`r"`, `r#`, `b"`, `br#`, `c"`, `cr#`, …)
fn is_string_prefix(ident: &str, next: Option<char>) -> bool {
    let prefix_ok = matches!(ident, "r" | "b" | "c" | "br" | "rb" | "cr" | "rc");
    prefix_ok && matches!(next, Some('"') | Some('#'))
}

/// For `r`-flavoured prefixes, `#*"` must actually follow — `r#foo` is a
/// raw identifier, not a raw string.
fn raw_quote_follows(lx: &Lexer, ident: &str) -> bool {
    if !ident.contains('r') {
        return true;
    }
    let mut k = 0usize;
    while lx.peek(k) == Some('#') {
        k += 1;
    }
    lx.peek(k) == Some('"')
}

/// Tokenize one source file.  Never panics: unterminated constructs are
/// closed at end-of-file (the lint keeps whatever it saw up to there).
pub fn lex(src: &str) -> Lexed {
    let mut lx = Lexer { chars: src.chars().collect(), i: 0, line: 1, col: 1 };
    let mut out = Lexed::default();

    while let Some(c) = lx.peek(0) {
        let (line, col) = (lx.line, lx.col);
        if c.is_whitespace() {
            lx.bump();
            continue;
        }
        // Comments.
        if c == '/' && lx.peek(1) == Some('/') {
            let mut text = String::new();
            lx.take_while(&mut text, |c| c != '\n');
            out.comments.push(Comment { text, line });
            continue;
        }
        if c == '/' && lx.peek(1) == Some('*') {
            let mut text = String::new();
            let mut depth = 0usize;
            while let Some(c) = lx.peek(0) {
                if c == '/' && lx.peek(1) == Some('*') {
                    depth += 1;
                    text.push('/');
                    text.push('*');
                    lx.bump();
                    lx.bump();
                } else if c == '*' && lx.peek(1) == Some('/') {
                    depth -= 1;
                    text.push('*');
                    text.push('/');
                    lx.bump();
                    lx.bump();
                    if depth == 0 {
                        break;
                    }
                } else {
                    text.push(c);
                    lx.bump();
                }
            }
            out.comments.push(Comment { text, line });
            continue;
        }
        // Plain strings.
        if c == '"' {
            out.toks.push(lex_escaped_string(&mut lx, String::new(), line, col));
            continue;
        }
        // Lifetimes vs char literals.
        if c == '\'' {
            out.toks.push(lex_quote(&mut lx, line, col));
            continue;
        }
        // Idents, which may turn out to be raw/byte-string prefixes.
        if is_ident_start(c) {
            let mut text = String::new();
            lx.take_while(&mut text, is_ident_continue);
            if is_string_prefix(&text, lx.peek(0)) && raw_quote_follows(&lx, &text) {
                let raw = text.contains('r');
                let tok = if raw {
                    lex_raw_string(&mut lx, text, line, col)
                } else {
                    // b"…" / c"…": escaped body, prefixed.
                    lx.bump(); // opening quote
                    let mut head = text;
                    head.push('"');
                    lex_escaped_string(&mut lx, head, line, col)
                };
                out.toks.push(tok);
            } else if text == "r"
                && lx.peek(0) == Some('#')
                && lx.peek(1).is_some_and(is_ident_start)
            {
                // Raw identifier `r#foo`: one Ident token, `r#` kept in the
                // text so `r#unsafe` never matches the `unsafe` keyword.
                let mut text = text;
                text.push('#');
                lx.bump();
                lx.take_while(&mut text, is_ident_continue);
                out.toks.push(Tok { kind: TokKind::Ident, text, line, col });
            } else {
                out.toks.push(Tok { kind: TokKind::Ident, text, line, col });
            }
            continue;
        }
        // Numbers.
        if c.is_ascii_digit() {
            out.toks.push(lex_number(&mut lx, line, col));
            continue;
        }
        // Everything else: one punctuation char.
        lx.bump();
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line, col });
    }
    out
}

/// Body of a `"…"` string (opening quote not yet consumed when `text` is
/// empty; for `b"`/`c"` prefixes the caller already pushed `prefix"`).
fn lex_escaped_string(lx: &mut Lexer, mut text: String, line: u32, col: u32) -> Tok {
    if text.is_empty() {
        lx.bump();
        text.push('"');
    }
    while let Some(c) = lx.bump() {
        text.push(c);
        if c == '\\' {
            if let Some(esc) = lx.bump() {
                text.push(esc);
            }
        } else if c == '"' {
            break;
        }
    }
    Tok { kind: TokKind::Str, text, line, col }
}

/// `r"…"`, `r#"…"#`, `br##"…"##`, … — no escapes, hash-counted close.
fn lex_raw_string(lx: &mut Lexer, mut text: String, line: u32, col: u32) -> Tok {
    let mut hashes = 0usize;
    while lx.peek(0) == Some('#') {
        hashes += 1;
        text.push('#');
        lx.bump();
    }
    if lx.peek(0) == Some('"') {
        text.push('"');
        lx.bump();
        'body: while let Some(c) = lx.bump() {
            text.push(c);
            if c == '"' {
                for k in 0..hashes {
                    if lx.peek(k) != Some('#') {
                        continue 'body;
                    }
                }
                for _ in 0..hashes {
                    text.push('#');
                    lx.bump();
                }
                break;
            }
        }
    }
    Tok { kind: TokKind::Str, text, line, col }
}

/// A `'` is a lifetime/label when followed by an ident that is NOT then
/// closed by another `'` (so `'a'` is a char, `'a` a lifetime).
fn lex_quote(lx: &mut Lexer, line: u32, col: u32) -> Tok {
    let after = lx.peek(1);
    let lifetime = match after {
        Some(c) if is_ident_start(c) => lx.peek(2).map_or(true, |c2| c2 != '\''),
        _ => false,
    };
    let mut text = String::from("'");
    lx.bump();
    if lifetime {
        lx.take_while(&mut text, is_ident_continue);
        return Tok { kind: TokKind::Lifetime, text, line, col };
    }
    // Char literal: handle `'\''`, `'\\'`, `'\u{1F600}'`, `'x'`.
    while let Some(c) = lx.bump() {
        text.push(c);
        if c == '\\' {
            if let Some(esc) = lx.bump() {
                text.push(esc);
            }
        } else if c == '\'' {
            break;
        }
    }
    Tok { kind: TokKind::Char, text, line, col }
}

/// Numeric literal; consumes suffixes (`1.5e-3f64`) but stops before `..`
/// so ranges like `1..n` stay three tokens.
fn lex_number(lx: &mut Lexer, line: u32, col: u32) -> Tok {
    let mut text = String::new();
    if lx.peek(0) == Some('0') && matches!(lx.peek(1), Some('x') | Some('o') | Some('b')) {
        text.push('0');
        lx.bump();
        if let Some(base) = lx.bump() {
            text.push(base);
        }
        lx.take_while(&mut text, |c| c.is_ascii_hexdigit() || c == '_');
    } else {
        lx.take_while(&mut text, |c| c.is_ascii_digit() || c == '_');
        // Fraction only when `.` is followed by a digit (not `..`, not `.method()`).
        if lx.peek(0) == Some('.') && lx.peek(1).is_some_and(|c| c.is_ascii_digit()) {
            text.push('.');
            lx.bump();
            lx.take_while(&mut text, |c| c.is_ascii_digit() || c == '_');
        }
        // Exponent.
        if let Some(e @ ('e' | 'E')) = lx.peek(0) {
            let sign = matches!(lx.peek(1), Some('+') | Some('-'));
            let digit_at = if sign { 2 } else { 1 };
            if lx.peek(digit_at).is_some_and(|c| c.is_ascii_digit()) {
                text.push(e);
                lx.bump();
                if sign {
                    if let Some(s) = lx.bump() {
                        text.push(s);
                    }
                }
                lx.take_while(&mut text, |c| c.is_ascii_digit() || c == '_');
            }
        }
    }
    // Type suffix (`u64`, `f32`, `usize`).
    lx.take_while(&mut text, is_ident_continue);
    Tok { kind: TokKind::Number, text, line, col }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_code_words() {
        let src = r####"
            // unsafe in a line comment
            /* unsafe in /* a nested */ block */
            let a = "unsafe in a string";
            let b = r#"unsafe in a raw string"#;
            let c = 'u';
        "####;
        let ids = idents(src);
        assert!(!ids.contains(&"unsafe".to_string()), "{ids:?}");
        assert_eq!(ids, vec!["let", "a", "let", "b", "let", "c"]);
        assert_eq!(lex(src).comments.len(), 2);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) { 'outer: loop { break 'outer; } }").toks;
        let lifetimes: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| t.text.clone()).collect();
        assert_eq!(lifetimes, vec!["'a", "'a", "'outer", "'outer"]);
    }

    #[test]
    fn char_literal_with_escapes() {
        let toks = lex(r"let q = '\''; let n = '\n'; let p = 'x';").toks;
        let chars: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Char).map(|t| t.text.clone()).collect();
        assert_eq!(chars.len(), 3);
    }

    #[test]
    fn ranges_stay_split_and_hex_lexes() {
        let toks = lex("for i in 1..n { let t = 0xFA17_1055 ^ 1.5e-3f64; }").toks;
        let nums: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Number).map(|t| t.text.clone()).collect();
        assert_eq!(nums, vec!["1", "0xFA17_1055", "1.5e-3f64"]);
        let dots = toks.iter().filter(|t| t.text == "." && t.kind == TokKind::Punct).count();
        assert_eq!(dots, 2, "the `..` of the range");
    }

    #[test]
    fn line_and_column_spans_are_accurate() {
        let toks = lex("ab cd\n  ef").toks;
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (1, 4));
        assert_eq!((toks[2].line, toks[2].col), (2, 3));
    }

    #[test]
    fn method_after_float_free_number() {
        // `1.max(2)` — integer, then `.`, then ident.
        let toks = lex("let x = 1.max(2);").toks;
        assert_eq!(toks[3].text, "1");
        assert_eq!(toks[4].text, ".");
        assert_eq!(toks[5].text, "max");
    }

    #[test]
    fn uppercase_exponent_keeps_source_text() {
        let toks = lex("let t = 2E10 + 1.5E-3;").toks;
        let nums: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Number).map(|t| t.text.clone()).collect();
        assert_eq!(nums, vec!["2E10", "1.5E-3"]);
    }

    #[test]
    fn raw_identifiers_are_not_raw_strings() {
        let toks = lex("let r#type = r#fn + 1; let s = r#\"raw\"#;").toks;
        let ids = idents("let r#type = r#fn + 1; let s = r#\"raw\"#;");
        assert!(ids.contains(&"r#type".to_string()), "{ids:?}");
        assert!(ids.contains(&"r#fn".to_string()), "{ids:?}");
        let strs: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.clone()).collect();
        assert_eq!(strs, vec!["r#\"raw\"#"]);
        // `r#unsafe` must never read as the `unsafe` keyword.
        assert!(!idents("let r#unsafe = 1;").contains(&"unsafe".to_string()));
    }
}
