//! `amb-lint` — dependency-free determinism & invariant static analysis.
//!
//! Every contract this reproduction rests on — per-node minibatch a pure
//! function of the compute window, `threads=1 ≡ threads=k` bitwise,
//! all-clear faults ≡ no-fault bit-for-bit, ideal fabric ≡ abstract — is
//! otherwise enforced only *dynamically*, by golden pins and test suites.
//! This subsystem enforces the statically-checkable half of the contract
//! on every source file, before any test runs (DESIGN.md
//! §determinism-contract):
//!
//! | rule | checks |
//! |------|--------|
//! | D1 | no `Instant::now` / `SystemTime` / `available_parallelism` in deterministic modules |
//! | D2 | no `HashMap`/`HashSet` *iteration* anywhere (point lookups are fine) |
//! | D3 | every `Pcg64` construction routes through a namespaced tag-split (`LOSS_NS` style) |
//! | D4 | `unwrap`/`expect`/`panic!`/`unreachable!` in library code carries a justification |
//! | D5 | `#![forbid(unsafe_code)]` in lib.rs and no `unsafe` token anywhere |
//! | D6 | no `#[ignore]` without the golden-pin regen-helper marker |
//!
//! The deterministic-module set for D1 is [`DETERMINISTIC_MODULES`];
//! `coordinator::threaded` and `util::pool` are the explicit wall-clock
//! allowlist ([`WALL_CLOCK_ALLOWLIST`]) — real time IS their contract.
//!
//! ## Suppressions
//!
//! A violation is silenced by a plain line comment, either trailing on
//! the flagged line or standing alone on the line(s) directly above it:
//!
//! ```text
//! let first = v.first().unwrap(); // amb-lint: allow(D4, "v checked non-empty above")
//! ```
//!
//! `allow(<rule>)` takes an optional `, "justification"` string; D4
//! *requires* it.  `allow-file(<rule>, "justification")` suppresses a
//! rule for the whole file.  Doc comments (`///`, `//!`) are never read
//! as directives, so the syntax can be quoted in documentation.  Unknown
//! rule ids, malformed directives, and suppressions that stop matching
//! any violation are themselves reported (rule id `meta`), so stale
//! allows cannot rot in place.
//!
//! ## Scope model
//!
//! Analysis is purely lexical (see [`lexer`]): no type inference, no
//! macro expansion.  D2 therefore tracks hash-container *names* — local
//! bindings initialised from `HashMap::new()`-style constructors, any
//! `name: HashMap<…>`-shaped annotation (fields, params, struct
//! literals), and file-spanning `type X = HashSet<…>` aliases collected
//! across the whole scanned set — and flags `.iter()`-family calls and
//! `for … in &name` loops on those names.  `#[cfg(test)]` / `#[test]`
//! items are recognised by attribute + brace matching; D3 and D4 do not
//! apply inside them, nor to `tests/`, `examples/`, or bench sources.
//! Directories named `fixtures`, `golden`, `vendor`, or `target` are
//! never walked (the lint's own rule fixtures are deliberate violations).

pub mod lexer;
pub mod rules;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use lexer::{Lexed, Tok, TokKind};

/// Rule ids with one-line summaries (`amb-lint --rules`).
pub const RULES: &[(&str, &str)] = &[
    ("D1", "wall-clock read in a deterministic module"),
    ("D2", "HashMap/HashSet iteration: order is nondeterministic (lookups are fine)"),
    ("D3", "raw Pcg64 seeding outside the namespaced tag-split helpers"),
    ("D4", "unwrap/expect/panic!/unreachable! in library code without a justification"),
    ("D5", "unsafe code (crate forbids it), or lib.rs missing #![forbid(unsafe_code)]"),
    ("D6", "#[ignore] without the golden-pin regen-helper marker"),
    ("meta", "malformed, unknown, or unused amb-lint suppression"),
];

/// Modules whose state evolution must be a pure function of (spec, seed).
/// A module matches if it equals an entry or sits below it (`consensus`
/// covers `consensus::churn`).
pub const DETERMINISTIC_MODULES: &[&str] = &[
    "coordinator::sim",
    "consensus",
    "net",
    "fault",
    "churn",
    "optim",
    "straggler",
    "experiments",
];

/// The explicit wall-clock allowlist: the threaded runtime schedules real
/// deadlines and the worker pool sizes itself off the machine — both are
/// *outside* the deterministic plane by design (their outputs are pinned
/// bitwise against the deterministic paths instead).
pub const WALL_CLOCK_ALLOWLIST: &[&str] = &["coordinator::threaded", "util::pool"];

/// Rules whose suppressions must carry a justification string.
const JUSTIFICATION_REQUIRED: &[&str] = &["D4"];

/// Where a source file sits in the package layout; decides which rules
/// apply (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SourceKind {
    /// Library source under `src/` (module path known).
    Lib,
    /// Binary source (`src/main.rs`, `src/bin/*`).
    Bin,
    /// Integration-test source under `tests/`.
    Test,
    /// Example under `examples/`.
    Example,
    /// Bench under `benches/`.
    Bench,
    /// Anything else (e.g. the CI self-test's temp file).
    Other,
}

/// One finding, with a span-accurate anchor.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub path: String,
    pub line: u32,
    pub col: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!("{}:{}:{}: {}: {}", self.path, self.line, self.col, self.rule, self.msg)
    }
}

/// Scope of one suppression directive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SuppressionTarget {
    File,
    Line(u32),
}

#[derive(Debug)]
struct Suppression {
    rule: String,
    reason: Option<String>,
    target: SuppressionTarget,
    comment_line: u32,
    used: bool,
}

/// Lexed + classified view of one source file, ready for the rules.
pub struct FileAnalysis {
    pub path: String,
    pub kind: SourceKind,
    /// Crate-relative module path for `Lib` sources (`""` = lib.rs root,
    /// `"consensus::churn"`, …); `None` otherwise.
    pub module: Option<String>,
    pub lexed: Lexed,
    /// Inclusive line ranges of `#[cfg(test)]` / `#[test]` items.
    pub test_regions: Vec<(u32, u32)>,
    suppressions: Vec<Suppression>,
    /// Parse-stage problems (malformed directives, unknown rules).
    directive_issues: Vec<(u32, String)>,
}

impl FileAnalysis {
    pub fn in_test_region(&self, line: u32) -> bool {
        self.test_regions.iter().any(|&(a, b)| (a..=b).contains(&line))
    }
}

/// Result of one lint pass.
#[derive(Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    pub files: usize,
    pub suppressed: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "amb-lint: {} violation(s) across {} file(s) ({} suppressed)\n",
            self.diagnostics.len(),
            self.files,
            self.suppressed
        ));
        out
    }
}

/// Classify a (normalized, `/`-separated) path into kind + module path.
fn classify_path(path: &str) -> (SourceKind, Option<String>) {
    let comps: Vec<&str> = path.split('/').filter(|c| !c.is_empty() && *c != ".").collect();
    if let Some(src_at) = comps.iter().rposition(|c| *c == "src") {
        let rel = &comps[src_at + 1..];
        if rel.first() == Some(&"bin") || rel == ["main.rs"] {
            return (SourceKind::Bin, None);
        }
        let mut parts: Vec<String> =
            rel.iter().map(|c| c.trim_end_matches(".rs").to_string()).collect();
        if matches!(parts.last().map(String::as_str), Some("mod") | Some("lib")) {
            parts.pop();
        }
        return (SourceKind::Lib, Some(parts.join("::")));
    }
    if comps.contains(&"tests") {
        (SourceKind::Test, None)
    } else if comps.contains(&"examples") {
        (SourceKind::Example, None)
    } else if comps.contains(&"benches") {
        (SourceKind::Bench, None)
    } else {
        (SourceKind::Other, None)
    }
}

/// Is `module` inside the deterministic plane (and not allowlisted)?
pub fn is_deterministic_module(module: &str) -> bool {
    let within = |set: &[&str]| {
        set.iter().any(|m| module == *m || module.starts_with(&format!("{m}::")))
    };
    within(DETERMINISTIC_MODULES) && !within(WALL_CLOCK_ALLOWLIST)
}

fn is_known_rule(rule: &str) -> bool {
    RULES.iter().any(|(id, _)| *id == rule && *id != "meta")
}

/// Attribute scan: from the token index just inside `#[`, walk to the
/// matching `]`.  Returns (index of `]`, attr marks a test item).  `test`
/// under a `not(…)` (`#[cfg(not(test))]`) is NOT a test marker — that
/// attribute selects the production build, which the rules must cover.
fn scan_attr(toks: &[Tok], mut i: usize) -> (usize, bool) {
    let mut depth = 1usize;
    let mut has_test = false;
    let mut has_not = false;
    while i < toks.len() {
        let t = &toks[i];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "[") => depth += 1,
            (TokKind::Punct, "]") => {
                depth -= 1;
                if depth == 0 {
                    return (i, has_test && !has_not);
                }
            }
            (TokKind::Ident, "test") => has_test = true,
            (TokKind::Ident, "not") => has_not = true,
            _ => {}
        }
        i += 1;
    }
    (toks.len().saturating_sub(1), has_test && !has_not)
}

/// From a `{` token index, return the index of its matching `}` (or the
/// last token on unbalanced input).
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        match (toks[i].kind, toks[i].text.as_str()) {
            (TokKind::Punct, "{") => depth += 1,
            (TokKind::Punct, "}") => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

fn is_punct(toks: &[Tok], i: usize, c: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == c)
}

/// Line ranges covered by `#[cfg(test)]` mods / `#[test]` fns: from the
/// attribute line to the closing brace of the next braced item (or the
/// terminating `;` for brace-less items).
fn test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(is_punct(toks, i, "#") && is_punct(toks, i + 1, "[")) {
            i += 1;
            continue;
        }
        let (attr_end, has_test) = scan_attr(toks, i + 2);
        if !has_test {
            i = attr_end + 1;
            continue;
        }
        // Skip any further attributes, then find the item's `{` or `;`.
        let mut j = attr_end + 1;
        while is_punct(toks, j, "#") && is_punct(toks, j + 1, "[") {
            j = scan_attr(toks, j + 2).0 + 1;
        }
        while j < toks.len() && !is_punct(toks, j, "{") && !is_punct(toks, j, ";") {
            j += 1;
        }
        if is_punct(toks, j, "{") {
            let close = match_brace(toks, j);
            out.push((toks[i].line, toks[close].line));
        } else if j < toks.len() {
            out.push((toks[i].line, toks[j].line));
        }
        i = attr_end + 1;
    }
    out
}

/// Parse `amb-lint:` directives out of the comment stream.  Doc comments
/// are documentation, never directives.
fn parse_suppressions(
    lexed: &Lexed,
    issues: &mut Vec<(u32, String)>,
) -> Vec<Suppression> {
    let token_lines: BTreeSet<u32> = lexed.toks.iter().map(|t| t.line).collect();
    let mut out = Vec::new();
    for c in &lexed.comments {
        let text = c.text.as_str();
        let doc = ["///", "//!", "/**", "/*!"];
        if doc.iter().any(|d| text.starts_with(d)) {
            continue;
        }
        let Some(marker) = text.find("amb-lint:") else { continue };
        let body = &text[marker + "amb-lint:".len()..];
        let mut found_any = false;
        let mut pos = 0usize;
        while let Some(rel) = body[pos..].find("allow") {
            let mut at = pos + rel + "allow".len();
            let target = if body[at..].starts_with("-file(") {
                at += "-file(".len();
                SuppressionTarget::File
            } else if body[at..].starts_with('(') {
                at += 1;
                match token_lines.range(c.line..).next() {
                    Some(&l) => SuppressionTarget::Line(l),
                    None => {
                        issues.push((c.line, "suppression below all code: nothing to target".into()));
                        pos = at;
                        continue;
                    }
                }
            } else {
                pos = at;
                continue;
            };
            found_any = true;
            let rest = &body[at..];
            let rule: String =
                rest.chars().take_while(|ch| ch.is_ascii_alphanumeric() || *ch == '_').collect();
            let mut cur = at + rule.len();
            while body[cur..].starts_with(' ') {
                cur += 1;
            }
            let mut reason = None;
            if body[cur..].starts_with(',') {
                cur += 1;
                while body[cur..].starts_with(' ') {
                    cur += 1;
                }
                if body[cur..].starts_with('"') {
                    cur += 1;
                    match body[cur..].find('"') {
                        Some(end) => {
                            reason = Some(body[cur..cur + end].to_string());
                            cur += end + 1;
                        }
                        None => {
                            issues.push((c.line, "unterminated justification string".into()));
                            break;
                        }
                    }
                } else {
                    issues.push((c.line, "expected a quoted justification after `,`".into()));
                    break;
                }
                while body[cur..].starts_with(' ') {
                    cur += 1;
                }
            }
            if !body[cur..].starts_with(')') {
                issues.push((c.line, format!("expected `)` to close allow({rule}…)")));
                pos = cur;
                continue;
            }
            cur += 1;
            if !is_known_rule(&rule) {
                issues.push((c.line, format!("unknown rule `{rule}` in amb-lint directive")));
            } else {
                out.push(Suppression {
                    rule,
                    reason,
                    target,
                    comment_line: c.line,
                    used: false,
                });
            }
            pos = cur;
        }
        if !found_any {
            issues.push((c.line, "amb-lint marker without an allow(...) directive".into()));
        }
    }
    out
}

/// Lex + classify one (path, source) pair.
pub fn analyze_source(path: &str, src: &str) -> FileAnalysis {
    let path = path.replace('\\', "/");
    let (kind, module) = classify_path(&path);
    let lexed = lexer::lex(src);
    let regions = test_regions(&lexed.toks);
    let mut issues = Vec::new();
    let sups = parse_suppressions(&lexed, &mut issues);
    FileAnalysis {
        path,
        kind,
        module,
        lexed,
        test_regions: regions,
        suppressions: sups,
        directive_issues: issues,
    }
}

/// Lint an in-memory file set (the test hook; [`lint_tree`] routes here).
/// Two passes: hash-alias collection across the whole set, then rules +
/// suppression accounting per file.
pub fn lint_sources(files: &[(String, String)]) -> Report {
    let mut analyses: Vec<FileAnalysis> =
        files.iter().map(|(p, s)| analyze_source(p, s)).collect();
    let aliases = rules::hash_aliases(&analyses);
    let mut report = Report { files: analyses.len(), ..Report::default() };
    for fa in &mut analyses {
        let raw = rules::check_file(fa, &aliases);
        apply_suppressions(fa, raw, &mut report);
    }
    report
        .diagnostics
        .sort_by(|a, b| (&a.path, a.line, a.col, a.rule).cmp(&(&b.path, b.line, b.col, b.rule)));
    report
}

/// Match raw diagnostics against the file's suppressions; emit `meta`
/// findings for directive issues and unused suppressions.
fn apply_suppressions(fa: &mut FileAnalysis, raw: Vec<Diagnostic>, report: &mut Report) {
    for (line, msg) in &fa.directive_issues {
        report.diagnostics.push(Diagnostic {
            path: fa.path.clone(),
            line: *line,
            col: 1,
            rule: "meta",
            msg: msg.clone(),
        });
    }
    for mut d in raw {
        let hit = fa.suppressions.iter_mut().find(|s| {
            s.rule == d.rule
                && match s.target {
                    SuppressionTarget::File => true,
                    SuppressionTarget::Line(l) => l == d.line,
                }
        });
        match hit {
            Some(s) => {
                s.used = true;
                if JUSTIFICATION_REQUIRED.contains(&d.rule) && s.reason.is_none() {
                    d.msg
                        .push_str(" (suppression present but missing the justification string)");
                    report.diagnostics.push(d);
                } else {
                    report.suppressed += 1;
                }
            }
            None => report.diagnostics.push(d),
        }
    }
    for s in &fa.suppressions {
        if !s.used {
            report.diagnostics.push(Diagnostic {
                path: fa.path.clone(),
                line: s.comment_line,
                col: 1,
                rule: "meta",
                msg: format!("unused amb-lint suppression for {}: nothing fires it", s.rule),
            });
        }
    }
}

/// Directory names the walker never descends into: rule fixtures are
/// deliberate violations, golden pins and vendored crates are not ours
/// to lint, target is build output.
const SKIP_DIRS: &[&str] = &["fixtures", "golden", "vendor", "target"];

fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let meta = std::fs::metadata(root)
        .with_context(|| format!("amb-lint: cannot stat {}", root.display()))?;
    if meta.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)
        .with_context(|| format!("amb-lint: cannot read dir {}", root.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for entry in entries {
        let name = entry.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if entry.is_dir() {
            if SKIP_DIRS.contains(&name) || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&entry, out)?;
        } else if name.ends_with(".rs") {
            out.push(entry);
        }
    }
    Ok(())
}

/// Walk the given roots (files or directories), lint every `.rs` file.
pub fn lint_tree(roots: &[PathBuf]) -> Result<Report> {
    let mut paths = Vec::new();
    for root in roots {
        collect_rs_files(root, &mut paths)?;
    }
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let src = std::fs::read_to_string(&p)
            .with_context(|| format!("amb-lint: cannot read {}", p.display()))?;
        files.push((p.to_string_lossy().into_owned(), src));
    }
    Ok(lint_sources(&files))
}

#[cfg(test)]
mod tests;
