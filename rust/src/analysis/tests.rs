//! Fixture suite for the amb-lint rules: every rule fires on its
//! positive snippet and stays silent on the suppressed twin, plus the
//! `lints_clean_on_live_tree` meta-test the CI gate rides on.
//!
//! Fixtures live under `fixtures/` (a directory the tree walker skips,
//! because they are deliberate violations) and are linted here under
//! *virtual* paths so each lands in the [`SourceKind`]/module scope its
//! rule targets.

use std::path::Path;

use super::{lint_sources, lint_tree, Report};

const D1: &str = include_str!("fixtures/d1_wall_clock.rs");
const D1_OK: &str = include_str!("fixtures/d1_wall_clock_ok.rs");
const D2: &str = include_str!("fixtures/d2_hash_iter.rs");
const D2_OK: &str = include_str!("fixtures/d2_hash_iter_ok.rs");
const D3: &str = include_str!("fixtures/d3_rng.rs");
const D3_OK: &str = include_str!("fixtures/d3_rng_ok.rs");
const D4: &str = include_str!("fixtures/d4_panics.rs");
const D4_OK: &str = include_str!("fixtures/d4_panics_ok.rs");
const D4_BARE: &str = include_str!("fixtures/d4_bare_allow.rs");
const D5: &str = include_str!("fixtures/d5_unsafe.rs");
const D5_OK: &str = include_str!("fixtures/d5_unsafe_ok.rs");
const D6: &str = include_str!("fixtures/d6_ignore.rs");
const D6_OK: &str = include_str!("fixtures/d6_ignore_ok.rs");
const META_BAD: &str = include_str!("fixtures/meta_bad.rs");

/// Lint one fixture at a virtual path (so path classification applies).
fn lint_at(path: &str, src: &str) -> Report {
    lint_sources(&[(path.to_string(), src.to_string())])
}

fn rules_fired(report: &Report) -> Vec<&'static str> {
    report.diagnostics.iter().map(|d| d.rule).collect()
}

#[test]
fn d1_fires_in_deterministic_module() {
    let r = lint_at("rust/src/consensus/fix.rs", D1);
    assert_eq!(rules_fired(&r), ["D1"; 5], "{}", r.render());
    // Span accuracy: the Instant::now read sits at 5:14.
    let instant = r.diagnostics.iter().find(|d| d.msg.contains("Instant::now"));
    let instant = instant.unwrap_or_else(|| panic!("no Instant::now diag in {}", r.render()));
    assert_eq!((instant.line, instant.col), (5, 14));
}

#[test]
fn d1_silent_on_wall_clock_allowlist() {
    // Same source, but under coordinator::threaded — real time IS its
    // contract, so the allowlist swallows every read.
    let r = lint_at("rust/src/coordinator/threaded/fix.rs", D1);
    assert!(r.is_clean(), "{}", r.render());
    let r = lint_at("rust/src/util/pool/fix.rs", D1);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn d1_suppressed_twin_is_silent() {
    let r = lint_at("rust/src/consensus/fix.rs", D1_OK);
    assert!(r.is_clean(), "{}", r.render());
    assert_eq!(r.suppressed, 2);
}

#[test]
fn d2_fires_on_iteration_not_lookup() {
    let r = lint_at("rust/src/consensus/fix.rs", D2);
    assert_eq!(rules_fired(&r), ["D2"; 3], "{}", r.render());
    let lines: Vec<u32> = r.diagnostics.iter().map(|d| d.line).collect();
    assert_eq!(lines, [5, 9, 18]); // .values(), for-loop, .retain()
    let r = lint_at("rust/src/consensus/fix.rs", D2_OK);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn d2_sees_type_aliases_across_files() {
    // The alias prepass is global: a HashSet alias declared in `fault`
    // marks receivers annotated with it in `net`.
    let alias = "pub type DropMask = std::collections::HashSet<u64>;\n";
    let user = "pub fn live(mask: &DropMask) -> usize { mask.iter().count() }\n";
    let r = lint_sources(&[
        ("rust/src/fault/fix.rs".to_string(), alias.to_string()),
        ("rust/src/net/fix.rs".to_string(), user.to_string()),
    ]);
    assert_eq!(rules_fired(&r), ["D2"], "{}", r.render());
    assert_eq!(r.diagnostics[0].path, "rust/src/net/fix.rs");
}

#[test]
fn d3_fires_on_raw_seed_and_accepts_namespacing() {
    let r = lint_at("rust/src/consensus/fix.rs", D3);
    assert_eq!(rules_fired(&r), ["D3"], "{}", r.render());
    // The twin holds an xor construction, a `.split()` chain, and one
    // justified stream root — all silent.
    let r = lint_at("rust/src/consensus/fix.rs", D3_OK);
    assert!(r.is_clean(), "{}", r.render());
    assert_eq!(r.suppressed, 1);
}

#[test]
fn d3_exempt_in_test_regions_and_test_sources() {
    let src = "#[cfg(test)]\nmod tests {\n    use crate::util::rng::Pcg64;\n    #[test]\n    \
               fn draws() { let mut r = Pcg64::new(7); assert!(r.f64() < 1.0); }\n}\n";
    let r = lint_at("rust/src/consensus/fix.rs", src);
    assert!(r.is_clean(), "{}", r.render());
    let r = lint_at("rust/tests/fix.rs", D3);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn d4_fires_on_each_panic_form() {
    let r = lint_at("rust/src/consensus/fix.rs", D4);
    assert_eq!(rules_fired(&r), ["D4"; 4], "{}", r.render());
    let msgs: String = r.diagnostics.iter().map(|d| d.msg.as_str()).collect();
    for form in [".unwrap()", ".expect()", "panic!", "unreachable!"] {
        assert!(msgs.contains(form), "missing {form} in {msgs}");
    }
}

#[test]
fn d4_justified_twin_is_silent_but_bare_allow_still_fires() {
    let r = lint_at("rust/src/consensus/fix.rs", D4_OK);
    assert!(r.is_clean(), "{}", r.render());
    assert_eq!(r.suppressed, 2);
    // A bare allow(D4) is used (no meta-unused) but does NOT silence.
    let r = lint_at("rust/src/consensus/fix.rs", D4_BARE);
    assert_eq!(rules_fired(&r), ["D4"], "{}", r.render());
    assert!(r.diagnostics[0].msg.contains("missing the justification"), "{}", r.render());
}

#[test]
fn d4_not_applied_to_test_sources() {
    for path in ["rust/tests/fix.rs", "examples/fix.rs", "rust/benches/fix.rs"] {
        let r = lint_at(path, D4);
        assert!(r.is_clean(), "{path}: {}", r.render());
    }
}

#[test]
fn d5_fires_everywhere_even_scratch_files() {
    let r = lint_at("scratch/seeded.rs", D5);
    assert_eq!(rules_fired(&r), ["D5"], "{}", r.render());
    let r = lint_at("scratch/seeded.rs", D5_OK);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn d5_lib_rs_must_carry_the_forbid() {
    let r = lint_at("rust/src/lib.rs", "pub mod consensus;\n");
    assert_eq!(rules_fired(&r), ["D5"], "{}", r.render());
    assert!(r.diagnostics[0].msg.contains("forbid(unsafe_code)"), "{}", r.render());
    let r = lint_at("rust/src/lib.rs", "#![forbid(unsafe_code)]\npub mod consensus;\n");
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn d6_ignore_requires_the_regen_marker() {
    let r = lint_at("rust/tests/fix.rs", D6);
    assert_eq!(rules_fired(&r), ["D6"], "{}", r.render());
    let r = lint_at("rust/tests/fix.rs", D6_OK);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn meta_reports_unknown_rules_and_unused_suppressions() {
    let r = lint_at("rust/src/consensus/fix.rs", META_BAD);
    assert_eq!(rules_fired(&r), ["meta", "meta"], "{}", r.render());
    let msgs: String = r.diagnostics.iter().map(|d| d.msg.as_str()).collect();
    assert!(msgs.contains("unknown rule `D9`"), "{msgs}");
    assert!(msgs.contains("unused amb-lint suppression for D4"), "{msgs}");
}

#[test]
fn cfg_not_test_is_not_a_test_region() {
    // `#[cfg(not(test))]` selects the PRODUCTION build: code under it
    // must stay inside D3/D4's jurisdiction, not be exempted like a
    // `#[cfg(test)]` module would be.
    let src = "#[cfg(not(test))]\nmod shim {\n    \
               pub fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n}\n";
    let r = lint_at("rust/src/consensus/fix.rs", src);
    assert_eq!(rules_fired(&r), ["D4"], "{}", r.render());
}

#[test]
fn doc_comments_are_never_directives() {
    // The suppression syntax quoted in docs (as in this module's own
    // header) must not parse as a directive.
    let src = "/// Use `// amb-lint: allow(D4, \"why\")` at the site.\npub fn f() {}\n";
    let r = lint_at("rust/src/consensus/fix.rs", src);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn lints_clean_on_live_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots = [
        root.join("src"),
        root.join("tests"),
        root.join("benches"),
        root.join("../examples"),
    ];
    let report = match lint_tree(&roots) {
        Ok(r) => r,
        Err(e) => panic!("lint_tree failed: {e:#}"),
    };
    assert!(report.files > 50, "walker found only {} files", report.files);
    assert!(report.is_clean(), "live tree has violations:\n{}", report.render());
}
