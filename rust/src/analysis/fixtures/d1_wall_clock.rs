// D1 positive: wall-clock reads inside a deterministic module.
use std::time::{Instant, SystemTime};

pub fn epoch_deadline() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn stamp() -> SystemTime {
    SystemTime::now()
}

pub fn width() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
