// D4 negative: every panic site carries a justification string.
pub fn head(v: &[u64]) -> u64 {
    // amb-lint: allow(D4, "caller guarantees v non-empty (checked at spec parse)")
    *v.first().unwrap()
}

pub fn boom(kind: u8) -> u64 {
    match kind {
        0 => 0,
        // amb-lint: allow(D4, "kind validated at construction; other values are a bug")
        _ => unreachable!(),
    }
}
