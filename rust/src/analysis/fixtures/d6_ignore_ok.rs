// D6 negative: the golden-pin regen helper is the one sanctioned use.
#[test]
#[ignore = "regen helper: run explicitly to rewrite tests/golden/pins.txt"]
fn regen_pins() {}
