// D2 positive: iterating hash containers (order is nondeterministic).
use std::collections::{HashMap, HashSet};

pub fn sum_values(m: &HashMap<u64, f64>) -> f64 {
    m.values().sum()
}

pub fn first_seen(seen: &HashSet<u64>) -> Option<u64> {
    for &id in seen {
        return Some(id);
    }
    None
}

pub fn drain_all() {
    let mut inbox: HashMap<u64, Vec<f32>> = HashMap::new();
    inbox.insert(1, vec![0.0]);
    inbox.retain(|_, v| !v.is_empty());
}
