// D5 positive: an unsafe block (the crate forbids unsafe code).
pub fn reinterpret(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}
