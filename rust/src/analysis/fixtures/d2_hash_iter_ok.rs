// D2 negative: point lookups are fine; iteration carries a suppression.
use std::collections::HashMap;

pub fn lookup(m: &HashMap<u64, f64>, k: u64) -> Option<f64> {
    m.get(&k).copied()
}

pub fn purge(inbox: &mut HashMap<u64, Vec<f32>>) {
    // amb-lint: allow(D2, "retain applies a pure per-key predicate; order-independent")
    inbox.retain(|_, v| !v.is_empty());
}
