// D3 negative: namespaced constructions and tag-splits pass; a raw
// stream root carries a suppression.
use crate::util::rng::Pcg64;

const LOSS_NS: u64 = 0x1A55_0001;

pub fn namespaced(seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed ^ LOSS_NS);
    rng.f64()
}

pub fn split_root(seed: u64, node: u64) -> f64 {
    let mut root = Pcg64::new(seed ^ LOSS_NS).split(node);
    root.f64()
}

pub fn stream_root(seed: u64) -> Pcg64 {
    // amb-lint: allow(D3, "stream root: caller-supplied seed is this generator's namespace")
    Pcg64::new(seed)
}
