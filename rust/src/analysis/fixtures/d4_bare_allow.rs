// D4 with a suppression but no justification string: still reported.
pub fn head(v: &[u64]) -> u64 {
    // amb-lint: allow(D4)
    *v.first().unwrap()
}
