// D5 negative: the safe rewrite, no unsafe token anywhere.
pub fn to_bytes(data: &[f32]) -> Vec<u8> {
    data.iter().flat_map(|v| v.to_le_bytes()).collect()
}
