// D4 positive: unjustified panics in library code.
pub fn head(v: &[u64]) -> u64 {
    *v.first().unwrap()
}

pub fn must(v: Option<u64>) -> u64 {
    v.expect("always set")
}

pub fn boom(kind: u8) -> u64 {
    match kind {
        0 => 0,
        1 => panic!("bad kind"),
        _ => unreachable!(),
    }
}
