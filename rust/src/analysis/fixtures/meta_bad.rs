// meta positives: unknown rule, and a suppression nothing fires.
pub fn quiet() -> u64 {
    // amb-lint: allow(D9)
    // amb-lint: allow(D4, "nothing on the next line panics")
    7
}
