// D1 negative: the same reads, each carrying a suppression.
use std::time::Instant;

pub fn kernel_wall_time() -> f64 {
    // amb-lint: allow(D1, "host wall-time for the perf column; not simulated time")
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn width() -> usize {
    // amb-lint: allow(D1, "sizing a host-side scratch pool; result never enters the sim")
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
