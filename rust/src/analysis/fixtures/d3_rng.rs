// D3 positive: raw Pcg64 seeding outside the tag-split helpers.
use crate::util::rng::Pcg64;

pub fn draw(seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    rng.f64()
}
