// D6 positive: #[ignore] without the regen-helper marker.
#[test]
#[ignore]
fn slow_sweep() {}
