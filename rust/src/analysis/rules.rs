//! The amb-lint rules (D1–D6) over the lexical stream.
//!
//! Everything here is a pure function of the token/comment streams built
//! by [`super::lexer`] — no filesystem, no clock, no randomness — so a
//! lint run is itself bit-reproducible, the same property it enforces.

use std::collections::BTreeSet;

use super::lexer::{Tok, TokKind};
use super::{is_deterministic_module, Diagnostic, FileAnalysis, SourceKind};

/// `.method()` names whose receiver order is the hash map's bucket order.
const HASH_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Smart-pointer / cell idents skipped when reading a type annotation
/// down to its first meaningful constructor.
const TYPE_WRAPPERS: &[&str] =
    &["Option", "Rc", "Arc", "RefCell", "Mutex", "RwLock", "Box", "Cell", "mut", "dyn"];

fn ident<'t>(toks: &'t [Tok], i: usize) -> Option<&'t str> {
    toks.get(i).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
}

fn punct(toks: &[Tok], i: usize, c: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == c)
}

fn diag(fa: &FileAnalysis, t: &Tok, rule: &'static str, msg: String) -> Diagnostic {
    Diagnostic { path: fa.path.clone(), line: t.line, col: t.col, rule, msg }
}

/// Pass 1 over the whole file set: `type X = HashMap<…>;`-style aliases,
/// so a `DropMask` declared in `fault` is recognised in `net::fabric`.
pub fn hash_aliases(files: &[FileAnalysis]) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for fa in files {
        let toks = &fa.lexed.toks;
        for i in 0..toks.len() {
            if ident(toks, i) != Some("type") {
                continue;
            }
            let Some(name) = ident(toks, i + 1) else { continue };
            if !punct(toks, i + 2, "=") {
                continue;
            }
            let mut j = i + 3;
            while j < toks.len() && !punct(toks, j, ";") {
                if matches!(ident(toks, j), Some("HashMap") | Some("HashSet")) {
                    out.insert(name.to_string());
                    break;
                }
                j += 1;
            }
        }
    }
    out
}

/// Pass 2: run every rule that applies to this file's [`SourceKind`].
pub fn check_file(fa: &FileAnalysis, aliases: &BTreeSet<String>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    match fa.kind {
        SourceKind::Lib => {
            d1_wall_clock(fa, &mut out);
            d2_hash_iteration(fa, aliases, &mut out);
            d3_rng_discipline(fa, &mut out);
            d4_panic_audit(fa, &mut out);
            d5_unsafe(fa, &mut out);
            d6_ignore_audit(fa, &mut out);
        }
        SourceKind::Bin => {
            d2_hash_iteration(fa, aliases, &mut out);
            d3_rng_discipline(fa, &mut out);
            d4_panic_audit(fa, &mut out);
            d5_unsafe(fa, &mut out);
            d6_ignore_audit(fa, &mut out);
        }
        SourceKind::Test | SourceKind::Example | SourceKind::Bench | SourceKind::Other => {
            d2_hash_iteration(fa, aliases, &mut out);
            d5_unsafe(fa, &mut out);
            d6_ignore_audit(fa, &mut out);
        }
    }
    out
}

/// D1 — wall-clock reads in deterministic modules.  Simulated time comes
/// from the spec; reading the host clock or core count inside the
/// deterministic plane breaks `threads=1 ≡ threads=k` and run replay.
fn d1_wall_clock(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    let Some(module) = fa.module.as_deref() else { return };
    if !is_deterministic_module(module) {
        return;
    }
    let toks = &fa.lexed.toks;
    for i in 0..toks.len() {
        let Some(name) = ident(toks, i) else { continue };
        let flagged = match name {
            "SystemTime" | "available_parallelism" => Some(name),
            "Instant" => {
                let is_now = punct(toks, i + 1, ":")
                    && punct(toks, i + 2, ":")
                    && ident(toks, i + 3) == Some("now");
                is_now.then_some("Instant::now")
            }
            _ => None,
        };
        if let Some(what) = flagged {
            out.push(diag(
                fa,
                &toks[i],
                "D1",
                format!("wall-clock source `{what}` in deterministic module `{module}`"),
            ));
        }
    }
}

/// Read a type annotation / initialiser from `start`, returning true if
/// it resolves to a hash container: wrappers and path segments are
/// skipped, the first meaningful ident decides.
fn type_is_hash(toks: &[Tok], start: usize, aliases: &BTreeSet<String>) -> bool {
    let mut j = start;
    let limit = toks.len().min(start + 24);
    while j < limit {
        let t = &toks[j];
        match t.kind {
            TokKind::Punct if t.text == "&" || t.text == "<" => j += 1,
            TokKind::Lifetime => j += 1,
            TokKind::Ident => {
                let name = t.text.as_str();
                if name == "HashMap" || name == "HashSet" || aliases.contains(name) {
                    return true;
                }
                if TYPE_WRAPPERS.contains(&name) {
                    j += 1;
                } else if punct(toks, j + 1, ":") && punct(toks, j + 2, ":") {
                    // Path segment (`std::collections::HashMap`).
                    j += 3;
                } else {
                    return false;
                }
            }
            _ => return false,
        }
    }
    false
}

/// Names bound to hash containers in this file: `name: HashMap<…>`
/// annotations (fields, params, struct literals) and
/// `let [mut] name = HashMap::new()`-style initialisers.
fn hash_names(toks: &[Tok], aliases: &BTreeSet<String>) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        // `name: <type-or-value resolving to a hash container>` — skip
        // `::` path separators so `std::x` is not read as an annotation.
        if let Some(name) = ident(toks, i) {
            if punct(toks, i + 1, ":")
                && !punct(toks, i + 2, ":")
                && !punct(toks, i.wrapping_sub(1), ":")
                && type_is_hash(toks, i + 2, aliases)
            {
                names.insert(name.to_string());
            }
        }
        // `let [mut] name = … HashMap … ( …` — scan the initialiser head.
        if ident(toks, i) == Some("let") {
            let mut j = i + 1;
            if ident(toks, j) == Some("mut") {
                j += 1;
            }
            let Some(name) = ident(toks, j) else { continue };
            if !punct(toks, j + 1, "=") || punct(toks, j + 2, "=") {
                continue;
            }
            // Scan the initialiser head; stop at `(`/`;` and at `[` so a
            // `vec![DropMask::new(); n]` element type never marks the Vec.
            let mut k = j + 2;
            let limit = toks.len().min(k + 16);
            while k < limit && !punct(toks, k, "(") && !punct(toks, k, ";") && !punct(toks, k, "[")
            {
                if let Some(id) = ident(toks, k) {
                    if id == "HashMap" || id == "HashSet" || aliases.contains(id) {
                        names.insert(name.to_string());
                        break;
                    }
                }
                k += 1;
            }
        }
    }
    names
}

/// D2 — hash-container iteration.  Bucket order is a function of the
/// hasher's per-process random state; any fold over it is
/// run-to-run-nondeterministic.  Point lookups stay fine (the threaded
/// inboxes keep theirs); iterate a BTreeMap or sorted keys instead.
fn d2_hash_iteration(fa: &FileAnalysis, aliases: &BTreeSet<String>, out: &mut Vec<Diagnostic>) {
    let toks = &fa.lexed.toks;
    let names = hash_names(toks, aliases);
    if names.is_empty() {
        return;
    }
    for i in 0..toks.len() {
        // `name.iter()` family.
        if let Some(m) = ident(toks, i) {
            let call = punct(toks, i + 1, "(") && punct(toks, i.wrapping_sub(1), ".");
            if call && HASH_ITER_METHODS.contains(&m) {
                if let Some(recv) = ident(toks, i.wrapping_sub(2)) {
                    if names.contains(recv) {
                        let msg =
                            format!("`{recv}.{m}()` iterates a hash container: order is random");
                        out.push(diag(fa, &toks[i], "D2", msg));
                    }
                }
            }
        }
        // `for pat in [&[mut]] name {`.
        if ident(toks, i) == Some("for") {
            let limit = toks.len().min(i + 24);
            for j in i + 1..limit {
                if ident(toks, j) != Some("in") {
                    continue;
                }
                let mut k = j + 1;
                if punct(toks, k, "&") {
                    k += 1;
                }
                if ident(toks, k) == Some("mut") {
                    k += 1;
                }
                if let Some(name) = ident(toks, k) {
                    if names.contains(name) && punct(toks, k + 1, "{") {
                        let msg =
                            format!("`for … in {name}` iterates a hash container: order is random");
                        out.push(diag(fa, &toks[k], "D2", msg));
                    }
                }
                break;
            }
        }
    }
}

/// D3 — RNG discipline.  Every stream must be namespaced off its seed
/// the way the fault plane does (`Pcg64::new(seed).split(LOSS_NS-style
/// tag)` or `Pcg64::new(seed ^ NS)`), so two subsystems sharing one run
/// seed can never consume the same draw sequence.
fn d3_rng_discipline(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    if fa.module.as_deref() == Some("util::rng") {
        return; // the constructors themselves live here
    }
    let toks = &fa.lexed.toks;
    for i in 0..toks.len() {
        if ident(toks, i) != Some("Pcg64")
            || !punct(toks, i + 1, ":")
            || !punct(toks, i + 2, ":")
            || ident(toks, i + 3) != Some("new")
            || !punct(toks, i + 4, "(")
        {
            continue;
        }
        if fa.in_test_region(toks[i].line) {
            continue;
        }
        // Scan the argument list for a `^` namespace tag.
        let mut depth = 0usize;
        let mut j = i + 4;
        let mut namespaced = false;
        while j < toks.len() {
            if punct(toks, j, "(") {
                depth += 1;
            } else if punct(toks, j, ")") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if punct(toks, j, "^") {
                namespaced = true;
            }
            j += 1;
        }
        // `.split(tag)` directly on the construction also namespaces it.
        if punct(toks, j + 1, ".") && ident(toks, j + 2) == Some("split") {
            namespaced = true;
        }
        if !namespaced {
            out.push(diag(
                fa,
                &toks[i],
                "D3",
                "raw `Pcg64::new(seed)`: tag-split it (`.split(NS)`) or xor a namespace constant"
                    .to_string(),
            ));
        }
    }
}

/// D4 — panic audit.  Library panic paths must either be routed through
/// `anyhow::Result` or carry a written justification at the site.
fn d4_panic_audit(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    let toks = &fa.lexed.toks;
    for i in 0..toks.len() {
        let Some(name) = ident(toks, i) else { continue };
        if fa.in_test_region(toks[i].line) {
            continue;
        }
        let method = punct(toks, i + 1, "(") && punct(toks, i.wrapping_sub(1), ".");
        let what = match name {
            "unwrap" | "expect" if method => format!(".{name}()"),
            "panic" | "unreachable" if punct(toks, i + 1, "!") => format!("{name}!"),
            _ => continue,
        };
        out.push(diag(
            fa,
            &toks[i],
            "D4",
            format!("`{what}` in library code: route a Result or justify the panic path"),
        ));
    }
}

/// D5 — no unsafe code, and lib.rs must carry `#![forbid(unsafe_code)]`
/// so the compiler enforces the same thing from the inside.
fn d5_unsafe(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    let toks = &fa.lexed.toks;
    for t in toks {
        if t.kind == TokKind::Ident && t.text == "unsafe" {
            out.push(diag(fa, t, "D5", "`unsafe` token: the crate forbids unsafe code".into()));
        }
    }
    if fa.kind == SourceKind::Lib && fa.module.as_deref() == Some("") {
        let mut found = false;
        for i in 0..toks.len() {
            if punct(toks, i, "#")
                && punct(toks, i + 1, "!")
                && punct(toks, i + 2, "[")
                && ident(toks, i + 3) == Some("forbid")
                && punct(toks, i + 4, "(")
                && ident(toks, i + 5) == Some("unsafe_code")
            {
                found = true;
                break;
            }
        }
        if !found {
            out.push(Diagnostic {
                path: fa.path.clone(),
                line: 1,
                col: 1,
                rule: "D5",
                msg: "lib.rs is missing `#![forbid(unsafe_code)]`".into(),
            });
        }
    }
}

/// D6 — `#[ignore]` audit (the structured replacement for the old
/// grep-based CI step): only the golden-pin regen helpers may be
/// ignored, and they are recognised by their exact reason marker.
fn d6_ignore_audit(fa: &FileAnalysis, out: &mut Vec<Diagnostic>) {
    let toks = &fa.lexed.toks;
    for i in 0..toks.len() {
        let attr =
            punct(toks, i, "#") && punct(toks, i + 1, "[") && ident(toks, i + 2) == Some("ignore");
        if !attr {
            continue;
        }
        let ok = punct(toks, i + 3, "=")
            && toks
                .get(i + 4)
                .is_some_and(|t| t.kind == TokKind::Str && t.text.starts_with("\"regen helper"));
        if !ok {
            out.push(diag(
                fa,
                &toks[i + 2],
                "D6",
                "`#[ignore]` without the `regen helper` marker hides a test from the suite".into(),
            ));
        }
    }
}
