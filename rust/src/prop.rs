//! Mini property-testing harness (proptest is not in the offline vendor
//! set — DESIGN.md §7).  Deterministic: cases are generated from a PCG64
//! stream seeded per-property, and a failure report prints the case seed
//! so the exact input can be replayed with `reproduce`.
//!
//! ```ignore
//! forall(100, 0xA3, |g| {
//!     let n = g.usize_in(1, 20);
//!     let xs = g.vec_f64(n, -10.0, 10.0);
//!     prop_assert!(stats::mean(&xs) <= stats::max(&xs));
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg64;

/// Generator handed to each property case: typed draws over one RNG.
pub struct Gen {
    rng: Pcg64,
    /// The case seed; printed on failure for replay.
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        // amb-lint: allow(D3, "stream root: the prop case seed is the namespace; printed for replay")
        Gen { rng: Pcg64::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.f64() < p
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// {0,1} mask with inclusion probability p.
    pub fn mask(&mut self, n: usize, p: f64) -> Vec<f32> {
        (0..n).map(|_| if self.bool(p) { 1.0 } else { 0.0 }).collect()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Access the underlying RNG for domain samplers.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// A property failure: case index, seed, and message.
#[derive(Debug)]
pub struct PropFailure {
    pub case: usize,
    pub seed: u64,
    pub msg: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (replay seed {:#x}): {}",
            self.case, self.seed, self.msg
        )
    }
}

/// Run `cases` generated cases.  Panics with a replayable report on the
/// first failure.  `base_seed` namespaces the property so adding cases to
/// one property does not shift another's stream.
pub fn forall<F>(cases: usize, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64 + 1);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            // amb-lint: allow(D4, "prop harness reports failures by panicking, assert-style")
            panic!("{}", PropFailure { case, seed, msg });
        }
    }
}

/// Replay a single case by seed (paste from a failure report).
pub fn reproduce<F>(seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        // amb-lint: allow(D4, "prop harness reports failures by panicking, assert-style")
        panic!("{}", PropFailure { case: 0, seed, msg });
    }
}

/// assert-like helpers returning Err(String) instead of panicking, so the
/// harness can attach the replay seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Approximate equality helper for property bodies.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol) = ($a as f64, $b as f64, $tol as f64);
        if (a - b).abs() > tol * (1.0 + a.abs().max(b.abs())) {
            return Err(format!(
                "{} ≉ {} (|Δ|={:.3e}, tol={:.1e}) [{} vs {}]",
                a, b, (a - b).abs(), tol,
                stringify!($a), stringify!($b)
            ));
        }
    }};
}

/// Core optimizer & mixing properties, centralized here so the
/// harness's own module carries the invariants every layer leans on
/// (DESIGN.md §6).  These were previously scattered ad hoc through
/// `optim::dual_avg` and `topology` test modules.
#[cfg(test)]
mod domain_props {
    use super::forall;
    use crate::optim::{BetaSchedule, DualAveraging};
    use crate::topology::Topology;

    /// A connected topology of a random FAMILY and size — the mixing
    /// properties must hold on every graph shape we ship, not just
    /// Erdős–Rényi draws.
    fn random_topology(g: &mut super::Gen) -> Topology {
        match g.usize_in(0, 3) {
            0 => Topology::ring(g.usize_in(3, 20)),
            1 => Topology::complete(g.usize_in(2, 12)),
            2 => {
                // expander wants even n·d; keep d modest
                let n = 2 * g.usize_in(4, 10);
                Topology::expander(n, 4, g.u64())
            }
            _ => Topology::erdos_connected(g.usize_in(2, 20), g.f64_in(0.1, 0.7), g.u64()),
        }
    }

    /// ‖primal_step(z, t)‖ ≤ R for random z, t, R, β parameters — the
    /// feasible-ball projection of paper eq. (7) can never leak.
    #[test]
    fn primal_step_stays_in_ball() {
        forall(60, 0xD0_01, |g| {
            let dim = g.usize_in(1, 64);
            let da = DualAveraging::new(
                BetaSchedule::new(g.f64_in(0.0, 5.0), g.f64_in(0.5, 100.0)),
                g.f64_in(0.01, 3.0),
            );
            let z = g.vec_normal_f32(dim, 50.0);
            let mut w = vec![0.0f32; dim];
            da.primal_step(&z, g.usize_in(1, 50), &mut w);
            crate::prop_assert!(
                crate::util::norm2(&w) as f64 <= da.radius * (1.0 + 1e-5),
                "‖w‖ = {} > R = {}",
                crate::util::norm2(&w),
                da.radius
            );
            Ok(())
        });
    }

    /// w(1) = argmin h(w) = 0 for every dimension and schedule (paper
    /// eq. (2) with h = ½‖·‖²).
    #[test]
    fn initial_primal_is_zero() {
        forall(20, 0xD0_02, |g| {
            let dim = g.usize_in(1, 128);
            let da = DualAveraging::new(
                BetaSchedule::new(g.f64_in(0.0, 4.0), g.f64_in(0.1, 1000.0)),
                g.f64_in(0.01, 100.0),
            );
            crate::prop_assert!(da.initial_primal(dim) == vec![0.0f32; dim]);
            Ok(())
        });
    }

    /// β(t) is STRICTLY increasing in t for every (K, μ) — the paper's
    /// App. B schedule; a delay-D pipeline (AMB-DG) relies on exactly
    /// this plus z-as-a-sum-of-gradients, which is why β needs no
    /// change for delayed gradients (DESIGN.md §pipelining).
    #[test]
    fn beta_strictly_increasing() {
        forall(40, 0xD0_03, |g| {
            let s = BetaSchedule::new(g.f64_in(0.0, 10.0), g.f64_in(0.01, 5000.0));
            let mut prev = s.beta(1);
            crate::prop_assert!(prev.is_finite() && prev > 0.0);
            for t in 2..200 {
                let b = s.beta(t);
                crate::prop_assert!(b > prev, "β({t}) = {b} ≤ β({}) = {prev}", t - 1);
                prev = b;
            }
            Ok(())
        });
    }

    /// Induced-Metropolis rows are doubly stochastic over random
    /// topology FAMILIES × random active sets, with inactive rows
    /// exactly eᵢ (the churn engine's isolation invariant; moved here
    /// from the ad-hoc `topology` test so every mixing property lives
    /// in one suite).
    #[test]
    fn induced_metropolis_doubly_stochastic_over_random_topologies_and_active_sets() {
        forall(40, 0x70_05, |g| {
            let t = random_topology(g);
            let n = t.n();
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
            let m = t.induced(&active).metropolis();
            crate::prop_assert!(m.is_doubly_stochastic(1e-9));
            // inactive rows are exactly e_i: held bit-for-bit under mixing
            for i in 0..n {
                if !active[i] {
                    crate::prop_assert!(m.at(i, i) == 1.0, "row {i} not identity");
                    for j in 0..n {
                        if j != i {
                            crate::prop_assert!(m.at(i, j) == 0.0);
                            crate::prop_assert!(m.at(j, i) == 0.0);
                        }
                    }
                }
            }
            // ... and so is the lazy variant the consensus engine mixes
            // with (the all-active induced matrix IS the base matrix).
            let lazy = t.induced(&active).metropolis().lazy();
            crate::prop_assert!(lazy.is_doubly_stochastic(1e-9));
            Ok(())
        });
    }

    /// The O(n + E) lazy CSR build path is ENTRYWISE BITWISE the
    /// composed build (`induced().metropolis().lazy()`) over random
    /// topology families × active sets — ISSUE 7's substitution
    /// guarantee for the churn engine as a property, not just the fixed
    /// pin in `topology::tests`.
    #[test]
    fn induced_lazy_csr_matches_composed_build_over_families_and_active_sets() {
        forall(40, 0x70_09, |g| {
            let t = random_topology(g);
            let n = t.n();
            let active: Vec<bool> = (0..n).map(|_| g.bool(0.7)).collect();
            let direct = t.induced_metropolis_lazy_csr(&active);
            let composed = t.induced(&active).metropolis().lazy();
            crate::prop_assert!(
                direct.nnz() == composed.nnz(),
                "nnz {} vs {}",
                direct.nnz(),
                composed.nnz()
            );
            for i in 0..n {
                for j in 0..n {
                    crate::prop_assert!(
                        direct.at(i, j).to_bits() == composed.at(i, j).to_bits(),
                        "entry ({i},{j}): {} vs {}",
                        direct.at(i, j),
                        composed.at(i, j)
                    );
                }
            }
            crate::prop_assert!(direct.is_doubly_stochastic(1e-9));
            Ok(())
        });
    }

    /// The hierarchical scheme conserves the GLOBAL active-set mean:
    /// shard means mix on an A_s-weighted aggregator ring whose detailed
    /// balance keeps Σ_s A_s·v_s invariant every round
    /// (consensus::hierarchical) — over random families, shard counts,
    /// round budgets, and active sets.
    #[test]
    fn hierarchical_consensus_conserves_global_active_mean_over_families() {
        use crate::consensus::hierarchical::HierarchicalConsensus;
        use crate::util::matrix::NodeMatrix;
        let active_mean = |msgs: &NodeMatrix, active: &[bool], c: usize| -> f64 {
            let (mut s, mut k) = (0.0f64, 0usize);
            for i in 0..msgs.n() {
                if active[i] {
                    s += msgs.row(i)[c] as f64;
                    k += 1;
                }
            }
            s / k as f64
        };
        forall(30, 0x41_10, |g| {
            let t = random_topology(g);
            let n = t.n();
            let d = g.usize_in(1, 6);
            let mut active: Vec<bool> = (0..n).map(|_| g.bool(0.8)).collect();
            // at least one active node, so the mean is well defined
            let pin = g.usize_in(0, n - 1);
            active[pin] = true;
            let mut msgs = NodeMatrix::new(n, d);
            for i in 0..n {
                for c in 0..d {
                    msgs.row_mut(i)[c] = g.f32_in(-4.0, 4.0);
                }
            }
            let before: Vec<f64> = (0..d).map(|c| active_mean(&msgs, &active, c)).collect();
            let mut h = HierarchicalConsensus::new(&t, g.usize_in(1, 5));
            h.run(&mut msgs, g.usize_in(0, 6), g.usize_in(0, 8), &active);
            for c in 0..d {
                crate::prop_assert_close!(active_mean(&msgs, &active, c), before[c], 1e-4);
            }
            Ok(())
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // interior mutability not needed: use a Cell via closure trick
        let counter = std::cell::Cell::new(0usize);
        forall(50, 1, |g| {
            counter.set(counter.get() + 1);
            let x = g.f64_in(0.0, 1.0);
            prop_assert!((0.0..1.0).contains(&x));
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        forall(50, 2, |g| {
            let n = g.usize_in(0, 10);
            prop_assert!(n < 9, "n was {}", n);
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = |tag: u64| {
            let out = std::cell::RefCell::new(Vec::new());
            forall(10, tag, |g| {
                out.borrow_mut().push(g.u64());
                Ok(())
            });
            out.into_inner()
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }

    #[test]
    fn prop_assert_close_tolerance() {
        forall(10, 3, |g| {
            let x = g.f64_in(-5.0, 5.0);
            prop_assert_close!(x, x + 1e-12, 1e-9);
            Ok(())
        });
    }
}
