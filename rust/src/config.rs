//! Experiment configuration: JSON round-trip for [`RunSpec`]-level
//! settings plus named presets for every experiment in the paper, so a
//! run is fully described by a small config file:
//!
//! ```text
//! amb run --config configs/fig1a_amb.json
//! ```
//!
//! (No serde in the offline vendor set — uses util::json.)

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::churn::ChurnSpec;
use crate::coordinator::{ConsensusMode, RunSpec, Scheme};
use crate::fault::{CrashWindow, FaultSpec, Flap};
use crate::net::{FabricSpec, NetworkModel};
use crate::util::json::Json;

/// A full experiment description: scheduler + workload + environment.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub run: RunSpec,
    /// "linreg" | "logreg"
    pub workload: String,
    /// "shiftedexp" | "induced" | "pause" | "none"
    pub straggler: String,
    /// nodes (ignored for models with intrinsic n like induced/pause)
    pub nodes: usize,
    /// shifted-exp parameters (when applicable)
    pub zeta: f64,
    pub lambda: f64,
    pub unit_batch: usize,
}

impl ExperimentConfig {
    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        let scheme = match self.run.scheme {
            Scheme::Amb { t_compute, t_consensus } => Json::obj(vec![
                ("kind", Json::str("amb")),
                ("t_compute", Json::num(t_compute)),
                ("t_consensus", Json::num(t_consensus)),
            ]),
            Scheme::Fmb { per_node_batch, t_consensus } => Json::obj(vec![
                ("kind", Json::str("fmb")),
                ("per_node_batch", Json::num(per_node_batch as f64)),
                ("t_consensus", Json::num(t_consensus)),
            ]),
            Scheme::FmbBackup { per_node_batch, t_consensus, ignore, coded } => Json::obj(vec![
                ("kind", Json::str("fmb_backup")),
                ("per_node_batch", Json::num(per_node_batch as f64)),
                ("t_consensus", Json::num(t_consensus)),
                ("ignore", Json::num(ignore as f64)),
                ("coded", Json::Bool(coded)),
            ]),
            Scheme::AmbDg { t_compute, t_consensus, delay } => Json::obj(vec![
                ("kind", Json::str("amb_dg")),
                ("t_compute", Json::num(t_compute)),
                ("t_consensus", Json::num(t_consensus)),
                ("delay", Json::num(delay as f64)),
            ]),
        };
        let consensus = match self.run.consensus {
            ConsensusMode::Exact => Json::obj(vec![("kind", Json::str("exact"))]),
            ConsensusMode::Gossip { rounds } => Json::obj(vec![
                ("kind", Json::str("gossip")),
                ("rounds", Json::num(rounds as f64)),
            ]),
            ConsensusMode::GossipJitter { mean, jitter } => Json::obj(vec![
                ("kind", Json::str("gossip_jitter")),
                ("mean", Json::num(mean as f64)),
                ("jitter", Json::num(jitter as f64)),
            ]),
            ConsensusMode::Hierarchical { shards, intra_rounds, inter_rounds } => Json::obj(vec![
                ("kind", Json::str("hierarchical")),
                ("shards", Json::num(shards as f64)),
                ("intra_rounds", Json::num(intra_rounds as f64)),
                ("inter_rounds", Json::num(inter_rounds as f64)),
            ]),
        };
        let churn = match &self.run.churn {
            ChurnSpec::None => Json::obj(vec![("kind", Json::str("none"))]),
            ChurnSpec::IidDropout { p, seed } => Json::obj(vec![
                ("kind", Json::str("iid")),
                ("p", Json::num(*p)),
                ("seed", Json::num(*seed as f64)),
            ]),
            ChurnSpec::Markov { p_down, p_up, seed } => Json::obj(vec![
                ("kind", Json::str("markov")),
                ("p_down", Json::num(*p_down)),
                ("p_up", Json::num(*p_up)),
                ("seed", Json::num(*seed as f64)),
            ]),
            ChurnSpec::Trace { active } => Json::obj(vec![
                ("kind", Json::str("trace")),
                (
                    "active",
                    Json::arr(
                        active
                            .iter()
                            .map(|row| Json::arr(row.iter().map(|&b| Json::Bool(b)))),
                    ),
                ),
            ]),
        };
        // util::json has no infinity literal, so unconstrained bandwidth
        // (f64::INFINITY) is encoded as 0 — an otherwise-invalid value
        // the parser maps back.
        let enc_bw = |bw: f64| if bw.is_finite() { bw } else { 0.0 };
        let network = match &self.run.network {
            NetworkModel::Abstract => Json::obj(vec![("kind", Json::str("abstract"))]),
            NetworkModel::Fabric(f) => Json::obj(vec![
                ("kind", Json::str("fabric")),
                ("latency", Json::num(f.local.latency)),
                ("bandwidth", Json::num(enc_bw(f.local.bandwidth))),
                ("wan_latency", Json::num(f.wan.latency)),
                ("wan_bandwidth", Json::num(enc_bw(f.wan.bandwidth))),
                ("groups", Json::num(f.groups as f64)),
                ("min_gap", Json::num(f.min_gap)),
            ]),
        };
        let faults = {
            let f = &self.run.faults;
            let mut fields = vec![
                ("loss", Json::num(f.loss)),
                ("timeout", Json::num(f.round_timeout)),
                ("seed", Json::num(f.seed as f64)),
            ];
            if let Some(fl) = f.flap {
                fields.push((
                    "flap",
                    Json::obj(vec![
                        ("p_down", Json::num(fl.p_down)),
                        ("p_up", Json::num(fl.p_up)),
                    ]),
                ));
            }
            // A permanent window (`to = usize::MAX`) is encoded by
            // omitting "to" (util::json numbers are f64 — MAX would
            // not survive the round trip).
            fields.push((
                "crashes",
                Json::arr(f.crashes.iter().map(|c| {
                    let mut cf = vec![
                        ("node", Json::num(c.node as f64)),
                        ("from", Json::num(c.from as f64)),
                    ];
                    if c.to != usize::MAX {
                        cf.push(("to", Json::num(c.to as f64)));
                    }
                    Json::obj(cf)
                })),
            ));
            Json::obj(fields)
        };
        Json::obj(vec![
            ("name", Json::str(&self.run.name)),
            ("scheme", scheme),
            ("consensus", consensus),
            ("churn", churn),
            ("network", network),
            ("faults", faults),
            ("epochs", Json::num(self.run.epochs as f64)),
            ("seed", Json::num(self.run.seed as f64)),
            ("exact_bt", Json::Bool(self.run.exact_bt)),
            ("record_node_log", Json::Bool(self.run.record_node_log)),
            ("grad_chunk", Json::num(self.run.grad_chunk as f64)),
            (
                "slowdown",
                Json::arr(self.run.slowdown.iter().map(|&f| Json::num(f))),
            ),
            ("time_scale", Json::num(self.run.time_scale)),
            ("workload", Json::str(&self.workload)),
            ("straggler", Json::str(&self.straggler)),
            ("nodes", Json::num(self.nodes as f64)),
            ("zeta", Json::num(self.zeta)),
            ("lambda", Json::num(self.lambda)),
            ("unit_batch", Json::num(self.unit_batch as f64)),
        ])
    }

    /// Parse from JSON text.
    pub fn from_json(text: &str) -> Result<ExperimentConfig> {
        let j = Json::parse(text).context("config json")?;
        let req_str =
            |k: &str| j.get(k).and_then(|v| v.as_str()).with_context(|| format!("missing '{k}'"));
        let req_num =
            |k: &str| j.get(k).and_then(|v| v.as_f64()).with_context(|| format!("missing '{k}'"));

        let sj = j.get("scheme").context("missing 'scheme'")?;
        let sk = sj.get("kind").and_then(|v| v.as_str()).context("scheme.kind")?;
        let snum = |k: &str| {
            sj.get(k).and_then(|v| v.as_f64()).with_context(|| format!("scheme.{k}"))
        };
        let scheme = match sk {
            "amb" => Scheme::Amb { t_compute: snum("t_compute")?, t_consensus: snum("t_consensus")? },
            "fmb" => Scheme::Fmb {
                per_node_batch: snum("per_node_batch")? as usize,
                t_consensus: snum("t_consensus")?,
            },
            "fmb_backup" => Scheme::FmbBackup {
                per_node_batch: snum("per_node_batch")? as usize,
                t_consensus: snum("t_consensus")?,
                ignore: snum("ignore")? as usize,
                coded: sj.get("coded").and_then(|v| v.as_bool()).unwrap_or(false),
            },
            "amb_dg" => Scheme::AmbDg {
                t_compute: snum("t_compute")?,
                t_consensus: snum("t_consensus")?,
                delay: sj
                    .get("delay")
                    .and_then(|v| v.as_usize())
                    .context("scheme.delay (whole epochs of gradient staleness)")?,
            },
            other => bail!("unknown scheme kind '{other}'"),
        };

        let cj = j.get("consensus").context("missing 'consensus'")?;
        let consensus = match cj.get("kind").and_then(|v| v.as_str()) {
            Some("exact") => ConsensusMode::Exact,
            Some("gossip") => ConsensusMode::Gossip {
                rounds: cj.get("rounds").and_then(|v| v.as_usize()).context("rounds")?,
            },
            Some("gossip_jitter") => ConsensusMode::GossipJitter {
                mean: cj.get("mean").and_then(|v| v.as_usize()).context("mean")?,
                jitter: cj.get("jitter").and_then(|v| v.as_usize()).context("jitter")?,
            },
            Some("hierarchical") => {
                let shards =
                    cj.get("shards").and_then(|v| v.as_usize()).context("shards")?;
                if shards == 0 {
                    bail!("consensus.shards must be >= 1");
                }
                ConsensusMode::Hierarchical {
                    shards,
                    intra_rounds: cj
                        .get("intra_rounds")
                        .and_then(|v| v.as_usize())
                        .context("intra_rounds")?,
                    inter_rounds: cj
                        .get("inter_rounds")
                        .and_then(|v| v.as_usize())
                        .context("inter_rounds")?,
                }
            }
            other => bail!("unknown consensus kind {other:?}"),
        };

        let slowdown: Vec<f64> = match j.get("slowdown") {
            Some(Json::Arr(v)) => v
                .iter()
                .map(|x| x.as_f64().context("slowdown entries must be numbers"))
                .collect::<Result<_>>()?,
            _ => Vec::new(),
        };
        if !slowdown.iter().all(|f| f.is_finite() && *f >= 1.0) {
            bail!("slowdown factors must be finite and >= 1.0 (got {slowdown:?})");
        }

        // Optional churn block; absent (pre-churn configs) means static
        // membership, so old config files keep loading unchanged.
        let churn = match j.get("churn") {
            None => ChurnSpec::None,
            Some(cj) => {
                let prob = |k: &str| -> Result<f64> {
                    let p = cj
                        .get(k)
                        .and_then(|v| v.as_f64())
                        .with_context(|| format!("churn.{k}"))?;
                    if !(0.0..=1.0).contains(&p) {
                        bail!("churn.{k} = {p} not in [0, 1]");
                    }
                    Ok(p)
                };
                let seed = || -> Result<u64> {
                    Ok(cj.get("seed").and_then(|v| v.as_f64()).context("churn.seed")? as u64)
                };
                match cj.get("kind").and_then(|v| v.as_str()) {
                    Some("none") => ChurnSpec::None,
                    Some("iid") => ChurnSpec::IidDropout { p: prob("p")?, seed: seed()? },
                    Some("markov") => ChurnSpec::Markov {
                        p_down: prob("p_down")?,
                        p_up: prob("p_up")?,
                        seed: seed()?,
                    },
                    Some("trace") => {
                        let rows = match cj.get("active") {
                            Some(Json::Arr(rows)) => rows
                                .iter()
                                .map(|row| match row {
                                    Json::Arr(cells) => cells
                                        .iter()
                                        .map(|c| {
                                            c.as_bool()
                                                .context("churn.active cells must be booleans")
                                        })
                                        .collect::<Result<Vec<bool>>>(),
                                    _ => bail!("churn.active rows must be arrays"),
                                })
                                .collect::<Result<Vec<Vec<bool>>>>()?,
                            _ => bail!("churn.active must be an array of arrays"),
                        };
                        // Validate HERE, like every other field, so a
                        // malformed config is a clean load-time error and
                        // not a run-time assert inside ChurnSchedule::new.
                        if rows.iter().any(|r| r.is_empty()) {
                            bail!("churn.active rows must be non-empty");
                        }
                        let nodes = req_num("nodes")? as usize;
                        if rows.len() != nodes {
                            bail!(
                                "churn.active has {} rows but the config declares {} nodes",
                                rows.len(),
                                nodes
                            );
                        }
                        ChurnSpec::Trace { active: rows }
                    }
                    other => bail!("unknown churn kind {other:?}"),
                }
            }
        };
        // Optional network block; absent (pre-fabric configs) means the
        // abstract round budget, so old config files keep loading
        // unchanged.  Bandwidth 0 decodes to f64::INFINITY (see to_json).
        let network = match j.get("network") {
            None => NetworkModel::Abstract,
            Some(nj) => match nj.get("kind").and_then(|v| v.as_str()) {
                Some("abstract") => NetworkModel::Abstract,
                Some("fabric") => {
                    let num = |k: &str| -> Result<f64> {
                        nj.get(k).and_then(|v| v.as_f64()).with_context(|| format!("network.{k}"))
                    };
                    let dec_bw = |bw: f64| -> Result<f64> {
                        if bw == 0.0 {
                            Ok(f64::INFINITY)
                        } else if bw > 0.0 {
                            Ok(bw)
                        } else {
                            bail!("network bandwidth must be >= 0 (0 = unconstrained)")
                        }
                    };
                    let lat = num("latency")?;
                    let bw = dec_bw(num("bandwidth")?)?;
                    if !(lat.is_finite() && lat >= 0.0) {
                        bail!("network.latency must be finite and >= 0 (got {lat})");
                    }
                    let mut fab = FabricSpec::uniform(lat, bw);
                    let min_gap = match nj.get("min_gap") {
                        None => 0.0,
                        Some(v) => v.as_f64().context("network.min_gap must be a number")?,
                    };
                    if !(min_gap.is_finite() && min_gap >= 0.0) {
                        bail!("network.min_gap must be finite and >= 0 (got {min_gap})");
                    }
                    fab = fab.with_min_gap(min_gap);
                    let groups = match nj.get("groups") {
                        None => 1,
                        Some(v) => {
                            let g = v.as_usize().context("network.groups must be a number")?;
                            if g == 0 {
                                bail!("network.groups must be >= 1");
                            }
                            g
                        }
                    };
                    let wan_lat = match nj.get("wan_latency") {
                        None => lat,
                        Some(v) => v.as_f64().context("network.wan_latency")?,
                    };
                    let wan_bw = match nj.get("wan_bandwidth") {
                        None => bw,
                        Some(v) => dec_bw(v.as_f64().context("network.wan_bandwidth")?)?,
                    };
                    if !(wan_lat.is_finite() && wan_lat >= 0.0) {
                        bail!("network.wan_latency must be finite and >= 0 (got {wan_lat})");
                    }
                    NetworkModel::Fabric(fab.with_wan(wan_lat, wan_bw, groups))
                }
                other => bail!("unknown network kind {other:?}"),
            },
        };
        // Optional faults block; absent (pre-fault configs) means the
        // all-clear spec, so old config files keep loading unchanged.
        let faults = match j.get("faults") {
            None => FaultSpec::none(),
            Some(fj) => {
                let num = |k: &str, default: f64| -> Result<f64> {
                    match fj.get(k) {
                        None => Ok(default),
                        Some(v) => {
                            v.as_f64().with_context(|| format!("faults.{k} must be a number"))
                        }
                    }
                };
                let flap = match fj.get("flap") {
                    None => None,
                    Some(flj) => Some(Flap {
                        p_down: flj
                            .get("p_down")
                            .and_then(|v| v.as_f64())
                            .context("faults.flap.p_down")?,
                        p_up: flj
                            .get("p_up")
                            .and_then(|v| v.as_f64())
                            .context("faults.flap.p_up")?,
                    }),
                };
                let crashes = match fj.get("crashes") {
                    None => Vec::new(),
                    Some(Json::Arr(items)) => items
                        .iter()
                        .map(|c| {
                            let cnum = |k: &str| {
                                c.get(k)
                                    .and_then(|v| v.as_usize())
                                    .with_context(|| format!("faults.crashes[].{k}"))
                            };
                            Ok(CrashWindow {
                                node: cnum("node")?,
                                from: cnum("from")?,
                                // omitted "to" = permanent
                                to: match c.get("to") {
                                    None => usize::MAX,
                                    Some(v) => {
                                        v.as_usize().context("faults.crashes[].to")?
                                    }
                                },
                            })
                        })
                        .collect::<Result<Vec<_>>>()?,
                    Some(_) => bail!("faults.crashes must be an array"),
                };
                let spec = FaultSpec {
                    loss: num("loss", 0.0)?,
                    flap,
                    crashes,
                    round_timeout: num("timeout", 0.0)?,
                    seed: num("seed", 0.0)? as u64,
                };
                // Range checks at load time, like churn/network (the
                // node-vs-cluster-size check re-runs with the real n
                // inside the runtimes).
                spec.validate(usize::MAX)?;
                spec
            }
        };
        Ok(ExperimentConfig {
            run: RunSpec {
                name: req_str("name")?.to_string(),
                scheme,
                consensus,
                epochs: req_num("epochs")? as usize,
                seed: req_num("seed")? as u64,
                exact_bt: j.get("exact_bt").and_then(|v| v.as_bool()).unwrap_or(false),
                record_node_log: j
                    .get("record_node_log")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
                // validate like time_scale below: a zero chunk would
                // stall the threaded quota loop
                grad_chunk: match j.get("grad_chunk") {
                    None => 16,
                    Some(v) => {
                        let gc = v.as_usize().context("grad_chunk must be a number")?;
                        if gc == 0 {
                            bail!("grad_chunk must be positive");
                        }
                        gc
                    }
                },
                slowdown,
                time_scale: match j.get("time_scale") {
                    None => 1.0,
                    Some(v) => {
                        let ts = v.as_f64().context("time_scale must be a number")?;
                        if ts <= 0.0 {
                            bail!("time_scale must be positive (got {ts})");
                        }
                        ts
                    }
                },
                churn,
                network,
                faults,
            },
            workload: req_str("workload")?.to_string(),
            straggler: req_str("straggler")?.to_string(),
            nodes: req_num("nodes")? as usize,
            zeta: j.get("zeta").and_then(|v| v.as_f64()).unwrap_or(1.0),
            lambda: j.get("lambda").and_then(|v| v.as_f64()).unwrap_or(2.0 / 3.0),
            unit_batch: j.get("unit_batch").and_then(|v| v.as_usize()).unwrap_or(600),
        })
    }

    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        ExperimentConfig::from_json(&text)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

/// Named presets for every paper experiment (paper parameters verbatim
/// where published; see DESIGN.md §4).
pub fn preset(name: &str) -> Result<ExperimentConfig> {
    let base = |run: RunSpec, workload: &str, straggler: &str, nodes: usize,
                zeta: f64, lambda: f64, unit: usize| ExperimentConfig {
        run,
        workload: workload.into(),
        straggler: straggler.into(),
        nodes,
        zeta,
        lambda,
        unit_batch: unit,
    };
    Ok(match name {
        "fig1a_amb" => base(
            RunSpec::amb("fig1a-amb", 14.5, 4.5, 5, 24, 42),
            "linreg", "shiftedexp", 10, 12.5, 0.5, 600,
        ),
        "fig1a_fmb" => base(
            RunSpec::fmb("fig1a-fmb", 600, 4.5, 5, 24, 42),
            "linreg", "shiftedexp", 10, 12.5, 0.5, 600,
        ),
        "fig1b_amb" => base(
            RunSpec::amb("fig1b-amb", 12.0, 3.0, 5, 20, 42),
            "logreg", "shiftedexp", 10, 8.0, 0.25, 800,
        ),
        "fig1b_fmb" => base(
            RunSpec::fmb("fig1b-fmb", 800, 3.0, 5, 20, 42),
            "logreg", "shiftedexp", 10, 8.0, 0.25, 800,
        ),
        "fig4_amb" => base(
            RunSpec::amb("fig4-amb", 2.5, 0.5, 5, 20, 42),
            "linreg", "shiftedexp", 20, 1.0, 2.0 / 3.0, 600,
        ),
        "fig7_amb" => base(
            RunSpec::amb("fig7-amb", 12.0, 3.0, 5, 24, 42),
            "logreg", "induced", 10, 0.0, 0.0, 585,
        ),
        "fig9_amb" => base(
            RunSpec::amb("fig9-amb", 115.0, 10.0, 1, 60, 42)
                .with_consensus(ConsensusMode::Exact),
            "logreg", "pause", 50, 0.0, 0.0, 10,
        ),
        other => bail!("unknown preset '{other}' (see config::preset)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_all_schemes() {
        for name in ["fig1a_amb", "fig1a_fmb", "fig9_amb"] {
            let cfg = preset(name).unwrap();
            let text = cfg.to_json().to_string();
            let back = ExperimentConfig::from_json(&text).unwrap();
            assert_eq!(back.run.scheme, cfg.run.scheme, "{name}");
            assert_eq!(back.run.consensus, cfg.run.consensus);
            assert_eq!(back.run.epochs, cfg.run.epochs);
            assert_eq!(back.workload, cfg.workload);
            assert_eq!(back.nodes, cfg.nodes);
            assert_eq!(back.run.grad_chunk, cfg.run.grad_chunk);
            assert_eq!(back.run.slowdown, cfg.run.slowdown);
            assert!((back.run.time_scale - cfg.run.time_scale).abs() < 1e-12);
        }
    }

    #[test]
    fn amb_dg_scheme_roundtrip() {
        let mut cfg = preset("fig1a_amb").unwrap();
        for delay in [0usize, 1, 4] {
            cfg.run.scheme = Scheme::AmbDg { t_compute: 14.5, t_consensus: 4.5, delay };
            let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
            assert_eq!(back.run.scheme, cfg.run.scheme, "delay {delay}");
        }
        // a delayed scheme without the delay field is an error, not a
        // silent default
        let text = cfg.to_json().to_string();
        assert!(text.contains("\"kind\":\"amb_dg\""));
        let missing = text.replace(",\"delay\":4", "");
        assert!(ExperimentConfig::from_json(&missing).is_err());
    }

    #[test]
    fn backup_scheme_roundtrip() {
        let mut cfg = preset("fig1a_fmb").unwrap();
        cfg.run.scheme =
            Scheme::FmbBackup { per_node_batch: 100, t_consensus: 1.0, ignore: 2, coded: true };
        cfg.run = cfg.run.with_grad_chunk(64).with_slowdown(vec![3.0, 1.0]).with_time_scale(0.25);
        let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.run.scheme, cfg.run.scheme);
        assert_eq!(back.run.grad_chunk, 64);
        assert_eq!(back.run.slowdown, vec![3.0, 1.0]);
        assert!((back.run.time_scale - 0.25).abs() < 1e-12);
    }

    #[test]
    fn hierarchical_consensus_roundtrip() {
        let mut cfg = preset("fig1a_amb").unwrap();
        for consensus in [
            ConsensusMode::Hierarchical { shards: 1, intra_rounds: 5, inter_rounds: 0 },
            ConsensusMode::Hierarchical { shards: 8, intra_rounds: 6, inter_rounds: 4 },
        ] {
            cfg.run = cfg.run.clone().with_consensus(consensus);
            let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
            assert_eq!(back.run.consensus, consensus);
        }
        // zero shards rejected at load time
        let text = cfg.to_json().to_string();
        assert!(text.contains("\"kind\":\"hierarchical\""));
        assert!(ExperimentConfig::from_json(
            &text.replace("\"shards\":8", "\"shards\":0")
        )
        .is_err());
        // missing budget fields are errors, not silent defaults
        assert!(ExperimentConfig::from_json(
            &text.replace(",\"inter_rounds\":4", "")
        )
        .is_err());
    }

    #[test]
    fn churn_roundtrip_all_kinds() {
        let mut cfg = preset("fig1a_amb").unwrap();
        // one trace row per configured node (the parser validates this)
        let mut trace_rows = vec![vec![true]; cfg.nodes];
        trace_rows[0] = vec![true, false];
        for churn in [
            ChurnSpec::None,
            ChurnSpec::IidDropout { p: 0.2, seed: 7 },
            ChurnSpec::Markov { p_down: 0.05, p_up: 0.3, seed: 9 },
            ChurnSpec::Trace { active: trace_rows },
        ] {
            cfg.run = cfg.run.clone().with_churn(churn.clone());
            let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
            assert_eq!(back.run.churn, churn);
        }
        // configs written before the churn field default to static
        let pre_churn = preset("fig1a_amb").unwrap().to_json().to_string();
        let stripped = {
            // the preset serialises churn kind "none"; removing the block
            // entirely must still parse (backwards compatibility)
            assert!(pre_churn.contains("churn"));
            pre_churn.replace("\"churn\":{\"kind\":\"none\"},", "")
        };
        let back = ExperimentConfig::from_json(&stripped).unwrap();
        assert!(back.run.churn.is_none());
        // invalid probability rejected
        cfg.run = cfg.run.clone().with_churn(ChurnSpec::IidDropout { p: 0.2, seed: 7 });
        let text = cfg.to_json().to_string();
        assert!(ExperimentConfig::from_json(&text.replace("\"p\":0.2", "\"p\":1.5")).is_err());
        // trace shape mismatches rejected at load time, not run time
        cfg.run = cfg
            .run
            .clone()
            .with_churn(ChurnSpec::Trace { active: vec![vec![true]; cfg.nodes - 1] });
        assert!(ExperimentConfig::from_json(&cfg.to_json().to_string()).is_err());
        cfg.run = cfg.run.clone().with_churn(ChurnSpec::Trace {
            active: vec![Vec::new(); cfg.nodes],
        });
        assert!(ExperimentConfig::from_json(&cfg.to_json().to_string()).is_err());
    }

    #[test]
    fn faults_roundtrip_all_kinds() {
        let mut cfg = preset("fig1a_amb").unwrap();
        for faults in [
            FaultSpec::none(),
            FaultSpec { loss: 0.25, seed: 7, ..FaultSpec::none() },
            FaultSpec { flap: Some(Flap { p_down: 0.1, p_up: 0.5 }), ..FaultSpec::none() },
            FaultSpec {
                loss: 0.05,
                crashes: vec![
                    CrashWindow { node: 2, from: 3, to: 5 },
                    // permanent window survives the omitted-"to" encoding
                    CrashWindow { node: 0, from: 10, to: usize::MAX },
                ],
                round_timeout: 0.125,
                seed: 42,
                ..FaultSpec::none()
            },
        ] {
            cfg.run = cfg.run.clone().with_faults(faults.clone());
            let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
            assert_eq!(back.run.faults, faults);
        }
        // configs written before the faults field load as all-clear
        let pre_faults = preset("fig1a_amb").unwrap().to_json().to_string();
        assert!(pre_faults.contains("\"faults\":{\"loss\":0,\"timeout\":0,\"seed\":0,\"crashes\":[]}"));
        let stripped = pre_faults
            .replace(",\"faults\":{\"loss\":0,\"timeout\":0,\"seed\":0,\"crashes\":[]}", "");
        let back = ExperimentConfig::from_json(&stripped).unwrap();
        assert!(back.run.faults.is_none());
        assert_eq!(back.run.faults, FaultSpec::none());
        // invalid values rejected at load time, not run time
        cfg.run =
            cfg.run.clone().with_faults(FaultSpec { loss: 0.25, ..FaultSpec::none() });
        let text = cfg.to_json().to_string();
        assert!(
            ExperimentConfig::from_json(&text.replace("\"loss\":0.25", "\"loss\":1.5")).is_err()
        );
        assert!(
            ExperimentConfig::from_json(&text.replace("\"loss\":0.25", "\"loss\":\"all\""))
                .is_err()
        );
    }

    #[test]
    fn network_roundtrip_all_kinds() {
        let mut cfg = preset("fig1a_amb").unwrap();
        for network in [
            NetworkModel::Abstract,
            NetworkModel::Fabric(FabricSpec::uniform(0.005, 2.0e5)),
            // unconstrained bandwidth survives the 0-encoding round trip
            NetworkModel::Fabric(FabricSpec::ideal()),
            NetworkModel::Fabric(
                FabricSpec::uniform(0.001, 1.0e6).with_wan(0.05, 1.0e5, 2).with_min_gap(0.002),
            ),
        ] {
            cfg.run = cfg.run.clone().with_network(network.clone());
            let back = ExperimentConfig::from_json(&cfg.to_json().to_string()).unwrap();
            assert_eq!(back.run.network, network);
        }
        // configs written before the network field default to abstract
        let pre_net = preset("fig1a_amb").unwrap().to_json().to_string();
        assert!(pre_net.contains("\"network\":{\"kind\":\"abstract\"}"));
        let stripped = pre_net.replace(",\"network\":{\"kind\":\"abstract\"}", "");
        let back = ExperimentConfig::from_json(&stripped).unwrap();
        assert!(back.run.network.is_abstract());
        // invalid values rejected at load time
        cfg.run = cfg
            .run
            .clone()
            .with_network(NetworkModel::Fabric(FabricSpec::uniform(0.005, 2.0e5)));
        let text = cfg.to_json().to_string();
        assert!(ExperimentConfig::from_json(
            &text.replace("\"latency\":0.005", "\"latency\":-1")
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &text.replace("\"bandwidth\":200000", "\"bandwidth\":-5")
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &text.replace("\"groups\":1", "\"groups\":0")
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &text.replace("\"kind\":\"fabric\"", "\"kind\":\"carrier-pigeon\"")
        )
        .is_err());
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join("amb_config_test");
        let path = dir.join("x.json");
        let cfg = preset("fig1b_amb").unwrap();
        cfg.save(&path).unwrap();
        let back = ExperimentConfig::load(&path).unwrap();
        assert_eq!(back.run.name, "fig1b-amb");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_preset_and_bad_json_error() {
        assert!(preset("nope").is_err());
        assert!(ExperimentConfig::from_json("{}").is_err());
        assert!(ExperimentConfig::from_json("not json").is_err());
    }

    #[test]
    fn nonpositive_time_scale_rejected_at_parse() {
        let text = preset("fig1a_amb").unwrap().to_json().to_string();
        assert!(text.contains("\"time_scale\":1"));
        let bad = text.replace("\"time_scale\":1", "\"time_scale\":-1");
        assert!(ExperimentConfig::from_json(&bad).is_err());
        let zero = text.replace("\"time_scale\":1", "\"time_scale\":0");
        assert!(ExperimentConfig::from_json(&zero).is_err());
        let badgc = text.replace("\"grad_chunk\":16", "\"grad_chunk\":0");
        assert!(ExperimentConfig::from_json(&badgc).is_err());
    }
}
