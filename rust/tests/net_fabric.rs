//! Network-fabric acceptance tests (ISSUE 6): the ideal fabric (zero
//! latency, unconstrained bandwidth) must reproduce abstract runs
//! bitwise — rounds log, per-epoch stats, and final primal — with and
//! without churn; a congested hub-spoke fabric must measurably complete
//! fewer gossip rounds per T_c than a ring on identical links; and
//! fabric runs must be bit-reproducible and restricted to the sim
//! runtime's Gossip mode.

use std::sync::Arc;

use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::net::FabricSpec;
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::{
    ChurnSpec, ConsensusMode, NetworkModel, RunOutput, RunSpec, Runtime, Scheme, SimRuntime,
};

fn try_run_sim(spec: &RunSpec, topo: &Topology) -> anyhow::Result<RunOutput> {
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(24, 5)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 400.0), 4.0 * 24f64.sqrt());
    let f_star = src.f_star();
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(src.clone(), opt.clone()))
    };
    SimRuntime::new(&strag).run(spec, topo, &mk, f_star)
}

fn run_sim(spec: &RunSpec, topo: &Topology) -> RunOutput {
    try_run_sim(spec, topo).unwrap()
}

/// Full-output bitwise equality: primal bits, per-epoch stat bits, the
/// rounds log, and the membership log.
fn assert_bitwise_eq(a: &RunOutput, b: &RunOutput, what: &str) {
    assert_eq!(a.rounds, b.rounds, "{what}: rounds log");
    assert_eq!(a.active_counts, b.active_counts, "{what}: active counts");
    assert_eq!(a.final_w.as_slice().len(), b.final_w.as_slice().len(), "{what}: w shape");
    for (i, (x, y)) in a.final_w.as_slice().iter().zip(b.final_w.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final_w word {i}");
    }
    assert_eq!(a.record.epochs.len(), b.record.epochs.len(), "{what}: epoch count");
    for (x, y) in a.record.epochs.iter().zip(&b.record.epochs) {
        assert_eq!(x.batch, y.batch, "{what}: batch @ {}", x.epoch);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss @ {}", x.epoch);
        assert_eq!(x.error.to_bits(), y.error.to_bits(), "{what}: error @ {}", x.epoch);
        assert_eq!(
            x.consensus_err.to_bits(),
            y.consensus_err.to_bits(),
            "{what}: consensus_err @ {}",
            x.epoch
        );
        assert_eq!(
            x.wall_time.to_bits(),
            y.wall_time.to_bits(),
            "{what}: wall_time @ {}",
            x.epoch
        );
    }
}

fn ideal() -> NetworkModel {
    NetworkModel::Fabric(FabricSpec::ideal())
}

#[test]
fn ideal_fabric_reproduces_abstract_across_schemes() {
    // The ISSUE 6 acceptance pin, over every scheme family that
    // gossips: an ideal fabric measures the full cap for every node, so
    // the run must be bitwise the abstract run.
    let topo = Topology::paper_fig2();
    let schemes = [
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 },
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 2 },
    ];
    for scheme in schemes {
        let base = RunSpec::new(scheme.name(), scheme, 5, 13)
            .with_consensus(ConsensusMode::Gossip { rounds: 5 });
        let abstract_out = run_sim(&base, &topo);
        let fabric_out = run_sim(&base.clone().with_network(ideal()), &topo);
        assert_bitwise_eq(&abstract_out, &fabric_out, scheme.name());
        // and the rounds really are the cap, not coincidentally zero
        assert!(fabric_out.rounds.iter().all(|r| r == &vec![5usize; base.epochs]));
    }
}

#[test]
fn ideal_fabric_reproduces_abstract_under_churn() {
    // Churn exercises the per-node freeze path (inactive rows restored
    // after every mix): the ideal fabric must still match bitwise
    // because uniform budgets freeze nothing and restores of inactive
    // e_i rows are bitwise no-ops.
    let topo = Topology::ring(8);
    let base = RunSpec::amb("churned", 2.0, 0.5, 5, 6, 13)
        .with_churn(ChurnSpec::IidDropout { p: 0.3, seed: 11 });
    let abstract_out = run_sim(&base, &topo);
    let fabric_out = run_sim(&base.clone().with_network(ideal()), &topo);
    // the schedule must actually drop somebody for this test to bite
    assert!(
        abstract_out.active_counts.iter().any(|&a| a < 8),
        "churn schedule dropped nobody — raise p or change seed"
    );
    assert_bitwise_eq(&abstract_out, &fabric_out, "iid-churn");
}

#[test]
fn hub_spoke_completes_fewer_rounds_than_ring() {
    // Same 20 nodes, same uniform 5 ms / 200 kB/s links, same T_c and
    // cap: the hub's egress port serializes 19 rows per round where a
    // ring node sends 2, so the measured budget collapses.
    let fab = NetworkModel::Fabric(FabricSpec::uniform(0.005, 2.0e5));
    let spec = RunSpec::amb("contention", 2.0, 0.5, 8, 4, 13).with_network(fab);
    let ring = run_sim(&spec, &Topology::ring(20));
    let hub = run_sim(&spec, &Topology::hub_spoke(19));
    let mean = |out: &RunOutput| {
        out.rounds.iter().map(|r| r[0]).sum::<usize>() as f64 / out.rounds.len() as f64
    };
    let (rm, hm) = (mean(&ring), mean(&hub));
    assert!(rm > 0.0, "ring made no progress");
    assert!(hm < rm, "expected uplink contention: hub {hm} vs ring {rm}");
    // per-node measurements are epoch-invariant under static membership
    for out in [&ring, &hub] {
        for r in &out.rounds {
            assert!(r.iter().all(|&x| x == r[0]), "rounds drifted: {r:?}");
        }
    }
}

#[test]
fn fabric_runs_are_bit_reproducible() {
    let fab = NetworkModel::Fabric(FabricSpec::uniform(0.002, 1.0e5).with_min_gap(0.001));
    let spec = RunSpec::amb("repro", 2.0, 0.5, 10, 5, 13).with_network(fab);
    let topo = Topology::hub_spoke(9);
    let a = run_sim(&spec, &topo);
    let b = run_sim(&spec, &topo);
    assert_bitwise_eq(&a, &b, "repeat run");
}

#[test]
fn fabric_rejects_non_gossip_modes() {
    let topo = Topology::ring(4);
    for mode in [
        ConsensusMode::Exact,
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
    ] {
        let spec = RunSpec::amb("bad", 2.0, 0.5, 5, 2, 13)
            .with_consensus(mode)
            .with_network(ideal());
        let err = try_run_sim(&spec, &topo)
            .expect_err("Fabric must reject non-Gossip consensus");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("requires ConsensusMode::Gossip"),
            "unexpected error message: {msg}"
        );
    }
}
