//! Integration: full simulated AMB/FMB runs across straggler models and
//! topologies — the paper's qualitative claims at test scale, through
//! the unified `RunSpec` → `anytime_mb::run` API.

use std::sync::Arc;

use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::metrics::RunRecord;
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::{InducedGroups, PauseModel, ShiftedExp, StragglerModel};
use anytime_mb::topology::Topology;
use anytime_mb::{ConsensusMode, RunOutput, RunSpec, SimRuntime};

fn linreg(d: usize, seed: u64) -> (Arc<DataSource>, DualAveraging) {
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 1000.0), 4.0 * (d as f64).sqrt());
    (src, opt)
}

fn native_factory(
    src: Arc<DataSource>,
    opt: DualAveraging,
) -> impl Fn(usize) -> Box<dyn ExecEngine> + Send + Sync {
    move |_| Box::new(NativeExec::new(src.clone(), opt.clone()))
}

fn sim_run(
    spec: &RunSpec,
    topo: &Topology,
    strag: &dyn StragglerModel,
    src: &Arc<DataSource>,
    opt: &DualAveraging,
) -> RunOutput {
    let mk = native_factory(src.clone(), opt.clone());
    anytime_mb::run(&SimRuntime::new(strag), spec, topo, &mk, src.f_star()).unwrap()
}

fn sim_record(
    spec: &RunSpec,
    topo: &Topology,
    strag: &dyn StragglerModel,
    src: &Arc<DataSource>,
    opt: &DualAveraging,
) -> RunRecord {
    sim_run(spec, topo, strag, src, opt).record
}

/// Headline claim: AMB reaches the same error in less wall time than FMB
/// under heterogeneous compute (shifted exponential with high dispersion).
#[test]
fn amb_beats_fmb_on_wall_time() {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 0.5, unit_batch: 200 };
    let (src, opt) = linreg(64, 3);
    let epochs = 20;

    let amb = sim_record(&RunSpec::amb("amb", 3.0, 0.5, 6, epochs, 7), &topo, &strag, &src, &opt);
    let fmb = sim_record(&RunSpec::fmb("fmb", 200, 0.5, 6, epochs, 7), &topo, &strag, &src, &opt);

    let target = amb.epochs.last().unwrap().error.max(fmb.epochs.last().unwrap().error) * 2.0;
    let (ta, tb, speedup) = anytime_mb::metrics::speedup_at(&amb, &fmb, target).unwrap();
    assert!(speedup > 1.0, "AMB {ta}s vs FMB {tb}s (speedup {speedup})");
}

/// Per-epoch (not per-second) the two schemes are statistically matched
/// when T is set per Lemma 6 — the AMB advantage is wall time only.
#[test]
fn amb_and_fmb_match_per_epoch() {
    let topo = Topology::paper_fig2();
    // T = (1+n/b)*mu with mu = 2, b = 2000: T ≈ 2.01
    let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 200 };
    let (src, opt) = linreg(64, 5);
    let epochs = 15;

    let amb = sim_record(&RunSpec::amb("amb", 2.01, 0.5, 8, epochs, 11), &topo, &strag, &src, &opt);
    let fmb = sim_record(&RunSpec::fmb("fmb", 200, 0.5, 8, epochs, 11), &topo, &strag, &src, &opt);

    let ea = amb.epochs.last().unwrap().error;
    let ef = fmb.epochs.last().unwrap().error;
    let ratio = ea / ef;
    assert!(
        (0.2..5.0).contains(&ratio),
        "per-epoch errors should be same order: amb={ea} fmb={ef}"
    );
    // ... but AMB's epochs take deterministic time vs FMB's straggler-gated
    assert!(amb.total_time() < fmb.total_time());
}

/// Regret grows sublinearly in total samples (Thm. 2 / Cor. 3 shape:
/// R(τ)/m → 0, i.e. average regret per sample decays).
#[test]
fn regret_per_sample_decays() {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 100 };
    let (src, opt) = linreg(32, 9);
    let rec = sim_record(&RunSpec::amb("amb", 2.0, 0.5, 8, 40, 13), &topo, &strag, &src, &opt);

    let regret = rec.regret_series().expect("linreg knows F(w*)");
    let samples: Vec<f64> = rec
        .epochs
        .iter()
        .scan(0.0, |acc, e| {
            *acc += e.batch as f64;
            Some(*acc)
        })
        .collect();
    let early = regret[4] / samples[4];
    let late = regret.last().unwrap() / samples.last().unwrap();
    assert!(
        late < early * 0.5,
        "avg regret/sample should decay: early={early} late={late}"
    );
    // and R(τ)/√m should stay bounded (within a loose constant factor)
    let c_early = regret[4] / samples[4].sqrt();
    let c_late = regret.last().unwrap() / samples.last().unwrap().sqrt();
    assert!(c_late < c_early * 3.0, "R/√m blew up: {c_early} -> {c_late}");
}

/// Induced stragglers (App. I.3 model): AMB's advantage grows vs the
/// clean cluster — the paper's headline qualitative claim.
#[test]
fn straggler_variability_widens_gap() {
    let topo = Topology::paper_fig2();
    let (src, opt) = linreg(64, 17);
    let epochs = 15;

    let speedup_under = |strag: &dyn StragglerModel, t_amb: f64, b: usize, seed: u64| -> f64 {
        let amb =
            sim_record(&RunSpec::amb("amb", t_amb, 0.5, 6, epochs, seed), &topo, strag, &src, &opt);
        let fmb =
            sim_record(&RunSpec::fmb("fmb", b, 0.5, 6, epochs, seed), &topo, strag, &src, &opt);
        let target = amb.epochs.last().unwrap().error.max(fmb.epochs.last().unwrap().error) * 2.0;
        anytime_mb::metrics::speedup_at(&amb, &fmb, target).map(|x| x.2).unwrap_or(1.0)
    };

    // Low variability: sigma/mu = 0.25
    let low = ShiftedExp { zeta: 1.5, lambda: 2.0, unit_batch: 100 };
    // High variability: 3-group induced stragglers over the same mean-ish
    let high = InducedGroups {
        factors: vec![3.0, 3.0, 3.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0],
        base_zeta: 0.8,
        base_lambda: 2.0,
        unit_batch: 100,
    };
    let s_low = speedup_under(&low, 2.0, 100, 21);
    let s_high = speedup_under(&high, 2.0, 100, 21);
    assert!(
        s_high > s_low,
        "gap should widen with variability: low={s_low} high={s_high}"
    );
}

/// Hub-and-spoke with exact aggregation (paper Remark 1: ε = 0) matches
/// gossip-with-many-rounds on the same workload.
#[test]
fn exact_consensus_is_gossip_limit() {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 100 };
    let (src, opt) = linreg(32, 23);
    let epochs = 10;

    let exact_spec = RunSpec::amb("exact", 2.0, 0.5, 1, epochs, 31)
        .with_consensus(ConsensusMode::Exact);
    let exact = sim_record(&exact_spec, &topo, &strag, &src, &opt);

    let gossip = sim_record(
        &RunSpec::amb("gossip", 2.0, 0.5, 200, epochs, 31),
        &topo,
        &strag,
        &src,
        &opt,
    );

    let ee = exact.epochs.last().unwrap().error;
    let eg = gossip.epochs.last().unwrap().error;
    assert!(
        (ee - eg).abs() / ee.max(1e-12) < 0.05,
        "exact={ee} gossip(200 rounds)={eg}"
    );
}

/// The pause model (App. I.4) slots into the same coordinator unchanged.
#[test]
fn pause_model_end_to_end() {
    let strag = PauseModel {
        groups: vec![(3, 5.0, 1.0), (3, 20.0, 2.0), (4, 55.0, 5.0)],
        per_grad_base: 1.0,
    };
    let topo = Topology::erdos_connected(10, 0.4, 1);
    let (src, opt) = linreg(32, 29);
    let spec = RunSpec::amb("amb-pause", 115.0, 10.0, 6, 12, 37).with_node_log();
    let out = sim_run(&spec, &topo, &strag, &src, &opt);
    let log = out.node_log.unwrap();
    // group ordering visible in batches
    let mean = |node: usize| -> f64 {
        log.batches[node].iter().map(|&b| b as f64).sum::<f64>() / 12.0
    };
    assert!(mean(0) > 2.0 * mean(9), "fast {} vs slow {}", mean(0), mean(9));
    // training still progressed
    let errs = &out.record.epochs;
    assert!(errs.last().unwrap().error < errs[0].error);
}

/// Different topologies with the same workload: better-connected graphs
/// give lower consensus error for the same round budget.
#[test]
fn topology_affects_consensus_error() {
    let strag = ShiftedExp { zeta: 1.0, lambda: 1.0, unit_batch: 100 };
    let (src, opt) = linreg(32, 41);
    let avg_err = |topo: &Topology| -> f64 {
        let rec = sim_record(&RunSpec::amb("amb", 2.0, 0.5, 3, 8, 43), topo, &strag, &src, &opt);
        rec.epochs.iter().map(|e| e.consensus_err).sum::<f64>() / 8.0
    };
    let ring = avg_err(&Topology::ring(10));
    let complete = avg_err(&Topology::complete(10));
    assert!(complete < ring, "complete={complete} ring={ring}");
}
