//! Pinned bitwise golden traces for every `Scheme` × `ConsensusMode` on
//! the sim runtime at a fixed seed, so refactors cannot silently drift
//! numerics (ISSUE 5).
//!
//! Each trace compresses one run into a single line: the per-epoch
//! batch sequence, an FNV-1a fingerprint over `final_w`'s raw f32 bits,
//! the final loss/error/wall-time bit patterns, the final regret bits,
//! and the staleness column.  Every quantity is covered by the
//! determinism contract (one spec + one seed ⇒ bitwise identical output
//! at ANY thread count), so the same pins must verify under
//! `AMB_THREADS=1` and `AMB_THREADS=4` — CI regenerates the pin file in
//! its serial leg and verifies it in the pooled leg, which turns the
//! pins into a cross-thread-count golden gate even before a maintainer
//! commits them.
//!
//! Workflow:
//! * `cargo test --test golden_traces` — always checks self-consistency
//!   (two in-process runs bitwise equal; `AmbDg { delay: 0 }` ≡ `Amb`)
//!   and, when `tests/golden/pins.txt` exists, compares every trace
//!   against it.
//! * `cargo test --test golden_traces regen_golden_pins -- --ignored` —
//!   the regen helper: writes `tests/golden/pins.txt` with fresh pins
//!   and prints them.  Commit the file to pin numerics across refactors;
//!   re-run the helper (and review the diff!) when a change is MEANT to
//!   move them.

use std::sync::Arc;

use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::net::FabricSpec;
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::ShiftedExp;
use anytime_mb::topology::Topology;
use anytime_mb::{
    ConsensusMode, NetworkModel, RunOutput, RunSpec, Runtime, Scheme, SimRuntime,
};

const PINS_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/pins.txt");

/// The pinned grid: every scheme variant (including the degenerate and
/// a deep AMB-DG pipeline) × every consensus mode.
fn schemes() -> Vec<Scheme> {
    vec![
        Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 },
        Scheme::Fmb { per_node_batch: 40, t_consensus: 0.5 },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: false },
        Scheme::FmbBackup { per_node_batch: 40, t_consensus: 0.5, ignore: 2, coded: true },
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 0 },
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 2 },
    ]
}

fn modes() -> Vec<ConsensusMode> {
    vec![
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 5 },
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
    ]
}

fn run_sim(spec: &RunSpec) -> RunOutput {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(24, 5)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 400.0), 4.0 * 24f64.sqrt());
    let f_star = src.f_star();
    let mk = move |_i: usize| -> Box<dyn ExecEngine> {
        Box::new(NativeExec::new(src.clone(), opt.clone()))
    };
    SimRuntime::new(&strag).run(spec, &topo, &mk, f_star).unwrap()
}

/// FNV-1a over a word stream — stable, dependency-free fingerprint.
fn fnv64(words: impl Iterator<Item = u64>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The scheme's label in a pin line (disambiguates the two AmbDg pins).
fn scheme_label(s: &Scheme) -> String {
    format!("{} d={}", s.name(), s.delay())
}

fn mode_label(m: &ConsensusMode) -> String {
    match m {
        ConsensusMode::Exact => "exact".into(),
        ConsensusMode::Gossip { rounds } => format!("gossip{rounds}"),
        ConsensusMode::GossipJitter { mean, jitter } => format!("jitter{mean}±{jitter}"),
        ConsensusMode::Hierarchical { shards, intra_rounds, inter_rounds } => {
            format!("hier{shards}-{intra_rounds}-{inter_rounds}")
        }
    }
}

/// One run compressed to a pin line's CONTENT (everything after the
/// label, so `AmbDg {{ delay: 0 }}` content can be compared to `Amb`'s).
fn trace_content(out: &RunOutput) -> String {
    let batches: Vec<usize> = out.record.epochs.iter().map(|e| e.batch).collect();
    let stale: Vec<usize> = out.record.epochs.iter().map(|e| e.max_staleness).collect();
    let w_fp = fnv64(out.final_w.as_slice().iter().map(|x| x.to_bits() as u64));
    let last = out.record.epochs.last().expect("runs record epochs");
    let regret = match out.record.regret_series() {
        Some(r) => format!("{:016x}", r.last().expect("non-empty").to_bits()),
        None => "none".into(),
    };
    format!(
        "batches={batches:?} stale={stale:?} w=fnv:{w_fp:016x} loss={:016x} err={:016x} \
         wall={:016x} regret={regret}",
        last.loss.to_bits(),
        last.error.to_bits(),
        last.wall_time.to_bits(),
    )
}

/// Every pin line: the scheme × mode grid, then the network-fabric pins
/// (ISSUE 6) — an ideal fabric whose content must equal the abstract
/// `amb × gossip5` grid line bitwise, and a bandwidth-constrained fabric
/// (100-byte wire rows at 2 kB/s make T_c = 0.5 bind below the cap of 8)
/// pinning the measured-rounds numerics themselves.
fn all_traces() -> Vec<String> {
    let mut lines = Vec::new();
    for scheme in schemes() {
        for mode in modes() {
            let spec = RunSpec::new(scheme.name(), scheme, 5, 13).with_consensus(mode);
            let out = run_sim(&spec);
            lines.push(format!(
                "{} × {}: {}",
                scheme_label(&scheme),
                mode_label(&mode),
                trace_content(&out)
            ));
        }
    }
    let amb = Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 };
    let fabrics = [
        ("gossip5+ideal-fabric", 5usize, FabricSpec::ideal()),
        ("gossip8+fabric", 8, FabricSpec::uniform(0.005, 2.0e3)),
    ];
    for (label, rounds, fab) in fabrics {
        let spec = RunSpec::new(amb.name(), amb, 5, 13)
            .with_consensus(ConsensusMode::Gossip { rounds })
            .with_network(NetworkModel::Fabric(fab));
        let out = run_sim(&spec);
        lines.push(format!("{} × {}: {}", scheme_label(&amb), label, trace_content(&out)));
    }
    // ISSUE 7: one hierarchical-consensus pin (sim-only mode, so it rides
    // outside the scheme × mode grid; appended last to keep every
    // hard-coded trace index above stable).
    let hier = ConsensusMode::Hierarchical { shards: 3, intra_rounds: 4, inter_rounds: 3 };
    let spec = RunSpec::new(amb.name(), amb, 5, 13).with_consensus(hier);
    let out = run_sim(&spec);
    lines.push(format!("{} × {}: {}", scheme_label(&amb), mode_label(&hier), trace_content(&out)));
    lines
}

#[test]
fn golden_traces_are_self_consistent_and_match_pins() {
    let traces = all_traces();

    // Run-to-run bitwise determinism of the full trace set (at whatever
    // thread count this process runs with).
    let again = all_traces();
    assert_eq!(traces, again, "same seed, same process: traces must be bitwise stable");

    // AmbDg { delay: 0 } reproduces Amb bit for bit in every mode — the
    // acceptance contract, enforced at trace granularity.
    let n_modes = modes().len();
    for (k, mode) in modes().iter().enumerate() {
        let amb = traces[k].split_once(": ").expect("label: content").1;
        let dg0 = traces[4 * n_modes + k].split_once(": ").expect("label: content").1;
        assert_eq!(
            amb, dg0,
            "AmbDg {{ delay: 0 }} diverged from Amb under {}",
            mode_label(mode)
        );
    }

    // ISSUE 6 acceptance: the ideal fabric (zero latency, unconstrained
    // bandwidth) reproduces the abstract `amb × gossip5` trace bitwise —
    // compare content against grid index 1 (amb is scheme 0, gossip5 is
    // mode 1).  The constrained-fabric pin (last line) must differ: the
    // link budget binds, which is the whole point of measuring.
    let n_grid = schemes().len() * n_modes;
    let amb_gossip5 = traces[1].split_once(": ").expect("label: content").1;
    let ideal_fab = traces[n_grid].split_once(": ").expect("label: content").1;
    assert_eq!(
        amb_gossip5, ideal_fab,
        "ideal fabric diverged from the abstract gossip run"
    );
    let constrained = traces[n_grid + 1].split_once(": ").expect("label: content").1;
    assert_ne!(
        amb_gossip5, constrained,
        "the constrained fabric should bind below the abstract budget"
    );

    // Compare against the pinned file when present.  CI writes it via
    // the regen helper in the serial leg, so the pooled leg (and any
    // committed pins) verify here.
    match std::fs::read_to_string(PINS_PATH) {
        Ok(pinned) => {
            let pinned: Vec<&str> =
                pinned.lines().filter(|l| !l.is_empty() && !l.starts_with('#')).collect();
            assert_eq!(
                pinned.len(),
                traces.len(),
                "pin file has {} traces, this build produces {} — regen the pins \
                 (cargo test --test golden_traces regen_golden_pins -- --ignored)",
                pinned.len(),
                traces.len()
            );
            for (pin, got) in pinned.iter().zip(&traces) {
                assert_eq!(
                    *pin, got,
                    "golden trace drifted — if the numerics change is intended, regen \
                     the pins and review the diff"
                );
            }
        }
        Err(_) => {
            eprintln!(
                "golden_traces: no pin file at {PINS_PATH}; self-consistency checks ran, \
                 but traces were NOT compared against pins.  Generate them with \
                 `cargo test --test golden_traces regen_golden_pins -- --ignored`."
            );
        }
    }
}

#[test]
#[ignore = "regen helper: writes tests/golden/pins.txt; run with --ignored to refresh pins"]
fn regen_golden_pins() {
    let traces = all_traces();
    let dir = std::path::Path::new(PINS_PATH).parent().expect("pins live in a directory");
    std::fs::create_dir_all(dir).expect("create tests/golden");
    let mut body = String::from(
        "# Golden bitwise traces (sim runtime, seed 13, 5 epochs, paper fig-2 topology).\n\
         # Regenerate: cargo test --test golden_traces regen_golden_pins -- --ignored\n",
    );
    for line in &traces {
        body.push_str(line);
        body.push('\n');
    }
    std::fs::write(PINS_PATH, &body).expect("write pins");
    println!("wrote {} traces to {PINS_PATH}:\n{}", traces.len(), body);
}
