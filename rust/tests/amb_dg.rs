//! AMB-DG invariants (ISSUE 5 acceptance):
//!
//! * `AmbDg { delay: 0 }` reproduces `Amb` BIT FOR BIT on the simulator
//!   (through the pipeline ring, not around it) for every consensus
//!   mode, and runs the stock AMB schedule on the threaded runtime.
//! * sim ↔ threaded AMB-DG parity: the deterministic surfaces — the
//!   pipelined wall-clock cadence, the staleness columns, warm-up
//!   structure, membership — agree exactly; the stochastic surfaces
//!   (real-hardware batch sizes) agree qualitatively, matching the
//!   tolerance philosophy of `tests/runtime_parity.rs` (anytime batches
//!   are hardware-dependent, so unlike FMB they cannot be compared
//!   numerically).
//! * AMB-DG × churn: a delayed gradient computed by a node that churns
//!   out is still applied EXACTLY once, after it rejoins (the pipeline
//!   freezes across absence; staleness exceeds D by the epochs missed).

use std::sync::Arc;

mod common;
use common::assert_bitwise_equal;

use anytime_mb::churn::ChurnSpec;
use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::{Deterministic, ShiftedExp, StragglerModel};
use anytime_mb::topology::Topology;
use anytime_mb::{
    ConsensusMode, RunOutput, RunSpec, Runtime, Scheme, SimRuntime, ThreadedRuntime,
};

fn linreg_factory(
    d: usize,
    seed: u64,
) -> (
    impl Fn(usize) -> Box<dyn ExecEngine> + Send + Sync,
    Option<f64>,
) {
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 400.0), 4.0 * (d as f64).sqrt());
    let f_star = src.f_star();
    (
        move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        },
        f_star,
    )
}

fn run_sim(spec: &RunSpec, topo: &Topology, strag: &dyn StragglerModel) -> RunOutput {
    let (mk, f_star) = linreg_factory(24, 5);
    SimRuntime::new(strag).run(spec, topo, &mk, f_star).unwrap()
}

/// Acceptance: `AmbDg { delay: 0 }` ≡ `Amb` bitwise on the simulator,
/// for every consensus mode — through the pipeline ring.
#[test]
fn dg_zero_delay_is_amb_bitwise_on_sim() {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    let modes = [
        ConsensusMode::Exact,
        ConsensusMode::Gossip { rounds: 5 },
        ConsensusMode::GossipJitter { mean: 5, jitter: 2 },
    ];
    for mode in modes {
        let amb = RunSpec::new("amb", Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 }, 6, 13)
            .with_consensus(mode);
        let dg0 = RunSpec::new(
            "dg0",
            Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 0 },
            6,
            13,
        )
        .with_consensus(mode);
        let a = run_sim(&amb, &topo, &strag);
        let d = run_sim(&dg0, &topo, &strag);
        assert_bitwise_equal(&a, &d, &format!("D=0 vs AMB under {mode:?}"));
    }
}

/// ... and under churn, too: the degenerate pipeline must also track
/// AMB bitwise when membership fluctuates (active nodes push AND pop
/// every participating epoch at D = 0).
#[test]
fn dg_zero_delay_is_amb_bitwise_on_sim_under_churn() {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    let churn = ChurnSpec::IidDropout { p: 0.25, seed: 31 };
    let amb = RunSpec::new("amb", Scheme::Amb { t_compute: 2.0, t_consensus: 0.5 }, 6, 13)
        .with_churn(churn.clone());
    let dg0 = RunSpec::new(
        "dg0",
        Scheme::AmbDg { t_compute: 2.0, t_consensus: 0.5, delay: 0 },
        6,
        13,
    )
    .with_churn(churn);
    let a = run_sim(&amb, &topo, &strag);
    let d = run_sim(&dg0, &topo, &strag);
    assert_eq!(a.active_counts, d.active_counts);
    assert_bitwise_equal(&a, &d, "D=0 vs AMB under churn");
}

/// Threaded: D = 0 runs the stock AMB path — same absolute T + T_c
/// schedule (deterministic in spec units, so it compares exactly across
/// two real-time runs), zero staleness, no warm-up gap.
#[test]
fn dg_zero_delay_matches_amb_schedule_on_threaded() {
    let topo = Topology::ring(4);
    let (mk, f_star) = linreg_factory(16, 2);
    let amb = RunSpec::amb("amb-t", 0.06, 0.04, 3, 4, 5).with_grad_chunk(16);
    let dg0 = RunSpec::amb_dg("dg0-t", 0.06, 0.04, 0, 3, 4, 5).with_grad_chunk(16);
    let a = ThreadedRuntime.run(&amb, &topo, &mk, f_star).unwrap();
    let d = ThreadedRuntime.run(&dg0, &topo, &mk, f_star).unwrap();
    assert_eq!(a.record.epochs.len(), d.record.epochs.len());
    for (x, y) in a.record.epochs.iter().zip(&d.record.epochs) {
        // the absolute schedule is a pure function of the spec: bitwise
        assert_eq!(x.wall_time.to_bits(), y.wall_time.to_bits(), "epoch {}", x.epoch);
        assert_eq!(y.max_staleness, 0);
        assert_eq!(y.mean_staleness.to_bits(), 0.0f64.to_bits());
        assert!(x.batch > 0 && y.batch > 0, "no warm-up gap at D = 0");
    }
}

/// sim ↔ threaded AMB-DG parity: every deterministic surface agrees —
/// wall cadence max(T, T_c), warm-up epochs, staleness columns,
/// membership — and both runtimes make progress once the pipeline is
/// warm (batch sizes themselves are hardware-dependent on threads, as
/// for AMB; see the module doc).
#[test]
fn dg_parity_sim_threaded() {
    let topo = Topology::ring(4);
    let delay = 1usize;
    let epochs = 6usize;
    let spec = RunSpec::amb_dg("dg-parity", 0.06, 0.04, delay, 3, epochs, 21)
        .with_grad_chunk(16);
    let strag = Deterministic { unit_time: 0.01, unit_batch: 48 };

    let sim = run_sim(&spec, &topo, &strag);
    let (mk, f_star) = linreg_factory(24, 5);
    let thr = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();

    assert_eq!(sim.record.epochs.len(), thr.record.epochs.len());
    assert_eq!(sim.active_counts, thr.active_counts);
    for (t0, (es, et)) in sim.record.epochs.iter().zip(&thr.record.epochs).enumerate() {
        let t = t0 + 1;
        // pipelined cadence: both runtimes tick in max(T, T_c) steps
        let expect = 0.06 * t as f64;
        assert!((es.wall_time - expect).abs() < 1e-9, "sim wall @ {t}: {}", es.wall_time);
        assert!((et.wall_time - expect).abs() < 1e-9, "thr wall @ {t}: {}", et.wall_time);
        if t <= delay {
            // warm-up: nothing applied anywhere
            assert_eq!(es.batch, 0, "sim epoch {t}");
            assert_eq!(et.batch, 0, "thr epoch {t}");
            assert!(es.mean_staleness.is_nan() && et.mean_staleness.is_nan());
        } else {
            assert!(es.batch > 0 && et.batch > 0, "epoch {t} applied nothing");
            assert_eq!(es.max_staleness, delay, "sim staleness @ {t}");
            assert_eq!(et.max_staleness, delay, "thr staleness @ {t}");
            assert!((es.mean_staleness - delay as f64).abs() < 1e-12);
            assert!((et.mean_staleness - delay as f64).abs() < 1e-12);
        }
    }
    // both runtimes learn once warm (first applied epoch vs last)
    for (name, out) in [("sim", &sim), ("threaded", &thr)] {
        let first = out.record.epochs[delay].error;
        let last = out.record.epochs.last().unwrap().error;
        assert!(
            last.is_finite() && last < first,
            "{name}: no progress ({first} -> {last})"
        );
    }
}

/// AMB-DG × churn: a batch computed before the node churns out stays in
/// its frozen pipeline and is applied EXACTLY once after rejoin.  With
/// a deterministic straggler every applied batch is hand-computable:
///
/// n = 4 ring, D = 1, 80 gradients per active epoch per node; node 3 is
/// absent in epoch 3 only.  Node 3's pipeline: e1 push (applies
/// nothing), e2 push + apply e1, e3 frozen, e4 push + apply e2 at
/// staleness 2 (the epoch missed), e5 push + apply e4.  Globally:
/// b(t) = [0, 320, 240, 320, 320] — epoch 4 proves the e2 batch was
/// neither dropped (b = 320, not 240) nor double-applied (not 400).
#[test]
fn dg_churn_applies_delayed_gradient_exactly_once() {
    let topo = Topology::ring(4);
    let strag = Deterministic { unit_time: 1.0, unit_batch: 40 };
    let trace = ChurnSpec::Trace {
        active: vec![
            vec![true],
            vec![true],
            vec![true],
            vec![true, true, false, true, true],
        ],
    };
    let spec = RunSpec::amb_dg("dg-churn", 2.0, 0.5, 1, 4, 5, 9)
        .with_node_log()
        .with_churn(trace);
    let out = run_sim(&spec, &topo, &strag);

    assert_eq!(out.active_counts, vec![4, 4, 3, 4, 4]);
    let batches: Vec<usize> = out.record.epochs.iter().map(|e| e.batch).collect();
    assert_eq!(batches, vec![0, 4 * 80, 3 * 80, 4 * 80, 4 * 80], "exactly-once violated");
    let stale: Vec<usize> = out.record.epochs.iter().map(|e| e.max_staleness).collect();
    assert_eq!(stale, vec![0, 1, 1, 2, 1], "the rejoin batch must age by the absence");
    // epoch 4's mean: three batches at staleness 1 + node 3's at 2,
    // sample-weighted: (3·80·1 + 80·2) / 320 = 1.25
    assert!((out.record.epochs[3].mean_staleness - 1.25).abs() < 1e-12);
    // computed view: node 3 worked in its four active epochs
    let log = out.node_log.as_ref().unwrap();
    assert_eq!(log.batches[3], vec![80, 80, 0, 80, 80]);
    // conservation: computed = applied + still-in-flight (one 80-batch
    // per node at the end of a D = 1 run)
    let computed: usize = log.batches.iter().flatten().sum();
    let applied: usize = batches.iter().sum();
    assert_eq!(computed, applied + 4 * 80);
}

/// The pipelined cadence claim end to end: same spec, D = 0 vs D = 2 —
/// identical compute weather (shared straggler stream), identical
/// per-epoch COMPUTED batches, 20% shorter epochs at T = 2, T_c = 0.5.
#[test]
fn dg_delay_trades_staleness_for_wall_time() {
    let topo = Topology::paper_fig2();
    let strag = ShiftedExp { zeta: 1.0, lambda: 2.0 / 3.0, unit_batch: 40 };
    let mk_spec = |d: usize| {
        RunSpec::amb_dg(&format!("dg-d{d}"), 2.0, 0.5, d, 5, 8, 17).with_node_log()
    };
    let d0 = run_sim(&mk_spec(0), &topo, &strag);
    let d2 = run_sim(&mk_spec(2), &topo, &strag);
    // identical computed batches per (node, epoch): the delay changes
    // WHEN a batch is applied, never what is computed
    assert_eq!(
        d0.node_log.as_ref().unwrap().batches,
        d2.node_log.as_ref().unwrap().batches
    );
    // wall time: 8 × 2.5 vs 8 × 2.0
    assert!((d0.record.total_time() - 20.0).abs() < 1e-9);
    assert!((d2.record.total_time() - 16.0).abs() < 1e-9);
    // the applied stream is the computed stream shifted by D
    let b0: Vec<usize> = d0.record.epochs.iter().map(|e| e.batch).collect();
    let b2: Vec<usize> = d2.record.epochs.iter().map(|e| e.batch).collect();
    assert_eq!(&b2[2..], &b0[..6], "applied batches must be the D-shifted computed stream");
    assert_eq!(&b2[..2], &[0, 0], "warm-up epochs apply nothing");
}
