//! Integration: PJRT-loaded artifacts vs the native-Rust oracles.
//!
//! This is the Rust end of the L1/L2 correctness bridge: the Python side
//! pins kernels to ref.py; here we pin the *compiled HLO artifacts*,
//! executed through the production runtime, to the independent native
//! implementations (DESIGN.md §6).
//!
//! Requires `make artifacts`; tests exit early (pass, with a note) when
//! artifacts are absent so `cargo test` works in a fresh checkout.

use std::rc::Rc;
use std::sync::Arc;

use anytime_mb::data::{LinRegStream, MnistLike, TokenStream};
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::runtime::{lit_f32, lit_scalar, to_f32, to_scalar, PjrtExec, PjrtRuntime, TransformerExec};
use anytime_mb::util::matrix::NodeMatrix;
use anytime_mb::util::rng::Pcg64;

fn runtime() -> Option<Rc<PjrtRuntime>> {
    let dir = anytime_mb::artifacts_dir();
    match PjrtRuntime::load(&dir) {
        Ok(rt) => Some(Rc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (no artifacts at {}): {e}", dir.display());
            None
        }
    }
}

fn optimizer() -> DualAveraging {
    DualAveraging::new(BetaSchedule::new(1.0, 1000.0), 500.0)
}

#[test]
fn linreg_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let d = rt.manifest.linreg_d;
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, 11)));
    let mut pjrt = PjrtExec::new(rt, src.clone(), optimizer()).unwrap();
    let mut native = NativeExec::new(src, optimizer());

    // Same RNG stream => same sampled data on both engines.
    for (n_samples, seed) in [(1usize, 1u64), (77, 2), (256, 3), (700, 4)] {
        let mut g = Pcg64::new(seed);
        let w: Vec<f32> = (0..d).map(|_| g.normal() as f32 * 0.1).collect();
        let mut acc_p = vec![0.0f32; d];
        let mut acc_n = vec![0.0f32; d];
        let lp = pjrt.grad_chunk(&w, n_samples, &mut Pcg64::new(seed ^ 0xF00), &mut acc_p);
        let ln = native.grad_chunk(&w, n_samples, &mut Pcg64::new(seed ^ 0xF00), &mut acc_n);
        let rel = (lp - ln).abs() / ln.abs().max(1e-9);
        assert!(rel < 1e-3, "loss mismatch n={n_samples}: pjrt={lp} native={ln}");
        for k in 0..d {
            assert!(
                (acc_p[k] - acc_n[k]).abs() < 1e-2 * (1.0 + acc_n[k].abs()),
                "grad[{k}] pjrt={} native={}",
                acc_p[k],
                acc_n[k]
            );
        }
    }
}

#[test]
fn logreg_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let (k, d) = (rt.manifest.logreg_k, rt.manifest.logreg_d);
    let src = Arc::new(DataSource::Mnist(MnistLike::new(k, d - 1, 1.0, 1.0, 13)));
    let mut pjrt = PjrtExec::new(rt, src.clone(), optimizer()).unwrap();
    let mut native = NativeExec::new(src, optimizer());

    for (n_samples, seed) in [(5usize, 21u64), (128, 22), (300, 23)] {
        let mut g = Pcg64::new(seed);
        let w: Vec<f32> = (0..k * d).map(|_| g.normal() as f32 * 0.05).collect();
        let mut acc_p = vec![0.0f32; k * d];
        let mut acc_n = vec![0.0f32; k * d];
        let lp = pjrt.grad_chunk(&w, n_samples, &mut Pcg64::new(seed ^ 0xB4), &mut acc_p);
        let ln = native.grad_chunk(&w, n_samples, &mut Pcg64::new(seed ^ 0xB4), &mut acc_n);
        assert!(
            (lp - ln).abs() / ln.abs().max(1e-9) < 1e-3,
            "loss mismatch: {lp} vs {ln}"
        );
        for j in 0..k * d {
            assert!(
                (acc_p[j] - acc_n[j]).abs() < 1e-2 * (1.0 + acc_n[j].abs()),
                "grad[{j}] {} vs {}",
                acc_p[j],
                acc_n[j]
            );
        }
    }
}

#[test]
fn dual_update_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let d = rt.manifest.linreg_d;
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, 5)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 600.0), 2.0);
    let mut pjrt = PjrtExec::new(rt, src, opt.clone()).unwrap();

    let mut g = Pcg64::new(31);
    for t in [1usize, 3, 10, 100] {
        let z: Vec<f32> = (0..d).map(|_| g.normal() as f32 * 10.0).collect();
        let mut w_p = vec![0.0f32; d];
        let mut w_n = vec![0.0f32; d];
        pjrt.primal_step(&z, t, &mut w_p);
        opt.primal_step(&z, t, &mut w_n);
        for k in 0..d {
            assert!(
                (w_p[k] - w_n[k]).abs() < 1e-4 * (1.0 + w_n[k].abs()),
                "t={t} w[{k}]: {} vs {}",
                w_p[k],
                w_n[k]
            );
        }
        // feasibility
        assert!(anytime_mb::util::norm2(&w_p) <= 2.0 * (1.0 + 1e-4));
    }
}

#[test]
fn mix_artifact_is_doubly_stochastic_average() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.mix_n;
    let d = rt.manifest.mix_d;
    let topo = anytime_mb::topology::Topology::erdos_connected(n, 0.5, 3);
    let p = topo.metropolis();
    let mut pf = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            pf[i * n + j] = p.at(i, j) as f32;
        }
    }
    let mut g = Pcg64::new(7);
    let m: Vec<f32> = (0..n * d).map(|_| g.normal() as f32).collect();

    let name = rt.manifest.mix_entry_name();
    let outs = rt
        .execute(&name, &[lit_f32(&[n, n], &pf).unwrap(), lit_f32(&[n, d], &m).unwrap()])
        .unwrap();
    let mixed = to_f32(&outs[0]).unwrap();

    // column means preserved (consensus conservation through the artifact)
    for col in 0..d {
        let before: f32 = (0..n).map(|i| m[i * d + col]).sum::<f32>() / n as f32;
        let after: f32 = (0..n).map(|i| mixed[i * d + col]).sum::<f32>() / n as f32;
        assert!((before - after).abs() < 1e-3, "col {col}: {before} vs {after}");
    }
    // matches native mix (the artifact's row-major [n × d] operand IS the
    // arena layout — no reshaping on either side)
    let mut msgs = NodeMatrix::new(n, d);
    msgs.as_mut_slice().copy_from_slice(&m);
    let mut out = NodeMatrix::new(n, d);
    p.mix_into(&msgs, &mut out);
    for i in 0..n {
        for c in 0..d {
            assert!((mixed[i * d + c] - out.row(i)[c]).abs() < 1e-3);
        }
    }
}

#[test]
fn transformer_artifact_sane_and_trains() {
    let Some(rt) = runtime() else { return };
    let vocab = rt.manifest.transformer.vocab;
    let tokens = Arc::new(TokenStream::new(vocab, 99));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 32.0), 1000.0);
    let mut exec = TransformerExec::new(rt, tokens, opt).unwrap();
    let dim = exec.workload().dim();
    let mut w = exec.initial_primal();
    assert_eq!(w.len(), dim);

    // init loss per token ≈ ln(vocab)
    let mut rng = Pcg64::new(1);
    let mut acc = vec![0.0f32; dim];
    let loss = exec.grad_chunk(&w, exec.batch, &mut rng, &mut acc);
    let per_tok = loss / exec.last_token_count;
    assert!(
        (per_tok - (vocab as f64).ln()).abs() < 1.0,
        "init loss/token {per_tok} vs ln(V) {}",
        (vocab as f64).ln()
    );

    // a few dual-averaging epochs reduce loss
    let mut z = vec![0.0f32; dim];
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for t in 1..=8 {
        acc.fill(0.0);
        let loss = exec.grad_chunk(&w, 2 * exec.batch, &mut rng, &mut acc);
        let per_tok = loss / exec.last_token_count;
        if t == 1 {
            first = per_tok;
        }
        last = per_tok;
        let toks = exec.last_token_count as f32;
        for k in 0..dim {
            z[k] += acc[k] / toks;
        }
        exec.primal_step(&z, t + 1, &mut w);
    }
    assert!(last < first, "no training progress: {first} -> {last}");
}

#[test]
fn raw_execute_linreg_matches_native_formula() {
    // Lowest-level check: hand-marshalled literals through rt.execute.
    let Some(rt) = runtime() else { return };
    let (c, d) = (rt.manifest.linreg_c, rt.manifest.linreg_d);
    let mut g = Pcg64::new(17);
    let w: Vec<f32> = (0..d).map(|_| g.normal() as f32).collect();
    let x: Vec<f32> = (0..c * d).map(|_| g.normal() as f32).collect();
    let y: Vec<f32> = (0..c).map(|_| g.normal() as f32).collect();
    let mask: Vec<f32> = (0..c).map(|i| (i % 3 != 0) as u8 as f32).collect();

    let name = rt.manifest.linreg_entry_name();
    let outs = rt
        .execute(
            &name,
            &[
                lit_f32(&[d], &w).unwrap(),
                lit_f32(&[c, d], &x).unwrap(),
                lit_f32(&[c], &y).unwrap(),
                lit_f32(&[c], &mask).unwrap(),
            ],
        )
        .unwrap();
    let grad = to_f32(&outs[0]).unwrap();
    let loss = to_scalar(&outs[1]).unwrap() as f64;

    let mut grad_n = vec![0.0f32; d];
    let loss_n = anytime_mb::model::linreg::grad_sum(&w, &x, &y, &mask, &mut grad_n);
    assert!((loss - loss_n).abs() / loss_n.abs().max(1e-9) < 1e-3);
    for k in 0..d {
        assert!((grad[k] - grad_n[k]).abs() < 1e-2 * (1.0 + grad_n[k].abs()));
    }
    // scalar literal helper sanity
    let _ = lit_scalar(1.5);
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    let name = rt.manifest.linreg_entry_name();
    let a = rt.executable(&name).unwrap();
    let b = rt.executable(&name).unwrap();
    assert!(Rc::ptr_eq(&a, &b), "second lookup must hit the cache");
}
