//! Runtime parity: the paper's claims must hold IDENTICALLY whether a
//! `RunSpec` is replayed on the discrete-event simulator or executed on
//! the real threaded cluster.  These tests pin the contract down:
//!
//! * one spec (small linreg, `ConsensusMode::Exact`, no slowdown) run on
//!   both runtimes produces records whose losses agree within tolerance
//!   (the runtimes share data RNG streams, the epoch state machine, and
//!   the exact-averaging arithmetic — only f32 summation order differs,
//!   because the threaded compute phase accumulates in `grad_chunk`s);
//! * two sim runs with equal seeds are bitwise identical;
//! * every `Scheme` variant executes on BOTH runtimes.

use std::sync::Arc;

use anytime_mb::data::LinRegStream;
use anytime_mb::exec::{DataSource, ExecEngine, NativeExec};
use anytime_mb::optim::{BetaSchedule, DualAveraging};
use anytime_mb::straggler::{Deterministic, ShiftedExp};
use anytime_mb::topology::Topology;
use anytime_mb::{ConsensusMode, RunOutput, RunSpec, Runtime, Scheme, SimRuntime, ThreadedRuntime};

fn linreg_factory(
    d: usize,
    seed: u64,
) -> (
    impl Fn(usize) -> Box<dyn ExecEngine> + Send + Sync,
    Option<f64>,
) {
    let src = Arc::new(DataSource::LinReg(LinRegStream::new(d, seed)));
    let opt = DualAveraging::new(BetaSchedule::new(1.0, 500.0), 4.0 * (d as f64).sqrt());
    let f_star = src.f_star();
    (
        move |_i: usize| -> Box<dyn ExecEngine> {
            Box::new(NativeExec::new(src.clone(), opt.clone()))
        },
        f_star,
    )
}

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

/// Same RunSpec → both runtimes → same learning trajectory.
///
/// FMB pins the per-node batch, Exact consensus pins the averaging, the
/// shared `coordinator::epoch` RNG derivations pin the data — so the two
/// runtimes see the same samples in the same order and must agree up to
/// f32 chunked-summation rounding.
#[test]
fn fmb_exact_same_spec_agrees_across_runtimes() {
    let topo = Topology::ring(4);
    let (mk, f_star) = linreg_factory(16, 2);
    let spec = RunSpec::fmb("parity", 48, 0.05, 1, 6, 21)
        .with_consensus(ConsensusMode::Exact)
        .with_grad_chunk(16);
    // The sim attributes time from a deterministic model; time never
    // enters the learning math, only the records' wall clock.
    let strag = Deterministic { unit_time: 0.01, unit_batch: 48 };

    let sim = SimRuntime::new(&strag).run(&spec, &topo, &mk, f_star).unwrap();
    let thr = ThreadedRuntime.run(&spec, &topo, &mk, f_star).unwrap();

    assert_eq!(sim.record.epochs.len(), thr.record.epochs.len());
    for (es, et) in sim.record.epochs.iter().zip(&thr.record.epochs) {
        // batch accounting is EXACTLY equal: the quota is the quota
        assert_eq!(es.batch, et.batch, "epoch {}", es.epoch);
        assert_eq!(es.min_node_batch, et.min_node_batch);
        assert_eq!(es.max_node_batch, et.max_node_batch);
        // losses agree to f32 reorder tolerance
        assert!(
            rel_diff(es.loss, et.loss) < 1e-2,
            "epoch {}: sim loss {} vs threaded {}",
            es.epoch,
            es.loss,
            et.loss
        );
    }
    let (ls, lt) = (
        sim.record.epochs.last().unwrap().loss,
        thr.record.epochs.last().unwrap().loss,
    );
    assert!(rel_diff(ls, lt) < 1e-2, "final loss: sim {ls} vs threaded {lt}");
    let (es, et) = (
        sim.record.epochs.last().unwrap().error,
        thr.record.epochs.last().unwrap().error,
    );
    assert!(rel_diff(es, et) < 5e-2, "final error: sim {es} vs threaded {et}");

    // final primals agree per node (the whole state machine matched)
    assert_eq!(sim.final_w.n(), thr.final_w.n());
    for (ws, wt) in sim.final_w.rows().zip(thr.final_w.rows()) {
        let mut diff = 0.0f64;
        let mut norm = 0.0f64;
        for k in 0..ws.len() {
            diff += ((ws[k] - wt[k]) as f64).powi(2);
            norm += (ws[k] as f64).powi(2);
        }
        assert!(
            diff.sqrt() < 1e-2 * norm.sqrt().max(1e-9),
            "final w rel diff {}",
            diff.sqrt() / norm.sqrt().max(1e-9)
        );
    }
}

/// Two sim runs with equal seeds are bitwise identical; a different seed
/// diverges.
#[test]
fn sim_equal_seeds_bitwise_identical() {
    let topo = Topology::paper_fig2();
    let (mk, f_star) = linreg_factory(24, 5);
    let strag = ShiftedExp { zeta: 0.5, lambda: 1.0, unit_batch: 60 };
    let run = |seed: u64| -> RunOutput {
        let spec = RunSpec::amb("det", 2.0, 0.5, 4, 8, seed);
        SimRuntime::new(&strag).run(&spec, &topo, &mk, f_star).unwrap()
    };
    let a = run(77);
    let b = run(77);
    for (ea, eb) in a.record.epochs.iter().zip(&b.record.epochs) {
        assert_eq!(ea.batch, eb.batch);
        assert_eq!(ea.loss.to_bits(), eb.loss.to_bits());
        assert_eq!(ea.error.to_bits(), eb.error.to_bits());
        assert_eq!(ea.consensus_err.to_bits(), eb.consensus_err.to_bits());
    }
    assert_eq!(a.final_w, b.final_w, "final primal arenas must be bitwise identical");
    let c = run(78);
    assert_ne!(
        a.record.epochs[3].batch, c.record.epochs[3].batch,
        "different seeds should differ (overwhelmingly likely)"
    );
}

/// Acceptance: every Scheme variant executes on BOTH runtimes through
/// the one entrypoint.
#[test]
fn every_scheme_runs_on_both_runtimes() {
    let topo = Topology::complete(4);
    let (mk, f_star) = linreg_factory(8, 9);
    let strag = ShiftedExp { zeta: 0.05, lambda: 20.0, unit_batch: 32 };
    let schemes: Vec<Scheme> = vec![
        Scheme::Amb { t_compute: 0.04, t_consensus: 0.03 },
        Scheme::Fmb { per_node_batch: 32, t_consensus: 0.03 },
        Scheme::FmbBackup { per_node_batch: 32, t_consensus: 0.03, ignore: 1, coded: false },
        Scheme::FmbBackup { per_node_batch: 32, t_consensus: 0.03, ignore: 1, coded: true },
        Scheme::AmbDg { t_compute: 0.04, t_consensus: 0.03, delay: 1 },
    ];
    let sim = SimRuntime::new(&strag);
    let runtimes: Vec<(&str, &dyn Runtime)> = vec![("sim", &sim), ("threaded", &ThreadedRuntime)];
    for scheme in &schemes {
        for (rt_name, rt) in &runtimes {
            let spec = RunSpec::new(scheme.name(), *scheme, 3, 13).with_grad_chunk(8);
            let out = anytime_mb::run(*rt, &spec, &topo, &mk, f_star).unwrap();
            assert_eq!(
                out.record.epochs.len(),
                3,
                "{} on {rt_name} lost epochs",
                scheme.name()
            );
            for e in &out.record.epochs {
                // A delayed pipeline applies nothing during its first
                // `delay` warm-up epochs — by design, on BOTH runtimes.
                if e.epoch <= scheme.delay() {
                    assert_eq!(
                        e.batch, 0,
                        "{} on {rt_name}: warm-up epoch {} applied work",
                        scheme.name(),
                        e.epoch
                    );
                    continue;
                }
                assert!(
                    e.batch > 0,
                    "{} on {rt_name}: empty epoch {}",
                    scheme.name(),
                    e.epoch
                );
            }
            assert_eq!(out.final_w.n(), 4);
            assert_eq!(out.rounds.len(), 4);
        }
    }
}
